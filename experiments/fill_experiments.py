"""Regenerate the §Dry-run and §Roofline sections of EXPERIMENTS.md from the
dry-run artifacts. Usage: PYTHONPATH=src python experiments/fill_experiments.py
"""

import json
import re
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch import roofline  # noqa: E402

ROOT = Path(__file__).resolve().parents[1]
EXP = ROOT / "EXPERIMENTS.md"


def dryrun_section() -> str:
    rows = ["Both meshes lower **and compile** for every applicable "
            "(arch × shape) cell; skips follow DESIGN.md §4 (long_500k on "
            "pure full-attention archs).", ""]
    for mesh in ("single", "multi"):
        reps = [r for r in roofline.load_all().values()
                if r.get("mesh") == mesh]
        ok = [r for r in reps if not r.get("skipped") and "error" not in r]
        err = [r for r in reps if "error" in r]
        rows.append(f"**{mesh}-pod** ({'256' if mesh == 'single' else '512'} "
                    f"chips): {len(ok)} cells compiled, {len(err)} errors.")
        rows.append("")
        rows.append("| arch | shape | compile s | HLO GFLOP/dev | "
                    "HBM GB/dev (args+temp) | collectives seen |")
        rows.append("|---|---|---|---|---|---|")
        for r in sorted(ok, key=lambda x: (x["arch"], x["shape"])):
            mem = r.get("memory", {})
            gb = (mem.get("argument_bytes", 0) + mem.get("temp_bytes", 0)) / 1e9
            coll = ",".join(sorted(r.get("collective_bytes", {})))
            corr = r.get("corrected", {}).get("flops", r.get("flops", 0))
            rows.append(f"| {r['arch']} | {r['shape']} | {r['compile_s']} | "
                        f"{corr/1e9:.1f} | {gb:.1f} | {coll} |")
        for r in err:
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR | - | - | "
                        f"{r['error'][:80]} |")
        rows.append("")
    return "\n".join(rows)


def roofline_section() -> str:
    out = ["Per-cell lower bounds (seconds per step) on the single-pod mesh; "
           "the dominant term is the optimization target of §Perf. "
           "`MODEL/HLO` = analytic useful FLOPs / compiled FLOPs "
           "(remat & redundancy overhead); `roofline frac` = compute term / "
           "dominant term (1.0 = compute-bound).", ""]
    out.append(roofline.table("single"))
    out.append("")
    out.append("**Reading of the dominant bottlenecks**:")
    for name, rep in roofline.load_all().items():
        if rep.get("mesh") != "single":
            continue
        r = roofline.analyze(rep)
        if r is None:
            continue
    out.append(roofline_notes())
    out.append("")
    out.append("Multi-pod cells compile without probes (the roofline table "
               "is single-pod by design; §Dry-run carries the multi-pod "
               "memory/collective evidence). Batch shards over (pod, data); "
               "the gradient all-reduce becomes hierarchical: intra-pod "
               "reduce-scatter + inter-pod all-reduce on the shard.")
    return "\n".join(out)


def roofline_notes() -> str:
    notes = []
    for name, rep in sorted(roofline.load_all().items()):
        if rep.get("mesh") != "single":
            continue
        r = roofline.analyze(rep)
        if r is None:
            continue
        lever = {
            "compute": "already compute-dominated; lever = raise MODEL/HLO "
                       "(less remat, fused attention kernel)",
            "memory": "lever = cut bytes: bf16 loss path, windowed-attention "
                      "key slicing, larger loss chunks, remat policy",
            "collective": "lever = cut link traffic: keep dispatch local to "
                          "DP shards, weight-stationary decode matmuls, "
                          "hierarchical pod-axis reductions",
        }[r.dominant]
        notes.append(f"* `{r.arch} × {r.shape}`: {r.dominant}-bound "
                     f"({max(r.compute_s, r.memory_s, r.collective_s):.2e}s); "
                     f"{lever}.")
    return "\n".join(notes)


def splice(text: str, tag: str, body: str) -> str:
    begin, end = f"<!-- {tag}:BEGIN -->", f"<!-- {tag}:END -->"
    pat = re.compile(re.escape(begin) + ".*?" + re.escape(end), re.S)
    return pat.sub(begin + "\n" + body + "\n" + end, text)


def main() -> None:
    text = EXP.read_text()
    text = splice(text, "DRYRUN", dryrun_section())
    text = splice(text, "ROOFLINE", roofline_section())
    EXP.write_text(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
