"""§Perf hillclimb runner: compile a tagged variant of one cell and print the
three roofline terms next to the stored baseline.

Usage:
  PYTHONPATH=src python experiments/hillclimb.py <arch> <shape> <tag> \
      [--microbatches N] [--no-fsdp] [--no-remat]

The variant's report lands in experiments/dryrun/<tag>_<arch>__<shape>__single
.json; the printed delta feeds the §Perf log in EXPERIMENTS.md.
"""

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("arch")
    ap.add_argument("shape")
    ap.add_argument("tag")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--env", action="append", default=[],
                    help="FLAG=VAL set before importing repro (repeatable)")
    args = ap.parse_args()

    import os
    for kv in args.env:
        key, _, val = kv.partition("=")
        os.environ[key] = val or "1"

    from repro.launch.dryrun import RESULTS_DIR, lower_cell
    from repro.launch import roofline

    rep = lower_cell(args.arch, args.shape, multi_pod=False,
                     microbatches=args.microbatches, fsdp=not args.no_fsdp,
                     remat=not args.no_remat, extra_tag=args.tag)
    out = RESULTS_DIR / (f"{args.tag}_{args.arch}__{args.shape}__single.json")
    out.write_text(json.dumps(rep, indent=1))

    base_f = RESULTS_DIR / f"{args.arch}__{args.shape}__single.json"
    base = json.loads(base_f.read_text()) if base_f.exists() else None
    print(f"\n=== {args.arch} x {args.shape} [{args.tag}] ===")
    for name, r in (("baseline", base), ("variant", rep)):
        if r is None or "error" in r:
            print(f"{name}: {'missing' if r is None else r['error'][:200]}")
            continue
        a = roofline.analyze(r)
        if a is None:
            print(f"{name}: not analyzable")
            continue
        print(f"{name:>9}: compute {a.compute_s:.3e}s  memory "
              f"{a.memory_s:.3e}s  collective {a.collective_s:.3e}s  "
              f"dominant={a.dominant}  HBM {a.peak_hbm_gb:.1f}GB  "
              f"MODEL/HLO {a.useful_ratio:.2f}")
    if base is not None and "error" not in rep:
        ab, av = roofline.analyze(base), roofline.analyze(rep)
        if ab and av:
            for term in ("compute_s", "memory_s", "collective_s"):
                b, v = getattr(ab, term), getattr(av, term)
                if b > 0:
                    print(f"  {term}: {(v-b)/b*100:+.1f}%")


if __name__ == "__main__":
    main()
