"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each function mirrors its kernel's public semantics exactly; the kernel tests
sweep shapes/dtypes and assert allclose against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def fmix32(x: jax.Array, seed: int = 0) -> jax.Array:
    """jnp murmur3 finalizer — bit-identical to balancer.hashing.fmix32."""
    h = x.astype(jnp.uint32) ^ jnp.uint32(seed & 0xFFFFFFFF)
    h = h ^ (h >> jnp.uint32(16))
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> jnp.uint32(13))
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> jnp.uint32(16))
    return h


def key_stats(keys: jax.Array, costs: jax.Array, num_keys: int):
    """Per-key tuple frequency g(k) and cost c(k) over one interval's stream.

    keys: (N,) int32 in [0, num_keys); costs: (N,) float. Entries with
    key < 0 are padding and ignored.
    """
    valid = keys >= 0
    k = jnp.where(valid, keys, 0)
    freq = jnp.zeros((num_keys,), jnp.float32).at[k].add(
        jnp.where(valid, 1.0, 0.0))
    cost = jnp.zeros((num_keys,), jnp.float32).at[k].add(
        jnp.where(valid, costs.astype(jnp.float32), 0.0))
    return freq, cost


def routing_lookup(keys: jax.Array, table_keys: jax.Array,
                   table_dests: jax.Array, n_dest: int,
                   seed: int = 0) -> jax.Array:
    """Mixed routing F(k) (paper Eq. 1): VMEM-table override else hash.

    table_keys: (A,) int32, -1 = empty slot. Returns int32 destinations.
    """
    base = (fmix32(keys, seed) % jnp.uint32(n_dest)).astype(jnp.int32)
    hit = keys[:, None] == table_keys[None, :]            # (N, A)
    any_hit = jnp.any(hit, axis=1)
    slot = jnp.argmax(hit, axis=1)
    return jnp.where(any_hit, table_dests[slot], base).astype(jnp.int32)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, window: int = 0,
                    scale: float | None = None) -> jax.Array:
    """Reference GQA attention.

    q: (B, Hq, T, D); k, v: (B, Hkv, S, D) with Hq % Hkv == 0.
    window > 0 applies sliding-window masking of that width (local layers).
    """
    b, hq, t, d = q.shape
    hkv, s = k.shape[1], k.shape[2]
    group = hq // hkv
    scale = (d ** -0.5) if scale is None else scale
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhtd,bhsd->bhts", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(t)[:, None] + (s - t)   # right-aligned (decode-friendly)
    k_pos = jnp.arange(s)[None, :]
    mask = jnp.ones((t, s), dtype=bool)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window > 0:
        mask = mask & (k_pos > q_pos - window)
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhts,bhsd->bhtd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
