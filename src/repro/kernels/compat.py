"""Version compatibility shims for Pallas TPU APIs.

``jax.experimental.pallas.tpu`` renamed ``TPUCompilerParams`` to
``CompilerParams`` around jax 0.5; the kernels in this package are written
against the new name and resolve it through :data:`CompilerParams` here so
they load on both sides of the rename.
"""

from __future__ import annotations

import jax.experimental.pallas.tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams
