"""Fused per-key statistics histogram — the paper's monitoring hot path.

At every interval boundary each worker must produce g(k) (frequency) and c(k)
(computation cost) for its key slice (paper Fig. 5, step 1). On TPU the
natural formulation is a one-hot matmul: a (tokens x key-block) match matrix
contracted against ones / costs runs on the MXU, turning a scatter-add (bad
on TPU) into dense compute.

Tiling: grid (K/BK, N/BN); the stream axis (last grid dim) is sequential on
TPU, so each key-block accumulates partial sums across stream blocks in its
own VMEM output tile — no cross-program reduction needed.

VMEM budget per program: keys BN*4 + costs BN*4 + match BN*BK*4 + out 2*BK*4
bytes; BN=BK=512 -> ~1.1 MB, comfortably inside the ~16 MB/core VMEM.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from .compat import CompilerParams
from .routing_lookup import require_int32


def _key_stats_kernel(keys_ref, costs_ref, freq_ref, cost_ref, *, block_k: int):
    n_idx = pl.program_id(1)

    @pl.when(n_idx == 0)
    def _init():
        freq_ref[...] = jnp.zeros_like(freq_ref)
        cost_ref[...] = jnp.zeros_like(cost_ref)

    k_idx = pl.program_id(0)
    keys = keys_ref[...]                                  # (1, BN) int32
    costs = costs_ref[...].astype(jnp.float32)            # (1, BN)
    key_base = k_idx * block_k
    key_ids = key_base + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
    # (BN, BK) one-hot match matrix; padding keys (< 0) never match
    match = (keys.T == key_ids).astype(jnp.float32)       # (BN, BK)
    freq_ref[...] += jnp.sum(match, axis=0, keepdims=True)
    cost_ref[...] += jnp.dot(costs, match,                # MXU contraction
                             preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("num_keys", "block_n", "block_k",
                                    "interpret"))
def _key_stats(keys: jax.Array, costs: jax.Array, num_keys: int,
               block_n: int = 512, block_k: int = 512,
               interpret: Optional[bool] = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = keys.shape[0]
    n_pad = pl.cdiv(n, block_n) * block_n - n
    k_pad = pl.cdiv(num_keys, block_k) * block_k - num_keys
    keys_p = jnp.pad(keys.astype(jnp.int32), (0, n_pad),
                     constant_values=-1)[None, :]
    costs_p = jnp.pad(costs.astype(jnp.float32), (0, n_pad))[None, :]
    padded_k = num_keys + k_pad

    grid = (padded_k // block_k, keys_p.shape[1] // block_n)
    freq, cost = pl.pallas_call(
        functools.partial(_key_stats_kernel, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_n), lambda i, j: (0, j)),
            pl.BlockSpec((1, block_n), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_k), lambda i, j: (0, i)),
            pl.BlockSpec((1, block_k), lambda i, j: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, padded_k), jnp.float32),
            jax.ShapeDtypeStruct((1, padded_k), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(keys_p, costs_p)
    return freq[0, :num_keys], cost[0, :num_keys]


def key_stats(keys: jax.Array, costs: jax.Array, num_keys: int,
              block_n: int = 512, block_k: int = 512,
              interpret: Optional[bool] = None):
    """Per-key frequency and cost over a tuple/token stream.

    keys: (N,) int32 in [0, num_keys), -1 = padding; costs: (N,) float.
    Returns (freq, cost) each (num_keys,) float32. ``interpret=None``
    auto-selects: compiled on real TPU backends, interpret mode elsewhere.

    ``keys`` must already be int32 — enforced outside the jit boundary so a
    wider dtype raises TypeError instead of aliasing ids >= 2**31 (costs may
    be any float dtype; they accumulate in float32 either way).
    """
    require_int32("key_stats", "keys", keys)
    return _key_stats(keys, costs, num_keys, block_n=block_n,
                      block_k=block_k, interpret=interpret)


if hasattr(_key_stats, "_cache_size"):           # retrace-counting test hook
    key_stats._cache_size = _key_stats._cache_size
