"""Public jit'd wrappers for the Pallas kernels.

``interpret`` auto-detection: on CPU (this container) kernels run in
interpret mode — the kernel body executes in Python for correctness
validation; on TPU they compile to Mosaic. Callers can force either.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention as _flash
from .key_stats import key_stats as _key_stats
from .routing_lookup import routing_lookup as _routing


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def fused_key_stats(keys: jax.Array, costs: Optional[jax.Array],
                    num_keys: int, interpret: Optional[bool] = None):
    """g(k), c(k) for one interval's stream (paper Fig. 5 step 1)."""
    if costs is None:
        costs = jnp.ones(keys.shape, jnp.float32)
    interpret = _interpret_default() if interpret is None else interpret
    return _key_stats(keys, costs, num_keys, interpret=interpret)


def mixed_route(keys: jax.Array, table_keys: jax.Array,
                table_dests: jax.Array, n_dest: int, seed: int = 0,
                interpret: Optional[bool] = None) -> jax.Array:
    """F(k) per paper Eq. 1 with the override table pinned in VMEM."""
    interpret = _interpret_default() if interpret is None else interpret
    return _routing(keys, table_keys, table_dests, n_dest, seed=seed,
                    interpret=interpret)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True,
              window: int = 0, interpret: Optional[bool] = None,
              block_t: int = 512, block_s: int = 512) -> jax.Array:
    """Blocked causal/sliding-window GQA attention.

    Falls back to the jnp oracle for non-causal full attention (encoder
    self-attention / cross-attention), which XLA already fuses well.
    """
    if not causal and window <= 0:
        return ref.flash_attention(q, k, v, causal=False, window=0)
    interpret = _interpret_default() if interpret is None else interpret
    return _flash(q, k, v, causal=causal, window=window, interpret=interpret,
                  block_t=block_t, block_s=block_s)
