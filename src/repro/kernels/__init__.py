"""Pallas TPU kernels (validated in interpret mode on CPU) + jnp oracles."""

from . import ref
from .ops import attention, fused_key_stats, mixed_route

__all__ = ["ref", "attention", "fused_key_stats", "mixed_route"]
