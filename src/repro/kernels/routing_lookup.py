"""Mixed-routing dispatch kernel — F(k) on the per-token hot path (Eq. 1).

The override table (A_max entries) is pinned whole in VMEM for every program
(BlockSpec index_map is constant in the stream dimension), so each token block
pays one (BN x A) compare + reduce instead of a host-side dict probe. The
hash fallback is the murmur3 finalizer (fmix32) — TPUs have no 64-bit integer
units, so the 32-bit mix is the device-canonical hash shared bit-for-bit with
the host planner (balancer.hashing.Hash32) and the jnp oracle.

VMEM per program: BN*4 (keys) + 2*A*4 (table) + BN*A (match, promoted f32)
-> BN=1024, A=2048: ~8.5 MB peak with f32 match; we reduce with integer
max instead to stay ~2.5 MB.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from .compat import CompilerParams


def require_int32(kernel: str, name: str, arr) -> None:
    """Int32 contract check, outside the jit boundary.

    The kernels' integer lanes are 32-bit: a wider (or float) key array
    would be truncated inside the kernel and ids >= 2**31 would silently
    alias other keys instead of failing. Callers must validate the value
    range and cast explicitly (``KeyedStage._dest_batch`` does)."""
    dtype = np.dtype(getattr(arr, "dtype", np.asarray(arr).dtype))
    if dtype != np.dtype(np.int32):
        raise TypeError(
            f"{kernel} requires int32 {name} (got {dtype.name}): the kernel "
            "operates on 32-bit integer lanes, so wider ids would silently "
            "alias after truncation — validate ids are in [0, 2**31) and "
            "cast explicitly")


def _fmix32(h):
    h = h ^ (h >> jnp.uint32(16))
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> jnp.uint32(13))
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> jnp.uint32(16))
    return h


def _routing_kernel(keys_ref, tkeys_ref, tdests_ref, out_ref, *, n_dest: int,
                    seed: int):
    keys = keys_ref[...]                                   # (1, BN) int32
    h = _fmix32(keys.astype(jnp.uint32) ^ jnp.uint32(seed & 0xFFFFFFFF))
    base = (h % jnp.uint32(n_dest)).astype(jnp.int32)
    tkeys = tkeys_ref[...]                                 # (1, A)
    tdests = tdests_ref[...]                               # (1, A)
    # (BN, A) match; empty slots are -1 and keys are >= 0, so never match
    match = keys.reshape(-1, 1) == tkeys.reshape(1, -1)
    # integer-max reduction: dest+1 where matched, 0 where not; 0 -> miss
    hit_val = jnp.where(match, tdests.reshape(1, -1) + 1, 0)
    best = jnp.max(hit_val, axis=1).reshape(keys.shape)    # (1, BN)
    out_ref[...] = jnp.where(best > 0, best - 1, base)


@functools.partial(jax.jit,
                   static_argnames=("n_dest", "seed", "block_n", "interpret"))
def _routing_lookup(keys: jax.Array, table_keys: jax.Array,
                    table_dests: jax.Array, n_dest: int, seed: int = 0,
                    block_n: int = 1024,
                    interpret: Optional[bool] = None) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = keys.shape[0]
    a = table_keys.shape[0]
    n_pad = pl.cdiv(n, block_n) * block_n - n
    a_pad = pl.cdiv(a, 128) * 128 - a
    keys_p = jnp.pad(keys.astype(jnp.int32), (0, n_pad),
                     constant_values=-1)[None, :]
    tkeys_p = jnp.pad(table_keys.astype(jnp.int32), (0, a_pad),
                      constant_values=-1)[None, :]
    tdests_p = jnp.pad(table_dests.astype(jnp.int32), (0, a_pad))[None, :]
    a_total = a + a_pad

    out = pl.pallas_call(
        functools.partial(_routing_kernel, n_dest=n_dest, seed=seed),
        grid=(keys_p.shape[1] // block_n,),
        in_specs=[
            pl.BlockSpec((1, block_n), lambda i: (0, i)),
            pl.BlockSpec((1, a_total), lambda i: (0, 0)),   # table: whole, VMEM
            pl.BlockSpec((1, a_total), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, keys_p.shape[1]), jnp.int32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(keys_p, tkeys_p, tdests_p)
    return out[0, :n]


def routing_lookup(keys: jax.Array, table_keys: jax.Array,
                   table_dests: jax.Array, n_dest: int, seed: int = 0,
                   block_n: int = 1024,
                   interpret: Optional[bool] = None) -> jax.Array:
    """Vectorized F(k) for a token/tuple block. -1 table slots = empty.

    ``interpret=None`` (default) auto-selects: compiled Mosaic on real TPU
    backends, interpret mode elsewhere (CPU/GPU have no lowering for this
    kernel). Both values are static, so the choice is baked per trace.

    All three arrays must already be int32 — this unjitted wrapper enforces
    the contract (raises TypeError) before any tracing happens, so a wrong
    dtype fails loudly instead of silently aliasing key ids >= 2**31.
    """
    require_int32("routing_lookup", "keys", keys)
    require_int32("routing_lookup", "table_keys", table_keys)
    require_int32("routing_lookup", "table_dests", table_dests)
    return _routing_lookup(keys, table_keys, table_dests, n_dest, seed=seed,
                           block_n=block_n, interpret=interpret)


if hasattr(_routing_lookup, "_cache_size"):      # retrace-counting test hook
    routing_lookup._cache_size = _routing_lookup._cache_size
