"""Blocked causal GQA flash attention (train/prefill compute hot spot).

Standard online-softmax formulation tiled for the MXU: grid
(B, Hq, T/BT, S/BS) with the key/value axis innermost — TPU grids execute
sequentially over the last dimension, so the (m, l, acc) running state lives
in VMEM scratch across S-blocks of the same query tile.

GQA is handled in the index_map (kv head = q head // group), sliding-window
masking covers the gemma3-style local layers. Query positions are
right-aligned against the KV sequence so the same kernel serves training
(T == S) and single-step/chunked decode (T << S against a KV cache).

VMEM per program (BT=BS=512, D=128, f32): q/k/v tiles 3*512*128*4 = 0.79 MB,
logits 512*512*4 = 1 MB, acc + stats ~0.33 MB -> ~2.2 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from .compat import CompilerParams

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int, block_t: int,
                  block_s: int, q_offset: int, s_real: int):
    s_idx = pl.program_id(3)
    t_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                    # (BT, D)
    k = k_ref[0, 0].astype(jnp.float32)                    # (BS, D)
    v = v_ref[0, 0].astype(jnp.float32)                    # (BS, D)

    logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    # true positions: q rows are front-padded by t_pad (q_offset = s - t -
    # t_pad restores right alignment); keys are end-padded past s_real.
    q_pos = q_offset + t_idx * block_t + \
        jax.lax.broadcasted_iota(jnp.int32, (block_t, block_s), 0)
    k_pos = s_idx * block_s + \
        jax.lax.broadcasted_iota(jnp.int32, (block_t, block_s), 1)
    mask = k_pos < s_real                                   # kill key padding
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window > 0:
        mask = mask & (k_pos > q_pos - window)
    logits = jnp.where(mask, logits, _NEG_INF)

    m_prev = m_scr[...][:, :1]                             # (BT, 1)
    l_prev = l_scr[...][:, :1]
    m_cur = jnp.max(logits, axis=1, keepdims=True)         # (BT, 1)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.where(mask, jnp.exp(logits - m_new), 0.0)      # (BT, BS)
    l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(s_idx == pl.num_programs(3) - 1)
    def _finalize():
        l = l_scr[...][:, :1]
        o_ref[0, 0, ...] = (acc_scr[...] /
                            jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "block_t", "block_s",
                                    "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, window: int = 0,
                    block_t: int = 512, block_s: int = 512,
                    interpret: bool = True) -> jax.Array:
    """q: (B, Hq, T, D); k, v: (B, Hkv, S, D); Hq % Hkv == 0. Causal and/or
    sliding-window masked, right-aligned positions (decode friendly)."""
    b, hq, t, d = q.shape
    hkv, s = k.shape[1], k.shape[2]
    assert hq % hkv == 0, "GQA requires Hq to be a multiple of Hkv"
    group = hq // hkv
    scale = d ** -0.5

    block_t = min(block_t, max(t, 8))
    block_s = min(block_s, max(s, 8))
    t_pad = pl.cdiv(t, block_t) * block_t - t
    s_pad = pl.cdiv(s, block_s) * block_s - s
    # pad queries at the FRONT (right alignment preserved), keys at the END
    # (end-padded keys sit above every real query's causal horizon).
    qp = jnp.pad(q, ((0, 0), (0, 0), (t_pad, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, s_pad), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, s_pad), (0, 0)))
    tp, sp = t + t_pad, s + s_pad

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          window=window, block_t=block_t, block_s=block_s,
                          q_offset=s - t - t_pad, s_real=s),
        grid=(b, hq, tp // block_t, sp // block_s),
        in_specs=[
            pl.BlockSpec((1, 1, block_t, d), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, block_s, d),
                         lambda b_, h, i, j, g=group: (b_, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_s, d),
                         lambda b_, h, i, j, g=group: (b_, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_t, d),
                               lambda b_, h, i, j: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, tp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_t, 128), jnp.float32),      # m
            pltpu.VMEM((block_t, 128), jnp.float32),      # l
            pltpu.VMEM((block_t, d), jnp.float32),        # acc
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, t_pad:, :]
