"""Logical-axis -> mesh-axis sharding rules for params, caches and data.

Parameter 2-D sharding (TP x FSDP): tensor-parallel logical axes (vocab,
q_heads, kv_flat, mlp, expert, mamba_inner) map to "model"; the d_model
("embed") axis maps to "data" — ZeRO-3-style parameter sharding whose
all-gathers XLA schedules ahead of use. Divisibility fallback (e.g. qwen2's
28 heads on a 16-way axis) replicates that dim and is surfaced via
``schema.replication_report`` for the roofline notes.

Batch ("batch") shards over (pod, data); for global_batch < DP degree
(long_500k has batch 1) it falls back to replicated and the KV sequence
("kv_seq") shards over "data" instead — sequence parallelism for the cache.
"""

from __future__ import annotations

from typing import Optional

import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import schema as schema_mod

PARAM_RULES = {
    "vocab": "model",
    "q_heads": "model",
    "kv_flat": "model",
    "mlp": "model",
    "expert": "model",
    "mamba_inner": "model",
    "heads": "model",
    "embed": "data",            # FSDP over the data axis
    "stack": None,
    "conv": None,
    None: None,
}

PARAM_RULES_NO_FSDP = {**PARAM_RULES, "embed": None}


def _dp_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def dp_degree(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in _dp_axes(mesh)]))


def param_shardings(model_schema, mesh: Mesh, fsdp: bool = True):
    rules = PARAM_RULES if fsdp else PARAM_RULES_NO_FSDP
    return schema_mod.shardings(model_schema, mesh, rules)


def param_pspecs(model_schema, mesh: Mesh, fsdp: bool = True):
    rules = PARAM_RULES if fsdp else PARAM_RULES_NO_FSDP
    return schema_mod.partition_specs(model_schema, mesh, rules)


def batch_pspec(mesh: Mesh, global_batch: int) -> P:
    dp = _dp_axes(mesh)
    if global_batch % dp_degree(mesh) == 0:
        return P(dp, None)
    return P(None, None)


def batch_sharding(mesh: Mesh, global_batch: int) -> NamedSharding:
    return NamedSharding(mesh, batch_pspec(mesh, global_batch))


def cache_rules(mesh: Mesh, global_batch: int) -> dict:
    """KV-cache logical axes; SP fallback for unshardable batch."""
    dp = _dp_axes(mesh)
    batch_ok = global_batch % dp_degree(mesh) == 0
    return {
        **PARAM_RULES,
        "embed": None,                       # cache activations: no FSDP
        "batch": dp if batch_ok else None,
        "kv_seq": None if batch_ok else "data",   # sequence-parallel cache
    }


def cache_shardings(cache_schema, mesh: Mesh, global_batch: int):
    return schema_mod.shardings(cache_schema, mesh,
                                cache_rules(mesh, global_batch))


def cache_pspecs(cache_schema, mesh: Mesh, global_batch: int):
    return schema_mod.partition_specs(cache_schema, mesh,
                                      cache_rules(mesh, global_batch))


def replication_report(model_schema, mesh: Mesh, fsdp: bool = True) -> dict:
    rules = PARAM_RULES if fsdp else PARAM_RULES_NO_FSDP
    return schema_mod.replication_report(model_schema, mesh, rules)
