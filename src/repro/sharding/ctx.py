"""Activation-sharding constraint context.

Model code calls ``constrain(x, "batch", None, "model_like")`` with logical
axis names; when a mesh is installed (dry-run / real launch) this becomes a
``with_sharding_constraint``; with no mesh (CPU unit tests) it is a no-op.
GSPMD propagates most shardings fine, but scan/map bodies (microbatching,
chunked loss) lose them — these pins are what keep the loss path from
replicating per device (observed: ~150x per-device FLOP inflation without).
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

LOGICAL = {
    "dp": ("pod", "data"),        # batch-like dims
    "tp": ("model",),             # tensor/expert-parallel dims
    "sp": ("data",),              # sequence-parallel dims
}


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    prev = current_mesh()
    _state.mesh = mesh
    try:
        yield
    finally:
        _state.mesh = prev


def _resolve(mesh: Mesh, name, size: Optional[int]):
    if name is None:
        return None
    axes = tuple(a for a in LOGICAL.get(name, (name,))
                 if a in mesh.axis_names)
    if not axes:
        return None
    if size is not None:
        import numpy as np
        ax_size = int(np.prod([mesh.shape[a] for a in axes]))
        if size % ax_size != 0:
            return None
    return axes if len(axes) > 1 else axes[0]


def constrain(x: jax.Array, *parts):
    """parts: logical names ('dp'|'tp'|'sp'|mesh axis|None) per dim."""
    mesh = current_mesh()
    if mesh is None:
        return x
    assert len(parts) == x.ndim, (parts, x.shape)
    resolved = [_resolve(mesh, p, x.shape[i]) for i, p in enumerate(parts)]
    used = set()
    final = []
    for r in resolved:
        key = tuple(r) if isinstance(r, tuple) else (r,)
        if r is None or any(k in used for k in key):
            final.append(None)
            continue
        used.update(key)
        final.append(r)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*final)))
