"""Training loop: checkpoint/restart, straggler watchdog, SkewShield MoE
placement updates, elastic-fleet hooks. CPU-runnable at smoke scale; the same
loop drives the production mesh (the step function is mesh-agnostic)."""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model_schema, schema as schema_mod
from repro.models.config import ModelConfig
from repro.models.skewshield import (SkewShieldPlacer, permute_expert_params,
                                     placements_array)

from .checkpoint import CheckpointManager
from .optimizer import OptConfig, opt_init
from .train_step import make_train_step


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    rebalance_every: int = 10          # SkewShield interval (steps)
    microbatches: int = 1
    log_every: int = 10
    straggler_factor: float = 3.0      # step-time watchdog threshold
    skewshield: bool = True
    theta_max: float = 0.1


class Trainer:
    def __init__(self, cfg: ModelConfig, opt_cfg: OptConfig,
                 tcfg: TrainerConfig, checkpoint_dir: str,
                 data_fn: Callable[[int], Dict[str, jax.Array]],
                 seed: int = 0):
        self.cfg = cfg
        self.tcfg = tcfg
        self.data_fn = data_fn
        self.schema = model_schema(cfg)
        self.params = schema_mod.init(self.schema, jax.random.PRNGKey(seed))
        self.opt_state = opt_init(self.params)
        self.ckpt = CheckpointManager(checkpoint_dir)
        self.step_fn = jax.jit(make_train_step(
            cfg, opt_cfg, microbatches=tcfg.microbatches,
            collect_moe=tcfg.skewshield and cfg.moe_experts > 0))
        self.step = 0
        self.history: List[Dict[str, float]] = []
        self.step_times: List[float] = []
        self.placers: List[SkewShieldPlacer] = []
        self._moe_sub_names: List[str] = []
        if cfg.moe_experts and tcfg.skewshield:
            n_moe_layers = sum(cfg.layer_is_moe(j)
                               for j in range(cfg.pattern_period)) \
                * (cfg.n_layers // cfg.pattern_period)
            bytes_per_expert = 3 * cfg.d_model * cfg.d_ff * 2.0
            n_shards = min(cfg.moe_experts, 16)
            # shards must divide experts for the slot layout
            while cfg.moe_experts % n_shards:
                n_shards -= 1
            self.placers = [SkewShieldPlacer(cfg.moe_experts, n_shards,
                                             bytes_per_expert,
                                             theta_max=tcfg.theta_max)
                            for _ in range(cfg.n_layers)]

    # -------------------------------------------------------------- resume
    def try_resume(self) -> bool:
        like = {"params": self.params, "opt": self.opt_state}
        try:
            step, state, _ = self.ckpt.restore(like)
        except (FileNotFoundError, ValueError):
            return False
        self.params, self.opt_state = state["params"], state["opt"]
        self.step = step
        return True

    # ------------------------------------------------------------ main loop
    def placements(self) -> Optional[jax.Array]:
        if not self.placers:
            return None
        return placements_array(self.placers)

    def run(self, steps: Optional[int] = None) -> List[Dict[str, float]]:
        steps = steps if steps is not None else self.tcfg.total_steps
        end = self.step + steps
        while self.step < end:
            batch = self.data_fn(self.step)
            t0 = time.perf_counter()
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch, self.placements())
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            self.step += 1
            self.step_times.append(dt)
            rec = {"step": self.step, "loss": loss,
                   "grad_norm": float(metrics["grad_norm"]),
                   "time_s": dt}
            self.history.append(rec)
            self._watchdog(dt)
            if self.placers and self.step % self.tcfg.rebalance_every == 0 \
                    and "expert_load" in metrics:
                self._rebalance_experts(np.asarray(metrics["expert_load"]))
            if self.step % self.tcfg.checkpoint_every == 0:
                self.save()
        return self.history

    def save(self):
        self.ckpt.save(self.step,
                       {"params": self.params, "opt": self.opt_state},
                       meta={"arch": self.cfg.name})

    # -------------------------------------------------- fleet health hooks
    def _watchdog(self, dt: float) -> None:
        """Straggler detection: a step far beyond the trailing median flags a
        slow worker; the balancer-level response (derate_worker) lives in the
        controller — here we record the event for the ops plane."""
        if len(self.step_times) < 8:
            return
        med = float(np.median(self.step_times[-8:]))
        if dt > self.tcfg.straggler_factor * med:
            self.history[-1]["straggler_suspect"] = True

    # ----------------------------------------------------- SkewShield hook
    def _rebalance_experts(self, expert_load: np.ndarray) -> None:
        """expert_load: (n_groups, moe_per_group, E) accumulated loads."""
        period = self.cfg.pattern_period
        moe_js = [j for j in range(period) if self.cfg.layer_is_moe(j)]
        n_groups = self.cfg.n_layers // period
        flat_groups = self.params["groups"]
        for g in range(n_groups):
            for mi, j in enumerate(moe_js):
                layer = g * period + j
                placer = self.placers[layer]
                old = placer.placement.copy()
                upd = placer.update(expert_load[g, mi])
                if len(upd.moved_experts):
                    # weights AND optimizer moments move with the expert —
                    # Adam state must stay aligned with its parameter.
                    trees = [flat_groups[f"sub{j}"]["moe"]] + [
                        self.opt_state[k]["groups"][f"sub{j}"]["moe"]
                        for k in ("m", "v", "master")]
                    for tree in trees:
                        sliced = jax.tree.map(lambda a: a[g], tree)
                        permd = permute_expert_params(sliced, old,
                                                      upd.placement)
                        for name in ("w_gate", "w_up", "w_down"):
                            tree[name] = tree[name].at[g].set(permd[name])
