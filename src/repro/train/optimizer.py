"""AdamW with f32 master weights, global-norm clipping, warmup-cosine
schedule — built from scratch (no optax in this environment).

Optimizer state is a pytree mirroring the params, so the param sharding tree
applies verbatim (ZeRO-style: moments shard exactly like their parameter).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.lr * jnp.minimum(1.0, (step + 1.0) / max(cfg.warmup_steps, 1))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * \
        (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def opt_init(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "master": jax.tree.map(lambda p: p.astype(jnp.float32), params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def opt_update(grads, opt_state, params, cfg: OptConfig
               ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    step = opt_state["step"]
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    lr = schedule(cfg, step)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(g, m, v, master):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * g * g
        update = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + cfg.eps)
        master_new = master - lr * (update + cfg.weight_decay * master)
        return m_new, v_new, master_new

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    flat_w = treedef.flatten_up_to(opt_state["master"])
    outs = [upd(g, m, v, w) for g, m, v, w in
            zip(flat_g, flat_m, flat_v, flat_w)]
    new_m = treedef.unflatten([o[0] for o in outs])
    new_v = treedef.unflatten([o[1] for o in outs])
    new_master = treedef.unflatten([o[2] for o in outs])
    new_params = jax.tree.map(
        lambda w, p: w.astype(p.dtype), new_master, params)
    new_state = {"m": new_m, "v": new_v, "master": new_master,
                 "step": step + 1}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


def opt_shardings(param_shardings, mesh):
    """Optimizer-state sharding mirrors the parameter sharding."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    return {
        "m": param_shardings,
        "v": param_shardings,
        "master": param_shardings,
        "step": NamedSharding(mesh, P()),
    }
