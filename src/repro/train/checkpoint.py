"""Checkpointing: atomic, compressed, resumable (fault-tolerance substrate).

Layout: <dir>/step_<N>/state.msgpack.zst + manifest.json, with a ``latest``
pointer file written only after a successful save (crash-safe: a partial
save can never become ``latest``). Restore validates the manifest (arch,
tree structure hash) before loading. The balancer's routing table and the
RNG/step live in the same bundle so a restart resumes mid-interval cleanly.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import tempfile
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:                       # optional extra: install via `pip install .[test]`
    import zstandard
except ImportError:        # pragma: no cover - exercised in bare envs
    zstandard = None


def _require_zstandard():
    if zstandard is None:
        raise ImportError(
            "checkpointing requires the optional 'zstandard' package; "
            "install it with `pip install zstandard` (or the [test] extra)")
    return zstandard


def _tree_paths(tree) -> list:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(kp), leaf) for kp, leaf in flat]


def _structure_hash(tree) -> str:
    keys = "|".join(k for k, _ in _tree_paths(tree))
    return hashlib.sha256(keys.encode()).hexdigest()[:16]


def _pack_tree(tree) -> bytes:
    entries = {}
    for key, leaf in _tree_paths(tree):
        arr = np.asarray(leaf)
        # bf16 has no numpy dtype string portable through msgpack: view as u16
        if arr.dtype == jnp.bfloat16:
            entries[key] = {"d": "bfloat16", "s": arr.shape,
                            "b": arr.view(np.uint16).tobytes()}
        else:
            entries[key] = {"d": arr.dtype.str, "s": arr.shape,
                            "b": arr.tobytes()}
    return msgpack.packb(entries, use_bin_type=True)


def _unpack_tree(blob: bytes, like) -> Any:
    entries = msgpack.unpackb(blob, raw=False, strict_map_key=False)
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for kp, leaf in flat:
        key = jax.tree_util.keystr(kp)
        e = entries[key]
        shape = tuple(e["s"])
        if e["d"] == "bfloat16":
            arr = np.frombuffer(e["b"], np.uint16).reshape(shape)
            out = jnp.asarray(arr.view(jnp.bfloat16))
        else:
            arr = np.frombuffer(e["b"], np.dtype(e["d"])).reshape(shape)
            out = jnp.asarray(arr)
        leaves.append(out)
    return jax.tree_util.tree_unflatten(treedef, [l for l in leaves])


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep: int = 3
    compression_level: int = 3

    def __post_init__(self):
        self.dir = Path(self.directory)
        self.dir.mkdir(parents=True, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Dict[str, Any],
             meta: Optional[Dict[str, Any]] = None) -> Path:
        target = self.dir / f"step_{step:08d}"
        tmp = Path(tempfile.mkdtemp(dir=self.dir, prefix=".tmp_"))
        try:
            blob = _pack_tree(state)
            comp = _require_zstandard().ZstdCompressor(
                level=self.compression_level)
            (tmp / "state.msgpack.zst").write_bytes(comp.compress(blob))
            manifest = {
                "step": step,
                "time": time.time(),
                "structure": _structure_hash(state),
                "bytes_raw": len(blob),
                **(meta or {}),
            }
            (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
            if target.exists():
                shutil.rmtree(target)
            os.replace(tmp, target)                      # atomic publish
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        # 'latest' is written only after the directory is fully in place
        latest_tmp = self.dir / ".latest_tmp"
        latest_tmp.write_text(target.name)
        os.replace(latest_tmp, self.dir / "latest")
        self._gc()
        return target

    def _gc(self):
        steps = sorted(self.dir.glob("step_*"))
        for old in steps[:-self.keep]:
            shutil.rmtree(old, ignore_errors=True)

    # --------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        latest = self.dir / "latest"
        if not latest.exists():
            return None
        name = latest.read_text().strip()
        if not (self.dir / name).exists():
            return None
        return int(name.split("_")[1])

    def restore(self, like: Dict[str, Any],
                step: Optional[int] = None) -> Tuple[int, Any, Dict]:
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        target = self.dir / f"step_{step:08d}"
        manifest = json.loads((target / "manifest.json").read_text())
        if manifest["structure"] != _structure_hash(like):
            raise ValueError("checkpoint structure mismatch: "
                             f"{manifest['structure']} vs current tree")
        comp = _require_zstandard().ZstdDecompressor()
        blob = comp.decompress((target / "state.msgpack.zst").read_bytes())
        state = _unpack_tree(blob, like)
        return step, state, manifest
