"""Jitted training step: microbatched grad accumulation + AdamW update.

Microbatches run as a lax.scan inside the step so the DP gradient sync
happens once per step (XLA inserts the hierarchical all-reduce from the
sharding: intra-pod reduce-scatter + inter-pod all-reduce on the shard).
Expert loads for the SkewShield balancer are accumulated alongside.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import lm_loss
from repro.models.config import ModelConfig
from repro.sharding.ctx import constrain

from .optimizer import OptConfig, opt_update


def make_train_step(cfg: ModelConfig, opt_cfg: OptConfig,
                    microbatches: int = 1, use_flash: bool = False,
                    collect_moe: bool = False, remat: bool = True,
                    accum_dtype=jnp.float32, loss_chunks: int = 8,
                    unroll: bool = False):
    """Returns train_step(params, opt_state, batch, placements) ->
    (params, opt_state, metrics)."""

    def loss_fn(params, mb, placements):
        if collect_moe and cfg.moe_experts:
            loss, loads = lm_loss(params, cfg, mb, placements=placements,
                                  use_flash=use_flash, remat=remat,
                                  collect_moe=True, loss_chunks=loss_chunks,
                                  unroll=unroll)
            return loss, loads
        loss = lm_loss(params, cfg, mb, placements=placements,
                       use_flash=use_flash, remat=remat,
                       loss_chunks=loss_chunks, unroll=unroll)
        return loss, None

    import os
    if os.environ.get("REPRO_PERF_BF16_ACCUM", "0") == "1":
        accum_dtype = jnp.bfloat16      # halves DP grad-sync bytes (§Perf)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def split_micro(batch):
        def r(x):
            b = x.shape[0]
            assert b % microbatches == 0, (b, microbatches)
            return x.reshape(microbatches, b // microbatches, *x.shape[1:])
        return jax.tree.map(r, batch)

    def train_step(params, opt_state, batch, placements=None):
        if microbatches == 1:
            (loss, loads), grads = grad_fn(params, batch, placements)
        else:
            micro = split_micro(batch)

            # perf (flag-gated): mark per-microbatch grads 'unreduced' over
            # the DP axes so the cross-data reduction happens ONCE after the
            # scan instead of per microbatch (baseline: sync bytes scale with
            # microbatch count).
            defer = os.environ.get("REPRO_PERF_DEFER_GRAD_SYNC", "0") == "1"

            def _unreduced(g):
                from repro.sharding.ctx import current_mesh
                from jax.sharding import NamedSharding, PartitionSpec as P
                mesh = current_mesh()
                if mesh is None:
                    return g
                dp = {a for a in ("pod", "data") if a in mesh.axis_names}
                return jax.lax.with_sharding_constraint(
                    g, NamedSharding(mesh, P(*([None] * g.ndim),
                                             unreduced=dp)))

            def accum(carry, mb):
                g_acc, l_acc, ld_acc = carry
                mb = jax.tree.map(
                    lambda x: constrain(x, "dp", *([None] * (x.ndim - 1))), mb)
                (loss, loads), grads = grad_fn(params, mb, placements)
                if defer:
                    grads = jax.tree.map(_unreduced, grads)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(accum_dtype), g_acc, grads)
                ld_acc = ld_acc if loads is None else ld_acc + loads
                return (g_acc, l_acc + loss, ld_acc), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params)
            if defer:
                g0 = jax.tree.map(_unreduced, g0)
            ld0 = jnp.zeros((), jnp.float32) if not (
                collect_moe and cfg.moe_experts) else jnp.zeros(
                    (cfg.n_layers // cfg.pattern_period,
                     sum(cfg.layer_is_moe(j)
                         for j in range(cfg.pattern_period)),
                     cfg.moe_experts), jnp.float32)
            (grads, loss, loads), _ = jax.lax.scan(
                accum, (g0, jnp.zeros((), jnp.float32), ld0), micro)
            if defer:
                from repro.sharding.ctx import current_mesh as _cm
                from jax.sharding import NamedSharding as _NS, \
                    PartitionSpec as _P
                _mesh = _cm()
                if _mesh is not None:
                    grads = jax.tree.map(
                        lambda g: jax.lax.with_sharding_constraint(
                            g, _NS(_mesh, _P(*([None] * g.ndim)))), grads)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
            if not (collect_moe and cfg.moe_experts):
                loads = None

        new_params, new_opt, om = opt_update(grads, opt_state, params, opt_cfg)
        metrics: Dict[str, Any] = {"loss": loss, **om}
        if loads is not None:
            metrics["expert_load"] = loads
        return new_params, new_opt, metrics

    return train_step


def make_serve_step(cfg: ModelConfig, use_flash: bool = False,
                    unroll: bool = False):
    """Returns serve_step(params, cache, batch, index, placements) ->
    (logits (B, T, V), new_cache). T=1 for decode, T=seq for prefill."""
    from repro.models import forward, logits_from_hidden

    def serve_step(params, cache, batch, index, placements=None):
        hidden, new_cache = forward(params, cfg, batch, cache=cache,
                                    cache_index=index, placements=placements,
                                    use_flash=use_flash, remat=False,
                                    unroll=unroll)
        logits = logits_from_hidden(params, cfg, hidden[:, -1:, :])
        return logits, new_cache

    return serve_step
