"""Streaming keyed data pipeline partitioned by the balancer.

Documents arrive from skewed sources (source id = the key; e.g. crawl
domains / dataset shards whose volume drifts). Each DP worker owns the
packing state (token backlog) of its keys — a stateful operator in the
paper's sense — so rebalancing sources across workers must migrate backlogs.
The paper's controller keeps per-worker token throughput even, which keeps
global-batch assembly from stalling on one hot worker.

Deterministic + resumable: generation is seeded per (source, interval);
``state_dict``/``load_state`` round-trips through the checkpoint manager.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.core import (Assignment, BalanceConfig, KeyStats, ModHash,
                        RebalanceController)


def byte_tokenize(text: bytes, vocab: int) -> np.ndarray:
    """Toy reversible tokenizer: bytes (+ offset) clipped into vocab."""
    arr = np.frombuffer(text, np.uint8).astype(np.int32)
    return arr % vocab


@dataclasses.dataclass
class SourceSpec:
    source_id: int
    weight: float            # relative document volume (drifts over time)
    mean_len: int = 512      # mean document length in tokens


class KeyedDataPipeline:
    """Zipf-weighted multi-source document stream -> packed LM batches."""

    def __init__(self, sources: List[SourceSpec], n_workers: int,
                 seq_len: int, vocab: int, theta_max: float = 0.1,
                 table_max: int = 1024, seed: int = 0,
                 algorithm: str = "mixed"):
        self.sources = {s.source_id: s for s in sources}
        self.n_workers = n_workers
        self.seq_len = seq_len
        self.vocab = vocab
        self.seed = seed
        self.interval = 0
        self.backlogs: List[Dict[int, List[int]]] = [
            defaultdict(list) for _ in range(n_workers)]
        self.remainder: List[List[int]] = [[] for _ in range(n_workers)]
        self.controller = RebalanceController(
            Assignment(ModHash(n_workers, seed=seed)),
            BalanceConfig(theta_max=theta_max, table_max=table_max),
            algorithm=algorithm, executor=self._migrate)
        self._tokens_produced = np.zeros(n_workers)

    # ------------------------------------------------------------- migration
    def _migrate(self, moved_keys, old: Assignment, new: Assignment) -> None:
        moved = [int(k) for k in moved_keys]
        src = old.dest(np.asarray(moved, np.int64))
        dst = new.dest(np.asarray(moved, np.int64))
        for k, s, d in zip(moved, src, dst):
            if s == d:
                continue
            if k in self.backlogs[int(s)]:
                self.backlogs[int(d)][k] = self.backlogs[int(s)].pop(k)

    # -------------------------------------------------------------- ingest
    def _draw_documents(self, n_docs: int) -> List[Tuple[int, np.ndarray]]:
        rng = np.random.default_rng((self.seed, self.interval))
        ids = np.asarray(sorted(self.sources))
        w = np.asarray([self.sources[i].weight for i in ids], np.float64)
        w = w / w.sum()
        chosen = rng.choice(ids, size=n_docs, p=w)
        docs = []
        for sid in chosen:
            ln = max(8, int(rng.poisson(self.sources[int(sid)].mean_len)))
            docs.append((int(sid),
                         rng.integers(0, self.vocab, ln).astype(np.int32)))
        return docs

    def drift(self, rng: Optional[np.random.Generator] = None,
              magnitude: float = 0.5) -> None:
        """Short-term fluctuation: randomly re-weight a few sources."""
        rng = rng or np.random.default_rng((self.seed, self.interval, 7))
        ids = list(self.sources)
        for sid in rng.choice(ids, size=max(1, len(ids) // 10),
                              replace=False):
            self.sources[int(sid)].weight *= float(
                np.exp(rng.normal(0.0, magnitude)))

    def run_interval(self, n_docs: int = 512):
        """Ingest one interval of documents; report stats; rebalance."""
        self.interval += 1
        per_key_tokens: Dict[int, float] = defaultdict(float)
        worker_tokens = np.zeros(self.n_workers)
        for sid, tokens in self._draw_documents(n_docs):
            d = int(self.controller.assignment.dest(
                np.asarray([sid], np.int64))[0])
            self.backlogs[d][sid].extend(tokens.tolist())
            per_key_tokens[sid] += len(tokens)
            worker_tokens[d] += len(tokens)
        self._tokens_produced += worker_tokens
        # stats: cost = tokens ingested; mem = backlog size (migratable state)
        keys = np.asarray(sorted(set(per_key_tokens)
                                 | {k for b in self.backlogs for k in b}),
                          np.int64)
        if len(keys) == 0:
            return worker_tokens
        backlog_size = defaultdict(float)
        for b in self.backlogs:
            for k, toks in b.items():
                backlog_size[k] += len(toks)
        stats = KeyStats(
            keys=keys,
            cost=np.asarray([per_key_tokens.get(int(k), 0.0) for k in keys]),
            mem=np.asarray([backlog_size.get(int(k), 1.0) for k in keys]))
        self.controller.on_interval(stats)
        return worker_tokens

    # --------------------------------------------------------------- batches
    def worker_batch(self, worker: int, batch: int
                     ) -> Optional[Dict[str, np.ndarray]]:
        """Pack `batch` sequences of seq_len (+1 for labels) or None."""
        need = batch * (self.seq_len + 1)
        pool: List[int] = self.remainder[worker]
        self.remainder[worker] = []
        backlog = self.backlogs[worker]
        for k in sorted(backlog):
            if len(pool) >= need:
                break
            pool.extend(backlog[k])
            backlog[k] = []
        if len(pool) < need:
            self.remainder[worker] = pool
            return None
        self.remainder[worker] = pool[need:]
        arr = np.asarray(pool[:need], np.int32).reshape(batch,
                                                        self.seq_len + 1)
        return {"tokens": arr[:, :-1], "labels": arr[:, 1:]}

    # ------------------------------------------------------------ checkpoint
    def state_dict(self) -> dict:
        return {
            "interval": self.interval,
            "weights": {k: s.weight for k, s in self.sources.items()},
            "backlogs": [{k: list(v) for k, v in b.items()}
                         for b in self.backlogs],
            "remainder": [list(r) for r in self.remainder],
            "table": dict(self.controller.assignment.table),
        }

    def load_state(self, state: dict) -> None:
        self.interval = state["interval"]
        for k, w in state["weights"].items():
            self.sources[int(k)].weight = w
        self.backlogs = [defaultdict(list, {int(k): list(v)
                                            for k, v in b.items()})
                         for b in state["backlogs"]]
        self.remainder = [list(r) for r in state["remainder"]]
        self.controller.assignment.table = {int(k): int(v) for k, v
                                            in state["table"].items()}


def zipf_sources(n: int, z: float = 1.0, seed: int = 0) -> List[SourceSpec]:
    rng = np.random.default_rng(seed)
    w = (np.arange(1, n + 1, dtype=np.float64) ** -z)
    rng.shuffle(w)
    return [SourceSpec(i, float(w[i]), mean_len=256) for i in range(n)]
