"""gemma3-12b [dense] — 5:1 local:global attention, 128k context
[hf:google/gemma-3-1b-pt; unverified].

48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144. Superblock of 6:
five sliding-window (1024) layers then one global layer. The sliding-window
majority makes long-context decode sub-quadratic in 5/6 of layers; global
layers are linear-per-token at decode -> long_500k runs.
"""

import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8, d_ff=15360,
    vocab=262144, head_dim=240,
    layer_pattern=("attn",) * 6,
    window_pattern=(1024, 1024, 1024, 1024, 1024, 0),
    sub_quadratic=True,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=6, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512, window_pattern=(32, 32, 32, 32, 32, 0))
