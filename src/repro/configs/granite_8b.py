"""granite-8b [dense] — llama-arch code model [arXiv:2405.04324; hf].

36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152.
Full attention -> no long_500k cell.
"""

import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=49152, head_dim=128,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512)
