"""granite-20b [dense] — llama-arch code model, MQA [arXiv:2405.04324; hf].

52L d_model=6144 48H (GQA kv=1 = multi-query) d_ff=24576 vocab=49152.
Full attention -> no long_500k cell.
"""

import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1, d_ff=24576,
    vocab=49152, head_dim=128,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
    d_ff=128, vocab=512)
