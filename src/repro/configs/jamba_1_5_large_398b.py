"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e
top-2 every other layer [arXiv:2403.19887; hf].

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536. Superblock of 8:
attention at position 3, Mamba elsewhere; MoE on odd layers (period 8 % 2 == 0
so the pattern tiles exactly). sub_quadratic: Mamba carries long context.
"""

import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=24576,
    vocab=65536, head_dim=128,
    layer_pattern=("mamba", "mamba", "mamba", "attn",
                   "mamba", "mamba", "mamba", "mamba"),
    window_pattern=(0,),
    moe_experts=16, moe_topk=2, moe_every=2, moe_offset=1,
    mamba_d_state=16, mamba_d_conv=4, mamba_expand=2,
    sub_quadratic=True,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512, moe_experts=4, moe_topk=2)
