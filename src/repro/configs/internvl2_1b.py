"""internvl2-1b [vlm] — InternViT frontend (STUB patch embeddings) + an
InternLM2-0.9B decoder backbone [arXiv:2404.16821; hf].

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655. The vision tower is a
stub per the assignment: input_specs() provides precomputed pixel embeddings
(B, 256, d_model) prepended to the text sequence. Full attention -> no
long_500k cell.
"""

import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, d_ff=4864,
    vocab=151655,
    frontend="vision_stub", prefix_len=256,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=4, d_model=56, n_heads=7, n_kv_heads=1, d_ff=112,
    vocab=500, prefix_len=16)
