"""xlstm-125m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

12L d_model=768 4H d_ff=0 (the xLSTM blocks carry their own projections)
vocab=50304. Alternating mlstm/slstm per the paper's mixed stacks.
Recurrent state is O(1) in sequence length -> long_500k runs.
"""

import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab=50304,
    layer_pattern=("mlstm", "slstm"),
    sub_quadratic=True,
    tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, vocab=512)
