"""dbrx-132b [moe] — 16 experts top-4, fine-grained
[hf:databricks/dbrx-base; unverified].

40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352, MoE 16e top-4 in
every layer. Full attention -> no long_500k cell.
"""

import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=10752,
    vocab=100352, head_dim=128,
    moe_experts=16, moe_topk=4, moe_every=1,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=96, vocab=512, moe_experts=4, moe_topk=2)
