"""whisper-large-v3 [audio] — encoder-decoder, conv frontend STUBBED
[arXiv:2212.04356; unverified].

32L d_model=1280 20H (kv=20, full MHA) d_ff=5120 vocab=51866. The assignment
specifies the transformer backbone only: input_specs() provides precomputed
mel-frame embeddings (B, 1500, d_model); the decoder (32L) cross-attends to
the 32L encoder. Decode shapes exercise the decoder KV cache; full attention
-> no long_500k cell.
"""

import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20, d_ff=5120,
    vocab=51866, head_dim=64,
    encoder_layers=32, encoder_seq=1500, frontend="audio_stub",
    rope_theta=10_000.0,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=512, encoder_layers=2, encoder_seq=32)
