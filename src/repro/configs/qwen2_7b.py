"""qwen2-7b [dense] — GQA with QKV bias [arXiv:2407.10671; hf].

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064. 28 heads do not
divide the 16-way model axis: attention projections fall back to replication
(recorded by the sharding layer), FFN/vocab shard normally.
Full attention -> no long_500k cell.
"""

import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, d_ff=18944,
    vocab=152064, head_dim=128, qkv_bias=True,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=4, d_model=56, n_heads=7, n_kv_heads=1, head_dim=8,
    d_ff=112, vocab=512)
