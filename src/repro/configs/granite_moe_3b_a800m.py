"""granite-moe-3b-a800m [moe] — 40 experts top-8, finest granularity
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf].

32L d_model=1536 24H (GQA kv=8) d_ff=512 (per expert) vocab=49155,
MoE 40e top-8 in every layer. The balancer's best showcase: many small
experts -> fine-grained key domain.
"""

import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, d_ff=512,
    vocab=49155, head_dim=64,
    moe_experts=40, moe_topk=8, moe_every=1,
    tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=32, vocab=512, moe_experts=8, moe_topk=2)
