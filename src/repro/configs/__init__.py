"""Assigned-architecture registry: one module per arch, exact public configs.

Every config is selectable via --arch <id> in the launchers; reduced
smoke-size variants (same family, tiny dims) come from ``smoke_config``.
"""

import dataclasses
import importlib

ARCHS = [
    "jamba_1_5_large_398b",
    "internvl2_1b",
    "dbrx_132b",
    "granite_moe_3b_a800m",
    "granite_20b",
    "granite_8b",
    "gemma3_12b",
    "qwen2_7b",
    "xlstm_125m",
    "whisper_large_v3",
]

ALIASES = {a.replace("_", "-"): a for a in ARCHS}


def get_config(name: str):
    mod_name = ALIASES.get(name, name).replace("-", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def smoke_config(name: str):
    mod_name = ALIASES.get(name, name).replace("-", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.SMOKE


def all_configs():
    return {a: get_config(a) for a in ARCHS}
