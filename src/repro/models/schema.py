"""Declarative parameter schemas.

Every model builds a pytree of :class:`ParamSpec` (pure function of config);
the same schema then serves three consumers without drift:

* ``init(schema, rng)``          -> materialized params (random init)
* ``shardings(schema, mesh, rules)`` -> NamedSharding tree (logical axes ->
  mesh axes, with automatic divisibility fallback to replication)
* ``abstract(schema)``           -> ShapeDtypeStruct tree (dry-run, no alloc)

Logical axis names used across the zoo:
  embed (d_model), vocab, q_heads (flattened heads*head_dim), kv_flat
  (flattened kv_heads*head_dim), mlp (d_ff), expert, mamba_inner, conv,
  stack (scan-stacked layer dim), none (replicated).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]        # logical axis name per dim
    dtype: jnp.dtype = jnp.bfloat16
    init: str = "normal"                   # normal | zeros | ones
    scale: Optional[float] = None          # None -> 1/sqrt(fan_in)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _materialize(spec: ParamSpec, key) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    fan_in = spec.shape[0] if len(spec.shape) > 1 else max(spec.shape[-1], 1)
    scale = spec.scale if spec.scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, spec.shape, jnp.float32) * scale).astype(
        spec.dtype)


def init(schema, rng) -> dict:
    leaves, treedef = jax.tree.flatten(
        schema, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(rng, len(leaves))
    vals = [_materialize(spec, k) for spec, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract(schema) -> dict:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), schema,
        is_leaf=lambda x: isinstance(x, ParamSpec))


# default logical-axis -> mesh-axis rules (the TP/EP mapping)
DEFAULT_RULES = {
    "vocab": "model",
    "q_heads": "model",
    "kv_flat": "model",
    "mlp": "model",
    "expert": "model",
    "mamba_inner": "model",
    "heads": "model",
    "embed": None,            # d_model replicated (TP on the other operand)
    "stack": None,
    "conv": None,
    None: None,
}


def spec_for(spec: ParamSpec, mesh: Mesh, rules=None) -> P:
    """Logical axes -> PartitionSpec with divisibility fallback.

    A dim only shards if its size divides the mesh axis product; otherwise it
    falls back to replication (the pragmatic choice for e.g. qwen2's 28 heads
    on a 16-way model axis — recorded by callers for the roofline report).
    """
    rules = {**DEFAULT_RULES, **(rules or {})}
    out, used = [], set()
    for size, axis in zip(spec.shape, spec.axes):
        mesh_axis = rules.get(axis)
        if mesh_axis is None or mesh_axis in used:
            out.append(None)
            continue
        ax_size = int(np.prod([mesh.shape[a] for a in (
            mesh_axis if isinstance(mesh_axis, tuple) else (mesh_axis,))]))
        if size % ax_size == 0:
            out.append(mesh_axis)
            used.add(mesh_axis)
        else:
            out.append(None)
    return P(*out)


def shardings(schema, mesh: Mesh, rules=None):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, spec_for(s, mesh, rules)), schema,
        is_leaf=lambda x: isinstance(x, ParamSpec))


def partition_specs(schema, mesh: Mesh, rules=None):
    return jax.tree.map(
        lambda s: spec_for(s, mesh, rules), schema,
        is_leaf=lambda x: isinstance(x, ParamSpec))


def count_params(schema) -> int:
    leaves = jax.tree.leaves(schema, is_leaf=lambda x: isinstance(x, ParamSpec))
    return int(sum(math.prod(s.shape) for s in leaves))


def replication_report(schema, mesh: Mesh, rules=None) -> dict:
    """Which logical axes failed divisibility and got replicated (roofline)."""
    report = {}

    def visit(path, s):
        ps = spec_for(s, mesh, rules)
        for size, logical, assigned in zip(s.shape, s.axes, ps):
            if logical not in (None, "stack", "embed", "conv") and assigned is None:
                report.setdefault(logical, set()).add(size)

    jax.tree_util.tree_map_with_path(visit, schema,
                                     is_leaf=lambda x: isinstance(x, ParamSpec))
    return {k: sorted(v) for k, v in report.items()}
