"""Mamba (S6) block for the jamba hybrid — chunked parallel scan for TPU.

Hardware adaptation: the CUDA selective-scan kernel keeps state in SRAM
across a sequential sweep. On TPU we chunk time into CH-step blocks, run
``jax.lax.associative_scan`` *within* a chunk (VMEM-sized transient:
B x CH x D_in x N), and carry the (B, D_in, N) state *across* chunks with a
short sequential scan of length T/CH — MXU-dense inside, O(T/CH) serial
steps outside. Decode consumes/updates the carried state in O(1).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .schema import ParamSpec


def mamba_schema(cfg: ModelConfig, stack=()):
    st = tuple(["stack"] * len(stack))
    d = cfg.d_model
    di = cfg.mamba_expand * d
    n = cfg.mamba_d_state
    dc = cfg.mamba_d_conv
    dt_rank = max(1, d // 16)
    return {
        "in_proj": ParamSpec(stack + (d, 2 * di), st + ("embed", "mamba_inner")),
        "conv_w": ParamSpec(stack + (dc, di), st + ("conv", "mamba_inner"),
                            scale=0.5),
        "conv_b": ParamSpec(stack + (di,), st + ("mamba_inner",), init="zeros"),
        "x_proj": ParamSpec(stack + (di, dt_rank + 2 * n),
                            st + ("mamba_inner", None)),
        "dt_proj": ParamSpec(stack + (dt_rank, di), st + (None, "mamba_inner"),
                             scale=0.1),
        "dt_bias": ParamSpec(stack + (di,), st + ("mamba_inner",), init="zeros"),
        "a_log": ParamSpec(stack + (di, n), st + ("mamba_inner", None),
                           init="ones", dtype=jnp.float32),
        "d_skip": ParamSpec(stack + (di,), st + ("mamba_inner",), init="ones",
                            dtype=jnp.float32),
        "out_proj": ParamSpec(stack + (di, d), st + ("mamba_inner", "embed")),
    }


def _ssm_scan_chunked(a, bx, h0, chunk: int):
    """h_t = a_t * h_{t-1} + bx_t over time, chunked associative scan.

    a, bx: (B, T, Di, N); h0: (B, Di, N). Returns (h_all (B,T,Di,N), h_T).
    """
    b, t, di, n = a.shape
    assert t % chunk == 0, (t, chunk)
    nc = t // chunk
    a_c = a.reshape(b, nc, chunk, di, n)
    bx_c = bx.reshape(b, nc, chunk, di, n)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, br + ar * bl

    # within-chunk prefix (parallel, VMEM-sized transient)
    a_pref, bx_pref = jax.lax.associative_scan(combine, (a_c, bx_c), axis=2)

    # across-chunk carry (sequential, length T/chunk)
    def step(h, inputs):
        a_last, bx_last, a_all, bx_all = inputs
        h_all = a_all * h[:, None] + bx_all          # (B, chunk, Di, N)
        h_next = a_last * h + bx_last
        return h_next, h_all

    carry_in = (a_pref[:, :, -1], bx_pref[:, :, -1], a_pref, bx_pref)
    carry_in = jax.tree.map(lambda x: jnp.moveaxis(x, 1, 0), carry_in)
    h_t, h_all = jax.lax.scan(step, h0, carry_in)
    h_all = jnp.moveaxis(h_all, 0, 1).reshape(b, t, di, n)
    return h_all, h_t


def mamba(p, cfg: ModelConfig, x: jax.Array,
          state: Optional[dict] = None, chunk: int = 256
          ) -> Tuple[jax.Array, Optional[dict]]:
    """x: (B, T, D). state (decode): {"h": (B, Di, N), "conv": (B, dc-1, Di)}.

    Training/prefill: state=None, full-sequence chunked scan.
    Decode: T small (usually 1); sequential update from carried state.
    """
    b, t, d = x.shape
    di = cfg.mamba_expand * d
    n = cfg.mamba_d_state
    dc = cfg.mamba_d_conv
    dt_rank = max(1, d // 16)

    xz = jnp.einsum("btd,de->bte", x, p["in_proj"])
    xs, z = jnp.split(xz, 2, axis=-1)                      # (B, T, Di) each

    # causal depthwise conv over time
    if state is not None:
        conv_in = jnp.concatenate([state["conv"], xs], axis=1)  # (B, dc-1+T, Di)
        new_conv = conv_in[:, -(dc - 1):, :]
    else:
        conv_in = jnp.pad(xs, ((0, 0), (dc - 1, 0), (0, 0)))
        new_conv = conv_in[:, -(dc - 1):, :]
    windows = jnp.stack([conv_in[:, i:i + t, :] for i in range(dc)], axis=2)
    xs = jnp.einsum("btcd,cd->btd", windows, p["conv_w"]) + p["conv_b"]
    xs = jax.nn.silu(xs)

    proj = jnp.einsum("btd,dp->btp", xs, p["x_proj"])
    dt_low, b_in, c_in = jnp.split(proj, [dt_rank, dt_rank + n], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("btr,rd->btd", dt_low, p["dt_proj"])
                         + p["dt_bias"])                   # (B, T, Di)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))           # (Di, N)
    # discretize: a_bar = exp(dt * A); b_bar x = dt * B * x
    a_bar = jnp.exp(dt.astype(jnp.float32)[..., None] * a)         # (B,T,Di,N)
    bx = (dt.astype(jnp.float32) * xs.astype(jnp.float32))[..., None] * \
        b_in.astype(jnp.float32)[:, :, None, :]                    # (B,T,Di,N)

    h0 = state["h"] if state is not None else jnp.zeros((b, di, n), jnp.float32)
    if t == 1:
        h_t = a_bar[:, 0] * h0 + bx[:, 0]
        h_all = h_t[:, None]
    else:
        c = min(chunk, t)
        while t % c:                      # largest divisor of t that is <= chunk
            c -= 1
        h_all, h_t = _ssm_scan_chunked(a_bar, bx, h0, c)

    y = jnp.einsum("btdn,btn->btd", h_all,
                   c_in.astype(jnp.float32))               # (B, T, Di)
    y = y + p["d_skip"] * xs.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("btd,de->bte", y, p["out_proj"])
    # state is always returned: prefill hands it to the decode loop; the
    # training step simply drops it.
    return out, {"h": h_t, "conv": new_conv}
