"""Model configuration covering all 10 assigned architecture families."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None            # default d_model // n_heads
    # layer pattern, cycled over layers: attn | mamba | slstm | mlstm
    layer_pattern: Tuple[str, ...] = ("attn",)
    # sliding-window size per pattern position (0 = global attention)
    window_pattern: Tuple[int, ...] = (0,)
    qkv_bias: bool = False
    # MoE: layers where (layer_idx % moe_every == moe_offset) use MoE MLP
    moe_experts: int = 0
    moe_topk: int = 0
    moe_every: int = 1
    moe_offset: int = 0
    moe_capacity_factor: float = 1.25
    # mamba (jamba-style)
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    # encoder-decoder (whisper) / multimodal stub frontends
    encoder_layers: int = 0
    encoder_seq: int = 0                      # stub frames/patches length
    frontend: str = "none"                    # none | audio_stub | vision_stub
    prefix_len: int = 0                       # vision prefix tokens (vlm)
    # misc
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    # sub-quadratic capable? (drives long_500k applicability)
    sub_quadratic: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def vocab_padded(self) -> int:
        return ((self.vocab + 127) // 128) * 128

    @property
    def pattern_period(self) -> int:
        return len(self.layer_pattern)

    def layer_kind(self, i: int) -> str:
        return self.layer_pattern[i % self.pattern_period]

    def layer_window(self, i: int) -> int:
        return self.window_pattern[i % len(self.window_pattern)]

    def layer_is_moe(self, i: int) -> bool:
        return (self.moe_experts > 0
                and i % self.moe_every == self.moe_offset)

    def validate(self) -> None:
        assert self.n_heads % self.n_kv_heads == 0 or self.n_kv_heads == 0
        if self.moe_experts:
            assert 0 < self.moe_topk <= self.moe_experts
        assert self.n_layers % self.pattern_period == 0, \
            (self.name, self.n_layers, self.pattern_period)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell: what gets lowered in the dry-run."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
