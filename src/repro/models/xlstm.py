"""xLSTM blocks (arXiv:2405.04517): sLSTM (scalar memory, exponential gating)
and mLSTM (matrix memory, attention-like). TPU adaptation: the mLSTM
recurrence admits a chunked form — within a chunk the matrix-memory readout
is a masked attention-like GEMM (MXU), across chunks the (B, H, Dh, Dh)
memory is carried sequentially; the sLSTM is inherently sequential and runs
as a time scan (it is the minority block and the model family is small)."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .schema import ParamSpec


def _heads(cfg: ModelConfig):
    h = cfg.n_heads
    dh = cfg.d_model // h
    return h, dh


def slstm_schema(cfg: ModelConfig, stack=()):
    st = tuple(["stack"] * len(stack))
    d = cfg.d_model
    return {
        "w_izfo": ParamSpec(stack + (d, 4 * d), st + ("embed", "mamba_inner")),
        "r_izfo": ParamSpec(stack + (d, 4 * d), st + ("embed", "mamba_inner"),
                            scale=0.05),
        "b_izfo": ParamSpec(stack + (4 * d,), st + ("mamba_inner",),
                            init="zeros"),
        "out": ParamSpec(stack + (d, d), st + ("mamba_inner", "embed")),
    }


def slstm(p, cfg: ModelConfig, x: jax.Array,
          state: Optional[dict] = None) -> Tuple[jax.Array, dict]:
    """Scalar-memory LSTM with exponential gating + stabilizer state.

    state: {"c","n","m","h"} each (B, D).
    """
    b, t, d = x.shape
    if state is None:
        zeros = jnp.zeros((b, d), jnp.float32)
        state = {"c": zeros, "n": zeros, "m": zeros - 1e30, "h": zeros}
    wx = jnp.einsum("btd,de->bte", x, p["w_izfo"])          # (B, T, 4D)

    def step(s, wx_t):
        rec = jnp.einsum("bd,de->be", s["h"].astype(x.dtype), p["r_izfo"])
        z_i, z_z, z_f, z_o = jnp.split(
            (wx_t + rec + p["b_izfo"]).astype(jnp.float32), 4, axis=-1)
        i_log = z_i                                          # exp-gate logits
        f_log = jax.nn.log_sigmoid(z_f)
        m_new = jnp.maximum(f_log + s["m"], i_log)           # stabilizer
        i_g = jnp.exp(i_log - m_new)
        f_g = jnp.exp(f_log + s["m"] - m_new)
        c_new = f_g * s["c"] + i_g * jnp.tanh(z_z)
        n_new = f_g * s["n"] + i_g
        h_new = jax.nn.sigmoid(z_o) * c_new / jnp.maximum(n_new, 1e-6)
        return {"c": c_new, "n": n_new, "m": m_new, "h": h_new}, h_new

    state, hs = jax.lax.scan(step, state, jnp.moveaxis(wx, 1, 0))
    hs = jnp.moveaxis(hs, 0, 1).astype(x.dtype)              # (B, T, D)
    return jnp.einsum("btd,de->bte", hs, p["out"]), state


def mlstm_schema(cfg: ModelConfig, stack=()):
    st = tuple(["stack"] * len(stack))
    d = cfg.d_model
    return {
        "wq": ParamSpec(stack + (d, d), st + ("embed", "q_heads")),
        "wk": ParamSpec(stack + (d, d), st + ("embed", "q_heads")),
        "wv": ParamSpec(stack + (d, d), st + ("embed", "q_heads")),
        "w_if": ParamSpec(stack + (d, 2), st + ("embed", None),
                          dtype=jnp.float32),
        "b_if": ParamSpec(stack + (2,), st + (None,), init="zeros",
                          dtype=jnp.float32),
        "out": ParamSpec(stack + (d, d), st + ("q_heads", "embed")),
    }


def mlstm(p, cfg: ModelConfig, x: jax.Array,
          state: Optional[dict] = None, chunk: int = 128
          ) -> Tuple[jax.Array, dict]:
    """Matrix-memory LSTM, chunkwise-parallel.

    state: {"C": (B,H,Dh,Dh), "n": (B,H,Dh), "m": (B,H)}.
    Within a chunk: decay-masked attention-like readout (quadratic in chunk
    only); across chunks: sequential memory carry. Simplified stabilizer:
    per-chunk max-decay normalization.
    """
    b, t, d = x.shape
    h, dh = _heads(cfg)
    if state is None:
        state = {"C": jnp.zeros((b, h, dh, dh), jnp.float32),
                 "n": jnp.zeros((b, h, dh), jnp.float32),
                 "m": jnp.zeros((b, 1), jnp.float32)}   # shared across heads
    # f32 cell arithmetic: exponential gating amplifies bf16 rounding into
    # chunking-dependent outputs (verified: f32 is chunk-invariant to 1e-5).
    q = jnp.einsum("btd,de->bte", x, p["wq"]).reshape(b, t, h, dh)
    q = q.astype(jnp.float32)
    k = jnp.einsum("btd,de->bte", x, p["wk"]).reshape(b, t, h, dh)
    k = k.astype(jnp.float32) / (dh ** 0.5)
    v = jnp.einsum("btd,de->bte", x, p["wv"]).reshape(b, t, h, dh)
    v = v.astype(jnp.float32)
    if_log = jnp.einsum("btd,dg->btg", x.astype(jnp.float32), p["w_if"]) + \
        p["b_if"]
    i_log = if_log[..., 0]                                   # (B, T)
    f_log = jax.nn.log_sigmoid(if_log[..., 1])               # (B, T)

    c = min(chunk, t)
    while t % c:
        c -= 1
    nc = t // c

    def chunk_step(s, inp):
        # Gates are per-token scalars shared across heads (simplification of
        # the per-head gates in the paper; noted in DESIGN.md).
        qc, kc, vc, ic, fc = inp                             # (B,c,...) per chunk
        fcum = jnp.cumsum(fc, axis=1)                        # F_j (B, c)
        # intra-chunk decay: w[j,u] = exp(F_j - F_u + i_u) for u <= j
        decay = fcum[:, :, None] - fcum[:, None, :] + ic[:, None, :]
        mask = jnp.tril(jnp.ones((c, c), bool))
        decay = jnp.where(mask[None], decay, -1e30)
        # per-position stabilizer: m_j = max(max_u decay[j,u], m_carry + F_j)
        m_pos = jnp.maximum(jnp.max(decay, axis=2), s["m"] + fcum)   # (B, c)
        w = jnp.exp(decay - m_pos[:, :, None])               # (B, c, c)
        carry_scale = jnp.exp(s["m"] + fcum - m_pos)         # (B, c)
        logits = jnp.einsum("bjhd,buhd->bhju", qc, kc)       # (B,H,c,c)
        intra = jnp.einsum("bhju,bju,buhe->bjhe", logits,
                           w.astype(logits.dtype), vc)
        inter = jnp.einsum("bjhd,bhde->bjhe", qc, s["C"].astype(qc.dtype))
        num = intra + inter * carry_scale[:, :, None, None].astype(qc.dtype)
        den_intra = jnp.einsum("bhju,bju->bjh",
                               logits, w.astype(logits.dtype))
        den_inter = jnp.einsum("bjhd,bhd->bjh", qc, s["n"].astype(qc.dtype))
        den = jnp.abs(den_intra +
                      den_inter * carry_scale[:, :, None].astype(qc.dtype))
        # floor at exp(-m): in true (unstabilized) scale this is max(|.|, 1),
        # making the output invariant to the chunking of the stabilizer.
        floor = jnp.exp(-m_pos)[:, :, None]
        out_c = num / jnp.maximum(den, floor.astype(den.dtype))[..., None]
        # end-of-chunk memory carry
        f_tot = fcum[:, -1:]                                 # (B, 1)
        tail = f_tot - fcum + ic                             # (B, c)
        m_new = jnp.maximum(s["m"] + f_tot, jnp.max(tail, axis=1,
                                                    keepdims=True))
        wk = jnp.exp(tail - m_new)                           # (B, c)
        c_upd = jnp.einsum("bu,buhd,buhe->bhde",
                           wk.astype(kc.dtype), kc, vc).astype(jnp.float32)
        n_upd = jnp.einsum("bu,buhd->bhd",
                           wk.astype(kc.dtype), kc).astype(jnp.float32)
        scale_old = jnp.exp(s["m"] + f_tot - m_new)          # (B, 1)
        c_new = s["C"] * scale_old[:, :, None, None] + c_upd
        n_new = s["n"] * scale_old[:, :, None] + n_upd
        return {"C": c_new, "n": n_new, "m": m_new}, out_c

    xs = (q.reshape(b, nc, c, h, dh), k.reshape(b, nc, c, h, dh),
          v.reshape(b, nc, c, h, dh), i_log.reshape(b, nc, c),
          f_log.reshape(b, nc, c))
    xs = jax.tree.map(lambda a: jnp.moveaxis(a, 1, 0), xs)
    state, outs = jax.lax.scan(chunk_step, state, xs)
    outs = jnp.moveaxis(outs, 0, 1).reshape(b, t, h * dh).astype(x.dtype)
    return jnp.einsum("bte,ed->btd", outs, p["out"]), state
