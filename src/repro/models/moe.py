"""Mixture-of-Experts layer with SkewShield expert placement.

Dispatch is sort-based with static capacity (TPU-friendly: gathers + dense
batched GEMMs, no dynamic shapes):

  1. router top-k over logical experts;
  2. **SkewShield** (the paper's technique): logical expert ids are remapped
     through a ``placement`` vector — the mixed routing function F(e) of
     paper Eq. 1 materialized as an array. The balancer (repro.core) updates
     it between steps from measured expert loads; being a jit *argument*, a
     new placement never triggers recompilation;
  3. flat (token, slot) pairs sorted by physical expert; rank-in-expert via
     a searchsorted prefix; entries past capacity are dropped (classic
     capacity-factor semantics — imbalance becomes token drops, which is
     exactly the failure mode SkewShield minimizes);
  4. gather tokens into an (E, cap, D) buffer sharded over the model axis
     (EP), run the expert FFNs as batched GEMMs, gather back per (token,
     slot) and combine with gate weights. No scatter touches the D-wide
     data path.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding.ctx import constrain

from .config import ModelConfig
from .schema import ParamSpec


def moe_schema(cfg: ModelConfig, stack=()):
    st = tuple(["stack"] * len(stack))
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe_experts
    return {
        "router": ParamSpec(stack + (d, e), st + ("embed", None),
                            dtype=jnp.float32),
        "w_gate": ParamSpec(stack + (e, d, f), st + ("expert", "embed", "mlp")),
        "w_up": ParamSpec(stack + (e, d, f), st + ("expert", "embed", "mlp")),
        "w_down": ParamSpec(stack + (e, f, d), st + ("expert", "mlp", "embed")),
    }


def capacity_for(n_tokens: int, cfg: ModelConfig) -> int:
    cap = int(math.ceil(n_tokens * cfg.moe_topk * cfg.moe_capacity_factor
                        / cfg.moe_experts))
    return max(8, ((cap + 7) // 8) * 8)


def _dispatch_groups(n_tokens: int) -> int:
    """Dispatch-group count = DP degree of the installed mesh (perf: sort and
    rank stay *local* to each data shard; a single global argsort over N*k
    elements otherwise forces a cross-mesh sort network). 1 when unsharded.

    Gated behind REPRO_PERF_MOE_GROUPED so the paper-faithful baseline stays
    reproducible; hillclimb runs (and production configs) enable it.
    """
    import os
    if os.environ.get("REPRO_PERF_MOE_GROUPED", "0") != "1":
        return 1
    from repro.sharding.ctx import current_mesh
    mesh = current_mesh()
    if mesh is None:
        return 1
    import numpy as np
    g = int(np.prod([mesh.shape[a] for a in ("pod", "data")
                     if a in mesh.axis_names]))
    return g if n_tokens % g == 0 else 1


def moe(p, cfg: ModelConfig, x: jax.Array,
        placement: Optional[jax.Array] = None,
        return_stats: bool = False):
    """x: (B, T, D) -> (B, T, D) [, per-expert load (E,)].

    placement: (E,) int32 — physical slot of each logical expert (SkewShield
    F(e); identity = paper's pure-hash baseline).

    Dispatch is group-wise: tokens are split into G groups aligned with the
    DP shards; sort, rank and capacity are per (group, expert) — the
    standard EP formulation (local capacity) whose only cross-shard traffic
    is the (G, E, cap_g, D) buffer: an all-to-all between the data and model
    axes, O(tokens x D) bytes.
    """
    b, t, d = x.shape
    e, k = cfg.moe_experts, cfg.moe_topk
    n = b * t
    g = _dispatch_groups(n)
    ng = n // g                                            # tokens per group
    cap = capacity_for(ng, cfg)                            # per-group capacity
    xf = x.reshape(g, ng, d)
    xf = constrain(xf, "dp", None, None)

    gates = jnp.einsum("gnd,de->gne", xf.astype(jnp.float32), p["router"])
    top_vals, top_idx = jax.lax.top_k(gates, k)            # (G, Ng, k)
    weights = jax.nn.softmax(top_vals, axis=-1)

    flat_logical = top_idx.reshape(g, ng * k)
    if placement is not None:
        flat_e = placement[flat_logical]                   # SkewShield F(e)
    else:
        flat_e = flat_logical

    order = jnp.argsort(flat_e, axis=-1, stable=True)      # (G, Ng*k) local
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    starts = jax.vmap(lambda se: jnp.searchsorted(se, jnp.arange(e)))(sorted_e)
    rank_sorted = jnp.arange(ng * k)[None] - \
        jnp.take_along_axis(starts, sorted_e, axis=-1)
    keep_sorted = rank_sorted < cap

    # (G, E*cap) dispatch buffer of local token indices; Ng = sentinel
    slot = sorted_e * cap + jnp.minimum(rank_sorted, cap - 1)
    tok_sorted = order // k
    dispatch = jnp.full((g, e * cap), ng, jnp.int32)
    dispatch = jax.vmap(
        lambda dsp, sl, val: dsp.at[sl].set(val, mode="drop"))(
        dispatch, slot,
        jnp.where(keep_sorted, tok_sorted, ng).astype(jnp.int32))
    x_pad = jnp.concatenate([xf, jnp.zeros((g, 1, d), xf.dtype)], axis=1)
    xs = jnp.take_along_axis(x_pad, dispatch[..., None], axis=1)
    xs = xs.reshape(g, e, cap, d)
    # EP boundary: (G, E, cap, D) sharded (dp, model) -> all-to-all here
    xs = constrain(xs, "dp", "tp", None, None)

    gate_h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xs, p["w_gate"]))
    up_h = jnp.einsum("gecd,edf->gecf", xs, p["w_up"])
    ys = jnp.einsum("gecf,efd->gecd", gate_h * up_h, p["w_down"])
    ys = constrain(ys, "dp", "tp", None, None)
    ys_flat = ys.reshape(g, e * cap, d)

    # combine: per (token, slot) gather its expert output back (local)
    rank_of = jax.vmap(lambda o, r: jnp.zeros((ng * k,), jnp.int32)
                       .at[o].set(r.astype(jnp.int32)))(order, rank_sorted)
    keep_of = jax.vmap(lambda o, kp: jnp.zeros((ng * k,), bool)
                       .at[o].set(kp))(order, keep_sorted)
    src = flat_e * cap + jnp.minimum(rank_of, cap - 1)
    # NB: the zero literal must carry ys' dtype — a float 0.0 weak-promotes
    # the whole combine (and its backward all-reduces) to f32 (§Perf 1.3).
    y_tok = jnp.where(keep_of[..., None],
                      jnp.take_along_axis(ys_flat, src[..., None], axis=1),
                      jnp.zeros((), ys_flat.dtype))         # (G, Ng*k, D)
    out = jnp.sum(y_tok.reshape(g, ng, k, d) *
                  weights[..., None].astype(y_tok.dtype), axis=2)
    out = constrain(out, "dp", None, None).reshape(b, t, d)
    if return_stats:
        load = jnp.zeros((e,), jnp.float32).at[flat_e.reshape(-1)].add(1.0)
        dropped = jnp.sum(~keep_sorted)
        return out, {"expert_load": load, "dropped": dropped}
    return out


def aux_load_balance_loss(gates_softmax: jax.Array, top_idx: jax.Array,
                          e: int) -> jax.Array:
    """Switch-style auxiliary loss (the *long-term* fix the paper contrasts
    with; kept for completeness/ablation)."""
    me = jnp.mean(gates_softmax, axis=0)
    ce = jnp.zeros((e,)).at[top_idx.reshape(-1)].add(1.0)
    ce = ce / jnp.maximum(jnp.sum(ce), 1.0)
    return e * jnp.sum(me * ce)
