"""Shared building blocks: RMSNorm, RoPE, gated MLP, embeddings."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .schema import ParamSpec


# ------------------------------------------------------------------ norm --
def rmsnorm_schema(d: int, stack=()):
    return {"scale": ParamSpec(stack + (d,), tuple(["stack"] * len(stack)) +
                               ("embed",), init="ones", dtype=jnp.float32)}


def rmsnorm(p, x, eps: float = 1e-6):
    h = x.astype(jnp.float32)
    var = jnp.mean(h * h, axis=-1, keepdims=True)
    return (h * jax.lax.rsqrt(var + eps) * p["scale"]).astype(x.dtype)


# ------------------------------------------------------------------ rope --
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, T, H, Dh) with positions (B, T) or (T,)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, T, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------- mlp --
def mlp_schema(cfg: ModelConfig, stack=()):
    st = tuple(["stack"] * len(stack))
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_gate": ParamSpec(stack + (d, f), st + ("embed", "mlp")),
        "w_up": ParamSpec(stack + (d, f), st + ("embed", "mlp")),
        "w_down": ParamSpec(stack + (f, d), st + ("mlp", "embed")),
    }


def mlp(p, x):
    """SwiGLU feed-forward."""
    gate = jax.nn.silu(jnp.einsum("btd,df->btf", x, p["w_gate"]))
    up = jnp.einsum("btd,df->btf", x, p["w_up"])
    return jnp.einsum("btf,fd->btd", gate * up, p["w_down"])


# ------------------------------------------------------------- embedding --
def embed_schema(cfg: ModelConfig):
    return {
        # 1/sqrt(d) init: harmless for the forward pass (RMSNorm follows) and
        # keeps tied-unembedding logits at unit scale.
        "tokens": ParamSpec((cfg.vocab_padded, cfg.d_model),
                            ("vocab", "embed"), scale=cfg.d_model ** -0.5),
    }


def unembed_schema(cfg: ModelConfig):
    return {"w": ParamSpec((cfg.d_model, cfg.vocab_padded),
                           ("embed", "vocab"))}


def embed(p, tokens):
    return jnp.take(p["tokens"], tokens, axis=0)


def unembed(p, x):
    return jnp.einsum("btd,dv->btv", x, p["w"])
