"""Composable LM assembler for all 10 assigned architectures.

Layers are grouped into *superblocks* of ``cfg.layer_pattern`` length and
scanned with stacked parameters (n_groups leading dim): one traced block body
regardless of depth, which keeps dry-run HLO and compile time bounded for
72-layer hybrids. Layer kinds inside a superblock: attn | mamba | slstm |
mlstm, each optionally followed by a dense or MoE MLP.

The same forward serves train (cache=None), prefill (cache + index=0, T=seq)
and decode (cache + index=t, T=1) — the attention/SSM sublayers switch on the
presence of a cache.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding.ctx import constrain

from . import attention as attn_mod
from . import layers, mamba as mamba_mod, moe as moe_mod, xlstm as xlstm_mod
from .config import ModelConfig
from .schema import ParamSpec

PyTree = Any


# ------------------------------------------------------------------ schema --
def _sub_schema(cfg: ModelConfig, j: int, n_groups: int, cross: bool):
    kind = cfg.layer_pattern[j]
    stack = (n_groups,)
    sch: Dict[str, Any] = {"norm": layers.rmsnorm_schema(cfg.d_model, stack)}
    if kind == "attn":
        sch["attn"] = attn_mod.attn_schema(cfg, stack)
    elif kind == "mamba":
        sch["mamba"] = mamba_mod.mamba_schema(cfg, stack)
    elif kind == "slstm":
        sch["cell"] = xlstm_mod.slstm_schema(cfg, stack)
    elif kind == "mlstm":
        sch["cell"] = xlstm_mod.mlstm_schema(cfg, stack)
    else:
        raise ValueError(kind)
    if cross:
        sch["cross_norm"] = layers.rmsnorm_schema(cfg.d_model, stack)
        sch["cross"] = attn_mod.attn_schema(cfg, stack, cross=True)
    if cfg.d_ff > 0:
        sch["mlp_norm"] = layers.rmsnorm_schema(cfg.d_model, stack)
        if cfg.layer_is_moe(j):
            sch["moe"] = moe_mod.moe_schema(cfg, stack)
        else:
            sch["mlp"] = layers.mlp_schema(cfg, stack)
    return sch


def _encoder_cfg(cfg: ModelConfig) -> ModelConfig:
    return dataclasses.replace(
        cfg, n_layers=cfg.encoder_layers, layer_pattern=("attn",),
        window_pattern=(0,), moe_experts=0, qkv_bias=False)


def model_schema(cfg: ModelConfig) -> PyTree:
    cfg.validate()
    period = cfg.pattern_period
    n_groups = cfg.n_layers // period
    cross = cfg.encoder_layers > 0
    sch: Dict[str, Any] = {
        "embed": layers.embed_schema(cfg),
        "final_norm": layers.rmsnorm_schema(cfg.d_model),
        "groups": {f"sub{j}": _sub_schema(cfg, j, n_groups, cross)
                   for j in range(period)},
    }
    if not cfg.tie_embeddings:
        sch["unembed"] = layers.unembed_schema(cfg)
    if cross:
        ecfg = _encoder_cfg(cfg)
        sch["encoder"] = {
            "groups": {"sub0": _sub_schema(ecfg, 0, ecfg.n_layers, False)},
            "final_norm": layers.rmsnorm_schema(cfg.d_model),
        }
    return sch


# ------------------------------------------------------------------- cache --
def cache_schema(cfg: ModelConfig, batch: int, max_seq: int) -> PyTree:
    """Decode-state pytree as ParamSpecs (dry-run friendly)."""
    period = cfg.pattern_period
    n_groups = cfg.n_layers // period
    d, hkv, dh = cfg.d_model, cfg.n_kv_heads, cfg.hd
    di = cfg.mamba_expand * d
    h_heads = cfg.n_heads
    dhead = d // max(h_heads, 1)
    out = {}
    for j in range(period):
        kind = cfg.layer_pattern[j]
        st = (n_groups,)
        if kind == "attn":
            out[f"sub{j}"] = {
                "k": ParamSpec(st + (batch, max_seq, hkv * dh),
                               ("stack", "batch", "kv_seq", "kv_flat"),
                               init="zeros"),
                "v": ParamSpec(st + (batch, max_seq, hkv * dh),
                               ("stack", "batch", "kv_seq", "kv_flat"),
                               init="zeros"),
            }
        elif kind == "mamba":
            out[f"sub{j}"] = {
                "h": ParamSpec(st + (batch, di, cfg.mamba_d_state),
                               ("stack", "batch", "mamba_inner", None),
                               init="zeros", dtype=jnp.float32),
                "conv": ParamSpec(st + (batch, cfg.mamba_d_conv - 1, di),
                                  ("stack", "batch", None, "mamba_inner"),
                                  init="zeros"),
            }
        elif kind == "slstm":
            z = dict(init="zeros", dtype=jnp.float32)
            out[f"sub{j}"] = {
                "c": ParamSpec(st + (batch, d), ("stack", "batch", "embed"), **z),
                "n": ParamSpec(st + (batch, d), ("stack", "batch", "embed"), **z),
                "m": ParamSpec(st + (batch, d), ("stack", "batch", "embed"),
                               init="zeros", dtype=jnp.float32),
                "h": ParamSpec(st + (batch, d), ("stack", "batch", "embed"), **z),
            }
        elif kind == "mlstm":
            z = dict(init="zeros", dtype=jnp.float32)
            out[f"sub{j}"] = {
                "C": ParamSpec(st + (batch, h_heads, dhead, dhead),
                               ("stack", "batch", "heads", None, None), **z),
                "n": ParamSpec(st + (batch, h_heads, dhead),
                               ("stack", "batch", "heads", None), **z),
                "m": ParamSpec(st + (batch, 1), ("stack", "batch", None), **z),
            }
    return out


def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> PyTree:
    sch = cache_schema(cfg, batch, max_seq)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), sch,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


# ----------------------------------------------------------------- forward --
def _apply_sub(p, cfg: ModelConfig, j: int, x, positions, cache, cache_index,
               encoder_out, placement, use_flash, collect_moe=False):
    kind = cfg.layer_pattern[j]
    window = cfg.layer_window(j)
    new_cache = None
    moe_load = None
    # perf (flag-gated): weight-stationary decode — activations are ~MBs at
    # T=1 while FSDP weight gathers are ~GBs; shard the activation's embed
    # dim over 'data' so matmuls contract a sharded dim (partial sums +
    # activation-sized all-reduce) instead of all-gathering the weights.
    import os
    decode_ws = (os.environ.get("REPRO_PERF_DECODE_WS", "0") == "1"
                 and cache is not None and x.shape[1] == 1)
    if decode_ws:
        x = constrain(x, None, None, "sp")
    h = layers.rmsnorm(p["norm"], x, cfg.norm_eps)
    if kind == "attn":
        out, new_cache = attn_mod.attn(
            p["attn"], cfg, h, positions, window=window, causal=True,
            cache=cache, cache_index=cache_index, use_flash=use_flash)
    elif kind == "mamba":
        out, new_cache = mamba_mod.mamba(p["mamba"], cfg, h, state=cache)
        if cache is None:
            new_cache = None
    elif kind == "slstm":
        out, new_cache = xlstm_mod.slstm(p["cell"], cfg, h, state=cache)
        if cache is None:
            new_cache = None
    elif kind == "mlstm":
        out, new_cache = xlstm_mod.mlstm(p["cell"], cfg, h, state=cache)
        if cache is None:
            new_cache = None
    x = x + out
    if "cross" in p and encoder_out is not None:
        h = layers.rmsnorm(p["cross_norm"], x, cfg.norm_eps)
        out, _ = attn_mod.attn(p["cross"], cfg, h, positions, causal=False,
                               kv_source=encoder_out, use_rope=False)
        x = x + out
    if "mlp" in p:
        h = layers.rmsnorm(p["mlp_norm"], x, cfg.norm_eps)
        x = x + layers.mlp(p["mlp"], h)
    elif "moe" in p:
        h = layers.rmsnorm(p["mlp_norm"], x, cfg.norm_eps)
        if collect_moe:
            out, stats = moe_mod.moe(p["moe"], cfg, h, placement=placement,
                                     return_stats=True)
            moe_load = stats["expert_load"]
            x = x + out
        else:
            x = x + moe_mod.moe(p["moe"], cfg, h, placement=placement)
    if decode_ws:
        x = constrain(x, "dp", None, None)   # back to batch-sharded layout
    return x, new_cache, moe_load


def decoder_apply(params, cfg: ModelConfig, x, positions,
                  cache: Optional[PyTree] = None, cache_index=0,
                  encoder_out=None, placements: Optional[jax.Array] = None,
                  use_flash: bool = False, remat: bool = True,
                  collect_moe: bool = False, unroll: bool = False):
    """x: (B, T, D) -> (B, T, D) [, new stacked cache, moe loads]."""
    period = cfg.pattern_period
    n_groups = cfg.n_layers // period

    def body(carry, xs):
        h = carry
        gp, gc, gplace = xs
        new_gc = {}
        loads = []
        for j in range(period):
            sub_cache = gc[f"sub{j}"] if gc is not None else None
            place_j = gplace[j] if gplace is not None else None
            h, nc, load = _apply_sub(gp[f"sub{j}"], cfg, j, h, positions,
                                     sub_cache, cache_index, encoder_out,
                                     place_j, use_flash, collect_moe)
            if nc is not None:
                new_gc[f"sub{j}"] = nc
            if load is not None:
                loads.append(load)
        loads_out = jnp.stack(loads) if loads else None
        return h, ((new_gc if new_gc else None), loads_out)

    if remat and cache is None:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    if placements is not None:
        placements = placements.reshape(n_groups, period, -1)
    x, (new_caches, moe_loads) = jax.lax.scan(
        body, x, (params["groups"], cache, placements),
        unroll=n_groups if unroll else 1)
    return x, new_caches, moe_loads


def encode(params, cfg: ModelConfig, frames: jax.Array,
           use_flash: bool = False, unroll: bool = False) -> jax.Array:
    """Whisper-style encoder over stub frame embeddings (B, F, D)."""
    ecfg = _encoder_cfg(cfg)
    b, f, d = frames.shape
    pos = jnp.arange(f)
    half = d // 2
    freqs = 10_000.0 ** (-jnp.arange(half, dtype=jnp.float32) / half)
    sin = jnp.sin(pos[:, None] * freqs)
    cos = jnp.cos(pos[:, None] * freqs)
    x = frames + jnp.concatenate([sin, cos], -1).astype(frames.dtype)[None]

    def body(h, gp):
        hh = layers.rmsnorm(gp["sub0"]["norm"], h, cfg.norm_eps)
        out, _ = attn_mod.attn(gp["sub0"]["attn"], ecfg, hh, pos, causal=False,
                               use_rope=False, use_flash=False)
        h = h + out
        hh = layers.rmsnorm(gp["sub0"]["mlp_norm"], h, cfg.norm_eps)
        return h + layers.mlp(gp["sub0"]["mlp"], hh), None

    n_enc = jax.tree.leaves(params["encoder"]["groups"])[0].shape[0]
    x, _ = jax.lax.scan(body, x, params["encoder"]["groups"],
                        unroll=n_enc if unroll else 1)
    return layers.rmsnorm(params["encoder"]["final_norm"], x, cfg.norm_eps)


def forward(params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            cache: Optional[PyTree] = None, cache_index=0,
            placements: Optional[jax.Array] = None, use_flash: bool = False,
            remat: bool = True, collect_moe: bool = False,
            unroll: bool = False):
    """batch: {"tokens": (B, T)} + optional {"frames"} (audio, encoded here),
    {"encoder_out"} (audio, pre-encoded for decode steps) or {"pixel_embeds"}
    (vlm prefix). Returns (hidden, new_cache) or (hidden, new_cache, loads).
    """
    tokens = batch["tokens"]
    x = layers.embed(params["embed"], tokens).astype(jnp.bfloat16)
    x = constrain(x, "dp", None, None)
    encoder_out = batch.get("encoder_out")
    if encoder_out is None and cfg.frontend == "audio_stub" and "frames" in batch:
        encoder_out = encode(params, cfg, batch["frames"], use_flash,
                             unroll=unroll)
    elif cfg.frontend == "vision_stub" and "pixel_embeds" in batch:
        x = jnp.concatenate([batch["pixel_embeds"].astype(x.dtype), x], axis=1)
    t = x.shape[1]
    positions = cache_index + jnp.arange(t)
    x, new_cache, moe_loads = decoder_apply(
        params, cfg, x, positions, cache=cache, cache_index=cache_index,
        encoder_out=encoder_out, placements=placements, use_flash=use_flash,
        remat=remat, collect_moe=collect_moe, unroll=unroll)
    x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if collect_moe:
        return x, new_cache, moe_loads
    return x, new_cache


def logits_from_hidden(params, cfg: ModelConfig, hidden: jax.Array):
    if cfg.tie_embeddings:
        logits = jnp.einsum("btd,vd->btv", hidden, params["embed"]["tokens"])
    else:
        logits = layers.unembed(params["unembed"], hidden)
    # perf (flag-gated): keep the (B, Tc, V) tensor in bf16 until the f32
    # logsumexp accumulation — halves the dominant loss-path bytes at large
    # vocab (gemma3: 262k).
    import os
    if os.environ.get("REPRO_PERF_BF16_LOSS", "0") == "1":
        logits = logits.astype(jnp.bfloat16)
    # mask vocab padding
    if cfg.vocab_padded != cfg.vocab:
        pad = cfg.vocab_padded - cfg.vocab
        mask = jnp.concatenate([jnp.zeros((cfg.vocab,), logits.dtype),
                                jnp.full((pad,), -1e30, logits.dtype)])
        logits = logits + mask
    return logits


def lm_loss(params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            placements: Optional[jax.Array] = None, use_flash: bool = False,
            remat: bool = True, loss_chunks: int = 8,
            collect_moe: bool = False, unroll: bool = False):
    """Next-token cross-entropy; logits materialized per sequence chunk so the
    (B, T, V) tensor never exists at once (vocab up to 262k)."""
    if collect_moe:
        hidden, _, moe_loads = forward(params, cfg, batch,
                                       placements=placements,
                                       use_flash=use_flash, remat=remat,
                                       collect_moe=True, unroll=unroll)
    else:
        hidden, _ = forward(params, cfg, batch, placements=placements,
                            use_flash=use_flash, remat=remat, unroll=unroll)
        moe_loads = None
    labels = batch["labels"]
    if cfg.frontend == "vision_stub" and "pixel_embeds" in batch:
        p = batch["pixel_embeds"].shape[1]
        hidden = hidden[:, p:]                      # loss on text only
    b, t, _ = hidden.shape
    chunks = min(loss_chunks, t)
    while t % chunks:
        chunks -= 1
    hidden = constrain(hidden, "dp", None, None)
    hid_c = hidden.reshape(b, chunks, t // chunks, -1).transpose(1, 0, 2, 3)
    lab_c = labels.reshape(b, chunks, t // chunks).transpose(1, 0, 2)

    def one(chunk):
        h, lab = chunk
        h = constrain(h, "dp", None, None)
        logits = logits_from_hidden(params, cfg, h).astype(jnp.float32)
        logits = constrain(logits, "dp", None, "tp")
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lab, 0)[..., None], axis=-1)[..., 0]
        valid = (lab >= 0).astype(jnp.float32)
        return jnp.sum((logz - gold) * valid), jnp.sum(valid)

    losses, counts = jax.lax.map(one, (hid_c, lab_c))
    loss = jnp.sum(losses) / jnp.maximum(jnp.sum(counts), 1.0)
    if collect_moe:
        return loss, moe_loads
    return loss
