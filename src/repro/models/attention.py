"""GQA attention with RoPE, sliding windows, KV cache, and optional Pallas
flash path. The KV cache is stored flattened (B, S_max, Hkv*Dh) so the last
dim shards over the model axis even when Hkv < mesh model size (the per-arch
divisibility table lives in DESIGN.md)."""

from __future__ import annotations

import contextlib
import contextvars
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import attention as flash_attention
from repro.sharding.ctx import constrain

from .config import ModelConfig
from .layers import rope
from .schema import ParamSpec

NEG_INF = -1e30

# probe mode: unroll the query-chunk scan so XLA's (loop-blind) cost analysis
# counts every chunk — set by the dry-run probes, not by user code.
_UNROLL_CHUNKS = contextvars.ContextVar("attn_unroll_chunks", default=False)


def _attn_shard_pin() -> bool:
    import os
    return os.environ.get("REPRO_PERF_ATTN_SHARD", "0") == "1"


@contextlib.contextmanager
def unrolled_chunks():
    tok = _UNROLL_CHUNKS.set(True)
    try:
        yield
    finally:
        _UNROLL_CHUNKS.reset(tok)


def attn_schema(cfg: ModelConfig, stack=(), cross: bool = False):
    st = tuple(["stack"] * len(stack))
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    sch = {
        "wq": ParamSpec(stack + (d, hq * dh), st + ("embed", "q_heads")),
        "wk": ParamSpec(stack + (d, hkv * dh), st + ("embed", "kv_flat")),
        "wv": ParamSpec(stack + (d, hkv * dh), st + ("embed", "kv_flat")),
        "wo": ParamSpec(stack + (hq * dh, d), st + ("q_heads", "embed")),
    }
    if cfg.qkv_bias and not cross:
        sch["bq"] = ParamSpec(stack + (hq * dh,), st + ("q_heads",), init="zeros")
        sch["bk"] = ParamSpec(stack + (hkv * dh,), st + ("kv_flat",), init="zeros")
        sch["bv"] = ParamSpec(stack + (hkv * dh,), st + ("kv_flat",), init="zeros")
    return sch


def _split_heads(x, n, dh):
    b, t, _ = x.shape
    return x.reshape(b, t, n, dh)


def _attention_block(q, k, v, *, causal: bool, window: int, q_positions,
                     kv_valid_len) -> jax.Array:
    """jnp attention (B,H,T,Dh) x (B,Hkv,S,Dh); GQA via reshape-grouping."""
    b, hq, t, dh = q.shape
    hkv, s = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, t, dh)
    logits = jnp.einsum("bkgtd,bksd->bkgts", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * (dh ** -0.5)
    k_pos = jnp.arange(s)[None, :]
    q_pos = q_positions[:, :, None] if q_positions.ndim == 2 else \
        q_positions[None, :, None]
    mask = jnp.broadcast_to(k_pos[None] < kv_valid_len, (b, t, s)) \
        if kv_valid_len is not None else jnp.ones((1, t, s), bool)
    if causal:
        mask = mask & (k_pos[None] <= q_pos)
    if window > 0:
        mask = mask & (k_pos[None] > q_pos - window)
    logits = jnp.where(mask[:, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgts,bksd->bkgtd", probs, v.astype(jnp.float32))
    return out.reshape(b, hq, t, dh).astype(q.dtype)


_CHUNK_ELEMS = 2 ** 21        # materialize at most ~2M (T x S) scores / head


def _xla_attention(q, k, v, *, causal: bool, window: int, q_positions,
                   kv_valid_len) -> jax.Array:
    """Query-chunked attention: never materializes the full (T, S) score
    matrix (the standard pre-flash memory fix; the Pallas kernel replaces it
    on real TPU). Chunks run as a scan — unrolled under probe mode so the
    dry-run cost analysis counts every chunk."""
    t = q.shape[2]
    s = k.shape[2]
    import os
    window_slice = (os.environ.get("REPRO_PERF_WINDOW_SLICE", "0") == "1"
                    and causal and window > 0 and kv_valid_len is None
                    and t == s)
    # probe mode: a single block counts identical FLOPs with far fewer HLO
    # ops (dry-run compiles are abstract — no memory is actually allocated).
    # The window-slice path changes the FLOPs themselves, so it must NOT be
    # short-circuited in probe mode.
    probe_skip = (_UNROLL_CHUNKS.get()
                  and os.environ.get("REPRO_PROBE_CHUNKED", "0") != "1")
    if not window_slice and (t * s <= _CHUNK_ELEMS or t <= 128
                             or probe_skip):
        return _attention_block(q, k, v, causal=causal, window=window,
                                q_positions=q_positions,
                                kv_valid_len=kv_valid_len)
    chunk = max(128, _CHUNK_ELEMS // s)
    chunk = min(chunk, t)
    while t % chunk:
        chunk -= 1
    nc = t // chunk
    q_c = q.reshape(q.shape[0], q.shape[1], nc, chunk,
                    q.shape[3]).transpose(2, 0, 1, 3, 4)
    if q_positions.ndim == 1:
        pos_c = q_positions.reshape(nc, chunk)
    else:
        pos_c = q_positions.reshape(q_positions.shape[0], nc,
                                    chunk).transpose(1, 0, 2)

    # perf (flag-gated): sliding-window layers only need the KV band
    # [chunk_start - window, chunk_end) — slice it instead of scanning all S
    # (gemma3: 5/6 of layers are window=1024 -> ~S/(chunk+window) fewer
    # bytes and FLOPs on the attention path).
    band = window_slice and window + chunk < s
    if band:
        band_len = window + chunk

        def one_band(_, xs):
            qc, pc, i = xs
            start = jnp.clip(i * chunk - window, 0, s - band_len)
            kb = jax.lax.dynamic_slice_in_dim(k, start, band_len, axis=2)
            vb = jax.lax.dynamic_slice_in_dim(v, start, band_len, axis=2)
            out = _attention_block(qc, kb, vb, causal=causal, window=window,
                                   q_positions=pc - start, kv_valid_len=None)
            return None, out

        unroll = nc if _UNROLL_CHUNKS.get() else 1
        _, outs = jax.lax.scan(one_band, None,
                               (q_c, pos_c, jnp.arange(nc)), unroll=unroll)
        return outs.transpose(1, 2, 0, 3, 4).reshape(q.shape)

    def one(_, xs):
        qc, pc = xs
        out = _attention_block(qc, k, v, causal=causal, window=window,
                               q_positions=pc, kv_valid_len=kv_valid_len)
        return None, out

    unroll = nc if _UNROLL_CHUNKS.get() else 1
    _, outs = jax.lax.scan(one, None, (q_c, pos_c), unroll=unroll)
    return outs.transpose(1, 2, 0, 3, 4).reshape(q.shape)


def attn(p, cfg: ModelConfig, x: jax.Array, positions: jax.Array,
         window: int = 0, causal: bool = True,
         cache: Optional[dict] = None, cache_index=None,
         kv_source: Optional[jax.Array] = None, use_rope: bool = True,
         use_flash: bool = False) -> Tuple[jax.Array, Optional[dict]]:
    """Self- or cross-attention.

    cache: {"k": (B, S_max, Hkv*Dh), "v": ...} — decode writes this step's
    K/V at ``cache_index`` and attends over [0, cache_index].
    kv_source: encoder output for cross-attention (no cache, no causal).
    """
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    b, t, _ = x.shape
    src = x if kv_source is None else kv_source
    q = jnp.einsum("btd,dh->bth", x, p["wq"])
    k = jnp.einsum("btd,dh->bth", src, p["wk"])
    v = jnp.einsum("btd,dh->bth", src, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    qh = _split_heads(q, hq, dh)
    kh = _split_heads(k, hkv, dh)
    if use_rope and kv_source is None:
        qh = rope(qh, positions, cfg.rope_theta)
        kh = rope(kh, positions, cfg.rope_theta)
    k = kh.reshape(b, -1, hkv * dh)

    new_cache = None
    if cache is not None:
        # decode: write this step's K/V at cache_index, attend over the cache
        idx = cache_index
        ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, idx, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, idx, 0))
        new_cache = {"k": ck, "v": cv}
        k_full = ck.reshape(b, -1, hkv, dh).transpose(0, 2, 1, 3)
        v_full = cv.reshape(b, -1, hkv, dh).transpose(0, 2, 1, 3)
        # pin head-dim sharding: when heads % model-axis != 0 the partitioner
        # otherwise shards head_dim and all-reduces the logits (§Perf 1.3b);
        # constrain() auto-falls-back to replication for indivisible heads.
        # Flag-gated: a clear win for indivisible-head archs (granite-moe:
        # collective −35%), mildly harmful where heads already shard cleanly
        # (gemma3: +34% collective) — enabled per-arch by the launcher.
        qt = qh.transpose(0, 2, 1, 3)
        if _attn_shard_pin():
            qt = constrain(qt, "dp", "tp", None, None)
            k_full = constrain(k_full, "dp", "tp", None, None)
            v_full = constrain(v_full, "dp", "tp", None, None)
        out = _xla_attention(qt, k_full, v_full,
                             causal=True, window=window,
                             q_positions=positions, kv_valid_len=idx + t)
    else:
        k_full = kh.transpose(0, 2, 1, 3)
        v_full = _split_heads(v, hkv, dh).transpose(0, 2, 1, 3)
        qt = qh.transpose(0, 2, 1, 3)
        if _attn_shard_pin():
            k_full = constrain(k_full, "dp", "tp", None, None)
            v_full = constrain(v_full, "dp", "tp", None, None)
            qt = constrain(qt, "dp", "tp", None, None)
        if use_flash and causal and kv_source is None:
            out = flash_attention(qt, k_full, v_full, causal=True,
                                  window=window)
        else:
            out = _xla_attention(qt, k_full, v_full, causal=causal,
                                 window=window, q_positions=positions,
                                 kv_valid_len=None)
    out = out.transpose(0, 2, 1, 3).reshape(b, t, hq * dh)
    return jnp.einsum("bth,hd->btd", out, p["wo"]), new_cache
