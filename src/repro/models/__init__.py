"""Model zoo: composable transformer/hybrid LMs for the assigned archs."""

from .config import ModelConfig, ShapeConfig, SHAPES
from . import schema
from .transformer import (cache_schema, decoder_apply, forward, init_cache,
                          lm_loss, logits_from_hidden, model_schema)

__all__ = [
    "ModelConfig", "ShapeConfig", "SHAPES", "schema", "cache_schema",
    "decoder_apply", "forward", "init_cache", "lm_loss",
    "logits_from_hidden", "model_schema",
]
