"""SkewShield: the paper's dynamic key-based partitioning applied to
mixture-of-experts placement.

Mapping (DESIGN.md §2): logical experts = keys; EP shards = task instances;
static placement h(e) = e // (E / n_shards) (contiguous blocks) = the hash
baseline; the routing table = per-expert overrides; state = expert weights
(+ optimizer moments) so migration cost = bytes of experts moved between
shards. The controller runs the Mixed algorithm on measured expert loads at
step/interval boundaries; because the resulting placement is a jit *argument*
(an (E,) int32 permutation), installing a new plan never recompiles — the
paper's Pause/Resume collapses to a step-boundary swap plus one sharded
gather that XLA lowers to a collective-permute of the moved experts only.

Slot-count constraint: an (E,) permutation requires every shard to hold
exactly E/S slots, so after the balancer's load-driven plan a count-repair
pass moves the lightest surplus experts to shards with free slots (the
balancer optimizes load; slots are a layout constraint it doesn't know).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (Assignment, BalanceConfig, KeyStats,
                        RebalanceController)
from repro.core.balancer import metrics
from repro.core.balancer.types import HashRouter


class BlockRouter(HashRouter):
    """h(e) = e // (E / n_shards): the static contiguous expert layout."""

    def __init__(self, n_experts: int, n_shards: int):
        assert n_experts % n_shards == 0
        self.n_experts = n_experts
        self.n_dest = n_shards
        self.per_shard = n_experts // n_shards

    def __call__(self, keys: np.ndarray) -> np.ndarray:
        return (np.asarray(keys, np.int64) // self.per_shard)

    def with_n_dest(self, n_dest: int) -> "BlockRouter":
        return BlockRouter(self.n_experts, n_dest)


@dataclasses.dataclass
class PlacementUpdate:
    placement: np.ndarray          # (E,) logical expert -> physical slot
    moved_experts: np.ndarray      # logical ids whose shard changed
    migration_bytes: float
    theta_before: float
    theta_after: float
    plan_time_s: float


class SkewShieldPlacer:
    """One placer per MoE layer (or shared, if loads are aggregated)."""

    def __init__(self, n_experts: int, n_shards: int,
                 bytes_per_expert: float,
                 theta_max: float = 0.1, table_max: Optional[int] = None,
                 algorithm: str = "mixed", beta: float = 1.5):
        self.e = n_experts
        self.s = n_shards
        self.per_shard = n_experts // n_shards
        self.bytes_per_expert = bytes_per_expert
        cfg = BalanceConfig(theta_max=theta_max,
                            table_max=table_max if table_max is not None
                            else max(4, n_experts // 2),
                            beta=beta)
        self.controller = RebalanceController(
            Assignment(BlockRouter(n_experts, n_shards)), cfg,
            algorithm=algorithm)
        self.placement = np.arange(n_experts, dtype=np.int32)  # identity

    # ------------------------------------------------------------------ plan
    def shard_of_slot(self, slot: np.ndarray) -> np.ndarray:
        return np.asarray(slot) // self.per_shard

    def current_shards(self) -> np.ndarray:
        """shard of each logical expert under the current placement."""
        return self.shard_of_slot(self.placement)

    def update(self, expert_load: np.ndarray) -> PlacementUpdate:
        """expert_load: (E,) measured tokens per *logical* expert."""
        expert_load = np.asarray(expert_load, np.float64)
        stats = KeyStats(keys=np.arange(self.e, dtype=np.int64),
                         cost=np.maximum(expert_load, 0.0),
                         mem=np.full((self.e,), self.bytes_per_expert))
        shards_before = self.current_shards()
        loads_before = np.bincount(shards_before, weights=expert_load,
                                   minlength=self.s)
        ev = self.controller.on_interval(stats)
        if ev.result is None:                     # balanced already
            return PlacementUpdate(self.placement.copy(),
                                   np.zeros((0,), np.int64), 0.0,
                                   metrics.theta(loads_before),
                                   metrics.theta(loads_before), 0.0)
        want = ev.result.assignment.dest(stats.keys)       # expert -> shard
        want = self._repair_counts(want, expert_load)
        placement = self._slots_from_shards(want)
        moved = np.flatnonzero(self.shard_of_slot(placement)
                               != shards_before)
        loads_after = np.bincount(want, weights=expert_load, minlength=self.s)
        upd = PlacementUpdate(
            placement=placement, moved_experts=moved,
            migration_bytes=float(len(moved)) * self.bytes_per_expert,
            theta_before=metrics.theta(loads_before),
            theta_after=metrics.theta(loads_after),
            plan_time_s=ev.result.plan_time_s)
        self.placement = placement
        return upd

    def _repair_counts(self, want: np.ndarray,
                       load: np.ndarray) -> np.ndarray:
        """Enforce exactly E/S experts per shard, moving lightest first."""
        want = np.asarray(want, np.int64).copy()
        counts = np.bincount(want, minlength=self.s)
        over = [d for d in range(self.s) if counts[d] > self.per_shard]
        under = [d for d in range(self.s) if counts[d] < self.per_shard]
        for d in over:
            members = np.flatnonzero(want == d)
            members = members[np.argsort(load[members])]   # lightest first
            i = 0
            while counts[d] > self.per_shard and under:
                tgt = under[0]
                want[members[i]] = tgt
                counts[d] -= 1
                counts[tgt] += 1
                if counts[tgt] == self.per_shard:
                    under.pop(0)
                i += 1
        return want

    def _slots_from_shards(self, want: np.ndarray) -> np.ndarray:
        """Assign concrete slots, keeping unmoved experts in their old slot
        (minimizes the physical permutation — fewer weights move)."""
        placement = np.full((self.e,), -1, np.int32)
        old_shards = self.current_shards()
        free: Dict[int, List[int]] = {
            d: list(range(d * self.per_shard, (d + 1) * self.per_shard))
            for d in range(self.s)}
        # unmoved experts keep their slots
        for l in range(self.e):
            if want[l] == old_shards[l]:
                slot = int(self.placement[l])
                placement[l] = slot
                free[want[l]].remove(slot)
        for l in range(self.e):
            if placement[l] < 0:
                placement[l] = free[int(want[l])].pop(0)
        return placement


def permute_expert_params(moe_params: dict, old_placement: np.ndarray,
                          new_placement: np.ndarray) -> dict:
    """Physically migrate expert weights to their new slots.

    Weights are stored by physical slot; w_new[new[l]] = w_old[old[l]].
    The gather over the (sharded) expert dim lowers to a collective-permute
    touching only moved experts. Router weights are logical — untouched.
    """
    perm = np.empty_like(old_placement)
    perm[new_placement] = old_placement          # slot_new -> slot_old
    perm = jnp.asarray(perm, jnp.int32)
    out = dict(moe_params)
    for name in ("w_gate", "w_up", "w_down"):
        w = moe_params[name]
        out[name] = jnp.take(w, perm, axis=w.ndim - 3)
    return out


def placements_array(placers: List[SkewShieldPlacer]) -> jax.Array:
    """(n_layers, E) placement matrix for forward(placements=...)."""
    return jnp.asarray(np.stack([p.placement for p in placers]), jnp.int32)
