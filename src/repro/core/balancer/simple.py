"""The Simple algorithm (paper Alg. 5, appendix) — FFD-style full reassignment.

Used for the Theorem-1 analysis: if a perfect assignment exists and
c(k_1) < mean load, the resulting imbalance is <= 1/3 * (1 - 1/N_D).
"""

from __future__ import annotations

import time

import numpy as np

from . import metrics
from .types import Assignment, BalanceConfig, KeyStats, RebalanceResult


def simple(stats: KeyStats, assignment: Assignment,
           config: BalanceConfig) -> RebalanceResult:
    t0 = time.perf_counter()
    n_dest = assignment.n_dest
    hash_dest = assignment.hash_router(stats.keys)
    order = np.argsort(-stats.cost, kind="stable")
    loads = np.zeros((n_dest,), dtype=np.float64)
    assign = np.zeros((stats.num_keys,), dtype=np.int64)
    for idx in order:
        d = int(np.argmin(loads))
        assign[idx] = d
        loads[d] += stats.cost[idx]
    table = {int(k): int(d) for k, d, h in zip(stats.keys, assign, hash_dest)
             if d != h}
    new = Assignment(assignment.hash_router, table)
    moved = assign != assignment.dest(stats.keys)
    return RebalanceResult(
        assignment=new,
        moved_keys=stats.keys[moved],
        migration_cost=float(np.sum(stats.mem[moved])),
        loads=loads,
        table_size=len(table),
        theta=metrics.theta(loads),
        feasible_balance=metrics.theta(loads) <= config.theta_max + 1e-9,
        feasible_table=len(table) <= config.table_max,
        plan_time_s=time.perf_counter() - t0,
    )
