"""Compact 6-d representation and the adapted Mixed over it (paper Sec. IV-A).

Keys with identical (d, d_hash, v_c, v_S) collapse into one vector
``(d', d, d_hash, v_c, v_S, #)``; v_c / v_S are HLHE-discretized (Sec. IV-B).
The adapted phases manipulate vectors; concrete keys are materialized only at
the end, for Delta(F, F') and the routing table.

Vector-splitting note: the paper moves whole vectors but merges vectors that
agree on all five descriptor fields; since every unit inside a vector is
indistinguishable, splitting a vector's count across destinations is
semantically free and strictly improves balance. We place unit-by-unit
batches (the complexity stays O(#vectors * N_D), not O(K)).
"""

from __future__ import annotations

import heapq
import time
from collections import defaultdict
from typing import Dict, Tuple

import numpy as np

from . import metrics
from .discretize import discretize
from .types import Assignment, BalanceConfig, KeyStats, RebalanceResult

NIL = -1
GKey = Tuple[int, int, float, float]          # (d, dh, v_c, v_S) origin group
PKey = Tuple[int, int, float, float, int]     # + d' working placement


def build_groups(stats: KeyStats, assignment: Assignment,
                 r) -> Tuple[Dict[GKey, int], np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Collapse keys into origin groups. Returns (groups, d, dh, vc, vs arrays)."""
    groups, d, dh, vc, vs, _, _ = build_groups_indexed(stats, assignment, r)
    return groups, d, dh, vc, vs


def build_groups_indexed(stats: KeyStats, assignment: Assignment, r):
    """Vectorized grouping; also returns (inverse, uniq) for fast expansion."""
    d = assignment.dest(stats.keys)
    dh = assignment.hash_router(stats.keys)
    # normalize to >= 1 for HLHE (paper assumes normalized values)
    cost = np.maximum(stats.cost, 1.0)
    mem = np.maximum(stats.mem, 1.0)
    if r is None:
        vc, vs = cost, mem
    else:
        vc, vs = discretize(cost, r), discretize(mem, r)
    mat = np.column_stack([d.astype(np.float64), dh.astype(np.float64), vc, vs])
    uniq, inverse, counts = np.unique(mat, axis=0, return_inverse=True,
                                      return_counts=True)
    groups: Dict[GKey, int] = {
        (int(row[0]), int(row[1]), float(row[2]), float(row[3])): int(c)
        for row, c in zip(uniq, counts)}
    return groups, d, dh, vc, vs, inverse.ravel(), uniq


class _CompactWs:
    """Working placement: (origin group, d') -> unit count."""

    def __init__(self, groups: Dict[GKey, int], n_dest: int, config: BalanceConfig):
        self.placed: Dict[PKey, int] = {}
        self.cands: Dict[GKey, int] = defaultdict(int)
        self.n_dest = n_dest
        self.config = config
        self.loads = np.zeros((n_dest,), dtype=np.float64)
        total = 0.0
        for (d, dh, vc, vs), cnt in groups.items():
            self.placed[(d, dh, vc, vs, d)] = cnt
            self.loads[d] += vc * cnt
            total += vc * cnt
        self.mean = total / n_dest
        self.events = 0

    # unit bookkeeping ------------------------------------------------------
    def _take(self, pkey: PKey, n: int) -> None:
        cur = self.placed.get(pkey, 0)
        if cur < n:
            raise ValueError("taking more units than placed")
        if cur == n:
            self.placed.pop(pkey)
        else:
            self.placed[pkey] = cur - n
        self.loads[pkey[4]] -= pkey[2] * n

    def _put(self, gkey: GKey, dprime: int, n: int) -> None:
        pkey = (gkey[0], gkey[1], gkey[2], gkey[3], dprime)
        self.placed[pkey] = self.placed.get(pkey, 0) + n
        self.loads[dprime] += gkey[2] * n

    def disassociate(self, pkey: PKey, n: int) -> None:
        self._take(pkey, n)
        self.cands[pkey[:4]] += n

    def gamma(self, vc: float, vs: float) -> float:
        return (vc ** self.config.beta) / max(vs, 1e-12)

    # Phase II ---------------------------------------------------------------
    def prepare(self) -> None:
        l_max = self.config.l_max(self.mean)
        for d in range(self.n_dest):
            if self.loads[d] <= l_max:
                continue
            members = [p for p in self.placed if p[4] == d]
            members.sort(key=lambda p: -self.gamma(p[2], p[3]))
            for p in members:
                if self.loads[d] <= l_max:
                    break
                excess = self.loads[d] - l_max
                n_rm = min(self.placed[p], int(np.ceil(excess / p[2])))
                self.disassociate(p, n_rm)

    # Phase III: group LLFD ----------------------------------------------------
    def llfd(self) -> None:
        l_max = self.config.l_max(self.mean)
        heap = [(-g[2], g) for g, c in self.cands.items() if c > 0]
        heapq.heapify(heap)
        budget = self.config.max_llfd_events
        while heap:
            self.events += 1
            _, gkey = heapq.heappop(heap)
            cnt = self.cands.get(gkey, 0)
            if cnt <= 0:
                continue
            vc = gkey[2]
            placed_any = False
            if self.events <= budget:
                for d in np.argsort(self.loads, kind="stable"):
                    d = int(d)
                    fit = int(np.floor((l_max - self.loads[d]) / vc))
                    if fit >= 1:
                        n = min(cnt, fit)
                        self.cands[gkey] -= n
                        self._put(gkey, d, n)
                        placed_any = True
                        break
                    if self._exchange_one(gkey, d, l_max, heap):
                        placed_any = True
                        break
            if not placed_any:
                # oversized-unit fallback (mirrors llfd.py): place least-load,
                # then shed strictly-lighter units down to what the unit needs.
                d = int(np.argmin(self.loads))
                self.cands[gkey] = 0
                self._put(gkey, d, cnt)
                target = max(l_max, vc * cnt)
                members = [p for p in self.placed
                           if p[4] == d and p[2] < vc]
                members.sort(key=lambda p: -self.gamma(p[2], p[3]))
                for p in members:
                    if self.loads[d] <= target:
                        break
                    excess = self.loads[d] - target
                    n_rm = min(self.placed[p], int(np.ceil(excess / p[2])))
                    self.disassociate(p, n_rm)
                    heapq.heappush(heap, (-p[2], p[:4]))
                continue
            if self.cands.get(gkey, 0) > 0:
                heapq.heappush(heap, (-vc, gkey))     # remainder retries

    def _exchange_one(self, gkey: GKey, d: int, l_max: float, heap) -> bool:
        """Adjust for one unit of gkey onto d: displace strictly-lighter units."""
        vc = gkey[2]
        exch = [p for p in self.placed if p[4] == d and p[2] < vc]
        if not exch:
            return False
        exch.sort(key=lambda p: -self.gamma(p[2], p[3]))
        need = self.loads[d] + vc - l_max
        plan = []
        removed = 0.0
        for p in exch:
            if removed >= need:
                break
            n_av = self.placed[p]
            n_rm = min(n_av, int(np.ceil((need - removed) / p[2])))
            plan.append((p, n_rm))
            removed += p[2] * n_rm
        if removed < need:
            return False
        for p, n_rm in plan:
            self.disassociate(p, n_rm)
            heapq.heappush(heap, (-p[2], p[:4]))
        self.cands[gkey] -= 1
        self._put(gkey, d, 1)
        return True

    # outputs -----------------------------------------------------------------
    def splits(self) -> Dict[GKey, Dict[int, int]]:
        """origin group -> {d' -> units}."""
        out: Dict[GKey, Dict[int, int]] = defaultdict(dict)
        for p, cnt in self.placed.items():
            if cnt > 0:
                out[p[:4]][p[4]] = out[p[:4]].get(p[4], 0) + cnt
        return dict(out)


def compact_mixed(stats: KeyStats, assignment: Assignment, config: BalanceConfig,
                  r=None) -> RebalanceResult:
    """Adapted Mixed (paper Sec. IV-A) over the compact representation.

    ``r`` = HLHE degree of discretization (None = exact values; the vector
    space then collapses only identical-valued keys).
    """
    t0 = time.perf_counter()
    r = config.discretize_r if r is None else r
    (groups, d_arr, dh_arr, vc_arr, vs_arr, inverse,
     uniq) = build_groups_indexed(stats, assignment, r)
    n_dest = assignment.n_dest

    # eta order for Phase I: table vectors (d != dh), smallest v_S first
    table_groups = sorted((g for g in groups if g[0] != g[1]),
                          key=lambda g: (g[3], g))
    n = 0
    trials = 0
    while True:
        ws = _CompactWs(groups, n_dest, config)
        left = n
        for g in table_groups:                       # Phase I: move back n units
            if left <= 0:
                break
            pk = (g[0], g[1], g[2], g[3], g[0])
            avail = ws.placed.get(pk, 0)
            take = min(avail, left)
            if take > 0:
                ws._take(pk, take)
                ws._put(g, g[1], take)               # back to hash destination
                left -= take
        ws.prepare()                                 # Phase II
        ws.llfd()                                    # Phase III
        trials += 1
        # estimated table size: units whose final dest != dh
        est_table = sum(cnt for p, cnt in ws.placed.items() if p[4] != p[1])
        overuse = est_table - config.table_max
        max_units = sum(groups[g] for g in table_groups)
        if overuse <= 0 or n >= max_units:
            break
        n = min(max_units, n + overuse)

    # ---- expand vectors back to concrete keys (paper Phase III (i)-(iii)) ----
    # keys sorted by group id; group g occupies by_group[starts[g]:starts[g+1]]
    final = d_arr.copy()
    gamma_true = stats.gamma(config.beta)
    by_group = np.argsort(inverse, kind="stable")
    starts = np.searchsorted(inverse[by_group], np.arange(len(uniq) + 1))
    gid_of = {(int(row[0]), int(row[1]), float(row[2]), float(row[3])): g
              for g, row in enumerate(uniq)}
    for gkey, split in ws.splits().items():
        movers = {dp: cnt for dp, cnt in split.items() if dp != gkey[0]}
        if not movers:
            continue
        g = gid_of.get(gkey)
        if g is None:
            continue
        idxs = by_group[starts[g]:starts[g + 1]]
        idxs = idxs[np.argsort(-gamma_true[idxs], kind="stable")]  # psi order
        pos = 0
        for dp in sorted(movers):
            cnt = movers[dp]
            final[idxs[pos:pos + cnt]] = dp
            pos += cnt

    diff = final != dh_arr
    table = {int(k): int(d) for k, d in zip(stats.keys[diff], final[diff])}
    new = Assignment(assignment.hash_router, table)
    moved = final != d_arr
    true_loads = np.bincount(final, weights=stats.cost,
                             minlength=n_dest).astype(np.float64)
    th = metrics.theta(true_loads)
    est_err = float(np.max(np.abs(ws.loads - true_loads)) /
                    max(np.mean(true_loads), 1e-12))
    return RebalanceResult(
        assignment=new, moved_keys=stats.keys[moved],
        migration_cost=float(np.sum(stats.mem[moved])), loads=true_loads,
        table_size=len(table), theta=th,
        feasible_balance=th <= config.theta_max + 1e-9,
        feasible_table=len(table) <= config.table_max,
        plan_time_s=time.perf_counter() - t0,
        meta={"groups": float(len(groups)), "trials": float(trials),
              "load_est_err": est_err},
    )
