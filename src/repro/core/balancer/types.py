"""Core datatypes for the skew-shield balancer (paper Sec. II).

Everything here is *control plane*: plain numpy / python, runs on the host
controller. The data plane (vectorized routing of millions of tuples/tokens)
lives in ``repro.core.routing`` and ``repro.kernels``.

Key universe convention: algorithms operate on *key indices* ``0..K-1`` into
the per-interval :class:`KeyStats` arrays; the actual 64-bit key ids are kept
alongside so routing tables can be materialized for the data plane.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import numpy as np

Array = np.ndarray


@dataclasses.dataclass
class KeyStats:
    """Per-key statistics measured over one time interval ``T_{i-1}``.

    Mirrors the paper's notation:
      * ``freq[k]``  = g_{i-1}(k)   tuple frequency
      * ``cost[k]``  = c_{i-1}(k)   computation cost (CPU-seconds / chip-FLOPs)
      * ``mem[k]``   = S_{i-1}(k,w) windowed state size (bytes)

    ``base_loads`` (optional, sketch-mode stats — see ``balancer/sketch.py``)
    carries per-destination cost that belongs to *tail* keys not present in
    the per-key arrays: those keys are frozen on their hash destinations
    (the ``head_fraction`` head/tail contract), and every load/theta
    computation folds the base in (``metrics.loads_for``,
    ``PlannerContext.mean_load``). ``None`` (the default) means the per-key
    arrays are the whole universe — exact pre-sketch behavior.
    """

    keys: Array                    # (K,) int64 unique key ids
    cost: Array                    # (K,) float64
    mem: Array                     # (K,) float64
    freq: Optional[Array] = None   # (K,) float64, optional
    base_loads: Optional[Array] = None  # (n_dest,) float64, optional

    def __post_init__(self) -> None:
        self.keys = np.asarray(self.keys, dtype=np.int64)
        self.cost = np.asarray(self.cost, dtype=np.float64)
        self.mem = np.asarray(self.mem, dtype=np.float64)
        if self.freq is not None:
            self.freq = np.asarray(self.freq, dtype=np.float64)
        if self.base_loads is not None:
            self.base_loads = np.asarray(self.base_loads, dtype=np.float64)
            if self.base_loads.ndim != 1:
                raise ValueError("base_loads must be a 1-D (n_dest,) array")
        if self.keys.shape != self.cost.shape or self.keys.shape != self.mem.shape:
            raise ValueError("KeyStats arrays must have identical shapes")

    @property
    def num_keys(self) -> int:
        return int(self.keys.shape[0])

    def gamma(self, beta: float) -> Array:
        """Migration priority index gamma_i(k,w) = c(k)^beta / S(k,w) (Sec. III-B)."""
        mem = np.where(self.mem <= 0.0, 1.0, self.mem)
        return np.power(np.maximum(self.cost, 0.0), beta) / mem


@dataclasses.dataclass
class BalanceConfig:
    """User-facing knobs, names per the paper's Table II."""

    theta_max: float = 0.08        # tolerance on load imbalance
    table_max: int = 3_000         # A_max: routing table budget
    beta: float = 1.5              # migration selection factor
    window: int = 1                # w: state retention window (intervals)
    discretize_r: Optional[int] = None  # r: HLHE degree (None = raw values)
    # numerical slack for L <= L_max comparisons (theta_max = 0 must work)
    rel_eps: float = 1e-9
    # safety valve for the LLFD exchange cascade (see llfd.py)
    max_llfd_events: int = 1_000_000
    # head/tail split (llfd.py): keys with c(k) >= head_fraction * mean load
    # (plus all current table keys) get exact LLFD/Adjust placement; the tail
    # stays frozen on its hash destinations as pre-aggregated base loads.
    # 0.0 = every key is head (exact planner, pre-split behavior).
    head_fraction: float = 0.0

    def l_max(self, mean_load: float) -> float:
        return (1.0 + self.theta_max) * mean_load * (1.0 + self.rel_eps) + 1e-12


class HashRouter:
    """Vectorized base hash h: K -> D. See hashing.py for implementations."""

    n_dest: int

    def __call__(self, keys: Array) -> Array:  # pragma: no cover - interface
        raise NotImplementedError

    def with_n_dest(self, n_dest: int) -> "HashRouter":  # pragma: no cover
        raise NotImplementedError


@dataclasses.dataclass
class Assignment:
    """The mixed assignment function F(k) = A[k] if k in A else h(k) (Eq. 1)."""

    hash_router: "HashRouter"
    table: Dict[int, int] = dataclasses.field(default_factory=dict)

    @property
    def n_dest(self) -> int:
        return self.hash_router.n_dest

    @property
    def table_size(self) -> int:
        return len(self.table)

    def dest(self, keys: Array) -> Array:
        """Vectorized F(k) for an array of key ids."""
        keys = np.asarray(keys, dtype=np.int64)
        out = self.hash_router(keys)
        if self.table:
            tkeys = np.fromiter(self.table.keys(), dtype=np.int64, count=len(self.table))
            tdest = np.fromiter(self.table.values(), dtype=np.int64, count=len(self.table))
            order = np.argsort(tkeys, kind="stable")
            tkeys, tdest = tkeys[order], tdest[order]
            pos = np.searchsorted(tkeys, keys)
            pos = np.clip(pos, 0, len(tkeys) - 1)
            hit = tkeys[pos] == keys
            out = np.where(hit, tdest[pos], out)
        return out.astype(np.int64)

    def dest_one(self, key: int) -> int:
        if key in self.table:
            return self.table[key]
        return int(self.hash_router(np.asarray([key], dtype=np.int64))[0])

    def table_arrays(self, a_max: Optional[int] = None) -> tuple[Array, Array]:
        """(keys, dests) padded to a_max with key=-1 — data-plane handoff format."""
        n = len(self.table)
        a_max = n if a_max is None else a_max
        if n > a_max:
            raise ValueError(f"table size {n} exceeds a_max {a_max}")
        tk = np.full((a_max,), -1, dtype=np.int64)
        td = np.zeros((a_max,), dtype=np.int32)
        if n:
            tk[:n] = np.fromiter(self.table.keys(), dtype=np.int64, count=n)
            td[:n] = np.fromiter(self.table.values(), dtype=np.int32, count=n)
        return tk, td

    def copy(self) -> "Assignment":
        return Assignment(self.hash_router, dict(self.table))


@dataclasses.dataclass
class RebalanceResult:
    """Outcome of one controller decision (one solve of Eq. 3)."""

    assignment: Assignment            # F' (with new table A')
    moved_keys: Array                 # Delta(F, F') as key ids
    migration_cost: float             # M_i(w, F, F') = sum S over Delta
    loads: Array                      # (N_D,) post-rebalance estimated loads
    table_size: int
    theta: float                      # max_d |L(d) - mean| / mean
    feasible_balance: bool            # theta <= theta_max ?
    feasible_table: bool              # |A'| <= A_max ?
    plan_time_s: float = 0.0          # wall time to produce the plan
    meta: Dict[str, float] = dataclasses.field(default_factory=dict)

    def same_plan(self, other: "RebalanceResult") -> bool:
        """Bit-identical plan equality: table, moved keys, loads and theta.

        Used by the planner parity suite and ``benchmarks/planner_scaling.py``
        to prove the array-native planner reproduces the scalar oracle
        exactly (timing fields and meta are intentionally ignored).
        """
        return (self.assignment.table == other.assignment.table
                and np.array_equal(np.sort(self.moved_keys),
                                   np.sort(other.moved_keys))
                and np.array_equal(self.loads, other.loads)
                and self.theta == other.theta
                and self.table_size == other.table_size)


Algorithm = Callable[[KeyStats, Assignment, BalanceConfig], RebalanceResult]
