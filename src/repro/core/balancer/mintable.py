"""MinTable (paper Alg. 2): erase the whole routing table, psi = highest c(k)."""

from __future__ import annotations

import time

from .llfd import PlannerContext
from .phased import finish, run_phases, table_key_indices
from .types import Assignment, BalanceConfig, KeyStats, RebalanceResult


def mintable(stats: KeyStats, assignment: Assignment,
             config: BalanceConfig) -> RebalanceResult:
    t0 = time.perf_counter()
    ctx = PlannerContext(stats, assignment, config, psi=stats.cost)
    clean = table_key_indices(stats, assignment)     # Phase I: move back ALL of A
    ws = run_phases(stats, assignment, config, clean_idxs=clean, ctx=ctx)
    return finish(ws, assignment, config, t0, cleaned=float(len(clean)))
