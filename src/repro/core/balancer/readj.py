"""Readj baseline (Gedik, VLDBJ'14 [11]) as characterized by the paper.

"It considers all possible swaps by pairing tasks and keys to find the best
key movement to alleviate the workload imbalance ... just considers adjusting
the big load keys."

Implementation: iterative local search. Only keys whose cost exceeds a
``sigma`` fraction of the mean load participate ("big load keys"). Each round
evaluates every candidate single-key move and every candidate pairwise swap
between instances, applies the one that most reduces max load, and stops when
balanced or no improving move exists. Readj also prefers restoring keys to
their hash destination (to shrink the routing table), which we honour via a
tie-break. Complexity is O(rounds * H^2) for H heavy keys — the quadratic
blow-up the paper's Figs. 8/12 exhibit.
"""

from __future__ import annotations

import time

import numpy as np

from . import metrics
from .types import Assignment, BalanceConfig, KeyStats, RebalanceResult


def readj(stats: KeyStats, assignment: Assignment, config: BalanceConfig,
          sigma: float = 0.01, max_rounds: int = 10_000) -> RebalanceResult:
    t0 = time.perf_counter()
    n_dest = assignment.n_dest
    hash_dest = assignment.hash_router(stats.keys)
    assign = assignment.dest(stats.keys).copy()
    cost = stats.cost
    loads = np.bincount(assign, weights=cost, minlength=n_dest).astype(np.float64)
    base = metrics.base_for(stats, n_dest)   # frozen tail (sketch-mode stats)
    base_sum = 0.0
    if base is not None:
        loads += base
        base_sum = float(base.sum())
    mean = (float(np.sum(cost)) + base_sum) / n_dest
    l_max = config.l_max(mean)

    heavy = np.flatnonzero(cost >= sigma * mean)     # "big load keys" only
    for _ in range(max_rounds):
        if float(np.max(loads)) <= l_max:
            break
        src = int(np.argmax(loads))
        src_keys = heavy[assign[heavy] == src]
        if len(src_keys) == 0:
            break
        best = None  # (new_max_pair, prefer_hash_penalty, kind, i, j, dst)
        # single moves: heavy key i from src -> any other dest
        for i in src_keys:
            for dst in range(n_dest):
                if dst == src:
                    continue
                new_src = loads[src] - cost[i]
                new_dst = loads[dst] + cost[i]
                score = max(new_src, new_dst)
                pen = 0 if hash_dest[i] == dst else 1
                cand = (score, pen, 0, int(i), -1, dst)
                if best is None or cand < best:
                    best = cand
        # pairwise swaps: heavy i on src <-> heavy j elsewhere
        for i in src_keys:
            others = heavy[assign[heavy] != src]
            for j in others:
                dst = int(assign[j])
                if cost[i] <= cost[j]:
                    continue
                new_src = loads[src] - cost[i] + cost[j]
                new_dst = loads[dst] + cost[i] - cost[j]
                score = max(new_src, new_dst)
                pen = (0 if hash_dest[i] == dst else 1) + (0 if hash_dest[j] == src else 1)
                cand = (score, pen, 1, int(i), int(j), dst)
                if best is None or cand < best:
                    best = cand
        if best is None or best[0] >= float(np.max(loads)) - 1e-12:
            break                                     # no improving move
        _, _, kind, i, j, dst = best
        src_d = int(assign[i])
        loads[src_d] -= cost[i]
        loads[dst] += cost[i]
        assign[i] = dst
        if kind == 1:
            loads[dst] -= cost[j]
            loads[src_d] += cost[j]
            assign[j] = src_d

    table = {int(k): int(d) for k, d, h in zip(stats.keys, assign, hash_dest)
             if d != h}
    new = Assignment(assignment.hash_router, table)
    moved = assign != assignment.dest(stats.keys)
    th = metrics.theta(loads)
    return RebalanceResult(
        assignment=new, moved_keys=stats.keys[moved],
        migration_cost=float(np.sum(stats.mem[moved])), loads=loads,
        table_size=len(table), theta=th,
        feasible_balance=th <= config.theta_max + 1e-9,
        feasible_table=len(table) <= config.table_max,
        plan_time_s=time.perf_counter() - t0, meta={"sigma": sigma},
    )


def readj_best_sigma(stats: KeyStats, assignment: Assignment,
                     config: BalanceConfig,
                     sigmas=(0.2, 0.1, 0.05, 0.02, 0.01, 0.005)) -> RebalanceResult:
    """The paper tunes Readj's sigma per experiment and reports the best run."""
    best = None
    for s in sigmas:
        r = readj(stats, assignment, config, sigma=s)
        key = (not r.feasible_balance, r.theta, r.migration_cost)
        if best is None or key < best[0]:
            best = (key, r)
    return best[1]
