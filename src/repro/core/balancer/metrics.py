"""Balance / migration metrics (paper Sec. II-A and Sec. V 'Evaluation Metrics')."""

from __future__ import annotations

from typing import Optional

import numpy as np

from .types import Assignment, KeyStats


def segment_sum(values: np.ndarray, segment_ids: np.ndarray,
                n_segments: int) -> np.ndarray:
    """Sum ``values`` into ``n_segments`` buckets keyed by ``segment_ids``.

    The host-side twin of the device segment-sums (``kernels.key_stats``):
    the vectorized engine and the load computation below both reduce
    per-key quantities to per-task aggregates through this one primitive.
    """
    return np.bincount(segment_ids, weights=values,
                       minlength=n_segments).astype(np.float64)


def base_for(stats: KeyStats, n_dest: int) -> Optional[np.ndarray]:
    """The stats' frozen tail base loads sized to ``n_dest`` (or None).

    Sketch-mode stats (``balancer/sketch.py``) carry per-destination cost
    for tail keys absent from the per-key arrays. A rescale can briefly
    hand an ``n_dest`` differing from the snapshot's: pad with zeros on
    grow; truncate on shrink (the next interval's ingest re-derives the
    totals under the new fleet).
    """
    base = stats.base_loads
    if base is None:
        return None
    if base.size < n_dest:
        return np.concatenate([base, np.zeros(n_dest - base.size)])
    if base.size > n_dest:
        return base[:n_dest]
    return base


def loads_for(stats: KeyStats, dests: np.ndarray, n_dest: int) -> np.ndarray:
    """L(d) = sum of c(k) over keys assigned to d (+ frozen tail base)."""
    out = segment_sum(stats.cost, dests, n_dest)
    base = base_for(stats, n_dest)
    if base is not None:
        out = out + base
    return out


def loads(stats: KeyStats, assignment: Assignment) -> np.ndarray:
    return loads_for(stats, assignment.dest(stats.keys), assignment.n_dest)


def theta(loads_arr: np.ndarray) -> float:
    """max_d (L(d) - mean) / mean — the one-sided overload indicator.

    This is the form the paper's analysis actually uses (Lemma 3 defines
    theta_max = max_d (L(d) - L_bar)/L_bar) and the constraint every
    algorithm enforces (L(d) <= L_max). The two-sided variant is
    :func:`theta_two_sided`.
    """
    mean = float(np.mean(loads_arr))
    if mean <= 0.0:
        return 0.0
    return max(0.0, float(np.max(loads_arr - mean) / mean))


def theta_for(stats: KeyStats, assignment: Assignment) -> float:
    """theta of the current assignment in one call (trigger-path shorthand).

    The controller's step-2 decision and several benchmarks all spell
    ``theta(loads(stats, assignment))``; this keeps the pair fused so the
    destination lookup happens exactly once.
    """
    return theta(loads(stats, assignment))


def theta_two_sided(loads_arr: np.ndarray) -> float:
    """max_d |L(d) - mean| / mean (paper Sec. II-A's display form)."""
    mean = float(np.mean(loads_arr))
    if mean <= 0.0:
        return 0.0
    return float(np.max(np.abs(loads_arr - mean)) / mean)


def skewness(loads_arr: np.ndarray) -> float:
    """max L(d) / mean L  (the 'workload skewness' metric of Sec. V)."""
    mean = float(np.mean(loads_arr))
    if mean <= 0.0:
        return 1.0
    return float(np.max(loads_arr) / mean)


def migration_cost(stats: KeyStats, old: Assignment, new: Assignment) -> float:
    """M_i(w, F, F') = sum of S(k, w) over Delta(F, F') (Eq. 2)."""
    moved = old.dest(stats.keys) != new.dest(stats.keys)
    return float(np.sum(stats.mem[moved]))


def moved_keys(stats: KeyStats, old: Assignment, new: Assignment) -> np.ndarray:
    moved = old.dest(stats.keys) != new.dest(stats.keys)
    return stats.keys[moved]


def migration_fraction(stats: KeyStats, old: Assignment, new: Assignment) -> float:
    """Migration cost as a fraction of total maintained state (paper's metric)."""
    total = float(np.sum(stats.mem))
    if total <= 0.0:
        return 0.0
    return migration_cost(stats, old, new) / total
