"""Partitioning strategies: one protocol over interval planners and routers.

The paper's Mixed/MinTable/MinMig family and the competing partitioners it
evaluates against (PKG [1510.07623], the Power of Both Choices [1504.00788],
W-Choices [1510.05714]) are different *shapes* of algorithm:

* **table planners** solve a per-interval optimization producing a new
  assignment function F' (routing table + hash) and a migration plan — every
  tuple of a key goes to F(k), state moves when F changes;
* **choice routers** pick a destination per *tuple* from a small stable
  candidate set per key using live load estimates — a key's tuples split
  across candidates, nothing ever migrates, and non-commutative per-key
  aggregates need a downstream merge stage.

This module puts both behind one :class:`PartitionStrategy` protocol with a
registry, mirroring the ``StateBackend`` protocol/registry of
``repro.streams.backends``: strategies are *registered*, not if/elif'd —
:func:`register_strategy` + :func:`strategy_names` + :func:`resolve_strategy`
— and carry capability flags (``plans_migration``, ``needs_merge_stage``)
that the controller and engine consult instead of name-matching.

One ``algorithm=`` spec grammar (THE reference; the controller, ``KeyedStage``
and ``keyed_stage()`` all accept exactly this and delegate here):

* a **name** from :func:`strategy_names` — resolved to a fresh instance;
* a **callable** ``(stats, assignment, config) -> RebalanceResult`` — the
  legacy planner signature, wrapped as a :class:`TablePlanner` (e.g.
  ``functools.partial`` over extra knobs, or the scalar reference oracle);
* a **configured** :class:`PartitionStrategy` **instance** — used as-is
  (routers are stateful: one instance per controller).

The legacy ``ALGORITHMS`` dict survives as a read-only deprecated view over
the registered table planners (:data:`ALGORITHMS`); resolve through the
registry instead.

Choice-router semantics
-----------------------
Candidate sets are pure hash functions of the key — ``d`` independent
:class:`~repro.core.balancer.hashing.Hash32` draws (the device-canonical
fmix32 family the routing kernels implement), so they are stable across
batches, restarts and router instances. Routing is vectorized in chunks:
within a chunk each key's tuples round-robin over its candidates starting
from the currently least-loaded one (ties break toward the earlier hash,
matching the sequential greedy of :func:`~repro.core.balancer.pkg.pkg_route`),
and per-worker tuple-count loads update between chunks. This is the
power-of-d-choices policy under slightly stale loads — exactly the regime
the PKG paper proves safe (their sources route on local estimates).
"""

from __future__ import annotations

import warnings
from collections.abc import Mapping
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from .hashing import GOLDEN_SEED_STRIDE, Hash32
from .types import Assignment, BalanceConfig, KeyStats, RebalanceResult

#: name -> zero-arg factory returning a fresh strategy instance. Mutated only
#: through :func:`register_strategy` / :func:`_register_planner`.
STRATEGIES: Dict[str, Callable[[], "PartitionStrategy"]] = {}

#: seed spacing between the d candidate hashes — the shared golden-ratio
#: constant (hashing.GOLDEN_SEED_STRIDE), also used by the count-min sketch
#: rows so routers and sketches draw from one decorrelated seed family
_CHOICE_SEED_STRIDE = GOLDEN_SEED_STRIDE


def register_strategy(factory):
    """Register a strategy factory under ``factory.name`` (decorator-friendly).

    ``factory`` is typically a :class:`PartitionStrategy` subclass whose
    zero-arg constructor yields a usable default configuration.
    """
    name = getattr(factory, "name", None)
    if not name:
        raise ValueError(f"{factory!r} needs a non-empty 'name'")
    STRATEGIES[name] = factory
    return factory


def strategy_names() -> Tuple[str, ...]:
    """Every resolvable ``algorithm=`` name, sorted."""
    return tuple(sorted(STRATEGIES))


def get_strategy(name: str):
    """The registered factory for ``name`` (class or callable)."""
    if name not in STRATEGIES:
        raise ValueError(f"unknown algorithm {name!r}; "
                         f"choose from {list(strategy_names())}")
    return STRATEGIES[name]


def resolve_strategy(spec) -> "PartitionStrategy":
    """Map an ``algorithm=`` spec (name | callable | instance) to a strategy.

    Names yield a *fresh* instance per call (routers carry per-controller
    load state); instances pass through unchanged; bare callables with the
    planner signature are wrapped in a :class:`TablePlanner` (legacy
    passthrough, ``name`` taken from ``__name__``).
    """
    if isinstance(spec, PartitionStrategy):
        return spec
    if callable(spec):
        return TablePlanner(spec)
    return get_strategy(spec)()


class PartitionStrategy:
    """Protocol for partitioning strategies (capability-flag driven).

    Class attributes (the capability flags):

    * ``name`` — registry key / ``algorithm_name`` surfaced by controllers.
    * ``kind`` — ``"planner"`` or ``"router"``.
    * ``plans_migration`` — True when the strategy produces rebalance plans
      that move state (table planners); False for routers, which never
      migrate (the controller skips trigger/plan/executor entirely).
    * ``needs_merge_stage`` — True when the strategy may split one key's
      tuples across workers, so non-commutative per-key aggregates require
      a downstream merge stage (see ``repro.streams.topology``); the engine
      refuses operators without ``split_safe`` under such strategies.

    Lifecycle: the controller calls :meth:`bind` once with its assignment
    (routers size their load vectors and derive candidate-hash seeds from
    it); planners then serve :meth:`plan` per triggered interval, routers
    serve :meth:`route` per batch and :meth:`on_stats` per interval.
    """

    name: str = ""
    kind: str = "planner"
    plans_migration: bool = True
    needs_merge_stage: bool = False

    @property
    def is_router(self) -> bool:
        return self.kind == "router"

    def bind(self, assignment: Assignment) -> None:
        """Attach to a controller's assignment (called once per controller)."""

    # -- planner surface -------------------------------------------------------
    def plan(self, stats: KeyStats, assignment: Assignment,
             config: BalanceConfig) -> RebalanceResult:
        raise NotImplementedError(f"{self.name!r} is not a table planner")

    # -- router surface --------------------------------------------------------
    def route(self, keys: np.ndarray) -> np.ndarray:
        """Per-tuple destinations for one batch (stateful: advances loads)."""
        raise NotImplementedError(f"{self.name!r} is not a choice router")

    def on_stats(self, stats: KeyStats) -> None:
        """Interval-boundary measurement hook (e.g. head-key refresh)."""

    @property
    def loads(self) -> np.ndarray:
        """Per-worker routed tuple counts (router load estimate)."""
        raise NotImplementedError(f"{self.name!r} is not a choice router")


class TablePlanner(PartitionStrategy):
    """A paper-family interval planner behind the strategy protocol.

    Wraps the classic ``(stats, assignment, config) -> RebalanceResult``
    callable unchanged — the planners themselves did not move; this is the
    adapter that lets them share the seam with choice routers.
    """

    kind = "planner"
    plans_migration = True
    needs_merge_stage = False

    def __init__(self, fn, name: Optional[str] = None):
        self.fn = fn
        self.name = name or getattr(fn, "__name__", "custom")

    def plan(self, stats, assignment, config) -> RebalanceResult:
        return self.fn(stats, assignment, config)


#: raw name -> planner callable for the registered table planners — the
#: backing store of the deprecated :data:`ALGORITHMS` view.
PLANNERS: Dict[str, Callable] = {}


def _register_planner(name: str, fn) -> None:
    PLANNERS[name] = fn
    STRATEGIES[name] = lambda fn=fn, name=name: TablePlanner(fn, name)


def _occurrence_index(inv: np.ndarray, counts: np.ndarray) -> np.ndarray:
    """occ[i] = earlier tuples in the chunk sharing keys[i]'s key (the same
    closed form the batched operators use; local copy keeps the balancer
    package independent of repro.streams)."""
    order = np.argsort(inv, kind="stable")
    starts = np.concatenate(([0], np.cumsum(counts)[:-1]))
    occ = np.empty(inv.size, dtype=np.int64)
    occ[order] = np.arange(inv.size, dtype=np.int64) - np.repeat(starts,
                                                                 counts)
    return occ


class ChoiceRouter(PartitionStrategy):
    """Power-of-d-choices per-tuple router (PKG's scheme, d=2 by default).

    Every key has ``n_choices`` stable candidate destinations (independent
    :class:`~repro.core.balancer.hashing.Hash32` draws seeded off the
    controller's router seed); tuples go to candidates in least-loaded-first
    round-robin, vectorized chunk by chunk (see the module docstring for the
    exact semantics and their relation to the papers' sequential greedy).

    ``candidate_fn`` (tests / worked examples) overrides the hash-derived
    candidate matrix: ``candidate_fn(unique_keys) -> (U, d) int array``.
    """

    name = "pkg"
    kind = "router"
    plans_migration = False
    needs_merge_stage = True

    def __init__(self, n_choices: int = 2, chunk: int = 512,
                 seed: Optional[int] = None, candidate_fn=None):
        if n_choices < 1:
            raise ValueError("n_choices must be >= 1")
        if chunk < 1:
            raise ValueError("chunk must be >= 1")
        self.n_choices = int(n_choices)
        self.chunk = int(chunk)
        self._seed_override = seed
        self.candidate_fn = candidate_fn
        self.n_dest = 0
        self.seed = 0
        self._hashes: list = []
        self._loads = np.zeros(0, dtype=np.float64)

    def bind(self, assignment: Assignment) -> None:
        self.n_dest = assignment.n_dest
        self.seed = (self._seed_override if self._seed_override is not None
                     else getattr(assignment.hash_router, "seed", 0))
        self._hashes = [
            Hash32(self.n_dest, seed=self.seed + j * _CHOICE_SEED_STRIDE)
            for j in range(self.n_choices)]
        self._loads = np.zeros(self.n_dest, dtype=np.float64)

    @property
    def loads(self) -> np.ndarray:
        return self._loads

    # -- candidate sets (stable per key) ---------------------------------------
    def candidates(self, keys: np.ndarray) -> np.ndarray:
        """(len(keys), d) candidate destinations — a pure function of the
        key, so identical across batches and router instances with the same
        (n_dest, seed)."""
        keys = np.asarray(keys, dtype=np.int64)
        if self.candidate_fn is not None:
            return np.asarray(self.candidate_fn(keys), dtype=np.int64)
        return np.stack([h(keys) for h in self._hashes], axis=1)

    def _candidate_matrix(self, uk: np.ndarray
                          ) -> Tuple[np.ndarray, np.ndarray]:
        """(U, dmax) candidate matrix + (U,) per-key choice count. Subclasses
        may widen selected keys' candidate sets (W-Choices)."""
        cand = self.candidates(uk)
        dk = np.full(uk.size, cand.shape[1], dtype=np.int64)
        return cand, dk

    # -- chunked greedy routing ------------------------------------------------
    def _route_chunk(self, chunk_keys: np.ndarray,
                     loads: np.ndarray) -> np.ndarray:
        uk, inv, counts = np.unique(chunk_keys, return_inverse=True,
                                    return_counts=True)
        cand, dk = self._candidate_matrix(uk)
        lm = loads[cand]
        # pad columns beyond a key's choice count sort last (never selected:
        # occ % dk stays below dk)
        cols = np.arange(cand.shape[1], dtype=np.int64)
        lm[cols[None, :] >= dk[:, None]] = np.inf
        order = np.argsort(lm, axis=1, kind="stable")   # ties -> earlier hash
        ranked = np.take_along_axis(cand, order, axis=1)
        occ = _occurrence_index(inv, counts)
        dest = ranked[inv, occ % dk[inv]]
        loads += np.bincount(dest, minlength=loads.size).astype(np.float64)
        return dest

    def route(self, keys: np.ndarray) -> np.ndarray:
        keys = np.asarray(keys, dtype=np.int64)
        out = np.empty(keys.size, dtype=np.int64)
        for lo in range(0, keys.size, self.chunk):
            hi = min(keys.size, lo + self.chunk)
            out[lo:hi] = self._route_chunk(keys[lo:hi], self._loads)
        return out


@register_strategy
class PartialKeyGrouping(ChoiceRouter):
    """PKG (Nasir et al., arXiv:1510.07623): two choices per key, every tuple
    to the less-loaded candidate. Splits each key over at most 2 workers —
    the head key's worker share drops from p1 (key grouping) to p1/2."""

    name = "pkg"


@register_strategy
class PowerOfBothChoices(ChoiceRouter):
    """Power of Both Choices (Nasir et al., arXiv:1504.00788): the same
    two-choice policy run *independently at each of S sources*, each source
    routing on its own local load estimate — the paper's point is that no
    load coordination between sources is needed. ``n_sources=1`` is
    bit-identical to :class:`PartialKeyGrouping` (the benchmark matrix
    asserts exactly that parity)."""

    name = "potc"

    def __init__(self, n_sources: int = 4, **kwargs):
        super().__init__(**kwargs)
        if n_sources < 1:
            raise ValueError("n_sources must be >= 1")
        self.n_sources = int(n_sources)
        self._src_loads = np.zeros((self.n_sources, 0), dtype=np.float64)
        self._pos = 0

    def bind(self, assignment: Assignment) -> None:
        super().bind(assignment)
        self._src_loads = np.zeros((self.n_sources, self.n_dest),
                                   dtype=np.float64)
        self._pos = 0

    @property
    def loads(self) -> np.ndarray:
        return self._src_loads.sum(axis=0)

    def route(self, keys: np.ndarray) -> np.ndarray:
        keys = np.asarray(keys, dtype=np.int64)
        n = keys.size
        out = np.empty(n, dtype=np.int64)
        # tuples arrive round-robin at the S sources (position-deterministic
        # so repeated runs and parity oracles see the same split)
        src = (self._pos + np.arange(n, dtype=np.int64)) % self.n_sources
        for s in range(self.n_sources):
            idx = np.nonzero(src == s)[0]
            if not idx.size:
                continue
            sub = keys[idx]
            sub_out = np.empty(idx.size, dtype=np.int64)
            loads_s = self._src_loads[s]
            for lo in range(0, idx.size, self.chunk):
                hi = min(idx.size, lo + self.chunk)
                sub_out[lo:hi] = self._route_chunk(sub[lo:hi], loads_s)
            out[idx] = sub_out
        self._pos = int((self._pos + n) % self.n_sources)
        return out


@register_strategy
class WChoices(ChoiceRouter):
    """W-Choices (Nasir et al., "When Two Choices Are not Enough",
    arXiv:1510.05714): two choices cannot balance once the head key exceeds
    2/W of the stream (its two candidates must carry p1/2 each), so head
    keys — frequency share >= ``head_threshold`` in the last interval's
    stats — route over ALL W workers while the tail keeps PKG's two. The
    head set refreshes from the controller's step-1 measurement each
    interval; heavy hitters are estimated through the same
    :class:`~repro.core.balancer.sketch.SpaceSavingTracker` the sketch-mode
    planners use (the paper estimates them with a SpaceSaving sketch too),
    so routers and planners identify the head identically. With
    ``head_capacity`` at least the number of distinct keys the tracker
    never truncates and the head is the exact threshold set; the default
    capacity guarantees every key at or above the threshold share is
    captured with a 4x margin (capture needs capacity+1 >= 1/threshold).
    Before the first interval the head is empty and the router behaves
    exactly like PKG."""

    name = "wchoices"

    def __init__(self, head_threshold: float = 0.01,
                 head_capacity: Optional[int] = None, **kwargs):
        super().__init__(**kwargs)
        if not 0.0 < head_threshold <= 1.0:
            raise ValueError("head_threshold must be in (0, 1]")
        self.head_threshold = float(head_threshold)
        if head_capacity is None:
            head_capacity = max(4096, int(np.ceil(4.0 / self.head_threshold)))
        if head_capacity < 1:
            raise ValueError("head_capacity must be >= 1")
        self.head_capacity = int(head_capacity)
        self._head = np.zeros(0, dtype=np.int64)    # sorted head key ids

    def bind(self, assignment: Assignment) -> None:
        super().bind(assignment)
        self._head = np.zeros(0, dtype=np.int64)

    @property
    def head_keys(self) -> np.ndarray:
        return self._head

    def on_stats(self, stats: KeyStats) -> None:
        from .sketch import SpaceSavingTracker
        weight = stats.freq if stats.freq is not None else stats.cost
        total = float(weight.sum())
        if total <= 0.0:
            self._head = np.zeros(0, dtype=np.int64)
            return
        tracker = SpaceSavingTracker(self.head_capacity)
        tracker.update(stats.keys, weight)
        est = tracker.estimate(tracker.keys)    # upper bound: no head missed
        self._head = np.sort(
            tracker.keys[est >= self.head_threshold * tracker.total])

    def _candidate_matrix(self, uk: np.ndarray
                          ) -> Tuple[np.ndarray, np.ndarray]:
        base = self.candidates(uk)
        d = base.shape[1]
        if not self._head.size or self.n_dest <= d:
            return base, np.full(uk.size, d, dtype=np.int64)
        pos = np.searchsorted(self._head, uk)
        pos = np.clip(pos, 0, self._head.size - 1)
        is_head = self._head[pos] == uk
        if not is_head.any():
            return base, np.full(uk.size, d, dtype=np.int64)
        cand = np.zeros((uk.size, self.n_dest), dtype=np.int64)
        cand[:, :d] = base
        cand[is_head] = np.arange(self.n_dest, dtype=np.int64)
        dk = np.where(is_head, self.n_dest, d).astype(np.int64)
        return cand, dk


class _AlgorithmsView(Mapping):
    """Deprecated read-only view of the registered table planners.

    Preserves the legacy ``ALGORITHMS`` dict surface (lookups, iteration,
    membership) for one release; every access warns. New code resolves
    through :func:`strategy_names` / :func:`resolve_strategy`, which also
    cover the choice routers this dict never could.
    """

    def __init__(self, backing: Dict[str, Callable]):
        self._backing = backing

    @staticmethod
    def _warn() -> None:
        warnings.warn(
            "repro.core.balancer.ALGORITHMS is deprecated; use the strategy "
            "registry instead (repro.core.balancer.strategy: "
            "strategy_names() / resolve_strategy()), which also exposes the "
            "choice routers (pkg/potc/wchoices)",
            DeprecationWarning, stacklevel=3)

    def __getitem__(self, name):
        self._warn()
        return self._backing[name]

    def __iter__(self):
        self._warn()
        return iter(self._backing)

    def __len__(self):
        self._warn()
        return len(self._backing)

    def __contains__(self, name):
        self._warn()
        return name in self._backing

    def __repr__(self):
        return f"ALGORITHMS({list(self._backing)})"


ALGORITHMS = _AlgorithmsView(PLANNERS)
