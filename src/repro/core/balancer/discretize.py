"""HLHE value discretization with greedy sign balancing (paper Sec. IV-B).

Step 1 (representative values, half-linear-half-exponential): given degree
R = 2^r and max value M (values normalized so min >= 1),
  linear part      y = s*R, (s-1)*R, ..., R          with s = floor(M / R)
  exponential part y = R/2, R/4, ..., 2, 1
Step 2 (greedy): process values in non-increasing order; x in [y_j, y_{j-1})
may round to either bracket end; choose the larger iff the accumulated
deviation sum(x - phi(x)) so far is positive (cancels over-counting).

The paper's Fig. 6 worked example reaches |delta| = 0; the greedy rule as
stated reaches |delta| <= R in general (Theorem 3's "~0"), which the property
tests assert: |delta| stays bounded by the largest bracket gap independent of
the number of values.
"""

from __future__ import annotations

import numpy as np


def hlhe_representatives(max_value: float, r: int) -> np.ndarray:
    """Strictly decreasing representative values y_1 > y_2 > ... > y_m >= 1."""
    if r < 0:
        raise ValueError("r must be >= 0")
    R = 2 ** r
    s = max(1, int(np.floor(max_value / R)))
    linear = [float((s - i) * R) for i in range(s)]          # s*R ... R
    expo = [float(2 ** (r - t)) for t in range(1, r + 1)]    # R/2 ... 1
    ys = linear + expo
    # guard: strictly decreasing, unique (R=1 -> expo empty, linear only)
    out = []
    for y in ys:
        if not out or y < out[-1]:
            out.append(y)
    return np.asarray(out, dtype=np.float64)


def discretize(values: np.ndarray, r: int) -> np.ndarray:
    """phi(x) per the greedy sign-balancing rule. Preserves input order."""
    values = np.asarray(values, dtype=np.float64)
    if values.size == 0:
        return values.copy()
    if np.any(values < 1.0):
        raise ValueError("values must be normalized to >= 1")
    ys = hlhe_representatives(float(values.max()), r)
    order = np.argsort(-values, kind="stable")               # non-increasing
    sorted_vals = values[order]
    # ys is decreasing; bracket j such that ys[j-1] > x >= ys[j]: on the
    # increasing array -ys that is the first index with ys[j] <= x.
    # Vectorized once — the greedy sign choice below is inherently sequential.
    js = np.searchsorted(-ys, -sorted_vals, side="left")
    js = np.clip(js, 1, len(ys) - 1)
    hi_arr = ys[js - 1].tolist()
    lo_arr = ys[js].tolist()
    cap = float(ys[0])
    xs = sorted_vals.tolist()
    out_sorted = np.empty_like(sorted_vals)
    acc = 0.0                                                # sum(x - phi(x))
    for i, x in enumerate(xs):
        if x >= cap:
            phi = cap
        else:
            phi = hi_arr[i] if acc > 0 else lo_arr[i]
        acc += x - phi
        out_sorted[i] = phi
    out = np.empty_like(values)
    out[order] = out_sorted
    return out


def total_deviation(values: np.ndarray, discretized: np.ndarray) -> float:
    """|delta| = |sum(x - phi(x))| (the paper's accumulated-error metric)."""
    return float(abs(np.sum(np.asarray(values) - np.asarray(discretized))))
