"""Skew-shield balancer: the paper's core contribution as a composable library.

Algorithms (paper Sec. III): simple, llfd (via phased driver), mintable,
minmig, mixed, mixed_bf; baselines readj, pkg; optimizations compact_mixed +
HLHE discretization (Sec. IV).
"""

from .types import (Assignment, BalanceConfig, KeyStats, RebalanceResult,
                    HashRouter)
from .hashing import ConsistentHash, ModHash, splitmix64
from . import metrics
from .llfd import PlannerContext, Workspace
from .simple import simple
from .mintable import mintable
from .minmig import minmig
from .mixed import mixed, mixed_bf
from .readj import readj, readj_best_sigma
from .pkg import pkg_route, pkg_route_stats, PKGResult
from .compact import compact_mixed, build_groups
from .discretize import discretize, hlhe_representatives, total_deviation
from .reference import (REFERENCE_ALGORITHMS, reference_mintable,
                        reference_minmig, reference_mixed, reference_mixed_bf)

ALGORITHMS = {
    "simple": simple,
    "mintable": mintable,
    "minmig": minmig,
    "mixed": mixed,
    "mixed_bf": mixed_bf,
    "readj": readj,
    "compact_mixed": compact_mixed,
    # scalar pre-PR planner, kept as the parity oracle / A-B baseline
    "mixed_reference": reference_mixed,
    "mintable_reference": reference_mintable,
    "minmig_reference": reference_minmig,
}

__all__ = [
    "Assignment", "BalanceConfig", "KeyStats", "RebalanceResult", "HashRouter",
    "ConsistentHash", "ModHash", "splitmix64", "metrics",
    "PlannerContext", "Workspace",
    "simple", "mintable", "minmig", "mixed", "mixed_bf",
    "readj", "readj_best_sigma", "pkg_route", "pkg_route_stats", "PKGResult",
    "compact_mixed", "build_groups", "discretize", "hlhe_representatives",
    "total_deviation", "ALGORITHMS", "REFERENCE_ALGORITHMS",
    "reference_mintable", "reference_minmig", "reference_mixed",
    "reference_mixed_bf",
]
