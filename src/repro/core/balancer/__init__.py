"""Skew-shield balancer: the paper's core contribution as a composable library.

Algorithms (paper Sec. III): simple, llfd (via phased driver), mintable,
minmig, mixed, mixed_bf; baselines readj, pkg; optimizations compact_mixed +
HLHE discretization (Sec. IV).

Every strategy — the paper's table planners *and* the competing per-tuple
choice routers (pkg/potc/wchoices) — is resolvable by name through the
registry in :mod:`repro.core.balancer.strategy` (``strategy_names()`` /
``resolve_strategy()``); the legacy ``ALGORITHMS`` dict is a deprecated
read-only view over the planner subset.
"""

from .types import (Assignment, BalanceConfig, KeyStats, RebalanceResult,
                    HashRouter)
from .hashing import ConsistentHash, ModHash, splitmix64
from . import metrics
from .llfd import PlannerContext, Workspace
from .simple import simple
from .mintable import mintable
from .minmig import minmig
from .mixed import mixed, mixed_bf
from .readj import readj, readj_best_sigma
from .pkg import pkg_route, pkg_route_stats, PKGResult
from .compact import compact_mixed, build_groups
from .discretize import discretize, hlhe_representatives, total_deviation
from .reference import (REFERENCE_ALGORITHMS, reference_mintable,
                        reference_minmig, reference_mixed, reference_mixed_bf)
from .sketch import (CountMinSketch, SketchConfig, SketchStats,
                     SpaceSavingTracker)
from .strategy import (ALGORITHMS, ChoiceRouter, PartialKeyGrouping,
                       PartitionStrategy, PowerOfBothChoices, TablePlanner,
                       WChoices, _register_planner, register_strategy,
                       resolve_strategy, strategy_names)

for _name, _fn in (
    ("simple", simple),
    ("mintable", mintable),
    ("minmig", minmig),
    ("mixed", mixed),
    ("mixed_bf", mixed_bf),
    ("readj", readj),
    ("compact_mixed", compact_mixed),
    # scalar pre-PR planners, kept as parity oracles / A-B baselines
    ("mixed_reference", reference_mixed),
    ("mintable_reference", reference_mintable),
    ("minmig_reference", reference_minmig),
):
    _register_planner(_name, _fn)
del _name, _fn

__all__ = [
    "Assignment", "BalanceConfig", "KeyStats", "RebalanceResult", "HashRouter",
    "ConsistentHash", "ModHash", "splitmix64", "metrics",
    "PlannerContext", "Workspace",
    "simple", "mintable", "minmig", "mixed", "mixed_bf",
    "readj", "readj_best_sigma", "pkg_route", "pkg_route_stats", "PKGResult",
    "compact_mixed", "build_groups", "discretize", "hlhe_representatives",
    "total_deviation", "ALGORITHMS", "REFERENCE_ALGORITHMS",
    "reference_mintable", "reference_minmig", "reference_mixed",
    "reference_mixed_bf",
    "CountMinSketch", "SketchConfig", "SketchStats", "SpaceSavingTracker",
    "PartitionStrategy", "TablePlanner", "ChoiceRouter",
    "PartialKeyGrouping", "PowerOfBothChoices", "WChoices",
    "register_strategy", "resolve_strategy", "strategy_names",
]
