"""Skew-shield balancer: the paper's core contribution as a composable library.

Algorithms (paper Sec. III): simple, llfd (via phased driver), mintable,
minmig, mixed, mixed_bf; baselines readj, pkg; optimizations compact_mixed +
HLHE discretization (Sec. IV).
"""

from .types import (Assignment, BalanceConfig, KeyStats, RebalanceResult,
                    HashRouter)
from .hashing import ConsistentHash, ModHash, splitmix64
from . import metrics
from .simple import simple
from .mintable import mintable
from .minmig import minmig
from .mixed import mixed, mixed_bf
from .readj import readj, readj_best_sigma
from .pkg import pkg_route, pkg_route_stats, PKGResult
from .compact import compact_mixed, build_groups
from .discretize import discretize, hlhe_representatives, total_deviation

ALGORITHMS = {
    "simple": simple,
    "mintable": mintable,
    "minmig": minmig,
    "mixed": mixed,
    "mixed_bf": mixed_bf,
    "readj": readj,
    "compact_mixed": compact_mixed,
}

__all__ = [
    "Assignment", "BalanceConfig", "KeyStats", "RebalanceResult", "HashRouter",
    "ConsistentHash", "ModHash", "splitmix64", "metrics",
    "simple", "mintable", "minmig", "mixed", "mixed_bf",
    "readj", "readj_best_sigma", "pkg_route", "pkg_route_stats", "PKGResult",
    "compact_mixed", "build_groups", "discretize", "hlhe_representatives",
    "total_deviation", "ALGORITHMS",
]
