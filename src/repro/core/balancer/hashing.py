"""Base hash functions h: K -> D (paper Sec. II, uses consistent hashing [14]).

Two interchangeable routers:

* :class:`ModHash` — splitmix64 mix then mod N_D. Cheapest; the data-plane
  kernels reimplement exactly this mix so host and device agree bit-for-bit.
* :class:`ConsistentHash` — classic ring with virtual nodes; when ``n_dest``
  changes (elastic scale-out, paper Fig. 15) only ~K/N_D keys remap.
"""

from __future__ import annotations

import numpy as np

from .types import HashRouter

_U64 = np.uint64

#: seed spacing used wherever a family of independent Hash32 draws is needed
#: (choice-router candidates, count-min sketch rows): golden-ratio odd
#: constant — fmix32 decorrelates any two seeds, this just keeps them
#: distinct per row/candidate index.
GOLDEN_SEED_STRIDE = 0x9E3779B9


def splitmix64(x: np.ndarray, seed: int = 0x9E3779B97F4A7C15) -> np.ndarray:
    """Vectorized splitmix64 finalizer. uint64 in, uint64 out."""
    with np.errstate(over="ignore"):
        z = x.astype(_U64) + _U64(seed)
        z = (z ^ (z >> _U64(30))) * _U64(0xBF58476D1CE4E5B9)
        z = (z ^ (z >> _U64(27))) * _U64(0x94D049BB133111EB)
        z = z ^ (z >> _U64(31))
    return z


def fmix32(x: np.ndarray, seed: int = 0) -> np.ndarray:
    """Vectorized murmur3 finalizer (32-bit). TPUs have no 64-bit integer
    units, so this is the *device-canonical* hash: the numpy version here, the
    jnp version in repro.core.routing and the Pallas kernel all match
    bit-for-bit (tested)."""
    with np.errstate(over="ignore"):
        h = x.astype(np.uint32) ^ np.uint32(seed & 0xFFFFFFFF)
        h ^= h >> np.uint32(16)
        h *= np.uint32(0x85EBCA6B)
        h ^= h >> np.uint32(13)
        h *= np.uint32(0xC2B2AE35)
        h ^= h >> np.uint32(16)
    return h


class Hash32(HashRouter):
    """Device-compatible router: fmix32 then mod N_D. Keys must fit uint32."""

    def __init__(self, n_dest: int, seed: int = 0):
        if n_dest <= 0:
            raise ValueError("n_dest must be positive")
        self.n_dest = int(n_dest)
        self.seed = int(seed)

    def __call__(self, keys: np.ndarray) -> np.ndarray:
        keys = np.asarray(keys).astype(np.int64, copy=False)
        h = fmix32((keys & 0xFFFFFFFF).astype(np.uint32), self.seed)
        return (h % np.uint32(self.n_dest)).astype(np.int64)

    def with_n_dest(self, n_dest: int) -> "Hash32":
        return Hash32(n_dest, self.seed)


class ModHash(HashRouter):
    def __init__(self, n_dest: int, seed: int = 0):
        if n_dest <= 0:
            raise ValueError("n_dest must be positive")
        self.n_dest = int(n_dest)
        self.seed = int(seed)

    def __call__(self, keys: np.ndarray) -> np.ndarray:
        keys = np.asarray(keys).astype(np.int64, copy=False)
        h = splitmix64(keys.view(_U64) ^ _U64(self.seed & 0xFFFFFFFFFFFFFFFF))
        return (h % _U64(self.n_dest)).astype(np.int64)

    def with_n_dest(self, n_dest: int) -> "ModHash":
        return ModHash(n_dest, self.seed)


class ExplicitHash(HashRouter):
    """Fixed key->dest mapping (tests / paper worked examples). Keys outside
    the mapping fall back to ModHash."""

    def __init__(self, mapping: dict, n_dest: int, seed: int = 0):
        self.n_dest = int(n_dest)
        self.mapping = dict(mapping)
        self._fallback = ModHash(n_dest, seed)
        self.seed = seed

    def __call__(self, keys: np.ndarray) -> np.ndarray:
        keys = np.asarray(keys).astype(np.int64, copy=False)
        out = self._fallback(keys)
        for i, k in enumerate(keys.ravel()):
            if int(k) in self.mapping:
                out.ravel()[i] = self.mapping[int(k)]
        return out

    def with_n_dest(self, n_dest: int) -> "ExplicitHash":
        return ExplicitHash(self.mapping, n_dest, self.seed)


class ConsistentHash(HashRouter):
    """Hash ring with ``vnodes`` virtual nodes per destination."""

    def __init__(self, n_dest: int, vnodes: int = 64, seed: int = 0):
        if n_dest <= 0:
            raise ValueError("n_dest must be positive")
        self.n_dest = int(n_dest)
        self.vnodes = int(vnodes)
        self.seed = int(seed)
        ids = np.arange(n_dest * vnodes, dtype=np.int64)
        # ring position of virtual node j of dest d: mix(d * vnodes + j, seed+1)
        ring = splitmix64(ids.view(_U64) ^ _U64((seed + 1) & 0xFFFFFFFFFFFFFFFF))
        order = np.argsort(ring)
        self._ring = ring[order]
        self._ring_dest = (ids[order] // vnodes).astype(np.int64)

    def __call__(self, keys: np.ndarray) -> np.ndarray:
        keys = np.asarray(keys).astype(np.int64, copy=False)
        h = splitmix64(keys.view(_U64) ^ _U64(self.seed & 0xFFFFFFFFFFFFFFFF))
        pos = np.searchsorted(self._ring, h, side="left")
        pos = np.where(pos == len(self._ring), 0, pos)  # wrap around the ring
        return self._ring_dest[pos]

    def with_n_dest(self, n_dest: int) -> "ConsistentHash":
        return ConsistentHash(n_dest, self.vnodes, self.seed)
