"""PKG baseline (Nasir et al., ICDE'15 [21]) — split-key partial key grouping.

Power-of-two-choices: every key has two candidate destinations h1(k), h2(k);
each *tuple* is routed to whichever of the two currently has less load. This
splits a key's tuples across two workers, so stateful key semantics require a
downstream merge operator (paper Fig. 2) — we surface that as ``merge_cost``
so throughput simulations can charge for it. PKG performs no migration.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .hashing import splitmix64
from .types import KeyStats

_U64 = np.uint64


@dataclasses.dataclass
class PKGResult:
    loads: np.ndarray            # per-dest tuple-weighted load
    split_keys: int              # keys whose tuples landed on both choices
    merge_cost: float            # extra work: one merge per split key per interval


def pkg_route(keys: np.ndarray, weights: np.ndarray, n_dest: int,
              seed: int = 0) -> PKGResult:
    """Greedy per-tuple two-choice routing over a tuple stream.

    ``keys``/``weights`` are per-tuple (a key id repeats g(k) times, or is
    pre-aggregated with weights = per-chunk cost). Sequential by construction
    (each choice depends on current loads), mirroring the real algorithm.
    """
    keys = np.asarray(keys, dtype=np.int64)
    h1 = (splitmix64(keys.view(_U64) ^ _U64(seed)) % _U64(n_dest)).astype(np.int64)
    h2 = (splitmix64(keys.view(_U64) ^ _U64(seed + 0x5BD1E995)) % _U64(n_dest)).astype(np.int64)
    loads = np.zeros((n_dest,), dtype=np.float64)
    used = {}
    for k, w, a, b in zip(keys, weights, h1, h2):
        d = int(a) if loads[a] <= loads[b] else int(b)
        loads[d] += float(w)
        s = used.setdefault(int(k), set())
        s.add(d)
    split = sum(1 for s in used.values() if len(s) > 1)
    return PKGResult(loads=loads, split_keys=split, merge_cost=float(split))


def pkg_route_stats(stats: KeyStats, n_dest: int, chunks: int = 8,
                    seed: int = 0) -> PKGResult:
    """Route a KeyStats interval by splitting each key's cost into ``chunks``
    sub-tuples (PKG's granularity advantage comes precisely from splitting)."""
    reps = np.repeat(stats.keys, chunks)
    w = np.repeat(stats.cost / chunks, chunks)
    return pkg_route(reps, w, n_dest, seed=seed)
