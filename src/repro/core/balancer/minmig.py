"""MinMig (paper Alg. 3): no cleaning, psi = largest gamma(k,w) = c^beta / S first."""

from __future__ import annotations

import time

from .phased import finish, run_phases
from .types import Assignment, BalanceConfig, KeyStats, RebalanceResult


def minmig(stats: KeyStats, assignment: Assignment,
           config: BalanceConfig) -> RebalanceResult:
    t0 = time.perf_counter()
    ws = run_phases(stats, assignment, config, psi=stats.gamma(config.beta),
                    clean_idxs=None)                  # Phase I: do nothing
    return finish(ws, assignment, config, t0)
