"""MinMig (paper Alg. 3): no cleaning, psi = largest gamma(k,w) = c^beta / S first."""

from __future__ import annotations

import time

from .llfd import PlannerContext
from .phased import finish, run_phases
from .types import Assignment, BalanceConfig, KeyStats, RebalanceResult


def minmig(stats: KeyStats, assignment: Assignment,
           config: BalanceConfig) -> RebalanceResult:
    t0 = time.perf_counter()
    ctx = PlannerContext(stats, assignment, config,
                         psi=stats.gamma(config.beta))
    ws = run_phases(stats, assignment, config, clean_idxs=None, ctx=ctx)
    return finish(ws, assignment, config, t0)
