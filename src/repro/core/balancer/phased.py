"""Shared Phase I/II/III driver for MinTable, MinMig and Mixed (paper Sec. III).

Each algorithm is a different Phase-I cleaning policy + psi criterion feeding
the same LLFD Phase III; this module owns the plumbing and result assembly.
"""

from __future__ import annotations

import time
from typing import Optional

import numpy as np

from . import metrics
from .llfd import Workspace, llfd
from .types import Assignment, BalanceConfig, KeyStats, RebalanceResult


def run_phases(stats: KeyStats, assignment: Assignment, config: BalanceConfig,
               *, psi: Optional[np.ndarray] = None,
               clean_idxs: Optional[np.ndarray] = None) -> Workspace:
    """Phase I (move back ``clean_idxs``) -> Phase II -> Phase III (LLFD)."""
    ws = Workspace(stats, assignment, config, psi=psi)
    if clean_idxs is not None:
        for idx in np.asarray(clean_idxs, dtype=np.int64):
            ws.move_back(int(idx))
    ws.prepare()
    llfd(ws)
    return ws


def finish(ws: Workspace, assignment: Assignment, config: BalanceConfig,
           t0: float, **meta: float) -> RebalanceResult:
    table = ws.result_table()
    new = Assignment(assignment.hash_router, table)
    moved = ws.moved_mask()
    th = metrics.theta(ws.loads)
    return RebalanceResult(
        assignment=new,
        moved_keys=ws.stats.keys[moved],
        migration_cost=float(np.sum(ws.mem[moved])),
        loads=ws.loads.copy(),
        table_size=len(table),
        theta=th,
        feasible_balance=th <= config.theta_max + 1e-9,
        feasible_table=len(table) <= config.table_max,
        plan_time_s=time.perf_counter() - t0,
        meta=dict(meta),
    )


def table_key_indices(stats: KeyStats, assignment: Assignment) -> np.ndarray:
    """Indices (into stats arrays) of keys that currently sit in the table A."""
    if not assignment.table:
        return np.zeros((0,), dtype=np.int64)
    tkeys = np.fromiter(assignment.table.keys(), dtype=np.int64,
                        count=len(assignment.table))
    return np.flatnonzero(np.isin(stats.keys, tkeys))
