"""Shared Phase I/II/III driver for MinTable, MinMig and Mixed (paper Sec. III).

Each algorithm is a different Phase-I cleaning policy + psi criterion feeding
the same LLFD Phase III; this module owns the plumbing and result assembly.
Algorithms that run several trials (Mixed's n-escalation) build one
:class:`PlannerContext` and clone checkpoints instead of calling
:func:`run_phases` repeatedly — see ``mixed.py``.
"""

from __future__ import annotations

import time
from typing import Optional

import numpy as np

from . import metrics
from .llfd import PlannerContext, Workspace, llfd
from .types import Assignment, BalanceConfig, KeyStats, RebalanceResult


def run_phases(stats: KeyStats, assignment: Assignment, config: BalanceConfig,
               *, psi: Optional[np.ndarray] = None,
               clean_idxs: Optional[np.ndarray] = None,
               ctx: Optional[PlannerContext] = None) -> Workspace:
    """Phase I (move back ``clean_idxs``) -> Phase II -> Phase III (LLFD)."""
    if ctx is None:
        ctx = PlannerContext(stats, assignment, config, psi=psi)
    ws = Workspace(ctx=ctx)
    if clean_idxs is not None:
        ws.move_back_many(np.asarray(clean_idxs, dtype=np.int64))
    ws.prepare()
    llfd(ws)
    return ws


def finish(ws, assignment: Assignment, config: BalanceConfig,
           t0: float, **meta: float) -> RebalanceResult:
    """Assemble a :class:`RebalanceResult` from a drained workspace.

    Loads are recomputed canonically (one segment-sum over the final
    assignment) rather than read from the workspace's incrementally
    maintained estimate, so the array-native planner and the scalar oracle
    report bit-identical loads/theta regardless of their internal float
    accumulation order. Works for both Workspace implementations.
    ``loads_for`` folds in any frozen tail base loads (sketch-mode stats),
    so the reported loads/theta cover the whole stream, not just the head.
    """
    table = ws.result_table()
    new = Assignment(assignment.hash_router, table)
    moved = ws.moved_mask()
    loads = metrics.loads_for(ws.stats, ws.assign, ws.n_dest)
    th = metrics.theta(loads)
    return RebalanceResult(
        assignment=new,
        moved_keys=ws.stats.keys[moved],
        migration_cost=float(np.sum(ws.mem[moved])),
        loads=loads,
        table_size=len(table),
        theta=th,
        feasible_balance=th <= config.theta_max + 1e-9,
        feasible_table=len(table) <= config.table_max,
        plan_time_s=time.perf_counter() - t0,
        meta=dict(meta),
    )


def table_key_indices(stats: KeyStats, assignment: Assignment) -> np.ndarray:
    """Indices (into stats arrays) of keys that currently sit in the table A.

    Sorted-table binary search — O(K log A) instead of ``np.isin``'s
    O((K+A) log (K+A)) — computed once per planner call (Mixed shares the
    result across its trials via ``PlannerContext``).
    """
    if not assignment.table:
        return np.zeros((0,), dtype=np.int64)
    tkeys = np.fromiter(assignment.table.keys(), dtype=np.int64,
                        count=len(assignment.table))
    tkeys.sort()
    pos = np.clip(np.searchsorted(tkeys, stats.keys), 0, len(tkeys) - 1)
    return np.flatnonzero(tkeys[pos] == stats.keys)
