"""Reference (per-key Python) planner — the parity oracle for ``llfd.py``.

This module preserves the original scalar implementation of the paper's
Alg. 1/4 planner: a :class:`ReferenceWorkspace` over Python sets and heaps,
``sorted(..., key=lambda)`` psi orders, and a Mixed trial loop that rebuilds
the workspace from scratch for every ``n``-escalation step.

The production planner (:mod:`repro.core.balancer.llfd`) is array-native and
must produce *bit-identical* plans (routing table, moved keys, loads, theta)
in its default exact mode; ``tests/test_planner_parity.py`` proves that over
randomized skewed workloads and ``benchmarks/planner_scaling.py`` uses this
module as the timing baseline. Mirrors the engine-layer pattern of PR 1,
where ``KeyedStage(vectorized=False)`` is the per-tuple oracle for the
vectorized dispatch path.

Do not optimize this module: being slow-and-obvious is its job.
"""

from __future__ import annotations

import heapq
import time
from typing import Iterable, List, Optional, Set

import numpy as np

from .types import Assignment, BalanceConfig, KeyStats, RebalanceResult

IN_CANDIDATES = -1


class ReferenceWorkspace:
    """Mutable rebalance state over key indices 0..K-1 (scalar structures).

    ``assign[i]`` is the working destination of key index i, or
    ``IN_CANDIDATES`` while the key sits in the candidate set C.
    """

    def __init__(self, stats: KeyStats, assignment: Assignment, config: BalanceConfig,
                 psi: Optional[np.ndarray] = None):
        self.stats = stats
        self.config = config
        self.n_dest = assignment.n_dest
        self.hash_dest = assignment.hash_router(stats.keys)      # h(k) per index
        self.orig_dest = assignment.dest(stats.keys)             # F(k) per index
        self.assign = self.orig_dest.copy()                      # working F'(k)
        self.cost = stats.cost
        self.mem = stats.mem
        # psi: priority used for Phase II selection and Adjust's E (higher first)
        self.psi = self.cost if psi is None else np.asarray(psi, dtype=np.float64)
        self.loads = np.bincount(self.assign, weights=self.cost,
                                 minlength=self.n_dest).astype(np.float64)
        self.mean_load = float(np.sum(self.cost)) / self.n_dest
        self.dest_keys: List[Set[int]] = [set() for _ in range(self.n_dest)]
        for i, d in enumerate(self.assign):
            self.dest_keys[int(d)].add(i)
        self.candidates: List[tuple] = []   # max-heap of (-cost, idx)

    # -- candidate set C ----------------------------------------------------
    def disassociate(self, idx: int) -> None:
        d = int(self.assign[idx])
        if d == IN_CANDIDATES:
            return
        self.dest_keys[d].discard(idx)
        self.loads[d] -= self.cost[idx]
        self.assign[idx] = IN_CANDIDATES
        heapq.heappush(self.candidates, (-float(self.cost[idx]), int(idx)))

    def place(self, idx: int, d: int) -> None:
        self.assign[idx] = d
        self.dest_keys[d].add(idx)
        self.loads[d] += self.cost[idx]

    def move_back(self, idx: int) -> None:
        """Phase-I style 'virtual' move of a key to its hash destination."""
        d_old = int(self.assign[idx])
        d_new = int(self.hash_dest[idx])
        if d_old == d_new:
            return
        if d_old != IN_CANDIDATES:
            self.dest_keys[d_old].discard(idx)
            self.loads[d_old] -= self.cost[idx]
        self.place(idx, d_new)

    # -- Phase II -----------------------------------------------------------
    def prepare(self) -> None:
        """Disassociate keys from every overloaded instance by psi order."""
        l_max = self.config.l_max(self.mean_load)
        for d in range(self.n_dest):
            if self.loads[d] <= l_max:
                continue
            members = sorted(self.dest_keys[d],
                             key=lambda i: (-self.psi[i], i))
            for idx in members:
                if self.loads[d] <= l_max:
                    break
                self.disassociate(idx)

    # -- derived outputs ----------------------------------------------------
    def result_table(self) -> dict:
        """A' = {key id -> dest}  for keys whose working dest != hash dest."""
        diff = self.assign != self.hash_dest
        ids = self.stats.keys[diff]
        dst = self.assign[diff]
        return {int(k): int(d) for k, d in zip(ids, dst)}

    def moved_mask(self) -> np.ndarray:
        return self.assign != self.orig_dest


def _find_exchange_set(ws: ReferenceWorkspace, idx: int, d: int,
                       l_max: float) -> Optional[List[int]]:
    """Adjust's exchangeable set E (conditions (i)-(iii)), greedy in psi order."""
    c_k = ws.cost[idx]
    cands = [j for j in ws.dest_keys[d] if ws.cost[j] < c_k]        # (i) + (ii)
    if not cands:
        return None
    cands.sort(key=lambda j: (-ws.psi[j], j))
    need = ws.loads[d] + c_k - l_max
    out: List[int] = []
    removed = 0.0
    for j in cands:
        if removed >= need:
            break
        out.append(j)
        removed += ws.cost[j]
    if removed >= need:                                              # (iii)
        return out
    return None


def _adjust(ws: ReferenceWorkspace, idx: int, d: int, l_max: float) -> bool:
    """Paper Alg. 1 lines 10-20."""
    if ws.loads[d] + ws.cost[idx] <= l_max:
        return True
    exch = _find_exchange_set(ws, idx, d, l_max)
    if exch is None:
        return False
    for j in exch:
        ws.disassociate(j)
    return True


def reference_llfd(ws: ReferenceWorkspace) -> None:
    """Phase III: drain the candidate heap (paper Alg. 1 lines 1-9)."""
    l_max = ws.config.l_max(ws.mean_load)
    events = 0
    budget = ws.config.max_llfd_events
    while ws.candidates:
        neg_c, idx = heapq.heappop(ws.candidates)
        if ws.assign[idx] != IN_CANDIDATES:     # stale heap entry
            continue
        events += 1
        placed = False
        if events <= budget:
            order = np.argsort(ws.loads, kind="stable")  # ascending load, ties by index
            for d in order:
                if _adjust(ws, idx, int(d), l_max):
                    ws.place(idx, int(d))
                    placed = True
                    break
        if not placed:
            # No destination admits this key even with exchanges — place
            # least-load, then shed strictly-lighter keys until the
            # destination carries no more than the oversized key demands
            # (Adjust with relaxed (iii)). See llfd.py for the full rationale.
            d = int(np.argmin(ws.loads))
            ws.place(idx, d)
            target = max(l_max, float(ws.cost[idx]))
            if ws.loads[d] > target:
                members = sorted(
                    (j for j in ws.dest_keys[d]
                     if j != idx and ws.cost[j] < ws.cost[idx]),
                    key=lambda j: (-ws.psi[j], j))
                for j in members:
                    if ws.loads[d] <= target:
                        break
                    ws.disassociate(j)


def seed_candidates(ws: ReferenceWorkspace, idxs: Iterable[int]) -> None:
    for idx in idxs:
        ws.disassociate(int(idx))


# -- scalar phase driver (pre-PR phased.run_phases) ---------------------------

def reference_run_phases(stats: KeyStats, assignment: Assignment,
                         config: BalanceConfig, *,
                         psi: Optional[np.ndarray] = None,
                         clean_idxs: Optional[np.ndarray] = None
                         ) -> ReferenceWorkspace:
    """Phase I (move back ``clean_idxs``) -> Phase II -> Phase III (LLFD)."""
    ws = ReferenceWorkspace(stats, assignment, config, psi=psi)
    if clean_idxs is not None:
        for idx in np.asarray(clean_idxs, dtype=np.int64):
            ws.move_back(int(idx))
    ws.prepare()
    reference_llfd(ws)
    return ws


def _ref_table_key_indices(stats: KeyStats, assignment: Assignment) -> np.ndarray:
    """Pre-PR table membership: O(K log K) np.isin, recomputed per call."""
    if not assignment.table:
        return np.zeros((0,), dtype=np.int64)
    tkeys = np.fromiter(assignment.table.keys(), dtype=np.int64,
                        count=len(assignment.table))
    return np.flatnonzero(np.isin(stats.keys, tkeys))


def _eta_order(stats: KeyStats, assignment: Assignment) -> np.ndarray:
    """Table-key indices sorted by smallest memory consumption S(k,w) first."""
    idx = _ref_table_key_indices(stats, assignment)
    return idx[np.argsort(stats.mem[idx], kind="stable")]


def _finish(ws: ReferenceWorkspace, assignment: Assignment,
            config: BalanceConfig, t0: float, **meta: float) -> RebalanceResult:
    from .phased import finish
    return finish(ws, assignment, config, t0, **meta)


def _trial(stats: KeyStats, assignment: Assignment, config: BalanceConfig,
           table_idx_by_eta: np.ndarray, n: int, psi: np.ndarray):
    clean = table_idx_by_eta[:n] if n > 0 else None
    return reference_run_phases(stats, assignment, config, psi=psi,
                                clean_idxs=clean)


def reference_mintable(stats: KeyStats, assignment: Assignment,
                       config: BalanceConfig) -> RebalanceResult:
    t0 = time.perf_counter()
    clean = _ref_table_key_indices(stats, assignment)    # Phase I: all of A
    ws = reference_run_phases(stats, assignment, config, psi=stats.cost,
                              clean_idxs=clean)
    return _finish(ws, assignment, config, t0, cleaned=float(len(clean)))


def reference_minmig(stats: KeyStats, assignment: Assignment,
                     config: BalanceConfig) -> RebalanceResult:
    t0 = time.perf_counter()
    ws = reference_run_phases(stats, assignment, config,
                              psi=stats.gamma(config.beta), clean_idxs=None)
    return _finish(ws, assignment, config, t0)


def reference_mixed(stats: KeyStats, assignment: Assignment,
                    config: BalanceConfig) -> RebalanceResult:
    """Pre-PR Mixed: full Workspace rebuild per trial, in-loop imports kept."""
    t0 = time.perf_counter()
    psi = stats.gamma(config.beta)
    by_eta = _eta_order(stats, assignment)
    n_a = len(by_eta)
    n = 0
    trials = 0
    while True:
        ws = _trial(stats, assignment, config, by_eta, n, psi)
        trials += 1
        overuse = len(ws.result_table()) - config.table_max
        from . import metrics as _m
        balance_ok = _m.theta(ws.loads) <= config.theta_max + 1e-9
        if (overuse <= 0 and balance_ok) or n >= n_a:
            break
        if overuse > 0:
            n = min(n_a, n + overuse)                # monotone bump
        else:
            # Theorem-2 escalation: residual imbalance despite a fitting table
            # means stale entries pin keys badly — clean geometrically more.
            n = min(n_a, max(n + 1, 2 * max(n, 1)))
    return _finish(ws, assignment, config, t0, trials=float(trials),
                   cleaned=float(n))


def reference_mixed_bf(stats: KeyStats, assignment: Assignment,
                       config: BalanceConfig) -> RebalanceResult:
    """Brute force over n = 0..N_A; best feasible solution by migration cost."""
    t0 = time.perf_counter()
    psi = stats.gamma(config.beta)
    by_eta = _eta_order(stats, assignment)
    best_ws, best_key, best_n = None, None, 0
    for n in range(len(by_eta) + 1):
        ws = _trial(stats, assignment, config, by_eta, n, psi)
        table_ok = len(ws.result_table()) <= config.table_max
        mig = float(np.sum(ws.mem[ws.moved_mask()]))
        key = (not table_ok, mig)                    # feasible first, then min M
        if best_key is None or key < best_key:
            best_ws, best_key, best_n = ws, key, n
    return _finish(best_ws, assignment, config, t0,
                   trials=float(len(by_eta) + 1), cleaned=float(best_n))


REFERENCE_ALGORITHMS = {
    "mintable": reference_mintable,
    "minmig": reference_minmig,
    "mixed": reference_mixed,
    "mixed_bf": reference_mixed_bf,
}
