"""Sketch-based controller statistics: O(head) plan rounds at huge K.

Beyond paper (cf. W-Choices, arXiv:1510.05714; PKG, arXiv:1510.07623): the
planners can only ever *act* on a handful of head keys (the routing table is
bounded by ``A_max``), yet exact step-1 measurement materializes O(K) arrays
per interval and every plan round pays O(K) time. W-Choices shows a
SpaceSaving-style heavy-hitter estimate is all a head/tail partitioner
needs, and PKG shows the tail is safely handled by hashing alone — which is
exactly the contract PR 2's ``head_fraction`` split already established:
head keys get exact LLFD/Adjust placement, tail keys stay frozen on their
hash destinations as per-destination base loads.

Three pieces, all array-native numpy:

* :class:`CountMinSketch` — ``depth`` seeded fmix32 hash rows (golden-ratio
  seed stride, the same :class:`~.hashing.Hash32` family as the choice
  routers, computed fused across rows), vectorized ``update`` via per-row
  ``np.bincount`` and ``np.minimum`` across rows on query. Never
  underestimates; overestimate is bounded by the colliding mass per row
  (~N/width in expectation).
* :class:`SpaceSavingTracker` — fixed-capacity heavy-hitter tracker in the
  mergeable Misra-Gries formulation (Agarwal et al., "Mergeable
  Summaries"): per-entry lower-bound counters plus a scalar ``offset`` that
  accumulates every truncation's subtraction. Guarantees (provable, and
  asserted by ``tests/test_sketch_properties.py``):

  - ``offset <= total / (capacity + 1)``;
  - ``estimate(k) - true(k) <= offset`` and ``estimate(k) >= true(k)``;
  - every key with ``true(k) > offset`` is tracked;
  - entries with ``err == 0`` (inserted before any truncation — which
    includes every key tracked since its first occurrence) carry **exact**
    cost/mem/freq side counters, bit-identical to dict counting.

* :class:`SketchStats` — the controller-facing adapter. ``update()`` folds
  streaming ``(keys, dests, cost, mem, freq)`` batches into the sketch, the
  tracker AND exact per-destination totals (O(n_dest) memory, so the
  trigger's theta stays exact — head estimate errors cancel against the
  derived tail base loads). ``snapshot(assignment)`` emits a head-only
  :class:`~.types.KeyStats` whose ``base_loads`` carry the frozen tail:
  the planners (mixed/mintable/minmig/readj) run unmodified on H keys
  instead of K.

Head membership: tracked heavy hitters ∪ every key currently in the routing
table. Table keys must stay visible even when quiet — the planner derives
the new table from the stats it sees (``Workspace.result_table``), so a
table key missing from the snapshot would silently drop its entry and
strand its state on the old task (the same invariant exact stats collection
keeps via the seen ∪ held universe). Table keys not tracked exactly get
count-min estimates capped at the tracker's ``offset`` bound (still never
an underestimate — both are upper bounds on an untracked key's true
weight — so migration-cost accounting stays conservative).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from .hashing import GOLDEN_SEED_STRIDE, fmix32
from .types import Assignment, KeyStats

Array = np.ndarray

#: channels every sketch structure tracks alongside the balance weight
_CHANNELS = ("cost", "mem", "freq")


@dataclasses.dataclass
class SketchConfig:
    """Knobs for sketch-mode stats (``RebalanceController(stats_mode="sketch")``).

    Defaults hold the whole controller state near 2 MB regardless of K:
    one depth x width float64 plane per folded channel (~1 MB) +
    capacity-bounded tracker arrays (~0.8 MB) + O(n_dest) totals.
    ``capacity`` trades plan quality for head size: the planners can only
    move head keys, so the tracked mass fraction bounds how close a sketch
    plan can get to the exact plan's balance (16384 holds the
    strategy-matrix shapes within 10% of exact at K=1e5 — see
    ``benchmarks/sketch_scaling.py``).

    ``channels`` selects which per-key quantities the count-min planes
    refine. Only the cost (balance-weight) channel by default: untracked
    keys are provably light (true weight <= tracker ``offset``), the
    snapshot caps every loose cost estimate at that bound anyway, and
    their mem/freq are derived by proxy — so extra planes buy little
    precision while doubling the dominant O(K)-per-batch fold cost.
    ``depth=2`` for the same reason: with the offset cap, deeper
    ``np.minimum`` stacks only chase collision noise that is already
    bounded. Raise both for standalone CMS use.
    """

    width: int = 1 << 16       # count-min columns per row
    depth: int = 2             # independent seeded hash rows
    capacity: int = 16384      # H: max tracked heavy hitters
    channels: Tuple[str, ...] = ("cost",)   # planes folded per batch

    def __post_init__(self) -> None:
        if self.width < 16:
            raise ValueError("sketch width must be >= 16")
        if self.depth < 1:
            raise ValueError("sketch depth must be >= 1")
        if self.capacity < 1:
            raise ValueError("sketch capacity must be >= 1")
        if not self.channels or any(ch not in _CHANNELS
                                    for ch in self.channels):
            raise ValueError(f"channels must be a subset of {_CHANNELS}")


class CountMinSketch:
    """Array-native count-min sketch over int64 key ids.

    ``depth`` rows of :class:`Hash32` (seeds spaced by the golden-ratio
    stride), one ``(depth, width)`` float64 plane per channel. ``update``
    is one ``np.bincount`` per (row, channel); ``query`` takes the
    ``np.minimum`` across rows, so estimates never undercount.
    """

    def __init__(self, width: int, depth: int, seed: int = 0,
                 channels: Tuple[str, ...] = ("cost",)):
        self.width = int(width)
        self.depth = int(depth)
        # row j hashes with seed + j * golden stride — the same Hash32
        # family as the choice routers, computed fused across rows
        self._seeds = np.array(
            [(seed + j * GOLDEN_SEED_STRIDE) & 0xFFFFFFFF
             for j in range(self.depth)], dtype=np.uint32)
        self.planes = {ch: np.zeros((self.depth, self.width)) for ch in channels}

    def _indices(self, keys: Array) -> Array:
        """(depth, n) column indices: fmix32(key ^ row_seed) % width, all
        rows in one broadcast pass (bit-mask when width is a power of two)."""
        base = (keys & 0xFFFFFFFF).astype(np.uint32)
        h = fmix32(base[None, :] ^ self._seeds[:, None])
        if self.width & (self.width - 1) == 0:
            return (h & np.uint32(self.width - 1)).astype(np.int64,
                                                          copy=False)
        return (h % np.uint32(self.width)).astype(np.int64, copy=False)

    def update(self, keys: Array, **weights: Optional[Array]) -> None:
        """Fold ``weights[channel]`` (aligned with ``keys``) into each plane."""
        keys = np.asarray(keys, dtype=np.int64)
        arrs = {ch: np.asarray(w, dtype=np.float64)
                for ch, w in weights.items() if w is not None}
        for ch in arrs:
            if ch not in self.planes:
                raise KeyError(f"unknown sketch channel {ch!r}")
        if not keys.size or not arrs:
            return
        idx = self._indices(keys)
        for j in range(self.depth):
            for ch, w in arrs.items():
                self.planes[ch][j] += np.bincount(idx[j], weights=w,
                                                  minlength=self.width)

    def query(self, keys: Array, channel: str = "cost") -> Array:
        keys = np.asarray(keys, dtype=np.int64)
        if not keys.size:
            return np.zeros(0, dtype=np.float64)
        plane = self.planes[channel]
        idx = self._indices(keys)
        est = plane[0][idx[0]]
        for j in range(1, self.depth):
            est = np.minimum(est, plane[j][idx[j]])
        return est

    def reset(self) -> None:
        for plane in self.planes.values():
            plane[:] = 0.0

    # -- serialize seam (checkpointed recovery) --------------------------------
    def state_dict(self) -> dict:
        """Plain-array snapshot of the sketch (checkpoint contract)."""
        return {"width": self.width, "depth": self.depth,
                "seeds": self._seeds.copy(),
                "planes": {ch: p.copy() for ch, p in self.planes.items()}}

    def load_state_dict(self, state: dict) -> None:
        if int(state["width"]) != self.width \
                or int(state["depth"]) != self.depth:
            raise ValueError(
                f"sketch geometry mismatch: checkpoint is "
                f"{state['depth']}x{state['width']}, live sketch is "
                f"{self.depth}x{self.width}")
        self._seeds = np.asarray(state["seeds"], dtype=np.uint32).copy()
        self.planes = {ch: np.asarray(p, dtype=np.float64).copy()
                       for ch, p in state["planes"].items()}

    @property
    def nbytes(self) -> int:
        return sum(p.nbytes for p in self.planes.values())


class SpaceSavingTracker:
    """Fixed-capacity heavy-hitter tracker with exact side counters.

    SpaceSaving semantics via the mergeable Misra-Gries formulation: batch
    ``update`` merges the (deduplicated) incoming weights into the tracked
    counters, and when the entry count exceeds ``capacity`` subtracts the
    (capacity+1)-th largest counter from all of them, dropping entries that
    hit zero and adding the subtraction to the scalar ``offset``. The
    estimate of a key's true ingested weight is ``count + offset`` for
    tracked keys and ``offset`` for the rest — an upper bound with error at
    most ``offset <= total / (capacity + 1)``.

    ``err[i]`` records the offset at the entry's (re)insertion: ``err == 0``
    proves the key has been tracked since its first occurrence, making its
    ``cost``/``mem``/``freq`` side counters exact (they accumulate raw
    batch contributions and are never decremented).
    """

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = int(capacity)
        self._keys = np.zeros(0, dtype=np.int64)       # sorted ascending
        self._count = np.zeros(0, dtype=np.float64)    # MG lower-bound counter
        self._err = np.zeros(0, dtype=np.float64)      # offset at insertion
        self._side = {ch: np.zeros(0, dtype=np.float64) for ch in _CHANNELS}
        self.offset = 0.0                              # total subtracted mass
        self.total = 0.0                               # exact ingested weight

    def __len__(self) -> int:
        return int(self._keys.size)

    @property
    def keys(self) -> Array:
        return self._keys

    @property
    def counts(self) -> Array:
        return self._count

    @property
    def err(self) -> Array:
        return self._err

    @property
    def exact_mask(self) -> Array:
        """True where the entry's side counters are provably exact."""
        return self._err == 0.0

    def side(self, channel: str) -> Array:
        return self._side[channel]

    @property
    def nbytes(self) -> int:
        return int(self._keys.nbytes + self._count.nbytes + self._err.nbytes
                   + sum(a.nbytes for a in self._side.values()))

    # -- serialize seam (checkpointed recovery) --------------------------------
    def state_dict(self) -> dict:
        """Plain-array snapshot of the tracker (checkpoint contract)."""
        return {"capacity": self.capacity, "keys": self._keys.copy(),
                "count": self._count.copy(), "err": self._err.copy(),
                "side": {ch: a.copy() for ch, a in self._side.items()},
                "offset": self.offset, "total": self.total}

    def load_state_dict(self, state: dict) -> None:
        if int(state["capacity"]) != self.capacity:
            raise ValueError(
                f"tracker capacity mismatch: checkpoint has "
                f"{state['capacity']}, live tracker has {self.capacity}")
        self._keys = np.asarray(state["keys"], dtype=np.int64).copy()
        self._count = np.asarray(state["count"], dtype=np.float64).copy()
        self._err = np.asarray(state["err"], dtype=np.float64).copy()
        self._side = {ch: np.asarray(a, dtype=np.float64).copy()
                      for ch, a in state["side"].items()}
        self.offset = float(state["offset"])
        self.total = float(state["total"])

    def estimate(self, keys: Array) -> Array:
        """Upper-bound estimate of each key's true ingested weight."""
        keys = np.asarray(keys, dtype=np.int64)
        out = np.full(keys.shape, self.offset, dtype=np.float64)
        if self._keys.size and keys.size:
            pos = np.clip(np.searchsorted(self._keys, keys), 0,
                          self._keys.size - 1)
            hit = self._keys[pos] == keys
            out[hit] = self._count[pos[hit]] + self.offset
        return out

    def update(self, keys: Array, weight: Array,
               cost: Optional[Array] = None, mem: Optional[Array] = None,
               freq: Optional[Array] = None) -> None:
        """Merge one batch. ``weight`` drives head membership (the balance
        weight — cost); the side channels ride along for tracked entries.

        Zero-weight keys never *insert* (a quiet held key's state size
        should not evict a genuine heavy hitter) but still accumulate into
        the side counters of already-tracked entries — the engine folds
        end-of-interval state sizes as a zero-cost batch.
        """
        keys = np.asarray(keys, dtype=np.int64)
        if not keys.size:
            return
        weight = np.asarray(weight, dtype=np.float64)
        if keys.size == 1 or bool(np.all(keys[1:] > keys[:-1])):
            # pre-aggregated sorted-unique batch (the controller's observe
            # path and the engine's per-interval folds): skip the O(K log K)
            # unique — the whole update is then O(K)
            uk, w = keys, weight
            sides = {ch: (np.asarray(arr, np.float64)
                          if arr is not None else None)
                     for ch, arr in (("cost", cost), ("mem", mem),
                                     ("freq", freq))}
        else:
            uk, inv = np.unique(keys, return_inverse=True)
            w = np.bincount(inv, weights=weight, minlength=uk.size)
            sides = {}
            for ch, arr in (("cost", cost), ("mem", mem), ("freq", freq)):
                sides[ch] = (np.bincount(inv,
                                         weights=np.asarray(arr, np.float64),
                                         minlength=uk.size)
                             if arr is not None else None)
        self.total += float(w.sum())

        if self._keys.size:
            pos = np.clip(np.searchsorted(self._keys, uk), 0,
                          self._keys.size - 1)
            hit = self._keys[pos] == uk
            hit_at = np.flatnonzero(hit)
        else:
            pos = np.zeros(uk.size, dtype=np.int64)
            hit = np.zeros(uk.size, dtype=bool)
            hit_at = np.zeros(0, dtype=np.int64)

        # hits are bounded by capacity: gather via index lists, not masks
        hidx = pos[hit_at]
        self._count[hidx] += w[hit_at]
        for ch, agg in sides.items():
            if agg is not None:
                self._side[ch][hidx] += agg[hit_at]

        fresh_at = np.flatnonzero(~hit & (w > 0.0))
        if not fresh_at.size:
            return      # tracked set unchanged: still sorted, still <= cap
        m = self._keys.size
        nc = np.concatenate([self._count, w[fresh_at]])
        n = nc.size
        if n > self.capacity:
            # subtract the (capacity+1)-th largest counter from everything;
            # at most `capacity` counters exceed it. Selecting the
            # threshold first (np.partition on the counters alone) keeps a
            # K-sized insert batch O(K): keys/err/side arrays are only
            # materialized for the <= capacity survivors, and only those
            # get sorted.
            t = float(np.partition(nc, n - self.capacity - 1)
                      [n - self.capacity - 1])
            keep = nc > t
            keep_old, keep_new = keep[:m], keep[m:]
            fresh_at = fresh_at[keep_new]
            nk = np.concatenate([self._keys[keep_old], uk[fresh_at]])
            nc = nc[keep] - t
            ne = np.concatenate([self._err[keep_old],
                                 np.full(fresh_at.size, self.offset)])
            ns = {ch: np.concatenate(
                     [self._side[ch][keep_old],
                      agg[fresh_at] if agg is not None
                      else np.zeros(fresh_at.size)])
                  for ch, agg in sides.items()}
            self.offset += t
        else:
            nk = np.concatenate([self._keys, uk[fresh_at]])
            ne = np.concatenate([self._err,
                                 np.full(fresh_at.size, self.offset)])
            ns = {ch: np.concatenate(
                     [self._side[ch],
                      agg[fresh_at] if agg is not None
                      else np.zeros(fresh_at.size)])
                  for ch, agg in sides.items()}
        order = np.argsort(nk, kind="stable")
        self._keys = nk[order]
        self._count = nc[order]
        self._err = ne[order]
        for ch, a in ns.items():
            self._side[ch] = a[order]


class SketchStats:
    """Streaming step-1 measurement with O(H + sketch + n_dest) memory.

    One instance per controller interval cycle: ``update()`` per batch,
    ``snapshot(assignment)`` at the interval boundary, ``end_interval()``
    to reset for the next interval (stats are per-interval quantities,
    matching exact :class:`KeyStats` semantics).

    The per-destination cost totals are accumulated *exactly* (one bincount
    per batch), so ``theta_for`` on the snapshot is exact up to clipping:
    snapshot head loads + ``base_loads`` reproduce the true per-destination
    totals because the head's estimation error cancels in the subtraction
    (``base = total(d) - head(d)``, clipped at zero when a count-min
    overestimate for an untracked table key exceeds its destination total).
    """

    def __init__(self, config: SketchConfig, n_dest: int, seed: int = 0):
        self.config = config
        self.cms = CountMinSketch(config.width, config.depth, seed=seed,
                                  channels=config.channels)
        self.tracker = SpaceSavingTracker(config.capacity)
        self._dest_cost = np.zeros(int(n_dest), dtype=np.float64)
        self._mem_total = 0.0

    def _fold_dest(self, arr: Array, dests: Array, w: Array) -> Array:
        size = max(arr.size, int(dests.max()) + 1)
        out = np.bincount(dests, weights=w, minlength=size)
        out[:arr.size] += arr
        return out

    def update(self, keys: Array, dests: Optional[Array], cost: Array,
               mem: Optional[Array] = None,
               freq: Optional[Array] = None) -> None:
        """Fold one pre-aggregated batch (duplicate keys across batches are
        fine — everything accumulates).

        ``dests`` may be None for an all-zero-cost batch (the engine's
        end-of-interval state-size fold): zero weights contribute nothing
        to the per-destination totals or the count-min planes, so both the
        destination resolve and the sketch fold are skipped.
        """
        keys = np.asarray(keys, dtype=np.int64)
        if not keys.size:
            return
        cost = np.asarray(cost, dtype=np.float64)
        live = bool(cost.any())
        if dests is None:
            if live:
                raise ValueError(
                    "dests is required for a batch with nonzero cost")
        else:
            dests = np.asarray(dests, dtype=np.int64)
            self._dest_cost = self._fold_dest(self._dest_cost, dests, cost)
        if mem is not None:
            self._mem_total += float(np.sum(mem))
        folds = {"cost": cost if live else None, "mem": mem, "freq": freq}
        fold = {ch: folds[ch] for ch in self.cms.planes}
        if any(v is not None for v in fold.values()):
            self.cms.update(keys, **fold)
        self.tracker.update(keys, cost, cost=cost, mem=mem, freq=freq)

    def head_keys(self, assignment: Assignment) -> Array:
        """Tracked heavy hitters ∪ current table keys, sorted."""
        head = self.tracker.keys
        if assignment.table:
            tkeys = np.fromiter(assignment.table.keys(), dtype=np.int64,
                                count=len(assignment.table))
            head = np.union1d(head, tkeys)
        return head

    def snapshot(self, assignment: Assignment) -> KeyStats:
        """Materialize the head-only :class:`KeyStats` (+ tail base loads)."""
        keys = self.head_keys(assignment)
        n_dest = assignment.n_dest
        cost = np.zeros(keys.size)
        mem = np.zeros(keys.size)
        freq = np.zeros(keys.size)
        tracked = np.zeros(keys.size, dtype=bool)
        tk = self.tracker.keys
        if tk.size and keys.size:
            pos = np.clip(np.searchsorted(tk, keys), 0, tk.size - 1)
            tracked = tk[pos] == keys
            tidx = pos[tracked]
            cost[tracked] = self.tracker.side("cost")[tidx]
            mem[tracked] = self.tracker.side("mem")[tidx]
            freq[tracked] = self.tracker.side("freq")[tidx]
        loose = ~tracked
        if loose.any():
            lk = keys[loose]
            # untracked keys are provably light (true weight <= offset by
            # the Misra-Gries invariant), so the count-min refinement is
            # capped there — collision noise never inflates a loose key
            # past the tracker's own bound
            lcost = self.cms.query(lk, "cost")
            lcost = np.minimum(lcost, self.tracker.offset)
            cost[loose] = lcost
            if "mem" in self.cms.planes:
                mem[loose] = self.cms.query(lk, "mem")
            else:
                # cost-proportional proxy from the exact totals; loose keys
                # carry a vanishing mass fraction, so only the order of
                # magnitude matters to the planners' migration accounting
                total = self.tracker.total
                ratio = (self._mem_total / total) if total > 0 else 0.0
                mem[loose] = lcost * ratio
            if "freq" in self.cms.planes:
                freq[loose] = self.cms.query(lk, "freq")
            else:
                freq[loose] = lcost

        dest_cost = self._sized(self._dest_cost, n_dest)
        if keys.size:
            head_per_dest = np.bincount(assignment.dest(keys), weights=cost,
                                        minlength=n_dest)[:n_dest]
            base = np.maximum(dest_cost - head_per_dest, 0.0)
        else:
            base = dest_cost
        return KeyStats(keys=keys, cost=cost, mem=mem, freq=freq,
                        base_loads=base)

    @staticmethod
    def _sized(arr: Array, n_dest: int) -> Array:
        """Pad (grow) or truncate (stale rescale snapshot; the next interval's
        ingest re-derives totals under the new fleet) to ``n_dest``."""
        if arr.size < n_dest:
            return np.concatenate([arr, np.zeros(n_dest - arr.size)])
        return arr[:n_dest].copy()

    def end_interval(self) -> None:
        self.cms.reset()
        self.tracker = SpaceSavingTracker(self.config.capacity)
        self._dest_cost[:] = 0.0
        self._mem_total = 0.0

    # -- serialize seam (checkpointed recovery) --------------------------------
    def state_dict(self) -> dict:
        """Snapshot of the full mid-interval measurement state: recovery
        restores at an interval boundary, but a crash can land after a
        partial ingest, so the planes/tracker/totals must round-trip too."""
        return {"dest_cost": self._dest_cost.copy(),
                "mem_total": self._mem_total,
                "cms": self.cms.state_dict(),
                "tracker": self.tracker.state_dict()}

    def load_state_dict(self, state: dict) -> None:
        self._dest_cost = np.asarray(state["dest_cost"],
                                     dtype=np.float64).copy()
        self._mem_total = float(state["mem_total"])
        self.cms.load_state_dict(state["cms"])
        # end_interval swaps the tracker instance, so rebuild before loading
        self.tracker = SpaceSavingTracker(int(state["tracker"]["capacity"]))
        self.tracker.load_state_dict(state["tracker"])

    @property
    def nbytes(self) -> int:
        """Resident controller-side stats memory — O(H + sketch), not O(K)."""
        return int(self.cms.nbytes + self.tracker.nbytes
                   + self._dest_cost.nbytes)
