"""Least-Load Fit Decreasing with the Adjust exchange step (paper Alg. 1).

Array-native planner core. All phase-based algorithms (MinTable / MinMig /
Mixed) share a :class:`Workspace` over key *indices* and invoke :func:`llfd`
for Phase III; :class:`PlannerContext` holds the per-call immutable
precomputation (hash/current destinations, psi ranks, head/tail split) so the
Mixed trial loop can reuse it across its n-escalation trials.

Faithfulness notes (validated against the paper's Fig. 4 worked examples in
``tests/test_balancer_paper_examples.py`` and bit-for-bit against the scalar
pre-PR implementation — kept in :mod:`repro.core.balancer.reference` — by
``tests/test_planner_parity.py``):

* the candidate set C is processed in descending order of c(k), re-evaluated
  dynamically as Adjust pushes exchanged keys back into C -> a max-heap;
* destinations are probed in ascending order of the *current estimated* load,
  ties broken by destination index (matches the k3 step of the Fig. 4 trace);
* Adjust's exchangeable set E is grown greedily in psi-order over keys
  currently on the destination with c(k') < c(k) (conditions (i)-(ii)) until
  L(d) + c(k) - sum_E c(k') <= L_max (condition (iii));
* the exchange cascade is provably finite in practice (each displaced key is
  strictly lighter than the key displacing it); a large event budget guards
  pathological inputs, falling back to plain least-load placement.

Array representation
--------------------
Psi order is computed once per planner call as a global rank permutation
(``PlannerContext.order`` / ``.rank`` — descending psi, ties by key index).
Per-destination membership is a sorted array of ranks plus a small append
buffer merged lazily on scan, so Phase II disassociation, Adjust's E and the
fallback shed are all cumsum-prefix selections instead of per-key Python
loops. Greedy-prefix decisions follow the same accumulation order as the
scalar oracle, so integer-valued workloads match bit-for-bit and continuous
ones agree unless a comparison lands within ~1 ulp of L_max (measure-zero
for randomized inputs; the parity suite runs dozens of seeds).

Head/tail split (beyond paper; cf. arXiv:1510.05714, arXiv:2308.00938)
----------------------------------------------------------------------
With ``BalanceConfig.head_fraction > 0`` only keys whose cost is at least
``head_fraction * mean_load`` — plus every key currently in the routing
table — enter the exact LLFD/Adjust machinery. The remaining tail keys stay
frozen on their hash destinations and contribute fixed base loads, so at
million-key domains the planner's working set is the heavy head only. The
default (0.0) keeps every key exact and preserves pre-PR behavior.
"""

from __future__ import annotations

import heapq
from typing import List, Optional

import numpy as np

from . import metrics
from .types import Assignment, BalanceConfig, KeyStats

IN_CANDIDATES = -1


class PlannerContext:
    """Immutable per-call precomputation shared by every Mixed trial.

    Building this once per planner call (instead of once per trial) hoists
    the two O(K log A) ``Assignment`` lookups, the psi argsort and the
    head/tail split out of the n-escalation loop.
    """

    def __init__(self, stats: KeyStats, assignment: Assignment,
                 config: BalanceConfig, psi: Optional[np.ndarray] = None):
        self.stats = stats
        self.config = config
        self.n_dest = assignment.n_dest
        self.hash_dest = assignment.hash_router(stats.keys)      # h(k) per index
        self.orig_dest = assignment.dest(stats.keys)             # F(k) per index
        self.cost = stats.cost
        self.mem = stats.mem
        # psi: priority used for Phase II selection and Adjust's E (higher first)
        self.psi = self.cost if psi is None else np.asarray(psi, dtype=np.float64)
        # sketch-mode stats carry frozen tail cost as per-dest base loads
        # (see balancer/sketch.py); they count toward the mean and sit under
        # every destination's working load but never enter the candidate set.
        self.base = metrics.base_for(stats, self.n_dest)
        base_sum = 0.0 if self.base is None else float(self.base.sum())
        self.mean_load = (float(np.sum(self.cost)) + base_sum) / self.n_dest
        k = stats.num_keys
        frac = config.head_fraction
        if frac > 0.0:
            # table keys are always head: Phase I / eta ordering needs them
            head_mask = ((self.cost >= frac * self.mean_load)
                         | (self.orig_dest != self.hash_dest))
            self.head = np.flatnonzero(head_mask).astype(np.int64)
        else:
            self.head = np.arange(k, dtype=np.int64)
        # global psi order over head keys: rank r -> key index `order[r]`,
        # descending psi, ties by ascending key index (a stable argsort of
        # -psi breaks ties by position, which is exactly the oracle's
        # (-psi, index) sort key since `head` is ascending)
        hpsi = self.psi[self.head]
        self.order = self.head[np.argsort(-hpsi, kind="stable")]
        self.rank = np.full(k, -1, dtype=np.int64)
        self.rank[self.order] = np.arange(self.order.size, dtype=np.int64)

    @property
    def is_exact(self) -> bool:
        return self.head.size == self.stats.num_keys


class Workspace:
    """Mutable rebalance state over key indices 0..K-1, flat numpy arrays.

    ``assign[i]`` is the working destination of key index i, or
    ``IN_CANDIDATES`` while the key sits in the candidate set C. Tail keys
    (head/tail mode) keep their hash destination for the whole solve.
    """

    def __init__(self, stats: Optional[KeyStats] = None,
                 assignment: Optional[Assignment] = None,
                 config: Optional[BalanceConfig] = None,
                 psi: Optional[np.ndarray] = None, *,
                 ctx: Optional[PlannerContext] = None):
        if ctx is None:
            ctx = PlannerContext(stats, assignment, config, psi=psi)
        self.ctx = ctx
        self.assign = ctx.orig_dest.copy()                       # working F'(k)
        self.loads = np.bincount(self.assign, weights=ctx.cost,
                                 minlength=ctx.n_dest).astype(np.float64)
        if ctx.base is not None:
            self.loads += ctx.base
        self.candidates: List[tuple] = []   # max-heap of (-cost, idx)
        # per-dest member ranks (sorted asc) + append buffers, built lazily:
        # Phase I mutates `assign` wholesale, so membership is materialized
        # only when Phase II / III first needs psi-ordered scans.
        self._members: Optional[List[np.ndarray]] = None
        self._extra: Optional[List[List[int]]] = None

    # -- context aliases (same attribute surface as the scalar oracle) -------
    @property
    def stats(self) -> KeyStats:
        return self.ctx.stats

    @property
    def config(self) -> BalanceConfig:
        return self.ctx.config

    @property
    def n_dest(self) -> int:
        return self.ctx.n_dest

    @property
    def hash_dest(self) -> np.ndarray:
        return self.ctx.hash_dest

    @property
    def orig_dest(self) -> np.ndarray:
        return self.ctx.orig_dest

    @property
    def cost(self) -> np.ndarray:
        return self.ctx.cost

    @property
    def mem(self) -> np.ndarray:
        return self.ctx.mem

    @property
    def psi(self) -> np.ndarray:
        return self.ctx.psi

    @property
    def mean_load(self) -> float:
        return self.ctx.mean_load

    # -- trial reuse ---------------------------------------------------------
    def clone(self) -> "Workspace":
        """O(K) array-copy snapshot; shares the immutable context."""
        ws = object.__new__(Workspace)
        ws.ctx = self.ctx
        ws.assign = self.assign.copy()
        ws.loads = self.loads.copy()
        ws.candidates = list(self.candidates)
        ws._members = None if self._members is None else list(self._members)
        ws._extra = (None if self._extra is None
                     else [list(e) for e in self._extra])
        return ws

    # -- Phase I -------------------------------------------------------------
    def move_back_many(self, idxs: np.ndarray) -> None:
        """Vectorized Phase-I 'virtual' move of keys to their hash dests."""
        idxs = np.asarray(idxs, dtype=np.int64)
        if not idxs.size:
            return
        if self._members is not None:
            for idx in idxs:                       # post-prepare: keep members
                self.move_back(int(idx))
            return
        self.assign[idxs] = self.ctx.hash_dest[idxs]
        self.loads = np.bincount(self.assign[self.assign >= 0],
                                 weights=self.ctx.cost[self.assign >= 0],
                                 minlength=self.ctx.n_dest).astype(np.float64)
        if self.ctx.base is not None:
            self.loads += self.ctx.base

    def move_back(self, idx: int) -> None:
        """Scalar Phase-I move (kept for API parity with the oracle)."""
        d_old = int(self.assign[idx])
        d_new = int(self.ctx.hash_dest[idx])
        if d_old == d_new:
            return
        if d_old != IN_CANDIDATES:
            self.loads[d_old] -= self.ctx.cost[idx]
            self._drop_member(d_old, idx)
        self.place(idx, d_new)

    # -- candidate set C ----------------------------------------------------
    def disassociate(self, idx: int) -> None:
        if self.ctx.rank[idx] < 0:
            raise ValueError(
                f"key index {idx} is a frozen tail key (head_fraction split); "
                "only head keys may enter the candidate set")
        d = int(self.assign[idx])
        if d == IN_CANDIDATES:
            return
        self.loads[d] -= self.ctx.cost[idx]
        self.assign[idx] = IN_CANDIDATES
        self._drop_member(d, idx)
        heapq.heappush(self.candidates, (-float(self.ctx.cost[idx]), int(idx)))

    def place(self, idx: int, d: int) -> None:
        self.assign[idx] = d
        self.loads[d] += self.ctx.cost[idx]
        if self._members is not None:
            r = int(self.ctx.rank[idx])
            if r < 0:
                raise ValueError(
                    f"key index {idx} is a frozen tail key (head_fraction "
                    "split); it cannot join per-destination membership")
            self._extra[d].append(r)

    # -- per-dest membership in psi order ------------------------------------
    def _ensure_members(self) -> None:
        if self._members is not None:
            return
        # dest per rank position: a stable argsort of it groups ranks by
        # destination with ranks ascending inside each group, and the
        # permutation values *are* the member ranks. IN_CANDIDATES entries
        # sort first and fall outside the [0, n_dest) segment bounds.
        dest_by_rank = self.assign[self.ctx.order]
        perm = np.argsort(dest_by_rank, kind="stable")
        seg_dest = dest_by_rank[perm]
        starts = np.searchsorted(seg_dest, np.arange(self.ctx.n_dest + 1))
        self._members = [perm[starts[d]:starts[d + 1]]
                         for d in range(self.ctx.n_dest)]
        self._extra = [[] for _ in range(self.ctx.n_dest)]

    def _members_sorted(self, d: int) -> np.ndarray:
        """Member ranks of ``d``, ascending (= psi desc, ties by key index)."""
        ex = self._extra[d]
        if ex:
            m = np.sort(np.concatenate(
                [self._members[d], np.asarray(ex, dtype=np.int64)]))
            self._members[d] = m
            self._extra[d] = []
        return self._members[d]

    def _drop_member(self, d: int, idx: int) -> None:
        if self._members is None:
            return
        r = self.ctx.rank[idx]
        m = self._members_sorted(d)
        self._members[d] = m[m != r]

    def _remove_prefix(self, d: int, m: np.ndarray, sel: np.ndarray,
                       sel_cost: np.ndarray, sel_keys: np.ndarray) -> None:
        """Disassociate ``sel`` positions of ``m`` from d (heap + loads)."""
        self.assign[sel_keys] = IN_CANDIDATES
        # sequential load updates in psi order: same accumulation as the oracle
        for c, k in zip(sel_cost.tolist(), sel_keys.tolist()):
            self.loads[d] -= c
            heapq.heappush(self.candidates, (-c, k))
        keep = np.ones(m.size, dtype=bool)
        keep[sel] = False
        self._members[d] = m[keep]

    # -- Phase II -----------------------------------------------------------
    def prepare(self) -> None:
        """Disassociate keys from every overloaded instance by psi order.

        Per overloaded destination, the scalar loop removes the greedy prefix
        of its psi-ordered members until L(d) <= L_max; a cumsum over the
        member costs selects exactly that prefix in one shot.
        """
        l_max = self.ctx.config.l_max(self.ctx.mean_load)
        self._ensure_members()
        for d in range(self.ctx.n_dest):
            if self.loads[d] <= l_max:
                continue
            m = self._members_sorted(d)
            if not m.size:
                continue
            mk = self.ctx.order[m]
            mc = self.ctx.cost[mk]
            cums = np.cumsum(mc)
            # key j is shed iff the load before removing it still exceeds L_max
            nrm = int(np.count_nonzero(self.loads[d] - (cums - mc) > l_max))
            if nrm == 0:
                continue
            self._remove_prefix(d, m, np.arange(nrm), mc[:nrm], mk[:nrm])

    # -- Phase III helpers ---------------------------------------------------
    def _try_exchange(self, idx: int, d: int, l_max: float) -> bool:
        """Adjust's E (conditions (i)-(iii)): cumsum-prefix over strictly
        lighter members of ``d`` in psi order; disassociate it on success."""
        c_k = self.ctx.cost[idx]
        m = self._members_sorted(d)
        if not m.size:
            return False
        mk = self.ctx.order[m]
        mc = self.ctx.cost[mk]
        epos = np.flatnonzero(mc < c_k)                          # (i) + (ii)
        if not epos.size:
            return False
        ec = mc[epos]
        cums = np.cumsum(ec)
        need = self.loads[d] + c_k - l_max
        p = int(np.searchsorted(cums, need, side="left"))
        if p >= ec.size:                                         # (iii) fails
            return False
        sel = epos[:p + 1]
        self._remove_prefix(d, m, sel, ec[:p + 1], mk[sel])
        return True

    def _fallback_place(self, idx: int, l_max: float) -> None:
        """Oversized-key fallback: least-load placement + relaxed-(iii) shed.

        The paper's analysis assumes c(k1) < mean so this case is outside
        Theorems 1/2; in production it happens (one key heavier than L_max,
        e.g. one expert hotter than a whole shard's budget). Place least-load,
        then shed strictly-lighter keys until the destination carries no more
        than the oversized key demands.
        """
        d = int(np.argmin(self.loads))
        self.place(idx, d)
        target = max(l_max, float(self.ctx.cost[idx]))
        if self.loads[d] <= target:
            return
        m = self._members_sorted(d)
        mk = self.ctx.order[m]
        mc = self.ctx.cost[mk]
        epos = np.flatnonzero(mc < self.ctx.cost[idx])    # idx itself excluded
        if not epos.size:
            return
        ec = mc[epos]
        cums = np.cumsum(ec)
        nrm = int(np.count_nonzero(self.loads[d] - (cums - ec) > target))
        if nrm == 0:
            return
        sel = epos[:nrm]
        self._remove_prefix(d, m, sel, ec[:nrm], mk[sel])

    # -- derived outputs ----------------------------------------------------
    def working_table_size(self) -> int:
        """|A'| of the working assignment (valid once C is drained)."""
        return int(np.count_nonzero(self.assign != self.ctx.hash_dest))

    def result_table(self) -> dict:
        """A' = {key id -> dest}  for keys whose working dest != hash dest."""
        diff = self.assign != self.ctx.hash_dest
        ids = self.ctx.stats.keys[diff]
        dst = self.assign[diff]
        return {int(k): int(d) for k, d in zip(ids, dst)}

    def moved_mask(self) -> np.ndarray:
        return self.assign != self.ctx.orig_dest


def llfd(ws: Workspace) -> None:
    """Phase III: drain the candidate heap (paper Alg. 1 lines 1-9).

    Mutates ``ws`` in place; the routing table is derived afterwards via
    ``ws.result_table()``. The heap pop order (cost desc, ties by key index)
    and the least-load destination probe (ties by destination index) match
    the scalar oracle exactly.
    """
    ws._ensure_members()
    l_max = ws.ctx.config.l_max(ws.ctx.mean_load)
    events = 0
    budget = ws.ctx.config.max_llfd_events
    heap = ws.candidates
    assign = ws.assign
    cost = ws.ctx.cost
    while heap:
        neg_c, idx = heapq.heappop(heap)
        if assign[idx] != IN_CANDIDATES:     # stale heap entry
            continue
        events += 1
        placed = False
        if events <= budget:
            c_k = cost[idx]
            order = np.argsort(ws.loads, kind="stable")  # asc load, ties by d
            for d in order:
                d = int(d)
                if (ws.loads[d] + c_k <= l_max
                        or ws._try_exchange(idx, d, l_max)):
                    ws.place(idx, d)
                    placed = True
                    break
        if not placed:
            ws._fallback_place(idx, l_max)
