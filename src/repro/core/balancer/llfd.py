"""Least-Load Fit Decreasing with the Adjust exchange step (paper Alg. 1).

All phase-based algorithms (MinTable / MinMig / Mixed) share a mutable
:class:`Workspace` over key *indices* and invoke :func:`llfd` for Phase III.

Faithfulness notes (validated against the paper's Fig. 4 worked examples in
``tests/test_balancer_paper_examples.py``):

* the candidate set C is processed in descending order of c(k), re-evaluated
  dynamically as Adjust pushes exchanged keys back into C -> a max-heap;
* destinations are probed in ascending order of the *current estimated* load,
  ties broken by destination index (matches the k3 step of the Fig. 4 trace);
* Adjust's exchangeable set E is grown greedily in psi-order over keys
  currently on the destination with c(k') < c(k) (conditions (i)-(ii)) until
  L(d) + c(k) - sum_E c(k') <= L_max (condition (iii));
* the exchange cascade is provably finite in practice (each displaced key is
  strictly lighter than the key displacing it); a large event budget guards
  pathological inputs, falling back to plain least-load placement.
"""

from __future__ import annotations

import heapq
from typing import Iterable, List, Optional, Set

import numpy as np

from .types import Assignment, BalanceConfig, KeyStats

IN_CANDIDATES = -1


class Workspace:
    """Mutable rebalance state over key indices 0..K-1.

    ``assign[i]`` is the working destination of key index i, or
    ``IN_CANDIDATES`` while the key sits in the candidate set C.
    """

    def __init__(self, stats: KeyStats, assignment: Assignment, config: BalanceConfig,
                 psi: Optional[np.ndarray] = None):
        self.stats = stats
        self.config = config
        self.n_dest = assignment.n_dest
        self.hash_dest = assignment.hash_router(stats.keys)      # h(k) per index
        self.orig_dest = assignment.dest(stats.keys)             # F(k) per index
        self.assign = self.orig_dest.copy()                      # working F'(k)
        self.cost = stats.cost
        self.mem = stats.mem
        # psi: priority used for Phase II selection and Adjust's E (higher first)
        self.psi = self.cost if psi is None else np.asarray(psi, dtype=np.float64)
        self.loads = np.bincount(self.assign, weights=self.cost,
                                 minlength=self.n_dest).astype(np.float64)
        self.mean_load = float(np.sum(self.cost)) / self.n_dest
        self.dest_keys: List[Set[int]] = [set() for _ in range(self.n_dest)]
        for i, d in enumerate(self.assign):
            self.dest_keys[int(d)].add(i)
        self.candidates: List[tuple] = []   # max-heap of (-cost, idx)

    # -- candidate set C ----------------------------------------------------
    def disassociate(self, idx: int) -> None:
        d = int(self.assign[idx])
        if d == IN_CANDIDATES:
            return
        self.dest_keys[d].discard(idx)
        self.loads[d] -= self.cost[idx]
        self.assign[idx] = IN_CANDIDATES
        heapq.heappush(self.candidates, (-float(self.cost[idx]), int(idx)))

    def place(self, idx: int, d: int) -> None:
        self.assign[idx] = d
        self.dest_keys[d].add(idx)
        self.loads[d] += self.cost[idx]

    def move_back(self, idx: int) -> None:
        """Phase-I style 'virtual' move of a key to its hash destination."""
        d_old = int(self.assign[idx])
        d_new = int(self.hash_dest[idx])
        if d_old == d_new:
            return
        if d_old != IN_CANDIDATES:
            self.dest_keys[d_old].discard(idx)
            self.loads[d_old] -= self.cost[idx]
        self.place(idx, d_new)

    # -- Phase II -----------------------------------------------------------
    def prepare(self) -> None:
        """Disassociate keys from every overloaded instance by psi order."""
        l_max = self.config.l_max(self.mean_load)
        for d in range(self.n_dest):
            if self.loads[d] <= l_max:
                continue
            members = sorted(self.dest_keys[d],
                             key=lambda i: (-self.psi[i], i))
            for idx in members:
                if self.loads[d] <= l_max:
                    break
                self.disassociate(idx)

    # -- derived outputs ----------------------------------------------------
    def result_table(self) -> dict:
        """A' = {key id -> dest}  for keys whose working dest != hash dest."""
        diff = self.assign != self.hash_dest
        ids = self.stats.keys[diff]
        dst = self.assign[diff]
        return {int(k): int(d) for k, d in zip(ids, dst)}

    def moved_mask(self) -> np.ndarray:
        return self.assign != self.orig_dest


def _find_exchange_set(ws: Workspace, idx: int, d: int, l_max: float) -> Optional[List[int]]:
    """Adjust's exchangeable set E (conditions (i)-(iii)), greedy in psi order."""
    c_k = ws.cost[idx]
    cands = [j for j in ws.dest_keys[d] if ws.cost[j] < c_k]        # (i) + (ii)
    if not cands:
        return None
    cands.sort(key=lambda j: (-ws.psi[j], j))
    need = ws.loads[d] + c_k - l_max
    out: List[int] = []
    removed = 0.0
    for j in cands:
        if removed >= need:
            break
        out.append(j)
        removed += ws.cost[j]
    if removed >= need:                                              # (iii)
        return out
    return None


def _adjust(ws: Workspace, idx: int, d: int, l_max: float) -> bool:
    """Paper Alg. 1 lines 10-20."""
    if ws.loads[d] + ws.cost[idx] <= l_max:
        return True
    exch = _find_exchange_set(ws, idx, d, l_max)
    if exch is None:
        return False
    for j in exch:
        ws.disassociate(j)
    return True


def llfd(ws: Workspace) -> None:
    """Phase III: drain the candidate heap (paper Alg. 1 lines 1-9).

    Mutates ``ws`` in place; the routing table is derived afterwards via
    ``ws.result_table()``.
    """
    l_max = ws.config.l_max(ws.mean_load)
    events = 0
    budget = ws.config.max_llfd_events
    while ws.candidates:
        neg_c, idx = heapq.heappop(ws.candidates)
        if ws.assign[idx] != IN_CANDIDATES:     # stale heap entry
            continue
        events += 1
        placed = False
        if events <= budget:
            order = np.argsort(ws.loads, kind="stable")  # ascending load, ties by index
            for d in order:
                if _adjust(ws, idx, int(d), l_max):
                    ws.place(idx, int(d))
                    placed = True
                    break
        if not placed:
            # No destination admits this key even with exchanges — the paper's
            # analysis assumes c(k1) < mean so this case is outside Theorems
            # 1/2; in production it happens (one key heavier than L_max, e.g.
            # one expert hotter than a whole shard's budget). Place least-load,
            # then shed strictly-lighter keys until the destination carries no
            # more than the oversized key demands (Adjust with relaxed (iii)).
            d = int(np.argmin(ws.loads))
            ws.place(idx, d)
            target = max(l_max, float(ws.cost[idx]))
            if ws.loads[d] > target:
                members = sorted(
                    (j for j in ws.dest_keys[d]
                     if j != idx and ws.cost[j] < ws.cost[idx]),
                    key=lambda j: (-ws.psi[j], j))
                for j in members:
                    if ws.loads[d] <= target:
                        break
                    ws.disassociate(j)


def seed_candidates(ws: Workspace, idxs: Iterable[int]) -> None:
    for idx in idxs:
        ws.disassociate(int(idx))
