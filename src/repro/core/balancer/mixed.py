"""Mixed (paper Alg. 4) and its brute-force variant Mixed_BF.

Phase I moves back ``n`` table keys chosen by eta = smallest S(k,w) first;
Phases II/III follow MinMig (psi = largest gamma first). ``n`` starts at 0 and
is bumped by the table overuse of the previous trial (paper line 10). We make
the bump monotone (n += overuse, capped at N_A) so the loop provably
terminates; at n = N_A the trial equals MinTable, matching the paper's
observation that Mixed degenerates to MinTable when even the minimal table
needed for balance exceeds A_max.

Incremental trial reuse: one :class:`PlannerContext` (hash/current dests, psi
ranks, eta order, table membership) is built per call, and a ``base``
workspace tracks the cumulative Phase-I state — since the cleaned set for
trial n is a *prefix* of the eta order, escalating n only moves back the
newly added keys on the checkpoint, and each trial starts from an O(K)
array-copy clone instead of a full per-key rebuild.
"""

from __future__ import annotations

import time

import numpy as np

from . import metrics
from .llfd import PlannerContext, Workspace, llfd
from .phased import finish, table_key_indices
from .types import Assignment, BalanceConfig, KeyStats, RebalanceResult


def _eta_order(stats: KeyStats, assignment: Assignment) -> np.ndarray:
    """Table-key indices sorted by smallest memory consumption S(k,w) first."""
    idx = table_key_indices(stats, assignment)
    return idx[np.argsort(stats.mem[idx], kind="stable")]


def _run_trial(base: Workspace) -> Workspace:
    ws = base.clone()
    ws.prepare()
    llfd(ws)
    return ws


def mixed(stats: KeyStats, assignment: Assignment,
          config: BalanceConfig) -> RebalanceResult:
    t0 = time.perf_counter()
    psi = stats.gamma(config.beta)
    ctx = PlannerContext(stats, assignment, config, psi=psi)
    by_eta = _eta_order(stats, assignment)
    n_a = len(by_eta)
    base = Workspace(ctx=ctx)        # checkpoint: Phase-I state, grown in place
    cleaned = 0
    n = 0
    trials = 0
    while True:
        if n > cleaned:              # Phase I delta: newly cleaned eta prefix
            base.move_back_many(by_eta[cleaned:n])
            cleaned = n
        ws = _run_trial(base)
        trials += 1
        overuse = ws.working_table_size() - config.table_max
        balance_ok = metrics.theta(ws.loads) <= config.theta_max + 1e-9
        if (overuse <= 0 and balance_ok) or n >= n_a:
            break
        if overuse > 0:
            n = min(n_a, n + overuse)                # monotone bump (module doc)
        else:
            # Theorem-2 escalation: residual imbalance despite a fitting table
            # means stale entries pin keys badly — clean geometrically more.
            n = min(n_a, max(n + 1, 2 * max(n, 1)))
    return finish(ws, assignment, config, t0, trials=float(trials),
                  cleaned=float(n))


def mixed_bf(stats: KeyStats, assignment: Assignment,
             config: BalanceConfig) -> RebalanceResult:
    """Brute force over n = 0..N_A; best feasible solution by migration cost."""
    t0 = time.perf_counter()
    psi = stats.gamma(config.beta)
    ctx = PlannerContext(stats, assignment, config, psi=psi)
    by_eta = _eta_order(stats, assignment)
    base = Workspace(ctx=ctx)
    cleaned = 0
    best_ws, best_key, best_n = None, None, 0
    for n in range(len(by_eta) + 1):
        if n > cleaned:
            base.move_back_many(by_eta[cleaned:n])
            cleaned = n
        ws = _run_trial(base)
        table_ok = ws.working_table_size() <= config.table_max
        mig = float(np.sum(ws.mem[ws.moved_mask()]))
        key = (not table_ok, mig)                    # feasible first, then min M
        if best_key is None or key < best_key:
            best_ws, best_key, best_n = ws, key, n
    return finish(best_ws, assignment, config, t0,
                  trials=float(len(by_eta) + 1), cleaned=float(best_n))
