"""Autoscaling policy loop: observed interval load -> ``scale_to`` decisions.

The paper's protocol makes elasticity cheap *mechanically* (Fig. 15: state
migrates through the same Pause -> migrate -> Resume path as a rebalance),
but deciding *when* to scale is a policy question. This module closes that
loop with three deliberately separable pieces:

* :class:`AutoscalePolicy` — a watermark controller with hysteresis. Mean
  per-task load above ``high * target_load`` for ``patience`` consecutive
  intervals proposes scale-out; below ``low * target_load``, scale-in. The
  proposal is ``ceil(total_load / target_load)`` clipped to
  ``[min_tasks, max_tasks]`` — sized from demand, not incremented blindly.
* **The migration-cost damper** — before acting, the policy prices the
  proposal with the planner's own cost model: the keys that would move are
  exactly :func:`repro.core.balancer.metrics.moved_keys` against the
  *interim* assignment (rehash to ``n'`` destinations, table entries to
  dead tasks dropped — the same first step ``RebalanceController.rescale``
  takes), and the predicted stall is their summed state bytes over the
  migration bandwidth. The action fires only when that stall pays back
  within ``payback_intervals`` of per-interval gain — the damper that keeps
  a borderline breach from thrashing the fleet.
* :class:`HeartbeatMonitor` — a stall detector over the same observability:
  a task reporting zero load for ``patience`` intervals while the stage
  moves traffic is flagged, feeding the failure path
  (:mod:`repro.streams.faults`) rather than the scaling path.

:class:`AutoscaleLoop` wires policy + monitor onto one
:class:`~repro.streams.engine.KeyedStage`. Only table-planner strategies
can autoscale — choice routers reject ``scale_to`` by design (their
per-task load estimates cannot survive a fleet resize; see
``KeyedStage.scale_to``).

Hysteresis notes: the dead band between the watermarks, breach ``patience``,
post-action ``cooldown``, and the damper are each anti-oscillation devices;
``tests/test_chaos_recovery.py`` drives drift and burst shapes from the
strategy matrix and asserts the decision sequence converges without ever
reversing itself on the next decision.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, List, Optional

import numpy as np

from repro.core.balancer import Assignment, metrics

__all__ = ["AutoscaleConfig", "AutoscaleDecision", "AutoscalePolicy",
           "HeartbeatMonitor", "AutoscaleLoop"]


@dataclasses.dataclass
class AutoscaleConfig:
    """Watermark + damper knobs for :class:`AutoscalePolicy`.

    ``target_load`` is the per-task load (cost units per interval) the fleet
    is sized for; ``high``/``low`` are the watermark multipliers bracketing
    it (the gap is the hysteresis dead band). ``patience`` is how many
    consecutive breaching intervals arm an action; ``cooldown`` how many
    intervals after an action the policy stays quiet while migration
    settles. ``payback_intervals`` bounds the damper: act only when the
    predicted migration stall amortizes within that many intervals of gain.
    """

    target_load: float
    min_tasks: int = 1
    max_tasks: int = 64
    high: float = 1.25
    low: float = 0.6
    patience: int = 2
    cooldown: int = 2
    payback_intervals: float = 3.0

    def __post_init__(self):
        if self.target_load <= 0:
            raise ValueError(f"target_load must be > 0, got {self.target_load}")
        if not (1 <= self.min_tasks <= self.max_tasks):
            raise ValueError(
                f"need 1 <= min_tasks <= max_tasks, got "
                f"[{self.min_tasks}, {self.max_tasks}]")
        if not (0 < self.low < 1.0 <= self.high):
            raise ValueError(
                f"watermarks must satisfy 0 < low < 1 <= high, got "
                f"low={self.low}, high={self.high}")
        if self.patience < 1 or self.cooldown < 0:
            raise ValueError("patience must be >= 1 and cooldown >= 0")


@dataclasses.dataclass
class AutoscaleDecision:
    """One armed proposal — applied or vetoed by the migration damper."""

    interval: int
    from_tasks: int
    to_tasks: int
    reason: str                    # "scale-out" | "scale-in"
    predicted_bytes: float
    predicted_stall: float
    applied: bool


class AutoscalePolicy:
    """Stateful watermark controller; one ``observe`` call per interval."""

    def __init__(self, config: AutoscaleConfig):
        self.config = config
        self.decisions: List[AutoscaleDecision] = []
        self._breach_dir = 0           # +1 over high, -1 under low, 0 in band
        self._breach_run = 0
        self._cooldown = 0

    def desired_tasks(self, total_load: float) -> int:
        """Demand-sized fleet: ceil(total / target), clipped to the bounds."""
        c = self.config
        if total_load <= 0:
            return c.min_tasks
        return max(c.min_tasks,
                   min(c.max_tasks, math.ceil(total_load / c.target_load)))

    def predict_migration_bytes(self, stats, assignment: Assignment,
                                n_new: int) -> float:
        """State bytes a resize to ``n_new`` would move, per the planner's
        own model: rehash to ``n_new`` destinations with dead-task table
        entries dropped (the interim assignment ``rescale`` starts from),
        then sum ``S(k, w)`` over exactly ``metrics.moved_keys``."""
        if stats is None or stats.keys.size == 0:
            return 0.0
        interim = Assignment(
            assignment.hash_router.with_n_dest(n_new),
            {k: d for k, d in assignment.table.items() if d < n_new})
        moved = metrics.moved_keys(stats, assignment, interim)
        if moved.size == 0:
            return 0.0
        return float(stats.mem[np.isin(stats.keys, moved)].sum())

    def observe(self, report, stats, assignment: Assignment,
                migration_bandwidth: float) -> Optional[int]:
        """Feed one interval's observations; returns a new task count to
        apply, or None (in band / not yet armed / vetoed by the damper)."""
        c = self.config
        if self._cooldown > 0:
            self._cooldown -= 1
            self._breach_dir = 0
            self._breach_run = 0
            return None
        n = int(np.asarray(report.task_loads).shape[0])
        total = float(np.asarray(report.task_loads).sum())
        mean = total / n if n else 0.0
        if mean > c.high * c.target_load:
            direction = 1
        elif mean < c.low * c.target_load and n > c.min_tasks:
            direction = -1
        else:
            direction = 0
        if direction == 0:
            self._breach_dir = 0
            self._breach_run = 0
            return None
        if direction != self._breach_dir:
            self._breach_dir = direction
            self._breach_run = 0
        self._breach_run += 1
        if self._breach_run < c.patience:
            return None
        n_new = self.desired_tasks(total)
        if (direction > 0 and n_new <= n) or (direction < 0 and n_new >= n):
            # demand sizing disagrees with the breach (e.g. clipped at the
            # bounds, or one hot task skewing the mean): nothing to do
            self._breach_run = 0
            return None
        predicted = self.predict_migration_bytes(stats, assignment, n_new)
        stall = predicted / migration_bandwidth if migration_bandwidth else 0.0
        if direction > 0:
            # gain = critical-path reduction from spreading the same load
            gain = max(float(report.makespan) - total / n_new, 0.0)
        else:
            # gain = one task's worth of reclaimed capacity per interval
            gain = c.target_load
        applied = stall <= c.payback_intervals * gain
        self.decisions.append(AutoscaleDecision(
            interval=int(report.interval), from_tasks=n, to_tasks=n_new,
            reason="scale-out" if direction > 0 else "scale-in",
            predicted_bytes=predicted, predicted_stall=stall,
            applied=applied))
        self._breach_run = 0
        self._breach_dir = 0
        if not applied:
            return None                # damper veto: stall would not pay back
        self._cooldown = c.cooldown
        return n_new


class HeartbeatMonitor:
    """Flags tasks silent for ``patience`` intervals while traffic flows.

    "Silent" = zero observed load in an interval where the stage processed
    tuples — on an interval-synchronous engine the per-interval report IS
    the heartbeat, so a task that stops contributing shows up as a zero
    lane in ``task_loads``. Flags feed the failure path (restore + replay),
    not the scaling path: a dead task is a fault, not low demand.
    """

    def __init__(self, patience: int = 3):
        if patience < 1:
            raise ValueError(f"patience must be >= 1, got {patience}")
        self.patience = patience
        self.flagged: set = set()
        self._silent_runs: dict = {}

    def observe(self, report) -> List[int]:
        """Returns tasks *newly* flagged by this interval's heartbeat."""
        if int(report.tuples) == 0:
            return []                  # idle interval: no heartbeat expected
        loads = np.asarray(report.task_loads)
        newly: List[int] = []
        for task in range(loads.shape[0]):
            if loads[task] == 0:
                run = self._silent_runs.get(task, 0) + 1
                self._silent_runs[task] = run
                if run >= self.patience and task not in self.flagged:
                    self.flagged.add(task)
                    newly.append(task)
            else:
                self._silent_runs[task] = 0
                self.flagged.discard(task)
        return newly


class AutoscaleLoop:
    """Policy + monitor wired onto one stage: ``step`` per source interval."""

    def __init__(self, stage, config: AutoscaleConfig,
                 monitor: Optional[HeartbeatMonitor] = None):
        if stage.controller.strategy.is_router:
            raise ValueError(
                f"autoscaling requires a table-planner strategy; "
                f"{stage.controller.algorithm_name!r} is a choice router "
                "(scale_to rejects routers — their load estimates cannot "
                "survive a fleet resize)")
        self.stage = stage
        self.policy = AutoscalePolicy(config)
        self.monitor = monitor
        #: (interval, task) pairs the heartbeat monitor flagged as stalled
        self.stalled_tasks: List[Any] = []

    def step(self, keys: np.ndarray,
             values: Optional[np.ndarray] = None):
        """One interval: process, observe, maybe resize. Returns the report."""
        report = self.stage.process_interval_arrays(keys, values)
        if self.monitor is not None:
            for task in self.monitor.observe(report):
                self.stalled_tasks.append((int(report.interval), task))
        n_new = self.policy.observe(report, self.stage.last_stats,
                                    self.stage.controller.assignment,
                                    self.stage.migration_bandwidth)
        if n_new is not None and n_new != self.stage.n_tasks:
            self.stage.scale_to(n_new)
        return report

    @property
    def decisions(self) -> List[AutoscaleDecision]:
        return self.policy.decisions
