# The paper's primary contribution: mixed-routing workload partitioning with
# dynamic, migration-aware rebalancing (balancer + controller + data plane).

from . import balancer
from .autoscale import (AutoscaleConfig, AutoscaleDecision, AutoscaleLoop,
                        AutoscalePolicy, HeartbeatMonitor)
from .balancer import (ALGORITHMS, Assignment, BalanceConfig, ConsistentHash,
                       KeyStats, ModHash, PartialKeyGrouping,
                       PartitionStrategy, PowerOfBothChoices, RebalanceResult,
                       TablePlanner, WChoices, metrics, resolve_strategy,
                       strategy_names)
from .controller import ControllerEvent, RebalanceController

__all__ = [
    "balancer", "ALGORITHMS", "Assignment", "BalanceConfig", "ConsistentHash",
    "KeyStats", "ModHash", "RebalanceResult", "metrics",
    "ControllerEvent", "RebalanceController",
    "PartitionStrategy", "TablePlanner", "PartialKeyGrouping",
    "PowerOfBothChoices", "WChoices", "resolve_strategy", "strategy_names",
    "AutoscaleConfig", "AutoscaleDecision", "AutoscaleLoop",
    "AutoscalePolicy", "HeartbeatMonitor",
]
