"""Interval-driven rebalance controller (paper Sec. IV, Fig. 5).

The controller is pure host-side logic reused by three substrates:

* the stream engine (``repro.streams``) — tuples between operators,
* the MoE SkewShield placer (``repro.models.moe``) — experts over EP shards,
* the serving router (``repro.serve``) — sessions over replica groups.

Protocol per interval (paper's numbered steps):
  1. workers report per-key stats (collected for us by callers / key_stats kernel)
  2. controller evaluates imbalance; decides whether to trigger
  3. controller runs the algorithm (Mixed by default) -> F', Delta(F,F')
  4. Pause: only keys in Delta are affected (double-buffered table install)
  5-6. state migration + acks (executor callback)
  7. Resume with the new assignment
"""

from __future__ import annotations

import copy
import dataclasses
from typing import Callable, List, Optional

import numpy as np

from .balancer import (Assignment, BalanceConfig, KeyStats, RebalanceResult,
                       metrics, resolve_strategy)


@dataclasses.dataclass
class ControllerEvent:
    interval: int
    triggered: bool
    theta_before: float
    result: Optional[RebalanceResult] = None

    @property
    def theta_after(self) -> float:
        return self.result.theta if self.result else self.theta_before

    @property
    def migration_cost(self) -> float:
        return self.result.migration_cost if self.result else 0.0


MigrationExecutor = Callable[[np.ndarray, Assignment, Assignment], None]
"""(moved_keys, old_assignment, new_assignment) -> performs the state moves."""


class RebalanceController:
    """Owns the assignment function F and updates it at interval boundaries.

    ``stats_mode`` selects how step-1 measurement reaches the planner:

    * ``"exact"`` (default) — callers hand over full per-key
      :class:`KeyStats`; O(K) per round, bit-exact (pre-sketch behavior).
    * ``"sketch"`` — callers stream batches through :meth:`ingest`
      (count-min sketch + SpaceSaving head tracker + exact per-dest
      totals, see :mod:`repro.core.balancer.sketch`) and close the round
      with ``on_interval(None)``; the planner then runs on a head-only
      snapshot whose ``base_loads`` freeze the tail on its hash
      destinations — O(H + sketch) memory and O(H) plan time regardless
      of the key domain. The trigger's theta stays exact (head estimate
      errors cancel against the derived base loads, up to clipping).
    """

    def __init__(self, assignment: Assignment, config: BalanceConfig,
                 algorithm="mixed",
                 executor: Optional[MigrationExecutor] = None,
                 stats_mode: str = "exact",
                 sketch: Optional["SketchConfig"] = None):
        self.assignment = assignment
        self.config = config
        self.executor = executor
        self.use_algorithm(algorithm)
        self.history: List[ControllerEvent] = []
        self._interval = 0
        #: monotone counter bumped every time ``self.assignment`` is replaced
        #: (rebalance or rescale). Data planes key device-side routing-table
        #: caches on it so unchanged assignments skip the rebuild/re-upload
        #: (see KeyedStage._dest_batch).
        self.assignment_version = 0
        #: the stats the last protocol round actually planned on (exact or
        #: sketch snapshot) — what ``KeyedStage.last_stats``/``scale_to``
        #: consume in sketch mode.
        self.last_stats: Optional[KeyStats] = None
        if stats_mode not in ("exact", "sketch"):
            raise ValueError(f"unknown stats_mode {stats_mode!r}; "
                             "choose 'exact' or 'sketch'")
        self.stats_mode = stats_mode
        self._sketch: Optional["SketchStats"] = None
        if stats_mode == "sketch":
            from .balancer.sketch import SketchConfig, SketchStats
            cfg = sketch if sketch is not None else SketchConfig()
            seed = int(getattr(assignment.hash_router, "seed", 0))
            self._sketch = SketchStats(cfg, assignment.n_dest, seed=seed)
        elif sketch is not None:
            raise ValueError("sketch= config requires stats_mode='sketch'")

    @property
    def sketch(self) -> Optional["SketchStats"]:
        """The live :class:`SketchStats` instance (sketch mode only)."""
        return self._sketch

    def use_algorithm(self, algorithm) -> None:
        """Install an ``algorithm=`` spec: a registered strategy name, a bare
        planner callable ``(stats, assignment, config) -> RebalanceResult``
        (e.g. ``functools.partial`` over extra knobs, or the scalar reference
        oracle for an A/B run), or a configured
        :class:`~repro.core.balancer.strategy.PartitionStrategy` instance —
        one grammar everywhere (``keyed_stage()`` and ``KeyedStage`` accept
        exactly the same spec and delegate here)."""
        strategy = resolve_strategy(algorithm)
        strategy.bind(self.assignment)
        self.strategy = strategy
        self.algorithm_name = strategy.name
        # legacy surface: the raw planner callable when there is one
        self._algorithm = getattr(strategy, "fn", None)

    # -- paper step 2: trigger decision --------------------------------------
    def should_trigger(self, stats: KeyStats) -> bool:
        if self.strategy.is_router:
            return False   # routers balance per tuple; nothing to (re)plan
        return metrics.theta_for(stats, self.assignment) > self.config.theta_max

    def triggered_intervals(self) -> List[int]:
        """Intervals (1-based) where this controller actually rebalanced.

        In a multi-stage topology every stage owns one controller, so
        intersecting these lists across stages shows rebalances firing at
        different operators within the same interval (the per-operator
        protocol of the paper's Fig. 5)."""
        return [ev.interval for ev in self.history if ev.triggered]

    # -- paper step 1: array-native measurement handoff -----------------------
    def observe(self, keys: np.ndarray, cost: np.ndarray, mem: np.ndarray,
                freq: Optional[np.ndarray] = None,
                force: bool = False,
                interval: Optional[int] = None) -> ControllerEvent:
        """Ingest pre-aggregated per-key arrays and run one protocol round.

        This is the vectorized engine's entry point (and the natural one for
        any substrate whose workers already aggregate on-device, e.g. the
        ``key_stats`` Pallas kernel): callers hand over ``c(k)``/``S(k,w)``/
        ``g(k)`` arrays directly instead of building a :class:`KeyStats`
        themselves. Equivalent to ``on_interval(KeyStats(...), force)`` —
        in sketch mode the arrays fold through :meth:`ingest` instead and
        the round plans on the head-only snapshot.
        """
        if self._sketch is not None:
            self.ingest(keys, cost, mem=mem, freq=freq)
            return self.on_interval(None, force=force, interval=interval)
        return self.on_interval(
            KeyStats(keys=keys, cost=cost, mem=mem, freq=freq), force=force,
            interval=interval)

    def ingest(self, keys: np.ndarray, cost: np.ndarray,
               mem: Optional[np.ndarray] = None,
               freq: Optional[np.ndarray] = None) -> None:
        """Sketch-mode streaming step-1 fold (any number of calls per
        interval; batches may repeat keys — everything accumulates).

        Destinations are resolved through the *current* assignment, which
        is constant within an interval (F only changes at interval
        boundaries), so the exact per-destination totals the trigger uses
        line up with where the tuples actually ran.
        """
        if self._sketch is None:
            raise ValueError("ingest() requires stats_mode='sketch'")
        keys = np.asarray(keys, dtype=np.int64)
        if not keys.size:
            return
        cost = np.asarray(cost, dtype=np.float64)
        # an all-zero-cost batch (the end-of-interval state-size fold)
        # contributes nothing per destination — skip the O(K) dest resolve
        dests = self.assignment.dest(keys) if cost.any() else None
        self._sketch.update(keys, dests, cost, mem=mem, freq=freq)

    # -- paper steps 2-7 ------------------------------------------------------
    def on_interval(self, stats: Optional[KeyStats], force: bool = False,
                    interval: Optional[int] = None) -> ControllerEvent:
        """One protocol round. ``interval`` pins the recorded event to the
        caller's interval clock (the stream engine passes its own counter so
        ControllerEvent.interval stays aligned even when some intervals
        produce no stats and skip the controller entirely); None keeps the
        self-incrementing counter for callers without one.

        ``stats=None`` closes a sketch-mode interval: the round plans on
        the ingested data's head-only snapshot and the sketch resets for
        the next interval. Passing explicit stats works in either mode
        (e.g. ``derate_worker`` hands in a doctored copy)."""
        self._interval = self._interval + 1 if interval is None else interval
        if stats is None:
            if self._sketch is None:
                raise ValueError(
                    "on_interval(None) requires stats_mode='sketch'")
            stats = self._sketch.snapshot(self.assignment)
            self._sketch.end_interval()
        self.last_stats = stats
        if self.strategy.is_router:
            # choice routers balance per tuple and never produce a plan: the
            # interval boundary is measurement only. theta reflects the
            # router's own routed-tuple loads; the head-set hook lets
            # W-Choices refresh its heavy hitters from the step-1 stats.
            self.strategy.on_stats(stats)
            loads = self.strategy.loads
            th = metrics.theta(loads) if loads.size else 0.0
            ev = ControllerEvent(self._interval, False, th)
            self.history.append(ev)
            return ev
        th = metrics.theta_for(stats, self.assignment)
        if not force and th <= self.config.theta_max:
            ev = ControllerEvent(self._interval, False, th)
            self.history.append(ev)
            return ev
        result = self.strategy.plan(stats, self.assignment, self.config)
        # Pause/migrate/Resume: the executor moves state for Delta(F,F') only;
        # in jitted substrates this is a step-boundary double-buffer swap.
        if self.executor is not None and len(result.moved_keys):
            self.executor(result.moved_keys, self.assignment, result.assignment)
        self.assignment = result.assignment
        self.assignment_version += 1
        ev = ControllerEvent(self._interval, True, th, result)
        self.history.append(ev)
        return ev

    # -- checkpoint seam (repro.streams.checkpoint) ---------------------------
    def state_dict(self) -> dict:
        """Everything a recovery needs to resume the protocol bit-identically:
        the assignment (routing table + hash), the version counter that keys
        device routing caches, the interval clock, the event history, the
        planned-on stats, the strategy (routers carry live per-tuple load
        state), and the sketch measurement state when in sketch mode.

        The returned dict owns its data (copies/deepcopies), so it stays
        valid however far the live controller advances afterwards — and it
        is plain numpy/dataclass material, so it pickles for the on-disk
        manifest path.
        """
        return {
            "assignment": self.assignment.copy(),
            "assignment_version": self.assignment_version,
            "interval": self._interval,
            "history": list(self.history),
            "last_stats": self.last_stats,
            "strategy": copy.deepcopy(self.strategy),
            "stats_mode": self.stats_mode,
            "sketch": (self._sketch.state_dict()
                       if self._sketch is not None else None),
        }

    def load_state_dict(self, state: dict) -> None:
        """Restore a :meth:`state_dict` snapshot. Deep-copies on the way in
        as well, so one checkpoint can be restored any number of times.

        The strategy is restored as-is, NOT re-``bind()``-ed: bind resets a
        choice router's load estimates, which are exactly the state the
        checkpoint preserves.
        """
        if state["stats_mode"] != self.stats_mode:
            raise ValueError(
                f"stats_mode mismatch: checkpoint was taken in "
                f"{state['stats_mode']!r} mode, controller runs "
                f"{self.stats_mode!r}")
        self.assignment = state["assignment"].copy()
        self.assignment_version = int(state["assignment_version"])
        self._interval = int(state["interval"])
        self.history = list(state["history"])
        self.last_stats = state["last_stats"]
        self.strategy = copy.deepcopy(state["strategy"])
        self.algorithm_name = self.strategy.name
        self._algorithm = getattr(self.strategy, "fn", None)
        if state["sketch"] is not None:
            self._sketch.load_state_dict(state["sketch"])

    # -- elastic scale-out/in (paper Fig. 15) ---------------------------------
    def rescale(self, n_dest: int, stats: KeyStats) -> ControllerEvent:
        """Change the number of workers and rebalance onto the new fleet.

        Keys keep their table entries (still valid destinations if < n_dest);
        the hash router is swapped for the same family at the new size, so
        with consistent hashing only ~K/N keys re-hash. The regular algorithm
        then restores balance with minimal migration.
        """
        if self.strategy.is_router:
            raise ValueError(
                f"algorithm {self.algorithm_name!r} is a choice router: "
                "per-key state is split across candidate workers, so the "
                "assignment-driven rescale/reconciliation protocol does not "
                "apply; rebuild the stage at the new width instead")
        old_assignment = self.assignment
        new_router = old_assignment.hash_router.with_n_dest(n_dest)
        table = {k: d for k, d in old_assignment.table.items() if d < n_dest}
        interim = Assignment(new_router, table)
        # keys that re-hash under the resized router migrate physically NOW —
        # the optimizer below only sees deltas relative to the interim mapping.
        if self.executor is not None:
            rehashed = metrics.moved_keys(stats, old_assignment, interim)
            if len(rehashed):
                self.executor(rehashed, old_assignment, interim)
        self.assignment = interim
        self.assignment_version += 1
        return self.on_interval(stats, force=True)

    # -- fleet health: straggler demotion (beyond-paper, production posture) --
    def derate_worker(self, d: int, factor: float, stats: KeyStats) -> ControllerEvent:
        """Treat worker ``d`` as ``factor``x slower (straggler): inflate the
        cost of its keys so the balancer migrates load away proportionally."""
        dests = self.assignment.dest(stats.keys)
        cost = stats.cost.copy()
        cost[dests == d] *= factor
        derated = KeyStats(keys=stats.keys, cost=cost, mem=stats.mem,
                           freq=stats.freq)
        return self.on_interval(derated, force=True)
