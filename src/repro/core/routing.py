"""Data-plane mixed routing F(k) (paper Eq. 1), vectorized in JAX.

The controller hands the data plane a *dense override table* (padded key/dest
arrays); every tuple/token evaluates

    dest(k) = table_dest[j]   if table_key[j] == k for some j
            = fmix32(k) % n_dest   otherwise

fmix32 (murmur3 finalizer) is the device-canonical hash: TPUs have no 64-bit
integer units and jnp's uint64 needs x64 mode, so the 32-bit mix is shared
bit-for-bit between the host planner (balancer.hashing.Hash32), this module,
and the Pallas kernel (kernels.routing_lookup) — tested in
tests/test_routing.py.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ref import fmix32


def hash_route(keys: jax.Array, n_dest: int, seed: int = 0) -> jax.Array:
    """h(k) = fmix32(k ^ seed) mod n_dest — matches Hash32 on host."""
    h = fmix32(keys.astype(jnp.uint32), seed)
    return (h % jnp.uint32(n_dest)).astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class RoutingTableDev:
    """Device-side routing table: keys sorted ascending, INT32_MAX padded."""

    keys: jax.Array   # (A_max,) int32
    dests: jax.Array  # (A_max,) int32

    @staticmethod
    def from_assignment(assignment, a_max: int) -> "RoutingTableDev":
        tk, td = assignment.table_arrays(a_max)
        pad = tk < 0
        tk = np.where(pad, np.iinfo(np.int32).max, tk).astype(np.int32)
        order = np.argsort(tk, kind="stable")
        return RoutingTableDev(keys=jnp.asarray(tk[order]),
                               dests=jnp.asarray(td[order].astype(np.int32)))


def route(keys: jax.Array, table: Optional[RoutingTableDev], n_dest: int,
          seed: int = 0) -> jax.Array:
    """Vectorized F(k): table override else hash (paper Eq. 1)."""
    base = hash_route(keys, n_dest, seed)
    if table is None:
        return base
    keys32 = keys.astype(jnp.int32)
    pos = jnp.searchsorted(table.keys, keys32)
    pos = jnp.clip(pos, 0, table.keys.shape[0] - 1)
    hit = table.keys[pos] == keys32
    return jnp.where(hit, table.dests[pos], base).astype(jnp.int32)


def route_tokens_to_shards(keys: jax.Array, table_keys: jax.Array,
                           table_dests: jax.Array, n_dest: int,
                           seed: int = 0) -> jax.Array:
    """jit-friendly flat-argument variant (used inside train/serve steps)."""
    base = hash_route(keys, n_dest, seed)
    pos = jnp.searchsorted(table_keys, keys.astype(jnp.int32))
    pos = jnp.clip(pos, 0, table_keys.shape[0] - 1)
    hit = table_keys[pos] == keys.astype(jnp.int32)
    return jnp.where(hit, table_dests[pos], base).astype(jnp.int32)
