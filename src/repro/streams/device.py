"""Device-resident fused interval step — the dense state backend.

``KeyedStage(state_backend="device")`` keeps windowed per-key state as
device-resident ``jax.Array``s and advances a whole interval in ONE jitted
step: routing lookup (a dense dest table, cached per ``assignment_version``
— PR 2's cache seam), per-key tuple counts, the window-ring slot fold,
eviction, and the per-task cost bincount all happen on-device; the host only
derives the float64 closed forms (costs, emits, sizes) from the step's
integer outputs. That removes the per-interval lexsort / store-update /
segment-sum host work that dominates the columnar backend's profile.

Layout — dense key-indexed ring
-------------------------------
The columnar host store keeps a *compacted* sorted key column and row-
compacts at every boundary. Sorting is exactly what XLA is worst at relative
to numpy (argsort over 150k int32 measured ~4x slower on CPU), and
scatter/gather against compacted rows would re-sort every interval. The
device backend instead uses the same trick as the ``key_stats`` kernel —
trade the sort for dense compute over a bounded key domain:

* ``vals``  (window+1, domain+1) int32 — the ring of per-interval slots,
* ``pres``  (window+1, domain+1) int32 0/1 — slot-exists flags (slot
  creation is what ``ColumnarSpec.slot_bytes`` charges),

where ``domain`` is a power-of-two high-water mark over ``max key id + 1``
and row ``domain`` is the padding sink: tuple batches are padded to a
power-of-two bucket with key ``domain``, so padded scatters land on a row
that is zeroed/ignored by construction. Window totals are column sums;
eviction multiplies by a (window+1,) keep mask. Nothing is sorted, compiled
shapes never depend on how many keys are live, and both state arrays are
donated back into the next step (donation is gated off on CPU, where XLA
cannot alias buffers across calls).

Per-key counting is mode-split: "max" folding needs a device scatter-max
over the raw tuples, but for "add" operators the only per-tuple quantity is
the histogram — and XLA's CPU scatter-add is serial (measured ~16 ms for a
262k-tuple batch where ``np.bincount`` takes ~1 ms). The add-mode step
therefore takes the host-side ``np.bincount`` histogram as an INPUT (one
(domain+1,) int32 upload, smaller than the padded tuple batch it replaces)
and stays scatter-free; the integer values are identical either way, so
bit-parity is unaffected.

``pres`` is int32 rather than bool deliberately: bool buffers defeat CPU
donation ("donated buffers not usable") and the 0/1 integers multiply
straight into the masking arithmetic.

Bit-identical by construction
-----------------------------
Everything the operators' closed forms need — per-key counts, window and
current-slot totals *before* the update — is integer-valued; the step
returns int32 and the host finishes in float64, so reports match the
object/columnar backends bit-for-bit (``tests/test_engine_device.py``).
The engine's two-macro-batch pause split telescopes for these closed forms
(they are batch-boundary invariant), so the fused step processes the whole
interval as one batch and only the ``buffered`` count is computed host-side.

Ownership is a function of the key
----------------------------------
``dest == F(key)`` and migration moves every key whose dest changed, so a
held key always lives on the task F currently maps it to. The fleet keeps a
host ``task`` mirror (int32, -1 = not held) for ``key_location`` and
migration bookkeeping, but migration itself never touches device state —
state is key-indexed; only ownership labels move, and migrated bytes come
from the ``mem`` mirror's closed-form S(k, w). The
:class:`~repro.streams.state.ColumnarPack` contract is preserved:
:class:`DeviceTaskView` exposes ``extract_batch``/``install_batch`` as
device take/mask slices for ``scale_to``'s reconciliation sweep and for
tests — rebalances never fall back to the object path.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.routing_lookup import _fmix32

from .state import ColumnarPack, ColumnarSpec

_INT32_MIN = np.iinfo(np.int32).min

#: python-side-effect trace counters: the increments below run at TRACE time
#: only, so tests can assert the fused step compiles once across intervals
#: (same pattern as test_engine_substrate's retrace counting).
TRACE_COUNTS = {"interval_step": 0, "evict_step": 0, "route_dense": 0}

# XLA cannot alias donated buffers across calls on CPU and warns per call;
# elsewhere donation lets the (window+1, domain+1) state update in place.
_DONATE: Tuple[int, ...] = () if jax.default_backend() == "cpu" else (0, 1)


@functools.partial(jax.jit, donate_argnums=_DONATE)
def _interval_step_add(vals, pres, counts, cur_col, keep_cols):
    """One whole "add"-mode interval against the dense ring — scatter-free.

    Args (device):
      vals/pres: (W1, D+1) int32 state ring (donated).
      counts:    (D+1,) int32 per-key tuple histogram (host ``np.bincount``;
                 the padding row's count is structurally zero).
      cur_col:   (W1,) int32 one-hot of this interval's ring column.
      keep_cols: (W1,) int32 0/1 — columns surviving this boundary's eviction.

    Returns the post-boundary state plus the integer observables the host
    closed forms need: window/slot totals BEFORE the update, then per-key
    held slot-count and value-sum AFTER eviction. In add mode the slot
    delta IS the count, so the whole update is elementwise.
    """
    TRACE_COUNTS["interval_step"] += 1
    win0 = vals.sum(axis=0)
    slot0 = (vals * cur_col[:, None]).sum(axis=0)
    seen = (counts > 0).astype(jnp.int32)
    vals = vals + cur_col[:, None] * counts[None, :]
    pres = jnp.maximum(pres, cur_col[:, None] * seen[None, :])
    vals = vals * keep_cols[:, None]
    pres = pres * keep_cols[:, None]
    return vals, pres, win0, slot0, pres.sum(axis=0), vals.sum(axis=0)


@functools.partial(jax.jit, static_argnames=("n_tasks",),
                   donate_argnums=_DONATE)
def _interval_step_max(vals, pres, keys, tvals, dest_dense, cur_col,
                       keep_cols, *, n_tasks: int):
    """One whole "max"-mode interval: scatter-max fold over raw tuples.

    Args (device):
      vals/pres: (W1, D+1) int32 state ring (donated).
      keys:      (Npad,) int32 tuple keys, padded with D.
      tvals:     (Npad,) int32 per-tuple values, padded with INT32_MIN.
      dest_dense:(D+1,) int32 F(k) for every key id (see ``_route_dense``).
      cur_col:   (W1,) int32 one-hot of this interval's ring column.
      keep_cols: (W1,) int32 0/1 — columns surviving this boundary's eviction.

    Returns the post-boundary state plus per-key counts, window/slot totals
    BEFORE the update, held slot-count and value-sum AFTER eviction, and the
    per-task tuple bincount. Unlike add mode the fold genuinely needs the
    raw tuple values, so the scatters stay on-device.
    """
    TRACE_COUNTS["interval_step"] += 1
    d1 = vals.shape[1]
    pad_row = d1 - 1
    counts = jnp.zeros((d1,), jnp.int32).at[keys].add(jnp.int32(1))
    counts = counts.at[pad_row].set(0)
    win0 = vals.sum(axis=0)
    slot0 = (vals * cur_col[:, None]).sum(axis=0)
    seen = (counts > 0).astype(jnp.int32)
    gmax = jnp.full((d1,), _INT32_MIN, jnp.int32).at[keys].max(tvals)
    newslot = jnp.where(seen > 0, jnp.maximum(slot0, gmax), slot0)
    vals = vals + cur_col[:, None] * (newslot - slot0)[None, :]
    pres = jnp.maximum(pres, cur_col[:, None] * seen[None, :])
    vals = vals * keep_cols[:, None]
    pres = pres * keep_cols[:, None]
    held_cnt = pres.sum(axis=0)
    held_sum = vals.sum(axis=0)
    task_counts = jnp.zeros((n_tasks,), jnp.int32).at[dest_dense].add(counts)
    return vals, pres, counts, win0, slot0, held_cnt, held_sum, task_counts


@functools.partial(jax.jit, donate_argnums=_DONATE)
def _evict_step(vals, pres, keep_cols):
    """Boundary eviction for a tuple-free interval (no slot updates)."""
    TRACE_COUNTS["evict_step"] += 1
    vals = vals * keep_cols[:, None]
    pres = pres * keep_cols[:, None]
    return vals, pres, pres.sum(axis=0), vals.sum(axis=0)


@functools.partial(jax.jit, static_argnames=("n_dest", "seed"))
def _route_dense(all_keys, tkeys, tdests, *, n_dest: int, seed: int):
    """F(k) for EVERY key id at once: fmix32 hash + table-override scatter.

    The jnp twin of the Pallas ``routing_lookup`` kernel over a dense
    ``arange(domain + 1)`` key column — same mix, same override semantics,
    bit-equal to the host planner's Hash32. Empty table slots (-1) scatter
    onto the padding row, whose dest is never read.
    """
    TRACE_COUNTS["route_dense"] += 1
    h = _fmix32(all_keys.astype(jnp.uint32) ^ jnp.uint32(seed & 0xFFFFFFFF))
    base = (h % jnp.uint32(n_dest)).astype(jnp.int32)
    pad_row = all_keys.shape[0] - 1
    ok = (tkeys >= 0) & (tkeys < all_keys.shape[0])
    slot = jnp.where(ok, tkeys, pad_row)
    return base.at[slot].set(jnp.where(ok, tdests, base[pad_row]))


class DeviceStateFleet:
    """Shared device state ring + host mirrors for one stage's task fleet.

    One fleet serves ALL task instances of a stage (state is key-indexed;
    task ownership is the host ``task`` label array), so per-interval work
    is a single fused dispatch regardless of the task count.
    """

    def __init__(self, window: int, spec: ColumnarSpec, min_domain: int = 512):
        if spec.mode not in ("add", "max"):
            raise ValueError(f"unknown columnar mode {spec.mode!r}")
        self.window = window
        self.spec = spec
        self._ncols = window + 1
        self._min_domain = min_domain
        self.domain = 0                    # valid key ids are [0, domain)
        self.col_iv = np.full(self._ncols, -1, dtype=np.int64)
        self.task = np.full(1, -1, dtype=np.int32)       # (domain+1,)
        self.mem = np.zeros(1, dtype=np.float64)         # S(k, w) mirror
        self.vals = jnp.zeros((self._ncols, 1), jnp.int32)
        self.pres = jnp.zeros((self._ncols, 1), jnp.int32)
        self._all_keys = None              # device arange(domain+1) for routing
        self._keys_cap = 0                 # tuple-batch pad bucket (pow2 HWM)
        self._host_vals: Optional[np.ndarray] = None
        self._host_pres: Optional[np.ndarray] = None
        self._host_dirty = True

    # -- shape management -------------------------------------------------------
    def ensure_domain(self, needed: int) -> bool:
        """Grow the dense domain to a power-of-two >= ``needed``.

        Power-of-two high-water sizing keeps compiled shapes stable across
        intervals whose max key id wobbles; growth (a genuinely new shape)
        retraces once and copies live state forward. Returns True on growth.
        """
        if needed <= self.domain:
            return False
        dom = max(self._min_domain, 1 << (int(needed) - 1).bit_length())
        d1 = dom + 1
        vals = jnp.zeros((self._ncols, d1), jnp.int32)
        pres = jnp.zeros((self._ncols, d1), jnp.int32)
        task = np.full(d1, -1, dtype=np.int32)
        mem = np.zeros(d1, dtype=np.float64)
        if self.domain:
            # the old padding row is all-zero by construction; copy real rows
            vals = vals.at[:, :self.domain].set(self.vals[:, :self.domain])
            pres = pres.at[:, :self.domain].set(self.pres[:, :self.domain])
            task[:self.domain] = self.task[:self.domain]
            mem[:self.domain] = self.mem[:self.domain]
        self.domain = dom
        self.vals, self.pres = vals, pres
        self.task, self.mem = task, mem
        self._all_keys = None
        self._host_dirty = True
        return True

    # -- the fused hot path -----------------------------------------------------
    def interval_step(self, keys: np.ndarray, tuple_vals: Optional[np.ndarray],
                      dest_dense, n_tasks: int, keep_cols: np.ndarray,
                      cur_col: np.ndarray, mode: str):
        """Run one interval's fused step.

        Returns ``(counts, win0, slot0, held_cnt, held_sum, task_counts)``;
        ``counts`` is a host int32 array in add mode (where the histogram is
        computed host-side — see the module docstring) and ``task_counts``
        is None there (derive it from counts + the host dest mirror).
        """
        if mode == "add":
            counts = np.bincount(keys, minlength=self.domain + 1) \
                .astype(np.int32)
            out = _interval_step_add(self.vals, self.pres,
                                     jnp.asarray(counts),
                                     jnp.asarray(cur_col),
                                     jnp.asarray(keep_cols))
            self.vals, self.pres = out[0], out[1]
            self._host_dirty = True
            return (counts,) + tuple(out[2:]) + (None,)
        n = int(keys.shape[0])
        if n > self._keys_cap:
            self._keys_cap = max(1024, 1 << (n - 1).bit_length())
        cap = self._keys_cap
        kp = np.empty(cap, dtype=np.int32)
        kp[:n] = keys
        kp[n:] = self.domain
        tv = np.empty(cap, dtype=np.int32)
        tv[:n] = tuple_vals
        tv[n:] = _INT32_MIN
        out = _interval_step_max(self.vals, self.pres, jnp.asarray(kp),
                                 jnp.asarray(tv), dest_dense,
                                 jnp.asarray(cur_col), jnp.asarray(keep_cols),
                                 n_tasks=n_tasks)
        self.vals, self.pres = out[0], out[1]
        self._host_dirty = True
        return out[2:]

    def evict(self, keep_cols: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        out = _evict_step(self.vals, self.pres, jnp.asarray(keep_cols))
        self.vals, self.pres = out[0], out[1]
        self._host_dirty = True
        return np.asarray(out[2]), np.asarray(out[3])

    def route_dense(self, tkeys: np.ndarray, tdests: np.ndarray, n_dest: int,
                    seed: int, use_kernel: bool,
                    interpret: Optional[bool]):
        """Dense dest table over arange(domain + 1): kernel or jnp twin."""
        d1 = self.domain + 1
        if self._all_keys is None or int(self._all_keys.shape[0]) != d1:
            self._all_keys = jnp.arange(d1, dtype=jnp.int32)
        tk = jnp.asarray(tkeys.astype(np.int32))
        td = jnp.asarray(tdests.astype(np.int32))
        if use_kernel:
            from repro.kernels.routing_lookup import routing_lookup
            return routing_lookup(self._all_keys, tk, td, n_dest, seed=seed,
                                  interpret=interpret)
        return _route_dense(self._all_keys, tk, td, n_dest=n_dest, seed=seed)

    def dest_host_dense(self, dev) -> np.ndarray:
        """Host copy of a ``route_dense`` table, aligned to key id.

        Returns ``(domain+1,)`` int64 with ``out[k] == F(k)``. The single-
        device layout already is key-aligned; sharded fleets override this to
        de-interleave their per-shard blocks."""
        return np.asarray(dev).astype(np.int64)

    # -- host snapshots (pack contract + introspection) -------------------------
    def host_state(self) -> Tuple[np.ndarray, np.ndarray]:
        if self._host_dirty:
            self._host_vals = np.asarray(self.vals)
            self._host_pres = np.asarray(self.pres)
            self._host_dirty = False
        return self._host_vals, self._host_pres

    def sizes_matrix(self, rows: np.ndarray) -> np.ndarray:
        """(M, W1) float64 per-column sizes — the ColumnarPack closed form:
        slot creation charges ``slot_bytes``; each folded unit charges
        ``bytes_per_unit`` (identical to the columnar store's accumulation
        because both quantities are integer counts)."""
        host_vals, host_pres = self.host_state()
        pres = host_pres[:, rows].T.astype(np.float64)
        vals = host_vals[:, rows].T.astype(np.float64)
        return self.spec.slot_bytes * pres + self.spec.bytes_per_unit * vals

    def clear_rows(self, rows: np.ndarray) -> None:
        idx = jnp.asarray(rows.astype(np.int32))
        self.vals = self.vals.at[:, idx].set(0)
        self.pres = self.pres.at[:, idx].set(0)
        self.task[rows] = -1
        self.mem[rows] = 0.0
        self._host_dirty = True

    def install_rows(self, rows: np.ndarray, vals_cols: np.ndarray,
                     pres_cols: np.ndarray, task_idx: int,
                     sizes_rows: np.ndarray) -> None:
        idx = jnp.asarray(rows.astype(np.int32))
        self.vals = self.vals.at[:, idx].set(
            jnp.asarray(vals_cols.T.astype(np.int32)))
        self.pres = self.pres.at[:, idx].set(
            jnp.asarray(pres_cols.T.astype(np.int32)))
        self.task[rows] = task_idx
        self.mem[rows] = sizes_rows.sum(axis=1)
        self._host_dirty = True


class _DeviceKeysView:
    """Dict-like ``store.keys`` surface over one task's ownership labels."""

    def __init__(self, fleet: DeviceStateFleet, index: int):
        self._fleet = fleet
        self._index = index

    def _mask(self) -> np.ndarray:
        return self._fleet.task[:self._fleet.domain] == self._index

    def __len__(self) -> int:
        return int(self._mask().sum())

    def __iter__(self):
        return iter(np.nonzero(self._mask())[0].tolist())

    def __contains__(self, key) -> bool:
        k = int(key)
        return (0 <= k < self._fleet.domain
                and int(self._fleet.task[k]) == self._index)


class DeviceTaskView:
    """One task instance's window onto the shared device fleet.

    Implements the store surface the engine's backend-agnostic code paths
    touch outside the fused step: ``keys`` membership (``key_location``),
    ``sizes_arrays`` (scale_to's reconciliation sweep) and the
    ``extract_batch``/``install_batch`` ColumnarPack contract (migration
    primitives; packs interoperate with the columnar store's layout).
    """

    def __init__(self, fleet: DeviceStateFleet, index: int):
        self.fleet = fleet
        self.index = index

    @property
    def keys(self) -> _DeviceKeysView:
        return _DeviceKeysView(self.fleet, self.index)

    def sizes_arrays(self) -> Tuple[np.ndarray, np.ndarray]:
        fleet = self.fleet
        held = np.nonzero(fleet.task[:fleet.domain] == self.index)[0]
        return held.astype(np.int64), fleet.mem[held]

    def extract_batch(self, keys: np.ndarray) -> ColumnarPack:
        fleet = self.fleet
        arr = np.unique(np.asarray(keys, dtype=np.int64).ravel())
        arr = arr[(arr >= 0) & (arr < fleet.domain)]
        rows = arr[fleet.task[arr] == self.index]
        host_vals, host_pres = fleet.host_state()
        pack = ColumnarPack(rows,
                            host_vals[:, rows].T.astype(np.float64),
                            fleet.sizes_matrix(rows),
                            host_pres[:, rows].T.astype(bool),
                            fleet.col_iv.copy())
        if rows.size:
            fleet.clear_rows(rows)
        return pack

    def install_batch(self, pack: ColumnarPack) -> None:
        fleet = self.fleet
        if not pack.keys.size:
            return
        taken = pack.keys[fleet.task[pack.keys] >= 0]
        if taken.size:
            raise RuntimeError(
                f"key {int(taken[0])} already present on target task")
        live = pack.col_iv >= 0
        conflict = live & (fleet.col_iv >= 0) & (fleet.col_iv != pack.col_iv)
        if conflict.any():
            raise RuntimeError(
                "columnar install across skewed interval clocks: source and "
                "target stores disagree on column contents")
        fleet.col_iv = np.where(live & (fleet.col_iv < 0), pack.col_iv,
                                fleet.col_iv)
        fleet.install_rows(pack.keys, pack.vals, pack.present, self.index,
                           pack.sizes)
