"""Stateful operators — the paper's two real workloads (Sec. V).

* :class:`WordCount` — "store and aggregation on keywords" (Social data):
  per-key counts over the sliding window.
* :class:`WindowedSelfJoin` — "self-join over sliding window" (Stock data):
  each incoming tuple joins against all tuples of the same key within the
  window; join work (and hence c(k)) grows superlinearly with key frequency,
  which is exactly the skew-amplification the paper targets.

Operators report per-tuple cost so the engine can measure c(k) instead of
assuming cost == frequency (the paper makes the same distinction).

Batched execution
-----------------
The vectorized engine (``KeyedStage(vectorized=True)``, the default — see
:mod:`repro.streams.engine` and ``docs/architecture.md``) hands each task a
whole micro-batch segment at once via :meth:`Operator.process_batch`. The
built-in operators implement it with closed-form per-key arithmetic: a key
hit ``m`` times in a segment updates its state once and derives the same
emits/costs the per-tuple path would produce tuple by tuple. Custom
operators only need ``process``; the base-class ``process_batch`` falls back
to the per-tuple loop, so they stay correct (just not fast) under the
vectorized engine. Set ``needs_values = False`` on operators that ignore
tuple payloads so the engine can skip materializing per-segment value lists.

Batched emit contract (topologies)
----------------------------------
In a multi-stage :class:`repro.streams.topology.Topology` a stage's emits
become the next stage's input tuples, so the engine needs the *full* emit
stream — not just the last-wins ``outputs`` summary that single-stage
callers read. :meth:`Operator.process_batch_emits` is that contract: it
performs exactly one state update per unique key (same as ``process_batch``)
and additionally returns ``(emit_counts, emit_keys, emit_values)`` arrays —
``emit_counts[i]`` emits for the i-th input tuple, listed in input order.
Fan-out may be 0 (:class:`Filter` drops tuples), 1 (the aggregations), or
more (custom operators via the per-tuple fallback). The built-ins derive
the per-occurrence emit values in closed form — the j-th tuple of a key in
a segment emits an arithmetic-progression term — so chaining stages keeps
the no-per-tuple-Python property end to end.

Columnar whole-interval dispatch
--------------------------------
Operators whose windowed state is a single numeric slot per (key, interval)
declare a :class:`~repro.streams.state.ColumnarSpec` via ``columnar_spec``;
the engine then gives them a :class:`~repro.streams.state.ColumnarStateStore`
fleet and calls :meth:`Operator.process_interval_batch` ONCE per macro-batch
instead of once per task: one ``np.lexsort`` on ``(dest, key)`` yields every
task's segment, every unique-key group and every occurrence index in a
single pass; per-task costs are scattered with one ``np.bincount``; the
per-destination store updates are one vectorized ``update_slots`` slice
each. Custom operators (no ``columnar_spec``) keep the object store and the
per-task ``process_batch`` loop — the compatibility/parity oracle.
"""

from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Sequence, Tuple

import numpy as np

from .state import ColumnarSpec, TaskStateStore


@dataclasses.dataclass
class BatchResult:
    """What one :meth:`Operator.process_batch` call produced.

    The engine folds these straight into its array accumulators (per-task
    cost, per-key cost/freq via ``np.add.at``) — no per-tuple Python on the
    hot path.

    Attributes:
      uniq_keys: (U,) int64 — unique keys of the segment, sorted ascending.
      key_cost:  (U,) float64 — summed c(k) contribution per unique key.
      key_freq:  (U,) float64 — tuple count per unique key.
      task_cost: total cost charged to the task (== key_cost.sum()).
      outputs:   final (key, value) emit per key — the last emit the
                 per-tuple path would have written (downstream is last-wins).
      emit_sum:  sum of *all* numeric emitted values the per-tuple path
                 would have produced (not just the final ones).
    """

    uniq_keys: np.ndarray
    key_cost: np.ndarray
    key_freq: np.ndarray
    task_cost: float
    outputs: List[Tuple[int, Any]]
    emit_sum: float


@dataclasses.dataclass
class IntervalBatchResult:
    """What one :meth:`Operator.process_interval_batch` call produced.

    The whole-interval analogue of :class:`BatchResult`: covers every task's
    segment at once, so ``task_cost`` is the full per-task cost vector (one
    ``np.bincount`` scatter) instead of a single task's scalar.
    ``uniq_keys``/``key_cost``/``key_freq`` are ordered by ``(dest, key)`` —
    the exact concatenation order the per-task path would have produced.
    """

    uniq_keys: np.ndarray          # (U,) int64 groups, (dest, key)-sorted
    key_cost: np.ndarray           # (U,) float64
    key_freq: np.ndarray           # (U,) float64
    task_cost: np.ndarray          # (n_tasks,) float64
    outputs: List[Tuple[int, Any]]
    emit_sum: float


def _interval_groups(keys: np.ndarray, dests: np.ndarray):
    """One lexsort over a whole macro-batch -> every segment's closed-form
    inputs: ``(order, starts, gk, gd, counts, gidx, occ)``.

    ``order`` sorts positions by ``(dest, key)`` (stable); groups are the
    maximal runs sharing both. ``gk``/``gd``/``counts`` describe each group,
    ``gidx`` maps each sorted position to its group, and ``occ`` is the
    occurrence index within the group (stream order — the stable sort keeps
    same-key tuples in input order, which is what the per-occurrence emit
    progressions index by).
    """
    order = np.lexsort((keys, dests))
    sk = keys[order]
    sd = dests[order]
    n = sk.size
    newgrp = np.empty(n, dtype=bool)
    newgrp[0] = True
    np.logical_or(sk[1:] != sk[:-1], sd[1:] != sd[:-1], out=newgrp[1:])
    starts = np.nonzero(newgrp)[0]
    counts = np.diff(np.append(starts, n))
    gidx = np.cumsum(newgrp) - 1
    occ = np.arange(n, dtype=np.int64) - starts[gidx]
    return order, starts, sk[starts], sd[starts], counts, gidx, occ


def _update_by_dest(stores, interval: int, gk: np.ndarray, gd: np.ndarray,
                    add: np.ndarray, n_tasks: int
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Apply per-(dest, key) group updates store by store.

    ``gd`` is sorted, so each destination's groups are one contiguous slice —
    at most ``n_tasks`` vectorized ``update_slots`` calls, no per-key work.
    Returns the concatenated ``(win_before, slot_before)`` arrays aligned
    with the groups.
    """
    win0 = np.empty(gk.size, dtype=np.float64)
    slot0 = np.empty(gk.size, dtype=np.float64)
    bounds = np.searchsorted(gd, np.arange(n_tasks + 1))
    for d in range(n_tasks):
        s0, s1 = int(bounds[d]), int(bounds[d + 1])
        if s0 == s1:
            continue
        win0[s0:s1], slot0[s0:s1] = stores[d].update_slots(
            interval, gk[s0:s1], add[s0:s1])
    return win0, slot0


def _counting_interval_batch(stores, interval: int, keys: np.ndarray,
                             dests: np.ndarray, n_tasks: int,
                             collect_emits: bool, window_total: bool):
    """Whole-interval dispatch shared by the counting family.

    WordCount and PartialWordCount differ only in which ``c0`` their emit
    progression starts from: the windowed total (``window_total=True``) or
    the current interval slice (False). Everything else — one lexsort, one
    ``update_slots`` slice per destination, one ``np.bincount`` scatter,
    arithmetic-progression emits — is identical.
    """
    order, _, gk, gd, counts, gidx, occ = _interval_groups(keys, dests)
    fcounts = counts.astype(np.float64)
    win0, slot0 = _update_by_dest(stores, interval, gk, gd, fcounts, n_tasks)
    c0s = (win0 if window_total else slot0).astype(np.int64)
    # emits per key are the running totals c0+1 .. c0+m: sum and last value
    # are exact integer arithmetic
    outputs = list(zip(gk.tolist(), (c0s + counts).tolist()))
    emit_sum = float(np.dot(counts, c0s) + np.dot(counts, counts + 1) / 2.0)
    res = IntervalBatchResult(
        gk, fcounts.copy(), fcounts,
        np.bincount(gd, weights=fcounts, minlength=n_tasks),
        outputs, emit_sum)
    if not collect_emits:
        return res, None
    # the j-th occurrence of a key emits its running total c0 + j
    evals = np.empty(keys.size, dtype=np.int64)
    evals[order] = c0s[gidx] + occ + 1
    return res, (np.ones(keys.size, dtype=np.int64),
                 keys.astype(np.int64, copy=False), evals)


def _occurrence_index(inv: np.ndarray, counts: np.ndarray) -> np.ndarray:
    """occ[i] = how many earlier tuples in the segment share keys[i]'s key.

    Lets the closed-form operators reconstruct per-occurrence emits (the
    j-th hit of a key emits the j-th term of that key's progression) without
    a per-tuple loop: stable-sort positions by group, subtract group starts.
    """
    order = np.argsort(inv, kind="stable")
    starts = np.concatenate(([0], np.cumsum(counts)[:-1]))
    occ = np.empty(inv.size, dtype=np.int64)
    occ[order] = np.arange(inv.size, dtype=np.int64) - np.repeat(starts, counts)
    return occ


def _numeric_emit_sum(vals) -> float:
    """Sum of emitted values the per-tuple path counts as numeric.

    The reference loop's rule is ``isinstance(v, (int, float))``: numpy
    float scalars are ``float`` subclasses but numpy integer scalars are NOT
    ``int`` subclasses, so float arrays sum and integer/bool arrays
    contribute nothing. Matching that here keeps ``emitted_sum`` bit-equal
    between the batched and per-tuple paths for pass-through operators.
    """
    if isinstance(vals, np.ndarray):
        if vals.dtype.kind == "f":
            return float(vals.sum())
        if vals.dtype.kind in "iub":
            return 0.0
    return float(sum(float(v) for v in vals if isinstance(v, (int, float))))


def _group_values(inv: np.ndarray, counts: np.ndarray,
                  values: Sequence[Any]) -> List[List[Any]]:
    """Split ``values`` into per-unique-key lists (stream order preserved)."""
    order = np.argsort(inv, kind="stable")
    bounds = np.concatenate(([0], np.cumsum(counts)))
    if isinstance(values, np.ndarray):
        vs = values[order]
        return [vs[bounds[u]:bounds[u + 1]].tolist()
                for u in range(len(counts))]
    return [[values[i] for i in order[bounds[u]:bounds[u + 1]]]
            for u in range(len(counts))]


class Operator:
    name = "op"
    #: set False when ``process_batch`` never reads tuple payloads — lets the
    #: vectorized engine skip gathering per-segment value lists entirely.
    needs_values = True
    #: :class:`~repro.streams.state.ColumnarSpec` when the operator's state is
    #: one numeric slot per (key, interval) — opts into the columnar store
    #: backend and whole-interval dispatch. None keeps the object store.
    columnar_spec: Optional[ColumnarSpec] = None
    #: whether the columnar whole-interval path reads tuple payloads (may
    #: differ from ``needs_values``: the columnar self-join derives everything
    #: from counts and never stores the raw tuples).
    columnar_needs_values = True
    #: "add" / "max" when the operator's slot fold has a device closed form
    #: (must match ``columnar_spec.mode``); None = no device form, so the
    #: engine's ``state_backend="auto"`` never picks the device backend and
    #: an explicit ``"device"`` request raises (see streams/device.py).
    device_mode: Optional[str] = None
    #: True when per-key cost == tuple frequency (1.0 cost units per tuple):
    #: the engine then reads task loads straight off the fused step's integer
    #: per-task bincount instead of a host bincount over float costs.
    device_unit_cost = False
    #: True when the operator stays correct if one key's tuples are split
    #: across tasks (per-tuple output, or a commutative merge a downstream
    #: stage can combine). Choice-router strategies (pkg/potc/wchoices) split
    #: keys by design, so KeyedStage refuses ``split_safe = False`` operators
    #: under a ``needs_merge_stage`` strategy — pair them with a downstream
    #: merge stage instead (see repro.streams.topology).
    split_safe = False

    def device_finish(self, counts: np.ndarray, win0: np.ndarray,
                      slot0: np.ndarray
                      ) -> Tuple[np.ndarray, Optional[np.ndarray], float]:
        """Host closed forms over the fused step's per-key integers.

        Arguments are (m,) int64 arrays for the keys SEEN this interval
        (sorted ascending): tuple counts, windowed totals before the update,
        and current-slot totals before the update. Returns
        ``(key_cost float64, output_values int64 or None, emit_sum)`` — the
        exact quantities ``process_interval_batch`` derives, computed from
        the same integers, so reports stay bit-identical.
        """
        raise NotImplementedError

    def device_emit_values(self, keys: np.ndarray, occ: np.ndarray,
                           win0_dense: np.ndarray, slot0_dense: np.ndarray
                           ) -> Optional[np.ndarray]:
        """Per-tuple emit values (input order) from dense step outputs.

        ``occ`` is each tuple's occurrence index within its key;
        ``win0_dense``/``slot0_dense`` are the step's (domain,) pre-update
        totals indexed by key id. None = the operator emits nothing.
        """
        raise NotImplementedError

    def process(self, store: TaskStateStore, interval: int, key: int,
                value: Any) -> Tuple[List[Tuple[int, Any]], float]:
        """Returns (output tuples, cost units consumed)."""
        raise NotImplementedError

    def process_batch(self, store: TaskStateStore, interval: int,
                      keys: np.ndarray,
                      values: Optional[Sequence[Any]]) -> BatchResult:
        """Process one task's micro-batch segment; default per-tuple fallback.

        Semantically equivalent to calling :meth:`process` for each tuple in
        stream order — delegates to :meth:`process_batch_emits` (one shared
        accumulation loop) and drops the emit stream. Built-in operators
        override both with vectorized closed forms; custom operators inherit
        the loop and remain correct.
        """
        res, _, _, _ = self.process_batch_emits(store, interval, keys, values)
        return res

    def process_batch_emits(self, store: TaskStateStore, interval: int,
                            keys: np.ndarray,
                            values: Optional[Sequence[Any]]
                            ) -> Tuple[BatchResult, np.ndarray, np.ndarray,
                                       np.ndarray]:
        """Like :meth:`process_batch`, plus the full emit stream.

        Returns ``(result, emit_counts, emit_keys, emit_values)``:
        ``emit_counts`` is (len(keys),) int64 — emits produced by each input
        tuple; ``emit_keys``/``emit_values`` list those emits in input order
        (all emits of tuple i precede those of tuple i+1, each a scalar).
        The engine uses this to hand a stage's output to the next stage of a
        Topology as arrays. The state update happens exactly once — callers
        invoke either this or ``process_batch``, never both. Default:
        per-tuple fallback; built-ins override with closed forms.
        """
        key_cost: dict = {}
        key_freq: dict = {}
        outputs: dict = {}
        emit = 0.0
        total = 0.0
        n = len(keys)
        counts = np.zeros(n, dtype=np.int64)
        ekeys: List[int] = []
        evals: List[Any] = []
        vals = values if values is not None else [None] * n
        for i, (k, v) in enumerate(zip(keys.tolist(), vals)):
            outs, cost = self.process(store, interval, k, v)
            total += cost
            key_cost[k] = key_cost.get(k, 0.0) + cost
            key_freq[k] = key_freq.get(k, 0.0) + 1.0
            counts[i] = len(outs)
            for ok, ov in outs:
                outputs[ok] = ov
                ekeys.append(ok)
                evals.append(ov)
                if isinstance(ov, (int, float)):
                    emit += float(ov)
        uniq = np.fromiter(sorted(key_cost), dtype=np.int64, count=len(key_cost))
        res = BatchResult(
            uniq_keys=uniq,
            key_cost=np.fromiter((key_cost[int(k)] for k in uniq),
                                 dtype=np.float64, count=len(uniq)),
            key_freq=np.fromiter((key_freq[int(k)] for k in uniq),
                                 dtype=np.float64, count=len(uniq)),
            task_cost=total, outputs=list(outputs.items()), emit_sum=emit)
        return (res, counts, np.asarray(ekeys, dtype=np.int64),
                np.asarray(evals))

    def process_interval_batch(self, stores, interval: int, keys: np.ndarray,
                               dests: np.ndarray, n_tasks: int,
                               values: Optional[Sequence[Any]],
                               collect_emits: bool):
        """Whole-interval single dispatch over the columnar store fleet.

        Covers EVERY task's segment of one macro-batch in one call — the
        engine only invokes it when ``columnar_spec`` is set (``stores`` are
        then :class:`~repro.streams.state.ColumnarStateStore` instances).
        Returns ``(IntervalBatchResult, emits)`` where ``emits`` is the
        ``(emit_counts, emit_keys, emit_values)`` triple in input order when
        ``collect_emits`` is true, else None.
        """
        raise NotImplementedError(
            f"{type(self).__name__} sets columnar_spec but does not "
            "implement process_interval_batch")


class WordCount(Operator):
    name = "wordcount"
    needs_values = False
    columnar_needs_values = False
    device_mode = "add"
    device_unit_cost = True

    def __init__(self, bytes_per_entry: float = 16.0):
        self.bytes_per_entry = bytes_per_entry
        self.columnar_spec = ColumnarSpec(mode="add",
                                          slot_bytes=bytes_per_entry)

    def process(self, store, interval, key, value):
        ks = store.state(key)
        sl = ks.slice_for(interval, init=lambda: {"count": 0},
                          size=self.bytes_per_entry)
        sl.payload["count"] += 1
        total = sum(s.payload["count"] for s in ks.iter_window())
        return [(key, total)], 1.0

    def _apply_counts(self, store, interval, uniq, counts):
        """One state update per unique key; returns pre-batch window totals."""
        pairs = store.update_many(interval, uniq, init=lambda: {"count": 0},
                                  size=self.bytes_per_entry)
        c0s = np.empty(len(uniq), dtype=np.int64)
        for i, (m, (ks, sl)) in enumerate(zip(counts.tolist(), pairs)):
            c0 = 0
            for s in ks.slices.values():
                c0 += s.payload["count"]
            sl.payload["count"] += m
            c0s[i] = c0
        return c0s

    def _batch_result(self, uniq, counts, c0s, n):
        # emits per key are the running totals c0+1 .. c0+m: their sum and
        # the final (last-wins) value are exact integer arithmetic
        totals = c0s + counts
        outputs = list(zip(uniq.tolist(), totals.tolist()))
        emit = float(np.dot(counts, c0s) + np.dot(counts, counts + 1) / 2.0)
        freq = counts.astype(np.float64)
        return BatchResult(uniq, freq.copy(), freq, float(n), outputs, emit)

    def process_batch(self, store, interval, keys, values):
        # m tuples on a key whose window already counts c0 emit the running
        # totals c0+1 .. c0+m; one state update per unique key.
        uniq, counts = np.unique(keys, return_counts=True)
        c0s = self._apply_counts(store, interval, uniq, counts)
        return self._batch_result(uniq, counts, c0s, len(keys))

    def process_batch_emits(self, store, interval, keys, values):
        uniq, inv, counts = np.unique(keys, return_inverse=True,
                                      return_counts=True)
        c0s = self._apply_counts(store, interval, uniq, counts)
        res = self._batch_result(uniq, counts, c0s, len(keys))
        # the j-th occurrence of a key emits its running total c0 + j
        evals = c0s[inv] + _occurrence_index(inv, counts) + 1
        return (res, np.ones(len(keys), dtype=np.int64),
                keys.astype(np.int64, copy=False), evals)

    def process_interval_batch(self, stores, interval, keys, dests, n_tasks,
                               values, collect_emits):
        return _counting_interval_batch(stores, interval, keys, dests,
                                        n_tasks, collect_emits,
                                        window_total=True)

    def device_finish(self, counts, win0, slot0):
        emit = float(np.dot(counts, win0) + np.dot(counts, counts + 1) / 2.0)
        return counts.astype(np.float64), win0 + counts, emit

    def device_emit_values(self, keys, occ, win0_dense, slot0_dense):
        # the j-th occurrence of a key emits its running window total c0 + j
        return win0_dense[keys].astype(np.int64) + occ + 1


class WindowedSelfJoin(Operator):
    name = "selfjoin"
    #: columnar mode derives matches/costs from per-slot tuple COUNTS and
    #: does not retain the raw tuple payloads (nothing downstream reads them)
    columnar_needs_values = False
    device_mode = "add"

    def __init__(self, bytes_per_tuple: float = 32.0, probe_cost: float = 0.01):
        self.bytes_per_tuple = bytes_per_tuple
        self.probe_cost = probe_cost
        self.columnar_spec = ColumnarSpec(mode="add", slot_bytes=0.0,
                                          bytes_per_unit=bytes_per_tuple,
                                          payload="tuples")

    def process(self, store, interval, key, value):
        ks = store.state(key)
        matches = 0
        for sl in ks.iter_window():
            matches += len(sl.payload)
        cur = ks.slice_for(interval, init=list, size=0.0)
        cur.payload.append(value)
        cur.size += self.bytes_per_tuple
        # one output per match; cost = insert + probes over window
        cost = 1.0 + self.probe_cost * matches
        return [(key, matches)], cost

    def _batch_core(self, store, interval, keys, values, uniq, inv, counts):
        # the j-th of m tuples on a key with c0 window entries probes
        # c0 + (j-1) matches, so total probes = m*c0 + m(m-1)/2 and the last
        # emit is c0 + m - 1; cost = m inserts + probe_cost * total probes.
        grouped = _group_values(inv, counts, values)
        pairs = store.update_many(interval, uniq, init=list, size=0.0)
        outputs = []
        emit = 0.0
        key_cost = np.empty(len(uniq), dtype=np.float64)
        c0s = np.empty(len(uniq), dtype=np.int64)
        for u, (k, m, (ks, cur)) in enumerate(
                zip(uniq.tolist(), counts.tolist(), pairs)):
            c0 = sum(len(sl.payload) for sl in ks.iter_window())
            cur.payload.extend(grouped[u])
            cur.size += self.bytes_per_tuple * m
            probes = m * c0 + m * (m - 1) / 2.0
            emit += probes
            outputs.append((k, c0 + m - 1))
            key_cost[u] = m * 1.0 + self.probe_cost * probes
            c0s[u] = c0
        res = BatchResult(uniq, key_cost, counts.astype(np.float64),
                          float(key_cost.sum()), outputs, emit)
        return res, c0s

    def process_batch(self, store, interval, keys, values):
        uniq, inv, counts = np.unique(keys, return_inverse=True,
                                      return_counts=True)
        res, _ = self._batch_core(store, interval, keys, values, uniq, inv,
                                  counts)
        return res

    def process_batch_emits(self, store, interval, keys, values):
        uniq, inv, counts = np.unique(keys, return_inverse=True,
                                      return_counts=True)
        res, c0s = self._batch_core(store, interval, keys, values, uniq, inv,
                                    counts)
        # the j-th occurrence emits its probe-time match count c0 + (j-1)
        evals = c0s[inv] + _occurrence_index(inv, counts)
        return (res, np.ones(len(keys), dtype=np.int64),
                keys.astype(np.int64, copy=False), evals)

    def process_interval_batch(self, stores, interval, keys, dests, n_tasks,
                               values, collect_emits):
        order, _, gk, gd, counts, gidx, occ = _interval_groups(keys, dests)
        fcounts = counts.astype(np.float64)
        win0, _ = _update_by_dest(stores, interval, gk, gd, fcounts, n_tasks)
        c0s = win0.astype(np.int64)     # window tuple counts before the batch
        probes = counts * c0s + counts * (counts - 1) / 2.0
        key_cost = fcounts * 1.0 + self.probe_cost * probes
        outputs = list(zip(gk.tolist(), (c0s + counts - 1).tolist()))
        res = IntervalBatchResult(
            gk, key_cost, fcounts,
            np.bincount(gd, weights=key_cost, minlength=n_tasks),
            outputs, float(probes.sum()))
        if not collect_emits:
            return res, None
        evals = np.empty(keys.size, dtype=np.int64)
        evals[order] = c0s[gidx] + occ
        return res, (np.ones(keys.size, dtype=np.int64),
                     keys.astype(np.int64, copy=False), evals)

    def device_finish(self, counts, win0, slot0):
        probes = counts * win0 + counts * (counts - 1) / 2.0
        key_cost = counts * 1.0 + self.probe_cost * probes
        return key_cost, win0 + counts - 1, float(probes.sum())

    def device_emit_values(self, keys, occ, win0_dense, slot0_dense):
        # the j-th occurrence emits its probe-time match count c0 + (j-1)
        return win0_dense[keys].astype(np.int64) + occ


class PartialWordCount(Operator):
    """Split-key (PKG-style) word count: emits partial counts that must be
    merged downstream — used to model PKG's extra merge operator (Fig. 2a)."""

    name = "partial_wordcount"
    needs_values = False
    columnar_needs_values = False
    device_mode = "add"
    device_unit_cost = True
    #: one emit per input tuple, keyed by the same key: a downstream WordCount
    #: sums the increments to exact totals no matter how the key was split
    split_safe = True

    def __init__(self, bytes_per_entry: float = 16.0):
        self.bytes_per_entry = bytes_per_entry
        self.columnar_spec = ColumnarSpec(mode="add",
                                          slot_bytes=bytes_per_entry)

    def process(self, store, interval, key, value):
        ks = store.state(key)
        sl = ks.slice_for(interval, init=lambda: {"count": 0},
                          size=self.bytes_per_entry)
        sl.payload["count"] += 1
        return [(key, sl.payload["count"])], 1.0

    def _apply_slices(self, store, interval, uniq, counts):
        """One slice update per unique key; returns pre-batch slice counts."""
        pairs = store.update_many(interval, uniq,
                                  init=lambda: {"count": 0},
                                  size=self.bytes_per_entry)
        c0s = np.empty(len(uniq), dtype=np.int64)
        for i, (m, (_, sl)) in enumerate(zip(counts.tolist(), pairs)):
            c0s[i] = sl.payload["count"]
            sl.payload["count"] = c0s[i] + m
        return c0s

    def _batch_result(self, uniq, counts, c0s, n):
        # partial counts reset per interval slice: emits c0+1 .. c0+m where
        # c0 is the *current slice* count (not the window total).
        outputs = list(zip(uniq.tolist(), (c0s + counts).tolist()))
        emit = float(np.dot(counts, c0s) + np.dot(counts, counts + 1) / 2.0)
        freq = counts.astype(np.float64)
        return BatchResult(uniq, freq.copy(), freq, float(n), outputs, emit)

    def process_batch(self, store, interval, keys, values):
        uniq, counts = np.unique(keys, return_counts=True)
        c0s = self._apply_slices(store, interval, uniq, counts)
        return self._batch_result(uniq, counts, c0s, len(keys))

    def process_batch_emits(self, store, interval, keys, values):
        uniq, inv, counts = np.unique(keys, return_inverse=True,
                                      return_counts=True)
        c0s = self._apply_slices(store, interval, uniq, counts)
        res = self._batch_result(uniq, counts, c0s, len(keys))
        evals = c0s[inv] + _occurrence_index(inv, counts) + 1
        return (res, np.ones(len(keys), dtype=np.int64),
                keys.astype(np.int64, copy=False), evals)

    def process_interval_batch(self, stores, interval, keys, dests, n_tasks,
                               values, collect_emits):
        # partial counts restart per interval slice: c0 is the CURRENT slice
        # count, not the window total
        return _counting_interval_batch(stores, interval, keys, dests,
                                        n_tasks, collect_emits,
                                        window_total=False)

    def device_finish(self, counts, win0, slot0):
        emit = float(np.dot(counts, slot0) + np.dot(counts, counts + 1) / 2.0)
        return counts.astype(np.float64), slot0 + counts, emit

    def device_emit_values(self, keys, occ, win0_dense, slot0_dense):
        return slot0_dense[keys].astype(np.int64) + occ + 1


class MergeCounts(Operator):
    """PKG's downstream merger: combines partial counts per key."""

    name = "merge"
    device_mode = "max"
    #: running max is idempotent/commutative across partial streams — but a
    #: *split* MergeCounts only sees a subset of partials per task, so this
    #: flag marks per-task safety of the fold, not exactness of a split total
    split_safe = True

    def __init__(self):
        self.bytes_per_entry = 16.0
        self.columnar_spec = ColumnarSpec(mode="max",
                                          slot_bytes=self.bytes_per_entry)

    def process(self, store, interval, key, value):
        ks = store.state(key)
        sl = ks.slice_for(interval, init=lambda: {"count": 0},
                          size=self.bytes_per_entry)
        sl.payload["count"] = max(sl.payload["count"], int(value))
        return [], 0.5

    def process_batch(self, store, interval, keys, values):
        # running max over partial counts: order-insensitive, so the batch
        # form is a single max per unique key.
        uniq, inv, counts = np.unique(keys, return_inverse=True,
                                      return_counts=True)
        grouped = _group_values(inv, counts, values)
        pairs = store.update_many(interval, uniq,
                                  init=lambda: {"count": 0},
                                  size=self.bytes_per_entry)
        for u, (_, sl) in enumerate(pairs):
            sl.payload["count"] = max(sl.payload["count"],
                                      max(int(v) for v in grouped[u]))
        freq = counts.astype(np.float64)
        return BatchResult(uniq, 0.5 * freq, freq, 0.5 * float(len(keys)),
                           [], 0.0)

    def process_batch_emits(self, store, interval, keys, values):
        # terminal operator: absorbs partials, emits nothing downstream
        res = self.process_batch(store, interval, keys, values)
        return (res, np.zeros(len(keys), dtype=np.int64),
                np.zeros(0, dtype=np.int64), np.zeros(0, dtype=np.float64))

    def process_interval_batch(self, stores, interval, keys, dests, n_tasks,
                               values, collect_emits):
        order, starts, gk, gd, counts, _, _ = _interval_groups(keys, dests)
        # per-group running max; int cast first to match the scalar int(v)
        vals64 = np.asarray(values).astype(np.int64)
        gmax = np.maximum.reduceat(vals64[order], starts)
        _update_by_dest(stores, interval, gk, gd, gmax.astype(np.float64),
                        n_tasks)
        fcounts = counts.astype(np.float64)
        res = IntervalBatchResult(
            gk, 0.5 * fcounts, fcounts,
            np.bincount(gd, weights=0.5 * fcounts, minlength=n_tasks),
            [], 0.0)
        if not collect_emits:
            return res, None
        return res, (np.zeros(keys.size, dtype=np.int64),
                     np.zeros(0, dtype=np.int64), np.zeros(0, dtype=np.float64))

    def device_finish(self, counts, win0, slot0):
        # terminal operator: absorbs partials, emits nothing downstream
        return 0.5 * counts.astype(np.float64), None, 0.0

    def device_emit_values(self, keys, occ, win0_dense, slot0_dense):
        return None


class Filter(Operator):
    """Stateless selection: forwards tuples whose ``(key, value)`` passes
    ``predicate``, drops the rest — the 0-or-1 fan-out case of the batched
    emit contract (a TPC-H-style selection ahead of a keyed join).

    ``predicate(keys, values) -> bool mask`` must be a vectorized,
    deterministic function of its arguments; the per-tuple path calls it on
    length-1 arrays, so both engine paths evaluate identical predicates.
    """

    name = "filter"
    #: stateless, per-tuple output — any split of a key is trivially correct
    split_safe = True
    #: stateless — the columnar store is never touched, but opting in routes
    #: the stage through the whole-interval single dispatch
    columnar_spec = ColumnarSpec()

    def __init__(self, predicate, cost_per_tuple: float = 0.25):
        self.predicate = predicate
        self.cost_per_tuple = cost_per_tuple

    def process(self, store, interval, key, value):
        keep = bool(np.asarray(self.predicate(
            np.asarray([key], dtype=np.int64), np.asarray([value])))[0])
        return ([(key, value)] if keep else []), self.cost_per_tuple

    def process_batch(self, store, interval, keys, values):
        res, _, _, _ = self.process_batch_emits(store, interval, keys, values)
        return res

    def _select(self, keys, values):
        """Shared selection core: keep mask, kept tuples, last-wins outputs
        over kept tuples only (a dropped tuple never reaches the outputs
        dict), and the emitted-sum under the per-tuple isinstance rule on
        the ORIGINAL payloads — a Python list of ints counts, but its int64
        ndarray conversion would not, so sum from ``values`` when the
        caller passed a non-ndarray sequence."""
        vals = (values if isinstance(values, np.ndarray)
                else np.asarray(values if values is not None
                                else [None] * len(keys)))
        keep = np.asarray(self.predicate(keys, vals), dtype=bool)
        kept_k = keys[keep]
        kept_v = vals[keep]
        outputs = []
        if kept_k.size:
            rev_uniq, rev_first = np.unique(kept_k[::-1], return_index=True)
            outputs = list(zip(rev_uniq.tolist(),
                               kept_v[::-1][rev_first].tolist()))
        if isinstance(values, np.ndarray) or values is None:
            emit_sum = _numeric_emit_sum(kept_v)
        else:
            emit_sum = _numeric_emit_sum(
                [values[i] for i in np.nonzero(keep)[0]])
        return keep, kept_k, kept_v, outputs, emit_sum

    def process_batch_emits(self, store, interval, keys, values):
        keep, kept_k, kept_v, outputs, emit_sum = self._select(keys, values)
        uniq, counts = np.unique(keys, return_counts=True)
        freq = counts.astype(np.float64)
        res = BatchResult(uniq, self.cost_per_tuple * freq, freq,
                          self.cost_per_tuple * float(len(keys)), outputs,
                          emit_sum)
        return (res, keep.astype(np.int64),
                kept_k.astype(np.int64, copy=False), kept_v)

    def process_interval_batch(self, stores, interval, keys, dests, n_tasks,
                               values, collect_emits):
        keep, kept_k, kept_v, outputs, emit_sum = self._select(keys, values)
        _, _, gk, gd, counts, _, _ = _interval_groups(keys, dests)
        fcounts = counts.astype(np.float64)
        res = IntervalBatchResult(
            gk, self.cost_per_tuple * fcounts, fcounts,
            np.bincount(gd, weights=self.cost_per_tuple * fcounts,
                        minlength=n_tasks),
            outputs, emit_sum)
        if not collect_emits:
            return res, None
        return res, (keep.astype(np.int64),
                     kept_k.astype(np.int64, copy=False), kept_v)
