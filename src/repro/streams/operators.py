"""Stateful operators — the paper's two real workloads (Sec. V).

* :class:`WordCount` — "store and aggregation on keywords" (Social data):
  per-key counts over the sliding window.
* :class:`WindowedSelfJoin` — "self-join over sliding window" (Stock data):
  each incoming tuple joins against all tuples of the same key within the
  window; join work (and hence c(k)) grows superlinearly with key frequency,
  which is exactly the skew-amplification the paper targets.

Operators report per-tuple cost so the engine can measure c(k) instead of
assuming cost == frequency (the paper makes the same distinction).

Batched execution
-----------------
The vectorized engine (``KeyedStage(vectorized=True)``, the default — see
:mod:`repro.streams.engine` and ``docs/architecture.md``) hands each task a
whole micro-batch segment at once via :meth:`Operator.process_batch`. The
built-in operators implement it with closed-form per-key arithmetic: a key
hit ``m`` times in a segment updates its state once and derives the same
emits/costs the per-tuple path would produce tuple by tuple. Custom
operators only need ``process``; the base-class ``process_batch`` falls back
to the per-tuple loop, so they stay correct (just not fast) under the
vectorized engine. Set ``needs_values = False`` on operators that ignore
tuple payloads so the engine can skip materializing per-segment value lists.
"""

from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Sequence, Tuple

import numpy as np

from .state import TaskStateStore


@dataclasses.dataclass
class BatchResult:
    """What one :meth:`Operator.process_batch` call produced.

    The engine folds these straight into its array accumulators (per-task
    cost, per-key cost/freq via ``np.add.at``) — no per-tuple Python on the
    hot path.

    Attributes:
      uniq_keys: (U,) int64 — unique keys of the segment, sorted ascending.
      key_cost:  (U,) float64 — summed c(k) contribution per unique key.
      key_freq:  (U,) float64 — tuple count per unique key.
      task_cost: total cost charged to the task (== key_cost.sum()).
      outputs:   final (key, value) emit per key — the last emit the
                 per-tuple path would have written (downstream is last-wins).
      emit_sum:  sum of *all* numeric emitted values the per-tuple path
                 would have produced (not just the final ones).
    """

    uniq_keys: np.ndarray
    key_cost: np.ndarray
    key_freq: np.ndarray
    task_cost: float
    outputs: List[Tuple[int, Any]]
    emit_sum: float


def _group_values(inv: np.ndarray, counts: np.ndarray,
                  values: Sequence[Any]) -> List[List[Any]]:
    """Split ``values`` into per-unique-key lists (stream order preserved)."""
    order = np.argsort(inv, kind="stable")
    bounds = np.concatenate(([0], np.cumsum(counts)))
    if isinstance(values, np.ndarray):
        vs = values[order]
        return [vs[bounds[u]:bounds[u + 1]].tolist()
                for u in range(len(counts))]
    return [[values[i] for i in order[bounds[u]:bounds[u + 1]]]
            for u in range(len(counts))]


class Operator:
    name = "op"
    #: set False when ``process_batch`` never reads tuple payloads — lets the
    #: vectorized engine skip gathering per-segment value lists entirely.
    needs_values = True

    def process(self, store: TaskStateStore, interval: int, key: int,
                value: Any) -> Tuple[List[Tuple[int, Any]], float]:
        """Returns (output tuples, cost units consumed)."""
        raise NotImplementedError

    def process_batch(self, store: TaskStateStore, interval: int,
                      keys: np.ndarray,
                      values: Optional[Sequence[Any]]) -> BatchResult:
        """Process one task's micro-batch segment; default per-tuple fallback.

        Semantically equivalent to calling :meth:`process` for each tuple in
        stream order. Built-in operators override this with vectorized
        closed forms; custom operators inherit this loop and remain correct.
        """
        key_cost: dict = {}
        key_freq: dict = {}
        outputs: dict = {}
        emit = 0.0
        total = 0.0
        vals = values if values is not None else [None] * len(keys)
        for k, v in zip(keys.tolist(), vals):
            outs, cost = self.process(store, interval, k, v)
            total += cost
            key_cost[k] = key_cost.get(k, 0.0) + cost
            key_freq[k] = key_freq.get(k, 0.0) + 1.0
            for ok, ov in outs:
                outputs[ok] = ov
                if isinstance(ov, (int, float)):
                    emit += float(ov)
        uniq = np.fromiter(sorted(key_cost), dtype=np.int64, count=len(key_cost))
        return BatchResult(
            uniq_keys=uniq,
            key_cost=np.fromiter((key_cost[int(k)] for k in uniq),
                                 dtype=np.float64, count=len(uniq)),
            key_freq=np.fromiter((key_freq[int(k)] for k in uniq),
                                 dtype=np.float64, count=len(uniq)),
            task_cost=total, outputs=list(outputs.items()), emit_sum=emit)


class WordCount(Operator):
    name = "wordcount"
    needs_values = False

    def __init__(self, bytes_per_entry: float = 16.0):
        self.bytes_per_entry = bytes_per_entry

    def process(self, store, interval, key, value):
        ks = store.state(key)
        sl = ks.slice_for(interval, init=lambda: {"count": 0},
                          size=self.bytes_per_entry)
        sl.payload["count"] += 1
        total = sum(s.payload["count"] for s in ks.iter_window())
        return [(key, total)], 1.0

    def process_batch(self, store, interval, keys, values):
        # m tuples on a key whose window already counts c0 emit the running
        # totals c0+1 .. c0+m; their sum is m*c0 + m(m+1)/2 and the final
        # (last-wins) emit is c0+m. One state update per unique key.
        uniq, counts = np.unique(keys, return_counts=True)
        pairs = store.update_many(interval, uniq, init=lambda: {"count": 0},
                                  size=self.bytes_per_entry)
        c0s = np.empty(len(uniq), dtype=np.int64)
        for i, (m, (ks, sl)) in enumerate(zip(counts.tolist(), pairs)):
            c0 = 0
            for s in ks.slices.values():
                c0 += s.payload["count"]
            sl.payload["count"] += m
            c0s[i] = c0
        # emits per key are the running totals c0+1 .. c0+m: their sum and
        # the final value are exact integer arithmetic, done array-wide
        totals = c0s + counts
        outputs = list(zip(uniq.tolist(), totals.tolist()))
        emit = float(np.dot(counts, c0s) + np.dot(counts, counts + 1) / 2.0)
        freq = counts.astype(np.float64)
        return BatchResult(uniq, freq.copy(), freq, float(len(keys)),
                           outputs, emit)


class WindowedSelfJoin(Operator):
    name = "selfjoin"

    def __init__(self, bytes_per_tuple: float = 32.0, probe_cost: float = 0.01):
        self.bytes_per_tuple = bytes_per_tuple
        self.probe_cost = probe_cost

    def process(self, store, interval, key, value):
        ks = store.state(key)
        matches = 0
        for sl in ks.iter_window():
            matches += len(sl.payload)
        cur = ks.slice_for(interval, init=list, size=0.0)
        cur.payload.append(value)
        cur.size += self.bytes_per_tuple
        # one output per match; cost = insert + probes over window
        cost = 1.0 + self.probe_cost * matches
        return [(key, matches)], cost

    def process_batch(self, store, interval, keys, values):
        # the j-th of m tuples on a key with c0 window entries probes
        # c0 + (j-1) matches, so total probes = m*c0 + m(m-1)/2 and the last
        # emit is c0 + m - 1; cost = m inserts + probe_cost * total probes.
        uniq, inv, counts = np.unique(keys, return_inverse=True,
                                      return_counts=True)
        grouped = _group_values(inv, counts, values)
        pairs = store.update_many(interval, uniq, init=list, size=0.0)
        outputs = []
        emit = 0.0
        key_cost = np.empty(len(uniq), dtype=np.float64)
        for u, (k, m, (ks, cur)) in enumerate(
                zip(uniq.tolist(), counts.tolist(), pairs)):
            c0 = sum(len(sl.payload) for sl in ks.iter_window())
            cur.payload.extend(grouped[u])
            cur.size += self.bytes_per_tuple * m
            probes = m * c0 + m * (m - 1) / 2.0
            emit += probes
            outputs.append((k, c0 + m - 1))
            key_cost[u] = m * 1.0 + self.probe_cost * probes
        return BatchResult(uniq, key_cost, counts.astype(np.float64),
                           float(key_cost.sum()), outputs, emit)


class PartialWordCount(Operator):
    """Split-key (PKG-style) word count: emits partial counts that must be
    merged downstream — used to model PKG's extra merge operator (Fig. 2a)."""

    name = "partial_wordcount"
    needs_values = False

    def __init__(self, bytes_per_entry: float = 16.0):
        self.bytes_per_entry = bytes_per_entry

    def process(self, store, interval, key, value):
        ks = store.state(key)
        sl = ks.slice_for(interval, init=lambda: {"count": 0},
                          size=self.bytes_per_entry)
        sl.payload["count"] += 1
        return [(key, sl.payload["count"])], 1.0

    def process_batch(self, store, interval, keys, values):
        # partial counts reset per interval slice: emits c0+1 .. c0+m where
        # c0 is the *current slice* count (not the window total).
        uniq, counts = np.unique(keys, return_counts=True)
        pairs = store.update_many(interval, uniq,
                                  init=lambda: {"count": 0},
                                  size=self.bytes_per_entry)
        outputs = []
        emit = 0.0
        for k, m, (_, sl) in zip(uniq.tolist(), counts.tolist(), pairs):
            c0 = sl.payload["count"]
            sl.payload["count"] = c0 + m
            outputs.append((k, c0 + m))
            emit += m * c0 + m * (m + 1) / 2.0
        freq = counts.astype(np.float64)
        return BatchResult(uniq, freq.copy(), freq, float(len(keys)),
                           outputs, emit)


class MergeCounts(Operator):
    """PKG's downstream merger: combines partial counts per key."""

    name = "merge"

    def __init__(self):
        self.bytes_per_entry = 16.0

    def process(self, store, interval, key, value):
        ks = store.state(key)
        sl = ks.slice_for(interval, init=lambda: {"count": 0},
                          size=self.bytes_per_entry)
        sl.payload["count"] = max(sl.payload["count"], int(value))
        return [], 0.5

    def process_batch(self, store, interval, keys, values):
        # running max over partial counts: order-insensitive, so the batch
        # form is a single max per unique key.
        uniq, inv, counts = np.unique(keys, return_inverse=True,
                                      return_counts=True)
        grouped = _group_values(inv, counts, values)
        pairs = store.update_many(interval, uniq,
                                  init=lambda: {"count": 0},
                                  size=self.bytes_per_entry)
        for u, (_, sl) in enumerate(pairs):
            sl.payload["count"] = max(sl.payload["count"],
                                      max(int(v) for v in grouped[u]))
        freq = counts.astype(np.float64)
        return BatchResult(uniq, 0.5 * freq, freq, 0.5 * float(len(keys)),
                           [], 0.0)
