"""Stateful operators — the paper's two real workloads (Sec. V).

* :class:`WordCount` — "store and aggregation on keywords" (Social data):
  per-key counts over the sliding window.
* :class:`WindowedSelfJoin` — "self-join over sliding window" (Stock data):
  each incoming tuple joins against all tuples of the same key within the
  window; join work (and hence c(k)) grows superlinearly with key frequency,
  which is exactly the skew-amplification the paper targets.

Operators report per-tuple cost so the engine can measure c(k) instead of
assuming cost == frequency (the paper makes the same distinction).
"""

from __future__ import annotations

from typing import Any, List, Tuple

from .state import TaskStateStore


class Operator:
    name = "op"

    def process(self, store: TaskStateStore, interval: int, key: int,
                value: Any) -> Tuple[List[Tuple[int, Any]], float]:
        """Returns (output tuples, cost units consumed)."""
        raise NotImplementedError


class WordCount(Operator):
    name = "wordcount"

    def __init__(self, bytes_per_entry: float = 16.0):
        self.bytes_per_entry = bytes_per_entry

    def process(self, store, interval, key, value):
        ks = store.state(key)
        sl = ks.slice_for(interval, init=lambda: {"count": 0},
                          size=self.bytes_per_entry)
        sl.payload["count"] += 1
        total = sum(s.payload["count"] for s in ks.iter_window())
        return [(key, total)], 1.0


class WindowedSelfJoin(Operator):
    name = "selfjoin"

    def __init__(self, bytes_per_tuple: float = 32.0, probe_cost: float = 0.01):
        self.bytes_per_tuple = bytes_per_tuple
        self.probe_cost = probe_cost

    def process(self, store, interval, key, value):
        ks = store.state(key)
        matches = 0
        for sl in ks.iter_window():
            matches += len(sl.payload)
        cur = ks.slice_for(interval, init=list, size=0.0)
        cur.payload.append(value)
        cur.size += self.bytes_per_tuple
        # one output per match; cost = insert + probes over window
        cost = 1.0 + self.probe_cost * matches
        return [(key, matches)], cost


class PartialWordCount(Operator):
    """Split-key (PKG-style) word count: emits partial counts that must be
    merged downstream — used to model PKG's extra merge operator (Fig. 2a)."""

    name = "partial_wordcount"

    def __init__(self, bytes_per_entry: float = 16.0):
        self.bytes_per_entry = bytes_per_entry

    def process(self, store, interval, key, value):
        ks = store.state(key)
        sl = ks.slice_for(interval, init=lambda: {"count": 0},
                          size=self.bytes_per_entry)
        sl.payload["count"] += 1
        return [(key, sl.payload["count"])], 1.0


class MergeCounts(Operator):
    """PKG's downstream merger: combines partial counts per key."""

    name = "merge"

    def __init__(self):
        self.bytes_per_entry = 16.0

    def process(self, store, interval, key, value):
        ks = store.state(key)
        sl = ks.slice_for(interval, init=lambda: {"count": 0},
                          size=self.bytes_per_entry)
        sl.payload["count"] = max(sl.payload["count"], int(value))
        return [], 0.5
