"""Multi-stage streaming topologies: chained keyed operators (paper Fig. 5,
run per logical operator).

A real DSPE job is a chain ``O_1 -> O_2 -> ...`` where every operator is
key-partitioned over its own task fleet and tuples are *re-keyed* between
operators — the paper's protocol runs independently at each operator, and
the multi-stage benchmarks it evaluates (TPC-H, Social/Stock applications)
are exactly such chains. :class:`Topology` models that:

* each :class:`StageSpec` wraps a full :class:`~repro.streams.engine.KeyedStage`
  — its own :class:`~repro.core.controller.RebalanceController`, its own
  ``Assignment`` (routing table + hash), its own ``TaskStateStore`` fleet;
* stage *i*'s batched emit stream
  (:meth:`~repro.streams.engine.KeyedStage.process_interval_emits`, built on
  the operators' ``process_batch_emits`` closed forms) is re-keyed by the
  next spec's vectorized ``rekey`` into stage *i+1*'s micro-batch — arrays
  end to end, no per-tuple Python, so the vectorized (and pallas-substrate)
  fast path survives stage boundaries;
* rebalances at different stages may fire within the *same* interval, each
  pausing only its own Delta keys and replaying them on Resume —
  ``tests/test_topology.py`` proves the whole pipeline bit-identical to the
  per-tuple reference path through exactly that scenario.

Performance model
-----------------
A tuple admitted in interval ``T_i`` must clear every stage within the
interval, so the pipeline's critical path is the *sum* of per-stage critical
paths (each already ``max task cost + migration stall``):

    makespan_pipeline = sum_i (makespan_i + stall_i)
    throughput        = source tuples / makespan_pipeline

the multi-stage extension of the single-stage :class:`IntervalReport` model
(relative units, the same shape of quantity the paper measures on Storm).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core import (Assignment, BalanceConfig, ModHash,
                        RebalanceController)

from .engine import IntervalReport, KeyedStage
from .operators import Operator

#: Vectorized edge re-keying: maps the upstream emit stream's (keys, values)
#: arrays to this stage's routing keys. ``values`` may be None for stage 0.
Rekey = Callable[[np.ndarray, Optional[np.ndarray]], np.ndarray]


@dataclasses.dataclass
class StageSpec:
    """One pipeline stage: a named KeyedStage plus its inbound re-keying.

    ``rekey`` (optional) maps incoming ``(keys, values)`` to the routing
    keys this stage partitions on — e.g. orderkey -> custkey ahead of a
    join, or word -> bucket ahead of a top-k front. ``None`` routes on the
    incoming keys unchanged. It must be a deterministic vectorized function
    so both engine paths (and repeated runs) derive the same partitioning.
    """

    name: str
    stage: KeyedStage
    rekey: Optional[Rekey] = None


@dataclasses.dataclass
class TopologyReport:
    """Per-interval pipeline roll-up over the per-stage IntervalReports."""

    interval: int
    tuples_in: int                        # source tuples admitted
    stage_tuples: List[int]               # input size per stage (post-filter)
    stage_reports: List[IntervalReport]
    critical_path: float                  # sum_i (makespan_i + stall_i)
    throughput: float                     # tuples_in / critical_path
    migrated_bytes: float                 # summed over stages
    buffered: int                         # tuples paused, summed over stages


def keyed_stage(operator: Operator, n_tasks: int, theta_max: float, *,
                table_max: int = 2_000, window: int = 2, seed: int = 0,
                algorithm="mixed", hash_cls=ModHash, vectorized: bool = True,
                substrate: str = "numpy", state_backend: str = "auto",
                n_shards: Optional[int] = None,
                kernel_interpret: Optional[bool] = None,
                migration_bandwidth: float = 1e6,
                stats_mode: str = "exact",
                sketch=None) -> KeyedStage:
    """Convenience constructor: one stage = operator + fresh controller fleet.

    Every call builds an independent ``Assignment``/``RebalanceController``
    pair, which is what per-stage rebalance requires — stages must never
    share a controller (their tables, Delta sets and trigger decisions are
    per-operator state, exactly as in the paper's per-operator protocol).
    ``state_backend``/``kernel_interpret`` pass straight through to
    :class:`~repro.streams.engine.KeyedStage` — with the defaults, every
    built-in-operator stage gets the columnar store and the whole-interval
    single dispatch, so the no-per-key-Python property holds across the
    whole pipeline.

    ``algorithm`` takes the unified strategy spec — a registered name from
    :func:`repro.core.balancer.strategy_names` (table planners like
    ``"mixed"``/``"mintable"``/``"minmig"``/``"readj"`` *or* choice routers
    like ``"pkg"``/``"potc"``/``"wchoices"``), a bare planner callable, or a
    configured :class:`~repro.core.balancer.PartitionStrategy` instance —
    identical semantics to ``RebalanceController(algorithm=)`` and
    ``KeyedStage(algorithm=)`` (all three delegate to
    :meth:`~repro.core.controller.RebalanceController.use_algorithm`).
    Router strategies split keys across tasks, so the operator must be
    ``split_safe`` (pair e.g. ``PartialWordCount`` with a downstream
    ``WordCount`` merge stage — see :func:`router_merge_topology`).

    ``stats_mode``/``sketch`` pass straight through to
    :class:`~repro.core.controller.RebalanceController`: ``"sketch"``
    streams step-1 measurement through a count-min sketch + SpaceSaving
    head tracker (O(H + sketch) controller memory instead of O(K) — see
    ``repro.core.balancer.sketch``), with ``sketch=`` an optional
    :class:`~repro.core.balancer.sketch.SketchConfig`.
    """
    controller = RebalanceController(
        Assignment(hash_cls(n_tasks, seed=seed)),
        BalanceConfig(theta_max=theta_max, table_max=table_max,
                      window=window),
        algorithm=algorithm,
        stats_mode=stats_mode, sketch=sketch)
    return KeyedStage(operator, controller, window=window,
                      vectorized=vectorized, substrate=substrate,
                      state_backend=state_backend, n_shards=n_shards,
                      kernel_interpret=kernel_interpret,
                      migration_bandwidth=migration_bandwidth)


def router_merge_topology(partial_op: Operator, merge_op: Operator,
                          n_tasks: int, theta_max: float, *,
                          algorithm="pkg", merge_tasks: Optional[int] = None,
                          merge_algorithm="mixed", seed: int = 0,
                          **stage_kwargs) -> "Topology":
    """The canonical choice-router pairing: split stage + downstream merge.

    Choice routers (``"pkg"``/``"potc"``/``"wchoices"``) split one key's
    tuples across candidate tasks, which is exactly the PKG papers' two-step
    dataflow (Fig. 2a of 1510.07623): a *split-safe* partial operator under
    the router, then a key-grouped merge operator that recombines the
    partials. This helper wires that shape — ``partial_op`` under
    ``algorithm`` feeding ``merge_op`` under a table planner (the merge
    stage sees each key on one task again, so any planner applies).

    ``stage_kwargs`` pass through to both :func:`keyed_stage` calls
    (``window=``, ``state_backend=``, ...).
    """
    return Topology([
        StageSpec("split", keyed_stage(partial_op, n_tasks, theta_max,
                                       algorithm=algorithm, seed=seed,
                                       **stage_kwargs)),
        StageSpec("merge", keyed_stage(merge_op, merge_tasks or n_tasks,
                                       theta_max, algorithm=merge_algorithm,
                                       seed=seed + 1, **stage_kwargs)),
    ])


class Topology:
    """A chain of KeyedStages with vectorized stage-to-stage re-keying."""

    def __init__(self, stages: Sequence[StageSpec]):
        specs = list(stages)
        if not specs:
            raise ValueError("Topology needs at least one stage")
        names = [s.name for s in specs]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate stage names: {names}")
        self.specs = specs
        self.reports: List[TopologyReport] = []
        # the final stage's emit stream from the last processed interval
        # (e.g. the top-k front's per-bucket maxima), for consumers/tests
        self.last_emit_keys: np.ndarray = np.zeros(0, dtype=np.int64)
        self.last_emit_values: np.ndarray = np.zeros(0, dtype=np.float64)
        self._interval = 0

    # -- introspection ---------------------------------------------------------
    @property
    def n_stages(self) -> int:
        return len(self.specs)

    @property
    def names(self) -> List[str]:
        return [s.name for s in self.specs]

    def __getitem__(self, name: str) -> KeyedStage:
        for spec in self.specs:
            if spec.name == name:
                return spec.stage
        raise KeyError(name)

    def rebalances_by_stage(self) -> Dict[str, List[int]]:
        """Stage name -> intervals (1-based) where its controller triggered.

        This is how the multi-stage tests assert rebalances fired at
        *different* stages within the same interval: intersect the lists.
        """
        return {spec.name: spec.stage.controller.triggered_intervals()
                for spec in self.specs}

    def total_state_keys(self) -> int:
        """Keyed state held across every stage's store fleet (leak checks)."""
        return sum(spec.stage.total_state_keys() for spec in self.specs)

    # -- checkpointed recovery (repro.streams.checkpoint) ----------------------
    def checkpoint(self):
        """Coherent pipeline snapshot: every stage at this source boundary."""
        from .checkpoint import checkpoint_topology
        return checkpoint_topology(self)

    def restore(self, ckpt) -> None:
        """Rewind every stage (and the pipeline clock) to ``ckpt``."""
        from .checkpoint import restore_topology
        restore_topology(self, ckpt)

    # -- one interval through the whole pipeline -------------------------------
    def process_interval(self, keys: np.ndarray,
                         values: Optional[np.ndarray] = None
                         ) -> TopologyReport:
        """Run one interval of source traffic through every stage.

        ``keys``/``values`` feed stage 0 (after its ``rekey``, if any); each
        subsequent stage consumes the previous stage's emit stream. Every
        stage runs its own full protocol round — stats, trigger decision,
        plan, pause/migrate/replay — against its own controller.
        """
        self._interval += 1
        cur_keys = np.asarray(keys, dtype=np.int64)
        cur_vals: Optional[np.ndarray] = values
        tuples_in = int(cur_keys.shape[0])
        stage_tuples: List[int] = []
        stage_reports: List[IntervalReport] = []
        for spec in self.specs:
            if spec.rekey is not None:
                cur_keys = np.asarray(spec.rekey(cur_keys, cur_vals),
                                      dtype=np.int64)
            stage_tuples.append(int(cur_keys.shape[0]))
            rep, cur_keys, cur_vals = spec.stage.process_interval_emits(
                cur_keys, cur_vals)
            stage_reports.append(rep)
        self.last_emit_keys, self.last_emit_values = cur_keys, cur_vals
        critical = float(sum(r.makespan + r.migration_stall
                             for r in stage_reports))
        report = TopologyReport(
            interval=self._interval, tuples_in=tuples_in,
            stage_tuples=stage_tuples, stage_reports=stage_reports,
            critical_path=critical,
            throughput=tuples_in / critical if critical > 0 else 0.0,
            migrated_bytes=float(sum(r.migrated_bytes for r in stage_reports)),
            buffered=int(sum(r.buffered for r in stage_reports)),
        )
        self.reports.append(report)
        return report
