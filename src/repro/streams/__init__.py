"""Faithful stream-processing substrate: engine, operators, state, generator,
pluggable state backends, multi-stage topologies, and checkpointed recovery
with deterministic failure injection."""

from .backends import (BACKENDS, ColumnarBackend, DeviceBackend,
                       ObjectBackend, StateBackend, register_backend)
from .checkpoint import (CheckpointStore, StageCheckpoint, TopologyCheckpoint,
                         checkpoint_stage, checkpoint_topology, restore_stage,
                         restore_topology)
from .engine import STATE_BACKENDS, SUBSTRATES, IntervalReport, KeyedStage
from .faults import (ChaosRunner, DropDelivery, DuplicateDelivery, FaultPlan,
                     FaultInjector, KillTask, RecoveryEvent, StallTask,
                     TaskKilled, TaskStalled)
from .generator import WorkloadGen, zipf_frequencies
from .operators import (BatchResult, Filter, IntervalBatchResult, MergeCounts,
                        Operator, PartialWordCount, WindowedSelfJoin,
                        WordCount)
from .state import (ColumnarSpec, ColumnarStateStore, KeyState,
                    TaskStateStore)
from .topology import (StageSpec, Topology, TopologyReport, keyed_stage,
                       router_merge_topology)

__all__ = [
    "STATE_BACKENDS", "SUBSTRATES", "IntervalReport", "KeyedStage",
    "WorkloadGen", "zipf_frequencies", "BatchResult", "Filter",
    "IntervalBatchResult", "MergeCounts", "Operator", "PartialWordCount",
    "WindowedSelfJoin", "WordCount", "ColumnarSpec", "ColumnarStateStore",
    "KeyState", "TaskStateStore", "StageSpec", "Topology", "TopologyReport",
    "keyed_stage", "router_merge_topology", "DeviceStateFleet",
    "DeviceTaskView",
    "BACKENDS", "StateBackend", "ObjectBackend", "ColumnarBackend",
    "DeviceBackend", "register_backend", "ShardedDeviceBackend",
    "ShardedStateFleet",
    "CheckpointStore", "StageCheckpoint", "TopologyCheckpoint",
    "checkpoint_stage", "checkpoint_topology", "restore_stage",
    "restore_topology",
    "ChaosRunner", "DropDelivery", "DuplicateDelivery", "FaultPlan",
    "FaultInjector", "KillTask", "RecoveryEvent", "StallTask",
    "TaskKilled", "TaskStalled",
]


def __getattr__(name):
    # The device/sharded backends import jax at module scope; loading them
    # lazily keeps `import repro.streams` jax-free for ModHash/object-backend
    # users.
    if name in ("DeviceStateFleet", "DeviceTaskView"):
        from . import device
        return getattr(device, name)
    if name in ("ShardedDeviceBackend", "ShardedStateFleet"):
        from . import sharded
        return getattr(sharded, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
