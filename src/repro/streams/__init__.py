"""Faithful stream-processing substrate: engine, operators, state, generator,
and multi-stage topologies."""

from .engine import STATE_BACKENDS, SUBSTRATES, IntervalReport, KeyedStage
from .generator import WorkloadGen, zipf_frequencies
from .operators import (BatchResult, Filter, IntervalBatchResult, MergeCounts,
                        Operator, PartialWordCount, WindowedSelfJoin,
                        WordCount)
from .state import (ColumnarSpec, ColumnarStateStore, KeyState,
                    TaskStateStore)
from .topology import StageSpec, Topology, TopologyReport, keyed_stage

__all__ = [
    "STATE_BACKENDS", "SUBSTRATES", "IntervalReport", "KeyedStage",
    "WorkloadGen", "zipf_frequencies", "BatchResult", "Filter",
    "IntervalBatchResult", "MergeCounts", "Operator", "PartialWordCount",
    "WindowedSelfJoin", "WordCount", "ColumnarSpec", "ColumnarStateStore",
    "KeyState", "TaskStateStore", "StageSpec", "Topology", "TopologyReport",
    "keyed_stage", "DeviceStateFleet", "DeviceTaskView",
]


def __getattr__(name):
    # The device backend imports jax at module scope; loading it lazily keeps
    # `import repro.streams` jax-free for ModHash/object-backend users.
    if name in ("DeviceStateFleet", "DeviceTaskView"):
        from . import device
        return getattr(device, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
