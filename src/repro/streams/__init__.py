"""Faithful stream-processing substrate: engine, operators, state, generator."""

from .engine import SUBSTRATES, IntervalReport, KeyedStage
from .generator import WorkloadGen, zipf_frequencies
from .operators import (BatchResult, MergeCounts, Operator, PartialWordCount,
                        WindowedSelfJoin, WordCount)
from .state import KeyState, TaskStateStore

__all__ = [
    "SUBSTRATES", "IntervalReport", "KeyedStage", "WorkloadGen",
    "zipf_frequencies", "BatchResult", "MergeCounts", "Operator",
    "PartialWordCount", "WindowedSelfJoin", "WordCount", "KeyState",
    "TaskStateStore",
]
