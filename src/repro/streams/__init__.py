"""Faithful stream-processing substrate: engine, operators, state, generator."""

from .engine import IntervalReport, KeyedStage
from .generator import WorkloadGen, zipf_frequencies
from .operators import (MergeCounts, Operator, PartialWordCount, WindowedSelfJoin,
                        WordCount)
from .state import KeyState, TaskStateStore

__all__ = [
    "IntervalReport", "KeyedStage", "WorkloadGen", "zipf_frequencies",
    "MergeCounts", "Operator", "PartialWordCount", "WindowedSelfJoin",
    "WordCount", "KeyState", "TaskStateStore",
]
