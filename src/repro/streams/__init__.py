"""Faithful stream-processing substrate: engine, operators, state, generator,
and multi-stage topologies."""

from .engine import SUBSTRATES, IntervalReport, KeyedStage
from .generator import WorkloadGen, zipf_frequencies
from .operators import (BatchResult, Filter, MergeCounts, Operator,
                        PartialWordCount, WindowedSelfJoin, WordCount)
from .state import KeyState, TaskStateStore
from .topology import StageSpec, Topology, TopologyReport, keyed_stage

__all__ = [
    "SUBSTRATES", "IntervalReport", "KeyedStage", "WorkloadGen",
    "zipf_frequencies", "BatchResult", "Filter", "MergeCounts", "Operator",
    "PartialWordCount", "WindowedSelfJoin", "WordCount", "KeyState",
    "TaskStateStore", "StageSpec", "Topology", "TopologyReport",
    "keyed_stage",
]
