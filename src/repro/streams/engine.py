"""Interval-synchronous DSPE with the paper's rebalance protocol (Fig. 5).

One keyed stage = N_D task instances consuming a key-partitioned tuple
stream under the controller's mixed assignment function. Intervals are
discretized (paper Sec. II-A); each interval is processed in micro-batches so
the Pause -> migrate -> Resume protocol has real in-flight traffic to handle:

  * tuples whose key is in Delta(F, F') during the migration window are
    buffered ("cached locally" per the paper) and replayed on Resume;
  * tuples for all other keys flow uninterrupted (the paper's key property);
  * per-key state moves between task stores atomically at the boundary.

The engine also produces the performance model used by the benchmarks:
interval makespan = max per-task cost + migration stall, so throughput =
tuples / makespan (relative units; the paper measures the same shape of
quantity on Storm).

Router + controller shell over pluggable state backends
-------------------------------------------------------
:class:`KeyedStage` itself only owns what is backend-independent: routing
(``_dest_batch``, numpy or the Pallas kernel), the controller handoff and
report assembly (``_finish_interval``), the pause-window clock, elastic
scaling, and the per-tuple reference loop (``vectorized=False``) that serves
as the parity oracle. Everything state-shaped — store layout, interval
execution, migration, step-1 stats — lives behind the
:class:`~repro.streams.backends.StateBackend` protocol; see
:mod:`repro.streams.backends` for the object/columnar/device backends and
:mod:`repro.streams.sharded` for the multi-device mesh backend.
``tests/test_engine_parity.py`` proves the vectorized backends produce
:class:`IntervalReport` streams identical to the reference loop, and
``benchmarks/engine_fastpath.py`` measures the speedups.

Multi-stage topologies chain stages through
:meth:`KeyedStage.process_interval_emits`, which additionally returns the
operator's full emit stream as ``(keys, values)`` arrays in canonical
source-position order (see :mod:`repro.streams.topology` and the batched
emit contract in :mod:`repro.streams.operators`).

Substrate flag
--------------
``substrate="numpy"`` (default) computes routing and stats on host numpy.
``substrate="pallas"`` runs routing through the Pallas mixed-dispatch kernel
(:mod:`repro.kernels.routing_lookup`) and step-1 stats aggregation through
the fused histogram kernel (:mod:`repro.kernels.key_stats`), with the numpy
path as the reference semantics. Requirements: the assignment's hash router
must be :class:`repro.core.balancer.hashing.Hash32` (the device-canonical
fmix32 hash — ``ModHash`` uses splitmix64, which the kernels do not
implement) and key ids must fit int32. Stats come back float32, so reports
match numpy to ~1e-6 relative rather than bit-for-bit. See
``docs/architecture.md`` ("Kernels") for when to flip this flag.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.balancer import Assignment, KeyStats, metrics
from repro.core.controller import RebalanceController

from .backends import SKETCH_PENDING, resolve_backend
from .operators import Operator

SUBSTRATES = ("numpy", "pallas")
STATE_BACKENDS = ("auto", "columnar", "object", "device", "sharded")


@dataclasses.dataclass
class IntervalReport:
    interval: int
    tuples: int
    makespan: float              # max task cost (critical path)
    migration_stall: float       # migration bytes / bandwidth
    throughput: float            # tuples / (makespan + stall)
    skewness: float              # max load / mean load
    theta: float
    migrated_bytes: float
    table_size: int
    plan_time_s: float
    buffered: int                # tuples held during Pause
    task_loads: np.ndarray


class KeyedStage:
    """N_D task instances + controller-owned assignment (one logical operator).

    Args:
      vectorized: use the array-at-a-time fast path (default). ``False``
        selects the per-tuple reference loop — same results, ~10x slower;
        kept for parity testing and as executable documentation.
      substrate: ``"numpy"`` or ``"pallas"`` — see the module docstring.
      state_backend: which :class:`~repro.streams.backends.StateBackend`
        holds the keyed state. ``"auto"`` (default) resolves device >
        columnar > object: the columnar store when the operator declares a
        ``columnar_spec`` and the stage is vectorized — state then lives in
        flat per-task arrays and each macro-batch is ONE whole-interval
        operator dispatch — promoted to ``"device"`` only when the operator
        also has device closed forms (``device_mode``), the router is Hash32
        AND jax runs on an accelerator backend (on CPU the columnar store
        wins, so auto behavior there is unchanged). ``"object"`` forces the
        dict-of-KeyState store (the compatibility/parity backend, and the
        only one custom per-tuple operators can use); ``"columnar"`` forces
        the array store; ``"device"`` keeps state as device-resident arrays
        and fuses the whole interval into one jitted step (see
        :mod:`repro.streams.device`); ``"sharded"`` shards that same dense
        ring across a JAX mesh of ``n_shards`` devices (explicit-only; see
        :mod:`repro.streams.sharded`). Forced backends raise ``ValueError``
        when the operator/router cannot support them.
      n_shards: device count for ``state_backend="sharded"`` (default: every
        local jax device). Ignored by the other backends.
      device_domain_max: the device/sharded backends allocate dense state per
        key id; ids at or above this bound raise instead of silently
        exploding memory (sparse huge domains belong on the columnar
        backend).
      kernel_interpret: Pallas ``interpret=`` mode for the routing/stats
        kernels. ``None`` (default) auto-selects: compiled on real TPU
        backends, interpret elsewhere (CPU has no Mosaic lowering).
      stats_dense_max: in the pallas substrate, the stats histogram kernel
        needs a dense key domain; domains larger than this fall back to the
        numpy segment-sum for step 1 (routing stays on the kernel).
    """

    def __init__(self, operator: Operator, controller: RebalanceController,
                 window: int = 1, migration_bandwidth: float = 1e6,
                 micro_batches: int = 8, migration_batches: int = 2,
                 vectorized: bool = True, substrate: str = "numpy",
                 state_backend: str = "auto",
                 n_shards: Optional[int] = None,
                 kernel_interpret: Optional[bool] = None,
                 stats_dense_max: int = 1 << 20,
                 device_domain_max: int = 1 << 22,
                 algorithm=None):
        if substrate not in SUBSTRATES:
            raise ValueError(f"unknown substrate {substrate!r}; "
                             f"choose from {SUBSTRATES}")
        self.operator = operator
        self.controller = controller
        if algorithm is not None:
            # same spec grammar as RebalanceController(algorithm=) — name,
            # planner callable, or configured PartitionStrategy instance
            # (see RebalanceController.use_algorithm); installed before
            # backend resolution so backend support checks see the strategy.
            controller.use_algorithm(algorithm)
        if (controller.strategy.needs_merge_stage
                and not getattr(operator, "split_safe", False)):
            raise ValueError(
                f"algorithm {controller.algorithm_name!r} splits keys across "
                f"tasks but operator {operator.name!r} is not split-safe; "
                "use a split-safe operator (e.g. PartialWordCount) with a "
                "downstream merge stage (repro.streams.topology), or a "
                "table-planner algorithm")
        self.window = window
        self.n_tasks = controller.assignment.n_dest
        self.n_shards = n_shards
        self.device_domain_max = device_domain_max
        self.migration_bandwidth = migration_bandwidth
        self.micro_batches = micro_batches
        self.migration_batches = migration_batches
        self.vectorized = vectorized
        self.substrate = substrate
        self.stats_dense_max = stats_dense_max
        self.reports: List[IntervalReport] = []
        self.outputs: Dict[int, Any] = {}
        self.emitted_sum = 0.0                      # running sum of numeric emits
        self.last_stats: Optional[KeyStats] = None
        self._interval = 0
        self._pending_delta: Optional[set] = None   # keys paused this interval
        self._pending_delta_arr: Optional[np.ndarray] = None
        self._migrated_bytes_pending = 0.0
        self._plan_time_pending = 0.0
        self._table_capacity = 0      # pallas routing-table pad, high-water mark
        self._route_cache = None      # (cache key, device tk, device td)
        #: failure-injection seam (repro.streams.faults): when set, called as
        #: ``failpoint(site, stage)`` at the engine's crash points — "deliver"
        #: (before any mutation) and "mid" (state mutated, no report yet).
        #: None (the default) is zero-overhead for production runs.
        self.failpoint = None
        self._kernel_interpret = kernel_interpret
        # backend selection (and its support errors) precedes substrate init
        backend_cls = resolve_backend(state_backend, operator, controller,
                                      vectorized)
        if substrate == "pallas":
            self._init_pallas(kernel_interpret)
        self.backend = backend_cls(self)
        self.state_backend = self.backend.name
        self.stores = [self.backend.new_store() for _ in range(self.n_tasks)]
        # wire the migration executor (paper steps 5-6)
        self.controller.executor = self._execute_migration

    def _init_pallas(self, kernel_interpret: Optional[bool]) -> None:
        from repro.core.balancer.hashing import Hash32
        router = self.controller.assignment.hash_router
        if not isinstance(router, Hash32):
            raise ValueError(
                "substrate='pallas' requires a Hash32 router (device-"
                f"canonical fmix32); got {type(router).__name__}. ModHash's "
                "splitmix64 has no 32-bit kernel equivalent.")
        import jax                                    # lazy: numpy path stays jax-free
        import jax.numpy as jnp
        from repro.kernels.key_stats import key_stats
        from repro.kernels.routing_lookup import routing_lookup
        self._jnp = jnp
        self._kernel_route = routing_lookup
        self._kernel_stats = key_stats
        self._hash_seed = router.seed
        if kernel_interpret is None:
            # compiled kernels on real TPU backends; interpret elsewhere
            kernel_interpret = jax.default_backend() != "tpu"
        self._kernel_interpret = bool(kernel_interpret)

    # -- failure-injection seam (repro.streams.faults) --------------------------
    def _failpoint(self, site: str) -> None:
        if self.failpoint is not None:
            self.failpoint(site, self)

    # -- pause-window clock (protocol steps 4/7) --------------------------------
    def begin_interval(self) -> int:
        self._interval += 1
        return self._interval

    def pause_window(self, n: int) -> Optional[int]:
        """Index bound of the pause window, or None when no migration is in
        flight: the first ``migration_batches`` of ``micro_batches`` slices
        buffer Delta-keys while migration completes."""
        if not n or self._pending_delta_arr is None:
            return None
        edges = np.linspace(0, n, self.micro_batches + 1).astype(int)
        return int(edges[min(self.migration_batches, self.micro_batches)])

    def clear_pause(self) -> None:
        self._pending_delta = None
        self._pending_delta_arr = None

    # -- migration executor (paper steps 5-6) -----------------------------------
    def _execute_migration(self, moved_keys: np.ndarray, old: Assignment,
                           new: Assignment) -> None:
        """Controller-invoked: the backend moves the state, the stage books
        the stall and opens the pause window for Delta(F, F')."""
        keys = np.asarray(moved_keys, dtype=np.int64)
        self._migrated_bytes_pending += self.backend.migrate(keys, old, new)
        # the reference loop materializes the membership set lazily; the
        # vectorized backends only ever consult the array (np.isin)
        self._pending_delta = None
        self._pending_delta_arr = keys

    # -- one interval of traffic ------------------------------------------------
    def process_interval(self, tuples: Sequence[Tuple[int, Any]]) -> IntervalReport:
        """Process one interval given ``(key, value)`` tuples (list API)."""
        keys = np.fromiter((k for k, _ in tuples), dtype=np.int64,
                           count=len(tuples))
        values = [v for _, v in tuples]
        return self.process_interval_arrays(keys, values)

    def process_interval_arrays(self, keys: np.ndarray,
                                values: Optional[Sequence[Any]] = None
                                ) -> IntervalReport:
        """Array-native entry point: ``keys`` as int64 array, ``values`` as an
        aligned sequence (or None when the operator sets ``needs_values``
        False). This is the zero-conversion path used by the benchmarks."""
        self._failpoint("deliver")
        if not self.vectorized:
            return self._process_interval_reference(keys, values)
        return self.backend.process_interval(keys, values)

    def process_interval_emits(self, keys: np.ndarray,
                               values: Optional[Sequence[Any]] = None
                               ) -> Tuple[IntervalReport, np.ndarray,
                                          np.ndarray]:
        """Process one interval and also return the operator's emit stream.

        Returns ``(report, emit_keys, emit_values)``. Emits are ordered by
        source-tuple position (the fan-out emits of one tuple stay adjacent,
        in emit order) — per-key state only depends on that key's own tuple
        order, which pause/replay preserves, so ALL engine paths produce
        this exact stream. That canonical order is what makes chained stages
        parity-testable; it is the stage-to-stage hand-off used by
        :class:`repro.streams.topology.Topology`.
        """
        self._failpoint("deliver")
        if not self.vectorized:
            return self._process_interval_reference(keys, values,
                                                    collect_emits=True)
        return self.backend.process_interval(keys, values, collect_emits=True)

    def _dest_batch(self, keys: np.ndarray) -> np.ndarray:
        """Destinations for a key batch — the strategy's per-tuple router when
        one is installed, else F(k) via numpy Assignment.dest or the Pallas
        kernel. Called exactly ONCE per interval batch on every engine path
        (routers are stateful: their load estimates advance per call)."""
        strategy = self.controller.strategy
        if strategy.is_router:
            return strategy.route(keys)
        if self.substrate == "pallas" and keys.size:
            if int(keys.max()) > np.iinfo(np.int32).max or int(keys.min()) < 0:
                raise ValueError(
                    "substrate='pallas' requires key ids in [0, 2^31): the "
                    "routing kernel operates on int32 and larger ids would "
                    "silently alias")
            assignment = self.controller.assignment
            # pad the table to a stable capacity (next power of two, >= 128):
            # routing_lookup is jitted on the table shape, so size-exact
            # padding would retrace on every rebalance that resizes the table.
            # The capacity is a per-stage high-water mark — recomputing it
            # from the current table_size would shrink it again when the
            # table shrinks, so a table oscillating across a power-of-two
            # boundary (e.g. 128<->129 under Mixed churn) would retrace the
            # kernel every interval.
            needed = max(128, 1 << max(0, assignment.table_size - 1).bit_length())
            if needed > self._table_capacity:
                self._table_capacity = needed
            # Device-side table cache: rebuilding table_arrays and re-running
            # jnp.asarray uploads every interval is pure waste when the
            # assignment didn't change. The controller bumps
            # assignment_version on every rebalance/rescale, so (version,
            # table_size, capacity) only moves when the table can differ.
            # (In-place table mutation without a size change bypasses the
            # controller and is not supported by this cache.)
            cache_key = (self.controller.assignment_version,
                         assignment.table_size, self._table_capacity)
            if self._route_cache is None or self._route_cache[0] != cache_key:
                tk, td = assignment.table_arrays(self._table_capacity)
                self._route_cache = (
                    cache_key,
                    self._jnp.asarray(tk.astype(np.int32)),
                    self._jnp.asarray(td.astype(np.int32)))
            _, tk_dev, td_dev = self._route_cache
            out = self._kernel_route(
                self._jnp.asarray(keys.astype(np.int32)),
                tk_dev, td_dev,
                assignment.n_dest, seed=self._hash_seed,
                interpret=self._kernel_interpret)
            return np.asarray(out).astype(np.int64)
        return self.controller.assignment.dest(keys)

    def _finish_interval(self, iv: int, n: int, task_cost: np.ndarray,
                         buffered_count: int,
                         stats: Optional[KeyStats]) -> IntervalReport:
        # -- measurement + controller handoff (paper steps 1-2) -----------------
        stall = self._migrated_bytes_pending / self.migration_bandwidth
        makespan = float(task_cost.max()) if n else 0.0
        report = IntervalReport(
            interval=iv, tuples=n, makespan=makespan, migration_stall=stall,
            throughput=n / (makespan + stall) if (makespan + stall) > 0 else 0.0,
            skewness=metrics.skewness(task_cost) if n else 1.0,
            theta=metrics.theta(task_cost) if n else 0.0,
            migrated_bytes=self._migrated_bytes_pending,
            table_size=self.controller.assignment.table_size,
            plan_time_s=self._plan_time_pending,
            buffered=buffered_count, task_loads=task_cost,
        )
        self.reports.append(report)
        self._migrated_bytes_pending = 0.0
        self._plan_time_pending = 0.0
        if stats is not None:
            # pin the event to the STAGE interval: a stats-free interval
            # (no tuples, no held state) skips the controller, and its
            # private counter would silently lag the stage clock otherwise
            if stats is SKETCH_PENDING:
                # the backend streamed aggregates into the controller's
                # sketch; close the round on the head-only snapshot
                ev = self.controller.on_interval(None, interval=iv)
                self.last_stats = self.controller.last_stats
            else:
                self.last_stats = stats
                ev = self.controller.on_interval(stats, interval=iv)
            if ev.result is not None:
                self._plan_time_pending = ev.result.plan_time_s
        return report

    # -- reference per-tuple path (parity oracle; vectorized=False) ------------
    def _process_interval_reference(self, keys: np.ndarray,
                                    values: Optional[Sequence[Any]],
                                    collect_emits: bool = False):
        iv = self.begin_interval()
        n = int(keys.shape[0])
        vals = values if values is not None else [None] * n
        if self._pending_delta is None and self._pending_delta_arr is not None:
            self._pending_delta = set(self._pending_delta_arr.tolist())
        task_cost = np.zeros(self.n_tasks)
        key_cost: Dict[int, float] = defaultdict(float)
        key_freq: Dict[int, float] = defaultdict(float)
        buffer: List[Tuple[int, int, Any]] = []      # (position, key, value)
        buffered_count = 0
        emit_log: Optional[List[Tuple[int, int, Any]]] = \
            [] if collect_emits else None

        dests = self._dest_batch(keys) if n else np.zeros(0, np.int64)

        batch_edges = np.linspace(0, n, self.micro_batches + 1).astype(int)
        for b in range(self.micro_batches):
            lo, hi = batch_edges[b], batch_edges[b + 1]
            migrating = (self._pending_delta is not None
                         and b < self.migration_batches)
            if not migrating and buffer:
                # Resume: replay buffered tuples with the CURRENT assignment
                for pos, k, v in buffer:
                    d = int(self.controller.assignment.dest(
                        np.asarray([k], dtype=np.int64))[0])
                    self._run_one(d, iv, k, v, pos, task_cost, key_cost,
                                  key_freq, emit_log)
                buffer.clear()
                self.clear_pause()
            for i in range(lo, hi):
                k, v = int(keys[i]), vals[i]
                if migrating and k in self._pending_delta:
                    buffer.append((i, k, v))        # Pause: cache locally
                    buffered_count += 1
                    continue
                self._run_one(int(dests[i]), iv, k, v, i, task_cost, key_cost,
                              key_freq, emit_log)
        if buffer:                                   # traffic ended mid-pause
            for pos, k, v in buffer:
                d = int(self.controller.assignment.dest(
                    np.asarray([k], dtype=np.int64))[0])
                self._run_one(d, iv, k, v, pos, task_cost, key_cost, key_freq,
                              emit_log)
            buffer.clear()
        self.clear_pause()
        self._failpoint("mid")

        for store in self.stores:
            store.end_interval(iv)

        stats = self._collect_stats(key_cost, key_freq)
        report = self._finish_interval(iv, n, task_cost, buffered_count, stats)
        if not collect_emits:
            return report
        # canonical order = source position (replays keep their original
        # position, and a tuple's emits were appended contiguously)
        emit_log.sort(key=lambda t: t[0])
        ekeys = np.asarray([k for _, k, _ in emit_log], dtype=np.int64)
        evals = np.asarray([v for _, _, v in emit_log])
        return report, ekeys, evals

    def _run_one(self, d: int, interval: int, key: int, value: Any, pos: int,
                 task_cost, key_cost, key_freq, emit_log=None) -> None:
        outs, cost = self.operator.process(self.stores[d], interval, key, value)
        task_cost[d] += cost
        key_cost[key] += cost
        key_freq[key] += 1
        for ok, ov in outs:
            self.outputs[ok] = ov
            if isinstance(ov, (int, float)):
                self.emitted_sum += float(ov)
            if emit_log is not None:
                emit_log.append((pos, ok, ov))

    def _collect_stats(self, key_cost, key_freq) -> Optional[KeyStats]:
        # Paper step 1: every instance reports c(k) AND S(k,w) for each key
        # *assigned to it* — the stat universe is (keys seen this interval)
        # UNION (keys still holding window state). Omitting quiet stateful
        # keys would let a table cleanup strand their state on the old task.
        sizes: Dict[int, float] = {}
        for store in self.stores:
            sizes.update(store.sizes())
        universe = set(key_cost) | set(sizes)
        if not universe:
            return None
        keys = np.fromiter(sorted(universe), dtype=np.int64, count=len(universe))
        cost = np.fromiter((key_cost.get(int(k), 0.0) for k in keys),
                           dtype=np.float64)
        freq = np.fromiter((key_freq.get(int(k), 0.0) for k in keys),
                           dtype=np.float64)
        mem = np.fromiter((sizes.get(int(k), 0.0) for k in keys),
                          dtype=np.float64)
        if self.controller.stats_mode == "sketch":
            # the reference loop is dict-based (it materializes the exact
            # universe anyway), but in sketch mode it still hands off
            # through the sketch so the controller plans on the same
            # head-only contract as the vectorized backends
            self.controller.ingest(keys, cost, mem=mem, freq=freq)
            return SKETCH_PENDING
        return KeyStats(keys=keys, cost=cost, mem=mem, freq=freq)

    # -- elastic scaling (paper Fig. 15) ----------------------------------------
    def scale_to(self, n_tasks: int) -> None:
        """Add/remove task instances and rebalance state onto the new fleet.

        New stores must exist before the controller's migration executor runs;
        shrink requires draining removed stores first (state migrates away via
        the rescale plan, since no key may map to a dead task)."""
        if n_tasks < 1:
            raise ValueError(
                f"scale_to requires n_tasks >= 1, got {n_tasks}: a stage "
                "cannot run with an empty fleet")
        if self.controller.strategy.is_router:
            # fail before touching stores: controller.rescale would raise
            # anyway, but only after we had already grown the fleet
            self.controller.rescale(n_tasks, self.last_stats)
        if self.last_stats is None:
            raise RuntimeError("scale_to requires at least one processed interval")
        while len(self.stores) < n_tasks:
            self.stores.append(self.backend.new_store())
        self.controller.rescale(n_tasks, self.last_stats)
        # reconciliation sweep: the rescale executor only covers keys present
        # in the last interval's stats; stale-state keys re-hash too. Pack
        # extraction + mask splits keep this array-native on every backend.
        for s_idx, store in enumerate(self.stores):
            held, _ = store.sizes_arrays()
            if not held.size:
                continue
            dst = self.controller.assignment.dest(held)
            movers = held[dst != s_idx]
            if movers.size:
                pack = store.extract_batch(movers)
                self._migrated_bytes_pending += pack.nbytes
                pdst = self.controller.assignment.dest(pack.keys)
                for d in np.unique(pdst):
                    self.stores[int(d)].install_batch(pack.take(pdst == d))
        self.stores = self.stores[:n_tasks]
        self.n_tasks = n_tasks

    # -- invariant helpers for tests -------------------------------------------
    def total_state_keys(self) -> int:
        return sum(len(s.keys) for s in self.stores)

    def key_location(self, key: int) -> List[int]:
        return [i for i, s in enumerate(self.stores) if key in s.keys]
