"""Interval-synchronous DSPE with the paper's rebalance protocol (Fig. 5).

One keyed stage = N_D task instances consuming a key-partitioned tuple
stream under the controller's mixed assignment function. Intervals are
discretized (paper Sec. II-A); each interval is processed in micro-batches so
the Pause -> migrate -> Resume protocol has real in-flight traffic to handle:

  * tuples whose key is in Delta(F, F') during the migration window are
    buffered ("cached locally" per the paper) and replayed on Resume;
  * tuples for all other keys flow uninterrupted (the paper's key property);
  * per-key state moves between task stores atomically at the boundary.

The engine also produces the performance model used by the benchmarks:
interval makespan = max per-task cost + migration stall, so throughput =
tuples / makespan (relative units; the paper measures the same shape of
quantity on Storm).
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.balancer import Assignment, BalanceConfig, KeyStats, metrics
from repro.core.controller import RebalanceController

from .operators import Operator
from .state import TaskStateStore


@dataclasses.dataclass
class IntervalReport:
    interval: int
    tuples: int
    makespan: float              # max task cost (critical path)
    migration_stall: float       # migration bytes / bandwidth
    throughput: float            # tuples / (makespan + stall)
    skewness: float              # max load / mean load
    theta: float
    migrated_bytes: float
    table_size: int
    plan_time_s: float
    buffered: int                # tuples held during Pause
    task_loads: np.ndarray


class KeyedStage:
    """N_D task instances + controller-owned assignment (one logical operator)."""

    def __init__(self, operator: Operator, controller: RebalanceController,
                 window: int = 1, migration_bandwidth: float = 1e6,
                 micro_batches: int = 8, migration_batches: int = 2):
        self.operator = operator
        self.controller = controller
        self.window = window
        self.n_tasks = controller.assignment.n_dest
        self.stores = [TaskStateStore(window) for _ in range(self.n_tasks)]
        self.migration_bandwidth = migration_bandwidth
        self.micro_batches = micro_batches
        self.migration_batches = migration_batches
        self.reports: List[IntervalReport] = []
        self.outputs: Dict[int, Any] = {}
        self.emitted_sum = 0.0                      # running sum of numeric emits
        self.last_stats: Optional[KeyStats] = None
        self._interval = 0
        self._pending_delta: Optional[set] = None   # keys paused this interval
        self._migrated_bytes_pending = 0.0
        self._plan_time_pending = 0.0
        # wire the migration executor (paper steps 5-6)
        self.controller.executor = self._migrate

    # -- state migration: move KeyState between stores -------------------------
    def _migrate(self, moved_keys: np.ndarray, old: Assignment,
                 new: Assignment) -> None:
        keys = [int(k) for k in moved_keys]
        src = old.dest(np.asarray(keys, dtype=np.int64))
        dst = new.dest(np.asarray(keys, dtype=np.int64))
        by_src: Dict[int, List[int]] = defaultdict(list)
        for k, s, d in zip(keys, src, dst):
            if s != d:
                by_src[int(s)].append(k)
        total = 0.0
        extracted: Dict[int, Dict] = {}
        for s, ks in by_src.items():
            total += self.stores[s].migrated_bytes(ks)
            extracted.update(self.stores[s].extract(ks))
        for k, state in extracted.items():
            d = int(new.dest(np.asarray([k], dtype=np.int64))[0])
            self.stores[d].install({k: state})
        self._migrated_bytes_pending += total
        self._pending_delta = set(keys)

    # -- one interval of traffic ------------------------------------------------
    def process_interval(self, tuples: List[Tuple[int, Any]]) -> IntervalReport:
        self._interval += 1
        iv = self._interval
        n = len(tuples)
        task_cost = np.zeros(self.n_tasks)
        key_cost: Dict[int, float] = defaultdict(float)
        key_freq: Dict[int, float] = defaultdict(float)
        buffer: List[Tuple[int, Any]] = []
        buffered_count = 0

        keys_arr = np.asarray([k for k, _ in tuples], dtype=np.int64)
        dests = self.controller.assignment.dest(keys_arr) if n else np.zeros(0, int)

        batch_edges = np.linspace(0, n, self.micro_batches + 1).astype(int)
        for b in range(self.micro_batches):
            lo, hi = batch_edges[b], batch_edges[b + 1]
            migrating = (self._pending_delta is not None
                         and b < self.migration_batches)
            if not migrating and buffer:
                # Resume: replay buffered tuples with the CURRENT assignment
                for k, v in buffer:
                    d = int(self.controller.assignment.dest(
                        np.asarray([k], dtype=np.int64))[0])
                    self._run_one(d, iv, k, v, task_cost, key_cost, key_freq)
                buffer.clear()
                self._pending_delta = None
            for i in range(lo, hi):
                k, v = tuples[i]
                if migrating and k in self._pending_delta:
                    buffer.append((k, v))           # Pause: cache locally
                    buffered_count += 1
                    continue
                self._run_one(int(dests[i]), iv, k, v, task_cost, key_cost,
                              key_freq)
        if buffer:                                   # traffic ended mid-pause
            for k, v in buffer:
                d = int(self.controller.assignment.dest(
                    np.asarray([k], dtype=np.int64))[0])
                self._run_one(d, iv, k, v, task_cost, key_cost, key_freq)
            buffer.clear()
        self._pending_delta = None

        for store in self.stores:
            store.end_interval(iv)

        # -- measurement + controller handoff (paper steps 1-2) -----------------
        stats = self._collect_stats(key_cost, key_freq)
        stall = self._migrated_bytes_pending / self.migration_bandwidth
        makespan = float(task_cost.max()) if n else 0.0
        report = IntervalReport(
            interval=iv, tuples=n, makespan=makespan, migration_stall=stall,
            throughput=n / (makespan + stall) if (makespan + stall) > 0 else 0.0,
            skewness=metrics.skewness(task_cost) if n else 1.0,
            theta=metrics.theta(task_cost) if n else 0.0,
            migrated_bytes=self._migrated_bytes_pending,
            table_size=self.controller.assignment.table_size,
            plan_time_s=self._plan_time_pending,
            buffered=buffered_count, task_loads=task_cost,
        )
        self.reports.append(report)
        self._migrated_bytes_pending = 0.0
        self._plan_time_pending = 0.0
        if stats is not None:
            self.last_stats = stats
            ev = self.controller.on_interval(stats)
            if ev.result is not None:
                self._plan_time_pending = ev.result.plan_time_s
        return report

    def _run_one(self, d: int, interval: int, key: int, value: Any,
                 task_cost, key_cost, key_freq) -> None:
        outs, cost = self.operator.process(self.stores[d], interval, key, value)
        task_cost[d] += cost
        key_cost[key] += cost
        key_freq[key] += 1
        for ok, ov in outs:
            self.outputs[ok] = ov
            if isinstance(ov, (int, float)):
                self.emitted_sum += float(ov)

    def _collect_stats(self, key_cost, key_freq) -> Optional[KeyStats]:
        # Paper step 1: every instance reports c(k) AND S(k,w) for each key
        # *assigned to it* — the stat universe is (keys seen this interval)
        # UNION (keys still holding window state). Omitting quiet stateful
        # keys would let a table cleanup strand their state on the old task.
        sizes: Dict[int, float] = {}
        for store in self.stores:
            sizes.update(store.sizes())
        universe = set(key_cost) | set(sizes)
        if not universe:
            return None
        keys = np.fromiter(sorted(universe), dtype=np.int64, count=len(universe))
        cost = np.fromiter((key_cost.get(int(k), 0.0) for k in keys),
                           dtype=np.float64)
        freq = np.fromiter((key_freq.get(int(k), 0.0) for k in keys),
                           dtype=np.float64)
        mem = np.fromiter((sizes.get(int(k), 0.0) for k in keys),
                          dtype=np.float64)
        return KeyStats(keys=keys, cost=cost, mem=mem, freq=freq)

    # -- elastic scaling (paper Fig. 15) ----------------------------------------
    def scale_to(self, n_tasks: int) -> None:
        """Add/remove task instances and rebalance state onto the new fleet.

        New stores must exist before the controller's migration executor runs;
        shrink requires draining removed stores first (state migrates away via
        the rescale plan, since no key may map to a dead task)."""
        if self.last_stats is None:
            raise RuntimeError("scale_to requires at least one processed interval")
        while len(self.stores) < n_tasks:
            self.stores.append(TaskStateStore(self.window))
        self.controller.rescale(n_tasks, self.last_stats)
        # reconciliation sweep: the rescale executor only covers keys present
        # in the last interval's stats; stale-state keys re-hash too.
        for s_idx, store in enumerate(self.stores):
            keys = list(store.keys)
            if not keys:
                continue
            dst = self.controller.assignment.dest(np.asarray(keys, np.int64))
            movers = [k for k, d in zip(keys, dst) if int(d) != s_idx]
            if movers:
                self._migrated_bytes_pending += store.migrated_bytes(movers)
                extracted = store.extract(movers)
                for k in movers:
                    d = int(self.controller.assignment.dest(
                        np.asarray([k], np.int64))[0])
                    self.stores[d].install({k: extracted[k]})
        self.stores = self.stores[:n_tasks]
        self.n_tasks = n_tasks

    # -- invariant helpers for tests -------------------------------------------
    def total_state_keys(self) -> int:
        return sum(len(s.keys) for s in self.stores)

    def key_location(self, key: int) -> List[int]:
        return [i for i, s in enumerate(self.stores) if key in s.keys]
