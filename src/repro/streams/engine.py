"""Interval-synchronous DSPE with the paper's rebalance protocol (Fig. 5).

One keyed stage = N_D task instances consuming a key-partitioned tuple
stream under the controller's mixed assignment function. Intervals are
discretized (paper Sec. II-A); each interval is processed in micro-batches so
the Pause -> migrate -> Resume protocol has real in-flight traffic to handle:

  * tuples whose key is in Delta(F, F') during the migration window are
    buffered ("cached locally" per the paper) and replayed on Resume;
  * tuples for all other keys flow uninterrupted (the paper's key property);
  * per-key state moves between task stores atomically at the boundary.

The engine also produces the performance model used by the benchmarks:
interval makespan = max per-task cost + migration stall, so throughput =
tuples / makespan (relative units; the paper measures the same shape of
quantity on Storm).

Vectorized fast path (default)
------------------------------
``KeyedStage(vectorized=True)`` dispatches whole micro-batches at a time:
one ``Assignment.dest`` call per interval, argsort + segment boundaries to
partition tuples per task, ``Operator.process_batch`` per segment, and
``np.add.at`` segment-sums for the per-key cost/freq/state-size stats of
protocol step 1 (see :mod:`repro.streams.operators` for the batched operator
contract and :mod:`repro.streams.state` for the batched store API).
``vectorized=False`` keeps the original per-tuple loop as the reference
implementation; ``tests/test_engine_parity.py`` proves the two produce
identical :class:`IntervalReport` streams, and
``benchmarks/engine_fastpath.py`` measures the speedup.

Multi-stage topologies chain stages through
:meth:`KeyedStage.process_interval_emits`, which additionally returns the
operator's full emit stream as ``(keys, values)`` arrays in canonical
source-position order (see :mod:`repro.streams.topology` and the batched
emit contract in :mod:`repro.streams.operators`).

Substrate flag
--------------
``substrate="numpy"`` (default) computes routing and stats on host numpy.
``substrate="pallas"`` runs routing through the Pallas mixed-dispatch kernel
(:mod:`repro.kernels.routing_lookup`) and step-1 stats aggregation through
the fused histogram kernel (:mod:`repro.kernels.key_stats`), with the numpy
path as the reference semantics. Requirements: the assignment's hash router
must be :class:`repro.core.balancer.hashing.Hash32` (the device-canonical
fmix32 hash — ``ModHash`` uses splitmix64, which the kernels do not
implement) and key ids must fit int32. Stats come back float32, so reports
match numpy to ~1e-6 relative rather than bit-for-bit. See
``docs/architecture.md`` ("Kernels") for when to flip this flag.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.balancer import Assignment, KeyStats, metrics
from repro.core.controller import RebalanceController

from .operators import Operator
from .state import ColumnarStateStore, TaskStateStore

SUBSTRATES = ("numpy", "pallas")
STATE_BACKENDS = ("auto", "columnar", "object", "device")


@dataclasses.dataclass
class IntervalReport:
    interval: int
    tuples: int
    makespan: float              # max task cost (critical path)
    migration_stall: float       # migration bytes / bandwidth
    throughput: float            # tuples / (makespan + stall)
    skewness: float              # max load / mean load
    theta: float
    migrated_bytes: float
    table_size: int
    plan_time_s: float
    buffered: int                # tuples held during Pause
    task_loads: np.ndarray


class KeyedStage:
    """N_D task instances + controller-owned assignment (one logical operator).

    Args:
      vectorized: use the array-at-a-time fast path (default). ``False``
        selects the per-tuple reference loop — same results, ~10x slower;
        kept for parity testing and as executable documentation.
      substrate: ``"numpy"`` or ``"pallas"`` — see the module docstring.
      state_backend: ``"auto"`` (default) picks the columnar store when the
        operator declares a ``columnar_spec`` and the stage is vectorized —
        state then lives in flat per-task arrays and each macro-batch is ONE
        whole-interval operator dispatch (``Operator.process_interval_batch``)
        instead of a per-task Python loop. ``"object"`` forces the dict-of-
        KeyState store (the compatibility/parity backend, and the only one
        custom per-tuple operators can use); ``"columnar"`` forces the array
        store and raises if the operator cannot support it. ``"device"``
        keeps state as device-resident arrays and fuses the whole interval
        into one jitted step (see :mod:`repro.streams.device`); it requires
        vectorized=True, a Hash32 router and an operator with device closed
        forms (``device_mode``) — ``"auto"`` picks it only when those hold
        AND jax runs on an accelerator backend (on CPU the columnar store
        wins, so auto behavior there is unchanged).
      device_domain_max: the device backend allocates dense state per key id;
        ids at or above this bound raise instead of silently exploding
        memory (sparse huge domains belong on the columnar backend).
      kernel_interpret: Pallas ``interpret=`` mode for the routing/stats
        kernels. ``None`` (default) auto-selects: compiled on real TPU
        backends, interpret elsewhere (CPU has no Mosaic lowering).
      stats_dense_max: in the pallas substrate, the stats histogram kernel
        needs a dense key domain; domains larger than this fall back to the
        numpy segment-sum for step 1 (routing stays on the kernel).
    """

    def __init__(self, operator: Operator, controller: RebalanceController,
                 window: int = 1, migration_bandwidth: float = 1e6,
                 micro_batches: int = 8, migration_batches: int = 2,
                 vectorized: bool = True, substrate: str = "numpy",
                 state_backend: str = "auto",
                 kernel_interpret: Optional[bool] = None,
                 stats_dense_max: int = 1 << 20,
                 device_domain_max: int = 1 << 22):
        if substrate not in SUBSTRATES:
            raise ValueError(f"unknown substrate {substrate!r}; "
                             f"choose from {SUBSTRATES}")
        if state_backend not in STATE_BACKENDS:
            raise ValueError(f"unknown state backend {state_backend!r}; "
                             f"choose from {STATE_BACKENDS}")
        self.operator = operator
        self.controller = controller
        self.window = window
        self.n_tasks = controller.assignment.n_dest
        spec = getattr(operator, "columnar_spec", None)
        dev_mode = getattr(operator, "device_mode", None)
        self._device = False
        if state_backend == "device":
            self._check_device_support(operator, vectorized, spec, dev_mode)
            self._device = True
            self._columnar = False
        elif state_backend == "columnar":
            if spec is None:
                raise ValueError(
                    f"state_backend='columnar' requires an operator with a "
                    f"columnar_spec; {type(operator).__name__} has none "
                    "(custom per-tuple operators need the object store)")
            if not vectorized:
                raise ValueError("state_backend='columnar' requires "
                                 "vectorized=True (the per-tuple reference "
                                 "path uses scalar state access)")
            self._columnar = True
        else:
            self._columnar = (state_backend == "auto" and vectorized
                              and spec is not None)
            # auto-promote to the device backend only when every device
            # requirement already holds AND jax runs on an accelerator —
            # checked lazily so ModHash/object stages never import jax
            if self._columnar and dev_mode is not None \
                    and self._is_hash32_router():
                import jax                       # lazy
                if jax.default_backend() != "cpu":
                    self._device = True
                    self._columnar = False
        self.state_backend = ("device" if self._device
                              else "columnar" if self._columnar else "object")
        self.device_domain_max = device_domain_max
        self.migration_bandwidth = migration_bandwidth
        self.micro_batches = micro_batches
        self.migration_batches = migration_batches
        self.vectorized = vectorized
        self.substrate = substrate
        self.stats_dense_max = stats_dense_max
        self.reports: List[IntervalReport] = []
        self.outputs: Dict[int, Any] = {}
        self.emitted_sum = 0.0                      # running sum of numeric emits
        self.last_stats: Optional[KeyStats] = None
        self._interval = 0
        self._pending_delta: Optional[set] = None   # keys paused this interval
        self._pending_delta_arr: Optional[np.ndarray] = None
        self._migrated_bytes_pending = 0.0
        self._plan_time_pending = 0.0
        self._table_capacity = 0      # pallas routing-table pad, high-water mark
        self._route_cache = None      # (cache key, device tk, device td)
        self._kernel_interpret = kernel_interpret
        if substrate == "pallas":
            self._init_pallas(kernel_interpret)
        if self._device:
            self._init_device()
        self.stores = [self._new_store() for _ in range(self.n_tasks)]
        # wire the migration executor (paper steps 5-6)
        self.controller.executor = (self._migrate_device if self._device
                                    else self._migrate)

    def _is_hash32_router(self) -> bool:
        from repro.core.balancer.hashing import Hash32
        return isinstance(self.controller.assignment.hash_router, Hash32)

    def _check_device_support(self, operator, vectorized, spec,
                              dev_mode) -> None:
        if not vectorized:
            raise ValueError("state_backend='device' requires "
                             "vectorized=True (the per-tuple reference path "
                             "uses scalar state access)")
        if dev_mode is None or spec is None:
            raise ValueError(
                f"state_backend='device' requires an operator with device "
                f"closed forms (device_mode + columnar_spec); "
                f"{type(operator).__name__} has none — such operators fall "
                "back to the columnar/object store under 'auto'")
        if not self._is_hash32_router():
            router = self.controller.assignment.hash_router
            raise ValueError(
                "state_backend='device' requires a Hash32 router (device-"
                f"canonical fmix32); got {type(router).__name__}. ModHash's "
                "splitmix64 has no 32-bit device equivalent.")

    def _init_device(self) -> None:
        from .device import DeviceStateFleet
        self._device_seed = self.controller.assignment.hash_router.seed
        self._fleet = DeviceStateFleet(self.window, self.operator.columnar_spec)
        self._dest_dense_cache = None   # (cache key, device dests, host dests)
        self._views_made = 0

    def _new_store(self):
        if self._device:
            from .device import DeviceTaskView
            idx = (len(self.stores) if hasattr(self, "stores")
                   else self._views_made)
            self._views_made += 1
            return DeviceTaskView(self._fleet, idx)
        if self._columnar:
            return ColumnarStateStore(self.window, self.operator.columnar_spec)
        return TaskStateStore(self.window)

    def _init_pallas(self, kernel_interpret: Optional[bool]) -> None:
        from repro.core.balancer.hashing import Hash32
        router = self.controller.assignment.hash_router
        if not isinstance(router, Hash32):
            raise ValueError(
                "substrate='pallas' requires a Hash32 router (device-"
                f"canonical fmix32); got {type(router).__name__}. ModHash's "
                "splitmix64 has no 32-bit kernel equivalent.")
        import jax                                    # lazy: numpy path stays jax-free
        import jax.numpy as jnp
        from repro.kernels.key_stats import key_stats
        from repro.kernels.routing_lookup import routing_lookup
        self._jnp = jnp
        self._kernel_route = routing_lookup
        self._kernel_stats = key_stats
        self._hash_seed = router.seed
        if kernel_interpret is None:
            # compiled kernels on real TPU backends; interpret elsewhere
            kernel_interpret = jax.default_backend() != "tpu"
        self._kernel_interpret = bool(kernel_interpret)

    # -- state migration: move keyed state between stores ----------------------
    def _migrate(self, moved_keys: np.ndarray, old: Assignment,
                 new: Assignment) -> None:
        """Executor for protocol steps 5-6, array-at-a-time and backend-
        agnostic: one dest() call per assignment, group-by-source extraction
        into packs, mask-split per destination, group installs. On the
        columnar backend a pack is a row slice of flat arrays; on the object
        backend it is the keys plus their KeyState objects — either way no
        per-key dict is built here."""
        keys = np.asarray(moved_keys, dtype=np.int64)
        src = old.dest(keys)
        dst = new.dest(keys)
        moving = src != dst
        mkeys, msrc = keys[moving], src[moving]
        total = 0.0
        installs = []
        for s in np.unique(msrc):
            pack = self.stores[int(s)].extract_batch(mkeys[msrc == s])
            if not pack.keys.size:
                continue
            total += pack.nbytes
            pdst = new.dest(pack.keys)
            for d in np.unique(pdst):
                installs.append((int(d), pack.take(pdst == d)))
        for d, pack in installs:
            self.stores[d].install_batch(pack)
        self._migrated_bytes_pending += total
        # the reference loop materializes the membership set lazily; the
        # vectorized path only ever consults the array (np.isin)
        self._pending_delta = None
        self._pending_delta_arr = keys

    def _migrate_device(self, moved_keys: np.ndarray, old: Assignment,
                        new: Assignment) -> None:
        """Device-backend migration executor: zero device work.

        State is key-indexed on the device, so moving a key between tasks
        only relabels host ownership; migrated bytes come from the ``mem``
        mirror's closed-form S(k, w) — the exact per-pack sums the columnar
        executor reports, because every quantity is an integer-valued
        float64 (order-free exact summation)."""
        keys = np.asarray(moved_keys, dtype=np.int64)
        src = old.dest(keys)
        dst = new.dest(keys)
        moving = src != dst
        mkeys = keys[moving]
        fleet = self._fleet
        if mkeys.size and fleet.domain:
            ok = (mkeys >= 0) & (mkeys < fleet.domain)
            mk = mkeys[ok]
            held = fleet.task[mk] >= 0
            hk = mk[held]
            self._migrated_bytes_pending += float(fleet.mem[hk].sum())
            fleet.task[hk] = dst[moving][ok][held].astype(np.int32)
        self._pending_delta = None
        self._pending_delta_arr = keys

    # -- device fast path (state_backend="device") ------------------------------
    def _dest_dense_arrays(self):
        """Dense F(k) table over every key id, refreshed once per
        ``assignment_version`` (and per domain growth) — the device twin of
        ``_dest_batch``'s routing-table cache, sharing its power-of-two
        high-water table capacity so table churn never retraces."""
        assignment = self.controller.assignment
        needed = max(128, 1 << max(0, assignment.table_size - 1).bit_length())
        if needed > self._table_capacity:
            self._table_capacity = needed
        cache_key = (self.controller.assignment_version,
                     assignment.table_size, self._table_capacity,
                     self._fleet.domain, self.n_tasks)
        if self._dest_dense_cache is None \
                or self._dest_dense_cache[0] != cache_key:
            tk, td = assignment.table_arrays(self._table_capacity)
            dev = self._fleet.route_dense(
                tk, td, assignment.n_dest, seed=self._device_seed,
                use_kernel=(self.substrate == "pallas"),
                interpret=self._kernel_interpret)
            self._dest_dense_cache = (cache_key, dev,
                                      np.asarray(dev).astype(np.int64))
        return self._dest_dense_cache[1], self._dest_dense_cache[2]

    def _process_interval_device(self, keys: np.ndarray,
                                 values: Optional[Sequence[Any]] = None,
                                 collect_emits: bool = False):
        """One interval as ONE fused device step (see streams/device.py).

        The pause-window macro-batch split of the vectorized path telescopes
        for device operators (their closed forms are batch-boundary
        invariant), so only the ``buffered`` count needs the host split; the
        step itself sees the whole interval."""
        self._interval += 1
        iv = self._interval
        n = int(keys.shape[0])
        fleet = self._fleet
        op = self.operator
        spec = op.columnar_spec

        buffered_count = 0
        if n and self._pending_delta_arr is not None:
            edges = np.linspace(0, n, self.micro_batches + 1).astype(int)
            pause_hi = edges[min(self.migration_batches, self.micro_batches)]
            buffered_count = int(np.isin(keys[:pause_hi],
                                         self._pending_delta_arr).sum())
        self._pending_delta = None
        self._pending_delta_arr = None

        # ring-column bookkeeping (host mirror of the columnar _col_iv)
        w1 = self.window + 1
        c = iv % w1
        col_iv = fleet.col_iv
        if n:
            if col_iv[c] not in (-1, iv):
                raise RuntimeError(
                    f"device ring column clock skew: column {c} still holds "
                    f"interval {int(col_iv[c])} at interval {iv}")
            col_iv[c] = iv
        cutoff = iv - self.window + 1
        expire = (col_iv >= 0) & (col_iv < cutoff)
        keep = (~expire).astype(np.int32)
        col_iv[expire] = -1

        task_cost = np.zeros(self.n_tasks)
        stats: Optional[KeyStats] = None
        win0_h = slot0_h = None

        if n:
            kmin, kmax = int(keys.min()), int(keys.max())
            if kmin < 0:
                raise ValueError(
                    f"state_backend='device' requires non-negative key ids; "
                    f"got {kmin}")
            if kmax >= self.device_domain_max:
                raise ValueError(
                    f"key id {kmax} exceeds device_domain_max="
                    f"{self.device_domain_max}: the dense device backend "
                    "allocates state per key id — raise device_domain_max or "
                    "use the columnar backend for sparse huge domains")
            fleet.ensure_domain(kmax + 1)
            dest_dev, dest_host = self._dest_dense_arrays()
            cur = np.zeros(w1, dtype=np.int32)
            cur[c] = 1
            tv = None
            if op.device_mode == "max":
                tv64 = np.asarray(values).astype(np.int64)
                if tv64.size and not (
                        int(tv64.min()) > np.iinfo(np.int32).min
                        and int(tv64.max()) <= np.iinfo(np.int32).max):
                    raise ValueError(
                        "state_backend='device' folds values in int32; "
                        "tuple value out of int32 range")
                tv = tv64
            step = fleet.interval_step(keys, tv, dest_dev, self.n_tasks,
                                       keep, cur, op.device_mode)
            dom = fleet.domain
            counts_h = np.asarray(step[0])[:dom]
            win0_h = np.asarray(step[1])[:dom]
            slot0_h = np.asarray(step[2])[:dom]
            held_cnt = np.asarray(step[3])[:dom]
            held_sum = np.asarray(step[4])[:dom]

            seen_mask = counts_h > 0
            gk = np.nonzero(seen_mask)[0].astype(np.int64)
            key_cost_g, out_vals, emit_sum = op.device_finish(
                counts_h[seen_mask].astype(np.int64),
                win0_h[seen_mask].astype(np.int64),
                slot0_h[seen_mask].astype(np.int64))
            if out_vals is not None:
                self.outputs.update(zip(gk.tolist(), out_vals.tolist()))
            self.emitted_sum += emit_sum
            if op.device_unit_cost:
                if step[5] is not None:           # max mode: device bincount
                    task_cost = np.asarray(step[5]).astype(np.float64)
                else:                             # add mode: counts are host
                    task_cost = np.bincount(dest_host[:dom],
                                            weights=counts_h,
                                            minlength=self.n_tasks)
            else:
                task_cost = np.bincount(dest_host[gk], weights=key_cost_g,
                                        minlength=self.n_tasks)

            # host mirrors: ownership labels (new keys adopt F(k); evicted
            # keys clear) and the closed-form S(k, w) per key
            alive = held_cnt > 0
            t = fleet.task
            t[:dom] = np.where(alive,
                               np.where(t[:dom] >= 0, t[:dom],
                                        dest_host[:dom].astype(np.int32)),
                               -1)
            fleet.mem[:dom] = (spec.slot_bytes * held_cnt
                               + spec.bytes_per_unit * held_sum)
            fleet.mem[:dom][~alive] = 0.0

            # stat universe = seen ∪ held == alive: a seen key's current slot
            # never expires at its own boundary, so seen ⊆ held-after
            uni = np.nonzero(alive)[0].astype(np.int64)
            if uni.size:
                cost = np.zeros(uni.size, dtype=np.float64)
                cost[np.searchsorted(uni, gk)] = key_cost_g
                stats = KeyStats(keys=uni,
                                 cost=cost,
                                 mem=fleet.mem[uni].copy(),
                                 freq=counts_h[alive].astype(np.float64))
        else:
            if fleet.domain and expire.any():
                held_cnt, held_sum = fleet.evict(keep)
                dom = fleet.domain
                alive = held_cnt[:dom] > 0
                fleet.task[:dom] = np.where(alive, fleet.task[:dom], -1)
                fleet.mem[:dom] = (spec.slot_bytes * held_cnt[:dom]
                                   + spec.bytes_per_unit * held_sum[:dom])
                fleet.mem[:dom][~alive] = 0.0
            if fleet.domain:
                uni = np.nonzero(fleet.task[:fleet.domain] >= 0)[0] \
                    .astype(np.int64)
                if uni.size:
                    stats = KeyStats(keys=uni,
                                     cost=np.zeros(uni.size),
                                     mem=fleet.mem[uni].copy(),
                                     freq=np.zeros(uni.size))

        report = self._finish_interval(iv, n, task_cost, buffered_count, stats)
        if not collect_emits:
            return report
        if n == 0:
            return report, np.zeros(0, np.int64), np.zeros(0, np.float64)
        _, inv, ucounts = np.unique(keys, return_inverse=True,
                                    return_counts=True)
        from .operators import _occurrence_index
        occ = _occurrence_index(inv, ucounts)
        evals = op.device_emit_values(keys, occ, win0_h, slot0_h)
        if evals is None:
            return report, np.zeros(0, np.int64), np.zeros(0, np.float64)
        return report, keys.astype(np.int64, copy=False), evals

    # -- one interval of traffic ------------------------------------------------
    def process_interval(self, tuples: Sequence[Tuple[int, Any]]) -> IntervalReport:
        """Process one interval given ``(key, value)`` tuples (list API)."""
        keys = np.fromiter((k for k, _ in tuples), dtype=np.int64,
                           count=len(tuples))
        values = [v for _, v in tuples]
        return self.process_interval_arrays(keys, values)

    def process_interval_arrays(self, keys: np.ndarray,
                                values: Optional[Sequence[Any]] = None
                                ) -> IntervalReport:
        """Array-native entry point: ``keys`` as int64 array, ``values`` as an
        aligned sequence (or None when the operator sets ``needs_values``
        False). This is the zero-conversion path used by the benchmarks."""
        if not self.vectorized:
            return self._process_interval_reference(keys, values)
        if self._device:
            return self._process_interval_device(keys, values)
        return self._process_interval_vectorized(keys, values)

    def process_interval_emits(self, keys: np.ndarray,
                               values: Optional[Sequence[Any]] = None
                               ) -> Tuple[IntervalReport, np.ndarray,
                                          np.ndarray]:
        """Process one interval and also return the operator's emit stream.

        Returns ``(report, emit_keys, emit_values)``. Emits are ordered by
        source-tuple position (the fan-out emits of one tuple stay adjacent,
        in emit order) — per-key state only depends on that key's own tuple
        order, which pause/replay preserves, so BOTH engine paths produce
        this exact stream. That canonical order is what makes chained stages
        parity-testable; it is the stage-to-stage hand-off used by
        :class:`repro.streams.topology.Topology`.
        """
        if not self.vectorized:
            return self._process_interval_reference(keys, values,
                                                    collect_emits=True)
        if self._device:
            return self._process_interval_device(keys, values,
                                                 collect_emits=True)
        return self._process_interval_vectorized(keys, values,
                                                 collect_emits=True)

    def _process_interval_vectorized(self, keys: np.ndarray,
                                     values: Optional[Sequence[Any]] = None,
                                     collect_emits: bool = False):
        self._interval += 1
        iv = self._interval
        n = int(keys.shape[0])
        task_cost = np.zeros(self.n_tasks)
        acc_keys: List[np.ndarray] = []
        acc_cost: List[np.ndarray] = []
        acc_freq: List[np.ndarray] = []
        emit_acc: Optional[List[Tuple[np.ndarray, np.ndarray, np.ndarray]]] = \
            [] if collect_emits else None
        buffered_count = 0

        dests = self._dest_batch(keys) if n else np.zeros(0, np.int64)

        # Micro-batch boundaries are only *observable* through the pause
        # window: the first `migration_batches` of `micro_batches` slices
        # buffer Delta-keys while migration is in flight. Outside that
        # window the batched operators are batch-boundary-invariant (their
        # per-key closed forms telescope — see operators.py), so the engine
        # coalesces the interval into at most two macro-dispatches:
        #   A. the pause window, with Delta-keys masked out and buffered;
        #   B. Resume — buffered tuples replayed (CURRENT assignment, which
        #      equals `dests` since F only changes at interval boundaries)
        #      followed by the rest of the stream.
        if n and self._pending_delta_arr is not None:
            edges = np.linspace(0, n, self.micro_batches + 1).astype(int)
            pause_hi = edges[min(self.migration_batches, self.micro_batches)]
            head = np.arange(pause_hi)
            paused = np.isin(keys[:pause_hi], self._pending_delta_arr)
            buffered_count = int(paused.sum())
            kept = head[~paused]
            if kept.size:
                self._process_batch(iv, keys[kept], dests[kept], kept, values,
                                    task_cost, acc_keys, acc_cost, acc_freq,
                                    emit_acc)
            resume = np.concatenate([head[paused], np.arange(pause_hi, n)])
            if resume.size:
                self._process_batch(iv, keys[resume], dests[resume], resume,
                                    values, task_cost, acc_keys, acc_cost,
                                    acc_freq, emit_acc)
        elif n:
            idx = np.arange(n)
            self._process_batch(iv, keys, dests, idx, values, task_cost,
                                acc_keys, acc_cost, acc_freq, emit_acc)
        self._pending_delta = None
        self._pending_delta_arr = None

        held = [store.end_interval_collect(iv) for store in self.stores]

        stats = self._collect_stats_vectorized(acc_keys, acc_cost, acc_freq,
                                               held)
        report = self._finish_interval(iv, n, task_cost, buffered_count, stats)
        if not collect_emits:
            return report
        ekeys, evals = self._assemble_emits(emit_acc)
        return report, ekeys, evals

    @staticmethod
    def _assemble_emits(emit_acc) -> Tuple[np.ndarray, np.ndarray]:
        """Order accumulated (positions, keys, values) chunks into the
        canonical source-position emit stream. Positions are unique per
        source tuple across chunks, and one tuple's emits are contiguous
        within a chunk, so a stable argsort reproduces stream order."""
        if not emit_acc:
            return np.zeros(0, np.int64), np.zeros(0, np.float64)
        pos = np.concatenate([p for p, _, _ in emit_acc])
        ekeys = np.concatenate([k for _, k, _ in emit_acc])
        evals = np.concatenate([v for _, _, v in emit_acc])
        order = np.argsort(pos, kind="stable")
        return ekeys[order], evals[order]

    def _process_batch(self, iv: int, bkeys: np.ndarray, bdests: np.ndarray,
                       abs_idx: np.ndarray, values: Optional[Sequence[Any]],
                       task_cost, acc_keys, acc_cost, acc_freq,
                       emit_acc=None) -> None:
        """Hand one macro-batch to the operator.

        Columnar backend: ONE whole-interval dispatch — the operator lexsorts
        on (dest, key) once, computes every segment's closed forms in a
        single pass, and scatters per-task costs with one ``np.bincount``.
        Object backend: partition per task via argsort + segment boundaries
        and call the operator's batched kernel per segment (compatibility
        path for custom operators; also the parity oracle)."""
        if self._columnar:
            op = self.operator
            if not op.columnar_needs_values or values is None:
                vals_b = None
            elif isinstance(values, np.ndarray):
                vals_b = values[abs_idx]
            else:
                vals_b = [values[i] for i in abs_idx.tolist()]
            res, emits = op.process_interval_batch(
                self.stores, iv, bkeys, bdests, self.n_tasks, vals_b,
                collect_emits=emit_acc is not None)
            task_cost += res.task_cost
            acc_keys.append(res.uniq_keys)
            acc_cost.append(res.key_cost)
            acc_freq.append(res.key_freq)
            for ok, ov in res.outputs:
                self.outputs[ok] = ov
            self.emitted_sum += res.emit_sum
            if emit_acc is not None:
                ecounts, ekeys, evals = emits
                if ekeys.size:
                    emit_acc.append((np.repeat(abs_idx, ecounts),
                                     ekeys, evals))
            return
        order = np.argsort(bdests, kind="stable")
        sorted_dests = bdests[order]
        bounds = np.searchsorted(sorted_dests, np.arange(self.n_tasks + 1))
        needs_values = self.operator.needs_values
        values_arr = values if isinstance(values, np.ndarray) else None
        for d in range(self.n_tasks):
            s0, s1 = bounds[d], bounds[d + 1]
            if s0 == s1:
                continue
            seg = order[s0:s1]
            kseg = bkeys[seg]
            vseg: Optional[Sequence[Any]] = None
            if needs_values:
                if values is None:
                    # match the reference path: absent payloads flow as None
                    vseg = [None] * len(seg)
                elif values_arr is not None:
                    vseg = values_arr[abs_idx[seg]]
                else:
                    vseg = [values[i] for i in abs_idx[seg]]
            if emit_acc is None:
                res = self.operator.process_batch(self.stores[d], iv, kseg,
                                                  vseg)
            else:
                res, ecounts, ekeys, evals = self.operator.process_batch_emits(
                    self.stores[d], iv, kseg, vseg)
                if ekeys.size:
                    emit_acc.append((np.repeat(abs_idx[seg], ecounts),
                                     ekeys, evals))
            task_cost[d] += res.task_cost
            acc_keys.append(res.uniq_keys)
            acc_cost.append(res.key_cost)
            acc_freq.append(res.key_freq)
            for ok, ov in res.outputs:
                self.outputs[ok] = ov
            self.emitted_sum += res.emit_sum

    def _dest_batch(self, keys: np.ndarray) -> np.ndarray:
        """F(k) for a key batch — numpy Assignment.dest or the Pallas kernel."""
        if self.substrate == "pallas" and keys.size:
            if int(keys.max()) > np.iinfo(np.int32).max or int(keys.min()) < 0:
                raise ValueError(
                    "substrate='pallas' requires key ids in [0, 2^31): the "
                    "routing kernel operates on int32 and larger ids would "
                    "silently alias")
            assignment = self.controller.assignment
            # pad the table to a stable capacity (next power of two, >= 128):
            # routing_lookup is jitted on the table shape, so size-exact
            # padding would retrace on every rebalance that resizes the table.
            # The capacity is a per-stage high-water mark — recomputing it
            # from the current table_size would shrink it again when the
            # table shrinks, so a table oscillating across a power-of-two
            # boundary (e.g. 128<->129 under Mixed churn) would retrace the
            # kernel every interval.
            needed = max(128, 1 << max(0, assignment.table_size - 1).bit_length())
            if needed > self._table_capacity:
                self._table_capacity = needed
            # Device-side table cache: rebuilding table_arrays and re-running
            # jnp.asarray uploads every interval is pure waste when the
            # assignment didn't change. The controller bumps
            # assignment_version on every rebalance/rescale, so (version,
            # table_size, capacity) only moves when the table can differ.
            # (In-place table mutation without a size change bypasses the
            # controller and is not supported by this cache.)
            cache_key = (self.controller.assignment_version,
                         assignment.table_size, self._table_capacity)
            if self._route_cache is None or self._route_cache[0] != cache_key:
                tk, td = assignment.table_arrays(self._table_capacity)
                self._route_cache = (
                    cache_key,
                    self._jnp.asarray(tk.astype(np.int32)),
                    self._jnp.asarray(td.astype(np.int32)))
            _, tk_dev, td_dev = self._route_cache
            out = self._kernel_route(
                self._jnp.asarray(keys.astype(np.int32)),
                tk_dev, td_dev,
                assignment.n_dest, seed=self._hash_seed,
                interpret=self._kernel_interpret)
            return np.asarray(out).astype(np.int64)
        return self.controller.assignment.dest(keys)

    # -- stats collection (paper Fig. 5 step 1), segment-sum form --------------
    def _collect_stats_vectorized(self, acc_keys, acc_cost, acc_freq,
                                  held) -> Optional[KeyStats]:
        # The stat universe is (keys seen this interval) UNION (keys still
        # holding window state): omitting quiet stateful keys would let a
        # table cleanup strand their state on the old task.
        seen = (np.concatenate(acc_keys) if acc_keys
                else np.zeros(0, np.int64))
        cost_parts = (np.concatenate(acc_cost) if acc_cost
                      else np.zeros(0, np.float64))
        freq_parts = (np.concatenate(acc_freq) if acc_freq
                      else np.zeros(0, np.float64))
        held_keys = np.concatenate([h[0] for h in held]) if held else \
            np.zeros(0, np.int64)
        held_sizes = np.concatenate([h[1] for h in held]) if held else \
            np.zeros(0, np.float64)
        universe = np.union1d(seen, held_keys)
        if not universe.size:
            return None
        if (self.substrate == "pallas" and seen.size
                and int(universe.max()) < self.stats_dense_max
                and int(universe.min()) >= 0):
            return self._collect_stats_pallas(seen, cost_parts, freq_parts,
                                              held_keys, held_sizes)
        pos = np.searchsorted(universe, seen)
        cost = metrics.segment_sum(cost_parts, pos, universe.size)
        freq = metrics.segment_sum(freq_parts, pos, universe.size)
        mem = metrics.segment_sum(held_sizes,
                                  np.searchsorted(universe, held_keys),
                                  universe.size)
        return KeyStats(keys=universe, cost=cost, mem=mem, freq=freq)

    def _collect_stats_pallas(self, seen, cost_parts, freq_parts, held_keys,
                              held_sizes) -> KeyStats:
        """Step-1 stats via the fused histogram kernel over a dense domain.

        The kernel is a weighted segment-sum (one-hot matmul on the MXU), so
        two passes — weights = per-key cost, weights = per-key freq — yield
        c(k) and g(k). Accumulation is float32 on-device; reports therefore
        match the numpy path to ~1e-6 relative, not bit-for-bit."""
        jnp = self._jnp
        num = int(max(seen.max(initial=0), held_keys.max(initial=0))) + 1
        seen_dev = jnp.asarray(seen.astype(np.int32))
        _, cost_d = self._kernel_stats(seen_dev, jnp.asarray(cost_parts), num,
                                       interpret=self._kernel_interpret)
        _, freq_d = self._kernel_stats(seen_dev, jnp.asarray(freq_parts), num,
                                       interpret=self._kernel_interpret)
        cost = np.asarray(cost_d, dtype=np.float64)
        freq = np.asarray(freq_d, dtype=np.float64)
        mem = metrics.segment_sum(held_sizes, held_keys, num)
        # universe = seen ∪ held — held membership, not mem > 0: a quiet key
        # whose window fully evicted still occupies the store and must stay
        # visible to the balancer (same invariant as the numpy paths)
        live = freq > 0
        live[held_keys] = True
        universe = np.nonzero(live)[0].astype(np.int64)
        return KeyStats(keys=universe, cost=cost[live], mem=mem[live],
                        freq=freq[live])

    def _finish_interval(self, iv: int, n: int, task_cost: np.ndarray,
                         buffered_count: int,
                         stats: Optional[KeyStats]) -> IntervalReport:
        # -- measurement + controller handoff (paper steps 1-2) -----------------
        stall = self._migrated_bytes_pending / self.migration_bandwidth
        makespan = float(task_cost.max()) if n else 0.0
        report = IntervalReport(
            interval=iv, tuples=n, makespan=makespan, migration_stall=stall,
            throughput=n / (makespan + stall) if (makespan + stall) > 0 else 0.0,
            skewness=metrics.skewness(task_cost) if n else 1.0,
            theta=metrics.theta(task_cost) if n else 0.0,
            migrated_bytes=self._migrated_bytes_pending,
            table_size=self.controller.assignment.table_size,
            plan_time_s=self._plan_time_pending,
            buffered=buffered_count, task_loads=task_cost,
        )
        self.reports.append(report)
        self._migrated_bytes_pending = 0.0
        self._plan_time_pending = 0.0
        if stats is not None:
            self.last_stats = stats
            # pin the event to the STAGE interval: a stats-free interval
            # (no tuples, no held state) skips the controller, and its
            # private counter would silently lag the stage clock otherwise
            ev = self.controller.on_interval(stats, interval=iv)
            if ev.result is not None:
                self._plan_time_pending = ev.result.plan_time_s
        return report

    # -- reference per-tuple path (parity oracle; vectorized=False) ------------
    def _process_interval_reference(self, keys: np.ndarray,
                                    values: Optional[Sequence[Any]],
                                    collect_emits: bool = False):
        self._interval += 1
        iv = self._interval
        n = int(keys.shape[0])
        vals = values if values is not None else [None] * n
        if self._pending_delta is None and self._pending_delta_arr is not None:
            self._pending_delta = set(self._pending_delta_arr.tolist())
        task_cost = np.zeros(self.n_tasks)
        key_cost: Dict[int, float] = defaultdict(float)
        key_freq: Dict[int, float] = defaultdict(float)
        buffer: List[Tuple[int, int, Any]] = []      # (position, key, value)
        buffered_count = 0
        emit_log: Optional[List[Tuple[int, int, Any]]] = \
            [] if collect_emits else None

        dests = self._dest_batch(keys) if n else np.zeros(0, np.int64)

        batch_edges = np.linspace(0, n, self.micro_batches + 1).astype(int)
        for b in range(self.micro_batches):
            lo, hi = batch_edges[b], batch_edges[b + 1]
            migrating = (self._pending_delta is not None
                         and b < self.migration_batches)
            if not migrating and buffer:
                # Resume: replay buffered tuples with the CURRENT assignment
                for pos, k, v in buffer:
                    d = int(self.controller.assignment.dest(
                        np.asarray([k], dtype=np.int64))[0])
                    self._run_one(d, iv, k, v, pos, task_cost, key_cost,
                                  key_freq, emit_log)
                buffer.clear()
                self._pending_delta = None
                self._pending_delta_arr = None
            for i in range(lo, hi):
                k, v = int(keys[i]), vals[i]
                if migrating and k in self._pending_delta:
                    buffer.append((i, k, v))        # Pause: cache locally
                    buffered_count += 1
                    continue
                self._run_one(int(dests[i]), iv, k, v, i, task_cost, key_cost,
                              key_freq, emit_log)
        if buffer:                                   # traffic ended mid-pause
            for pos, k, v in buffer:
                d = int(self.controller.assignment.dest(
                    np.asarray([k], dtype=np.int64))[0])
                self._run_one(d, iv, k, v, pos, task_cost, key_cost, key_freq,
                              emit_log)
            buffer.clear()
        self._pending_delta = None
        self._pending_delta_arr = None

        for store in self.stores:
            store.end_interval(iv)

        stats = self._collect_stats(key_cost, key_freq)
        report = self._finish_interval(iv, n, task_cost, buffered_count, stats)
        if not collect_emits:
            return report
        # canonical order = source position (replays keep their original
        # position, and a tuple's emits were appended contiguously)
        emit_log.sort(key=lambda t: t[0])
        ekeys = np.asarray([k for _, k, _ in emit_log], dtype=np.int64)
        evals = np.asarray([v for _, _, v in emit_log])
        return report, ekeys, evals

    def _run_one(self, d: int, interval: int, key: int, value: Any, pos: int,
                 task_cost, key_cost, key_freq, emit_log=None) -> None:
        outs, cost = self.operator.process(self.stores[d], interval, key, value)
        task_cost[d] += cost
        key_cost[key] += cost
        key_freq[key] += 1
        for ok, ov in outs:
            self.outputs[ok] = ov
            if isinstance(ov, (int, float)):
                self.emitted_sum += float(ov)
            if emit_log is not None:
                emit_log.append((pos, ok, ov))

    def _collect_stats(self, key_cost, key_freq) -> Optional[KeyStats]:
        # Paper step 1: every instance reports c(k) AND S(k,w) for each key
        # *assigned to it* — the stat universe is (keys seen this interval)
        # UNION (keys still holding window state). Omitting quiet stateful
        # keys would let a table cleanup strand their state on the old task.
        sizes: Dict[int, float] = {}
        for store in self.stores:
            sizes.update(store.sizes())
        universe = set(key_cost) | set(sizes)
        if not universe:
            return None
        keys = np.fromiter(sorted(universe), dtype=np.int64, count=len(universe))
        cost = np.fromiter((key_cost.get(int(k), 0.0) for k in keys),
                           dtype=np.float64)
        freq = np.fromiter((key_freq.get(int(k), 0.0) for k in keys),
                           dtype=np.float64)
        mem = np.fromiter((sizes.get(int(k), 0.0) for k in keys),
                          dtype=np.float64)
        return KeyStats(keys=keys, cost=cost, mem=mem, freq=freq)

    # -- elastic scaling (paper Fig. 15) ----------------------------------------
    def scale_to(self, n_tasks: int) -> None:
        """Add/remove task instances and rebalance state onto the new fleet.

        New stores must exist before the controller's migration executor runs;
        shrink requires draining removed stores first (state migrates away via
        the rescale plan, since no key may map to a dead task)."""
        if self.last_stats is None:
            raise RuntimeError("scale_to requires at least one processed interval")
        while len(self.stores) < n_tasks:
            self.stores.append(self._new_store())
        self.controller.rescale(n_tasks, self.last_stats)
        # reconciliation sweep: the rescale executor only covers keys present
        # in the last interval's stats; stale-state keys re-hash too. Pack
        # extraction + mask splits keep this array-native on both backends.
        for s_idx, store in enumerate(self.stores):
            held, _ = store.sizes_arrays()
            if not held.size:
                continue
            dst = self.controller.assignment.dest(held)
            movers = held[dst != s_idx]
            if movers.size:
                pack = store.extract_batch(movers)
                self._migrated_bytes_pending += pack.nbytes
                pdst = self.controller.assignment.dest(pack.keys)
                for d in np.unique(pdst):
                    self.stores[int(d)].install_batch(pack.take(pdst == d))
        self.stores = self.stores[:n_tasks]
        self.n_tasks = n_tasks

    # -- invariant helpers for tests -------------------------------------------
    def total_state_keys(self) -> int:
        return sum(len(s.keys) for s in self.stores)

    def key_location(self, key: int) -> List[int]:
        return [i for i, s in enumerate(self.stores) if key in s.keys]
