"""Checkpointed recovery: interval-aligned stage/topology snapshots.

The recovery story rides entirely on seams that already exist:

* **State** travels as the same packs the migration path uses —
  :meth:`StateBackend.checkpoint` extracts every task's held keys through
  ``extract_batch``, clones the pack (``ObjectPack`` deepcopies its live
  ``KeyState`` refs; ``ColumnarPack`` rows are already independent arrays)
  and installs it straight back, so a checkpoint is observationally
  transparent on every backend (object/columnar/device/sharded).
* **Routing** travels as :meth:`RebalanceController.state_dict` —
  assignment table + hash router, ``assignment_version``, interval clock,
  trigger history, and (in sketch mode) the CMS/SpaceSaving contents via
  their own ``state_dict`` seams.
* **Time** is the interval boundary: a :class:`StageCheckpoint` is only
  meaningful *between* intervals, which is exactly when
  :class:`~repro.streams.faults.ChaosRunner` takes them. Restoring rewinds
  the stage clock, so replaying the buffered intervals after the checkpoint
  reproduces the original :class:`~repro.streams.engine.IntervalReport`
  stream bit-for-bit (proved in ``tests/test_chaos_recovery.py``).

Durability uses the classic tmp-file + ``os.replace`` + manifest dance:
:class:`CheckpointStore` writes ``ckpt_<interval>.pkl`` atomically first,
then atomically replaces ``MANIFEST.json`` to point at it — a crash at any
point leaves the previous manifest (and therefore a complete, readable
checkpoint) in place.

This module is deliberately jax-free and imports neither the engine nor the
topology: stages and topologies are duck-typed, so ``import
repro.streams.checkpoint`` stays cheap and dependency-light.
"""

from __future__ import annotations

import dataclasses
import json
import os
import pickle
from typing import Any, Dict, List, Optional

import numpy as np

__all__ = [
    "StageCheckpoint", "TopologyCheckpoint", "CheckpointStore",
    "checkpoint_stage", "restore_stage",
    "checkpoint_topology", "restore_topology",
]


@dataclasses.dataclass
class StageCheckpoint:
    """Everything needed to rebuild one KeyedStage at an interval boundary.

    ``packs`` holds one cloned state pack per task (the same pack types the
    migration path moves); ``backend_extra`` carries backend-private extras
    (the device fleet's ring-column clock ``col_iv`` — empty packs cannot
    carry it). ``pending_delta`` / ``migrated_bytes_pending`` /
    ``plan_time_pending`` are the cross-interval carry of the Pause ->
    migrate -> Resume protocol: a rebalance planned at interval *i* opens
    the pause window and books its stall during interval *i+1*, so a
    boundary-*i* checkpoint must preserve them for the replay to match.
    """

    backend: str                       # stage.state_backend, validated on restore
    interval: int
    n_tasks: int
    window: int
    packs: List[Any]                   # one cloned pack per task
    backend_extra: Dict[str, Any]
    pending_delta: Optional[np.ndarray]
    migrated_bytes_pending: float
    plan_time_pending: float
    table_capacity: int
    emitted_sum: float
    outputs: Dict[int, Any]
    reports: List[Any]
    last_stats: Any
    controller: Dict[str, Any]         # RebalanceController.state_dict()


def checkpoint_stage(stage) -> StageCheckpoint:
    """Snapshot ``stage`` at its current interval boundary.

    Must be called between intervals (never from inside
    ``process_interval``): the snapshot captures the post-interval-*i*
    boundary state, including any migration carry planned at *i*.
    """
    snap = stage.backend.checkpoint()
    packs = snap.pop("packs")
    return StageCheckpoint(
        backend=stage.state_backend,
        interval=stage._interval,
        n_tasks=stage.n_tasks,
        window=stage.window,
        packs=packs,
        backend_extra=snap,
        pending_delta=(stage._pending_delta_arr.copy()
                       if stage._pending_delta_arr is not None else None),
        migrated_bytes_pending=stage._migrated_bytes_pending,
        plan_time_pending=stage._plan_time_pending,
        table_capacity=stage._table_capacity,
        emitted_sum=stage.emitted_sum,
        outputs=dict(stage.outputs),
        reports=list(stage.reports),
        last_stats=stage.last_stats,
        controller=stage.controller.state_dict(),
    )


def restore_stage(stage, ckpt: StageCheckpoint) -> None:
    """Rebuild ``stage`` from ``ckpt`` (in place; reusable checkpoint).

    The target stage must be structurally compatible — same backend and
    window — but may be freshly constructed or mid-run with arbitrary state:
    everything run-dependent is overwritten. One checkpoint object restores
    any number of times (packs are re-cloned on install, the controller
    state is re-copied on load), which is what lets the chaos runner retry
    a replay that itself hits an injected fault.
    """
    if ckpt.backend != stage.state_backend:
        raise ValueError(
            f"checkpoint was taken on state_backend={ckpt.backend!r} but the "
            f"target stage runs {stage.state_backend!r}; packs are only "
            "portable within a backend")
    if ckpt.window != stage.window:
        raise ValueError(
            f"checkpoint window={ckpt.window} != stage window={stage.window}: "
            "the ring layout would not line up")
    stage.backend.restore(ckpt)
    stage.n_tasks = ckpt.n_tasks
    stage._interval = ckpt.interval
    stage._pending_delta = None
    stage._pending_delta_arr = (ckpt.pending_delta.copy()
                                if ckpt.pending_delta is not None else None)
    stage._migrated_bytes_pending = float(ckpt.migrated_bytes_pending)
    stage._plan_time_pending = float(ckpt.plan_time_pending)
    stage._table_capacity = int(ckpt.table_capacity)
    # assignment_version rewinds on restore, so any cached routing keyed on
    # it would alias a *different* table — drop the caches unconditionally
    stage._route_cache = None
    stage.emitted_sum = float(ckpt.emitted_sum)
    stage.outputs = dict(ckpt.outputs)
    stage.reports = list(ckpt.reports)
    stage.last_stats = ckpt.last_stats
    stage.controller.load_state_dict(ckpt.controller)
    # the executor is a bound method of the (possibly new) stage, never
    # part of the serialized controller state — rewire it explicitly
    stage.controller.executor = stage._execute_migration


@dataclasses.dataclass
class TopologyCheckpoint:
    """A whole pipeline at one interval boundary: per-stage coordination.

    All stages snapshot at the *same* source interval — the topology clock —
    so a restore rewinds the entire chain coherently and replaying source
    traffic reproduces every stage's report stream.
    """

    interval: int
    last_emit_keys: np.ndarray
    last_emit_values: Any
    reports: List[Any]
    stages: List[StageCheckpoint]


def checkpoint_topology(topo) -> TopologyCheckpoint:
    """Snapshot every stage of ``topo`` at the current source boundary."""
    return TopologyCheckpoint(
        interval=topo._interval,
        last_emit_keys=np.asarray(topo.last_emit_keys).copy(),
        last_emit_values=(np.asarray(topo.last_emit_values).copy()
                          if topo.last_emit_values is not None else None),
        reports=list(topo.reports),
        stages=[checkpoint_stage(spec.stage) for spec in topo.specs],
    )


def restore_topology(topo, ckpt: TopologyCheckpoint) -> None:
    """Rebuild every stage of ``topo`` from a coherent pipeline snapshot."""
    if len(ckpt.stages) != len(topo.specs):
        raise ValueError(
            f"checkpoint has {len(ckpt.stages)} stages but the topology has "
            f"{len(topo.specs)}")
    for spec, stage_ckpt in zip(topo.specs, ckpt.stages):
        restore_stage(spec.stage, stage_ckpt)
    topo._interval = ckpt.interval
    topo.last_emit_keys = np.asarray(ckpt.last_emit_keys).copy()
    topo.last_emit_values = (np.asarray(ckpt.last_emit_values).copy()
                             if ckpt.last_emit_values is not None else None)
    topo.reports = list(ckpt.reports)


class CheckpointStore:
    """Durable checkpoint directory with an interval-aligned atomic manifest.

    Layout::

        <dir>/ckpt_00000004.pkl     one pickle per retained checkpoint
        <dir>/MANIFEST.json         {"latest": ..., "interval": ...}

    Both the checkpoint file and the manifest are written tmp-then-
    ``os.replace``, and the manifest is only flipped *after* the checkpoint
    file is fully on disk — a crash mid-save leaves the previous manifest
    pointing at a complete snapshot. ``keep`` bounds retention (older
    checkpoint files are unlinked after the manifest flip).
    """

    MANIFEST = "MANIFEST.json"

    def __init__(self, directory, keep: int = 2):
        if keep < 1:
            raise ValueError(f"keep must be >= 1, got {keep}")
        self.directory = str(directory)
        self.keep = keep
        os.makedirs(self.directory, exist_ok=True)

    def _path(self, name: str) -> str:
        return os.path.join(self.directory, name)

    def save(self, ckpt) -> str:
        """Persist ``ckpt`` atomically and flip the manifest to it."""
        name = f"ckpt_{int(ckpt.interval):08d}.pkl"
        path = self._path(name)
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            pickle.dump(ckpt, f, protocol=pickle.HIGHEST_PROTOCOL)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        mtmp = self._path(self.MANIFEST + ".tmp")
        with open(mtmp, "w") as f:
            json.dump({"latest": name, "interval": int(ckpt.interval)}, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(mtmp, self._path(self.MANIFEST))
        self._prune(keep_name=name)
        return path

    def _prune(self, keep_name: str) -> None:
        snaps = sorted(n for n in os.listdir(self.directory)
                       if n.startswith("ckpt_") and n.endswith(".pkl"))
        for stale in snaps[:-self.keep]:
            if stale != keep_name:
                os.unlink(self._path(stale))

    def latest_interval(self) -> Optional[int]:
        mpath = self._path(self.MANIFEST)
        if not os.path.exists(mpath):
            return None
        with open(mpath) as f:
            return int(json.load(f)["interval"])

    def load_latest(self):
        """The checkpoint the manifest points at, or None if none saved."""
        mpath = self._path(self.MANIFEST)
        if not os.path.exists(mpath):
            return None
        with open(mpath) as f:
            manifest = json.load(f)
        with open(self._path(manifest["latest"]), "rb") as f:
            return pickle.load(f)
