"""Multi-device sharded streaming: the dense device ring over a JAX mesh.

``KeyedStage(state_backend="sharded")`` runs :mod:`repro.streams.device`'s
dense key-indexed state ring across ``n_shards`` devices of a 1-D
``("shard",)`` mesh (built with :func:`repro.launch.mesh.make_mesh`), with
the whole interval still ONE jitted step — now a ``shard_map`` whose only
cross-device traffic is a single masked ``all_to_all``.

Placement: key-block sharding
-----------------------------
The global dense domain ``D`` (power-of-two high-water mark, as on one
device) is split into ``S`` contiguous key blocks of ``B = ceil(D / S)``
rows; key ``k`` lives on shard ``k // B`` at local row ``k % B`` forever.
Each shard appends its own padding-sink row (local index ``B``), so the
global state arrays are ``(window+1, S * (B+1))`` with
``NamedSharding(mesh, P(None, "shard"))`` and every shard-local scatter can
dump masked/padded lanes harmlessly, exactly like the single-device layout.

State placement is a function of the KEY, not of the assignment — F(k)
moves keys between *tasks*, never between *shards*. That is why rebalance
migration stays relabel-only per shard (the host ``task`` mirror is the only
thing that changes, same as the single-device backend) and why the paper's
protocol cost model is preserved bit-for-bit: migrated bytes still come
from the closed-form ``mem`` mirror.

Dataflow: replicated table, one all_to_all per interval
-------------------------------------------------------
The *stream* enters the mesh sliced by position: the interval's tuple batch
is split into ``S`` contiguous chunks (padded to a power-of-two cap with
key ``-1``), one per device — the moral equivalent of ``S`` upstream
sources. Each device then ships its tuples' contributions to the shard that
owns each key inside the jitted step:

* "add" mode never moves tuples at all: each device builds an ``(S, B+1)``
  partial histogram of its chunk (rows = destination shard) and ONE tiled
  ``all_to_all`` transposes partials across the mesh; the receiving shard
  sums its ``S`` incoming rows. Traffic is ``S * (B+1)`` ints per device
  regardless of tuple count.
* "max" mode needs the raw values for the scatter-max fold, so each device
  builds masked ``(S, cap)`` send matrices (key ``-1`` / value
  ``INT32_MIN`` in lanes that target other shards) and the same tiled
  ``all_to_all`` delivers every tuple to its owner, which folds locally.

The routing table stays host-replicated: the controller's small mixed
table (the paper's core "small Delta" property is exactly what makes
replication cheap) is broadcast to every device on ``assignment_version``
bumps, and each shard rebuilds the ``F(k)`` column for ITS key block only
(``axis_index * B + arange``), so the dense route refresh parallelizes
S-ways and no dense table is ever shipped.

Everything downstream of the step — float64 closed forms, ownership/mem
mirrors, stats, emits — is shared verbatim with the single-device backend:
:class:`ShardedStateFleet` returns *host-dense* ``(D+1,)`` views (the
per-shard blocks de-interleaved) so :class:`~repro.streams.backends.
DeviceBackend`'s host logic cannot tell the difference, which is what makes
``tests/test_engine_sharded.py``'s bit-parity against the object oracle a
structural property rather than a numerical accident.

CPU CI runs this with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(virtual devices; architecture demonstration). Real speedups, compiled
Mosaic kernels inside the shard_map, and donation of the sharded state are
TPU follow-ups — the route uses the jnp twin of the routing kernel
unconditionally for now.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.kernels.routing_lookup import _fmix32
from repro.launch.mesh import make_mesh

from .backends import DeviceBackend, register_backend
from .device import DeviceStateFleet
from .state import ColumnarSpec

_INT32_MIN = np.iinfo(np.int32).min

#: python-side-effect trace counters (same pattern as streams/device.py):
#: increments run at TRACE time only, so tests can assert the sharded step
#: compiles once across intervals and once per route refresh shape.
TRACE_COUNTS = {"interval_step": 0, "route_dense": 0}


def _build_step_add(mesh, S: int, B: int):
    """Jitted shard_map for one "add"-mode interval on an S-device mesh."""
    L = B + 1

    def body(vals, pres, kchunk, cur_col, keep_cols):
        TRACE_COUNTS["interval_step"] += 1
        k = kchunk[0]                                  # this device's chunk
        valid = k >= 0
        t = jnp.where(valid, k // B, 0)
        r = jnp.where(valid, k % B, B)
        # partial histogram: row s = my chunk's counts for shard s's block
        partial = jnp.zeros((S, L), jnp.int32).at[t, r] \
            .add(valid.astype(jnp.int32))
        # transpose partials across the mesh: after the tiled all_to_all,
        # row i holds device i's partial for MY block — sum and fold
        recv = jax.lax.all_to_all(partial, "shard", 0, 0, tiled=True)
        counts = recv.sum(axis=0).at[B].set(0)
        win0 = vals.sum(axis=0)
        slot0 = (vals * cur_col[:, None]).sum(axis=0)
        seen = (counts > 0).astype(jnp.int32)
        vals = vals + cur_col[:, None] * counts[None, :]
        pres = jnp.maximum(pres, cur_col[:, None] * seen[None, :])
        vals = vals * keep_cols[:, None]
        pres = pres * keep_cols[:, None]
        return (vals, pres, counts, win0, slot0,
                pres.sum(axis=0), vals.sum(axis=0))

    return jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=(P(None, "shard"), P(None, "shard"), P("shard", None),
                  P(None), P(None)),
        out_specs=(P(None, "shard"), P(None, "shard"), P("shard"), P("shard"),
                   P("shard"), P("shard"), P("shard"))))


def _build_step_max(mesh, S: int, B: int):
    """Jitted shard_map for one "max"-mode interval: tuples travel."""
    L = B + 1

    def body(vals, pres, kchunk, vchunk, cur_col, keep_cols):
        TRACE_COUNTS["interval_step"] += 1
        k = kchunk[0]
        v = vchunk[0]
        valid = k >= 0
        t = jnp.where(valid, k // B, 0)
        # masked send matrices: row s carries only my lanes that target
        # shard s; every other lane is the padding identity
        hit = valid[None, :] & (t[None, :] == jnp.arange(S,
                                                         dtype=k.dtype)[:, None])
        send_k = jnp.where(hit, k[None, :], -1)
        send_v = jnp.where(hit, v[None, :], _INT32_MIN)
        rk = jax.lax.all_to_all(send_k, "shard", 0, 0, tiled=True).reshape(-1)
        rv = jax.lax.all_to_all(send_v, "shard", 0, 0, tiled=True).reshape(-1)
        rvalid = rk >= 0
        r = jnp.where(rvalid, rk % B, B)
        counts = jnp.zeros((L,), jnp.int32).at[r] \
            .add(rvalid.astype(jnp.int32)).at[B].set(0)
        gmax = jnp.full((L,), _INT32_MIN, jnp.int32).at[r].max(rv)
        win0 = vals.sum(axis=0)
        slot0 = (vals * cur_col[:, None]).sum(axis=0)
        seen = (counts > 0).astype(jnp.int32)
        newslot = jnp.where(seen > 0, jnp.maximum(slot0, gmax), slot0)
        vals = vals + cur_col[:, None] * (newslot - slot0)[None, :]
        pres = jnp.maximum(pres, cur_col[:, None] * seen[None, :])
        vals = vals * keep_cols[:, None]
        pres = pres * keep_cols[:, None]
        return (vals, pres, counts, win0, slot0,
                pres.sum(axis=0), vals.sum(axis=0))

    return jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=(P(None, "shard"), P(None, "shard"), P("shard", None),
                  P("shard", None), P(None), P(None)),
        out_specs=(P(None, "shard"), P(None, "shard"), P("shard"), P("shard"),
                   P("shard"), P("shard"), P("shard"))))


def _build_route(mesh, S: int, B: int, n_dest: int, seed: int):
    """Jitted shard_map route refresh: each shard computes F(k) for its own
    key block from the replicated (tkeys, tdests) table — the jnp twin of
    the routing kernel's mix + table-override semantics."""
    L = B + 1

    def body(tk, td):
        TRACE_COUNTS["route_dense"] += 1
        me = jax.lax.axis_index("shard").astype(jnp.int32)
        kid = me * B + jnp.arange(L, dtype=jnp.int32)
        h = _fmix32(kid.astype(jnp.uint32) ^ jnp.uint32(seed & 0xFFFFFFFF))
        base = (h % jnp.uint32(n_dest)).astype(jnp.int32)
        ok = (tk >= 0) & (tk < S * B) & (tk // B == me)
        slot = jnp.where(ok, tk % B, B)
        # non-local / empty table slots write base[B] onto the sink row — a
        # no-op (same trick as device._route_dense's padding row)
        return base.at[slot].set(jnp.where(ok, td, base[B]))

    return jax.jit(shard_map(body, mesh=mesh, in_specs=(P(None), P(None)),
                             out_specs=P("shard")))


class ShardedStateFleet(DeviceStateFleet):
    """The dense state ring block-sharded across an S-device mesh.

    Drop-in for :class:`~repro.streams.device.DeviceStateFleet`: the same
    surface, but ``vals``/``pres`` are global ``(W1, S*(B+1))`` arrays
    sharded over the mesh's ``"shard"`` axis, and every host-facing output
    (step observables, route tables, ``host_state``) is de-interleaved back
    to the key-dense ``(D+1,)`` layout so the engine-side closed forms are
    shared verbatim with the single-device backend.
    """

    def __init__(self, window: int, spec: ColumnarSpec,
                 n_shards: Optional[int] = None, min_domain: int = 512):
        n_avail = jax.device_count()
        if n_shards is None:
            n_shards = n_avail
        if not 1 <= n_shards <= n_avail:
            raise ValueError(
                f"n_shards={n_shards} outside [1, {n_avail}] available jax "
                "devices (set XLA_FLAGS=--xla_force_host_platform_device_"
                "count=N for virtual CPU devices)")
        self.n_shards = int(n_shards)
        self.mesh = make_mesh((self.n_shards,), ("shard",))
        self._sharding = NamedSharding(self.mesh, P(None, "shard"))
        self._block = 0            # B: keys per shard; local sink row is B
        self._chunk_cap = 0        # per-shard tuple-chunk pad bucket (pow2 HWM)
        self._step_fns = {}        # (mode, B) -> jitted shard_map
        self._route_fns = {}       # (B, n_dest, seed) -> jitted shard_map
        super().__init__(window, spec, min_domain)

    # -- layout helpers ---------------------------------------------------------
    def _gcols(self, rows: np.ndarray) -> np.ndarray:
        """Global key ids -> columns of the interleaved sharded layout."""
        B = self._block
        return ((rows // B) * (B + 1) + rows % B).astype(np.int64)

    def _to_dense_1d(self, garr) -> np.ndarray:
        """(S*(B+1),) global output -> host key-dense (domain+1,)."""
        a = np.asarray(garr)
        if self._block == 0:           # domain never grown: nothing held yet
            return np.zeros(self.domain + 1, a.dtype)
        L = self._block + 1
        dense = a.reshape(self.n_shards, L)[:, :self._block] \
            .reshape(-1)[:self.domain]
        out = np.zeros(self.domain + 1, a.dtype)
        out[:self.domain] = dense
        return out

    def _to_dense_2d(self, a: np.ndarray) -> np.ndarray:
        if self._block == 0:           # domain never grown: nothing held yet
            return np.zeros((a.shape[0], self.domain + 1), a.dtype)
        L = self._block + 1
        dense = a.reshape(a.shape[0], self.n_shards, L)[:, :, :self._block] \
            .reshape(a.shape[0], -1)[:, :self.domain]
        out = np.zeros((a.shape[0], self.domain + 1), a.dtype)
        out[:, :self.domain] = dense
        return out

    # -- shape management -------------------------------------------------------
    def ensure_domain(self, needed: int) -> bool:
        if needed <= self.domain:
            return False
        old_dom = self.domain
        if old_dom:
            old_vals, old_pres = self.host_state()    # key-dense (W1, D+1)
        dom = max(self._min_domain, 1 << (int(needed) - 1).bit_length())
        S = self.n_shards
        B = -(-dom // S)          # ceil: rows in [dom, S*B) are dead padding
        G = S * (B + 1)
        vals = np.zeros((self._ncols, G), np.int32)
        pres = np.zeros((self._ncols, G), np.int32)
        task = np.full(dom + 1, -1, dtype=np.int32)
        mem = np.zeros(dom + 1, dtype=np.float64)
        self._block = B
        if old_dom:
            gcol = self._gcols(np.arange(old_dom))
            vals[:, gcol] = old_vals[:, :old_dom]
            pres[:, gcol] = old_pres[:, :old_dom]
            task[:old_dom] = self.task[:old_dom]
            mem[:old_dom] = self.mem[:old_dom]
        self.domain = dom
        self.vals = jax.device_put(vals, self._sharding)
        self.pres = jax.device_put(pres, self._sharding)
        self.task, self.mem = task, mem
        self._all_keys = None
        self._host_dirty = True
        return True

    # -- the fused hot path -----------------------------------------------------
    def _chunk(self, arr: Optional[np.ndarray], n: int, pad,
               cap: int) -> jnp.ndarray:
        flat = np.full(self.n_shards * cap, pad, dtype=np.int32)
        if n:
            flat[:n] = arr
        return jnp.asarray(flat.reshape(self.n_shards, cap))

    def interval_step(self, keys: np.ndarray, tuple_vals: Optional[np.ndarray],
                      dest_dense, n_tasks: int, keep_cols: np.ndarray,
                      cur_col: np.ndarray, mode: str):
        """Same contract as the parent, all-host-dense outputs; the final
        ``task_counts`` slot is always None (no built-in operator is
        max-mode AND unit-cost, so the engine derives per-task loads from
        counts + the host dest mirror — see backends.DeviceBackend)."""
        S = self.n_shards
        n = int(keys.shape[0])
        per = -(-n // S) if n else 1
        if per > self._chunk_cap:
            self._chunk_cap = max(256, 1 << (per - 1).bit_length())
        cap = self._chunk_cap
        kchunk = self._chunk(keys, n, -1, cap)
        fn_key = (mode, self._block)
        fn = self._step_fns.get(fn_key)
        if fn is None:
            build = _build_step_add if mode == "add" else _build_step_max
            fn = build(self.mesh, S, self._block)
            self._step_fns[fn_key] = fn
        cur = jnp.asarray(cur_col)
        keep = jnp.asarray(keep_cols)
        if mode == "add":
            out = fn(self.vals, self.pres, kchunk, cur, keep)
        else:
            vchunk = self._chunk(tuple_vals, n, _INT32_MIN, cap)
            out = fn(self.vals, self.pres, kchunk, vchunk, cur, keep)
        self.vals, self.pres = out[0], out[1]
        self._host_dirty = True
        return (self._to_dense_1d(out[2]), self._to_dense_1d(out[3]),
                self._to_dense_1d(out[4]), self._to_dense_1d(out[5]),
                self._to_dense_1d(out[6]), None)

    def evict(self, keep_cols: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        cnt, tot = super().evict(keep_cols)     # global (S*(B+1),) outputs
        return self._to_dense_1d(cnt), self._to_dense_1d(tot)

    def route_dense(self, tkeys: np.ndarray, tdests: np.ndarray, n_dest: int,
                    seed: int, use_kernel: bool, interpret: Optional[bool]):
        """S-way parallel dense route refresh from the replicated table.

        ``use_kernel`` is accepted for interface parity but the jnp twin is
        used unconditionally: Pallas-inside-shard_map is the TPU follow-up.
        """
        fn_key = (self._block, int(n_dest), int(seed))
        fn = self._route_fns.get(fn_key)
        if fn is None:
            fn = _build_route(self.mesh, self.n_shards, self._block,
                              int(n_dest), int(seed))
            self._route_fns[fn_key] = fn
        return fn(jnp.asarray(tkeys.astype(np.int32)),
                  jnp.asarray(tdests.astype(np.int32)))

    def dest_host_dense(self, dev) -> np.ndarray:
        return self._to_dense_1d(dev).astype(np.int64)

    # -- host snapshots (pack contract + introspection) -------------------------
    def host_state(self) -> Tuple[np.ndarray, np.ndarray]:
        if self._host_dirty:
            self._host_vals = self._to_dense_2d(np.asarray(self.vals))
            self._host_pres = self._to_dense_2d(np.asarray(self.pres))
            self._host_dirty = False
        return self._host_vals, self._host_pres

    def clear_rows(self, rows: np.ndarray) -> None:
        idx = jnp.asarray(self._gcols(rows).astype(np.int32))
        self.vals = self.vals.at[:, idx].set(0)
        self.pres = self.pres.at[:, idx].set(0)
        self.task[rows] = -1
        self.mem[rows] = 0.0
        self._host_dirty = True

    def install_rows(self, rows: np.ndarray, vals_cols: np.ndarray,
                     pres_cols: np.ndarray, task_idx: int,
                     sizes_rows: np.ndarray) -> None:
        idx = jnp.asarray(self._gcols(rows).astype(np.int32))
        self.vals = self.vals.at[:, idx].set(
            jnp.asarray(vals_cols.T.astype(np.int32)))
        self.pres = self.pres.at[:, idx].set(
            jnp.asarray(pres_cols.T.astype(np.int32)))
        self.task[rows] = task_idx
        self.mem[rows] = sizes_rows.sum(axis=1)
        self._host_dirty = True


@register_backend
class ShardedDeviceBackend(DeviceBackend):
    """The device backend over a :class:`ShardedStateFleet`.

    Everything above the fleet — closed forms, mirrors, stats, emits, the
    relabel-only migration — is inherited from
    :class:`~repro.streams.backends.DeviceBackend` untouched; the sharding
    is invisible outside the fused step. Explicit-only: ``auto`` never
    selects it (on CPU the virtual devices are an architecture
    demonstration, and on accelerators the choice of S belongs to the
    launcher).
    """

    name = "sharded"

    def _make_fleet(self):
        stage = self.stage
        return ShardedStateFleet(stage.window, stage.operator.columnar_spec,
                                 n_shards=stage.n_shards)

    @classmethod
    def auto_eligible(cls, operator, controller, vectorized):
        return False
