"""State-backend protocol: how a KeyedStage's keyed state lives and moves.

:class:`~repro.streams.engine.KeyedStage` is a thin router+controller shell;
everything state-shaped — store layout, interval execution, migration,
step-1 stats collection — lives behind the :class:`StateBackend` protocol
defined here. Backends are *registered*, not if/elif'd: the engine resolves
``state_backend="..."`` through :func:`get_backend` /
:func:`resolve_backend`, so a new backend is a subclass plus a
:func:`register_backend` call (see :mod:`repro.streams.sharded` for the
out-of-module example).

The protocol (one instance per stage)::

    new_store()                         -> per-task store object
    process_interval(keys, values, collect_emits)
                                        -> IntervalReport [,emits]
    migrate(keys, old, new)             -> bytes moved (protocol steps 5-6)
    extract_batch(task, keys) / install_batch(task, pack)
                                        -> the ColumnarPack/ObjectPack
                                           contract used by scale_to
    collect_stats(...)                  -> KeyStats (paper step 1)

plus two classmethod selection hooks: :meth:`StateBackend.check` (raise
``ValueError`` when an explicit request is unsupported) and
:meth:`StateBackend.auto_eligible` (may ``state_backend="auto"`` pick this
backend?). Auto resolution order is device > columnar > object — see
``docs/architecture.md`` ("State backends") for the full selection matrix;
the sharded backend is explicit-only.

Four backends implement the protocol:

* :class:`ObjectBackend` — dict-of-KeyState stores, per-task segment
  dispatch. The compatibility backend (custom per-tuple operators) and the
  parity oracle.
* :class:`ColumnarBackend` — flat per-task arrays, ONE whole-interval
  operator dispatch (``Operator.process_interval_batch``).
* :class:`DeviceBackend` — the dense device-resident ring of
  :mod:`repro.streams.device`: one fused jitted step per interval,
  relabel-only migration.
* ``ShardedDeviceBackend`` (:mod:`repro.streams.sharded`, lazy-loaded) —
  the device ring sharded across a JAX mesh via ``shard_map``.

Importing this module never imports jax: the device/sharded backends load
their jax-facing modules lazily at construction, so ModHash/object-backend
users stay jax-free (same policy as ``repro.streams.__init__``).
"""

from __future__ import annotations

import importlib
from typing import Any, Dict, List, Optional, Sequence, Tuple, Type

import numpy as np

from repro.core.balancer import Assignment, KeyStats, metrics

from .state import ColumnarStateStore, TaskStateStore

#: name -> backend class. Mutated only through :func:`register_backend`.
BACKENDS: Dict[str, Type["StateBackend"]] = {}


class _SketchPending:
    """Sentinel: step-1 aggregates were streamed into the controller's
    sketch (``RebalanceController.ingest``) instead of materialized as a
    K-sized :class:`KeyStats`; ``KeyedStage._finish_interval`` closes the
    round with ``controller.on_interval(None)``."""

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        return "<SKETCH_PENDING>"


SKETCH_PENDING = _SketchPending()

#: backends that live in modules with heavyweight imports (jax at module
#: scope) — loaded on first request instead of at import time.
_LAZY_BACKENDS = {"sharded": "repro.streams.sharded"}


def register_backend(cls: Type["StateBackend"]) -> Type["StateBackend"]:
    """Register a backend class under ``cls.name`` (usable as a decorator)."""
    if not getattr(cls, "name", None):
        raise ValueError(f"{cls.__name__} needs a non-empty 'name'")
    BACKENDS[cls.name] = cls
    return cls


def backend_names() -> Tuple[str, ...]:
    """Every selectable ``state_backend`` value (registered + lazy)."""
    return tuple(sorted(set(BACKENDS) | set(_LAZY_BACKENDS) | {"auto"}))


def get_backend(name: str) -> Type["StateBackend"]:
    if name not in BACKENDS and name in _LAZY_BACKENDS:
        importlib.import_module(_LAZY_BACKENDS[name])   # registers itself
    if name not in BACKENDS:
        raise ValueError(f"unknown state backend {name!r}; "
                         f"choose from {backend_names()}")
    return BACKENDS[name]


def resolve_backend(name: str, operator, controller,
                    vectorized: bool) -> Type["StateBackend"]:
    """Map a ``state_backend=`` request to a backend class.

    Explicit names validate via the class's :meth:`StateBackend.check`
    (raising ``ValueError`` with the reason); ``"auto"`` walks the
    eligibility order device > columnar > object, which preserves the
    historical selection rules exactly (device only on an accelerator jax
    backend; columnar whenever the operator is columnar-capable and the
    stage vectorized; object otherwise)."""
    if name != "auto":
        cls = get_backend(name)
        cls.check(operator, controller, vectorized)
        return cls
    for cand in ("device", "columnar"):
        cls = get_backend(cand)
        if cls.auto_eligible(operator, controller, vectorized):
            return cls
    return BACKENDS["object"]


def _is_hash32(controller) -> bool:
    from repro.core.balancer.hashing import Hash32
    return isinstance(controller.assignment.hash_router, Hash32)


class StateBackend:
    """Base protocol + the shared pack-based migration executor.

    A backend instance belongs to exactly one stage and reaches the
    router/controller surface through ``self.stage`` (routing via
    ``stage._dest_batch``, report assembly via ``stage._finish_interval``,
    the pause-window bookkeeping via ``stage._pending_delta_arr``)."""

    name: str = ""

    def __init__(self, stage):
        self.stage = stage

    # -- selection hooks -------------------------------------------------------
    @classmethod
    def check(cls, operator, controller, vectorized: bool) -> None:
        """Raise ``ValueError`` when an explicit request is unsupported."""

    @classmethod
    def auto_eligible(cls, operator, controller, vectorized: bool) -> bool:
        """May ``state_backend='auto'`` select this backend?"""
        return False

    # -- store fleet -----------------------------------------------------------
    def new_store(self):
        raise NotImplementedError

    # -- one interval of traffic ----------------------------------------------
    def process_interval(self, keys: np.ndarray,
                         values: Optional[Sequence[Any]],
                         collect_emits: bool = False):
        raise NotImplementedError

    # -- migration (protocol steps 5-6); returns bytes moved -------------------
    def migrate(self, keys: np.ndarray, old: Assignment,
                new: Assignment) -> float:
        """Array-at-a-time and store-agnostic: one dest() call per
        assignment, group-by-source extraction into packs, mask-split per
        destination, group installs. On the columnar backend a pack is a row
        slice of flat arrays; on the object backend it is the keys plus
        their KeyState objects — either way no per-key dict is built here."""
        stage = self.stage
        src = old.dest(keys)
        dst = new.dest(keys)
        moving = src != dst
        mkeys, msrc = keys[moving], src[moving]
        total = 0.0
        installs = []
        for s in np.unique(msrc):
            pack = self.extract_batch(int(s), mkeys[msrc == s])
            if not pack.keys.size:
                continue
            total += pack.nbytes
            pdst = new.dest(pack.keys)
            for d in np.unique(pdst):
                installs.append((int(d), pack.take(pdst == d)))
        for d, pack in installs:
            self.install_batch(d, pack)
        return total

    # -- pack contract (scale_to's reconciliation sweep, tests) ----------------
    def extract_batch(self, task: int, keys: np.ndarray):
        return self.stage.stores[task].extract_batch(keys)

    def install_batch(self, task: int, pack) -> None:
        self.stage.stores[task].install_batch(pack)

    # -- checkpoint/restore (repro.streams.checkpoint) -------------------------
    def checkpoint(self) -> dict:
        """Snapshot every task's state as cloned packs, riding the existing
        extract/install contract: extract all held keys, clone the pack,
        install it straight back. Observationally transparent — extraction
        preserves key order on every store type, and the closed forms are
        order-free sums — so a checkpointed run stays bit-identical to an
        uncheckpointed one (asserted by ``tests/test_chaos_recovery.py``).

        Returns ``{"packs": [pack_per_task, ...], **backend_extras}``.
        """
        stage = self.stage
        packs = []
        for task, store in enumerate(stage.stores):
            held, _ = store.sizes_arrays()
            pack = self.extract_batch(task, held)
            snapshot = pack.clone()
            self.install_batch(task, pack)
            packs.append(snapshot)
        return {"packs": packs}

    def restore(self, ckpt) -> None:
        """Rebuild the store fleet from a :class:`StageCheckpoint`'s packs.

        Fresh stores accept any interval clock (a new columnar store's
        monotonic guard is unset), so restoring an older checkpoint after
        the live fleet advanced is always legal; the stage-level counters
        are rewound by ``restore_stage``.
        """
        stage = self.stage
        stage.stores = []
        for _ in ckpt.packs:
            stage.stores.append(self.new_store())
        for store, pack in zip(stage.stores, ckpt.packs):
            store.install_batch(pack.clone())

    # -- paper step 1 ----------------------------------------------------------
    def collect_stats(self, acc_keys, acc_cost, acc_freq,
                      held) -> Optional[KeyStats]:
        raise NotImplementedError


class HostStoreBackend(StateBackend):
    """Shared vectorized interval loop for the host-store backends.

    Owns the macro-batch pause split (protocol steps 4/7): micro-batch
    boundaries are only *observable* through the pause window — the first
    ``migration_batches`` of ``micro_batches`` slices buffer Delta-keys
    while migration is in flight. Outside that window the batched operators
    are batch-boundary-invariant (their per-key closed forms telescope —
    see operators.py), so the interval coalesces into at most two
    macro-dispatches:

      A. the pause window, with Delta-keys masked out and buffered;
      B. Resume — buffered tuples replayed (CURRENT assignment, which
         equals ``dests`` since F only changes at interval boundaries)
         followed by the rest of the stream.

    Subclasses provide :meth:`dispatch_batch` — how one macro-batch reaches
    the operator and the store fleet."""

    def process_interval(self, keys: np.ndarray,
                         values: Optional[Sequence[Any]],
                         collect_emits: bool = False):
        stage = self.stage
        iv = stage.begin_interval()
        n = int(keys.shape[0])
        task_cost = np.zeros(stage.n_tasks)
        acc_keys: List[np.ndarray] = []
        acc_cost: List[np.ndarray] = []
        acc_freq: List[np.ndarray] = []
        emit_acc: Optional[List[Tuple[np.ndarray, np.ndarray, np.ndarray]]] \
            = [] if collect_emits else None
        buffered_count = 0

        dests = stage._dest_batch(keys) if n else np.zeros(0, np.int64)

        pause_hi = stage.pause_window(n)
        if pause_hi is not None:
            head = np.arange(pause_hi)
            paused = np.isin(keys[:pause_hi], stage._pending_delta_arr)
            buffered_count = int(paused.sum())
            kept = head[~paused]
            if kept.size:
                self.dispatch_batch(iv, keys[kept], dests[kept], kept, values,
                                    task_cost, acc_keys, acc_cost, acc_freq,
                                    emit_acc)
            resume = np.concatenate([head[paused], np.arange(pause_hi, n)])
            if resume.size:
                self.dispatch_batch(iv, keys[resume], dests[resume], resume,
                                    values, task_cost, acc_keys, acc_cost,
                                    acc_freq, emit_acc)
        elif n:
            idx = np.arange(n)
            self.dispatch_batch(iv, keys, dests, idx, values, task_cost,
                                acc_keys, acc_cost, acc_freq, emit_acc)
        stage.clear_pause()
        # fault seam: state is mutated, stores not yet advanced past the
        # boundary, no report — a genuinely dirty mid-interval crash point
        stage._failpoint("mid")

        held = [store.end_interval_collect(iv) for store in stage.stores]

        stats = self.collect_stats(acc_keys, acc_cost, acc_freq, held)
        report = stage._finish_interval(iv, n, task_cost, buffered_count,
                                        stats)
        if not collect_emits:
            return report
        ekeys, evals = _assemble_emits(emit_acc)
        return report, ekeys, evals

    def dispatch_batch(self, iv: int, bkeys: np.ndarray, bdests: np.ndarray,
                       abs_idx: np.ndarray, values: Optional[Sequence[Any]],
                       task_cost, acc_keys, acc_cost, acc_freq,
                       emit_acc=None) -> None:
        raise NotImplementedError

    # -- stats collection (paper Fig. 5 step 1), segment-sum form --------------
    def collect_stats(self, acc_keys, acc_cost, acc_freq,
                      held) -> Optional[KeyStats]:
        # The stat universe is (keys seen this interval) UNION (keys still
        # holding window state): omitting quiet stateful keys would let a
        # table cleanup strand their state on the old task.
        stage = self.stage
        seen = (np.concatenate(acc_keys) if acc_keys
                else np.zeros(0, np.int64))
        cost_parts = (np.concatenate(acc_cost) if acc_cost
                      else np.zeros(0, np.float64))
        freq_parts = (np.concatenate(acc_freq) if acc_freq
                      else np.zeros(0, np.float64))
        held_keys = np.concatenate([h[0] for h in held]) if held else \
            np.zeros(0, np.int64)
        held_sizes = np.concatenate([h[1] for h in held]) if held else \
            np.zeros(0, np.float64)
        if stage.controller.stats_mode == "sketch":
            # stream the per-(task,key) aggregates into the controller's
            # sketch instead of building the O(K) union universe. Two folds
            # per interval: traffic (cost+freq; duplicates across tasks /
            # macro-batches aggregate inside the sketch) and held state
            # sizes (zero cost: quiet keys must not displace heavy
            # hitters, but tracked keys pick up their exact S(k, w)).
            # The seen∪held invariant holds because the snapshot always
            # re-includes every current table key.
            ctrl = stage.controller
            if seen.size:
                ctrl.ingest(seen, cost_parts, freq=freq_parts)
            if held_keys.size:
                ctrl.ingest(held_keys, np.zeros(held_keys.size),
                            mem=held_sizes)
            return SKETCH_PENDING if (seen.size or held_keys.size) else None
        universe = np.union1d(seen, held_keys)
        if not universe.size:
            return None
        if (stage.substrate == "pallas" and seen.size
                and int(universe.max()) < stage.stats_dense_max
                and int(universe.min()) >= 0):
            return self._collect_stats_pallas(seen, cost_parts, freq_parts,
                                              held_keys, held_sizes)
        pos = np.searchsorted(universe, seen)
        cost = metrics.segment_sum(cost_parts, pos, universe.size)
        freq = metrics.segment_sum(freq_parts, pos, universe.size)
        mem = metrics.segment_sum(held_sizes,
                                  np.searchsorted(universe, held_keys),
                                  universe.size)
        return KeyStats(keys=universe, cost=cost, mem=mem, freq=freq)

    def _collect_stats_pallas(self, seen, cost_parts, freq_parts, held_keys,
                              held_sizes) -> KeyStats:
        """Step-1 stats via the fused histogram kernel over a dense domain.

        The kernel is a weighted segment-sum (one-hot matmul on the MXU), so
        two passes — weights = per-key cost, weights = per-key freq — yield
        c(k) and g(k). Accumulation is float32 on-device; reports therefore
        match the numpy path to ~1e-6 relative, not bit-for-bit."""
        stage = self.stage
        jnp = stage._jnp
        num = int(max(seen.max(initial=0), held_keys.max(initial=0))) + 1
        seen_dev = jnp.asarray(seen.astype(np.int32))
        _, cost_d = stage._kernel_stats(seen_dev, jnp.asarray(cost_parts),
                                        num, interpret=stage._kernel_interpret)
        _, freq_d = stage._kernel_stats(seen_dev, jnp.asarray(freq_parts),
                                        num, interpret=stage._kernel_interpret)
        cost = np.asarray(cost_d, dtype=np.float64)
        freq = np.asarray(freq_d, dtype=np.float64)
        mem = metrics.segment_sum(held_sizes, held_keys, num)
        # universe = seen ∪ held — held membership, not mem > 0: a quiet key
        # whose window fully evicted still occupies the store and must stay
        # visible to the balancer (same invariant as the numpy paths)
        live = freq > 0
        live[held_keys] = True
        universe = np.nonzero(live)[0].astype(np.int64)
        return KeyStats(keys=universe, cost=cost[live], mem=mem[live],
                        freq=freq[live])


def _assemble_emits(emit_acc) -> Tuple[np.ndarray, np.ndarray]:
    """Order accumulated (positions, keys, values) chunks into the
    canonical source-position emit stream. Positions are unique per
    source tuple across chunks, and one tuple's emits are contiguous
    within a chunk, so a stable argsort reproduces stream order."""
    if not emit_acc:
        return np.zeros(0, np.int64), np.zeros(0, np.float64)
    pos = np.concatenate([p for p, _, _ in emit_acc])
    ekeys = np.concatenate([k for _, k, _ in emit_acc])
    evals = np.concatenate([v for _, _, v in emit_acc])
    order = np.argsort(pos, kind="stable")
    return ekeys[order], evals[order]


@register_backend
class ObjectBackend(HostStoreBackend):
    """Dict-of-KeyState stores, per-task segment dispatch.

    Fully general (payloads are arbitrary Python objects): the only backend
    custom per-tuple operators can use, the store of the per-tuple reference
    path, and the parity oracle for every other backend."""

    name = "object"

    def new_store(self):
        return TaskStateStore(self.stage.window)

    def dispatch_batch(self, iv, bkeys, bdests, abs_idx, values, task_cost,
                       acc_keys, acc_cost, acc_freq, emit_acc=None):
        """Partition per task via argsort + segment boundaries and call the
        operator's batched kernel per segment."""
        stage = self.stage
        order = np.argsort(bdests, kind="stable")
        sorted_dests = bdests[order]
        bounds = np.searchsorted(sorted_dests, np.arange(stage.n_tasks + 1))
        needs_values = stage.operator.needs_values
        values_arr = values if isinstance(values, np.ndarray) else None
        for d in range(stage.n_tasks):
            s0, s1 = bounds[d], bounds[d + 1]
            if s0 == s1:
                continue
            seg = order[s0:s1]
            kseg = bkeys[seg]
            vseg: Optional[Sequence[Any]] = None
            if needs_values:
                if values is None:
                    # match the reference path: absent payloads flow as None
                    vseg = [None] * len(seg)
                elif values_arr is not None:
                    vseg = values_arr[abs_idx[seg]]
                else:
                    vseg = [values[i] for i in abs_idx[seg]]
            if emit_acc is None:
                res = stage.operator.process_batch(stage.stores[d], iv, kseg,
                                                   vseg)
            else:
                res, ecounts, ekeys, evals = \
                    stage.operator.process_batch_emits(stage.stores[d], iv,
                                                       kseg, vseg)
                if ekeys.size:
                    emit_acc.append((np.repeat(abs_idx[seg], ecounts),
                                     ekeys, evals))
            task_cost[d] += res.task_cost
            acc_keys.append(res.uniq_keys)
            acc_cost.append(res.key_cost)
            acc_freq.append(res.key_freq)
            for ok, ov in res.outputs:
                stage.outputs[ok] = ov
            stage.emitted_sum += res.emit_sum


@register_backend
class ColumnarBackend(HostStoreBackend):
    """Flat per-task arrays + ONE whole-interval operator dispatch."""

    name = "columnar"

    @classmethod
    def check(cls, operator, controller, vectorized):
        if getattr(operator, "columnar_spec", None) is None:
            raise ValueError(
                f"state_backend='columnar' requires an operator with a "
                f"columnar_spec; {type(operator).__name__} has none "
                "(custom per-tuple operators need the object store)")
        if not vectorized:
            raise ValueError("state_backend='columnar' requires "
                             "vectorized=True (the per-tuple reference "
                             "path uses scalar state access)")

    @classmethod
    def auto_eligible(cls, operator, controller, vectorized):
        return vectorized and getattr(operator, "columnar_spec", None) \
            is not None

    def new_store(self):
        return ColumnarStateStore(self.stage.window,
                                  self.stage.operator.columnar_spec)

    def dispatch_batch(self, iv, bkeys, bdests, abs_idx, values, task_cost,
                       acc_keys, acc_cost, acc_freq, emit_acc=None):
        """ONE whole-interval dispatch — the operator lexsorts on
        (dest, key) once, computes every segment's closed forms in a single
        pass, and scatters per-task costs with one ``np.bincount``."""
        stage = self.stage
        op = stage.operator
        if not op.columnar_needs_values or values is None:
            vals_b = None
        elif isinstance(values, np.ndarray):
            vals_b = values[abs_idx]
        else:
            vals_b = [values[i] for i in abs_idx.tolist()]
        res, emits = op.process_interval_batch(
            stage.stores, iv, bkeys, bdests, stage.n_tasks, vals_b,
            collect_emits=emit_acc is not None)
        task_cost += res.task_cost
        acc_keys.append(res.uniq_keys)
        acc_cost.append(res.key_cost)
        acc_freq.append(res.key_freq)
        for ok, ov in res.outputs:
            stage.outputs[ok] = ov
        stage.emitted_sum += res.emit_sum
        if emit_acc is not None:
            ecounts, ekeys, evals = emits
            if ekeys.size:
                emit_acc.append((np.repeat(abs_idx, ecounts), ekeys, evals))


@register_backend
class DeviceBackend(StateBackend):
    """Device-resident dense ring, one fused jitted step per interval.

    All state lives in a :class:`~repro.streams.device.DeviceStateFleet`
    (per-task stores are :class:`~repro.streams.device.DeviceTaskView`
    windows onto it); migration relabels the host task mirror only. See
    :mod:`repro.streams.device` for the layout rationale."""

    name = "device"

    def __init__(self, stage):
        super().__init__(stage)
        self._device_seed = stage.controller.assignment.hash_router.seed
        self._fleet = self._make_fleet()
        self._dest_dense_cache = None   # (cache key, device dests, host dests)
        self._views_made = 0

    def _make_fleet(self):
        from .device import DeviceStateFleet
        stage = self.stage
        return DeviceStateFleet(stage.window, stage.operator.columnar_spec)

    @classmethod
    def check(cls, operator, controller, vectorized):
        if not vectorized:
            raise ValueError(f"state_backend={cls.name!r} requires "
                             "vectorized=True (the per-tuple reference path "
                             "uses scalar state access)")
        if controller.strategy.is_router:
            raise ValueError(
                f"state_backend={cls.name!r} requires an assignment-driven "
                f"strategy: algorithm {controller.algorithm_name!r} routes "
                "per tuple on live loads, but the device table cache is "
                "keyed on assignment_version (destinations must be a pure "
                "function of the key between rebalances)")
        if getattr(operator, "device_mode", None) is None \
                or getattr(operator, "columnar_spec", None) is None:
            raise ValueError(
                f"state_backend={cls.name!r} requires an operator with "
                f"device closed forms (device_mode + columnar_spec); "
                f"{type(operator).__name__} has none — such operators fall "
                "back to the columnar/object store under 'auto'")
        if not _is_hash32(controller):
            router = controller.assignment.hash_router
            raise ValueError(
                f"state_backend={cls.name!r} requires a Hash32 router "
                f"(device-canonical fmix32); got {type(router).__name__}. "
                "ModHash's splitmix64 has no 32-bit device equivalent.")

    @classmethod
    def auto_eligible(cls, operator, controller, vectorized):
        # every device requirement must already hold AND jax must run on an
        # accelerator — checked lazily so ModHash/object stages never
        # import jax
        if not (vectorized
                and not controller.strategy.is_router
                and getattr(operator, "columnar_spec", None) is not None
                and getattr(operator, "device_mode", None) is not None
                and _is_hash32(controller)):
            return False
        import jax                       # lazy
        return jax.default_backend() != "cpu"

    def new_store(self):
        from .device import DeviceTaskView
        # a view's index IS the task id: during initial fleet construction
        # count views; afterwards (scale_to appends) follow the live store
        # list so shrink-then-grow reuses the freed task ids
        stage = self.stage
        idx = (len(stage.stores) if hasattr(stage, "stores")
               else self._views_made)
        self._views_made += 1
        return DeviceTaskView(self._fleet, idx)

    # -- migration: zero device work -------------------------------------------
    def migrate(self, keys: np.ndarray, old: Assignment,
                new: Assignment) -> float:
        """State is key-indexed on the device, so moving a key between tasks
        only relabels host ownership; migrated bytes come from the ``mem``
        mirror's closed-form S(k, w) — the exact per-pack sums the pack
        executor reports, because every quantity is an integer-valued
        float64 (order-free exact summation)."""
        src = old.dest(keys)
        dst = new.dest(keys)
        moving = src != dst
        mkeys = keys[moving]
        fleet = self._fleet
        total = 0.0
        if mkeys.size and fleet.domain:
            ok = (mkeys >= 0) & (mkeys < fleet.domain)
            mk = mkeys[ok]
            held = fleet.task[mk] >= 0
            hk = mk[held]
            total = float(fleet.mem[hk].sum())
            fleet.task[hk] = dst[moving][ok][held].astype(np.int32)
        return total

    # -- checkpoint/restore ----------------------------------------------------
    def checkpoint(self) -> dict:
        """Base pack round-trip plus the fleet's ring-column clock: a task
        whose pack is empty carries no ``col_iv``, but the shared fleet's
        clock must still survive (install_batch only adopts columns from
        non-empty packs)."""
        snap = super().checkpoint()
        snap["col_iv"] = self._fleet.col_iv.copy()
        return snap

    def restore(self, ckpt) -> None:
        """Rebuild the fleet from scratch and reinstall the packs.

        ``_make_fleet`` is the same seam the constructor (and the sharded
        subclass) uses, so restore works identically on the mesh-sharded
        fleet. The dense-dest cache is dropped: the restored controller's
        ``assignment_version`` rewinds, so a stale cache entry could alias
        a different table under the same version number.
        """
        stage = self.stage
        self._fleet = self._make_fleet()
        self._dest_dense_cache = None
        self._views_made = 0
        stage.stores = []
        for _ in ckpt.packs:
            stage.stores.append(self.new_store())
        maxk = max((int(p.keys.max()) for p in ckpt.packs if p.keys.size),
                   default=-1)
        if maxk >= 0:
            self._fleet.ensure_domain(maxk + 1)
        self._fleet.col_iv = np.asarray(ckpt.backend_extra["col_iv"],
                                        dtype=np.int64).copy()
        for store, pack in zip(stage.stores, ckpt.packs):
            store.install_batch(pack.clone())

    # -- dense routing table ---------------------------------------------------
    def _dest_dense_arrays(self):
        """Dense F(k) table over every key id, refreshed once per
        ``assignment_version`` (and per domain growth) — the device twin of
        the pallas substrate's routing-table cache, sharing its power-of-two
        high-water table capacity so table churn never retraces."""
        stage = self.stage
        assignment = stage.controller.assignment
        needed = max(128, 1 << max(0, assignment.table_size - 1).bit_length())
        if needed > stage._table_capacity:
            stage._table_capacity = needed
        cache_key = (stage.controller.assignment_version,
                     assignment.table_size, stage._table_capacity,
                     self._fleet.domain, stage.n_tasks)
        if self._dest_dense_cache is None \
                or self._dest_dense_cache[0] != cache_key:
            tk, td = assignment.table_arrays(stage._table_capacity)
            dev = self._fleet.route_dense(
                tk, td, assignment.n_dest, seed=self._device_seed,
                use_kernel=(stage.substrate == "pallas"),
                interpret=stage._kernel_interpret)
            self._dest_dense_cache = (cache_key, dev,
                                      self._fleet.dest_host_dense(dev))
        return self._dest_dense_cache[1], self._dest_dense_cache[2]

    # -- one interval as ONE fused device step ---------------------------------
    def process_interval(self, keys: np.ndarray,
                         values: Optional[Sequence[Any]] = None,
                         collect_emits: bool = False):
        """The pause-window macro-batch split of the host path telescopes
        for device operators (their closed forms are batch-boundary
        invariant), so only the ``buffered`` count needs the host split; the
        step itself sees the whole interval."""
        stage = self.stage
        iv = stage.begin_interval()
        n = int(keys.shape[0])
        fleet = self._fleet
        op = stage.operator
        spec = op.columnar_spec

        buffered_count = 0
        pause_hi = stage.pause_window(n)
        if pause_hi is not None:
            buffered_count = int(np.isin(keys[:pause_hi],
                                         stage._pending_delta_arr).sum())
        stage.clear_pause()

        # ring-column bookkeeping (host mirror of the columnar _col_iv)
        w1 = stage.window + 1
        c = iv % w1
        col_iv = fleet.col_iv
        if n:
            if col_iv[c] not in (-1, iv):
                raise RuntimeError(
                    f"device ring column clock skew: column {c} still holds "
                    f"interval {int(col_iv[c])} at interval {iv}")
            col_iv[c] = iv
        cutoff = iv - stage.window + 1
        expire = (col_iv >= 0) & (col_iv < cutoff)
        keep = (~expire).astype(np.int32)
        col_iv[expire] = -1

        task_cost = np.zeros(stage.n_tasks)
        stats: Optional[KeyStats] = None
        win0_h = slot0_h = None

        if n:
            kmin, kmax = int(keys.min()), int(keys.max())
            if kmin < 0:
                raise ValueError(
                    f"state_backend={self.name!r} requires non-negative key "
                    f"ids; got {kmin}")
            if kmax >= stage.device_domain_max:
                raise ValueError(
                    f"key id {kmax} exceeds device_domain_max="
                    f"{stage.device_domain_max}: the dense device backend "
                    "allocates state per key id — raise device_domain_max or "
                    "use the columnar backend for sparse huge domains")
            fleet.ensure_domain(kmax + 1)
            dest_dev, dest_host = self._dest_dense_arrays()
            cur = np.zeros(w1, dtype=np.int32)
            cur[c] = 1
            tv = None
            if op.device_mode == "max":
                tv64 = np.asarray(values).astype(np.int64)
                if tv64.size and not (
                        int(tv64.min()) > np.iinfo(np.int32).min
                        and int(tv64.max()) <= np.iinfo(np.int32).max):
                    raise ValueError(
                        f"state_backend={self.name!r} folds values in "
                        "int32; tuple value out of int32 range")
                tv = tv64
            step = fleet.interval_step(keys, tv, dest_dev, stage.n_tasks,
                                       keep, cur, op.device_mode)
            dom = fleet.domain
            counts_h = np.asarray(step[0])[:dom]
            win0_h = np.asarray(step[1])[:dom]
            slot0_h = np.asarray(step[2])[:dom]
            held_cnt = np.asarray(step[3])[:dom]
            held_sum = np.asarray(step[4])[:dom]

            seen_mask = counts_h > 0
            gk = np.nonzero(seen_mask)[0].astype(np.int64)
            key_cost_g, out_vals, emit_sum = op.device_finish(
                counts_h[seen_mask].astype(np.int64),
                win0_h[seen_mask].astype(np.int64),
                slot0_h[seen_mask].astype(np.int64))
            if out_vals is not None:
                stage.outputs.update(zip(gk.tolist(), out_vals.tolist()))
            stage.emitted_sum += emit_sum
            if op.device_unit_cost:
                if step[5] is not None:           # max mode: device bincount
                    task_cost = np.asarray(step[5]).astype(np.float64)
                else:                             # add mode: counts are host
                    task_cost = np.bincount(dest_host[:dom],
                                            weights=counts_h,
                                            minlength=stage.n_tasks)
            else:
                task_cost = np.bincount(dest_host[gk], weights=key_cost_g,
                                        minlength=stage.n_tasks)

            # host mirrors: ownership labels (new keys adopt F(k); evicted
            # keys clear) and the closed-form S(k, w) per key
            alive = held_cnt > 0
            t = fleet.task
            t[:dom] = np.where(alive,
                               np.where(t[:dom] >= 0, t[:dom],
                                        dest_host[:dom].astype(np.int32)),
                               -1)
            fleet.mem[:dom] = (spec.slot_bytes * held_cnt
                               + spec.bytes_per_unit * held_sum)
            fleet.mem[:dom][~alive] = 0.0

            # stat universe = seen ∪ held == alive: a seen key's current slot
            # never expires at its own boundary, so seen ⊆ held-after
            uni = np.nonzero(alive)[0].astype(np.int64)
            if uni.size:
                cost = np.zeros(uni.size, dtype=np.float64)
                cost[np.searchsorted(uni, gk)] = key_cost_g
                if stage.controller.stats_mode == "sketch":
                    # one fold with every channel: the fused step already
                    # aggregated per key, so this is the same multiset the
                    # host backends stream in (see HostStoreBackend)
                    stage.controller.ingest(
                        uni, cost, mem=fleet.mem[uni],
                        freq=counts_h[alive].astype(np.float64))
                    stats = SKETCH_PENDING
                else:
                    stats = KeyStats(keys=uni,
                                     cost=cost,
                                     mem=fleet.mem[uni].copy(),
                                     freq=counts_h[alive].astype(np.float64))
        else:
            if fleet.domain and expire.any():
                held_cnt, held_sum = fleet.evict(keep)
                dom = fleet.domain
                alive = held_cnt[:dom] > 0
                fleet.task[:dom] = np.where(alive, fleet.task[:dom], -1)
                fleet.mem[:dom] = (spec.slot_bytes * held_cnt[:dom]
                                   + spec.bytes_per_unit * held_sum[:dom])
                fleet.mem[:dom][~alive] = 0.0
            stats = self.collect_stats(None, None, None, None)

        # fault seam: device state and host mirrors are mutated (and in
        # sketch mode the controller's sketch already ingested), no report
        stage._failpoint("mid")
        report = stage._finish_interval(iv, n, task_cost, buffered_count,
                                        stats)
        if not collect_emits:
            return report
        if n == 0:
            return report, np.zeros(0, np.int64), np.zeros(0, np.float64)
        _, inv, ucounts = np.unique(keys, return_inverse=True,
                                    return_counts=True)
        from .operators import _occurrence_index
        occ = _occurrence_index(inv, ucounts)
        evals = op.device_emit_values(keys, occ, win0_h, slot0_h)
        if evals is None:
            return report, np.zeros(0, np.int64), np.zeros(0, np.float64)
        return report, keys.astype(np.int64, copy=False), evals

    def collect_stats(self, acc_keys, acc_cost, acc_freq,
                      held) -> Optional[KeyStats]:
        """Quiet-interval stats straight off the host mirrors (the traffic
        path builds its stats inline from the fused step's outputs)."""
        fleet = self._fleet
        if not fleet.domain:
            return None
        uni = np.nonzero(fleet.task[:fleet.domain] >= 0)[0].astype(np.int64)
        if not uni.size:
            return None
        if self.stage.controller.stats_mode == "sketch":
            self.stage.controller.ingest(uni, np.zeros(uni.size),
                                         mem=fleet.mem[uni])
            return SKETCH_PENDING
        return KeyStats(keys=uni, cost=np.zeros(uni.size),
                        mem=fleet.mem[uni].copy(), freq=np.zeros(uni.size))
