"""Keyed, windowed state store for stateful operators (paper Sec. II-A).

Each key holds one state object per time interval; the store evicts state
older than ``window`` intervals after the interval closes (the paper's model:
"the task instance erases the state from T_{i-w} after finishing T_i").
``S(k, w)`` — the migration-cost weight — is the summed size over the window.

Two backends implement the same store contract:

* :class:`TaskStateStore` — the original object store: one :class:`KeyState`
  per key holding an ``OrderedDict`` of per-interval :class:`WindowSlice`
  objects. Fully general (payloads are arbitrary Python objects); this is
  the reference-path store and the compatibility backend for custom
  operators.
* :class:`ColumnarStateStore` — flat arrays for numeric windowed operators:
  a sorted key column plus a ring of ``window + 1`` per-interval value/size
  columns. ``update_slots`` / ``end_interval_collect`` / migration are pure
  numpy — no per-key Python anywhere — so interval boundaries and
  migrations cost O(columns) vectorized work instead of O(keys) dict
  traffic. Eviction is a column clear; migration is row slicing.

Batched API
-----------
The vectorized engine (see :mod:`repro.streams.engine`) never touches state
one key at a time on the hot path.  Instead it uses the array-at-a-time
methods shared by both backends:

* :meth:`TaskStateStore.update_many` (object) /
  :meth:`ColumnarStateStore.update_slots` (columnar) — fetch-or-create the
  current interval's slot for a whole batch of unique keys in one call;
* :meth:`TaskStateStore.extract_batch` / :meth:`TaskStateStore.install_batch`
  — migration primitives over key arrays (paper protocol steps 5-6); both
  backends exchange opaque *packs* (:class:`ObjectPack` /
  :class:`ColumnarPack`) that support destination splitting via
  :meth:`~ColumnarPack.take`, so the engine's migration executor never
  builds per-key dicts;
* :meth:`TaskStateStore.sizes_arrays` — ``(keys, S(k,w))`` as numpy arrays
  for vectorized stats collection (paper step 1).

The scalar methods (:meth:`state`, :meth:`extract`, :meth:`install`) remain
for the reference per-tuple path and for custom operators.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from collections.abc import Mapping
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class WindowSlice:
    interval: int
    payload: Any
    size: float        # bytes (or abstract units) — feeds S(k, w)


class KeyState:
    """Ring of per-interval slices for one key."""

    def __init__(self, window: int):
        self.window = window
        self.slices: "OrderedDict[int, WindowSlice]" = OrderedDict()

    def slice_for(self, interval: int, init: Callable[[], Any],
                  size: float = 0.0) -> WindowSlice:
        sl = self.slices.get(interval)
        if sl is None:
            sl = WindowSlice(interval, init(), size)
            self.slices[interval] = sl
        return sl

    def evict_before(self, interval: int) -> None:
        cutoff = interval - self.window + 1
        slices = self.slices
        # slices are appended in interval order, so stale ones are a prefix
        while slices and next(iter(slices)) < cutoff:
            slices.popitem(last=False)

    def total_size(self) -> float:
        return float(sum(sl.size for sl in self.slices.values()))

    def iter_window(self) -> Iterator[WindowSlice]:
        return iter(self.slices.values())


class TaskStateStore:
    """All keyed state held by one task instance."""

    def __init__(self, window: int):
        self.window = window
        self.keys: Dict[int, KeyState] = {}

    def state(self, key: int) -> KeyState:
        ks = self.keys.get(key)
        if ks is None:
            ks = KeyState(self.window)
            self.keys[key] = ks
        return ks

    def end_interval(self, interval: int) -> None:
        """Evict expired slices; drop keys whose window fully emptied.

        Keys must not linger once every slice expired: an empty
        :class:`KeyState` contributes nothing to S(k,w) but would stay in
        ``self.keys`` forever, growing the step-1 stat universe (and thus
        planner input) monotonically on long runs.
        """
        dead = []
        for k, ks in self.keys.items():
            ks.evict_before(interval)
            if not ks.slices:
                dead.append(k)
        for k in dead:
            del self.keys[k]

    def end_interval_collect(self, interval: int
                             ) -> Tuple[np.ndarray, np.ndarray]:
        """Evict expired slices AND return ``(keys, S(k,w))`` in one pass.

        Fuses :meth:`end_interval` with :meth:`sizes_arrays` so the
        vectorized engine touches each key once per interval boundary instead
        of twice; produces exactly the values the two separate calls would —
        including dropping (and not reporting) keys left with no slices.
        """
        keys_out = []
        sizes_out = []
        dead = []
        for k, ks in self.keys.items():
            ks.evict_before(interval)
            slices = ks.slices
            if not slices:
                dead.append(k)
                continue
            total = 0.0
            for sl in slices.values():
                total += sl.size
            keys_out.append(k)
            sizes_out.append(total)
        for k in dead:
            del self.keys[k]
        return (np.asarray(keys_out, dtype=np.int64),
                np.asarray(sizes_out, dtype=np.float64))

    def sizes(self) -> Dict[int, float]:
        return {k: ks.total_size() for k, ks in self.keys.items()}

    def sizes_arrays(self) -> Tuple[np.ndarray, np.ndarray]:
        """All held keys and their windowed sizes ``S(k, w)`` as arrays.

        Feeds the vectorized stats collection (paper Fig. 5 step 1) without
        building an intermediate dict per interval.
        """
        n = len(self.keys)
        ks = np.fromiter(self.keys.keys(), dtype=np.int64, count=n)
        sz = np.fromiter(
            (sum(sl.size for sl in s.slices.values())
             for s in self.keys.values()),
            dtype=np.float64, count=n)
        return ks, sz

    # -- batched hot-path access ----------------------------------------------
    def update_many(self, interval: int, uniq_keys: np.ndarray,
                    init: Callable[[], Any],
                    size: float = 0.0) -> List[Tuple[KeyState, WindowSlice]]:
        """Fetch-or-create the interval slice for a batch of *unique* keys.

        Returns ``(KeyState, WindowSlice)`` pairs aligned with ``uniq_keys``
        (operators need the full :class:`KeyState` to scan the window, e.g.
        for the word-count total or the self-join probe count). This is the
        batched form of ``store.state(k).slice_for(interval, ...)`` used by
        :meth:`repro.streams.operators.Operator.process_batch`: the engine
        groups a micro-batch by key first, so each unique key pays one dict
        probe no matter how many tuples hit it.
        """
        out: List[Tuple[KeyState, WindowSlice]] = []
        keys = self.keys
        window = self.window
        for k in uniq_keys.tolist():
            ks = keys.get(k)
            if ks is None:
                ks = KeyState(window)
                keys[k] = ks
            sl = ks.slices.get(interval)      # slice_for, inlined (hot path)
            if sl is None:
                sl = WindowSlice(interval, init(), size)
                ks.slices[interval] = sl
            out.append((ks, sl))
        return out

    # -- migration primitives (paper steps 5-6) --------------------------------
    def extract(self, keys: List[int]) -> Dict[int, KeyState]:
        out = {}
        for k in keys:
            if k in self.keys:
                out[k] = self.keys.pop(k)
        return out

    def extract_many(self, keys: np.ndarray) -> Dict[int, KeyState]:
        """Array-at-a-time :meth:`extract` (migration step 5).

        Accepts any integer array; keys not present on this task are ignored,
        matching the scalar method's semantics. ``ndarray.tolist()`` converts
        to native ints in one C call — no per-element ``int(k)`` round-trip.
        """
        return self.extract(np.asarray(keys, dtype=np.int64).ravel().tolist())

    def install(self, states: Dict[int, KeyState]) -> None:
        for k, ks in states.items():
            if k in self.keys:
                raise RuntimeError(f"key {k} already present on target task")
            self.keys[k] = ks

    def install_many(self, states: Dict[int, KeyState]) -> None:
        """Alias of :meth:`install` under the batched-API naming (step 6)."""
        self.install(states)

    # -- pack-based migration (backend-agnostic engine contract) ---------------
    def extract_batch(self, keys: np.ndarray) -> "ObjectPack":
        """Remove ``keys`` (missing ones ignored) and return them as a pack.

        The pack supports :meth:`ObjectPack.take` so the engine can split one
        extraction across destinations without rebuilding per-key dicts.
        """
        arr = np.asarray(keys, dtype=np.int64).ravel()
        found = np.zeros(arr.size, dtype=bool)
        states: List[KeyState] = []
        store = self.keys
        for i, k in enumerate(arr.tolist()):
            ks = store.pop(k, None)
            if ks is not None:
                found[i] = True
                states.append(ks)
        return ObjectPack(arr[found], states)

    def install_batch(self, pack: "ObjectPack") -> None:
        store = self.keys
        for k, ks in zip(pack.keys.tolist(), pack.states):
            if k in store:
                raise RuntimeError(f"key {k} already present on target task")
            store[k] = ks


@dataclasses.dataclass
class ObjectPack:
    """In-flight migration payload for the object backend: keys + their
    :class:`KeyState` objects, aligned."""

    keys: np.ndarray
    states: List[KeyState]

    @property
    def nbytes(self) -> float:
        return float(sum(ks.total_size() for ks in self.states))

    def take(self, mask: np.ndarray) -> "ObjectPack":
        mask = np.asarray(mask, dtype=bool)
        return ObjectPack(self.keys[mask],
                          [s for s, m in zip(self.states, mask.tolist()) if m])

    def clone(self) -> "ObjectPack":
        """Deep-copied pack (checkpoint contract): the original pack holds
        live :class:`KeyState` references, so a snapshot that must survive
        further mutation — or be installed more than once — needs its own
        state objects."""
        import copy
        return ObjectPack(self.keys.copy(), copy.deepcopy(self.states))


# ---------------------------------------------------------------------------
# Columnar backend
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ColumnarSpec:
    """Slot semantics for :class:`ColumnarStateStore`.

    The columnar backend models one *numeric* slot per (key, interval):
    ``mode`` describes how a batch of ``add`` units folds into the slot
    value, ``slot_bytes`` is the size charged when a slot is first created
    (WordCount's fixed per-entry bytes) and ``bytes_per_unit`` the size
    growth per added unit (the self-join's per-stored-tuple bytes).
    ``payload`` selects how the compatibility ``keys`` view materializes
    slot payloads for store introspection: ``"count"`` -> ``{"count": n}``
    (the word-count family), ``"tuples"`` -> a length-``n`` list (the
    self-join; the raw tuple payloads are not retained columnarly).
    """

    mode: str = "add"            # "add" | "max"
    slot_bytes: float = 0.0      # size charged when a slot is created
    bytes_per_unit: float = 0.0  # extra size per added unit
    payload: str = "count"       # compat-view materialization


class _ColumnarKeysView(Mapping):
    """Read-only dict-like view over a columnar store's keys.

    Materializes :class:`KeyState` snapshots on demand so store
    introspection (tests, notebooks) works identically across backends.
    Mutating a snapshot does NOT write back to the columns.
    """

    def __init__(self, store: "ColumnarStateStore"):
        self._store = store

    def __len__(self) -> int:
        return int(self._store._keys.size)

    def __iter__(self):
        return iter(self._store._keys.tolist())

    def __contains__(self, key) -> bool:
        return self._store._row_of(key) is not None

    def __getitem__(self, key) -> KeyState:
        row = self._store._row_of(key)
        if row is None:
            raise KeyError(key)
        return self._store._key_state_snapshot(row)


class ColumnarStateStore:
    """Array-native windowed state for numeric operators (one task instance).

    Layout: ``_keys`` (K,) int64 sorted ascending; ``_vals`` / ``_sizes``
    (K, window+1) float64; ``_present`` (K, window+1) bool; ``_col_iv``
    (window+1,) maps each column to the interval it currently holds (-1 =
    empty). ``window + 1`` columns because during interval ``T_i`` the live
    window still includes ``T_{i-w}`` (it is erased only *after* ``T_i``
    finishes — paper Sec. II-A), so ``w + 1`` intervals are readable at
    once. Column assignment is the ring position ``interval % (window+1)``,
    which is identical across stores of one stage, so migration moves rows
    column-for-column.

    Invariant: non-present slots hold exact 0.0 in ``_vals`` and ``_sizes``,
    so window totals and S(k, w) are plain row sums.
    """

    def __init__(self, window: int, spec: ColumnarSpec):
        if spec.mode not in ("add", "max"):
            raise ValueError(f"unknown columnar mode {spec.mode!r}")
        self.window = window
        self.spec = spec
        self._ncols = window + 1
        self._keys = np.zeros(0, dtype=np.int64)
        self._vals = np.zeros((0, self._ncols), dtype=np.float64)
        self._sizes = np.zeros((0, self._ncols), dtype=np.float64)
        self._present = np.zeros((0, self._ncols), dtype=bool)
        self._col_iv = np.full(self._ncols, -1, dtype=np.int64)
        self._clock = None            # monotonic interval high-water mark

    def _advance_clock(self, interval: int, what: str) -> int:
        """Reject non-monotonic interval arguments.

        The ring position is ``interval % (window+1)``, so writing (or
        evicting at) an interval older than one already processed would
        silently alias a live column — corrupting window totals instead of
        failing. Equal intervals are fine (macro-batches within one
        interval, update followed by the boundary collect)."""
        interval = int(interval)
        if self._clock is not None and interval < self._clock:
            raise ValueError(
                f"non-monotonic interval: {what}({interval}) after the store "
                f"already advanced to interval {self._clock}; the window "
                f"ring (size {self._ncols}) would alias a live column")
        self._clock = interval
        return interval

    # -- introspection (dict-store-compatible surface) -------------------------
    @property
    def keys(self) -> _ColumnarKeysView:
        return _ColumnarKeysView(self)

    def _row_of(self, key) -> Optional[int]:
        keys = self._keys
        if not keys.size:
            return None
        pos = int(np.searchsorted(keys, key))
        if pos < keys.size and int(keys[pos]) == key:
            return pos
        return None

    def _key_state_snapshot(self, row: int) -> KeyState:
        ks = KeyState(self.window)
        live = np.nonzero(self._present[row])[0]
        for j in live[np.argsort(self._col_iv[live])]:
            iv = int(self._col_iv[j])
            n = int(self._vals[row, j])
            if self.spec.payload == "tuples":
                payload: Any = [None] * n
            else:
                payload = {"count": n}
            ks.slices[iv] = WindowSlice(iv, payload, float(self._sizes[row, j]))
        return ks

    def state(self, key: int) -> KeyState:
        raise NotImplementedError(
            "ColumnarStateStore has no mutable per-key objects; scalar "
            "operator access needs the object backend "
            "(KeyedStage(state_backend='object'))")

    # -- batched hot-path access ----------------------------------------------
    def update_slots(self, interval: int, keys: np.ndarray, add: np.ndarray
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """Fold a batch of per-key units into interval ``interval``'s column.

        ``keys`` must be sorted unique int64; ``add`` aligned float64 (tuple
        counts for the "add" ops, per-key maxima for "max"). Returns
        ``(win_before, slot_before)``: the windowed totals (all live slots,
        current included) and the current-slot values, both *before* this
        update — exactly the ``c0`` quantities the operators' closed forms
        need. Missing keys/slots are created; slot creation charges
        ``spec.slot_bytes`` and each added unit ``spec.bytes_per_unit``.
        """
        keys = np.asarray(keys, dtype=np.int64)
        add = np.asarray(add, dtype=np.float64)
        interval = self._advance_clock(interval, "update_slots")
        c = interval % self._ncols
        if self._col_iv[c] != interval:
            # the ring slot last held interval - (window+1), which eviction
            # cleared at the previous boundary; the wipe below only does work
            # for direct-API callers that skip end_interval
            if self._col_iv[c] >= 0:
                self._vals[:, c] = 0.0
                self._sizes[:, c] = 0.0
                self._present[:, c] = False
            self._col_iv[c] = interval
        nkeys = self._keys
        if nkeys.size:
            pos = np.searchsorted(nkeys, keys)
            inb = pos < nkeys.size
            found = np.zeros(keys.size, dtype=bool)
            found[inb] = nkeys[pos[inb]] == keys[inb]
            if found.all():              # steady state: no new keys, no rescan
                rows = pos
            else:
                self._insert_rows(keys[~found])
                rows = np.searchsorted(self._keys, keys)
        else:
            self._insert_rows(keys)
            rows = np.arange(keys.size)
        win_before = self._vals[rows].sum(axis=1)
        slot_before = self._vals[rows, c].copy()
        fresh = ~self._present[rows, c]
        self._present[rows, c] = True
        grow = np.where(fresh, self.spec.slot_bytes, 0.0)
        if self.spec.mode == "add":
            self._vals[rows, c] = slot_before + add
            if self.spec.bytes_per_unit:
                grow = grow + self.spec.bytes_per_unit * add
        else:
            self._vals[rows, c] = np.maximum(slot_before, add)
        self._sizes[rows, c] += grow
        return win_before, slot_before

    def _insert_rows(self, new_keys: np.ndarray) -> None:
        """Merge-insert sorted unique ``new_keys`` as zeroed rows."""
        old = self._keys
        idx = np.searchsorted(old, new_keys)
        newpos = idx + np.arange(new_keys.size)
        total = old.size + new_keys.size
        keep = np.ones(total, dtype=bool)
        keep[newpos] = False
        keys2 = np.empty(total, dtype=np.int64)
        keys2[keep] = old
        keys2[newpos] = new_keys
        vals2 = np.zeros((total, self._ncols), dtype=np.float64)
        sizes2 = np.zeros((total, self._ncols), dtype=np.float64)
        pres2 = np.zeros((total, self._ncols), dtype=bool)
        vals2[keep] = self._vals
        sizes2[keep] = self._sizes
        pres2[keep] = self._present
        self._keys, self._vals, self._sizes, self._present = \
            keys2, vals2, sizes2, pres2

    # -- interval boundary ------------------------------------------------------
    def end_interval_collect(self, interval: int
                             ) -> Tuple[np.ndarray, np.ndarray]:
        """Evict expired columns AND return ``(keys, S(k,w))`` — one column
        clear plus one row compaction instead of a per-key pass."""
        interval = self._advance_clock(interval, "end_interval_collect")
        cutoff = interval - self.window + 1
        expire = (self._col_iv >= 0) & (self._col_iv < cutoff)
        if expire.any():
            self._vals[:, expire] = 0.0
            self._sizes[:, expire] = 0.0
            self._present[:, expire] = False
            self._col_iv[expire] = -1
            alive = self._present.any(axis=1)
            if not alive.all():
                self._keys = self._keys[alive]
                self._vals = self._vals[alive]
                self._sizes = self._sizes[alive]
                self._present = self._present[alive]
        return self._keys, self._sizes.sum(axis=1)

    def end_interval(self, interval: int) -> None:
        self.end_interval_collect(interval)

    # -- stats ------------------------------------------------------------------
    def sizes_arrays(self) -> Tuple[np.ndarray, np.ndarray]:
        return self._keys, self._sizes.sum(axis=1)

    def sizes(self) -> Dict[int, float]:
        keys, sz = self.sizes_arrays()
        return dict(zip(keys.tolist(), sz.tolist()))

    def total_state_keys(self) -> int:
        return int(self._keys.size)

    # -- pack-based migration (paper steps 5-6) --------------------------------
    def extract_batch(self, keys: np.ndarray) -> "ColumnarPack":
        """Slice out the rows for ``keys`` (missing ones ignored) as a pack."""
        arr = np.unique(np.asarray(keys, dtype=np.int64).ravel())
        if arr.size and self._keys.size:
            pos = np.searchsorted(self._keys, arr)
            inb = pos < self._keys.size
            rows = pos[inb][self._keys[pos[inb]] == arr[inb]]
        else:
            rows = np.zeros(0, dtype=np.int64)
        pack = ColumnarPack(self._keys[rows], self._vals[rows],
                            self._sizes[rows], self._present[rows],
                            self._col_iv.copy())
        if rows.size:
            keep = np.ones(self._keys.size, dtype=bool)
            keep[rows] = False
            self._keys = self._keys[keep]
            self._vals = self._vals[keep]
            self._sizes = self._sizes[keep]
            self._present = self._present[keep]
        return pack

    def install_batch(self, pack: "ColumnarPack") -> None:
        if not pack.keys.size:
            return
        if self._keys.size and np.intersect1d(self._keys, pack.keys).size:
            dup = np.intersect1d(self._keys, pack.keys)
            raise RuntimeError(
                f"key {int(dup[0])} already present on target task")
        live = pack.col_iv >= 0
        conflict = live & (self._col_iv >= 0) & (self._col_iv != pack.col_iv)
        if conflict.any():
            raise RuntimeError(
                "columnar install across skewed interval clocks: source and "
                "target stores disagree on column contents")
        self._col_iv = np.where(live & (self._col_iv < 0), pack.col_iv,
                                self._col_iv)
        self._insert_rows(pack.keys)
        rows = np.searchsorted(self._keys, pack.keys)
        self._vals[rows] = pack.vals
        self._sizes[rows] = pack.sizes
        self._present[rows] = pack.present


@dataclasses.dataclass
class ColumnarPack:
    """In-flight migration payload for the columnar backend: row slices plus
    the source store's column->interval map (ring layouts agree across stores
    of one stage, so installs are column-aligned)."""

    keys: np.ndarray       # (M,) int64 sorted
    vals: np.ndarray       # (M, window+1) float64
    sizes: np.ndarray      # (M, window+1) float64
    present: np.ndarray    # (M, window+1) bool
    col_iv: np.ndarray     # (window+1,) int64

    @property
    def nbytes(self) -> float:
        return float(self.sizes.sum())

    def take(self, mask: np.ndarray) -> "ColumnarPack":
        mask = np.asarray(mask, dtype=bool)
        return ColumnarPack(self.keys[mask], self.vals[mask],
                            self.sizes[mask], self.present[mask], self.col_iv)

    def clone(self) -> "ColumnarPack":
        """Array-copied pack (checkpoint contract) — extraction already
        slices fresh arrays, but a checkpoint must stay installable more
        than once, and ``install_batch`` assigns the pack's rows into the
        target store, so the snapshot keeps its own buffers."""
        return ColumnarPack(self.keys.copy(), self.vals.copy(),
                            self.sizes.copy(), self.present.copy(),
                            self.col_iv.copy())
