"""Keyed, windowed state store for stateful operators (paper Sec. II-A).

Each key holds one state object per time interval; the store evicts state
older than ``window`` intervals after the interval closes (the paper's model:
"the task instance erases the state from T_{i-w} after finishing T_i").
``S(k, w)`` — the migration-cost weight — is the summed size over the window.

Batched API
-----------
The vectorized engine (see :mod:`repro.streams.engine`) never touches state
one key at a time on the hot path.  Instead it uses the array-at-a-time
methods added here:

* :meth:`TaskStateStore.update_many` — fetch-or-create the current interval's
  :class:`WindowSlice` for a whole batch of unique keys in one call (one dict
  probe per *unique* key instead of one per tuple);
* :meth:`TaskStateStore.extract_many` / :meth:`TaskStateStore.install_many` —
  migration primitives over key arrays (paper protocol steps 5-6);
* :meth:`TaskStateStore.sizes_arrays` — ``(keys, S(k,w))`` as numpy arrays
  for vectorized stats collection (paper step 1).

The scalar methods (:meth:`state`, :meth:`extract`, :meth:`install`) remain
for the reference per-tuple path and for custom operators.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Callable, Dict, Iterator, List, Tuple

import numpy as np


@dataclasses.dataclass
class WindowSlice:
    interval: int
    payload: Any
    size: float        # bytes (or abstract units) — feeds S(k, w)


class KeyState:
    """Ring of per-interval slices for one key."""

    def __init__(self, window: int):
        self.window = window
        self.slices: "OrderedDict[int, WindowSlice]" = OrderedDict()

    def slice_for(self, interval: int, init: Callable[[], Any],
                  size: float = 0.0) -> WindowSlice:
        sl = self.slices.get(interval)
        if sl is None:
            sl = WindowSlice(interval, init(), size)
            self.slices[interval] = sl
        return sl

    def evict_before(self, interval: int) -> None:
        cutoff = interval - self.window + 1
        slices = self.slices
        # slices are appended in interval order, so stale ones are a prefix
        while slices and next(iter(slices)) < cutoff:
            slices.popitem(last=False)

    def total_size(self) -> float:
        return float(sum(sl.size for sl in self.slices.values()))

    def iter_window(self) -> Iterator[WindowSlice]:
        return iter(self.slices.values())


class TaskStateStore:
    """All keyed state held by one task instance."""

    def __init__(self, window: int):
        self.window = window
        self.keys: Dict[int, KeyState] = {}

    def state(self, key: int) -> KeyState:
        ks = self.keys.get(key)
        if ks is None:
            ks = KeyState(self.window)
            self.keys[key] = ks
        return ks

    def end_interval(self, interval: int) -> None:
        """Evict expired slices; drop keys whose window fully emptied.

        Keys must not linger once every slice expired: an empty
        :class:`KeyState` contributes nothing to S(k,w) but would stay in
        ``self.keys`` forever, growing the step-1 stat universe (and thus
        planner input) monotonically on long runs.
        """
        dead = []
        for k, ks in self.keys.items():
            ks.evict_before(interval)
            if not ks.slices:
                dead.append(k)
        for k in dead:
            del self.keys[k]

    def end_interval_collect(self, interval: int
                             ) -> Tuple[np.ndarray, np.ndarray]:
        """Evict expired slices AND return ``(keys, S(k,w))`` in one pass.

        Fuses :meth:`end_interval` with :meth:`sizes_arrays` so the
        vectorized engine touches each key once per interval boundary instead
        of twice; produces exactly the values the two separate calls would —
        including dropping (and not reporting) keys left with no slices.
        """
        keys_out = []
        sizes_out = []
        dead = []
        for k, ks in self.keys.items():
            ks.evict_before(interval)
            slices = ks.slices
            if not slices:
                dead.append(k)
                continue
            total = 0.0
            for sl in slices.values():
                total += sl.size
            keys_out.append(k)
            sizes_out.append(total)
        for k in dead:
            del self.keys[k]
        return (np.asarray(keys_out, dtype=np.int64),
                np.asarray(sizes_out, dtype=np.float64))

    def sizes(self) -> Dict[int, float]:
        return {k: ks.total_size() for k, ks in self.keys.items()}

    def sizes_arrays(self) -> Tuple[np.ndarray, np.ndarray]:
        """All held keys and their windowed sizes ``S(k, w)`` as arrays.

        Feeds the vectorized stats collection (paper Fig. 5 step 1) without
        building an intermediate dict per interval.
        """
        n = len(self.keys)
        ks = np.fromiter(self.keys.keys(), dtype=np.int64, count=n)
        sz = np.fromiter(
            (sum(sl.size for sl in s.slices.values())
             for s in self.keys.values()),
            dtype=np.float64, count=n)
        return ks, sz

    # -- batched hot-path access ----------------------------------------------
    def update_many(self, interval: int, uniq_keys: np.ndarray,
                    init: Callable[[], Any],
                    size: float = 0.0) -> List[Tuple[KeyState, WindowSlice]]:
        """Fetch-or-create the interval slice for a batch of *unique* keys.

        Returns ``(KeyState, WindowSlice)`` pairs aligned with ``uniq_keys``
        (operators need the full :class:`KeyState` to scan the window, e.g.
        for the word-count total or the self-join probe count). This is the
        batched form of ``store.state(k).slice_for(interval, ...)`` used by
        :meth:`repro.streams.operators.Operator.process_batch`: the engine
        groups a micro-batch by key first, so each unique key pays one dict
        probe no matter how many tuples hit it.
        """
        out: List[Tuple[KeyState, WindowSlice]] = []
        keys = self.keys
        window = self.window
        for k in uniq_keys.tolist():
            ks = keys.get(k)
            if ks is None:
                ks = KeyState(window)
                keys[k] = ks
            sl = ks.slices.get(interval)      # slice_for, inlined (hot path)
            if sl is None:
                sl = WindowSlice(interval, init(), size)
                ks.slices[interval] = sl
            out.append((ks, sl))
        return out

    # -- migration primitives (paper steps 5-6) --------------------------------
    def extract(self, keys: List[int]) -> Dict[int, KeyState]:
        out = {}
        for k in keys:
            if k in self.keys:
                out[k] = self.keys.pop(k)
        return out

    def extract_many(self, keys: np.ndarray) -> Dict[int, KeyState]:
        """Array-at-a-time :meth:`extract` (migration step 5).

        Accepts any integer array; keys not present on this task are ignored,
        matching the scalar method's semantics.
        """
        return self.extract([int(k) for k in np.asarray(keys).ravel()])

    def install(self, states: Dict[int, KeyState]) -> None:
        for k, ks in states.items():
            if k in self.keys:
                raise RuntimeError(f"key {k} already present on target task")
            self.keys[k] = ks

    def install_many(self, states: Dict[int, KeyState]) -> None:
        """Alias of :meth:`install` under the batched-API naming (step 6)."""
        self.install(states)

    def migrated_bytes(self, keys: List[int]) -> float:
        return float(sum(self.keys[k].total_size() for k in keys
                         if k in self.keys))
