"""Keyed, windowed state store for stateful operators (paper Sec. II-A).

Each key holds one state object per time interval; the store evicts state
older than ``window`` intervals after the interval closes (the paper's model:
"the task instance erases the state from T_{i-w} after finishing T_i").
``S(k, w)`` — the migration-cost weight — is the summed size over the window.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict, defaultdict
from typing import Any, Callable, Dict, Iterator, List, Tuple


@dataclasses.dataclass
class WindowSlice:
    interval: int
    payload: Any
    size: float        # bytes (or abstract units) — feeds S(k, w)


class KeyState:
    """Ring of per-interval slices for one key."""

    def __init__(self, window: int):
        self.window = window
        self.slices: "OrderedDict[int, WindowSlice]" = OrderedDict()

    def slice_for(self, interval: int, init: Callable[[], Any],
                  size: float = 0.0) -> WindowSlice:
        sl = self.slices.get(interval)
        if sl is None:
            sl = WindowSlice(interval, init(), size)
            self.slices[interval] = sl
        return sl

    def evict_before(self, interval: int) -> None:
        cutoff = interval - self.window + 1
        stale = [i for i in self.slices if i < cutoff]
        for i in stale:
            del self.slices[i]

    def total_size(self) -> float:
        return float(sum(sl.size for sl in self.slices.values()))

    def iter_window(self) -> Iterator[WindowSlice]:
        return iter(self.slices.values())


class TaskStateStore:
    """All keyed state held by one task instance."""

    def __init__(self, window: int):
        self.window = window
        self.keys: Dict[int, KeyState] = {}

    def state(self, key: int) -> KeyState:
        ks = self.keys.get(key)
        if ks is None:
            ks = KeyState(self.window)
            self.keys[key] = ks
        return ks

    def end_interval(self, interval: int) -> None:
        for ks in self.keys.values():
            ks.evict_before(interval)

    def sizes(self) -> Dict[int, float]:
        return {k: ks.total_size() for k, ks in self.keys.items()}

    # -- migration primitives (paper steps 5-6) --------------------------------
    def extract(self, keys: List[int]) -> Dict[int, KeyState]:
        out = {}
        for k in keys:
            if k in self.keys:
                out[k] = self.keys.pop(k)
        return out

    def install(self, states: Dict[int, KeyState]) -> None:
        for k, ks in states.items():
            if k in self.keys:
                raise RuntimeError(f"key {k} already present on target task")
            self.keys[k] = ks

    def migrated_bytes(self, keys: List[int]) -> float:
        return float(sum(self.keys[k].total_size() for k in keys
                         if k in self.keys))
