"""Deterministic failure injection + restore-and-replay recovery.

The engine exposes exactly two crash sites through the ``stage.failpoint``
seam (see :class:`~repro.streams.engine.KeyedStage`):

* ``"deliver"`` — the interval's traffic has arrived but *nothing* has
  mutated yet (``process_interval_arrays`` entry, before the backend
  dispatch). A kill here models a task dying between intervals.
* ``"mid"`` — keyed state has been mutated for the interval but no report
  was produced (reference loop: after replay/clear_pause, before the ring
  advances; vectorized backends: after state mutation, before
  ``_finish_interval``). A kill here models a task dying mid-interval, the
  hard case: the half-applied interval must be discarded wholesale.

Faults are *declared*, not random: a :class:`FaultPlan` lists frozen fault
records pinned to intervals, the :class:`FaultInjector` fires each exactly
once (stalls: ``attempts`` times), and recovery is therefore convergent —
replaying a buffered interval does not re-trigger the fault that killed it.

:class:`ChaosRunner` closes the loop: it buffers every delivered interval,
checkpoints the stage at a fixed cadence through
:mod:`repro.streams.checkpoint`, and on any detected failure restores the
last checkpoint and replays the buffered intervals. The resulting
:class:`~repro.streams.engine.IntervalReport` stream is **bit-identical**
to a fault-free run of the same traffic — the recovery-lossless property
``tests/test_chaos_recovery.py`` pins on every state backend.

Delivery faults (:class:`DropDelivery` / :class:`DuplicateDelivery`) live at
the runner level — the "network" delivers an interval zero or two times —
and are detected by epoch mismatch: after the deliveries, the stage clock
does not equal the expected interval, so the runner restores and replays.
Exactly-once interval semantics are thus *recovered*, not assumed.

Like :mod:`repro.streams.checkpoint`, this module is jax-free and
duck-types the stage — no engine import.
"""

from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Sequence, Tuple

import numpy as np

from .checkpoint import CheckpointStore, checkpoint_stage, restore_stage

__all__ = [
    "TaskKilled", "TaskStalled",
    "KillTask", "StallTask", "DropDelivery", "DuplicateDelivery",
    "FaultPlan", "FaultInjector", "RecoveryEvent", "ChaosRunner",
]

FAIL_SITES = ("deliver", "mid")


class TaskKilled(RuntimeError):
    """A task crashed at an engine crash site; the interval is lost."""

    def __init__(self, task: int, interval: int, site: str):
        super().__init__(f"task {task} killed at interval {interval} "
                         f"(site={site!r})")
        self.task = task
        self.interval = interval
        self.site = site


class TaskStalled(RuntimeError):
    """A task's store stalled (transient): the attempt fails, retries heal."""

    def __init__(self, task: int, interval: int, site: str):
        super().__init__(f"task {task} stalled at interval {interval} "
                         f"(site={site!r})")
        self.task = task
        self.interval = interval
        self.site = site


@dataclasses.dataclass(frozen=True)
class KillTask:
    """Kill task ``task`` at interval ``interval``, at crash site ``site``."""

    interval: int
    task: int = 0
    site: str = "mid"

    def __post_init__(self):
        if self.site not in FAIL_SITES:
            raise ValueError(f"unknown fail site {self.site!r}; "
                             f"choose from {FAIL_SITES}")


@dataclasses.dataclass(frozen=True)
class StallTask:
    """Stall task ``task`` at interval ``interval`` for ``attempts`` tries.

    Fires at the ``deliver`` site (a stalled store refuses the interval's
    traffic); the delivery succeeds once ``attempts`` failures have burned
    off — modelling a transiently wedged store that heals under retry.
    """

    interval: int
    task: int = 0
    attempts: int = 2

    def __post_init__(self):
        if self.attempts < 1:
            raise ValueError(f"attempts must be >= 1, got {self.attempts}")


@dataclasses.dataclass(frozen=True)
class DropDelivery:
    """The interval's traffic is never delivered (0 deliveries)."""

    interval: int


@dataclasses.dataclass(frozen=True)
class DuplicateDelivery:
    """The interval's traffic is delivered twice (at-least-once network)."""

    interval: int


@dataclasses.dataclass
class RecoveryEvent:
    """One restore-and-replay episode, for assertions and benchmarks."""

    interval: int                  # the interval whose processing failed
    kind: str                      # "kill@mid", "stall@deliver", "drop", ...
    replayed: int                  # buffered-interval deliveries replayed


class FaultPlan:
    """A deterministic schedule of faults, each consumed exactly once."""

    def __init__(self, faults: Sequence[Any] = ()):
        self.faults: List[Any] = list(faults)
        for f in self.faults:
            if not isinstance(f, (KillTask, StallTask, DropDelivery,
                                  DuplicateDelivery)):
                raise TypeError(f"unknown fault type: {f!r}")
        self._delivery = {}
        for f in self.faults:
            if isinstance(f, DropDelivery):
                self._delivery[f.interval] = (0, "drop")
            elif isinstance(f, DuplicateDelivery):
                self._delivery[f.interval] = (2, "duplicate")

    def take_delivery_fault(self, interval: int) -> Tuple[int, Optional[str]]:
        """(deliveries, kind) for this interval; the fault is consumed."""
        return self._delivery.pop(interval, (1, None))


class FaultInjector:
    """Installable ``stage.failpoint`` that fires a plan's in-engine faults.

    Kills fire exactly once (the ``fired`` set survives restores — the
    injector lives outside the stage, like a real environment does), so a
    recovery replay of the same interval runs clean. Stalls fire up to
    ``attempts`` times and then heal.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.fired: set = set()
        self._stall_tries: dict = {}

    def install(self, stage) -> "FaultInjector":
        stage.failpoint = self
        return self

    def __call__(self, site: str, stage) -> None:
        # "deliver" fires before begin_interval, "mid" after it
        iv = stage._interval + 1 if site == "deliver" else stage._interval
        for f in self.plan.faults:
            if (isinstance(f, KillTask) and f.interval == iv
                    and f.site == site and f not in self.fired):
                self.fired.add(f)
                raise TaskKilled(f.task, iv, site)
            if (isinstance(f, StallTask) and f.interval == iv
                    and site == "deliver"):
                tries = self._stall_tries.get(f, 0)
                if tries < f.attempts:
                    self._stall_tries[f] = tries + 1
                    raise TaskStalled(f.task, iv, site)


class ChaosRunner:
    """Checkpoint + buffer + restore-and-replay driver for one stage.

    Wraps ``stage.process_interval_arrays`` with the full recovery loop:

    1. buffer the interval's traffic (the upstream replay log);
    2. deliver it through the fault plan's delivery schedule;
    3. on a caught kill/stall or a detected epoch mismatch, restore the
       last checkpoint and replay every buffered interval up to and
       including the failed one — retrying from the checkpoint if a fault
       fires *during* replay — then resume;
    4. at ``checkpoint_every`` boundaries, snapshot the stage (optionally
       persisting through a :class:`~repro.streams.checkpoint
       .CheckpointStore`) and trim the replay buffer.

    ``events`` records every recovery episode. With ``plan=None`` the
    runner degrades to a checkpoint-overhead harness (no faults injected),
    which is what the chaos benchmark's overhead arm measures.
    """

    def __init__(self, stage, plan: Optional[FaultPlan] = None,
                 checkpoint_every: int = 2,
                 store: Optional[CheckpointStore] = None):
        if checkpoint_every < 1:
            raise ValueError(
                f"checkpoint_every must be >= 1, got {checkpoint_every}")
        self.stage = stage
        self.plan = plan if plan is not None else FaultPlan()
        self.checkpoint_every = checkpoint_every
        self.store = store
        self.injector = FaultInjector(self.plan).install(stage)
        self.events: List[RecoveryEvent] = []
        self._buffer: List[Tuple[int, np.ndarray, Optional[np.ndarray]]] = []
        # interval-0 baseline: recovery works even before the first cadence
        self._ckpt = checkpoint_stage(stage)
        if self.store is not None:
            self.store.save(self._ckpt)

    # -- driving ---------------------------------------------------------------
    def process_interval(self, keys: np.ndarray,
                         values: Optional[np.ndarray] = None):
        """Deliver one interval under the fault plan; returns its report."""
        iv = self.stage._interval + 1
        bkeys = np.asarray(keys, dtype=np.int64).copy()
        bvals = None if values is None else np.asarray(values).copy()
        self._buffer.append((iv, bkeys, bvals))
        deliveries, kind = self.plan.take_delivery_fault(iv)
        fault: Optional[str] = None
        try:
            for _ in range(deliveries):
                self.stage.process_interval_arrays(bkeys, bvals)
        except TaskKilled as e:
            fault = f"kill@{e.site}"
        except TaskStalled as e:
            fault = f"stall@{e.site}"
        if fault is None and self.stage._interval != iv:
            # 0 or 2 deliveries left the stage clock out of step with the
            # source epoch — exactly-once is violated, recover it
            fault = kind or "epoch-mismatch"
        if fault is None:
            self._maybe_checkpoint(iv)
        else:
            self._recover(iv, fault)
        return self.stage.reports[-1]

    # -- recovery --------------------------------------------------------------
    def _recover(self, upto: int, kind: str) -> None:
        """Restore the last checkpoint, replay the buffer through ``upto``."""
        replayed = 0
        while True:
            restore_stage(self.stage, self._ckpt)
            try:
                for biv, bkeys, bvals in self._buffer:
                    if biv <= self.stage._interval:
                        continue          # covered by the checkpoint
                    if biv > upto:
                        break
                    self.stage.process_interval_arrays(bkeys, bvals)
                    replayed += 1
            except (TaskKilled, TaskStalled):
                continue                  # a fault fired mid-replay: retry
            if self.stage._interval == upto:
                break
        self.events.append(RecoveryEvent(interval=upto, kind=kind,
                                         replayed=replayed))
        self._maybe_checkpoint(upto)

    def _maybe_checkpoint(self, interval: int) -> None:
        if interval % self.checkpoint_every != 0:
            return
        self._ckpt = checkpoint_stage(self.stage)
        if self.store is not None:
            self.store.save(self._ckpt)
        # intervals at or before the snapshot can never be replayed again
        self._buffer = [b for b in self._buffer if b[0] > interval]

    # -- introspection ---------------------------------------------------------
    @property
    def reports(self):
        return self.stage.reports

    def buffered_intervals(self) -> List[int]:
        return [iv for iv, _, _ in self._buffer]
