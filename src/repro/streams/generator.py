"""Synthetic workload generator (paper Sec. V, Table II).

Creates per-interval KeyStats snapshots from an integer key domain of size K:
tuple frequencies follow Zipf(z); parameter ``f`` controls the fluctuation
rate across intervals — at each new interval frequencies are swapped between
keys routed to different task instances until the per-instance workload change
reaches ``|L_i(d) - L_{i-1}(d)| / L_{i-1}(d) >= f`` (the paper's rule).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

from repro.core.balancer import Assignment, KeyStats


def zipf_frequencies(k: int, z: float, total: float = 1e6,
                     rng: Optional[np.random.Generator] = None) -> np.ndarray:
    """Frequencies proportional to rank^-z, scaled to ``total`` tuples,
    randomly permuted over key ids (rank != key id)."""
    rng = rng or np.random.default_rng(0)
    ranks = np.arange(1, k + 1, dtype=np.float64)
    p = ranks ** (-z) if z > 0 else np.ones_like(ranks)
    p /= p.sum()
    freq = p * total
    rng.shuffle(freq)
    return freq


@dataclasses.dataclass
class WorkloadGen:
    """Streaming generator of per-interval KeyStats."""

    k: int = 10_000                  # key domain size
    z: float = 0.85                  # zipf skewness
    f: float = 1.0                   # fluctuation rate
    total_tuples: float = 1e6
    cost_per_tuple: float = 1.0
    mem_per_tuple: float = 1.0
    window: int = 1                  # w: S(k,w) sums the last w intervals
    seed: int = 0

    def __post_init__(self) -> None:
        self.rng = np.random.default_rng(self.seed)
        self.keys = np.arange(self.k, dtype=np.int64)
        self.freq = zipf_frequencies(self.k, self.z, self.total_tuples, self.rng)
        self._mem_hist = [self.freq * self.mem_per_tuple]

    def _fluctuate(self, assignment: Assignment) -> None:
        """Swap frequencies between keys on different instances until the
        workload change on some instance reaches f (paper's procedure)."""
        if self.f <= 0:
            return
        dests = assignment.dest(self.keys)
        n_dest = assignment.n_dest
        old_loads = np.bincount(dests, weights=self.freq * self.cost_per_tuple,
                                minlength=n_dest)
        old_loads = np.maximum(old_loads, 1e-9)
        # incremental load maintenance: each swap moves freq mass between two
        # instances, so the per-instance loads update in O(N_D) instead of a
        # full O(K) bincount per candidate swap (same rng draws, same
        # termination rule as the paper's procedure)
        cur_loads = old_loads.copy()
        for _ in range(200_000):
            i, j = self.rng.integers(0, self.k, size=2)
            di, dj = dests[i], dests[j]
            if di == dj or i == j:
                continue
            delta = (self.freq[j] - self.freq[i]) * self.cost_per_tuple
            self.freq[i], self.freq[j] = self.freq[j], self.freq[i]
            cur_loads[di] += delta
            cur_loads[dj] -= delta
            rel = np.abs(cur_loads - old_loads) / old_loads
            if float(np.max(rel)) >= self.f:
                return

    def interval(self, assignment: Assignment, fluctuate: bool = True) -> KeyStats:
        """Produce the next interval's statistics."""
        if fluctuate:
            self._fluctuate(assignment)
        mem_now = self.freq * self.mem_per_tuple
        self._mem_hist.append(mem_now.copy())
        if len(self._mem_hist) > self.window:
            self._mem_hist = self._mem_hist[-self.window:]
        s_kw = np.sum(self._mem_hist, axis=0)
        return KeyStats(keys=self.keys.copy(),
                        cost=self.freq * self.cost_per_tuple,
                        mem=s_kw,
                        freq=self.freq.copy())

    def stream(self, assignment: Assignment, n: int) -> Iterator[KeyStats]:
        for i in range(n):
            yield self.interval(assignment, fluctuate=i > 0)

    def draw_tuples(self, n: int) -> np.ndarray:
        """Sample n concrete tuple keys from the current distribution."""
        p = self.freq / self.freq.sum()
        return self.rng.choice(self.keys, size=n, p=p)
