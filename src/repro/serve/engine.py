"""Serving engine: continuous batching with session-key routing.

Replica groups = the paper's task instances; session ids = keys; per-session
KV cache = the migratable state. Hot sessions (agents, long contexts, high
QPS) skew replica load exactly like hot keys skew operator load; the
controller's Mixed algorithm re-routes a handful of sessions per interval and
prices each move by its KV bytes S(k, w) — sessions idle past ``window``
intervals are evicted, matching the paper's windowed state model.

The engine is model-agnostic: `decode_fn(replica, session_ids) -> tokens`
abstracts the actual serve_step; the simulation path (used by benchmarks)
charges per-token cost instead.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core import (Assignment, BalanceConfig, KeyStats,
                        RebalanceController)
from repro.core.balancer.hashing import Hash32


@dataclasses.dataclass
class Session:
    session_id: int
    context_len: int = 0           # KV entries held
    last_active: int = 0
    tokens_this_interval: int = 0


@dataclasses.dataclass
class ServeReport:
    interval: int
    requests: int
    tokens: int
    makespan: float
    throughput: float
    theta: float
    migrated_kv_bytes: float
    migrated_sessions: int
    table_size: int
    replica_loads: np.ndarray


class ServeEngine:
    def __init__(self, n_replicas: int, bytes_per_kv_token: float = 2048.0,
                 theta_max: float = 0.1, table_max: int = 4096,
                 window: int = 4, seed: int = 0, algorithm: str = "mixed",
                 decode_fn: Optional[Callable] = None):
        self.n_replicas = n_replicas
        self.bytes_per_kv = bytes_per_kv_token
        self.window = window
        self.sessions: Dict[int, Session] = {}
        self.location: Dict[int, int] = {}     # session -> replica (state)
        self.controller = RebalanceController(
            Assignment(Hash32(n_replicas, seed=seed)),
            BalanceConfig(theta_max=theta_max, table_max=table_max,
                          window=window),
            algorithm=algorithm, executor=self._migrate)
        self.decode_fn = decode_fn
        self.reports: List[ServeReport] = []
        self._interval = 0
        self._migrated_bytes = 0.0
        self._migrated_sessions = 0

    # ------------------------------------------------------------- migration
    def _migrate(self, moved_keys, old: Assignment, new: Assignment) -> None:
        ids = np.asarray([int(k) for k in moved_keys], np.int64)
        dst = new.dest(ids)
        for sid, d in zip(ids, dst):
            sess = self.sessions.get(int(sid))
            if sess is None:
                continue
            if self.location.get(int(sid)) != int(d):
                self._migrated_bytes += sess.context_len * self.bytes_per_kv
                self._migrated_sessions += 1
                self.location[int(sid)] = int(d)

    # --------------------------------------------------------------- serving
    def submit(self, session_id: int, prompt_tokens: int) -> int:
        """Route a request; create/extend its session. Returns the replica."""
        sid = int(session_id)
        d = int(self.controller.assignment.dest(np.asarray([sid],
                                                           np.int64))[0])
        sess = self.sessions.get(sid)
        if sess is None:
            sess = Session(sid)
            self.sessions[sid] = sess
            self.location[sid] = d
        sess.context_len += prompt_tokens
        sess.tokens_this_interval += prompt_tokens
        sess.last_active = self._interval
        return self.location[sid]

    def run_interval(self, requests: List) -> ServeReport:
        """requests: list of (session_id, prompt_tokens, decode_tokens)."""
        self._interval += 1
        loads = np.zeros(self.n_replicas)
        tokens = 0
        for sid, prompt, decode in requests:
            replica = self.submit(sid, prompt)
            sess = self.sessions[int(sid)]
            sess.context_len += decode
            sess.tokens_this_interval += decode
            # cost model: prefill tokens + decode tokens x context factor
            loads[replica] += prompt + decode * (
                1.0 + sess.context_len / 65536.0)
            tokens += prompt + decode
            if self.decode_fn is not None:
                self.decode_fn(replica, int(sid), prompt, decode)

        # evict idle sessions beyond the window (paper's state expiry)
        for sid in [s for s, v in self.sessions.items()
                    if self._interval - v.last_active >= self.window]:
            self.sessions.pop(sid)
            self.location.pop(sid, None)

        stats = self._stats()
        makespan = float(loads.max()) if len(requests) else 0.0
        mean = float(loads.mean()) if len(requests) else 0.0
        report = ServeReport(
            interval=self._interval, requests=len(requests), tokens=tokens,
            makespan=makespan,
            throughput=tokens / makespan if makespan > 0 else 0.0,
            theta=(makespan - mean) / mean if mean > 0 else 0.0,
            migrated_kv_bytes=self._migrated_bytes,
            migrated_sessions=self._migrated_sessions,
            table_size=self.controller.assignment.table_size,
            replica_loads=loads)
        self.reports.append(report)
        self._migrated_bytes = 0.0
        self._migrated_sessions = 0
        if stats is not None:
            self.controller.on_interval(stats)
        for sess in self.sessions.values():
            sess.tokens_this_interval = 0
        return report

    def _stats(self) -> Optional[KeyStats]:
        if not self.sessions:
            return None
        keys = np.asarray(sorted(self.sessions), np.int64)
        cost = np.asarray([self.sessions[int(k)].tokens_this_interval
                           for k in keys], np.float64)
        mem = np.asarray([self.sessions[int(k)].context_len
                          * self.bytes_per_kv for k in keys], np.float64)
        return KeyStats(keys=keys, cost=cost, mem=np.maximum(mem, 1.0))
