"""Training launcher.

Two modes:
  * ``--mode local``  — run real steps at reduced (smoke) scale on this host:
    full stack (keyed pipeline → microbatched AdamW → checkpoints →
    SkewShield for MoE archs). Works on CPU.
  * ``--mode lower``  — lower + compile the FULL config's train step for the
    production mesh (single or multi pod) and print the memory/cost digest;
    this is what a real cluster job would execute per worker before the
    first step, so a green run here is the go/no-go signal.

Per-arch perf flags (§Perf-validated) are applied automatically unless
--no-perf-flags. Examples:

  PYTHONPATH=src python -m repro.launch.train --arch granite-8b --steps 20
  PYTHONPATH=src python -m repro.launch.train --arch dbrx-132b \
      --mode lower --shape train_4k --mesh multi
"""

from __future__ import annotations

import argparse
import dataclasses
import os


def _apply_perf_flags(arch: str, enable: bool) -> None:
    if not enable:
        return
    os.environ.setdefault("REPRO_PERF_MOE_GROUPED", "1")
    cfgless_indivisible = {"qwen2_7b", "whisper_large_v3", "internvl2_1b",
                           "granite_moe_3b_a800m", "xlstm_125m"}
    if arch in cfgless_indivisible:
        os.environ.setdefault("REPRO_PERF_ATTN_SHARD", "1")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--mode", choices=["local", "lower"], default="local")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--no-perf-flags", action="store_true")
    args = ap.parse_args()
    arch = args.arch.replace("-", "_")
    _apply_perf_flags(arch, not args.no_perf_flags)

    if args.mode == "lower":
        # production-mesh compile: must set device count before jax loads
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        from repro.launch.dryrun import lower_cell
        rep = lower_cell(arch, args.shape, multi_pod=args.mesh == "multi",
                         microbatches=args.microbatches)
        mem = rep.get("memory", {})
        print(f"compiled {arch} x {args.shape} on {rep.get('devices')} chips "
              f"in {rep.get('compile_s')}s")
        print(f"  HLO flops/dev: {rep.get('flops'):.3e} "
              f"(corrected {rep.get('corrected', {}).get('flops', 0):.3e})")
        print(f"  HBM args+temp: "
              f"{(mem.get('argument_bytes', 0) + mem.get('temp_bytes', 0))/1e9:.1f} GB/dev")
        print(f"  collectives: {rep.get('collective_bytes')}")
        return

    import jax.numpy as jnp
    from repro.configs import smoke_config
    from repro.data.pipeline import KeyedDataPipeline, zipf_sources
    from repro.train.optimizer import OptConfig
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = smoke_config(arch)
    pipe = KeyedDataPipeline(zipf_sources(32, z=1.0), n_workers=1,
                             seq_len=args.seq, vocab=cfg.vocab)

    def data_fn(step):
        while True:
            pipe.run_interval(n_docs=32)
            b = pipe.worker_batch(0, args.batch)
            if b is not None:
                out = {k: jnp.asarray(v) for k, v in b.items()}
                if cfg.frontend == "vision_stub":
                    import numpy as np
                    out["pixel_embeds"] = jnp.asarray(
                        np.random.default_rng(step).standard_normal(
                            (args.batch, cfg.prefix_len, cfg.d_model)),
                        jnp.bfloat16)
                elif cfg.frontend == "audio_stub":
                    import numpy as np
                    out["frames"] = jnp.asarray(
                        np.random.default_rng(step).standard_normal(
                            (args.batch, cfg.encoder_seq, cfg.d_model)),
                        jnp.bfloat16)
                return out

    tcfg = TrainerConfig(total_steps=args.steps, checkpoint_every=10,
                         microbatches=args.microbatches or 1,
                         skewshield=cfg.moe_experts > 0)
    tr = Trainer(cfg, OptConfig(lr=1e-3, warmup_steps=5,
                                total_steps=args.steps),
                 tcfg, args.ckpt, data_fn)
    if tr.try_resume():
        print(f"resumed at step {tr.step}")
    hist = tr.run()
    print(f"{arch}: step {tr.step} loss "
          f"{hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
