"""Serving launcher.

  * ``--mode local`` — smoke-scale real decoding on this host: prefill +
    decode through the KV cache, session routing across simulated replica
    groups, SkewShield placement for MoE archs.
  * ``--mode lower`` — compile the FULL config's serve step (prefill or
    decode cell) for the production mesh; the go/no-go signal for a real
    serving fleet.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --tokens 16
  PYTHONPATH=src python -m repro.launch.serve --arch jamba-1.5-large-398b \
      --mode lower --shape decode_32k --mesh single
"""

from __future__ import annotations

import argparse
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--mode", choices=["local", "lower"], default="local")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--no-perf-flags", action="store_true")
    args = ap.parse_args()
    arch = args.arch.replace("-", "_")
    if not args.no_perf_flags:
        os.environ.setdefault("REPRO_PERF_DECODE_WS", "1")
        os.environ.setdefault("REPRO_PERF_MOE_GROUPED", "1")

    if args.mode == "lower":
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        from repro.launch.dryrun import lower_cell
        rep = lower_cell(arch, args.shape, multi_pod=args.mesh == "multi")
        if rep.get("skipped"):
            print(f"skipped: {rep['reason']}")
            return
        mem = rep.get("memory", {})
        print(f"compiled {arch} x {args.shape} serve step on "
              f"{rep.get('devices')} chips in {rep.get('compile_s')}s")
        print(f"  HBM args+temp: "
              f"{(mem.get('argument_bytes', 0) + mem.get('temp_bytes', 0))/1e9:.1f} GB/dev")
        print(f"  collectives: {rep.get('collective_bytes')}")
        return

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import smoke_config
    from repro.models import init_cache, model_schema, schema
    from repro.models.skewshield import SkewShieldPlacer, placements_array
    from repro.train.train_step import make_serve_step

    cfg = smoke_config(arch)
    params = schema.init(model_schema(cfg), jax.random.PRNGKey(0))
    serve_step = jax.jit(make_serve_step(cfg))
    rng = np.random.default_rng(0)
    max_seq = args.prompt + args.tokens + cfg.prefix_len
    cache = init_cache(cfg, args.batch, max_seq)

    placements = None
    if cfg.moe_experts:
        shards = max(2, min(4, cfg.moe_experts))
        while cfg.moe_experts % shards:
            shards -= 1
        placers = [SkewShieldPlacer(cfg.moe_experts, shards, 1e6)
                   for _ in range(cfg.n_layers)]
        placements = placements_array(placers)

    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt)), jnp.int32)}
    if cfg.frontend == "audio_stub":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.encoder_seq, cfg.d_model)),
            jnp.bfloat16)
    elif cfg.frontend == "vision_stub":
        batch["pixel_embeds"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.prefix_len, cfg.d_model)),
            jnp.bfloat16)
    logits, cache = serve_step(params, cache, batch, 0, placements)
    idx = args.prompt + (cfg.prefix_len if cfg.frontend == "vision_stub"
                         else 0)
    outs = []
    step_batch = {}
    if cfg.frontend == "audio_stub":
        # decode steps reuse the prefill-computed encoder output
        from repro.models import forward
        step_batch["frames"] = batch["frames"]
    for t in range(args.tokens):
        nxt = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        outs.append(np.asarray(nxt)[:, 0])
        step_batch["tokens"] = nxt
        logits, cache = serve_step(params, cache, step_batch, idx, placements)
        idx += 1
    print(f"{arch}: decoded {args.tokens} tokens x batch {args.batch}")
    print(np.stack(outs, 1))


if __name__ == "__main__":
    main()
