"""ShapeDtypeStruct stand-ins for every (arch x shape) dry-run cell.

``input_specs`` returns the abstract inputs for the step that the cell
lowers: train/prefill -> (B, seq) token batches; decode -> one new token
against a KV cache of seq_len. Modality frontends are stubs per the
assignment: whisper gets precomputed frame embeddings, internvl2 precomputed
patch embeddings.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import cache_schema, model_schema, schema as schema_mod
from repro.models.config import ModelConfig, SHAPES, ShapeConfig
from repro.sharding import rules

SDS = jax.ShapeDtypeStruct


def cell_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """The assignment's skip rules (documented in DESIGN.md)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: 500k decode skipped"
    return True, ""


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, SDS]:
    b = shape.global_batch
    if shape.kind == "train":
        out = {"tokens": SDS((b, shape.seq_len), jnp.int32),
               "labels": SDS((b, shape.seq_len), jnp.int32)}
        if cfg.frontend == "audio_stub":
            out["frames"] = SDS((b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        elif cfg.frontend == "vision_stub":
            out["pixel_embeds"] = SDS((b, cfg.prefix_len, cfg.d_model),
                                      jnp.bfloat16)
        return out
    if shape.kind == "prefill":
        out = {"tokens": SDS((b, shape.seq_len), jnp.int32)}
        if cfg.frontend == "audio_stub":
            out["frames"] = SDS((b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        elif cfg.frontend == "vision_stub":
            out["pixel_embeds"] = SDS((b, cfg.prefix_len, cfg.d_model),
                                      jnp.bfloat16)
        return out
    # decode: one new token with a KV cache of seq_len
    out = {"tokens": SDS((b, 1), jnp.int32)}
    if cfg.frontend == "audio_stub":
        out["encoder_out"] = SDS((b, cfg.encoder_seq, cfg.d_model),
                                 jnp.bfloat16)
    return out


def batch_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh):
    from jax.sharding import NamedSharding, PartitionSpec as P
    bspec = rules.batch_pspec(mesh, shape.global_batch)
    bs = batch_specs(cfg, shape)
    out = {}
    for k, v in bs.items():
        parts = [bspec[0] if bspec else None] + [None] * (len(v.shape) - 1)
        out[k] = NamedSharding(mesh, P(*parts))
    return out


def cache_max_seq(cfg: ModelConfig, shape: ShapeConfig) -> int:
    extra = cfg.prefix_len if cfg.frontend == "vision_stub" else 0
    return shape.seq_len + extra


def decode_cache_specs(cfg: ModelConfig, shape: ShapeConfig):
    sch = cache_schema(cfg, shape.global_batch, cache_max_seq(cfg, shape))
    return schema_mod.abstract(sch), sch


def param_specs(cfg: ModelConfig):
    sch = model_schema(cfg)
    return schema_mod.abstract(sch), sch
