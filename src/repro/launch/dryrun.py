import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may import jax (device count is locked above) ---------
import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from pathlib import Path  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCHS, get_config              # noqa: E402
from repro.launch import specs as specs_mod              # noqa: E402
from repro.launch.mesh import make_production_mesh       # noqa: E402
from repro.models import model_schema, cache_schema      # noqa: E402
from repro.models import schema as schema_mod            # noqa: E402
from repro.models.config import SHAPES                   # noqa: E402
from repro.sharding import rules                         # noqa: E402
from repro.sharding import ctx as shard_ctx                # noqa: E402
from repro.train.optimizer import OptConfig              # noqa: E402
from repro.train.train_step import (make_serve_step,     # noqa: E402
                                    make_train_step)

RESULTS_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

# default microbatch counts per train cell (keeps MoE dispatch transients sane)
TRAIN_MICROBATCHES = {"train_4k": 8}

_COLLECTIVE_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\s*=\s*\(?([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "u64": 8, "s64": 8,
                "u32": 4, "s32": 4, "u16": 2, "s16": 2, "u8": 1, "s8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}


def collective_bytes(hlo_text: str) -> dict:
    """Sum collective result bytes per op family from optimized HLO.

    Link-traffic multipliers applied downstream (roofline.py): all-reduce
    moves ~2x its payload over the ring; others ~1x.
    """
    out = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        op, dt, dims = m.group(1), m.group(2), m.group(3)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out[op] = out.get(op, 0) + n * _DTYPE_BYTES.get(dt, 4)
    return out



def _cost_dict(compiled):
    """compiled.cost_analysis() compat: jax < 0.5 returns [dict], newer dict."""
    cost = compiled.cost_analysis()
    return cost[0] if isinstance(cost, list) else cost

def _probe_costs(cfg, shape, mesh, fsdp: bool, remat: bool):
    """XLA's cost_analysis counts a while-loop body ONCE, so scan-over-layers
    (and microbatch) totals are undercounted. Probe with 1-group and 2-group
    variants of the same config at microbatches=1; per-group deltas give the
    exact linear-in-depth totals:  total(n) = base + n * delta.
    """
    import dataclasses as dc
    period = cfg.pattern_period
    n_groups = cfg.n_layers // period
    # probe at 2 and 3 groups: the 1-group edge case occasionally flips SPMD
    # partitioner decisions (observed: logits path replicated at g=1 for
    # internvl2), corrupting the delta. 2->3 sits in the steady regime.
    reports = []
    for g in (2, 3):
        # encoder scales 1:1 with decoder groups (whisper: 32 enc / 32 dec)
        c = dc.replace(cfg, n_layers=g * period,
                       encoder_layers=g if cfg.encoder_layers else 0)
        reports.append(_lower_raw(c, shape, mesh, fsdp, remat,
                                  microbatches=1))
    c2, c3 = reports
    out = {}
    for key in ("flops", "bytes_accessed"):
        delta = c3[key] - c2[key]
        out[key] = c2[key] + (n_groups - 2) * delta
        out[key + "_per_group"] = delta
    coll = {}
    ops = set(c2["collective_bytes"]) | set(c3["collective_bytes"])
    for op in ops:
        v2 = c2["collective_bytes"].get(op, 0)
        v3 = c3["collective_bytes"].get(op, 0)
        coll[op] = v2 + (n_groups - 2) * (v3 - v2)
    out["collective_bytes"] = coll
    # microbatch scan scales tokens linearly and probes ran the full batch at
    # microbatches=1, so no further correction is needed for train cells.
    return out


def _lower_raw(cfg, shape, mesh, fsdp, remat, microbatches):
    """Lower+compile one step; return raw cost numbers (no caching)."""
    from repro.models import attention as attn_mod
    with shard_ctx.use_mesh(mesh), attn_mod.unrolled_chunks():
        return _lower_raw_inner(cfg, shape, mesh, fsdp, remat, microbatches)


def _lower_raw_inner(cfg, shape, mesh, fsdp, remat, microbatches):
    sch = model_schema(cfg)
    params_abs = schema_mod.abstract(sch)
    p_shard = rules.param_shardings(sch, mesh, fsdp=fsdp)
    b_specs = specs_mod.batch_specs(cfg, shape)
    b_shard = specs_mod.batch_shardings(cfg, shape, mesh)
    from jax.sharding import NamedSharding, PartitionSpec as P
    repl = NamedSharding(mesh, P())
    placements_abs = None
    place_shard = None
    if cfg.moe_experts:
        placements_abs = jax.ShapeDtypeStruct(
            (cfg.n_layers, cfg.moe_experts), jnp.int32)
        place_shard = repl
    if shape.kind == "train":
        # loss_chunks=1 + unrolled layer scan: no loops left for XLA's
        # loop-blind cost model, so totals are exact for architectures
        # without inner time scans (see roofline.py).
        step = make_train_step(cfg, OptConfig(), microbatches=microbatches,
                               remat=remat, loss_chunks=1, unroll=True)
        opt_abs = abstract_opt_state(params_abs)
        opt_shard = {"m": p_shard, "v": p_shard, "master": p_shard,
                     "step": repl}
        jitted = jax.jit(step,
                         in_shardings=(p_shard, opt_shard, b_shard,
                                       place_shard),
                         donate_argnums=(0, 1))
        lowered = jitted.lower(params_abs, opt_abs, b_specs, placements_abs)
    else:
        step = make_serve_step(cfg, unroll=True)
        csch = cache_schema(cfg, shape.global_batch,
                            specs_mod.cache_max_seq(cfg, shape))
        cache_abs = schema_mod.abstract(csch)
        c_shard = rules.cache_shardings(csch, mesh, shape.global_batch)
        index = shape.seq_len - 1 if shape.kind == "decode" else 0
        if cfg.moe_experts:
            jitted = jax.jit(lambda p, c, b, pl: step(p, c, b, index, pl),
                             in_shardings=(p_shard, c_shard, b_shard,
                                           place_shard),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_abs, cache_abs, b_specs,
                                   placements_abs)
        else:
            jitted = jax.jit(lambda p, c, b: step(p, c, b, index, None),
                             in_shardings=(p_shard, c_shard, b_shard),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_abs, cache_abs, b_specs)
    compiled = lowered.compile()
    cost = _cost_dict(compiled)
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            "collective_bytes": collective_bytes(compiled.as_text())}


def abstract_opt_state(param_abstract):
    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)
    return {
        "m": jax.tree.map(f32, param_abstract),
        "v": jax.tree.map(f32, param_abstract),
        "master": jax.tree.map(f32, param_abstract),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               microbatches: int | None = None, fsdp: bool = True,
               remat: bool = True, extra_tag: str = ""):
    """Lower + compile one (arch x shape x mesh) cell; return the report."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = specs_mod.cell_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "skipped": True, "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    sch = model_schema(cfg)
    params_abs = schema_mod.abstract(sch)
    p_shard = rules.param_shardings(sch, mesh, fsdp=fsdp)
    b_specs = specs_mod.batch_specs(cfg, shape)
    b_shard = specs_mod.batch_shardings(cfg, shape, mesh)
    from jax.sharding import NamedSharding, PartitionSpec as P
    repl = NamedSharding(mesh, P())

    placements_abs = None
    place_shard = None
    if cfg.moe_experts:
        placements_abs = jax.ShapeDtypeStruct(
            (cfg.n_layers, cfg.moe_experts), jnp.int32)
        place_shard = repl

    shard_ctx_cm = shard_ctx.use_mesh(mesh)
    shard_ctx_cm.__enter__()
    if shape.kind == "train":
        mb = microbatches or TRAIN_MICROBATCHES.get(shape_name, 1)
        step = make_train_step(cfg, OptConfig(), microbatches=mb,
                               remat=remat)
        opt_abs = abstract_opt_state(params_abs)
        opt_shard = {"m": p_shard, "v": p_shard, "master": p_shard,
                     "step": repl}
        jitted = jax.jit(
            step,
            in_shardings=(p_shard, opt_shard, b_shard, place_shard),
            donate_argnums=(0, 1))
        lowered = jitted.lower(params_abs, opt_abs, b_specs, placements_abs)
    else:
        step = make_serve_step(cfg)
        csch = cache_schema(cfg, shape.global_batch,
                            specs_mod.cache_max_seq(cfg, shape))
        cache_abs = schema_mod.abstract(csch)
        c_shard = rules.cache_shardings(csch, mesh, shape.global_batch)
        index = shape.seq_len - 1 if shape.kind == "decode" else 0
        if cfg.moe_experts:
            jitted = jax.jit(
                lambda p, c, b, pl: step(p, c, b, index, pl),
                in_shardings=(p_shard, c_shard, b_shard, place_shard),
                donate_argnums=(1,))
            lowered = jitted.lower(params_abs, cache_abs, b_specs,
                                   placements_abs)
        else:
            jitted = jax.jit(
                lambda p, c, b: step(p, c, b, index, None),
                in_shardings=(p_shard, c_shard, b_shard),
                donate_argnums=(1,))
            lowered = jitted.lower(params_abs, cache_abs, b_specs)
    shard_ctx_cm.__exit__(None, None, None)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = _cost_dict(compiled)
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    # depth-corrected totals (scan bodies are undercounted by cost_analysis).
    # Multi-pod cells skip probes: §Roofline is single-pod by design and the
    # multi-pod pass exists to prove the pod axis shards + report memory.
    if multi_pod:
        probe = {"skipped": "multi-pod: no probes"}
    else:
        try:
            probe = _probe_costs(cfg, shape, mesh, fsdp, remat)
        except Exception as e:  # noqa: BLE001
            probe = {"error": f"{type(e).__name__}: {e}"}

    report = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "devices": int(mesh.devices.size),
        "skipped": False,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": coll,
        "corrected": probe,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0),
        },
        "params": schema_mod.count_params(sch),
        "replicated_fallbacks": rules.replication_report(sch, mesh, fsdp),
        "microbatches": microbatches or TRAIN_MICROBATCHES.get(shape_name, 1)
        if shape.kind == "train" else None,
        "tag": extra_tag,
    }
    return report


def cell_list():
    cells = []
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape_name in SHAPES:
            ok, _ = specs_mod.cell_applicable(cfg, SHAPES[shape_name])
            if ok:
                cells.append((arch, shape_name))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    if args.all:
        cells = cell_list()
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch.replace("-", "_"), args.shape)]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    for arch, shape_name in cells:
        for multi in meshes:
            tag = f"{args.tag}_" if args.tag else ""
            name = f"{tag}{arch}__{shape_name}__{'multi' if multi else 'single'}.json"
            out = RESULTS_DIR / name
            if out.exists() and not args.force:
                print(f"[skip-cached] {name}")
                continue
            print(f"[dryrun] {arch} x {shape_name} x "
                  f"{'multi' if multi else 'single'} ...", flush=True)
            try:
                rep = lower_cell(arch, shape_name, multi,
                                 microbatches=args.microbatches,
                                 fsdp=not args.no_fsdp,
                                 remat=not args.no_remat,
                                 extra_tag=args.tag)
            except Exception as e:  # noqa: BLE001 - report and continue
                rep = {"arch": arch, "shape": shape_name,
                       "mesh": "multi" if multi else "single",
                       "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-4000:]}
            out.write_text(json.dumps(rep, indent=1))
            status = ("ERROR " + rep["error"][:120]) if "error" in rep else \
                ("skipped: " + rep["reason"] if rep.get("skipped") else
                 f"ok flops={rep['flops']:.3e} compile={rep['compile_s']}s")
            print(f"  -> {status}", flush=True)


if __name__ == "__main__":
    main()
