"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell, three per-step time lower bounds on TPU v5e:

  compute    = HLO_FLOPs_per_device / peak_FLOPs            (197 TF bf16)
  memory     = HLO_bytes_per_device / HBM_bw                (819 GB/s)
  collective = link_bytes_per_device / link_bw              (~50 GB/s/link)

Sources: ``compiled.cost_analysis()`` per-device flops/bytes, depth-corrected
by the 2-vs-3-group probes (XLA's cost model counts a while body once; the
dry-run unrolls probes so the correction is exact for architectures without
inner time scans). Collective bytes are parsed from the optimized HLO;
all-reduce is charged 2x (ring reduce-scatter + all-gather), others 1x.
SSM inner-scan residuals (jamba's chunk carry, xlstm's time scan) are added
analytically below — they are elementwise-dominated and small vs the GEMMs.

MODEL_FLOPS = 6*N*D (train, dense), 6*N_active*D (MoE), 2*N_active*tokens
(decode fwd-only); the ratio MODEL_FLOPS / HLO_FLOPs exposes remat/redundancy.
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict, Optional

from repro.configs import get_config
from repro.models import model_schema, schema as schema_mod
from repro.models.config import SHAPES

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

RESULTS_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def active_params(cfg) -> int:
    """Parameters touched per token (MoE counts top-k experts only)."""
    total = schema_mod.count_params(model_schema(cfg))
    if not cfg.moe_experts:
        return total
    period = cfg.pattern_period
    n_groups = cfg.n_layers // period
    from repro.models.moe import moe_schema
    per_layer_moe = schema_mod.count_params(moe_schema(cfg)) // 1
    n_moe_layers = sum(cfg.layer_is_moe(j) for j in range(period)) * n_groups
    moe_total = per_layer_moe * n_moe_layers
    expert_part = moe_total * (1 - 1 / cfg.moe_experts * 0)  # router negligible
    dense = total - moe_total
    active_moe = moe_total * cfg.moe_topk / cfg.moe_experts
    return int(dense + active_moe)


def model_flops(cfg, shape) -> float:
    """Analytic 'useful' FLOPs for the whole step (global, all devices)."""
    n_act = active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_act * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_act * tokens
    # decode: one token per sequence + attention over the cache
    tokens = shape.global_batch * 1
    attn = 0.0
    n_attn = sum(1 for j in range(cfg.pattern_period)
                 if cfg.layer_pattern[j] == "attn")
    n_attn *= cfg.n_layers // cfg.pattern_period
    attn = 4.0 * n_attn * cfg.n_heads * cfg.hd * shape.seq_len * tokens
    return 2.0 * n_act * tokens + attn


def ssm_inner_residual_flops(cfg, shape, devices: int) -> float:
    """Per-device FLOPs of inner time loops the probes cannot see."""
    if shape.kind == "decode":
        return 0.0
    tokens = shape.global_batch * shape.seq_len
    period = cfg.pattern_period
    n_groups = cfg.n_layers // period
    total = 0.0
    fb = 3.0 if shape.kind == "train" else 1.0   # fwd+bwd multiplier
    for j in range(period):
        kind = cfg.layer_pattern[j]
        if kind == "mamba":
            di = cfg.mamba_expand * cfg.d_model
            # h_all = a*h + b per element over the carry path
            total += 3.0 * tokens * di * cfg.mamba_d_state * n_groups * fb
        elif kind == "slstm":
            d = cfg.d_model
            # recurrent matmul R (D x 4D) each step + gates
            total += (2.0 * tokens * d * 4 * d + 30.0 * tokens * d) \
                * n_groups * fb
        elif kind == "mlstm":
            d = cfg.d_model
            h = cfg.n_heads
            dh = d // h
            chunk = 128
            # intra-chunk (c x c) attention-like terms
            total += (4.0 * tokens * chunk * d) * n_groups * fb
    return total / devices


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops_device: float
    useful_ratio: float
    bound_frac: float           # compute_s / max(all three) = roofline fraction
    peak_hbm_gb: float
    note: str = ""


def analyze(report: dict) -> Optional[Roofline]:
    if report.get("skipped") or "error" in report:
        return None
    cfg = get_config(report["arch"])
    shape = SHAPES[report["shape"]]
    dev = report["devices"]
    corr = report.get("corrected", {})
    if "flops" not in corr:
        return None
    flops_dev = corr["flops"] + ssm_inner_residual_flops(cfg, shape, dev)
    bytes_dev = corr["bytes_accessed"]
    coll = corr.get("collective_bytes", {})
    link_bytes = sum(v * (2.0 if op == "all-reduce" else 1.0)
                     for op, v in coll.items())
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = link_bytes / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    mf_dev = mf / dev
    useful = mf_dev / flops_dev if flops_dev else 0.0
    bound = compute_s / max(max(terms.values()), 1e-30)
    mem = report.get("memory", {})
    peak = (mem.get("argument_bytes", 0) + mem.get("temp_bytes", 0)) / 1e9
    return Roofline(
        arch=report["arch"], shape=report["shape"], mesh=report["mesh"],
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops=mf, hlo_flops_device=flops_dev,
        useful_ratio=useful, bound_frac=bound, peak_hbm_gb=peak)


def load_all(tag: str = "") -> Dict[str, dict]:
    """Load artifacts; tag='' returns ONLY untagged baselines."""
    out = {}
    prefix = f"{tag}_" if tag else ""
    for f in sorted(RESULTS_DIR.glob(f"{prefix}*.json")):
        rep = json.loads(f.read_text())
        if (rep.get("tag") or "") != tag:
            continue
        out[f.stem] = rep
    return out


def table(mesh: str = "single", tag: str = "") -> str:
    rows = []
    hdr = (f"| arch | shape | compute s | memory s | collective s | dominant "
           f"| MODEL/HLO | roofline frac | HBM GB/dev |")
    sep = "|" + "---|" * 9
    rows.append(hdr)
    rows.append(sep)
    for name, rep in load_all(tag).items():
        if rep.get("mesh") != mesh:
            continue
        r = analyze(rep)
        if r is None:
            status = rep.get("reason", rep.get("error", "?"))[:40]
            rows.append(f"| {rep.get('arch')} | {rep.get('shape')} | - | - | "
                        f"- | {status} | - | - | - |")
            continue
        rows.append(
            f"| {r.arch} | {r.shape} | {r.compute_s:.3e} | {r.memory_s:.3e} |"
            f" {r.collective_s:.3e} | **{r.dominant}** | {r.useful_ratio:.2f}"
            f" | {r.bound_frac:.2f} | {r.peak_hbm_gb:.1f} |")
    return "\n".join(rows)


if __name__ == "__main__":
    import sys
    mesh = sys.argv[1] if len(sys.argv) > 1 else "single"
    print(table(mesh))
