"""Per-kernel validation: shape/dtype sweeps, allclose vs the ref.py oracles,
and host/device hash agreement. Kernels run in interpret mode on CPU."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.balancer.hashing import Hash32, fmix32 as np_fmix32
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.key_stats import key_stats
from repro.kernels.routing_lookup import routing_lookup


# ------------------------------------------------------------- key_stats --
@pytest.mark.parametrize("n,num_keys,block_n,block_k", [
    (64, 16, 32, 16),
    (1000, 257, 128, 128),
    (4096, 1024, 512, 512),
    (777, 33, 256, 64),          # ragged: padding on both axes
])
def test_key_stats_matches_oracle(n, num_keys, block_n, block_k):
    rng = np.random.default_rng(0)
    keys = jnp.asarray(rng.integers(0, num_keys, size=n), jnp.int32)
    costs = jnp.asarray(rng.uniform(0.1, 3.0, size=n), jnp.float32)
    freq, cost = key_stats(keys, costs, num_keys, block_n=block_n,
                           block_k=block_k, interpret=True)
    freq_ref, cost_ref = ref.key_stats(keys, costs, num_keys)
    np.testing.assert_allclose(freq, freq_ref, rtol=1e-6)
    np.testing.assert_allclose(cost, cost_ref, rtol=1e-5)


def test_key_stats_ignores_padding_keys():
    keys = jnp.asarray([0, 1, -1, 1, -1], jnp.int32)
    costs = jnp.ones((5,), jnp.float32)
    freq, cost = key_stats(keys, costs, 4, block_n=8, block_k=8,
                           interpret=True)
    np.testing.assert_allclose(freq, [1, 2, 0, 0])
    np.testing.assert_allclose(cost, [1, 2, 0, 0])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_key_stats_dtypes(dtype):
    rng = np.random.default_rng(1)
    keys = jnp.asarray(rng.integers(0, 100, size=500), jnp.int32)
    costs = jnp.asarray(rng.uniform(0.5, 2.0, size=500)).astype(dtype)
    freq, cost = key_stats(keys, costs, 100, interpret=True)
    freq_ref, cost_ref = ref.key_stats(keys, costs, 100)
    np.testing.assert_allclose(freq, freq_ref, rtol=1e-6)
    np.testing.assert_allclose(cost, cost_ref, rtol=2e-2)


# -------------------------------------------------------- routing_lookup --
@pytest.mark.parametrize("n,a,n_dest", [
    (100, 16, 4), (2048, 128, 16), (5000, 1000, 256), (63, 1, 2),
])
def test_routing_lookup_matches_oracle(n, a, n_dest):
    rng = np.random.default_rng(2)
    keys = jnp.asarray(rng.integers(0, 10_000, size=n), jnp.int32)
    tkeys = np.full((a,), -1, np.int32)
    tdests = np.zeros((a,), np.int32)
    n_real = max(1, a // 2)
    tkeys[:n_real] = rng.choice(10_000, size=n_real, replace=False)
    tdests[:n_real] = rng.integers(0, n_dest, size=n_real)
    out = routing_lookup(keys, jnp.asarray(tkeys), jnp.asarray(tdests),
                         n_dest, seed=7, interpret=True)
    exp = ref.routing_lookup(keys, jnp.asarray(tkeys), jnp.asarray(tdests),
                             n_dest, seed=7)
    np.testing.assert_array_equal(out, exp)


def test_routing_hash_matches_host_planner():
    """Device fmix32 == jnp oracle == numpy Hash32: the controller's plan and
    the data plane's routing agree bit-for-bit."""
    keys = np.arange(50_000, dtype=np.int64)
    host = Hash32(13, seed=5)(keys)
    empty_k = jnp.full((8,), -1, jnp.int32)
    empty_d = jnp.zeros((8,), jnp.int32)
    dev = routing_lookup(jnp.asarray(keys, jnp.int32), empty_k, empty_d, 13,
                         seed=5, interpret=True)
    oracle = ref.routing_lookup(jnp.asarray(keys, jnp.int32), empty_k,
                                empty_d, 13, seed=5)
    np.testing.assert_array_equal(np.asarray(dev), host)
    np.testing.assert_array_equal(np.asarray(oracle), host)
    # raw mix agreement too
    np.testing.assert_array_equal(
        np.asarray(ref.fmix32(jnp.asarray(keys, jnp.int32).astype(jnp.uint32), 5)),
        np_fmix32(keys.astype(np.uint32), 5))


def test_routing_table_override_wins():
    keys = jnp.asarray([3, 4, 5], jnp.int32)
    tkeys = jnp.asarray([4, -1, -1, -1], jnp.int32)
    tdests = jnp.asarray([9, 0, 0, 0], jnp.int32)
    out = routing_lookup(keys, tkeys, tdests, 10, interpret=True)
    assert int(out[1]) == 9


# ------------------------------------------------- int32 dtype contract --
# The kernels' integer lanes are 32-bit. A wider key array would be
# truncated inside the trace, so ids >= 2**31 would silently alias other
# keys — the public wrappers must REJECT wide dtypes loudly instead.

_EMPTY_K = jnp.full((8,), -1, jnp.int32)
_EMPTY_D = jnp.zeros((8,), jnp.int32)


@pytest.mark.parametrize("bad", [jnp.int64, jnp.float32, jnp.uint32])
def test_routing_lookup_rejects_non_int32_keys(bad):
    with jax.experimental.enable_x64():
        keys = jnp.asarray([1, 2, 3]).astype(bad)
        with pytest.raises(TypeError, match="int32 keys"):
            routing_lookup(keys, _EMPTY_K, _EMPTY_D, 4, interpret=True)


def test_routing_lookup_rejects_non_int32_table():
    keys = jnp.asarray([1, 2, 3], jnp.int32)
    with pytest.raises(TypeError, match="int32 table_keys"):
        routing_lookup(keys, _EMPTY_K.astype(jnp.float32), _EMPTY_D, 4,
                       interpret=True)
    with pytest.raises(TypeError, match="int32 table_dests"):
        routing_lookup(keys, _EMPTY_K, _EMPTY_D.astype(jnp.int16), 4,
                       interpret=True)


@pytest.mark.parametrize("bad", [jnp.int64, jnp.float32, jnp.int16])
def test_key_stats_rejects_non_int32_keys(bad):
    with jax.experimental.enable_x64():
        keys = jnp.asarray([0, 1, 2]).astype(bad)
        with pytest.raises(TypeError, match="int32 keys"):
            key_stats(keys, jnp.ones((3,), jnp.float32), 4, interpret=True)


def _int32_edge_keys():
    """int32 boundary ids plus keys whose fmix32 hash lands >= 2**31 —
    the mix/modulo must stay unsigned end-to-end or those wrap negative."""
    edge = np.array([0, 1, 2**31 - 2, 2**31 - 1], dtype=np.int32)
    probe = np.arange(4096, dtype=np.int64)
    high = probe[np_fmix32(probe.astype(np.uint32), 5) >= 2**31]
    assert high.size > 0                         # the regression is exercised
    return np.concatenate([edge.astype(np.int64), high[:64]])


def test_routing_boundary_keys_match_host_interpret():
    keys = _int32_edge_keys()
    host = Hash32(13, seed=5)(keys)
    dev = routing_lookup(jnp.asarray(keys, jnp.int32), _EMPTY_K, _EMPTY_D,
                         13, seed=5, interpret=True)
    np.testing.assert_array_equal(np.asarray(dev), host)


def test_key_stats_boundary_ids_interpret():
    """num_keys stays modest (dense histogram) but the VALUES flowing through
    the match matrix include int32 max ids — they must count as misses, not
    alias into the [0, num_keys) range after any internal widening."""
    keys = jnp.asarray([0, 3, 2**31 - 1, 3, 2**31 - 2], jnp.int32)
    freq, cost = key_stats(keys, jnp.ones((5,), jnp.float32), 4,
                           block_n=8, block_k=8, interpret=True)
    np.testing.assert_allclose(freq, [1, 0, 0, 2])
    np.testing.assert_allclose(cost, [1, 0, 0, 2])


@pytest.mark.skipif(jax.default_backend() != "tpu",
                    reason="compiled Mosaic path needs a real TPU backend")
def test_routing_boundary_keys_match_host_compiled():
    keys = _int32_edge_keys()
    host = Hash32(13, seed=5)(keys)
    dev = routing_lookup(jnp.asarray(keys, jnp.int32), _EMPTY_K, _EMPTY_D,
                         13, seed=5, interpret=False)
    np.testing.assert_array_equal(np.asarray(dev), host)


@pytest.mark.skipif(jax.default_backend() != "tpu",
                    reason="compiled Mosaic path needs a real TPU backend")
def test_key_stats_boundary_ids_compiled():
    keys = jnp.asarray([0, 3, 2**31 - 1, 3, 2**31 - 2], jnp.int32)
    freq, cost = key_stats(keys, jnp.ones((5,), jnp.float32), 4,
                           interpret=False)
    np.testing.assert_allclose(freq[:4], [1, 0, 0, 2])
    np.testing.assert_allclose(cost[:4], [1, 0, 0, 2])


# ------------------------------------------------------- flash attention --
@pytest.mark.parametrize("b,hq,hkv,t,s,d", [
    (1, 2, 2, 64, 64, 32),        # MHA square
    (2, 8, 2, 128, 128, 64),      # GQA 4:1
    (1, 4, 1, 96, 96, 32),        # MQA, ragged T
    (1, 4, 4, 1, 256, 64),        # decode: one query vs KV cache
    (1, 8, 2, 17, 250, 32),       # chunked decode, ragged both axes
])
def test_flash_attention_matches_oracle(b, hq, hkv, t, s, d):
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.standard_normal((b, hq, t, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
    out = flash_attention(q, k, v, causal=True, block_t=64, block_s=64,
                          interpret=True)
    exp = ref.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5)


@pytest.mark.parametrize("window", [16, 64, 300])
def test_flash_attention_sliding_window(window):
    rng = np.random.default_rng(4)
    b, hq, hkv, t, d = 1, 4, 2, 192, 32
    q = jnp.asarray(rng.standard_normal((b, hq, t, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, hkv, t, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, hkv, t, d)), jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window, block_t=64,
                          block_s=64, interpret=True)
    exp = ref.flash_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 2e-5),
                                        (jnp.bfloat16, 2e-2)])
def test_flash_attention_dtypes(dtype, atol):
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.standard_normal((1, 4, 128, 64))).astype(dtype)
    k = jnp.asarray(rng.standard_normal((1, 2, 128, 64))).astype(dtype)
    v = jnp.asarray(rng.standard_normal((1, 2, 128, 64))).astype(dtype)
    out = flash_attention(q, k, v, block_t=64, block_s=64, interpret=True)
    exp = ref.flash_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=atol)
    assert out.dtype == dtype


def test_flash_attention_matches_plain_softmax_property():
    """Row-stochastic sanity: with v = identity basis the output rows are the
    attention probabilities and must sum to 1."""
    rng = np.random.default_rng(6)
    b, h, t, d = 1, 2, 64, 64
    q = jnp.asarray(rng.standard_normal((b, h, t, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, h, t, d)), jnp.float32)
    v = jnp.broadcast_to(jnp.eye(t, d, dtype=jnp.float32), (b, h, t, d))
    out = flash_attention(q, k, v, block_t=32, block_s=32, interpret=True)
    sums = np.asarray(out).sum(-1)
    np.testing.assert_allclose(sums, np.ones_like(sums), atol=1e-5)
