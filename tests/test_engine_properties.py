"""Hypothesis properties for the stream engine: exactness and single-ownership
under arbitrary skew/fluctuation/algorithm sequences."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")   # optional [test] extra
from hypothesis import given, settings, strategies as st

from repro.core import (Assignment, BalanceConfig, ModHash,
                        RebalanceController)
from repro.streams import KeyedStage, WordCount, WorkloadGen


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 2**31 - 1), st.floats(0.0, 1.5),
       st.floats(0.6, 1.3), st.sampled_from(["mixed", "mintable", "readj"]),
       st.sampled_from([0.0, 0.05, 0.3]))
def test_wordcount_exact_under_any_policy(seed, f, z, algorithm, theta):
    """For every (fluctuation, skew, algorithm, tolerance) combination, no
    tuple is lost or double-counted and every key's state has one owner."""
    gen = WorkloadGen(k=300, z=z, f=f, seed=seed, window=10)
    controller = RebalanceController(
        Assignment(ModHash(5, seed=seed % 11)),
        BalanceConfig(theta_max=theta, table_max=200, window=10),
        algorithm=algorithm)
    stage = KeyedStage(WordCount(), controller, window=10)
    sent = {}
    for i in range(4):
        if i:
            gen.interval(controller.assignment)
        keys = gen.draw_tuples(1200)
        for k in keys:
            sent[int(k)] = sent.get(int(k), 0) + 1
        stage.process_interval([(int(k), i) for k in keys])
    got = {}
    owners = {}
    for s_idx, store in enumerate(stage.stores):
        for k, ks in store.keys.items():
            assert k not in owners, "key state on two tasks"
            owners[k] = s_idx
            got[k] = sum(sl.payload["count"] for sl in ks.iter_window())
    assert got == sent


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(3, 9), st.integers(4, 10))
def test_scale_out_and_in_lossless(seed, n_start, n_end):
    """Arbitrary rescale (grow or shrink) preserves all window state."""
    gen = WorkloadGen(k=200, z=1.0, f=0.4, seed=seed, window=10)
    controller = RebalanceController(
        Assignment(ModHash(n_start, seed=1)),
        BalanceConfig(theta_max=0.1, table_max=150, window=10),
        algorithm="mixed")
    stage = KeyedStage(WordCount(), controller, window=10)
    sent = {}
    for i in range(3):
        if i:
            gen.interval(controller.assignment)
        keys = gen.draw_tuples(800)
        for k in keys:
            sent[int(k)] = sent.get(int(k), 0) + 1
        stage.process_interval([(int(k), i) for k in keys])
    stage.scale_to(n_end)
    assert len(stage.stores) == n_end
    got = {}
    for store in stage.stores:
        for k, ks in store.keys.items():
            got[k] = got.get(k, 0) + sum(sl.payload["count"]
                                         for sl in ks.iter_window())
    assert got == sent
    # post-rescale, every key is stored exactly where F routes it
    for s_idx, store in enumerate(stage.stores):
        for k in store.keys:
            d = int(controller.assignment.dest(np.asarray([k], np.int64))[0])
            assert d == s_idx
