"""Choice routers end-to-end through the engine (no hypothesis needed).

Property-based coverage of the papers' bounds lives in
``test_choice_router_properties.py``; this module keeps the engine-level
integration runnable without the optional [test] extras.
"""

import numpy as np
import pytest

from repro.streams import PartialWordCount, keyed_stage


def _zipf_keys(seed, z, n, domain):
    rng = np.random.default_rng(seed)
    return ((rng.zipf(z, size=n) - 1) % domain).astype(np.int64)


@pytest.mark.parametrize("algo", ["pkg", "potc", "wchoices"])
def test_router_end_to_end_vectorized(algo):
    stage = keyed_stage(PartialWordCount(), n_tasks=8, theta_max=0.08,
                        algorithm=algo, window=2)
    assert stage.state_backend == "columnar"     # auto never picks device
    rng = np.random.default_rng(5)
    for _ in range(4):
        keys = ((rng.zipf(1.3, size=2000) - 1) % 500).astype(np.int64)
        rep = stage.process_interval_arrays(keys)
        assert rep.tuples == 2000
        assert rep.migrated_bytes == 0.0 and rep.migration_stall == 0.0
        assert rep.throughput > 0
    assert stage.controller.algorithm_name == algo
    assert len(stage.controller.history) == 4
    assert not any(ev.triggered for ev in stage.controller.history)


@pytest.mark.parametrize("algo", ["pkg", "wchoices"])
def test_router_reference_path_parity(algo):
    """vectorized=False (per-tuple loop, object store) must produce the same
    reports: _dest_batch runs the router exactly once per interval on both
    paths, and fresh instances with equal (n_dest, seed) route identically."""
    fast = keyed_stage(PartialWordCount(), n_tasks=6, theta_max=0.08,
                       algorithm=algo, window=2, seed=11)
    slow = keyed_stage(PartialWordCount(), n_tasks=6, theta_max=0.08,
                       algorithm=algo, window=2, seed=11, vectorized=False)
    rng = np.random.default_rng(9)
    for _ in range(3):
        keys = ((rng.zipf(1.5, size=1200) - 1) % 300).astype(np.int64)
        rf = fast.process_interval_arrays(keys)
        rs = slow.process_interval_arrays(keys)
        assert rf.makespan == rs.makespan
        assert rf.theta == rs.theta
        assert np.array_equal(rf.task_loads, rs.task_loads)
    assert fast.emitted_sum == slow.emitted_sum
