"""Checkpointed recovery is *lossless*: chaos == oracle, bit for bit.

The tentpole property: run the same recorded traffic through (a) a fault-free
oracle stage and (b) a chaos stage where :class:`ChaosRunner` injects kills
(at both engine crash sites), dropped/duplicated deliveries and store stalls,
recovering each by restore-last-checkpoint + replay-buffered-intervals. The
resulting :class:`IntervalReport` streams — every modelled field plus the
per-task load vectors — must be **identical** on every state backend
(object/columnar/device/sharded), as must outputs and the emitted sum.
Recovery must not even perturb the *performance model*, because the replay
re-runs the same protocol decisions against the same restored controller
state.

Also covered here: checkpoint transparency (snapshotting every interval
changes nothing), the disk round-trip through :class:`CheckpointStore` into a
freshly constructed stage, sketch-mode controller state across restores,
whole-topology coordination, a Hypothesis property randomizing the fault
schedule, the autoscaling policy loop (convergence without oscillation on
drift/burst shapes + the migration-cost damper), the heartbeat stall
detector, the ``scale_to`` hardening satellites, and the pause/replay edge
where traffic ends mid-pause (the engine's buffered-flush path) on every
backend.
"""

import types

import numpy as np
import pytest

from repro.core import (Assignment, AutoscaleConfig, AutoscaleLoop,
                        AutoscalePolicy, BalanceConfig, HeartbeatMonitor,
                        ModHash, RebalanceController)
from repro.core.balancer.hashing import Hash32
from repro.streams import (ChaosRunner, CheckpointStore, DropDelivery,
                           DuplicateDelivery, FaultPlan, KeyedStage, KillTask,
                           PartialWordCount, StageSpec, StallTask, Topology,
                           WordCount, WorkloadGen, checkpoint_stage,
                           keyed_stage, restore_stage)

REPORT_FIELDS = ("interval", "tuples", "makespan", "migration_stall",
                 "throughput", "skewness", "theta", "migrated_bytes",
                 "table_size", "buffered")

BACKENDS = ["object", "columnar", "device", "sharded"]


def _guard(backend):
    if backend in ("device", "sharded"):
        pytest.importorskip("jax")


def make_stage(backend="object", n_tasks=6, window=3, theta_max=0.05,
               table_max=400, seed=0, vectorized=True, **kwargs):
    hash_cls = Hash32 if backend in ("device", "sharded") else ModHash
    controller = RebalanceController(
        Assignment(hash_cls(n_tasks, seed=seed)),
        BalanceConfig(theta_max=theta_max, table_max=table_max,
                      window=window),
        algorithm="mixed")
    return KeyedStage(WordCount(), controller, window=window,
                      vectorized=vectorized, state_backend=backend, **kwargs)


def make_trace(n_iv=10, n_tuples=600, k=800, seed=2, window=3):
    """Record a deterministic per-interval key trace once, then feed the
    *same* arrays to every stage under test — the oracle and the chaos run
    must see identical traffic for bit-identity to be meaningful."""
    gen = WorkloadGen(k=k, z=1.1, f=0.8, seed=seed, window=window)
    driver = make_stage("object", window=window)
    trace = []
    for i in range(n_iv):
        gen.interval(driver.controller.assignment, fluctuate=i > 0)
        keys = gen.draw_tuples(n_tuples)
        trace.append(keys)
        driver.process_interval_arrays(keys)
    return trace


@pytest.fixture(scope="module")
def trace():
    return make_trace()


def assert_reports_identical(got, want):
    assert len(got) == len(want)
    for rg, rw in zip(got, want):
        for field in REPORT_FIELDS:
            assert getattr(rg, field) == getattr(rw, field), \
                (rg.interval, field)
        assert np.array_equal(np.asarray(rg.task_loads),
                              np.asarray(rw.task_loads)), \
            (rg.interval, "task_loads")


def run_oracle(backend, trace):
    stage = make_stage(backend)
    for keys in trace:
        stage.process_interval_arrays(keys)
    return stage


# -- the recovery-lossless property (fixed instances, every backend) ----------

@pytest.mark.parametrize("backend", BACKENDS)
def test_kill_recovery_is_lossless(backend, trace):
    """Kills at BOTH crash sites — mid-interval (state half-mutated) and
    at delivery — restore + replay to the oracle's exact report stream."""
    _guard(backend)
    oracle = run_oracle(backend, trace)
    plan = FaultPlan([KillTask(interval=3, task=1, site="mid"),
                      KillTask(interval=5, task=0, site="deliver"),
                      KillTask(interval=7, task=2, site="mid")])
    stage = make_stage(backend)
    runner = ChaosRunner(stage, plan, checkpoint_every=2)
    for keys in trace:
        runner.process_interval(keys)
    assert [(e.interval, e.kind) for e in runner.events] == \
        [(3, "kill@mid"), (5, "kill@deliver"), (7, "kill@mid")]
    assert_reports_identical(stage.reports, oracle.reports)
    assert stage.emitted_sum == oracle.emitted_sum
    assert stage.outputs == oracle.outputs


@pytest.mark.parametrize("backend", ["object", "columnar"])
def test_delivery_faults_are_recovered(backend, trace):
    """Dropped (0x) and duplicated (2x) deliveries are detected by epoch
    mismatch and healed by restore + replay — exactly-once is recovered."""
    _guard(backend)
    oracle = run_oracle(backend, trace)
    plan = FaultPlan([DropDelivery(interval=4),
                      DuplicateDelivery(interval=7)])
    stage = make_stage(backend)
    runner = ChaosRunner(stage, plan, checkpoint_every=2)
    for keys in trace:
        runner.process_interval(keys)
    assert [(e.interval, e.kind) for e in runner.events] == \
        [(4, "drop"), (7, "duplicate")]
    assert_reports_identical(stage.reports, oracle.reports)
    assert stage.outputs == oracle.outputs


def test_stall_heals_under_retry_and_is_lossless(trace):
    oracle = run_oracle("columnar", trace)
    plan = FaultPlan([StallTask(interval=4, task=2, attempts=3)])
    stage = make_stage("columnar")
    runner = ChaosRunner(stage, plan, checkpoint_every=3)
    for keys in trace:
        runner.process_interval(keys)
    assert [e.kind for e in runner.events] == ["stall@deliver"]
    # the replay retried from the checkpoint until the stall burned off
    assert runner.events[0].replayed >= 1
    assert_reports_identical(stage.reports, oracle.reports)


def test_kill_before_first_cadence_checkpoint(trace):
    """Recovery works from the interval-0 baseline snapshot the runner takes
    at construction — a kill in interval 1 replays from a pristine stage."""
    oracle = run_oracle("object", trace)
    stage = make_stage("object")
    runner = ChaosRunner(stage, FaultPlan([KillTask(interval=1, site="mid")]),
                         checkpoint_every=4)
    for keys in trace:
        runner.process_interval(keys)
    assert_reports_identical(stage.reports, oracle.reports)


# -- checkpoint transparency + the disk round-trip ----------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_checkpointing_is_observationally_free(backend, trace):
    """Snapshotting after EVERY interval (the extract -> clone -> reinstall
    round-trip, plus controller serialization) must not change a thing."""
    _guard(backend)
    plain = run_oracle(backend, trace)
    stage = make_stage(backend)
    for keys in trace:
        stage.process_interval_arrays(keys)
        checkpoint_stage(stage)
    assert_reports_identical(stage.reports, plain.reports)
    assert stage.outputs == plain.outputs
    assert stage.emitted_sum == plain.emitted_sum


@pytest.mark.parametrize("backend", ["object", "columnar", "device"])
def test_restore_rewinds_and_replays_identically(backend, trace):
    """restore_stage is a true rewind: re-running the tail after a restore
    reproduces the exact reports the first run produced."""
    _guard(backend)
    stage = make_stage(backend)
    for keys in trace[:5]:
        stage.process_interval_arrays(keys)
    ckpt = checkpoint_stage(stage)
    for keys in trace[5:]:
        stage.process_interval_arrays(keys)
    first = list(stage.reports)
    restore_stage(stage, ckpt)
    assert stage._interval == 5
    for keys in trace[5:]:
        stage.process_interval_arrays(keys)
    assert_reports_identical(stage.reports, first)
    # and the same checkpoint restores twice (packs re-clone on install)
    restore_stage(stage, ckpt)
    for keys in trace[5:]:
        stage.process_interval_arrays(keys)
    assert_reports_identical(stage.reports, first)


def test_disk_roundtrip_into_fresh_stage(tmp_path, trace):
    """CheckpointStore -> fresh, never-run stage: continuing from disk is
    indistinguishable from never having crashed."""
    store = CheckpointStore(tmp_path / "ckpts")
    src = make_stage("object")
    for keys in trace[:6]:
        src.process_interval_arrays(keys)
    store.save(checkpoint_stage(src))
    for keys in trace[6:]:
        src.process_interval_arrays(keys)

    fresh = make_stage("object")
    ckpt = store.load_latest()
    assert ckpt.interval == 6 == store.latest_interval()
    restore_stage(fresh, ckpt)
    for keys in trace[6:]:
        fresh.process_interval_arrays(keys)
    assert_reports_identical(fresh.reports, src.reports)
    assert fresh.outputs == src.outputs


def test_checkpoint_store_manifest_and_retention(tmp_path):
    store = CheckpointStore(tmp_path, keep=2)
    assert store.load_latest() is None and store.latest_interval() is None
    stage = make_stage("object", n_tasks=3)
    trace = make_trace(n_iv=3, n_tuples=100, k=50, seed=9)
    for keys in trace:
        stage.process_interval_arrays(keys)
        store.save(checkpoint_stage(stage))
    snaps = sorted(p.name for p in tmp_path.glob("ckpt_*.pkl"))
    assert snaps == ["ckpt_00000002.pkl", "ckpt_00000003.pkl"]  # keep=2 pruned
    assert store.latest_interval() == 3
    assert store.load_latest().interval == 3


def test_restore_validates_backend_and_window(trace):
    stage = make_stage("object")
    stage.process_interval_arrays(trace[0])
    ckpt = checkpoint_stage(stage)
    other = make_stage("columnar")
    with pytest.raises(ValueError, match="state_backend"):
        restore_stage(other, ckpt)
    narrow = make_stage("object", window=2)
    with pytest.raises(ValueError, match="window"):
        restore_stage(narrow, ckpt)


def test_sketch_mode_controller_state_survives_recovery(trace):
    """In sketch stats mode the checkpoint must carry the CMS planes and the
    SpaceSaving head — the replanning after a restore runs on the restored
    sketch, so chaos == oracle still holds bit-for-bit."""
    def sketch_stage():
        controller = RebalanceController(
            Assignment(ModHash(6, seed=0)),
            BalanceConfig(theta_max=0.05, table_max=400, window=3),
            algorithm="mixed", stats_mode="sketch")
        return KeyedStage(WordCount(), controller, window=3,
                          state_backend="columnar")
    oracle = sketch_stage()
    for keys in trace:
        oracle.process_interval_arrays(keys)
    stage = sketch_stage()
    runner = ChaosRunner(stage, FaultPlan([KillTask(interval=4, site="mid"),
                                           DropDelivery(interval=8)]),
                         checkpoint_every=2)
    for keys in trace:
        runner.process_interval(keys)
    assert len(runner.events) == 2
    assert_reports_identical(stage.reports, oracle.reports)


# -- per-stage coordination across a topology ---------------------------------

def _two_stage_topology():
    return Topology([
        StageSpec("count", keyed_stage(WordCount(), 4, 0.05, table_max=300,
                                       window=2, seed=0)),
        StageSpec("rollup", keyed_stage(WordCount(), 3, 0.05, table_max=300,
                                        window=2, seed=1),
                  rekey=lambda k, v: k % 16),
    ])


def test_topology_checkpoint_restores_every_stage(trace):
    topo = _two_stage_topology()
    for keys in trace[:5]:
        topo.process_interval(keys)
    ckpt = topo.checkpoint()
    assert ckpt.interval == 5 and len(ckpt.stages) == 2
    for keys in trace[5:]:
        topo.process_interval(keys)
    first = [r.stage_reports for r in topo.reports]
    first_crit = [r.critical_path for r in topo.reports]

    topo.restore(ckpt)
    assert topo._interval == 5
    for keys in trace[5:]:
        topo.process_interval(keys)
    assert [r.critical_path for r in topo.reports] == first_crit
    for got, want in zip([r.stage_reports for r in topo.reports], first):
        for g_stage, w_stage in zip(got, want):
            for field in REPORT_FIELDS:
                assert getattr(g_stage, field) == getattr(w_stage, field)


def test_topology_restore_rejects_shape_mismatch(trace):
    topo = _two_stage_topology()
    topo.process_interval(trace[0])
    ckpt = topo.checkpoint()
    single = Topology([StageSpec("count",
                                 keyed_stage(WordCount(), 4, 0.05, window=2))])
    with pytest.raises(ValueError, match="stages"):
        single.restore(ckpt)


# -- randomized fault schedules (hypothesis) ----------------------------------

def test_random_fault_schedule_property():
    """Property: ANY (interval, site, cadence, delivery-fault) combination
    recovers losslessly on both host backends."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    short = make_trace(n_iv=6, n_tuples=300, k=300, seed=5)
    oracles = {b: run_oracle(b, short) for b in ("object", "columnar")}

    @settings(max_examples=12, deadline=None)
    @given(backend=st.sampled_from(["object", "columnar"]),
           kill_iv=st.integers(min_value=1, max_value=6),
           site=st.sampled_from(["deliver", "mid"]),
           cadence=st.integers(min_value=1, max_value=3),
           drop_iv=st.one_of(st.none(),
                             st.integers(min_value=1, max_value=6)))
    def prop(backend, kill_iv, site, cadence, drop_iv):
        faults = [KillTask(interval=kill_iv, task=0, site=site)]
        if drop_iv is not None and drop_iv != kill_iv:
            faults.append(DropDelivery(interval=drop_iv))
        stage = make_stage(backend)
        runner = ChaosRunner(stage, FaultPlan(faults),
                             checkpoint_every=cadence)
        for keys in short:
            runner.process_interval(keys)
        assert len(runner.events) == len(faults)
        assert_reports_identical(stage.reports, oracles[backend].reports)
        assert stage.outputs == oracles[backend].outputs

    prop()


# -- autoscaling policy loop --------------------------------------------------

def _drive_autoscale(loop, gen, tuple_counts):
    ns = []
    for i, count in enumerate(tuple_counts):
        gen.interval(loop.stage.controller.assignment, fluctuate=i > 0)
        loop.step(gen.draw_tuples(count))
        ns.append(loop.stage.n_tasks)
    return ns


def _assert_no_oscillation(decisions, min_gap=4):
    """A direction reversal is legitimate when the workload really changed
    (burst drains -> scale back in); it is thrash when it lands inside the
    hysteresis horizon (patience + cooldown) of the opposite action."""
    applied = [d for d in decisions if d.applied]
    for prev, cur in zip(applied, applied[1:]):
        if cur.reason != prev.reason:
            assert cur.interval - prev.interval >= min_gap, (prev, cur)


def test_autoscaler_converges_on_drift_without_oscillation():
    """The strategy-matrix drift shape (heavy fluctuation, f=2.5): the fleet
    grows to demand and then stays put — hysteresis + patience + the damper
    keep the decision stream short and non-reversing."""
    controller = RebalanceController(
        Assignment(ModHash(2, seed=0)),
        BalanceConfig(theta_max=0.2, table_max=400, window=2),
        algorithm="mixed")
    stage = KeyedStage(WordCount(), controller, window=2,
                       state_backend="columnar")
    gen = WorkloadGen(k=2000, z=1.1, f=2.5, seed=3, window=2)
    loop = AutoscaleLoop(stage, AutoscaleConfig(target_load=200.0,
                                                max_tasks=16),
                         monitor=HeartbeatMonitor())
    ns = _drive_autoscale(loop, gen, [900] * 25)
    applied = [d for d in loop.decisions if d.applied]
    assert applied, "steady overload must trigger at least one scale-out"
    assert all(d.reason == "scale-out" for d in applied)
    assert len(applied) <= 3
    _assert_no_oscillation(loop.decisions)
    # converged: the fleet stops moving once sized to demand
    assert len(set(ns[-5:])) == 1
    assert ns[-1] >= 4      # 900 load / 200 target, after damping


def test_autoscaler_burst_scales_out_then_in_without_thrash():
    """The burst shape: quiet -> hot burst -> quiet. One scale-out episode
    during the burst, one scale-in after it drains, and no ping-pong."""
    controller = RebalanceController(
        Assignment(ModHash(4, seed=1)),
        BalanceConfig(theta_max=0.2, table_max=400, window=2),
        algorithm="mixed")
    stage = KeyedStage(WordCount(), controller, window=2,
                       state_backend="columnar")
    gen = WorkloadGen(k=1000, z=1.0, f=0.5, seed=4, window=2)
    loop = AutoscaleLoop(stage, AutoscaleConfig(target_load=200.0,
                                                min_tasks=2, max_tasks=16))
    counts = [300] * 4 + [1600] * 8 + [300] * 10
    ns = _drive_autoscale(loop, gen, counts)
    applied = [d for d in loop.decisions if d.applied]
    assert any(d.reason == "scale-out" for d in applied)
    assert any(d.reason == "scale-in" for d in applied)
    _assert_no_oscillation(loop.decisions)
    assert max(ns) >= 6                 # sized up for the burst
    assert ns[-1] < max(ns)             # and back down after it
    assert len(set(ns[-4:])) == 1       # quiet tail: no further motion


def test_autoscale_damper_vetoes_unpayable_migration():
    """With near-zero migration bandwidth the predicted stall can never pay
    back: the decision is recorded but NOT applied, and the fleet holds."""
    controller = RebalanceController(
        Assignment(ModHash(2, seed=0)),
        BalanceConfig(theta_max=0.2, table_max=400, window=2),
        algorithm="mixed")
    stage = KeyedStage(WordCount(), controller, window=2,
                       state_backend="columnar", migration_bandwidth=1e-6)
    gen = WorkloadGen(k=2000, z=1.1, f=1.0, seed=3, window=2)
    loop = AutoscaleLoop(stage, AutoscaleConfig(target_load=200.0,
                                                max_tasks=16))
    _drive_autoscale(loop, gen, [900] * 8)
    assert loop.decisions, "the watermark breach must still arm proposals"
    assert all(not d.applied for d in loop.decisions)
    assert all(d.predicted_stall > 0 for d in loop.decisions)
    assert stage.n_tasks == 2


def test_autoscale_loop_rejects_router_strategies():
    controller = RebalanceController(
        Assignment(ModHash(4, seed=0)),
        BalanceConfig(theta_max=0.2, window=2), algorithm="pkg")
    stage = KeyedStage(PartialWordCount(), controller, window=2)
    with pytest.raises(ValueError, match="router"):
        AutoscaleLoop(stage, AutoscaleConfig(target_load=100.0))


def _report(interval, loads, tuples=None):
    loads = np.asarray(loads, dtype=np.float64)
    return types.SimpleNamespace(interval=interval, tuples=(
        int(loads.sum()) if tuples is None else tuples),
        task_loads=loads, makespan=float(loads.max()))


def test_heartbeat_monitor_flags_silent_tasks():
    mon = HeartbeatMonitor(patience=2)
    assert mon.observe(_report(1, [5, 5, 5])) == []
    assert mon.observe(_report(2, [5, 0, 5])) == []      # one silent interval
    assert mon.observe(_report(3, [5, 0, 5])) == [1]     # patience reached
    assert mon.observe(_report(4, [5, 0, 5])) == []      # flagged only once
    assert mon.flagged == {1}
    assert mon.observe(_report(5, [5, 4, 5])) == []      # heartbeat returns
    assert mon.flagged == set()
    # idle intervals carry no heartbeat signal at all
    assert mon.observe(_report(6, [0, 0, 0], tuples=0)) == []
    assert mon.flagged == set()


# -- scale_to hardening (satellites) ------------------------------------------

@pytest.mark.parametrize("bad", [0, -1, -7])
def test_scale_to_rejects_empty_fleet_before_any_mutation(bad, trace):
    stage = make_stage("object", n_tasks=4)
    stage.process_interval_arrays(trace[0])
    before = len(stage.stores)
    with pytest.raises(ValueError, match="n_tasks >= 1"):
        stage.scale_to(bad)
    assert len(stage.stores) == before and stage.n_tasks == 4


def test_scale_to_router_rejection_fires_before_store_growth(trace):
    """Regression pin: the router-strategy ValueError must fire BEFORE any
    new stores are appended — a half-grown fleet would leak stores."""
    controller = RebalanceController(
        Assignment(ModHash(4, seed=0)),
        BalanceConfig(theta_max=0.2, window=2), algorithm="pkg")
    stage = KeyedStage(PartialWordCount(), controller, window=2)
    stage.process_interval_arrays(trace[0])
    before = len(stage.stores)
    with pytest.raises(ValueError):
        stage.scale_to(8)
    assert len(stage.stores) == before and stage.n_tasks == 4


# -- pause/replay when traffic ends mid-pause (satellite) ---------------------

@pytest.mark.parametrize("backend", ["object", "columnar", "device"])
def test_traffic_ending_mid_pause_flushes_buffer_identically(backend):
    """With migration_batches >= micro_batches the pause window covers the
    whole interval, so every Delta-key tuple is still buffered when traffic
    ends — the end-of-interval flush path must replay them, identically on
    the reference loop and every vectorized backend."""
    _guard(backend)

    def build(vectorized, state_backend):
        controller = RebalanceController(
            Assignment(Hash32(5, seed=1)),
            BalanceConfig(theta_max=0.01, table_max=300, window=3),
            algorithm="mixed")
        return KeyedStage(WordCount(), controller, window=3,
                          vectorized=vectorized, state_backend=state_backend,
                          micro_batches=4, migration_batches=4)

    trace = make_trace(n_iv=6, n_tuples=400, k=300, seed=11)
    ref = build(False, "object")
    for keys in trace:
        ref.process_interval_arrays(keys)
    # the scenario only proves the flush path if tuples were actually
    # buffered to the end of some interval
    assert any(r.buffered > 0 for r in ref.reports)

    vec = build(True, backend)
    for keys in trace:
        vec.process_interval_arrays(keys)
    assert_reports_identical(vec.reports, ref.reports)
    assert vec.outputs == ref.outputs
    assert vec.emitted_sum == ref.emitted_sum
