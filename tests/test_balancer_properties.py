"""Property-based tests (hypothesis) for the balancer's invariants."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")   # optional [test] extra
from hypothesis import given, settings, strategies as st

from repro.core.balancer import (Assignment, BalanceConfig, KeyStats, ModHash,
                                 metrics, mintable, minmig, mixed, mixed_bf,
                                 reference_mintable, reference_minmig,
                                 simple, readj)
from repro.streams.generator import WorkloadGen


def make_stats(rng, k, heavy_tail=1.2):
    cost = rng.pareto(heavy_tail, size=k) + 1.0
    mem = rng.pareto(heavy_tail, size=k) + 1.0
    return KeyStats(keys=np.arange(k, dtype=np.int64), cost=cost, mem=mem)


@st.composite
def instances(draw):
    seed = draw(st.integers(0, 2**31 - 1))
    k = draw(st.integers(8, 400))
    n_dest = draw(st.integers(2, 12))
    theta = draw(st.sampled_from([0.0, 0.02, 0.08, 0.3]))
    rng = np.random.default_rng(seed)
    stats = make_stats(rng, k)
    assignment = Assignment(ModHash(n_dest, seed=seed % 7))
    cfg = BalanceConfig(theta_max=theta, table_max=max(4, k // 4))
    return stats, assignment, cfg


@settings(max_examples=60, deadline=None)
@given(instances())
def test_result_consistency(inst):
    """Loads, theta, migration cost and table reported by every algorithm are
    internally consistent with the returned assignment function."""
    stats, assignment, cfg = inst
    for algo in (mintable, minmig, mixed):
        res = algo(stats, assignment, cfg)
        # reported loads match recomputation through the new assignment
        re_loads = metrics.loads(stats, res.assignment)
        np.testing.assert_allclose(re_loads, res.loads, rtol=1e-9)
        assert res.theta == pytest.approx(metrics.theta(re_loads))
        # migration cost matches Eq. 2 recomputed from Delta(F, F')
        assert res.migration_cost == pytest.approx(
            metrics.migration_cost(stats, assignment, res.assignment))
        assert set(res.moved_keys.tolist()) == set(
            metrics.moved_keys(stats, assignment, res.assignment).tolist())
        # every table entry deviates from the hash destination
        for key, d in res.assignment.table.items():
            h = int(assignment.hash_router(np.array([key]))[0])
            assert d != h
        assert res.table_size == len(res.assignment.table)


@settings(max_examples=40, deadline=None)
@given(instances())
def test_balance_reached_or_infeasible(inst):
    """LLFD-based algorithms reach theta <= theta_max whenever any single key
    is lighter than the remaining headroom (standard feasibility proxy)."""
    stats, assignment, cfg = inst
    mean = stats.cost.sum() / assignment.n_dest
    res = mixed(stats, assignment, cfg)
    if float(stats.cost.max()) <= cfg.theta_max * mean + mean:
        # max key fits under L_max entirely on an empty instance -> feasible
        # region is non-trivial; the heuristic must get within the Theorem-1
        # style additive bound of the best case.
        bound = max(cfg.theta_max, (1.0 / 3.0) * (1.0 - 1.0 / assignment.n_dest))
        assert res.theta <= bound + 1e-6 or res.feasible_balance


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 8), st.integers(2, 30))
def test_theorem1_bound_with_perfect_assignment(seed, n_dest, per_dest):
    """Theorem 1: when a perfect assignment exists and c(k1) < mean load,
    LLFD's imbalance is <= 1/3 * (1 - 1/N_D)."""
    rng = np.random.default_rng(seed)
    target = 100.0
    costs = []
    for _ in range(n_dest):  # construct keys as compositions of equal sums
        cuts = np.sort(rng.uniform(0, target, size=per_dest - 1))
        parts = np.diff(np.concatenate([[0.0], cuts, [target]]))
        costs.extend(parts.tolist())
    costs = np.asarray(costs) + 1e-9
    stats = KeyStats(keys=np.arange(len(costs), dtype=np.int64),
                     cost=costs, mem=np.ones_like(costs))
    mean = costs.sum() / n_dest
    if costs.max() >= mean:
        return  # theorem precondition violated
    assignment = Assignment(ModHash(n_dest, seed=seed % 13))
    bound = (1.0 / 3.0) * (1.0 - 1.0 / n_dest)
    cfg = BalanceConfig(theta_max=bound, table_max=10**9)
    res_simple = simple(stats, assignment, cfg)
    assert res_simple.theta <= bound + 1e-9
    res = mintable(stats, assignment, cfg)   # LLFD with full clean
    assert res.theta <= bound + 1e-9


@settings(max_examples=25, deadline=None)
@given(instances())
def test_theorem2_mixed_not_worse_than_simple(inst):
    """Theorem 2/4: Mixed's balance status is not worse than Simple's."""
    stats, assignment, cfg = inst
    th_mixed = mixed(stats, assignment, cfg).theta
    th_simple = simple(stats, assignment, cfg).theta
    # 'Not worse' is judged on constraint satisfaction: Mixed stops at
    # theta_max on purpose (it is *minimizing migration* subject to balance),
    # so raw-theta comparison vs Simple's full rebuild is meaningless unless
    # Simple satisfies the constraint and Mixed does not.
    if th_simple <= cfg.theta_max:
        assert th_mixed <= cfg.theta_max + 0.02
    else:
        assert th_mixed <= th_simple + 0.02


@settings(max_examples=20, deadline=None)
@given(instances())
def test_mixed_first_trial_is_minmig(inst):
    """Mixed starts at n=0 which is exactly MinMig; if that trial already
    satisfies both constraints, the plans coincide."""
    stats, assignment, cfg = inst
    res_mm = minmig(stats, assignment, cfg)
    res_mx = mixed(stats, assignment, cfg)
    if res_mx.meta.get("trials", 1) == 1:
        assert res_mx.migration_cost == pytest.approx(res_mm.migration_cost)
        assert res_mx.table_size == res_mm.table_size


def test_heuristic_spectrum_statistical():
    """Paper Sec. III-C / Figs. 8-10: across skewed workloads, MinMig migrates
    less state than MinTable, and MinTable ends with smaller tables. The claim
    is statistical (it is about the heuristics' tendencies), so we average
    over seeds on the paper's synthetic workload."""
    mig_mm, mig_mt, tab_mm, tab_mt = [], [], [], []
    for seed in range(8):
        gen = WorkloadGen(k=800, z=0.85, f=0.8, seed=seed, window=2)
        assignment = Assignment(ModHash(12, seed=seed))
        cfg = BalanceConfig(theta_max=0.08, table_max=400)
        stats0 = gen.interval(assignment, fluctuate=False)
        warm = mixed(stats0, assignment, cfg)          # build up a table first
        stats1 = gen.interval(warm.assignment)
        res_mm = minmig(stats1, warm.assignment, cfg)
        res_mt = mintable(stats1, warm.assignment, cfg)
        mig_mm.append(res_mm.migration_cost)
        mig_mt.append(res_mt.migration_cost)
        tab_mm.append(res_mm.table_size)
        tab_mt.append(res_mt.table_size)
    assert np.mean(mig_mm) <= np.mean(mig_mt)
    assert np.mean(tab_mt) <= np.mean(tab_mm)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_rebalance_loop_converges_under_fluctuation(seed):
    """Driving intervals through the controller-style loop keeps theta bounded
    (the paper's core end-to-end claim on synthetic data)."""
    gen = WorkloadGen(k=500, z=0.9, f=0.5, seed=seed, window=2)
    assignment = Assignment(ModHash(8, seed=1))
    cfg = BalanceConfig(theta_max=0.08, table_max=200)
    for i, stats in enumerate(gen.stream(assignment, 6)):
        res = mixed(stats, assignment, cfg)
        if res.feasible_balance:
            assert res.theta <= cfg.theta_max + 1e-9
        assignment = res.assignment


def test_mixed_bf_not_worse_than_mixed():
    rng = np.random.default_rng(7)
    stats = make_stats(rng, 300)
    assignment = Assignment(ModHash(6, seed=3))
    cfg = BalanceConfig(theta_max=0.05, table_max=40)
    # warm up: create a non-empty table first
    res0 = mixed(stats, assignment, cfg)
    stats2 = make_stats(np.random.default_rng(8), 300)
    res_bf = mixed_bf(stats2, res0.assignment, cfg)
    res_mx = mixed(stats2, res0.assignment, cfg)
    assert (not res_bf.feasible_table, res_bf.migration_cost) <= \
           (not res_mx.feasible_table, res_mx.migration_cost + 1e-9)


@st.composite
def oversized_instances(draw):
    """One key heavier than every other key combined + a uniform light tail.

    This is the regime outside the paper's Theorem 1/2 preconditions
    (c(k1) >= mean load), constructed so the light tail always fits: the
    oversized key must take LLFD's relaxed-(iii) fallback, and under an
    exhausted event budget every key takes it.
    """
    seed = draw(st.integers(0, 2**31 - 1))
    k = draw(st.integers(20, 200))
    n_dest = draw(st.integers(2, 8))
    factor = draw(st.floats(1.5, 4.0))
    rng = np.random.default_rng(seed)
    light = rng.uniform(0.5, 1.5, size=k)
    cost = np.concatenate([light, [factor * light.sum()]])
    mem = rng.uniform(0.5, 1.5, size=k + 1)
    stats = KeyStats(keys=np.arange(k + 1, dtype=np.int64), cost=cost, mem=mem)
    assignment = Assignment(ModHash(n_dest, seed=seed % 11))
    return stats, assignment


def _assert_fallback_invariants(stats, assignment, res, cfg):
    mean = float(stats.cost.sum()) / assignment.n_dest
    l_max = cfg.l_max(mean)
    c_max = float(stats.cost.max())
    # no key lost: every key resolves to a live destination and the reported
    # loads are exactly the recomputed per-destination cost sums
    dests = res.assignment.dest(stats.keys)
    assert int(dests.min()) >= 0 and int(dests.max()) < assignment.n_dest
    np.testing.assert_array_equal(
        metrics.loads_for(stats, dests, assignment.n_dest), res.loads)
    assert float(res.loads.sum()) == pytest.approx(float(stats.cost.sum()))
    # the oversized destination carries no more than the oversized key
    # demands; every other destination respects L_max
    assert float(res.loads.max()) <= max(l_max, c_max) * (1 + 1e-9)


@settings(max_examples=40, deadline=None)
@given(oversized_instances())
def test_llfd_oversized_key_fallback(inst):
    """The relaxed-(iii) fallback terminates, loses no key, and bounds every
    load by max(L_max, c_max); the array planner matches the scalar oracle
    on this path too."""
    stats, assignment = inst
    cfg = BalanceConfig(theta_max=0.08, table_max=10**9)
    for algo in (mintable, minmig, mixed):
        res = algo(stats, assignment, cfg)
        _assert_fallback_invariants(stats, assignment, res, cfg)
    assert mintable(stats, assignment, cfg).same_plan(
        reference_mintable(stats, assignment, cfg))


@settings(max_examples=30, deadline=None)
@given(oversized_instances(), st.integers(0, 3))
def test_llfd_event_budget_exhaustion(inst, budget):
    """With the event budget exhausted every candidate takes the fallback:
    the cascade still terminates (each shed key is strictly lighter than the
    key displacing it), no key is lost, and loads stay bounded."""
    stats, assignment = inst
    cfg = BalanceConfig(theta_max=0.08, table_max=10**9,
                        max_llfd_events=budget)
    for algo in (mintable, minmig, mixed):
        res = algo(stats, assignment, cfg)
        _assert_fallback_invariants(stats, assignment, res, cfg)
    assert minmig(stats, assignment, cfg).same_plan(
        reference_minmig(stats, assignment, cfg))


def test_readj_slower_than_mixed_on_many_keys():
    """The complexity claim behind Fig. 12: Readj's pairwise search scales
    worse than Mixed's heuristic on skewed key sets."""
    gen = WorkloadGen(k=3000, z=1.0, f=0.0, seed=0)
    assignment = Assignment(ModHash(10, seed=0))
    stats = gen.interval(assignment, fluctuate=False)
    cfg = BalanceConfig(theta_max=0.08, table_max=1000)
    res_mx = mixed(stats, assignment, cfg)
    res_rj = readj(stats, assignment, cfg, sigma=0.001)
    assert res_mx.plan_time_s < res_rj.plan_time_s * 5  # mixed never blows up
    assert res_mx.theta <= max(res_rj.theta, cfg.theta_max) + 1e-9
