"""Sharded (multi-device) state backend == dict state backend, observationally.

``state_backend='sharded'`` block-shards the dense device ring across a JAX
mesh and realizes the paper's mixed routing as a masked ``all_to_all``
inside ONE jitted ``shard_map`` step per interval. That is a pure placement
change: under the same streams, rebalances, window>1 eviction and mid-run
rescales it must produce the bit-identical :class:`IntervalReport` stream,
the same post-migration ``key_location`` map, and the same outputs/emit
streams as the object-store oracle — the Hypothesis property drives
randomized workloads through both backends in lockstep, mirroring
``tests/test_engine_device.py``.

The suite adapts to the available device count: the default tier-1 run has
one jax CPU device (a 1-shard mesh — the collectives still execute), while
the dedicated CI leg runs under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the all_to_all
crosses 8 real device boundaries. A cross-shard-count test additionally
pins that the shard count itself is observationally invisible (including a
block size that does NOT divide the domain).

The retrace test pins the compile-once contract: one trace per mode's step
across intervals and rebalances (the dense dest table is data, not shape),
and a route refresh recompile only when ``n_dest`` changes (scale_to).
"""

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core import Assignment, BalanceConfig, ModHash, RebalanceController
from repro.core.balancer.hashing import Hash32
from repro.streams import (KeyedStage, MergeCounts, Operator, PartialWordCount,
                           WindowedSelfJoin, WordCount, WorkloadGen)

N_SHARDS = min(8, jax.device_count())

REPORT_FIELDS = ("interval", "tuples", "makespan", "migration_stall",
                 "throughput", "skewness", "theta", "migrated_bytes",
                 "table_size", "buffered")


def make_stage(op, backend, n_tasks=5, window=3, theta_max=0.05,
               table_max=300, seed=1, n_shards=N_SHARDS, **kwargs):
    controller = RebalanceController(
        Assignment(Hash32(n_tasks, seed=seed)),
        BalanceConfig(theta_max=theta_max, table_max=table_max,
                      window=window),
        algorithm="mixed")
    if backend != "sharded":
        n_shards = None
    return KeyedStage(op, controller, window=window, vectorized=True,
                      state_backend=backend, n_shards=n_shards, **kwargs)


def assert_stages_identical(shd, obj):
    assert len(shd.reports) == len(obj.reports)
    for rc, ro in zip(shd.reports, obj.reports):
        for field in REPORT_FIELDS:
            assert getattr(rc, field) == getattr(ro, field), field
        np.testing.assert_array_equal(rc.task_loads, ro.task_loads)
    assert shd.outputs == obj.outputs
    assert shd.emitted_sum == obj.emitted_sum
    assert shd.total_state_keys() == obj.total_state_keys()
    # identical post-migration ownership: every held key lives on the same
    # task under both backends (and exactly one task each)
    all_keys = set()
    for store in obj.stores:
        all_keys.update(store.keys)
    for k in all_keys:
        loc_s, loc_o = shd.key_location(k), obj.key_location(k)
        assert loc_s == loc_o, k
        assert len(loc_o) == 1, k


# -- the property: randomized workloads, rebalances, eviction, rescale --------

def _check_property(seed, z, f, window, theta, op_kind, scale_step):
    """Identical IntervalReport streams, emit streams and post-migration
    key_location maps over randomized skewed/fluctuating workloads with
    rebalances, window>1 eviction, and scale_to mid-run."""
    def op():
        return (WordCount() if op_kind == "wordcount"
                else WindowedSelfJoin(probe_cost=1.0 / 64))

    gens = [WorkloadGen(k=400, z=z, f=f, seed=seed, window=window)
            for _ in range(2)]
    stages = [make_stage(op(), b, window=window, theta_max=theta,
                         table_max=250, seed=seed % 13)
              for b in ("sharded", "object")]
    for i in range(5):
        keys = emits = None
        for gen, stage in zip(gens, stages):
            if i:
                gen.interval(stage.controller.assignment)
            drawn = gen.draw_tuples(1000).astype(np.int64)
            if keys is None:
                keys = drawn
            else:
                assert np.array_equal(drawn, keys), "streams diverged"
            _, ek, ev = stage.process_interval_emits(drawn,
                                                     np.full(1000, i))
            if emits is None:
                emits = (ek, ev)
            else:
                np.testing.assert_array_equal(ek, emits[0])
                np.testing.assert_array_equal(ev, emits[1])
        if scale_step is not None and i == 2:
            for stage in stages:
                stage.scale_to(scale_step)
            assert stages[0]._migrated_bytes_pending == \
                stages[1]._migrated_bytes_pending
    assert_stages_identical(*stages)


@pytest.mark.parametrize("seed,z,f,window,theta,op_kind,scale_step", [
    (2, 1.1, 0.8, 3, 0.0, "wordcount", None),
    (11, 0.9, 1.0, 4, 0.03, "selfjoin", 7),
    (23, 1.2, 0.3, 2, 0.0, "wordcount", 3),
], ids=["wordcount_rebalance", "selfjoin_scale_out", "wordcount_scale_in"])
def test_sharded_equals_object_store_fixed(seed, z, f, window, theta,
                                           op_kind, scale_step):
    """Deterministic instances of the property — run even without the
    optional hypothesis extra (bare envs, see ci.yml's bare-collect job)."""
    _check_property(seed, z, f, window, theta, op_kind, scale_step)


try:                                    # optional [test] extra
    from hypothesis import given, settings, strategies as st
except ImportError:                     # pragma: no cover - bare env
    pass
else:
    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           z=st.floats(0.6, 1.3),
           f=st.floats(0.0, 1.2),
           window=st.integers(2, 4),
           theta=st.sampled_from([0.0, 0.03, 0.2]),
           op_kind=st.sampled_from(["wordcount", "selfjoin"]),
           scale_step=st.sampled_from([None, 3, 7]))
    def test_sharded_equals_object_store_property(seed, z, f, window, theta,
                                                  op_kind, scale_step):
        _check_property(seed, z, f, window, theta, op_kind, scale_step)


def test_partial_wordcount_sharded_matches_object():
    gens = [WorkloadGen(k=350, z=1.0, f=0.6, seed=17, window=2)
            for _ in range(2)]
    stages = [make_stage(PartialWordCount(), b, window=2)
              for b in ("sharded", "object")]
    for i in range(4):
        for gen, stage in zip(gens, stages):
            if i:
                gen.interval(stage.controller.assignment)
            drawn = gen.draw_tuples(900).astype(np.int64)
            stage.process_interval_arrays(drawn, np.full(900, i))
    assert_stages_identical(*stages)


def test_merge_counts_sharded_matches_object():
    """max-mode folding (MergeCounts): the raw tuples travel the mesh
    through the masked all_to_all and fold by scatter-max on the owner."""
    rng = np.random.default_rng(3)
    stages = [make_stage(MergeCounts(), b, window=2)
              for b in ("sharded", "object")]
    for _ in range(4):
        keys = rng.integers(0, 150, size=1200).astype(np.int64)
        vals = rng.integers(1, 40, size=1200)
        for stage in stages:
            stage.process_interval_arrays(keys, vals)
    assert_stages_identical(*stages)


def test_shard_count_is_observationally_invisible():
    """1-shard vs N-shard meshes produce identical results — including a
    shard count whose block size does NOT divide the (power-of-two) dense
    domain, so the dead padding rows in the last block are exercised."""
    counts = sorted({1, min(3, jax.device_count()), N_SHARDS})
    gens = [WorkloadGen(k=600, z=1.05, f=0.7, seed=9, window=3)
            for _ in counts]
    stages = [make_stage(WordCount(), "sharded", n_shards=s, seed=4)
              for s in counts]
    for i in range(5):
        for gen, stage in zip(gens, stages):
            if i:
                gen.interval(stage.controller.assignment)
            drawn = gen.draw_tuples(1500).astype(np.int64)
            stage.process_interval_emits(drawn, np.full(1500, i))
        if i == 2:
            for stage in stages:
                stage.scale_to(8)
    for other in stages[1:]:
        assert_stages_identical(other, stages[0])


def test_sharded_with_pallas_substrate_matches_object():
    """substrate='pallas' routes the host paths through the kernel; the
    sharded route refresh stays on the jnp twin (accepted + documented),
    and parity must still be exact."""
    gens = [WorkloadGen(k=300, z=1.0, f=0.5, seed=5, window=3)
            for _ in range(2)]
    stages = [make_stage(WordCount(), "sharded", substrate="pallas"),
              make_stage(WordCount(), "object")]
    for i in range(4):
        for gen, stage in zip(gens, stages):
            if i:
                gen.interval(stage.controller.assignment)
            drawn = gen.draw_tuples(500).astype(np.int64)
            stage.process_interval_arrays(drawn, np.full(500, i))
    assert_stages_identical(*stages)


# -- compile-once: the sharded step must not retrace across intervals --------

def test_no_retrace_sharded():
    """The shard_map step traces once per mode and is reused for every
    subsequent interval — rebalances swap the (data, not shape) replicated
    table and relabel host mirrors, so they must not retrace; the sharded
    step carries no n_tasks static at all, so even ``scale_to`` leaves it
    alone. The per-shard route refresh recompiles exactly once per
    ``n_dest`` change (rescale)."""
    from repro.streams import sharded as sh_mod

    # the sharded jit wrappers are per-fleet (not module-level), so a fresh
    # stage always contributes exactly its own traces to the counters
    base = dict(sh_mod.TRACE_COUNTS)
    stage = make_stage(WordCount(), "sharded", n_tasks=6, window=5,
                       theta_max=0.03, seed=99)
    gen = WorkloadGen(k=400, z=1.1, f=0.8, seed=3, window=5)
    for i in range(6):
        if i:
            gen.interval(stage.controller.assignment)
        stage.process_interval_arrays(gen.draw_tuples(1000).astype(np.int64),
                                      np.full(1000, i))
    # at least one rebalance actually happened, so the no-retrace claim is
    # exercised against a moving assignment, not a static one
    assert stage.controller.assignment.table_size > 0
    d6 = {k: sh_mod.TRACE_COUNTS[k] - base[k] for k in base}
    assert d6["interval_step"] == 1, d6
    assert d6["route_dense"] == 1, d6

    stage.scale_to(9)
    for i in range(6, 10):
        gen.interval(stage.controller.assignment)
        stage.process_interval_arrays(gen.draw_tuples(1000).astype(np.int64),
                                      np.full(1000, i))
    d10 = {k: sh_mod.TRACE_COUNTS[k] - base[k] for k in base}
    assert d10["interval_step"] == 1, d10
    assert d10["route_dense"] == 2, d10


# -- backend selection + validation ------------------------------------------

def _hash32_controller(n_tasks=4, seed=0):
    return RebalanceController(Assignment(Hash32(n_tasks, seed=seed)),
                               BalanceConfig())


def test_sharded_backend_selection_rules():
    class CustomOp(Operator):
        def process(self, store, interval, key, value):
            return [], 1.0

    # explicit request works and reports its name
    stage = make_stage(WordCount(), "sharded")
    assert stage.state_backend == "sharded"
    assert stage.backend._fleet.n_shards == N_SHARDS
    # sharded inherits every device requirement, with its own name in the
    # errors
    with pytest.raises(ValueError, match="vectorized"):
        KeyedStage(WordCount(), _hash32_controller(), vectorized=False,
                   state_backend="sharded")
    with pytest.raises(ValueError, match="device closed forms"):
        KeyedStage(CustomOp(), _hash32_controller(), state_backend="sharded")
    with pytest.raises(ValueError, match="Hash32"):
        KeyedStage(WordCount(),
                   RebalanceController(Assignment(ModHash(4, seed=0)),
                                       BalanceConfig()),
                   state_backend="sharded")
    # explicit-only: auto never lands on sharded (device/columnar/object
    # cover auto; the shard count is a launcher decision)
    assert KeyedStage(WordCount(),
                      _hash32_controller()).state_backend != "sharded"
    # shard counts beyond the local device fleet fail loudly
    with pytest.raises(ValueError, match="n_shards"):
        KeyedStage(WordCount(), _hash32_controller(),
                   state_backend="sharded",
                   n_shards=jax.device_count() + 1)


def test_sharded_rejects_out_of_domain_keys():
    stage = make_stage(WordCount(), "sharded", device_domain_max=1 << 12)
    with pytest.raises(ValueError, match="non-negative"):
        stage.process_interval_arrays(np.array([3, -1], dtype=np.int64),
                                      np.zeros(2))
    with pytest.raises(ValueError, match="device_domain_max"):
        stage.process_interval_arrays(np.array([1 << 12], dtype=np.int64),
                                      np.zeros(1))
    # in-range keys still work after the rejections (no partial mutation of
    # the interval counter would leave the ring clock skewed)
    stage.process_interval_arrays(np.array([5, 9], dtype=np.int64),
                                  np.zeros(2))
    assert stage.total_state_keys() == 2


def test_sharded_max_mode_rejects_out_of_int32_values():
    stage = make_stage(MergeCounts(), "sharded")
    with pytest.raises(ValueError, match="int32"):
        stage.process_interval_arrays(np.array([1], dtype=np.int64),
                                      np.array([1 << 40]))


def test_sharded_empty_intervals_and_eviction():
    """n==0 intervals still advance the ring clock and expire columns."""
    stages = [make_stage(WordCount(), b, window=2)
              for b in ("sharded", "object")]
    for stage in stages:
        stage.process_interval_arrays(np.array([1, 2, 3], dtype=np.int64),
                                      np.zeros(3))
        for _ in range(3):                       # idle intervals: state ages out
            stage.process_interval_arrays(np.zeros(0, dtype=np.int64),
                                          np.zeros(0))
    assert_stages_identical(*stages)
    assert stages[0].total_state_keys() == 0
