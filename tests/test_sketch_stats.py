"""Sketch-mode controller stats: deterministic unit + integration tests.

Property-based coverage (never-underestimate, SpaceSaving error bounds,
head-key exactness under random streams) lives in
``tests/test_sketch_properties.py`` behind the optional hypothesis extra;
this file is the always-run tier: worked examples with hand-checked
numbers, exact-vs-sketch controller parity when the tracker capacity
covers the key domain, and the engine round-trip (SKETCH_PENDING handoff,
backend parity, rescale) in sketch mode.
"""

import numpy as np
import pytest

from repro.core import Assignment, BalanceConfig, RebalanceController
from repro.core.balancer import (CountMinSketch, KeyStats, ModHash,
                                 SketchConfig, SketchStats,
                                 SpaceSavingTracker, WChoices, metrics)
from repro.streams.generator import WorkloadGen
from repro.streams.operators import WordCount
from repro.streams.topology import keyed_stage


# ---------------------------------------------------------------------------
# CountMinSketch
# ---------------------------------------------------------------------------

def test_cms_exact_on_sparse_stream():
    cms = CountMinSketch(1024, 4, seed=7, channels=("cost", "mem"))
    keys = np.array([1, 2, 3, 1, 1, 2], dtype=np.int64)
    cms.update(keys, cost=np.ones(6), mem=np.full(6, 2.0))
    got = cms.query(np.array([1, 2, 3], dtype=np.int64), "cost")
    # 3 keys in 1024 columns x 4 rows: no key collides in every row
    np.testing.assert_allclose(got, [3.0, 2.0, 1.0])
    np.testing.assert_allclose(
        cms.query(np.array([1, 2, 3], dtype=np.int64), "mem"),
        [6.0, 4.0, 2.0])


def test_cms_never_underestimates_under_collisions():
    # Tiny width forces collisions; estimates must stay >= true counts.
    cms = CountMinSketch(16, 3, seed=0)
    rng = np.random.default_rng(4)
    keys = rng.integers(0, 200, size=5000).astype(np.int64)
    cms.update(keys, cost=np.ones(keys.size))
    uk, true = np.unique(keys, return_counts=True)
    est = cms.query(uk, "cost")
    assert np.all(est >= true - 1e-9)


def test_cms_reset_and_guards():
    cms = CountMinSketch(64, 2)
    cms.update(np.array([5], dtype=np.int64), cost=np.array([2.0]))
    cms.reset()
    assert cms.query(np.array([5], dtype=np.int64), "cost")[0] == 0.0
    assert cms.query(np.zeros(0, dtype=np.int64)).size == 0
    with pytest.raises(KeyError):
        cms.update(np.array([5], dtype=np.int64), bogus=np.array([1.0]))
    assert cms.nbytes == 64 * 2 * 8  # one float64 plane


# ---------------------------------------------------------------------------
# SpaceSavingTracker
# ---------------------------------------------------------------------------

def test_tracker_worked_example():
    # capacity 2, stream 10:10, 20:3, 30:1 -> truncate subtracts the 3rd
    # largest counter (1): keys {10: 9, 20: 2}, offset 1.
    tr = SpaceSavingTracker(2)
    tr.update(np.array([10, 10, 20, 30], dtype=np.int64),
              np.array([5.0, 5.0, 3.0, 1.0]),
              cost=np.array([5.0, 5.0, 3.0, 1.0]))
    np.testing.assert_array_equal(tr.keys, [10, 20])
    np.testing.assert_allclose(tr.counts, [9.0, 2.0])
    assert tr.offset == 1.0 and tr.total == 14.0
    # estimates are upper bounds: 10 -> 10 (true 10), 20 -> 3 (true 3),
    # missing 30 -> offset 1 (true 1)
    np.testing.assert_allclose(
        tr.estimate(np.array([10, 20, 30], dtype=np.int64)), [10.0, 3.0, 1.0])
    # both survivors were inserted before any truncation -> exact sides
    assert tr.exact_mask.all()
    np.testing.assert_allclose(tr.side("cost"), [10.0, 3.0])


def test_tracker_captures_every_heavy_hitter():
    rng = np.random.default_rng(0)
    keys = rng.zipf(1.3, size=50_000).astype(np.int64)
    cap = 64
    tr = SpaceSavingTracker(cap)
    for lo in range(0, keys.size, 7919):     # chunked, as the engine feeds it
        chunk = keys[lo:lo + 7919]
        tr.update(chunk, np.ones(chunk.size))
    uk, true = np.unique(keys, return_counts=True)
    assert tr.offset <= tr.total / (cap + 1) + 1e-9
    est = tr.estimate(uk)
    assert np.all(est >= true - 1e-9)                  # upper bound
    assert np.all(est - true <= tr.offset + 1e-9)       # error <= offset
    heavy = uk[true > tr.total / cap]
    assert np.isin(heavy, tr.keys).all()               # no hitter missed


def test_tracker_zero_weight_keys_do_not_insert():
    tr = SpaceSavingTracker(4)
    tr.update(np.array([1, 2], dtype=np.int64), np.array([5.0, 3.0]))
    # zero-weight (held-state) fold: key 9 must not enter, but key 1's mem
    # side counter must still accumulate
    tr.update(np.array([1, 9], dtype=np.int64), np.zeros(2),
              mem=np.array([7.0, 7.0]))
    np.testing.assert_array_equal(tr.keys, [1, 2])
    assert tr.side("mem")[0] == 7.0


def test_tracker_validates_capacity():
    with pytest.raises(ValueError):
        SpaceSavingTracker(0)


# ---------------------------------------------------------------------------
# SketchStats adapter
# ---------------------------------------------------------------------------

def _zipf_batch(rng, n, k):
    return (rng.zipf(1.4, size=n) % k).astype(np.int64)


def test_snapshot_head_includes_table_keys_and_exact_base():
    rng = np.random.default_rng(3)
    assignment = Assignment(ModHash(6, seed=1))
    assignment.table = {999_999: 2, 123_456: 4}   # quiet keys pinned in F
    ss = SketchStats(SketchConfig(width=1 << 12, depth=4, capacity=64),
                     assignment.n_dest, seed=0)
    keys = _zipf_batch(rng, 30_000, 5_000)
    ss.update(keys, assignment.dest(keys), np.ones(keys.size),
              mem=np.ones(keys.size))
    snap = ss.snapshot(assignment)
    # table keys always appear in the head, even when never ingested
    assert np.isin([999_999, 123_456], snap.keys).all()
    assert snap.base_loads is not None and (snap.base_loads >= 0.0).all()
    # exact per-dest totals: head loads + base reproduce true theta
    true_loads = np.bincount(assignment.dest(keys),
                             minlength=assignment.n_dest).astype(float)
    folded = metrics.loads_for(snap, assignment.dest(snap.keys),
                               assignment.n_dest)
    # head estimation error cancels in base = total - head (up to clipping,
    # which cannot trigger here: every head key was actually ingested)
    np.testing.assert_allclose(folded, true_loads)


def test_snapshot_head_side_counters_exact_when_capacity_covers_domain():
    rng = np.random.default_rng(8)
    assignment = Assignment(ModHash(4, seed=0))
    k = 300
    ss = SketchStats(SketchConfig(width=1 << 12, depth=4, capacity=k),
                     assignment.n_dest)
    keys = _zipf_batch(rng, 20_000, k)
    cost = rng.integers(1, 5, size=keys.size).astype(np.float64)
    mem = np.ones(keys.size)
    for lo in range(0, keys.size, 3001):
        sl = slice(lo, lo + 3001)
        ss.update(keys[sl], assignment.dest(keys[sl]), cost[sl], mem=mem[sl])
    snap = ss.snapshot(assignment)
    uk, inv = np.unique(keys, return_inverse=True)
    np.testing.assert_array_equal(snap.keys, uk)
    np.testing.assert_array_equal(snap.cost, np.bincount(inv, weights=cost))
    np.testing.assert_array_equal(snap.mem, np.bincount(inv, weights=mem))
    np.testing.assert_array_equal(snap.base_loads,
                                  np.zeros(assignment.n_dest))


def test_end_interval_resets_everything():
    assignment = Assignment(ModHash(3, seed=0))
    ss = SketchStats(SketchConfig(capacity=8), assignment.n_dest)
    keys = np.arange(5, dtype=np.int64)
    ss.update(keys, assignment.dest(keys), np.ones(5))
    ss.end_interval()
    snap = ss.snapshot(assignment)
    assert snap.keys.size == 0
    np.testing.assert_array_equal(snap.base_loads, np.zeros(3))
    # bounded memory regardless of traffic
    assert ss.nbytes < 16 << 20


# ---------------------------------------------------------------------------
# Controller integration
# ---------------------------------------------------------------------------

def _agg(keys):
    uk, inv = np.unique(keys, return_inverse=True)
    return uk, np.bincount(inv).astype(np.float64)


def test_sketch_controller_matches_exact_when_capacity_covers_domain():
    gen = WorkloadGen(k=400, z=1.4, f=1.0, seed=2)
    cfg = BalanceConfig(theta_max=0.05, table_max=2_000, window=1)
    sk = SketchConfig(width=1 << 14, depth=4, capacity=4096)
    ctrl_e = RebalanceController(Assignment(ModHash(8, seed=3)), cfg,
                                 algorithm="mixed")
    ctrl_s = RebalanceController(Assignment(ModHash(8, seed=3)), cfg,
                                 algorithm="mixed", stats_mode="sketch",
                                 sketch=sk)
    for stats in gen.stream(ctrl_e.assignment, 3):
        ev_e = ctrl_e.observe(stats.keys, stats.cost, stats.mem,
                              freq=stats.freq, force=True)
        ev_s = ctrl_s.observe(stats.keys, stats.cost, stats.mem,
                              freq=stats.freq, force=True)
        assert ev_e.triggered == ev_s.triggered
        assert dict(ctrl_e.assignment.table) == dict(ctrl_s.assignment.table)
        assert ev_e.result.theta == pytest.approx(ev_s.result.theta)


def test_sketch_mode_streaming_ingest_equals_one_shot():
    # many small un-aggregated ingests per interval == one big observe
    rng = np.random.default_rng(5)
    raw = (rng.zipf(1.3, size=12_000) % 300).astype(np.int64)
    cfg = BalanceConfig(theta_max=0.05, table_max=1_000, window=1)
    sk = SketchConfig(width=1 << 14, depth=4, capacity=1024)

    def build():
        return RebalanceController(Assignment(ModHash(6, seed=1)), cfg,
                                   algorithm="mixed", stats_mode="sketch",
                                   sketch=sk)

    a, b = build(), build()
    keys, cost = _agg(raw)
    a.observe(keys, cost, cost.copy(), force=True)
    for lo in range(0, raw.size, 999):    # un-aggregated chunked feed
        chunk = raw[lo:lo + 999]
        b.ingest(chunk, np.ones(chunk.size), mem=np.ones(chunk.size))
    b.on_interval(None, force=True)
    assert dict(a.assignment.table) == dict(b.assignment.table)


def test_sketch_mode_guards():
    cfg = BalanceConfig(theta_max=0.1, table_max=100, window=1)
    exact = RebalanceController(Assignment(ModHash(4)), cfg)
    with pytest.raises(ValueError):
        exact.ingest(np.array([1], dtype=np.int64), np.array([1.0]))
    with pytest.raises(ValueError):
        exact.on_interval(None)
    with pytest.raises(ValueError):
        RebalanceController(Assignment(ModHash(4)), cfg,
                            sketch=SketchConfig())
    with pytest.raises(ValueError):
        RebalanceController(Assignment(ModHash(4)), cfg, stats_mode="bogus")
    sk = RebalanceController(Assignment(ModHash(4)), cfg,
                             stats_mode="sketch")
    assert sk.sketch is not None
    assert sk.stats_mode == "sketch"


def test_sketch_controller_tracks_last_stats():
    cfg = BalanceConfig(theta_max=0.1, table_max=100, window=1)
    ctrl = RebalanceController(Assignment(ModHash(4)), cfg,
                               stats_mode="sketch")
    keys = np.arange(50, dtype=np.int64)
    ctrl.observe(keys, np.ones(50), np.ones(50), force=True)
    assert ctrl.last_stats is not None
    assert ctrl.last_stats.keys.size == 50
    assert ctrl.last_stats.base_loads is not None


# ---------------------------------------------------------------------------
# Engine round-trip (SKETCH_PENDING handoff)
# ---------------------------------------------------------------------------

def _run_stage(state_backend, stats_mode, *, n_intervals=6, seed=11):
    rng = np.random.default_rng(seed)
    st = keyed_stage(WordCount(), 6, 0.3, table_max=500, window=2, seed=5,
                     state_backend=state_backend, stats_mode=stats_mode)
    for _ in range(n_intervals):
        keys = (rng.zipf(1.3, size=4_000) % 300).astype(np.int64)
        st.process_interval_emits(keys, None)
    return st


def test_engine_sketch_mode_backend_parity():
    obj = _run_stage("object", "sketch")
    col = _run_stage("columnar", "sketch")
    assert (obj.controller.triggered_intervals()
            == col.controller.triggered_intervals())
    assert (dict(obj.controller.assignment.table)
            == dict(col.controller.assignment.table))
    # state fully conserved across rebalances in sketch mode
    assert obj.total_state_keys() == col.total_state_keys() == 300
    assert col.last_stats is not None and col.last_stats.keys.size > 0


def test_engine_sketch_matches_exact_with_covering_capacity():
    # K=300 distinct keys < default capacity 4096: sketch-mode engine run
    # must produce the exact-mode rebalance decisions bit for bit.
    sk = _run_stage("columnar", "sketch")
    ex = _run_stage("columnar", "exact")
    assert (sk.controller.triggered_intervals()
            == ex.controller.triggered_intervals())
    assert (dict(sk.controller.assignment.table)
            == dict(ex.controller.assignment.table))


def test_engine_sketch_mode_rescale_conserves_state():
    st = _run_stage("columnar", "sketch", n_intervals=3)
    before = st.total_state_keys()
    st.scale_to(9)
    assert st.total_state_keys() == before
    rng = np.random.default_rng(77)
    keys = (rng.zipf(1.3, size=4_000) % 300).astype(np.int64)
    rep, _, _ = st.process_interval_emits(keys, None)
    assert st.controller.assignment.n_dest == 9
    assert rep.makespan > 0


# ---------------------------------------------------------------------------
# W-Choices through the shared tracker
# ---------------------------------------------------------------------------

def test_wchoices_head_matches_threshold_set_when_capacity_covers():
    rng = np.random.default_rng(9)
    keys = (rng.zipf(1.5, size=40_000) % 1_000).astype(np.int64)
    uk, freq = _agg(keys)
    stats = KeyStats(keys=uk, cost=freq, mem=np.ones(uk.size), freq=freq)
    router = WChoices(head_threshold=0.01)
    router.bind(Assignment(ModHash(10, seed=0)))
    router.on_stats(stats)
    # capacity (>= 4096) covers the 1000-key domain: tracker estimates are
    # exact and the head is exactly the threshold set
    expected = np.sort(uk[freq >= 0.01 * freq.sum()])
    np.testing.assert_array_equal(router.head_keys, expected)


def test_wchoices_tiny_capacity_never_misses_a_head_key():
    rng = np.random.default_rng(10)
    keys = (rng.zipf(1.6, size=30_000) % 500).astype(np.int64)
    uk, freq = _agg(keys)
    stats = KeyStats(keys=uk, cost=freq, mem=np.ones(uk.size), freq=freq)
    exact = np.sort(uk[freq >= 0.05 * freq.sum()])
    # capacity at the 4x-margin floor for this threshold: 80 entries
    router = WChoices(head_threshold=0.05, head_capacity=80)
    router.bind(Assignment(ModHash(10, seed=0)))
    router.on_stats(stats)
    # upper-bound estimates can only ADD borderline keys, never drop one
    assert np.isin(exact, router.head_keys).all()
