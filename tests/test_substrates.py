"""Integration tests: SkewShield MoE placement, keyed data pipeline, serving
engine, checkpointing, and the trainer loop (smoke scale, CPU)."""

import importlib.util

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.data.pipeline import KeyedDataPipeline, zipf_sources
from repro.models import forward, model_schema, schema
from repro.models.moe import moe
from repro.models.skewshield import (SkewShieldPlacer, permute_expert_params,
                                     placements_array)
from repro.serve.engine import ServeEngine
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import OptConfig, opt_init, opt_update
from repro.train.trainer import Trainer, TrainerConfig


# ------------------------------------------------------------- skewshield --
def test_skewshield_balances_hot_experts():
    placer = SkewShieldPlacer(n_experts=16, n_shards=4,
                              bytes_per_expert=1e6, theta_max=0.1)
    load = np.ones(16)
    load[0] = 20.0                       # one hot expert on shard 0
    load[1] = 15.0                       # and another
    upd = placer.update(load)
    assert upd.theta_after < upd.theta_before
    # slot-count constraint: every shard holds exactly 4 experts
    shards = placer.current_shards()
    assert np.bincount(shards, minlength=4).tolist() == [4, 4, 4, 4]


def test_skewshield_migration_is_minimal_when_balanced():
    placer = SkewShieldPlacer(16, 4, 1e6, theta_max=0.2)
    upd = placer.update(np.ones(16))
    assert len(upd.moved_experts) == 0
    assert np.array_equal(placer.placement, np.arange(16))


def test_skewshield_placement_preserves_moe_semantics():
    """Permuting placement + weights together leaves the layer function
    unchanged (non-split-key semantics on TPU)."""
    cfg = smoke_config("dbrx_132b")
    sch = model_schema(cfg)
    params = schema.init(sch, jax.random.PRNGKey(0))
    p = jax.tree.map(lambda a: a[0], params["groups"]["sub0"]["moe"])
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 8, cfg.d_model)),
                    jnp.float32)
    identity = np.arange(cfg.moe_experts, dtype=np.int32)
    out_base = moe(p, cfg, x, placement=jnp.asarray(identity))
    # move expert 0 <-> expert 2 (same shard size irrelevant here)
    new_place = identity.copy()
    new_place[[0, 2]] = new_place[[2, 0]]
    p2 = permute_expert_params(p, identity, new_place)
    out_perm = moe(p2, cfg, x, placement=jnp.asarray(new_place))
    np.testing.assert_allclose(np.asarray(out_base, np.float32),
                               np.asarray(out_perm, np.float32),
                               atol=2e-2)


def test_skewshield_repeated_updates_converge():
    # feasible regime: hottest expert stays below the mean shard load
    # (with a heavier tail the slot-count constraint pins theta at the
    # oversized-expert bound and no placement can fix it)
    rng = np.random.default_rng(0)
    placer = SkewShieldPlacer(40, 8, 1e6, theta_max=0.15)
    thetas = []
    load = rng.uniform(0.5, 2.0, 40)
    load[:3] = 4.0                                # hot but < total/8 ~ 6.3
    for _ in range(5):
        upd = placer.update(load)
        thetas.append(upd.theta_after)
        load = load * rng.uniform(0.9, 1.1, 40)   # mild drift
    # steady state: every interval ends within tolerance (+ drift slack);
    # the controller correctly does NOT re-trigger while under theta_max.
    assert all(t < 0.15 + 0.1 for t in thetas)
    assert thetas[-1] < 0.15


# ---------------------------------------------------------------- pipeline --
def test_pipeline_balances_worker_tokens():
    pipe = KeyedDataPipeline(zipf_sources(200, z=1.1), n_workers=8,
                             seq_len=64, vocab=1000, theta_max=0.1)
    loads = []
    for i in range(6):
        if i == 3:
            pipe.drift(magnitude=1.0)
        loads.append(pipe.run_interval(n_docs=400))
    first = loads[0]
    last = loads[-1]
    skew_first = first.max() / first.mean()
    skew_last = last.max() / last.mean()
    assert skew_last < max(skew_first, 1.6)
    b = pipe.worker_batch(0, batch=2)
    assert b is not None and b["tokens"].shape == (2, 64)
    assert np.array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_pipeline_checkpoint_roundtrip(tmp_path):
    pipe = KeyedDataPipeline(zipf_sources(50), n_workers=4, seq_len=32,
                             vocab=500)
    pipe.run_interval(200)
    state = pipe.state_dict()
    pipe2 = KeyedDataPipeline(zipf_sources(50), n_workers=4, seq_len=32,
                              vocab=500)
    pipe2.load_state(state)
    # identical continuation
    a = pipe.run_interval(100)
    b = pipe2.run_interval(100)
    np.testing.assert_array_equal(a, b)


# ------------------------------------------------------------------ serve --
def test_serve_engine_rebalances_hot_sessions():
    rng = np.random.default_rng(1)
    eng = ServeEngine(n_replicas=8, theta_max=0.1)
    hot = [1, 2, 3]                       # heavy agent sessions
    thetas = []
    for i in range(8):
        reqs = []
        for sid in hot:
            reqs.append((sid, 512, 1024))
        for _ in range(60):
            reqs.append((int(rng.integers(10, 500)), 128, 64))
        rep = eng.run_interval(reqs)
        thetas.append(rep.theta)
    assert np.mean(thetas[4:]) < np.mean(thetas[:2]) + 1e-9
    assert any(r.migrated_sessions > 0 for r in eng.reports)
    # each session's state lives on exactly one replica
    assert set(eng.location) >= set(eng.sessions)


def test_serve_engine_evicts_idle_sessions():
    eng = ServeEngine(n_replicas=2, window=2)
    eng.run_interval([(7, 100, 10)])
    for _ in range(3):
        eng.run_interval([(8, 10, 1)])
    assert 7 not in eng.sessions


# ------------------------------------------------------------- checkpoint --
needs_zstandard = pytest.mark.skipif(
    importlib.util.find_spec("zstandard") is None,
    reason="optional dep zstandard not installed")


@needs_zstandard
def test_checkpoint_roundtrip_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"w": jnp.arange(8, dtype=jnp.bfloat16),
             "n": {"m": jnp.ones((3, 3), jnp.float32)}}
    mgr.save(10, state)
    state2 = jax.tree.map(lambda x: x * 2, state)
    mgr.save(20, state2)
    step, restored, _ = mgr.restore(state)
    assert step == 20
    np.testing.assert_array_equal(np.asarray(restored["w"], np.float32),
                                  np.asarray(state2["w"], np.float32))


@needs_zstandard
def test_checkpoint_gc_and_structure_check(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=1)
    state = {"a": jnp.zeros(4)}
    mgr.save(1, state)
    mgr.save(2, state)
    assert mgr.latest_step() == 2
    assert not (tmp_path / "step_00000001").exists()
    with pytest.raises(ValueError):
        mgr.restore({"b": jnp.zeros(4)})


# ---------------------------------------------------------------- trainer --
def _toy_data(cfg, batch=2, seq=16):
    def data_fn(step):
        rng = np.random.default_rng(step)
        toks = rng.integers(0, cfg.vocab, (batch, seq + 1)).astype(np.int32)
        return {"tokens": jnp.asarray(toks[:, :-1]),
                "labels": jnp.asarray(toks[:, 1:])}
    return data_fn


@needs_zstandard
def test_trainer_loss_decreases_and_resumes(tmp_path):
    cfg = smoke_config("granite_8b")
    tcfg = TrainerConfig(total_steps=8, checkpoint_every=4, log_every=100,
                         skewshield=False)
    tr = Trainer(cfg, OptConfig(lr=1e-2, warmup_steps=2), tcfg,
                 str(tmp_path), _toy_data(cfg))
    hist = tr.run(8)
    assert hist[-1]["loss"] < hist[0]["loss"]
    # crash/restart: a new trainer resumes from step 8
    tr2 = Trainer(cfg, OptConfig(lr=1e-2, warmup_steps=2), tcfg,
                  str(tmp_path), _toy_data(cfg))
    assert tr2.try_resume()
    assert tr2.step == 8
    np.testing.assert_allclose(
        np.asarray(jax.tree.leaves(tr2.params)[0], np.float32),
        np.asarray(jax.tree.leaves(tr.params)[0], np.float32))


def test_trainer_moe_skewshield_loop(tmp_path):
    cfg = smoke_config("granite_moe_3b_a800m")
    tcfg = TrainerConfig(total_steps=6, checkpoint_every=100,
                         rebalance_every=2, skewshield=True, theta_max=0.2)
    tr = Trainer(cfg, OptConfig(lr=5e-3, warmup_steps=2), tcfg,
                 str(tmp_path), _toy_data(cfg))
    hist = tr.run(6)
    assert np.isfinite(hist[-1]["loss"])
    assert tr.placements() is not None
    # loss still finite after any expert migrations
    assert hist[-1]["loss"] < hist[0]["loss"] * 1.5
