"""Multi-stage topology: vectorized pipeline == per-tuple reference pipeline.

The topology chains full KeyedStages — each with its own controller,
assignment and store fleet — through the batched emit contract
(``Operator.process_batch_emits`` -> ``KeyedStage.process_interval_emits``).
On the numpy substrate every per-stage :class:`IntervalReport` must be
bit-identical between the all-vectorized and all-per-tuple pipelines,
*including* intervals where rebalances fire at more than one stage at once
(each stage pausing/replaying its own Delta keys).

Costs are chosen dyadic (WordCount 1.0, MergeCounts 0.5, Filter 0.25,
self-join probe_cost 1/64) so float summation order cannot introduce ulp
drift — same discipline as tests/test_engine_parity.py.
"""

import numpy as np
import pytest

from repro.streams import (Filter, MergeCounts, StageSpec, Topology,
                           WindowedSelfJoin, WordCount, WorkloadGen,
                           keyed_stage)

REPORT_FIELDS = ("interval", "tuples", "makespan", "migration_stall",
                 "throughput", "skewness", "theta", "migrated_bytes",
                 "table_size", "buffered")


def build_three_stage(vectorized, theta=0.04):
    """filter -> count -> top-k front (bucketed running max)."""
    s1 = keyed_stage(Filter(lambda k, v: (k + v) % 4 != 0), n_tasks=5,
                     theta_max=theta, table_max=300, window=3, seed=0,
                     vectorized=vectorized)
    s2 = keyed_stage(WordCount(), n_tasks=6, theta_max=theta, table_max=400,
                     window=3, seed=1, vectorized=vectorized)
    s3 = keyed_stage(MergeCounts(), n_tasks=4, theta_max=theta, table_max=200,
                     window=3, seed=2, vectorized=vectorized)
    return Topology([
        StageSpec("filter", s1),
        StageSpec("count", s2),
        StageSpec("topk", s3, rekey=lambda k, v: k % 32),
    ])


def drive_topology_pair(builder, intervals=6, tuples=4000, k=800, z=1.1,
                        f=0.8, gen_seed=3):
    """Drive vectorized and reference pipelines on identical source streams.

    Fluctuation follows stage 0's live assignment; if the two pipelines'
    plans ever diverge the drawn streams diverge too, which the assert
    catches (they are already non-equivalent at that point)."""
    gens = [WorkloadGen(k=k, z=z, f=f, seed=gen_seed, window=3)
            for _ in range(2)]
    topos = [builder(vec) for vec in (True, False)]
    for i in range(intervals):
        keys = None
        for gen, topo in zip(gens, topos):
            if i:
                gen.interval(topo.specs[0].stage.controller.assignment)
            drawn = gen.draw_tuples(tuples).astype(np.int64)
            if keys is None:
                keys = drawn
            else:
                assert np.array_equal(drawn, keys), "streams diverged"
            topo.process_interval(drawn, (drawn * 7 + i) % 11)
    return topos


def assert_topologies_identical(vec, ref):
    assert len(vec.reports) == len(ref.reports)
    for rv, rr in zip(vec.reports, ref.reports):
        assert rv.tuples_in == rr.tuples_in
        assert rv.stage_tuples == rr.stage_tuples
        assert rv.critical_path == rr.critical_path
        assert rv.throughput == rr.throughput
        assert rv.migrated_bytes == rr.migrated_bytes
        assert rv.buffered == rr.buffered
        for sv, sr in zip(rv.stage_reports, rr.stage_reports):
            for field in REPORT_FIELDS:
                assert getattr(sv, field) == getattr(sr, field), \
                    (rv.interval, field)
            assert np.array_equal(sv.task_loads, sr.task_loads)
    assert np.array_equal(vec.last_emit_keys, ref.last_emit_keys)
    assert np.array_equal(vec.last_emit_values, ref.last_emit_values)


def test_three_stage_pipeline_parity_with_multi_stage_rebalances():
    vec, ref = drive_topology_pair(build_three_stage)
    assert_topologies_identical(vec, ref)
    # the scenario is only meaningful if the protocol actually ran at
    # multiple stages: migrations at >= 2 stages, live pause/replay
    # downstream, and at least one interval where rebalances fired at more
    # than one stage simultaneously
    by_stage = vec.rebalances_by_stage()
    triggered_stages = [name for name, ivs in by_stage.items() if ivs]
    assert len(triggered_stages) >= 2, by_stage
    sets = [set(ivs) for ivs in by_stage.values() if ivs]
    common = set.intersection(*sets) if len(sets) >= 2 else set()
    assert common, f"no interval with rebalances at >=2 stages: {by_stage}"
    migrating_stages = {
        spec.name
        for spec in vec.specs
        if any(r.migrated_bytes > 0 for r in spec.stage.reports)}
    assert len(migrating_stages) >= 2, by_stage
    assert any(r.buffered > 0 for r in vec.reports)


def test_two_stage_selfjoin_pipeline_parity():
    """Join-flavored chain: self-join keyed by ticker -> per-sector volume."""

    def build(vec):
        s1 = keyed_stage(WindowedSelfJoin(probe_cost=1.0 / 64), n_tasks=6,
                         theta_max=0.05, table_max=300, window=3, seed=0,
                         vectorized=vec)
        s2 = keyed_stage(WordCount(), n_tasks=4, theta_max=0.05,
                         table_max=200, window=3, seed=1, vectorized=vec)
        return Topology([
            StageSpec("join", s1),
            StageSpec("volume", s2, rekey=lambda k, v: k % 16),
        ])

    vec, ref = drive_topology_pair(build, intervals=5, tuples=2000, k=300,
                                   z=1.0, f=1.0)
    assert_topologies_identical(vec, ref)
    assert any(r.migrated_bytes > 0 for r in vec.reports)


def test_filter_drops_tuples_downstream():
    vec, _ = drive_topology_pair(build_three_stage, intervals=3)
    for rep in vec.reports:
        n_src, n_counted, n_topk = rep.stage_tuples
        assert n_src == rep.tuples_in
        assert 0 < n_counted < n_src          # the filter dropped some
        assert n_topk == n_counted            # aggregations are 1-to-1
    # critical path really is the sum of the per-stage critical paths
    rep = vec.reports[-1]
    assert rep.critical_path == sum(r.makespan + r.migration_stall
                                    for r in rep.stage_reports)
    assert rep.throughput == rep.tuples_in / rep.critical_path


def test_topology_final_emits_are_running_maxima():
    """The top-k front's emit stream is empty (MergeCounts is terminal) but
    its stores hold the per-bucket running max of upstream counts."""
    vec, _ = drive_topology_pair(build_three_stage, intervals=3)
    assert vec.last_emit_keys.size == 0
    topk = vec["topk"]
    buckets = {}
    for store in topk.stores:
        for k, ks in store.keys.items():
            buckets[k] = max(buckets.get(k, 0),
                             max(sl.payload["count"]
                                 for sl in ks.slices.values()))
    # with 3 intervals driven under a 3-interval window nothing has evicted,
    # so each word's running totals are monotone and its LAST emit equals its
    # last-wins output — the per-bucket running max is exactly the max final
    # output over the bucket's words
    count_stage = vec["count"]
    expected = {}
    for k, v in count_stage.outputs.items():
        b = k % 32
        expected[b] = max(expected.get(b, 0), int(v))
    assert buckets == expected
    assert set(buckets) <= set(range(32))


def test_rekey_sees_values():
    """Edges re-key on (key, value) pairs — value-dependent routing works."""

    def build(vec):
        s1 = keyed_stage(WordCount(), n_tasks=4, theta_max=0.1, table_max=200,
                         window=2, seed=0, vectorized=vec)
        s2 = keyed_stage(MergeCounts(), n_tasks=3, theta_max=0.1,
                         table_max=100, window=2, seed=1, vectorized=vec)
        return Topology([
            StageSpec("count", s1),
            # route by count magnitude: hot words (large totals) share keys
            StageSpec("bands", s2, rekey=lambda k, v: np.minimum(v, 7)),
        ])

    vec, ref = drive_topology_pair(build, intervals=4, tuples=1500, k=200,
                                   z=1.0, f=0.5)
    assert_topologies_identical(vec, ref)
    bands = set()
    for store in vec["bands"].stores:
        bands.update(store.keys)
    assert bands <= set(range(8))


def test_filter_list_api_emitted_sum_parity():
    """The list API hands Python ints as payloads; Filter's batched path
    must count them toward emitted_sum exactly like the per-tuple loop does
    (regression: the ndarray conversion used to zero it out)."""
    stages = [keyed_stage(Filter(lambda k, v: (k + v) % 4 != 0), n_tasks=3,
                          theta_max=0.1, table_max=100, window=2, seed=0,
                          vectorized=vec) for vec in (True, False)]
    rng = np.random.default_rng(0)
    for i in range(2):
        keys = rng.integers(0, 50, size=400)
        for stage in stages:
            stage.process_interval([(int(k), int(k) % 7) for k in keys])
    vec, ref = stages
    assert vec.emitted_sum == ref.emitted_sum
    assert vec.emitted_sum > 0
    assert vec.outputs == ref.outputs


def test_triggered_intervals_survive_empty_interval():
    """A stats-free interval (no tuples, no held state) skips the controller;
    its recorded intervals must still align with the stage clock (regression:
    the private counter used to lag by one forever)."""
    stage = keyed_stage(WordCount(), n_tasks=4, theta_max=0.0, table_max=200,
                        window=1, seed=0)
    stage.process_interval_arrays(np.zeros(0, dtype=np.int64))   # interval 1
    rng = np.random.default_rng(1)
    keys = rng.zipf(1.5, size=2000) % 100                        # interval 2
    stage.process_interval_arrays(keys.astype(np.int64))
    assert stage.controller.triggered_intervals() == [2]


def test_topology_validation():
    s = keyed_stage(WordCount(), n_tasks=2, theta_max=0.1)
    with pytest.raises(ValueError):
        Topology([])
    with pytest.raises(ValueError):
        Topology([StageSpec("a", s), StageSpec("a", s)])
    topo = Topology([StageSpec("a", s)])
    with pytest.raises(KeyError):
        topo["missing"]


def test_pallas_substrate_topology_matches_numpy():
    """2-stage pipeline with both stages on the pallas substrate: integer
    routing decisions (table size, migration, buffering) must coincide with
    the numpy pipeline; float32 stats make loads agree to ~1e-5."""
    pytest.importorskip("jax")
    from repro.core.balancer.hashing import Hash32

    def build(substrate):
        s1 = keyed_stage(WordCount(), n_tasks=5, theta_max=0.05,
                         table_max=300, window=2, seed=3, hash_cls=Hash32,
                         substrate=substrate)
        s2 = keyed_stage(MergeCounts(), n_tasks=3, theta_max=0.05,
                         table_max=150, window=2, seed=4, hash_cls=Hash32,
                         substrate=substrate)
        return Topology([
            StageSpec("count", s1),
            StageSpec("topk", s2, rekey=lambda k, v: k % 16),
        ])

    gens = [WorkloadGen(k=400, z=1.1, f=0.8, seed=7, window=2)
            for _ in range(2)]
    topos = [build(s) for s in ("numpy", "pallas")]
    for i in range(4):
        keys = None
        for gen, topo in zip(gens, topos):
            if i:
                gen.interval(topo.specs[0].stage.controller.assignment)
            drawn = gen.draw_tuples(1500).astype(np.int64)
            if keys is None:
                keys = drawn
            else:
                assert np.array_equal(drawn, keys), "plans diverged"
            topo.process_interval(drawn, None)
    np_topo, pl_topo = topos
    for rn, rp in zip(np_topo.reports, pl_topo.reports):
        assert rn.buffered == rp.buffered
        assert rn.migrated_bytes == rp.migrated_bytes
        assert rn.stage_tuples == rp.stage_tuples
        for sn, sp in zip(rn.stage_reports, rp.stage_reports):
            assert sn.table_size == sp.table_size
            np.testing.assert_allclose(sp.task_loads, sn.task_loads,
                                       rtol=1e-5)
    assert any(r.table_size > 0
               for r in np_topo.specs[0].stage.reports)
