"""Pallas-substrate engine: kernel routing/stats vs the numpy reference.

``KeyedStage(substrate="pallas")`` routes micro-batches through the Pallas
mixed-dispatch kernel and aggregates step-1 stats through the fused
histogram kernel (interpret mode on CPU). Routing is integer and must match
numpy exactly; stats accumulate in float32 on-device, so reports agree to
~1e-5 relative rather than bit-for-bit.
"""

import numpy as np
import pytest

from repro.core import (Assignment, BalanceConfig, ModHash,
                        RebalanceController)
from repro.core.balancer.hashing import Hash32
from repro.streams import KeyedStage, WordCount, WorkloadGen


def make_stage(substrate, n_tasks=6, seed=3):
    controller = RebalanceController(
        Assignment(Hash32(n_tasks, seed=seed)),
        BalanceConfig(theta_max=0.05, table_max=300, window=2),
        algorithm="mixed")
    return KeyedStage(WordCount(), controller, window=2, substrate=substrate)


def test_pallas_substrate_matches_numpy():
    gens = [WorkloadGen(k=500, z=1.1, f=0.8, seed=7, window=2)
            for _ in range(2)]
    stages = [make_stage(s) for s in ("numpy", "pallas")]
    for i in range(4):
        keys = None
        for gen, stage in zip(gens, stages):
            if i:
                gen.interval(stage.controller.assignment)
            drawn = gen.draw_tuples(2000).astype(np.int64)
            if keys is None:
                keys = drawn
            else:
                assert np.array_equal(drawn, keys), "plans diverged"
            stage.process_interval_arrays(drawn, None)
    np_stage, pl_stage = stages
    for rn, rp in zip(np_stage.reports, pl_stage.reports):
        # routing is integer-exact, so migration/table decisions coincide
        assert rn.table_size == rp.table_size
        assert rn.migrated_bytes == rp.migrated_bytes
        assert rn.buffered == rp.buffered
        np.testing.assert_allclose(rp.task_loads, rn.task_loads, rtol=1e-5)
    assert np_stage.outputs == pl_stage.outputs
    # rebalancing actually ran (the kernels saw a non-empty table)
    assert any(r.table_size > 0 for r in np_stage.reports)


def test_routing_capacity_high_water_no_retrace():
    """The padded routing-table capacity is a per-stage high-water mark: a
    table oscillating across a power-of-two boundary (128<->129, the Mixed
    churn case) keeps one canonical kernel shape and never retraces.

    Before the fix the capacity was recomputed from the current table size,
    so the kernel alternated between the 128- and 256-slot shapes."""
    from repro.kernels.routing_lookup import routing_lookup
    stage = make_stage("pallas")
    keys = np.arange(512, dtype=np.int64)

    def set_table(n):
        stage.controller.assignment.table = {int(k): 0 for k in range(n)}

    set_table(120)
    stage._dest_batch(keys)
    assert stage._table_capacity == 128
    set_table(129)                      # crosses the power-of-two boundary
    stage._dest_batch(keys)
    assert stage._table_capacity == 256

    shapes = []
    orig = stage._kernel_route

    def spy(k, tk, td, n_dest, seed, **kw):
        shapes.append(int(tk.shape[0]))
        return orig(k, tk, td, n_dest, seed=seed, **kw)

    stage._kernel_route = spy
    # _cache_size is a private jax attribute; use it when present, but the
    # shape spy below proves the no-retrace invariant on public surface alone
    cache_size = getattr(routing_lookup, "_cache_size", None)
    traces_before = cache_size() if cache_size else None
    for n in (128, 129, 127, 130, 128, 129, 200, 256):
        set_table(n)
        stage._dest_batch(keys)
    if cache_size:
        assert cache_size() == traces_before               # no retrace
    assert set(shapes) == {256}        # capacity never shrinks back
    assert stage._table_capacity == 256


def test_pallas_requires_hash32_router():
    controller = RebalanceController(Assignment(ModHash(4)), BalanceConfig())
    with pytest.raises(ValueError, match="Hash32"):
        KeyedStage(WordCount(), controller, substrate="pallas")


def test_unknown_substrate_rejected():
    controller = RebalanceController(Assignment(ModHash(4)), BalanceConfig())
    with pytest.raises(ValueError, match="substrate"):
        KeyedStage(WordCount(), controller, substrate="cuda")


def test_observe_accepts_preaggregated_arrays():
    """RebalanceController.observe is the array-native step-1 handoff."""
    controller = RebalanceController(
        Assignment(ModHash(4, seed=1)),
        BalanceConfig(theta_max=0.01, table_max=100))
    keys = np.arange(64, dtype=np.int64)
    cost = np.ones(64)
    cost[:4] = 50.0                                    # skewed
    ev = controller.observe(keys, cost, mem=np.ones(64), freq=cost.copy())
    assert ev.triggered
    assert controller.assignment.table_size > 0


def test_kernel_interpret_auto_and_explicit_plumbing():
    """The kernel_interpret knob reaches the routing kernel: auto resolves
    True off-TPU, and an explicit value is passed through verbatim (the
    explicit-False stage is exercised by forcing interpret at the kernel
    boundary, so the mode plumbing is covered without TPU hardware)."""
    import jax

    on_tpu = jax.default_backend() == "tpu"
    auto = make_stage("pallas")
    assert auto._kernel_interpret is (not on_tpu)

    seen = []
    results = {}
    for explicit in (True, False):
        stage = make_stage("pallas")
        stage.__dict__["_kernel_interpret"] = explicit
        orig = stage._kernel_route

        def spy(k, tk, td, n_dest, seed, interpret=None, _orig=orig):
            seen.append(interpret)
            # run in a CPU-executable mode regardless of the requested one
            return _orig(k, tk, td, n_dest, seed=seed,
                         interpret=interpret if on_tpu else True)

        stage._kernel_route = spy
        keys = np.arange(256, dtype=np.int64)
        results[explicit] = stage._dest_batch(keys)
    assert seen == [True, False]
    np.testing.assert_array_equal(results[True], results[False])


def test_routing_table_device_cache_hits_until_rebalance():
    """_dest_batch must not rebuild/re-upload the routing table while the
    assignment is unchanged; a controller rebalance (assignment_version
    bump) invalidates the cached device arrays."""
    stage = make_stage("pallas")
    calls = []
    assignment = stage.controller.assignment
    orig = assignment.table_arrays
    assignment.table_arrays = lambda a_max=None: (calls.append(a_max)
                                                 or orig(a_max))
    keys = np.arange(512, dtype=np.int64)
    stage._dest_batch(keys)
    stage._dest_batch(keys)
    stage._dest_batch(keys)
    assert len(calls) == 1                 # two intervals rode the cache
    # a rebalance replaces the assignment: the cache must miss exactly once
    stats_keys = np.arange(64, dtype=np.int64)
    cost = np.ones(64)
    cost[:4] = 200.0
    stage.controller.observe(stats_keys, cost, mem=np.ones(64),
                             freq=cost.copy(), force=True)
    v = stage.controller.assignment_version
    assert v >= 1
    new_assignment = stage.controller.assignment
    calls2 = []
    orig2 = new_assignment.table_arrays
    new_assignment.table_arrays = lambda a_max=None: (calls2.append(a_max)
                                                      or orig2(a_max))
    stage._dest_batch(keys)
    stage._dest_batch(keys)
    assert len(calls2) == 1
    assert stage.controller.assignment_version == v   # reads don't bump it
