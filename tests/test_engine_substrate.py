"""Pallas-substrate engine: kernel routing/stats vs the numpy reference.

``KeyedStage(substrate="pallas")`` routes micro-batches through the Pallas
mixed-dispatch kernel and aggregates step-1 stats through the fused
histogram kernel (interpret mode on CPU). Routing is integer and must match
numpy exactly; stats accumulate in float32 on-device, so reports agree to
~1e-5 relative rather than bit-for-bit.
"""

import numpy as np
import pytest

from repro.core import (Assignment, BalanceConfig, ModHash,
                        RebalanceController)
from repro.core.balancer.hashing import Hash32
from repro.streams import KeyedStage, WordCount, WorkloadGen


def make_stage(substrate, n_tasks=6, seed=3):
    controller = RebalanceController(
        Assignment(Hash32(n_tasks, seed=seed)),
        BalanceConfig(theta_max=0.05, table_max=300, window=2),
        algorithm="mixed")
    return KeyedStage(WordCount(), controller, window=2, substrate=substrate)


def test_pallas_substrate_matches_numpy():
    gens = [WorkloadGen(k=500, z=1.1, f=0.8, seed=7, window=2)
            for _ in range(2)]
    stages = [make_stage(s) for s in ("numpy", "pallas")]
    for i in range(4):
        keys = None
        for gen, stage in zip(gens, stages):
            if i:
                gen.interval(stage.controller.assignment)
            drawn = gen.draw_tuples(2000).astype(np.int64)
            if keys is None:
                keys = drawn
            else:
                assert np.array_equal(drawn, keys), "plans diverged"
            stage.process_interval_arrays(drawn, None)
    np_stage, pl_stage = stages
    for rn, rp in zip(np_stage.reports, pl_stage.reports):
        # routing is integer-exact, so migration/table decisions coincide
        assert rn.table_size == rp.table_size
        assert rn.migrated_bytes == rp.migrated_bytes
        assert rn.buffered == rp.buffered
        np.testing.assert_allclose(rp.task_loads, rn.task_loads, rtol=1e-5)
    assert np_stage.outputs == pl_stage.outputs
    # rebalancing actually ran (the kernels saw a non-empty table)
    assert any(r.table_size > 0 for r in np_stage.reports)


def test_pallas_requires_hash32_router():
    controller = RebalanceController(Assignment(ModHash(4)), BalanceConfig())
    with pytest.raises(ValueError, match="Hash32"):
        KeyedStage(WordCount(), controller, substrate="pallas")


def test_unknown_substrate_rejected():
    controller = RebalanceController(Assignment(ModHash(4)), BalanceConfig())
    with pytest.raises(ValueError, match="substrate"):
        KeyedStage(WordCount(), controller, substrate="cuda")


def test_observe_accepts_preaggregated_arrays():
    """RebalanceController.observe is the array-native step-1 handoff."""
    controller = RebalanceController(
        Assignment(ModHash(4, seed=1)),
        BalanceConfig(theta_max=0.01, table_max=100))
    keys = np.arange(64, dtype=np.int64)
    cost = np.ones(64)
    cost[:4] = 50.0                                    # skewed
    ev = controller.observe(keys, cost, mem=np.ones(64), freq=cost.copy())
    assert ev.triggered
    assert controller.assignment.table_size > 0
