"""Unit + property tests: hash routers and HLHE discretization (Sec. IV-B)."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")   # optional [test] extra
from hypothesis import given, settings, strategies as st

from repro.core.balancer import (ConsistentHash, ModHash, discretize,
                                 hlhe_representatives, total_deviation,
                                 splitmix64)
from repro.core.balancer.hashing import ExplicitHash


# ---------------------------------------------------------------- hashing --
@given(st.integers(1, 64), st.lists(st.integers(0, 2**62), min_size=1,
                                    max_size=200), st.integers(0, 2**31))
@settings(max_examples=50, deadline=None)
def test_modhash_range_and_determinism(n_dest, keys, seed):
    h = ModHash(n_dest, seed)
    keys = np.asarray(keys, dtype=np.int64)
    out1, out2 = h(keys), h(keys)
    assert np.array_equal(out1, out2)
    assert out1.min() >= 0 and out1.max() < n_dest


def test_modhash_distributes_uniformly():
    h = ModHash(16, seed=3)
    d = h(np.arange(200_000, dtype=np.int64))
    counts = np.bincount(d, minlength=16)
    assert counts.min() > 0.9 * counts.mean()
    assert counts.max() < 1.1 * counts.mean()


def test_consistent_hash_minimal_remap_on_scaleout():
    """Paper Sec. V uses consistent hashing [14]: adding one instance remaps
    only ~1/(N+1) of the keys (vs ~N/(N+1) for mod hashing)."""
    keys = np.arange(100_000, dtype=np.int64)
    ch10, ch11 = ConsistentHash(10, seed=1), ConsistentHash(11, seed=1)
    remap_ch = float(np.mean(ch10(keys) != ch11(keys)))
    mh10, mh11 = ModHash(10, seed=1), ModHash(11, seed=1)
    remap_mh = float(np.mean(mh10(keys) != mh11(keys)))
    assert remap_ch < 0.25          # ideal 1/11 ~ 0.09, vnode variance allows slack
    assert remap_mh > 0.8           # mod hashing reshuffles nearly everything
    assert remap_ch < remap_mh / 3


def test_consistent_hash_range():
    ch = ConsistentHash(7, seed=9)
    d = ch(np.arange(50_000, dtype=np.int64))
    assert d.min() >= 0 and d.max() < 7
    assert len(np.unique(d)) == 7


def test_explicit_hash():
    h = ExplicitHash({5: 2, 6: 0}, n_dest=3)
    out = h(np.array([5, 6, 7], dtype=np.int64))
    assert out[0] == 2 and out[1] == 0 and 0 <= out[2] < 3


def test_splitmix64_avalanche():
    """Adjacent inputs produce uncorrelated outputs (bit-mixing sanity)."""
    x = np.arange(10_000, dtype=np.int64).view(np.uint64)
    h = splitmix64(x)
    bits = np.unpackbits(h.view(np.uint8))
    assert abs(float(bits.mean()) - 0.5) < 0.01


# ----------------------------------------------------------- discretization --
def test_hlhe_representatives_paper_example():
    """Paper Fig. 6(b): r=2, R=4, max=8 -> y = [8, 4, 2, 1] (m=4)."""
    ys = hlhe_representatives(8.0, r=2)
    assert ys.tolist() == [8.0, 4.0, 2.0, 1.0]


def test_hlhe_paper_sequence_deviation():
    """Paper Fig. 6 worked values: 8,6,3,2,2,1x5 with R=4. The greedy rule
    keeps |delta| <= 1 (the paper idealizes this to ~0; simple piecewise
    rounding gives |delta| = 3, Fig. 6(a))."""
    vals = np.array([8, 6, 3, 2, 2, 1, 1, 1, 1, 1], dtype=np.float64)
    phi = discretize(vals, r=2)
    assert total_deviation(vals, phi) <= 1.0 + 1e-9
    assert phi[0] == 8.0
    # k3 (value 3) rounds UP to 4 to cancel k2's under-count, per the paper
    assert phi[2] == 4.0


@given(st.lists(st.floats(1.0, 1e4, allow_nan=False), min_size=1, max_size=500),
       st.integers(0, 8))
@settings(max_examples=60, deadline=None)
def test_discretization_bounded_total_deviation(vals, r):
    """Theorem 3 (operational form): accumulated error stays bounded by one
    bracket gap — it does NOT grow with the number of values."""
    vals = np.asarray(vals)
    phi = discretize(vals, r)
    ys = hlhe_representatives(float(vals.max()), r)
    gaps = np.diff(-ys)
    max_gap = float(gaps.max()) if len(gaps) else 1.0
    # Values above the cap y_1 = s*R can only round DOWN (the paper's HLHE
    # construction); each contributes < R of irreducible positive deviation.
    above_cap = float(np.sum(np.maximum(vals - ys[0], 0.0)))
    assert total_deviation(vals, phi) <= max_gap + above_cap + 1e-6
    # every phi is a representative value (or the cap y_1)
    assert np.all(np.isin(phi, ys))


@given(st.integers(0, 8), st.floats(2.0, 1e5))
@settings(max_examples=50, deadline=None)
def test_hlhe_strictly_decreasing_to_one(r, max_value):
    ys = hlhe_representatives(max_value, r)
    assert np.all(np.diff(ys) < 0)
    assert ys[-1] == 1.0
