"""Vectorized engine == per-tuple reference engine, field for field.

The vectorized fast path (``KeyedStage(vectorized=True)``, the default) must
be a pure optimization: on the same fixed-seed skewed stream it has to emit
the same outputs, migrate the same bytes, and report the same
:class:`IntervalReport` numbers as the per-tuple reference loop
(``vectorized=False``) — including through live rebalances, pause/replay
windows, and elastic rescales.

WordCount costs are integers, so every float in the pipeline is exact and
the comparison is strict equality. For the self-join we pin ``probe_cost``
to a power of two so per-tuple costs are dyadic rationals and summation
order cannot produce ulp drift (with the default 0.01 the two paths differ
by ~1e-15, which the balancer's greedy tie-breaks can then amplify into a
different-but-equally-balanced plan).
"""

import numpy as np
import pytest

from repro.core import (Assignment, BalanceConfig, ModHash,
                        RebalanceController)
from repro.streams import (KeyedStage, MergeCounts, Operator,
                           PartialWordCount, WindowedSelfJoin, WordCount,
                           WorkloadGen)

REPORT_FIELDS = ("interval", "tuples", "makespan", "migration_stall",
                 "throughput", "skewness", "theta", "migrated_bytes",
                 "table_size", "buffered")


def make_stage(operator, vectorized, n_tasks=6, theta_max=0.05,
               table_max=400, window=3, algorithm="mixed", seed=0):
    controller = RebalanceController(
        Assignment(ModHash(n_tasks, seed=seed)),
        BalanceConfig(theta_max=theta_max, table_max=table_max, window=window),
        algorithm=algorithm)
    return KeyedStage(operator, controller, window=window,
                      vectorized=vectorized)


def drive_pair(op_factory, intervals=6, tuples=4000, k=800, z=1.1, f=0.8,
               gen_seed=2, **stage_kw):
    gens = [WorkloadGen(k=k, z=z, f=f, seed=gen_seed, window=3)
            for _ in range(2)]
    stages = [make_stage(op_factory(), vec, **stage_kw)
              for vec in (True, False)]
    for i in range(intervals):
        keys = None
        for gen, stage in zip(gens, stages):
            if i:
                gen.interval(stage.controller.assignment)
            drawn = gen.draw_tuples(tuples).astype(np.int64)
            if keys is None:
                keys = drawn
            else:
                # both paths must see the same stream: if they diverge the
                # engines are already non-equivalent (plans differ)
                assert np.array_equal(drawn, keys), "streams diverged"
            stage.process_interval_arrays(drawn, np.full(tuples, i))
    return stages


def assert_reports_identical(vec_stage, ref_stage):
    assert len(vec_stage.reports) == len(ref_stage.reports)
    for rv, rr in zip(vec_stage.reports, ref_stage.reports):
        for field in REPORT_FIELDS:
            assert getattr(rv, field) == getattr(rr, field), field
        assert np.array_equal(rv.task_loads, rr.task_loads)


@pytest.mark.parametrize("op_factory", [
    WordCount, PartialWordCount,
    lambda: WindowedSelfJoin(probe_cost=1.0 / 64),
], ids=["wordcount", "partial_wordcount", "selfjoin_dyadic"])
def test_reports_identical_through_rebalances(op_factory):
    vec, ref = drive_pair(op_factory)
    assert_reports_identical(vec, ref)
    # rebalances actually happened, so the pause/replay path was exercised
    assert any(r.migrated_bytes > 0 for r in vec.reports)
    assert any(r.buffered > 0 for r in vec.reports)


@pytest.mark.parametrize("algorithm", ["mixed", "mintable", "readj"])
def test_reports_identical_per_algorithm(algorithm):
    vec, ref = drive_pair(WordCount, intervals=4, algorithm=algorithm)
    assert_reports_identical(vec, ref)


def test_outputs_emits_and_state_identical():
    vec, ref = drive_pair(WordCount)
    assert vec.outputs == ref.outputs
    assert vec.emitted_sum == ref.emitted_sum
    assert len(vec.stores) == len(ref.stores)
    for sv, sr in zip(vec.stores, ref.stores):
        assert sorted(sv.keys) == sorted(sr.keys)
        for k, ks in sv.keys.items():
            other = sr.keys[k]
            assert list(ks.slices) == list(other.slices)
            for iv, sl in ks.slices.items():
                assert sl.payload == other.slices[iv].payload
                assert sl.size == other.slices[iv].size


def test_merge_counts_parity():
    rng = np.random.default_rng(0)
    stages = [make_stage(MergeCounts(), vec, window=2) for vec in (True, False)]
    for i in range(3):
        keys = rng.integers(0, 200, size=1500).astype(np.int64)
        vals = rng.integers(1, 50, size=1500)
        for stage in stages:
            stage.process_interval_arrays(keys, vals)
    assert_reports_identical(*stages)
    for sv, sr in zip(stages[0].stores, stages[1].stores):
        assert {k: [s.payload for s in ks.slices.values()]
                for k, ks in sv.keys.items()} == \
               {k: [s.payload for s in ks.slices.values()]
                for k, ks in sr.keys.items()}


@pytest.mark.parametrize("op_factory", [
    WordCount, PartialWordCount,
    lambda: WindowedSelfJoin(probe_cost=1.0 / 64),
], ids=["wordcount", "partial_wordcount", "selfjoin_dyadic"])
def test_emit_streams_identical(op_factory):
    """process_interval_emits: the full emit stream (the topology hand-off)
    is identical between the two paths, in canonical source-position order,
    through live rebalances and pause/replay windows."""
    gens = [WorkloadGen(k=800, z=1.1, f=0.8, seed=2, window=3)
            for _ in range(2)]
    stages = [make_stage(op_factory(), vec) for vec in (True, False)]
    saw_buffered = False
    for i in range(5):
        keys = None
        emits = []
        for gen, stage in zip(gens, stages):
            if i:
                gen.interval(stage.controller.assignment)
            drawn = gen.draw_tuples(3000).astype(np.int64)
            if keys is None:
                keys = drawn
            else:
                assert np.array_equal(drawn, keys), "streams diverged"
            emits.append(stage.process_interval_emits(drawn,
                                                      np.full(3000, i)))
        (rv, kv, vv), (rr, kr, vr) = emits
        assert np.array_equal(kv, kr)
        assert np.array_equal(vv, vr)
        assert rv.buffered == rr.buffered
        saw_buffered = saw_buffered or rv.buffered > 0
    assert_reports_identical(*stages)
    assert saw_buffered


def test_emits_with_custom_operator_fallback():
    """Operators that only implement process() inherit the per-tuple
    process_batch_emits fallback and still hand identical emit streams to a
    vectorized downstream."""

    class CustomCount(Operator):
        name = "custom"

        def __init__(self):
            self._inner = WordCount()

        def process(self, store, interval, key, value):
            return self._inner.process(store, interval, key, value)

    gens = [WorkloadGen(k=300, z=1.0, f=0.5, seed=4, window=2)
            for _ in range(2)]
    stages = [make_stage(CustomCount(), vec, window=2)
              for vec in (True, False)]
    for i in range(3):
        keys = None
        emits = []
        for gen, stage in zip(gens, stages):
            if i:
                gen.interval(stage.controller.assignment)
            drawn = gen.draw_tuples(1000).astype(np.int64)
            if keys is None:
                keys = drawn
            else:
                assert np.array_equal(drawn, keys), "streams diverged"
            emits.append(stage.process_interval_emits(drawn, None))
        (_, kv, vv), (_, kr, vr) = emits
        assert np.array_equal(kv, kr)
        assert np.array_equal(vv, vr)


def test_custom_operator_uses_fallback_batch_path():
    """Operators that only implement process() stay correct when vectorized:
    they inherit the base-class per-tuple process_batch fallback."""

    class CustomCount(Operator):
        name = "custom"

        def __init__(self):
            self._inner = WordCount()

        def process(self, store, interval, key, value):
            return self._inner.process(store, interval, key, value)

    vec, ref = drive_pair(CustomCount, intervals=3)
    assert_reports_identical(vec, ref)


def test_scale_out_parity():
    vec, ref = drive_pair(WordCount, intervals=3)
    vec.scale_to(9)
    ref.scale_to(9)
    assert vec.total_state_keys() == ref.total_state_keys()
    for sv, sr in zip(vec.stores, ref.stores):
        assert sorted(sv.keys) == sorted(sr.keys)
    assert vec._migrated_bytes_pending == ref._migrated_bytes_pending


def test_list_api_matches_array_api():
    gen = WorkloadGen(k=300, z=1.0, f=0.5, seed=4, window=2)
    a = make_stage(WordCount(), True, window=2)
    b = make_stage(WordCount(), True, window=2)
    for i in range(3):
        if i:
            gen.interval(a.controller.assignment)
        keys = gen.draw_tuples(1000).astype(np.int64)
        a.process_interval_arrays(keys, None)
        b.process_interval([(int(k), i) for k in keys])
    assert_reports_identical(a, b)
