"""Unit tests for the CI perf-regression gate (benchmarks/check_perf_gate.py).

The gate script is CI-critical: a bug that makes it exit 0 on garbage input
silently disables regression protection for every future PR. These tests
drive ``main()`` with synthetic fresh/baseline JSON pairs through every
outcome: clean pass, >max-ratio regression (exit 1), noise-floor exemption,
and the misconfiguration paths that must exit 2 rather than pass.
"""

import json
import sys

import pytest

from benchmarks import check_perf_gate


def _write(path, payload):
    path.write_text(json.dumps(payload))
    return str(path)


def _planner_json(tmp_path, name, times):
    """times: {(profile, algo, k): plan_time_s}"""
    series = [{"profile": p, "algo": a, "k": k, "plan_time_s": t}
              for (p, a, k), t in times.items()]
    return _write(tmp_path / name, {"series": series})


def _fastpath_json(tmp_path, name, times):
    """times: {point_name: seconds}"""
    series = [{"name": n, "seconds": s} for n, s in times.items()]
    return _write(tmp_path / name, {"series": series})


def _run_gate(monkeypatch, argv):
    monkeypatch.setattr(sys, "argv", ["check_perf_gate.py"] + argv)
    check_perf_gate.main()


def test_gate_passes_within_ratio(tmp_path, monkeypatch, capsys):
    base = _planner_json(tmp_path, "base.json",
                         {("zipf", "mixed", 10_000): 0.10,
                          ("zipf", "mixed", 30_000): 0.40})
    fresh = _planner_json(tmp_path, "fresh.json",
                          {("zipf", "mixed", 10_000): 0.15,
                           ("zipf", "mixed", 30_000): 0.50})
    _run_gate(monkeypatch, ["--fresh", fresh, "--baseline", base])
    assert "perf gate OK: 2 gated points" in capsys.readouterr().out


def test_gate_fails_on_regression(tmp_path, monkeypatch, capsys):
    base = _fastpath_json(tmp_path, "base.json",
                          {"store_ab/columnar": 0.10,
                           "store_ab/device": 0.05})
    fresh = _fastpath_json(tmp_path, "fresh.json",
                           {"store_ab/columnar": 0.11,
                            "store_ab/device": 0.12})   # 2.4x: regressed
    with pytest.raises(SystemExit) as e:
        _run_gate(monkeypatch, ["--fastpath-fresh", fresh,
                                "--fastpath-baseline", base])
    assert e.value.code == 1
    err = capsys.readouterr().err
    assert "store_ab/device: 2.40x" in err


def test_gate_max_ratio_is_configurable(tmp_path, monkeypatch):
    base = _fastpath_json(tmp_path, "base.json", {"a": 0.10})
    fresh = _fastpath_json(tmp_path, "fresh.json", {"a": 0.25})  # 2.5x
    with pytest.raises(SystemExit):
        _run_gate(monkeypatch, ["--fastpath-fresh", fresh,
                                "--fastpath-baseline", base])
    _run_gate(monkeypatch, ["--fastpath-fresh", fresh,
                            "--fastpath-baseline", base,
                            "--max-ratio", "3.0"])      # same pair now passes


def test_noise_floor_exempts_tiny_baselines(tmp_path, monkeypatch, capsys):
    """A 10x swing on a sub-floor point is reported but not gated — only
    the point whose baseline clears --min-baseline-s counts."""
    base = _planner_json(tmp_path, "base.json",
                         {("zipf", "mixed", 5_000): 0.001,   # < 15 ms floor
                          ("zipf", "mixed", 100_000): 1.00})
    fresh = _planner_json(tmp_path, "fresh.json",
                          {("zipf", "mixed", 5_000): 0.010,  # 10x, exempt
                           ("zipf", "mixed", 100_000): 1.10})
    _run_gate(monkeypatch, ["--fresh", fresh, "--baseline", base])
    out = capsys.readouterr().out
    assert "ungated: baseline < 15 ms" in out
    assert "perf gate OK: 1 gated points" in out


def test_all_points_exempt_exits_2(tmp_path, monkeypatch):
    """If every common point falls under the noise floor nothing was
    actually gated — that must read as misconfiguration, not a pass."""
    base = _fastpath_json(tmp_path, "base.json", {"a": 0.001, "b": 0.002})
    fresh = _fastpath_json(tmp_path, "fresh.json", {"a": 0.001, "b": 0.002})
    with pytest.raises(SystemExit) as e:
        _run_gate(monkeypatch, ["--fastpath-fresh", fresh,
                                "--fastpath-baseline", base])
    assert e.value.code == 2


def test_disjoint_sections_exit_2(tmp_path, monkeypatch, capsys):
    """Zero shared points (e.g. a renamed series) must never silently
    pass."""
    base = _fastpath_json(tmp_path, "base.json", {"old_name": 0.10})
    fresh = _fastpath_json(tmp_path, "fresh.json", {"new_name": 0.10})
    with pytest.raises(SystemExit) as e:
        _run_gate(monkeypatch, ["--fastpath-fresh", fresh,
                                "--fastpath-baseline", base])
    assert e.value.code == 2
    assert "no point is shared" in capsys.readouterr().err


def test_no_fresh_input_exits_2(monkeypatch):
    with pytest.raises(SystemExit) as e:
        _run_gate(monkeypatch, [])
    assert e.value.code == 2


def test_both_sections_gate_together(tmp_path, monkeypatch, capsys):
    """Planner and fastpath sections combine: a regression in either fails
    the run even when the other is clean."""
    pb = _planner_json(tmp_path, "pb.json", {("u", "mixed", 10_000): 0.10})
    pf = _planner_json(tmp_path, "pf.json", {("u", "mixed", 10_000): 0.10})
    fb = _fastpath_json(tmp_path, "fb.json", {"store_ab/device": 0.05})
    ff = _fastpath_json(tmp_path, "ff.json", {"store_ab/device": 0.50})
    with pytest.raises(SystemExit) as e:
        _run_gate(monkeypatch, ["--fresh", pf, "--baseline", pb,
                                "--fastpath-fresh", ff,
                                "--fastpath-baseline", fb])
    assert e.value.code == 1
    err = capsys.readouterr().err
    assert "1/2 gated points" in err
