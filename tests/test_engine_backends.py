"""The StateBackend registry + the full ``state_backend="auto"`` matrix.

PR 6 flipped ``auto`` to prefer the device backend when jax runs on an
accelerator; the backend-protocol refactor moved that decision into
``repro.streams.backends.resolve_backend``. This suite pins the whole
selection matrix (operator capability x router x vectorized x jax
backend) so future backends cannot silently shift existing stages, plus
the registry surface itself (registration, lazy names, unknown-name
errors). The accelerator rows monkeypatch ``jax.default_backend`` — the
decision reads the backend name, not device properties, so the matrix is
testable on CPU CI.

See docs/architecture.md ("State backends") for the selection-rules table
this suite executes.
"""

import pytest

from repro.core import Assignment, BalanceConfig, ModHash, RebalanceController
from repro.core.balancer.hashing import Hash32
from repro.streams import KeyedStage, Operator, WordCount
from repro.streams.backends import (BACKENDS, StateBackend, backend_names,
                                    get_backend, register_backend,
                                    resolve_backend)


class CustomOp(Operator):
    """No columnar_spec, no device_mode: object-store only."""

    def process(self, store, interval, key, value):
        return [], 1.0


def _controller(hash_cls=Hash32, n_tasks=4):
    return RebalanceController(Assignment(hash_cls(n_tasks, seed=0)),
                               BalanceConfig())


def _stage(op, *, hash_cls=Hash32, vectorized=True, backend="auto"):
    return KeyedStage(op, _controller(hash_cls), vectorized=vectorized,
                      state_backend=backend)


# -- the auto-selection matrix -------------------------------------------------
# rows: (operator capability, router, vectorized, jax backend) -> chosen

def test_auto_matrix_on_cpu():
    """On the CPU jax backend the device backend is never auto-picked (the
    host columnar store wins there, measured in engine_fastpath.py)."""
    assert _stage(WordCount()).state_backend == "columnar"
    assert _stage(WordCount(), hash_cls=ModHash).state_backend == "columnar"
    assert _stage(CustomOp()).state_backend == "object"
    # the reference loop needs scalar state access: object, regardless of
    # operator capability
    assert _stage(WordCount(), vectorized=False).state_backend == "object"
    assert _stage(CustomOp(), vectorized=False).state_backend == "object"


def test_auto_matrix_on_accelerator(monkeypatch):
    """On an accelerator backend auto promotes to device — exactly when the
    operator has device closed forms AND the router is Hash32."""
    import jax
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    # full device capability: promoted
    assert _stage(WordCount()).state_backend == "device"
    # ModHash has no device-canonical hash: stays columnar
    assert _stage(WordCount(), hash_cls=ModHash).state_backend == "columnar"
    # columnar-capable but no device closed forms: stays columnar
    from repro.streams import Filter
    assert _stage(Filter(lambda k, v: True)).state_backend == "columnar"
    # per-tuple operators still land on object
    assert _stage(CustomOp()).state_backend == "object"
    # reference loop: never promoted
    assert _stage(WordCount(), vectorized=False).state_backend == "object"
    # sharded stays explicit-only even when every device requirement holds
    assert _stage(WordCount()).state_backend != "sharded"


def test_explicit_backend_requests_are_validated():
    # forcing a backend the operator cannot support raises with the reason
    with pytest.raises(ValueError, match="columnar_spec"):
        _stage(CustomOp(), backend="columnar")
    with pytest.raises(ValueError, match="device closed forms"):
        _stage(CustomOp(), backend="device")
    # forcing object always works (the compatibility backend)
    assert _stage(WordCount(), backend="object").state_backend == "object"


# -- registry surface ----------------------------------------------------------

def test_registry_names_and_unknown_backend():
    assert {"object", "columnar", "device"} <= set(BACKENDS)
    # lazy backends are selectable without having been imported
    assert set(backend_names()) >= {"auto", "object", "columnar", "device",
                                    "sharded"}
    with pytest.raises(ValueError, match="unknown state backend"):
        get_backend("bogus")
    with pytest.raises(ValueError, match="unknown state backend"):
        KeyedStage(WordCount(), _controller(), state_backend="bogus")


def test_register_backend_round_trip():
    class NullBackend(StateBackend):
        name = "null-test"

    try:
        register_backend(NullBackend)
        assert get_backend("null-test") is NullBackend
        assert resolve_backend("null-test", WordCount(), _controller(),
                               True) is NullBackend
        # auto never considers backends that do not opt in
        assert resolve_backend("auto", WordCount(), _controller(),
                               True).name == "columnar"
    finally:
        BACKENDS.pop("null-test", None)
    # nameless classes are rejected outright
    with pytest.raises(ValueError, match="name"):
        register_backend(StateBackend)
