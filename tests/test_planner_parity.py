"""Array-native planner vs the scalar reference oracle — bit-identical plans.

The production planner (`balancer.llfd` / `phased` / `mixed`) replaces the
pre-PR per-key Python implementation (preserved in `balancer.reference`) with
flat numpy state. In its default exact mode it must produce *identical*
`RebalanceResult`s — routing table, moved keys, loads, theta — over
randomized skewed workloads, including warmed tables (non-trivial Phase I)
and every algorithm of the family. This is the planner-layer counterpart of
`tests/test_engine_parity.py`.
"""

import numpy as np
import pytest

from repro.core.balancer import (Assignment, BalanceConfig, ConsistentHash,
                                 KeyStats, ModHash, metrics, mintable, minmig,
                                 mixed, mixed_bf, reference_mintable,
                                 reference_minmig, reference_mixed,
                                 reference_mixed_bf)
from repro.core.balancer.hashing import Hash32

PAIRS = [
    (mixed, reference_mixed),
    (mixed_bf, reference_mixed_bf),
    (mintable, reference_mintable),
    (minmig, reference_minmig),
]


def make_stats(rng, k, heavy_tail=1.2):
    """Pareto-skewed per-key cost/state over a sparse 64-bit-ish key domain."""
    cost = rng.pareto(heavy_tail, size=k) + 1.0
    mem = rng.pareto(heavy_tail, size=k) + 1.0
    keys = np.sort(rng.choice(10**7, size=k, replace=False)).astype(np.int64)
    return KeyStats(keys=keys, cost=cost, mem=mem)


def make_instance(seed):
    rng = np.random.default_rng(seed)
    k = int(rng.integers(16, 500))
    n_dest = int(rng.integers(2, 14))
    theta = [0.0, 0.02, 0.08, 0.3][seed % 4]
    router = [ModHash(n_dest, seed=seed % 7), Hash32(n_dest, seed=seed % 5),
              ConsistentHash(n_dest, seed=seed % 3)][seed % 3]
    stats = make_stats(rng, k)
    cfg = BalanceConfig(theta_max=theta, table_max=max(4, k // 4))
    return stats, Assignment(router), cfg


@pytest.mark.parametrize("seed", range(12))
def test_plans_identical_on_randomized_workloads(seed):
    """Every algorithm, cold table: new plan == reference plan, bit for bit."""
    stats, assignment, cfg = make_instance(seed)
    for new_algo, ref_algo in PAIRS:
        rn = new_algo(stats, assignment, cfg)
        rr = ref_algo(stats, assignment, cfg)
        assert rn.same_plan(rr), (seed, new_algo.__name__)
        assert rn.migration_cost == rr.migration_cost
        assert rn.feasible_balance == rr.feasible_balance
        assert rn.feasible_table == rr.feasible_table


@pytest.mark.parametrize("seed", range(8))
def test_plans_identical_with_warmed_table(seed):
    """Second interval on a warmed (non-empty) table: Phase I / eta order and
    Mixed's n-escalation take the same decisions in both implementations."""
    stats, assignment, cfg = make_instance(seed)
    warm = reference_mixed(stats, assignment, cfg)
    stats2 = make_stats(np.random.default_rng(seed + 10_000), stats.num_keys)
    for new_algo, ref_algo in PAIRS:
        rn = new_algo(stats2, warm.assignment, cfg)
        rr = ref_algo(stats2, warm.assignment, cfg)
        assert rn.same_plan(rr), (seed, new_algo.__name__)
        if "mixed_bf" not in new_algo.__name__:
            assert rn.meta.get("trials") == rr.meta.get("trials")
            assert rn.meta.get("cleaned") == rr.meta.get("cleaned")


def test_head_tail_split_default_off_is_exact():
    """head_fraction=0 (default) must leave the planner bit-identical; the
    explicit 0.0 knob is the same code path."""
    stats, assignment, cfg = make_instance(3)
    res_default = mixed(stats, assignment, cfg)
    res_zero = mixed(stats, assignment,
                     BalanceConfig(theta_max=cfg.theta_max,
                                   table_max=cfg.table_max, head_fraction=0.0))
    assert res_default.same_plan(res_zero)


@pytest.mark.parametrize("seed", range(6))
def test_head_tail_split_moves_only_head_keys(seed):
    """With head_fraction > 0: tail keys (light, untabled) stay frozen on
    their hash destinations, the reported result stays internally consistent,
    and the head alone carries enough mass to restore feasibility on the
    paper's synthetic skew (the tail enters the solve as per-destination
    base loads, so LLFD levels against it)."""
    rng = np.random.default_rng(seed)
    k = 4_000
    stats = make_stats(rng, k)
    assignment = Assignment(ModHash(8, seed=seed))
    frac = 0.01
    cfg = BalanceConfig(theta_max=0.08, table_max=k, head_fraction=frac)
    res = mixed(stats, assignment, cfg)
    # internal consistency: loads recompute through the returned assignment
    re_loads = metrics.loads(stats, res.assignment)
    np.testing.assert_array_equal(re_loads, res.loads)
    # only head keys may move
    mean = float(stats.cost.sum()) / assignment.n_dest
    head_ids = set(stats.keys[stats.cost >= frac * mean].tolist())
    assert len(head_ids) < k // 10          # the split actually prunes
    for kid in res.moved_keys.tolist():
        assert kid in head_ids
    for kid in res.assignment.table:
        assert kid in head_ids
    # exact placement of the ~2% head restores the balance constraint
    assert res.feasible_balance
    assert res.theta <= cfg.theta_max + 1e-9


def test_controller_accepts_callable_algorithm():
    """RebalanceController can run a custom planner callable directly."""
    from repro.core.controller import RebalanceController
    calls = []

    def probe(stats, assignment, config):
        calls.append(stats.num_keys)
        return mixed(stats, assignment, config)

    stats, assignment, cfg = make_instance(1)
    ctl = RebalanceController(assignment, cfg, algorithm=probe)
    ev = ctl.on_interval(stats, force=True)
    assert calls and ev.triggered
    assert ctl.algorithm_name == "probe"
