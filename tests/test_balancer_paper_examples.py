"""Exact reproduction of the paper's worked examples (Fig. 4 and Sec. III-A).

Scenario: keys k1..k6 with costs [7,4,2,1,5,1]; two instances d1=0, d2=1;
hash destinations h = [0,0,0,1,1,1]; initial routing table {k3->1, k5->0}
(so initially d1 holds {k1,k2,k5}=16 and d2 holds {k3,k4,k6}=4);
theta_max = 0 (absolute balance), mean load = 10.
"""

import numpy as np
import pytest

from repro.core.balancer import (Assignment, BalanceConfig, KeyStats, mintable,
                                 metrics)
from repro.core.balancer.hashing import ExplicitHash
from repro.core.balancer.phased import run_phases, finish
import time

K1, K2, K3, K4, K5, K6 = 1, 2, 3, 4, 5, 6


@pytest.fixture()
def fig4():
    stats = KeyStats(
        keys=np.array([K1, K2, K3, K4, K5, K6]),
        cost=np.array([7.0, 4.0, 2.0, 1.0, 5.0, 1.0]),
        mem=np.array([7.0, 4.0, 2.0, 1.0, 5.0, 1.0]),  # w=1, S=c as in Sec. III-B
    )
    router = ExplicitHash({K1: 0, K2: 0, K3: 0, K4: 1, K5: 1, K6: 1}, n_dest=2)
    assignment = Assignment(router, table={K3: 1, K5: 0})
    config = BalanceConfig(theta_max=0.0, table_max=100)
    return stats, assignment, config


def test_initial_loads(fig4):
    stats, assignment, _ = fig4
    loads = metrics.loads(stats, assignment)
    assert loads.tolist() == [16.0, 4.0]


def test_llfd_left_example(fig4):
    """Plain LLFD (no cleaning) ends perfectly balanced with a 4-entry table."""
    stats, assignment, config = fig4
    t0 = time.perf_counter()
    ws = run_phases(stats, assignment, config, psi=stats.cost, clean_idxs=None)
    res = finish(ws, assignment, config, t0)
    assert res.loads.tolist() == [10.0, 10.0]
    assert res.theta == 0.0
    # paper narrative: k1->d2 (exchange {k3}), k3 fails on d1, stays d2
    # (exchange {k4}), k4->d1; k5 keeps its table entry.
    assert res.assignment.table == {K1: 1, K3: 1, K4: 0, K5: 0}
    assert res.table_size == 4


def test_llfd_narrative_steps(fig4):
    """The internal trace matches Sec. III-A: E={k3} then E={k4}."""
    stats, assignment, config = fig4
    ws = run_phases(stats, assignment, config, psi=stats.cost, clean_idxs=None)
    final = {int(k): int(d) for k, d in zip(stats.keys, ws.assign)}
    assert final == {K1: 1, K2: 0, K3: 1, K4: 0, K5: 0, K6: 1}


def test_mintable_right_example(fig4):
    """MinTable cleans A first and reaches balance with only 2 entries."""
    stats, assignment, config = fig4
    res = mintable(stats, assignment, config)
    assert res.loads.tolist() == [10.0, 10.0]
    assert res.theta == 0.0
    assert res.table_size == 2
    assert res.assignment.table == {K2: 1, K4: 0}
    # final placement is the partition d1={k1,k3,k4}, d2={k2,k5,k6}
    dest = res.assignment.dest(stats.keys)
    assert dest.tolist() == [0, 1, 0, 0, 1, 1]


def test_mintable_cleaning_costs_more_migration(fig4):
    """Fig. 4's tradeoff: MinTable's table is smaller, but it migrates more
    state than plain LLFD starting from the existing table."""
    stats, assignment, config = fig4
    t0 = time.perf_counter()
    ws = run_phases(stats, assignment, config, psi=stats.cost, clean_idxs=None)
    res_llfd = finish(ws, assignment, config, t0)
    res_mt = mintable(stats, assignment, config)
    assert res_mt.table_size < res_llfd.table_size
    assert res_mt.migration_cost >= res_llfd.migration_cost


def test_gamma_example():
    """Sec. III-B: beta=1 -> gamma(k1)=gamma(k2)=1; beta=0.5 -> k2 first."""
    stats = KeyStats(keys=np.array([K1, K2]), cost=np.array([7.0, 4.0]),
                     mem=np.array([7.0, 4.0]))
    g1 = stats.gamma(1.0)
    assert g1[0] == pytest.approx(1.0) and g1[1] == pytest.approx(1.0)
    g05 = stats.gamma(0.5)
    assert g05[1] > g05[0]


# -- competing partitioners: the papers' worked examples ----------------------
#
# The comparison baselines are pinned to the published headline numbers, not
# just self-consistency: PKG (ICDE'15, arXiv:1504.00788 / 1510.07623) bounds
# the hot key's per-worker share at p1/2 where key grouping pays p1; W-Choices
# (arXiv:1510.05714) shows two choices stop working once p1 > 2/W and spreads
# head keys over all W workers for a p1/W share. `candidate_fn` pins the
# candidate sets so the arithmetic matches the papers' examples exactly.

from repro.core.balancer import (ChoiceRouter, ModHash, PartialKeyGrouping,
                                 PowerOfBothChoices, WChoices)


def _loads_for(router, keys, n_dest):
    dests = router.route(np.asarray(keys, dtype=np.int64))
    return np.bincount(dests, minlength=n_dest).tolist()


def test_pkg_halves_the_hot_key():
    """1504.00788 Sec. 3: key grouping's max load is p1*n; PKG's two choices
    cut the hot key's contribution to exactly p1*n/2 per worker."""
    n = 1000
    stream = np.zeros(n, dtype=np.int64)          # one key, p1 = 1
    kg = ChoiceRouter(n_choices=1, candidate_fn=lambda uk: [[0]] * len(uk))
    kg.bind(Assignment(ModHash(2)))
    assert _loads_for(kg, stream, 2) == [1000, 0]        # KG: p1*n on one task
    pkg = PartialKeyGrouping(candidate_fn=lambda uk: [[0, 1]] * len(uk))
    pkg.bind(Assignment(ModHash(2)))
    assert _loads_for(pkg, stream, 2) == [500, 500]      # PKG: p1*n/2 each


def test_pkg_disjoint_pairs_split_evenly():
    """Two keys at 80/20 with disjoint candidate pairs: each key's tuples
    split in half over its own pair — loads [400, 400, 100, 100]."""
    stream = np.array([0] * 800 + [1] * 200, dtype=np.int64)
    pkg = PartialKeyGrouping(
        candidate_fn=lambda uk: [[0, 1] if k == 0 else [2, 3] for k in uk])
    pkg.bind(Assignment(ModHash(4)))
    assert _loads_for(pkg, stream, 4) == [400, 400, 100, 100]
    assert metrics.theta(pkg.loads) == pytest.approx(0.6)    # 400/250 - 1


def test_potc_local_estimates_reach_the_same_split():
    """1504.00788's point: each source routing on its OWN load estimates
    (no coordination) still halves the hot key — 2 sources each split their
    share of the stream, summing to the same [n/2, n/2]."""
    stream = np.zeros(1000, dtype=np.int64)
    potc = PowerOfBothChoices(
        n_sources=2, candidate_fn=lambda uk: [[0, 1]] * len(uk))
    potc.bind(Assignment(ModHash(2)))
    assert _loads_for(potc, stream, 2) == [500, 500]
    # and each source's local view accounts for exactly its half
    assert potc._src_loads.sum(axis=1).tolist() == [500.0, 500.0]


def test_wchoices_beats_two_choices_on_an_extreme_head():
    """1510.05714's worked point: with p1 > 2/W two choices bottom out at
    p1*n/2, while W-Choices spreads the head key over all W workers for
    p1*n/W — here W=5, n=1000: 500 vs 200."""
    W, n = 5, 1000
    stream = np.zeros(n, dtype=np.int64)
    stats = KeyStats(keys=np.array([0]), cost=np.array([float(n)]),
                     mem=np.array([1.0]), freq=np.array([float(n)]))
    pkg = PartialKeyGrouping(candidate_fn=lambda uk: [[0, 1]] * len(uk))
    pkg.bind(Assignment(ModHash(W)))
    assert max(_loads_for(pkg, stream, W)) == n // 2         # p1*n/2
    w = WChoices(candidate_fn=lambda uk: [[0, 1]] * len(uk))
    w.bind(Assignment(ModHash(W)))
    w.on_stats(stats)                  # head detection from interval stats
    assert w.head_keys.tolist() == [0]
    assert _loads_for(w, stream, W) == [n // W] * W          # p1*n/W each
    assert metrics.theta(w.loads) == 0.0
