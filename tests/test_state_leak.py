"""Regression: fully-evicted keys must leave the state store (and the stat
universe) instead of accumulating forever.

Before the fix, a key whose window slices all expired kept an empty
``KeyState`` in ``TaskStateStore.keys``, so ``end_interval_collect`` /
``sizes_arrays`` and the step-1 stat universe grew monotonically on long
runs with churning key populations, inflating planner input without bound.
"""

import numpy as np

from repro.core import (Assignment, BalanceConfig, ModHash,
                        RebalanceController)
from repro.streams import KeyedStage, WordCount
from repro.streams.state import TaskStateStore


def test_store_drops_fully_evicted_keys():
    store = TaskStateStore(window=2)
    store.state(7).slice_for(1, dict, size=4.0)
    store.state(9).slice_for(2, dict, size=2.0)
    store.end_interval(2)                      # key 7 still in window (w=2)
    assert set(store.keys) == {7, 9}
    store.end_interval(3)                      # key 7's last slice expires
    assert set(store.keys) == {9}
    store.end_interval(5)
    assert not store.keys


def test_collect_drops_and_reports_consistently():
    store = TaskStateStore(window=1)
    store.state(1).slice_for(1, dict, size=3.0)
    store.state(2).slice_for(2, dict, size=5.0)
    keys, sizes = store.end_interval_collect(2)  # key 1 expired, key 2 lives
    assert keys.tolist() == [2]
    assert sizes.tolist() == [5.0]
    assert set(store.keys) == {2}
    keys, sizes = store.end_interval_collect(3)
    assert keys.size == 0 and sizes.size == 0
    assert not store.keys


def _make_stage(vectorized, n_tasks=4, window=2):
    controller = RebalanceController(
        Assignment(ModHash(n_tasks, seed=1)),
        BalanceConfig(theta_max=0.08, table_max=200, window=window),
        algorithm="mixed")
    return KeyedStage(WordCount(), controller, window=window,
                      vectorized=vectorized)


def test_long_run_state_keys_stay_bounded():
    """Disjoint key waves per interval: live state is at most `window` waves'
    worth of keys, in both engine paths, no matter how many intervals ran."""
    wave = 64
    window = 2
    stages = [_make_stage(v, window=window) for v in (True, False)]
    rng = np.random.default_rng(0)
    for iv in range(12):
        base = iv * wave
        keys = rng.integers(base, base + wave, size=600).astype(np.int64)
        for stage in stages:
            stage.process_interval_arrays(keys.copy(), None)
        bound = window * wave
        for stage in stages:
            assert stage.total_state_keys() <= bound, iv
    vec, ref = stages
    # the leak fix keeps the two engine paths in lockstep
    assert vec.total_state_keys() == ref.total_state_keys()
    for rv, rr in zip(vec.reports, ref.reports):
        assert rv.tuples == rr.tuples
        assert rv.table_size == rr.table_size
        np.testing.assert_array_equal(rv.task_loads, rr.task_loads)
