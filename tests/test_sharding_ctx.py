"""Seed-module coverage: sharding/ctx.py, sharding/rules.py, launch/mesh.py.

These modules shipped with the seed and back the sharded stream backend
(``streams/sharded.py`` builds its 1-D mesh with ``launch.mesh.make_mesh``),
so their contracts — thread-local mesh context restore, logical-axis
resolution fallbacks, divisibility-based replication — get pinned here.

``_resolve``/``dp_degree``/``batch_pspec``/``cache_rules`` only consult
``mesh.axis_names`` and ``mesh.shape``, so multi-axis meshes are stubbed —
the suite exercises 16-way production shapes without needing 256 devices.
"""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
jnp = jax.numpy

from repro.launch.mesh import (data_axes, make_mesh, make_production_mesh,
                               model_axes)
from repro.sharding.ctx import _resolve, constrain, current_mesh, use_mesh
from repro.sharding.rules import batch_pspec, cache_rules, dp_degree


class StubMesh:
    """axis_names + shape are all the resolution logic reads."""

    def __init__(self, **axes):
        self.axis_names = tuple(axes)
        self.shape = dict(axes)


# -- ctx: thread-local mesh context -------------------------------------------

def test_use_mesh_nesting_and_restore():
    assert current_mesh() is None
    m1, m2 = StubMesh(data=1), StubMesh(model=1)
    with use_mesh(m1):
        assert current_mesh() is m1
        with use_mesh(m2):
            assert current_mesh() is m2
        assert current_mesh() is m1          # inner exit restores outer
        with use_mesh(None):                 # explicit suspension nests too
            assert current_mesh() is None
        assert current_mesh() is m1
    assert current_mesh() is None


def test_use_mesh_restores_on_exception():
    with pytest.raises(RuntimeError):
        with use_mesh(StubMesh(data=2)):
            raise RuntimeError("boom")
    assert current_mesh() is None


# -- ctx: logical axis resolution ---------------------------------------------

def test_resolve_logical_names_and_fallbacks():
    mesh = StubMesh(pod=2, data=4, model=8)
    # dp spans (pod, data) when both exist; multi-axis results stay tuples
    assert _resolve(mesh, "dp", 16) == ("pod", "data")
    assert _resolve(mesh, "tp", 16) == "model"
    assert _resolve(mesh, "sp", 16) == "data"
    # raw mesh axis names pass through
    assert _resolve(mesh, "model", 16) == "model"
    # None dim and unknown axes resolve to replicated
    assert _resolve(mesh, None, 16) is None
    assert _resolve(mesh, "no_such_axis", 16) is None


def test_resolve_missing_axes_and_divisibility():
    data_only = StubMesh(data=4)
    # tp -> ("model",) filtered against the mesh leaves nothing: replicate
    assert _resolve(data_only, "tp", 16) is None
    # dp on a data-only mesh drops the missing pod axis
    assert _resolve(data_only, "dp", 16) == "data"
    # indivisible dim sizes replicate instead of erroring (qwen2's 28 heads
    # on a 16-way axis is the motivating case)
    assert _resolve(data_only, "dp", 6) is None
    assert _resolve(data_only, "dp", 8) == "data"
    # multi-axis divisibility uses the PRODUCT of the spanned axes
    pod_data = StubMesh(pod=2, data=4)
    assert _resolve(pod_data, "dp", 8) == ("pod", "data")
    assert _resolve(pod_data, "dp", 4) is None
    # size=None skips the divisibility check entirely
    assert _resolve(pod_data, "dp", None) == ("pod", "data")


# -- ctx: constrain ------------------------------------------------------------

def test_constrain_is_noop_without_mesh():
    x = jnp.arange(6.0).reshape(2, 3)
    assert constrain(x, "dp", "tp") is x


def test_constrain_rank_mismatch_asserts():
    with use_mesh(make_mesh((1,), ("data",))):
        with pytest.raises(AssertionError):
            constrain(jnp.zeros((2, 3)), "dp")


def test_constrain_applies_and_dedups_used_axes():
    mesh = make_mesh((min(2, jax.device_count()),), ("data",))
    x = jnp.arange(8.0).reshape(4, 2)
    with use_mesh(mesh):
        y = constrain(x, "dp", "sp")     # sp also resolves to "data": deduped
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
    # first dim claimed "data"; the duplicate second dim fell back to None
    # (1-device meshes may normalize the constraint away entirely)
    spec = getattr(y.sharding, "spec", None)
    if spec is not None:
        parts = tuple(spec)
        assert parts and parts[0] in ("data", ("data",))
        assert all(p is None for p in parts[1:])


# -- rules: DP degree + batch/cache fallbacks ---------------------------------

def test_dp_degree_and_batch_pspec():
    mesh = StubMesh(pod=2, data=4, model=8)
    assert dp_degree(mesh) == 8
    assert batch_pspec(mesh, 16) == jax.sharding.PartitionSpec(
        ("pod", "data"), None)
    # global_batch below/indivisible by the DP degree: replicated fallback
    assert batch_pspec(mesh, 1) == jax.sharding.PartitionSpec(None, None)
    assert batch_pspec(mesh, 12) == jax.sharding.PartitionSpec(None, None)


def test_cache_rules_sp_fallback():
    mesh = StubMesh(pod=2, data=4, model=8)
    ok = cache_rules(mesh, global_batch=16)
    assert ok["batch"] == ("pod", "data")
    assert ok["kv_seq"] is None
    assert ok["embed"] is None               # cache activations never FSDP
    # batch 1 cannot shard over DP=8: batch replicates, the kv sequence
    # shards over "data" instead (sequence-parallel cache)
    sp = cache_rules(mesh, global_batch=1)
    assert sp["batch"] is None
    assert sp["kv_seq"] == "data"


# -- launch/mesh helpers -------------------------------------------------------

def test_make_mesh_and_axis_helpers():
    mesh = make_mesh((jax.device_count(),), ("shard",))
    assert mesh.axis_names == ("shard",)
    assert mesh.shape["shard"] == jax.device_count()
    assert data_axes(mesh) == ()             # no pod/data axis on this mesh
    assert model_axes(mesh) == ()
    stub = StubMesh(pod=2, data=16, model=16)
    assert data_axes(stub) == ("pod", "data")
    assert model_axes(stub) == ("model",)


def test_make_production_mesh_requires_pod_scale():
    if jax.device_count() >= 256:            # pragma: no cover - real pod
        assert make_production_mesh().axis_names == ("data", "model")
    else:
        with pytest.raises(ValueError):
            make_production_mesh()
