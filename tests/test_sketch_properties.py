"""Property-based tests (hypothesis) for the sketch stats guarantees.

The three ISSUE-level contracts, asserted over randomized streams:

* count-min never underestimates and overestimates by at most the
  colliding mass (``<= N / 256`` at width 4096 x depth 4 on <= 64 keys —
  in practice exact, the bound is generous);
* SpaceSaving: estimates are upper bounds with error ``<= N / (H + 1)``,
  and every key with true weight ``> N / H`` is tracked;
* head-key stats with ``err == 0`` are bit-identical to exact dict
  counting — the invariant that lets sketch-mode planners treat the head
  as exact — on zipf and drifting streams fed in engine-sized chunks.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")   # optional [test] extra
from hypothesis import given, settings, strategies as st

from repro.core.balancer import (Assignment, CountMinSketch, ModHash,
                                 SketchConfig, SketchStats,
                                 SpaceSavingTracker)


# ---------------------------------------------------------------------------
# stream generators
# ---------------------------------------------------------------------------

@st.composite
def zipf_streams(draw):
    seed = draw(st.integers(0, 2**31 - 1))
    k = draw(st.integers(50, 2_000))
    z = draw(st.sampled_from([1.1, 1.3, 1.8]))
    n = draw(st.integers(1_000, 20_000))
    rng = np.random.default_rng(seed)
    keys = (rng.zipf(z, size=n) % k).astype(np.int64)
    weights = rng.integers(1, 8, size=n).astype(np.float64)
    return keys, weights, seed


@st.composite
def drift_streams(draw):
    """Two zipf phases over shifted key ranges — the fluctuation shape."""
    seed = draw(st.integers(0, 2**31 - 1))
    k = draw(st.integers(100, 1_000))
    n = draw(st.integers(2_000, 10_000))
    rng = np.random.default_rng(seed)
    a = (rng.zipf(1.3, size=n // 2) % k).astype(np.int64)
    b = ((rng.zipf(1.3, size=n - n // 2) % k) + k // 3).astype(np.int64)
    keys = np.concatenate([a, b])
    weights = np.ones(keys.size)
    return keys, weights, seed


def _chunks(arr, size=1_500):
    for lo in range(0, arr.shape[0], size):
        yield slice(lo, lo + size)


def _true_counts(keys, weights):
    uk, inv = np.unique(keys, return_inverse=True)
    return uk, np.bincount(inv, weights=weights)


# ---------------------------------------------------------------------------
# count-min
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 64), st.integers(100, 5_000))
def test_cms_bounds(seed, n_keys, n_tuples):
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, n_keys, size=n_tuples).astype(np.int64)
    weights = rng.integers(1, 10, size=n_tuples).astype(np.float64)
    cms = CountMinSketch(4_096, 4, seed=seed % 97)
    for sl in _chunks(keys):
        cms.update(keys[sl], cost=weights[sl])
    uk, true = _true_counts(keys, weights)
    est = cms.query(uk, "cost")
    total = float(weights.sum())
    assert np.all(est >= true - 1e-9)                 # never underestimates
    assert np.all(est - true <= total / 256 + 1e-9)   # eps * N


# ---------------------------------------------------------------------------
# SpaceSaving
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(zipf_streams(), st.sampled_from([8, 32]))
def test_spacesaving_bounds(stream, capacity):
    keys, weights, _ = stream
    tr = SpaceSavingTracker(capacity)
    for sl in _chunks(keys):
        tr.update(keys[sl], weights[sl])
    uk, true = _true_counts(keys, weights)
    total = float(weights.sum())
    assert tr.total == pytest.approx(total)
    assert len(tr) <= capacity
    assert tr.offset <= total / (capacity + 1) + 1e-9
    est = tr.estimate(uk)
    assert np.all(est >= true - 1e-9)                 # upper bound
    assert np.all(est - true <= tr.offset + 1e-9)     # error <= offset
    heavy = uk[true > total / capacity]               # every hitter captured
    assert np.isin(heavy, tr.keys).all()


# ---------------------------------------------------------------------------
# head-key exactness through the full adapter
# ---------------------------------------------------------------------------

def _assert_exact_head(keys, weights, seed, capacity):
    assignment = Assignment(ModHash(7, seed=seed % 13))
    ss = SketchStats(SketchConfig(width=1 << 13, depth=4, capacity=capacity),
                     assignment.n_dest, seed=seed % 1_000)
    mem = np.ones(keys.size)
    for sl in _chunks(keys):
        ss.update(keys[sl], assignment.dest(keys[sl]), weights[sl],
                  mem=mem[sl], freq=mem[sl])
    uk, true_cost = _true_counts(keys, weights)
    _, true_freq = _true_counts(keys, np.ones(keys.size))
    snap = ss.snapshot(assignment)
    # exact-mask entries are bit-identical to dict counting
    tr = ss.tracker
    exact_keys = tr.keys[tr.exact_mask]
    if exact_keys.size:
        in_true = np.searchsorted(uk, exact_keys)
        in_snap = np.searchsorted(snap.keys, exact_keys)
        np.testing.assert_array_equal(snap.cost[in_snap], true_cost[in_true])
        np.testing.assert_array_equal(snap.freq[in_snap], true_freq[in_true])
    # and the exact per-destination identity always holds
    true_loads = np.bincount(assignment.dest(keys), weights=weights,
                             minlength=assignment.n_dest)
    head_loads = np.bincount(assignment.dest(snap.keys), weights=snap.cost,
                             minlength=assignment.n_dest)
    assert snap.base_loads is not None
    assert np.all(snap.base_loads >= -1e-9)
    # base + head >= true everywhere (head estimates only overcount), and
    # equality wherever no clipping occurred
    assert np.all(head_loads + snap.base_loads >= true_loads - 1e-6)


@settings(max_examples=30, deadline=None)
@given(zipf_streams(), st.sampled_from([16, 256]))
def test_head_exactness_zipf(stream, capacity):
    keys, weights, seed = stream
    _assert_exact_head(keys, weights, seed, capacity)


@settings(max_examples=30, deadline=None)
@given(drift_streams(), st.sampled_from([16, 256]))
def test_head_exactness_drift(stream, capacity):
    keys, weights, seed = stream
    _assert_exact_head(keys, weights, seed, capacity)


@settings(max_examples=30, deadline=None)
@given(zipf_streams())
def test_covering_capacity_is_fully_exact(stream):
    """With capacity >= distinct keys the whole snapshot equals exact
    counting — the invariant the engine parity tests lean on."""
    keys, weights, seed = stream
    uk, true_cost = _true_counts(keys, weights)
    assignment = Assignment(ModHash(5, seed=1))
    ss = SketchStats(SketchConfig(width=1 << 13, depth=4,
                                  capacity=int(uk.size)),
                     assignment.n_dest, seed=seed % 1_000)
    for sl in _chunks(keys):
        ss.update(keys[sl], assignment.dest(keys[sl]), weights[sl])
    snap = ss.snapshot(assignment)
    np.testing.assert_array_equal(snap.keys, uk)
    np.testing.assert_array_equal(snap.cost, true_cost)
    np.testing.assert_allclose(snap.base_loads,
                               np.zeros(assignment.n_dest), atol=1e-9)
