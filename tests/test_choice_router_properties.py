"""Choice-router invariants: PKG / Power of Both Choices / W-Choices.

Property-based (Hypothesis) coverage of the papers' claims on adversarial
zipf streams (engine-level integration without the extras lives in
``test_choice_routers.py``):

* candidate sets are stable per key (pure hash functions — identical across
  batches and fresh router instances) and every routed destination is drawn
  from the tuple's candidate set;
* loads stay within the papers' bounds: the aggregate max load tracks
  ``max(n/W, max_k count_k / distinct_candidates_k)`` (the structural floor —
  a key can only spread over its candidates, and colliding hashes shrink
  that set), and the hot key itself splits near-evenly across its candidates;
* PoTC with one source is bit-identical to PKG (the 1504.00788 paper's
  "both choices" policy *is* PKG's; multiple sources only localize the load
  estimates);
* a split stage under a router + downstream merge matches the single-route
  oracle exactly (the Fig. 2a dataflow of 1510.07623).
"""

import numpy as np
import pytest

from repro.core.balancer import Assignment, KeyStats, ModHash
from repro.core.balancer.strategy import (PartialKeyGrouping,
                                          PowerOfBothChoices, WChoices)
from repro.streams import (PartialWordCount, WordCount, keyed_stage,
                           router_merge_topology)

pytest.importorskip("hypothesis")   # optional [test] extra
from hypothesis import given, settings, strategies as st


def _zipf_keys(seed, z, n, domain):
    rng = np.random.default_rng(seed)
    return ((rng.zipf(z, size=n) - 1) % domain).astype(np.int64)


ROUTER_CASES = st.tuples(
    st.integers(0, 2**31 - 1),            # stream seed
    st.floats(1.05, 2.6),                 # zipf exponent (adversarial skew)
    st.integers(500, 4000),               # tuples
    st.sampled_from([40, 300, 1500]),     # key domain
    st.sampled_from([4, 8, 16]),          # workers
)


# -- candidate-set stability ---------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(ROUTER_CASES, st.sampled_from(["pkg", "potc", "wchoices"]))
def test_candidates_stable_and_contain_destinations(case, name):
    seed, z, n, domain, W = case
    keys = _zipf_keys(seed, z, n, domain)
    a = Assignment(ModHash(W, seed=seed % 997))
    make = {"pkg": PartialKeyGrouping,
            "potc": PowerOfBothChoices,
            "wchoices": WChoices}[name]
    r1, r2 = make(), make()
    r1.bind(a)
    r2.bind(a)
    c1 = r1.candidates(keys)
    assert np.array_equal(c1, r2.candidates(keys))           # instance-stable
    assert np.array_equal(c1, r1.candidates(keys))           # batch-stable
    assert c1.shape == (n, 2) and (0 <= c1).all() and (c1 < W).all()
    d = r1.route(keys)
    # tail routing: every destination from the 2-candidate set (wchoices has
    # no head yet — no stats seen — so it degrades to exactly PKG's sets)
    assert ((d == c1[:, 0]) | (d == c1[:, 1])).all()
    assert np.bincount(d, minlength=W).sum() == n
    assert np.array_equal(np.bincount(d, minlength=W), r1.loads)


# -- load bounds on adversarial zipf ------------------------------------------

@settings(max_examples=25, deadline=None)
@given(ROUTER_CASES)
def test_pkg_loads_within_structural_bound(case):
    seed, z, n, domain, W = case
    keys = _zipf_keys(seed, z, n, domain)
    pkg = PartialKeyGrouping()
    pkg.bind(Assignment(ModHash(W, seed=seed % 997)))
    d = pkg.route(keys)
    loads = np.bincount(d, minlength=W)
    uk, cnt = np.unique(keys, return_counts=True)
    du = np.array([len(set(row)) for row in pkg.candidates(uk).tolist()])
    # the structural floor: perfect balance is n/W, but a key can only spread
    # over its distinct candidates (two hashes may collide: du == 1)
    floor = max(n / W, float((cnt / du).max()))
    assert loads.max() <= floor * 1.5 + pkg.chunk
    # the hot key itself splits near-evenly over its candidates (round-robin
    # from the least-loaded one; staleness costs at most one per chunk)
    hot = int(np.argmax(cnt))
    n_chunks = -(-n // pkg.chunk)
    hot_share = np.bincount(d[keys == uk[hot]], minlength=W).max()
    assert hot_share <= -(-int(cnt[hot]) // int(du[hot])) + n_chunks


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.floats(1.6, 2.8),
       st.sampled_from([8, 16]))
def test_wchoices_flattens_head_keys(seed, z, W):
    n, domain = 4000, 300
    keys = _zipf_keys(seed, z, n, domain)
    w = WChoices(head_threshold=0.01)
    w.bind(Assignment(ModHash(W, seed=seed % 997)))
    w.route(keys)                               # interval 1: PKG-equivalent
    uk, cnt = np.unique(keys, return_counts=True)
    w.on_stats(KeyStats(keys=uk, cost=cnt.astype(float),
                        mem=np.ones(uk.size), freq=cnt.astype(float)))
    assert w.head_keys.size >= 1                # zipf >= 1.6 has a clear head
    keys2 = _zipf_keys(seed + 1, z, n, domain)
    before = w.loads.copy()
    d2 = w.route(keys2)
    loads2 = np.bincount(d2, minlength=W)
    assert np.array_equal(w.loads - before, loads2)
    # every head key spreads over ALL W workers, so its per-worker share is
    # ~count/W — two choices could never do better than count/2
    n_chunks = -(-n // w.chunk)
    head = set(w.head_keys.tolist())
    for k in head:
        kcnt = int((keys2 == k).sum())
        if kcnt < W:
            continue
        share = np.bincount(d2[keys2 == k], minlength=W).max()
        assert share <= -(-kcnt // W) + n_chunks


# -- PoTC locality claim -------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(ROUTER_CASES)
def test_potc_single_source_is_pkg(case):
    seed, z, n, domain, W = case
    keys = _zipf_keys(seed, z, n, domain)
    a = Assignment(ModHash(W, seed=seed % 997))
    pkg = PartialKeyGrouping()
    potc = PowerOfBothChoices(n_sources=1)
    pkg.bind(a)
    potc.bind(a)
    assert np.array_equal(pkg.route(keys), potc.route(keys))
    assert np.array_equal(pkg.loads, potc.loads)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([2, 4, 8]))
def test_potc_sources_partition_the_stream(seed, S):
    keys = _zipf_keys(seed, 1.4, 3000, 200)
    potc = PowerOfBothChoices(n_sources=S)
    potc.bind(Assignment(ModHash(8, seed=1)))
    d = potc.route(keys)
    cand = potc.candidates(keys)
    assert ((d == cand[:, 0]) | (d == cand[:, 1])).all()
    # per-source local estimates sum to the true routed loads
    assert potc._src_loads.shape == (S, 8)
    assert np.array_equal(potc.loads, np.bincount(d, minlength=8))


# -- merge-stage oracle --------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.floats(1.1, 2.2),
       st.sampled_from(["pkg", "potc", "wchoices"]))
def test_router_plus_merge_matches_single_route_oracle(seed, z, algo):
    """Split word count under a router + WordCount merge == plain WordCount.

    Stage 1 emits exactly one increment per input tuple keyed by the same
    key, so the merge stage's per-(key, window) totals are exact tuple
    counts no matter how the router split the key — the summed emit stream
    (sum of running counts = sum over keys of c(c+1)/2 per window, an
    order-insensitive exactness witness) must match the single-route
    pipeline bit-for-bit.
    """
    topo = router_merge_topology(PartialWordCount(), WordCount(), 8, 0.08,
                                 algorithm=algo, window=2, seed=seed % 997)
    oracle = keyed_stage(WordCount(), n_tasks=8, theta_max=0.08,
                         algorithm="mixed", window=2, seed=seed % 997)
    for iv in range(3):
        keys = _zipf_keys(seed + iv, z, 1500, 250)
        topo.process_interval(keys)
        oracle.process_interval_arrays(keys)
    assert topo["merge"].emitted_sum == oracle.emitted_sum
    # routers never plan: no migration, no table, no pause
    split = topo["split"]
    assert all(r.migrated_bytes == 0.0 for r in split.reports)
    assert all(r.table_size == 0 for r in split.reports)
    assert all(r.buffered == 0 for r in split.reports)
    assert not split.controller.triggered_intervals()


