"""Per-architecture smoke tests on reduced configs: one forward + one train
gradient + a prefill/decode consistency check on CPU; asserts shapes and the
absence of NaNs. The FULL configs are exercised only via the dry-run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_config
from repro.models import (forward, init_cache, lm_loss, logits_from_hidden,
                          model_schema, schema)

B, T = 2, 32


def make_batch(cfg, rng):
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, T)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, T)), jnp.int32),
    }
    if cfg.frontend == "audio_stub":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.encoder_seq, cfg.d_model)),
            jnp.bfloat16)
    elif cfg.frontend == "vision_stub":
        batch["pixel_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.prefix_len, cfg.d_model)),
            jnp.bfloat16)
    return batch


@pytest.fixture()
def rng():
    # function-scoped: batches must not depend on which tests ran before
    # (a module-scoped stream made results order-dependent, so tests could
    # pass in isolation and fail in the full suite)
    return np.random.default_rng(0)


# The big configs dominate suite wall time (minutes each on CPU); tier-1
# deselects them via the `slow` marker (see pyproject.toml).
_HEAVY = {"jamba_1_5_large_398b", "gemma3_12b", "whisper_large_v3"}


def _arch_params(archs):
    return [pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY else a
            for a in archs]


@pytest.mark.parametrize("arch", _arch_params(ARCHS))
def test_forward_and_loss(arch, rng):
    cfg = smoke_config(arch)
    cfg.validate()
    params = schema.init(model_schema(cfg), jax.random.PRNGKey(0))
    batch = make_batch(cfg, rng)
    hidden, _ = forward(params, cfg, batch, remat=False)
    t_expect = T + (cfg.prefix_len if cfg.frontend == "vision_stub" else 0)
    assert hidden.shape == (B, t_expect, cfg.d_model)
    assert not bool(jnp.any(jnp.isnan(hidden.astype(jnp.float32))))
    loss = lm_loss(params, cfg, batch, remat=False)
    assert np.isfinite(float(loss))
    # untrained CE should be near ln(vocab)
    assert float(loss) < np.log(cfg.vocab) * 3


@pytest.mark.parametrize("arch", _arch_params(ARCHS))
def test_train_gradient_finite(arch, rng):
    cfg = smoke_config(arch)
    params = schema.init(model_schema(cfg), jax.random.PRNGKey(1))
    batch = make_batch(cfg, rng)
    loss, grads = jax.value_and_grad(
        lambda p: lm_loss(p, cfg, batch, remat=True))(params)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in flat)
    # gradients actually flow to the first and last parameter groups
    norms = [float(jnp.linalg.norm(g.astype(jnp.float32))) for g in flat]
    assert sum(1 for n_ in norms if n_ > 0) > len(norms) * 0.5


@pytest.mark.parametrize("arch", _arch_params(
    ["gemma3_12b", "qwen2_7b", "xlstm_125m", "jamba_1_5_large_398b",
     "whisper_large_v3"]))
def test_prefill_then_decode_matches_full_forward(arch, rng):
    """Teacher-forced decode through the cache must reproduce the full-seq
    forward logits (the serve path's correctness invariant)."""
    cfg = smoke_config(arch)
    params = schema.init(model_schema(cfg), jax.random.PRNGKey(2))
    batch = make_batch(cfg, rng)
    tokens = batch["tokens"]
    full_batch = dict(batch)
    hidden_full, _ = forward(params, cfg, full_batch, remat=False)
    logits_full = logits_from_hidden(params, cfg, hidden_full)

    max_seq = T + (cfg.prefix_len if cfg.frontend == "vision_stub" else 0)
    cache = init_cache(cfg, B, max_seq)
    t_pre = T // 2
    pre_batch = dict(batch)
    pre_batch["tokens"] = tokens[:, :t_pre]
    hidden_pre, cache = forward(params, cfg, pre_batch, cache=cache,
                                cache_index=0, remat=False)
    logits = [logits_from_hidden(params, cfg, hidden_pre)]
    idx = t_pre + (cfg.prefix_len if cfg.frontend == "vision_stub" else 0)
    step_batch = dict(batch)
    step_batch.pop("pixel_embeds", None)   # vision prefix only at prefill
    for t in range(t_pre, T):
        step_batch["tokens"] = tokens[:, t:t + 1]
        h, cache = forward(params, cfg, step_batch, cache=cache,
                           cache_index=idx, remat=False)
        logits.append(logits_from_hidden(params, cfg, h))
        idx += 1
    logits_inc = jnp.concatenate(logits, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_inc, np.float32),
        np.asarray(logits_full, np.float32), atol=0.3, rtol=0.05)


def test_vocab_padding_masked(rng):
    cfg = smoke_config("internvl2_1b")
    assert cfg.vocab_padded > cfg.vocab
    params = schema.init(model_schema(cfg), jax.random.PRNGKey(3))
    batch = make_batch(cfg, rng)
    hidden, _ = forward(params, cfg, batch, remat=False)
    logits = logits_from_hidden(params, cfg, hidden)
    pad_logits = np.asarray(logits[..., cfg.vocab:], np.float32)
    assert (pad_logits < -1e29).all()


def test_label_masking(rng):
    cfg = smoke_config("granite_8b")
    params = schema.init(model_schema(cfg), jax.random.PRNGKey(4))
    batch = make_batch(cfg, rng)
    batch["labels"] = batch["labels"].at[:, T // 2:].set(-1)
    loss_half = lm_loss(params, cfg, batch, remat=False)
    assert np.isfinite(float(loss_half))
