"""The PartitionStrategy protocol + registry (the `algorithm=` seam).

One spec grammar everywhere: RebalanceController(algorithm=),
KeyedStage(algorithm=) and keyed_stage(algorithm=) accept a registered name,
a bare planner callable, or a configured strategy instance with identical
semantics; the legacy ALGORITHMS dict is a deprecated read-only view.
"""

import warnings

import numpy as np
import pytest

from repro.core import (BalanceConfig, PartialKeyGrouping, PartitionStrategy,
                        PowerOfBothChoices, RebalanceController, TablePlanner,
                        WChoices, resolve_strategy, strategy_names)
from repro.core.balancer import (ALGORITHMS, Assignment, KeyStats, ModHash,
                                 mixed)
from repro.core.balancer.strategy import get_strategy, register_strategy
from repro.streams import PartialWordCount, WordCount, keyed_stage


def _stats(n=16):
    return KeyStats(keys=np.arange(n), cost=np.arange(n) + 1.0,
                    mem=np.ones(n))


# -- registry surface ---------------------------------------------------------

def test_registry_covers_planners_and_routers():
    names = strategy_names()
    assert names == tuple(sorted(names))
    for name in ("mixed", "mintable", "minmig", "readj", "simple",
                 "pkg", "potc", "wchoices"):
        assert name in names


def test_resolve_name_returns_fresh_instances():
    a = resolve_strategy("pkg")
    b = resolve_strategy("pkg")
    assert a is not b                       # routers carry per-controller state
    assert a.is_router and a.needs_merge_stage and not a.plans_migration


def test_resolve_instance_passthrough():
    inst = PowerOfBothChoices(n_sources=2)
    assert resolve_strategy(inst) is inst


def test_resolve_callable_wraps_as_planner():
    strat = resolve_strategy(mixed)
    assert isinstance(strat, TablePlanner)
    assert strat.name == "mixed"
    assert not strat.is_router and strat.plans_migration
    assert strat.fn is mixed


def test_unknown_name_error_lists_registry():
    with pytest.raises(ValueError, match="unknown algorithm"):
        get_strategy("nope")
    try:
        get_strategy("nope")
    except ValueError as e:
        assert str(list(strategy_names())) in str(e)


def test_register_strategy_requires_name():
    class Nameless(PartitionStrategy):
        pass
    with pytest.raises(ValueError, match="non-empty 'name'"):
        register_strategy(Nameless)


def test_capability_flags():
    for name in ("mixed", "mintable", "minmig", "readj"):
        s = resolve_strategy(name)
        assert s.kind == "planner" and s.plans_migration
        assert not s.needs_merge_stage and not s.is_router
    for name in ("pkg", "potc", "wchoices"):
        s = resolve_strategy(name)
        assert s.kind == "router" and s.needs_merge_stage
        assert not s.plans_migration and s.is_router


# -- deprecated ALGORITHMS view ----------------------------------------------

def test_algorithms_view_warns_and_matches_registry():
    with pytest.warns(DeprecationWarning):
        fn = ALGORITHMS["mixed"]
    assert fn is mixed
    with pytest.warns(DeprecationWarning):
        names = set(ALGORITHMS)
    assert names < set(strategy_names())    # planner subset; routers excluded
    assert "pkg" not in names


def test_algorithms_view_read_only():
    assert not hasattr(ALGORITHMS, "__setitem__")
    with pytest.raises(TypeError):
        ALGORITHMS["x"] = mixed             # Mapping: no item assignment


def test_import_does_not_warn():
    # the view only warns on *access*; importing the package must stay quiet
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        import importlib
        import repro.core
        importlib.reload(repro.core)


# -- controller resolution ----------------------------------------------------

def test_controller_accepts_name_callable_instance():
    cfg = BalanceConfig(theta_max=0.08)
    by_name = RebalanceController(Assignment(ModHash(4)), cfg,
                                  algorithm="mixed")
    by_call = RebalanceController(Assignment(ModHash(4)), cfg, algorithm=mixed)
    by_inst = RebalanceController(Assignment(ModHash(4)), cfg,
                                  algorithm=TablePlanner(mixed))
    assert (by_name.algorithm_name == by_call.algorithm_name
            == by_inst.algorithm_name == "mixed")
    st = _stats()
    evs = [c.on_interval(st, force=True)
           for c in (by_name, by_call, by_inst)]
    r0 = evs[0].result
    for ev in evs[1:]:
        assert ev.result.theta == r0.theta
        assert np.array_equal(ev.result.moved_keys, r0.moved_keys)


def test_controller_unknown_name_lists_strategies():
    with pytest.raises(ValueError, match="pkg"):
        RebalanceController(Assignment(ModHash(4)), BalanceConfig(),
                            algorithm="not_a_strategy")


def test_controller_callable_name_passthrough():
    def probe(stats, assignment, config):            # pragma: no cover
        raise AssertionError
    c = RebalanceController(Assignment(ModHash(4)), BalanceConfig(),
                            algorithm=probe)
    assert c.algorithm_name == "probe"


def test_router_controller_never_triggers_or_rescales():
    c = RebalanceController(Assignment(ModHash(8)), BalanceConfig(),
                            algorithm="pkg")
    st = _stats()
    assert not c.should_trigger(st)
    ev = c.on_interval(st, force=True)               # force cannot plan either
    assert not ev.triggered and ev.result is None
    with pytest.raises(ValueError, match="choice router"):
        c.rescale(12, st)


# -- engine-level unification -------------------------------------------------

def test_keyed_stage_accepts_strategy_instance():
    stage = keyed_stage(PartialWordCount(), n_tasks=6, theta_max=0.08,
                        algorithm=PowerOfBothChoices(n_sources=2))
    assert stage.controller.algorithm_name == "potc"
    assert stage.controller.strategy.n_dest == 6     # bound to the assignment
    rep = stage.process_interval_arrays(np.arange(300, dtype=np.int64) % 40)
    assert rep.tuples == 300 and rep.migrated_bytes == 0.0


def test_keyed_stage_algorithm_override_kwarg():
    from repro.streams import KeyedStage
    c = RebalanceController(Assignment(ModHash(4)), BalanceConfig(),
                            algorithm="mixed")
    stage = KeyedStage(PartialWordCount(), c, algorithm="pkg")
    assert c.algorithm_name == "pkg" and c.strategy.is_router
    assert stage.controller is c


def test_router_requires_split_safe_operator():
    with pytest.raises(ValueError, match="not split-safe"):
        keyed_stage(WordCount(), n_tasks=4, theta_max=0.08, algorithm="pkg")


def test_router_rejects_device_backend():
    with pytest.raises(ValueError, match="assignment-driven"):
        keyed_stage(PartialWordCount(), n_tasks=4, theta_max=0.08,
                    algorithm="pkg", state_backend="device")


def test_router_rejects_scale_to():
    stage = keyed_stage(PartialWordCount(), n_tasks=4, theta_max=0.08,
                        algorithm="wchoices")
    stage.process_interval_arrays(np.arange(50, dtype=np.int64))
    n_stores = len(stage.stores)
    with pytest.raises(ValueError, match="choice router"):
        stage.scale_to(8)
    assert len(stage.stores) == n_stores             # fleet untouched


def test_router_binding_uses_assignment_seed():
    a = Assignment(ModHash(8, seed=41))
    pkg = PartialKeyGrouping()
    pkg.bind(a)
    assert pkg.seed == 41 and pkg.n_dest == 8
    w = WChoices(seed=7)
    w.bind(a)
    assert w.seed == 7                               # explicit seed wins
