"""Dry-run machinery integration test on a small fake-device mesh.

Runs in a subprocess so XLA_FLAGS device-count never pollutes the main test
process (smoke tests must see 1 device, per the launcher contract)."""

import json
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys, json, dataclasses
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import smoke_config
    from repro.models import model_schema, cache_schema
    from repro.models import schema as schema_mod
    from repro.sharding import rules, ctx as shard_ctx
    from repro.train.optimizer import OptConfig
    from repro.train.train_step import make_train_step, make_serve_step
    from repro.launch.dryrun import (_cost_dict, abstract_opt_state,
                                     collective_bytes)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = smoke_config("granite_moe_3b_a800m")
    sch = model_schema(cfg)
    pa = schema_mod.abstract(sch)
    ps = rules.param_shardings(sch, mesh, fsdp=True)
    b = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
         "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
    bs = {k: NamedSharding(mesh, P("data", None)) for k in b}
    repl = NamedSharding(mesh, P())
    pl = jax.ShapeDtypeStruct((cfg.n_layers, cfg.moe_experts), jnp.int32)
    step = make_train_step(cfg, OptConfig(), microbatches=2)
    oa = abstract_opt_state(pa)
    os_ = {"m": ps, "v": ps, "master": ps, "step": repl}
    with shard_ctx.use_mesh(mesh):
        jt = jax.jit(step, in_shardings=(ps, os_, bs, repl),
                     donate_argnums=(0, 1))
        lowered = jt.lower(pa, oa, b, pl)
    compiled = lowered.compile()
    cost = _cost_dict(compiled)
    coll = collective_bytes(compiled.as_text())
    mem = compiled.memory_analysis()
    print(json.dumps({
        "flops": float(cost.get("flops", 0)),
        "coll_ops": sorted(coll),
        "coll_total": sum(coll.values()),
        "arg_bytes": getattr(mem, "argument_size_in_bytes", 0),
    }))

    # decode path on the same mesh
    csch = cache_schema(cfg, 8, 128)
    ca = schema_mod.abstract(csch)
    cs = rules.cache_shardings(csch, mesh, 8)
    serve = make_serve_step(cfg)
    with shard_ctx.use_mesh(mesh):
        js = jax.jit(lambda p, c, bb, plc: serve(p, c, bb, 127, plc),
                     in_shardings=(ps, cs, {"tokens": NamedSharding(mesh, P("data", None))}, repl),
                     donate_argnums=(1,))
        low2 = js.lower(pa, ca, {"tokens": jax.ShapeDtypeStruct((8, 1), jnp.int32)}, pl)
    comp2 = low2.compile()
    print(json.dumps(
        {"decode_flops": float(_cost_dict(comp2).get("flops", 0))}))
""")


@pytest.mark.slow
def test_small_mesh_dryrun_compiles():
    proc = subprocess.run([sys.executable, "-c", SCRIPT], cwd="/root/repo",
                          capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, proc.stderr[-3000:]
    lines = [l for l in proc.stdout.strip().splitlines() if l.startswith("{")]
    train = json.loads(lines[0])
    decode = json.loads(lines[1])
    assert train["flops"] > 0
    assert train["coll_total"] > 0            # DP sync + EP dispatch exist
    assert "all-reduce" in train["coll_ops"]
    assert decode["decode_flops"] > 0
