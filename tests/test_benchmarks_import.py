"""Benchmark bit-rot guard (tier-1).

Every module in ``benchmarks.run.MODULES`` must import and expose a
``rows(quick)`` callable, and the two cheapest modules actually run in quick
mode — so a refactor that breaks a figure module fails tier-1 instead of
only surfacing in the nightly benchmark job.

Requires the repo root on sys.path (as ``python -m pytest`` from the root
provides); ``benchmarks`` is a namespace package.
"""

import importlib

import pytest

from benchmarks.run import MODULES


@pytest.mark.parametrize("mod_name", MODULES)
def test_module_imports_and_exposes_rows(mod_name):
    mod = importlib.import_module(f"benchmarks.{mod_name}")
    assert callable(getattr(mod, "rows", None)), \
        f"benchmarks/{mod_name}.py lost its rows() entry point"


# the two cheapest figure modules (<0.1 s in quick mode) — cheap enough for
# tier-1, and they exercise the WorkloadGen + balancer + CSV row shape that
# every other module shares
CHEAP_MODULES = ("fig20_beta", "fig19_window")


@pytest.mark.parametrize("mod_name", CHEAP_MODULES)
def test_cheap_module_rows_run_in_quick_mode(mod_name):
    mod = importlib.import_module(f"benchmarks.{mod_name}")
    rows = mod.rows(quick=True)
    assert rows, f"{mod_name}.rows(quick=True) returned no rows"
    for name, us, derived in rows:
        assert isinstance(name, str) and name
        assert isinstance(float(us), float)
        assert isinstance(derived, str)
