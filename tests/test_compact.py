"""Tests for the compact 6-d representation + adapted Mixed (paper Sec. IV-A)."""

import numpy as np
import pytest

from repro.core.balancer import (Assignment, BalanceConfig, KeyStats, ModHash,
                                 build_groups, compact_mixed, metrics, mixed,
                                 reference_mixed)
from repro.streams.generator import WorkloadGen


def _workload(seed=0, k=1200, n_dest=10, z=0.9):
    gen = WorkloadGen(k=k, z=z, f=0.0, seed=seed)
    assignment = Assignment(ModHash(n_dest, seed=seed))
    return gen.interval(assignment, fluctuate=False), assignment


def test_group_compression():
    """Discretization collapses the key space into O(N_D^2 |vc| |vS|) vectors
    (paper's K^c bound), far fewer than K."""
    stats, assignment = _workload(k=5000)
    groups, *_ = build_groups(stats, assignment, r=3)
    n_dest = assignment.n_dest
    ys = len(np.unique([g[2] for g in groups]))
    vs = len(np.unique([g[3] for g in groups]))
    assert len(groups) <= n_dest * n_dest * ys * vs
    assert len(groups) < stats.num_keys / 4


def test_compact_mixed_balances():
    stats, assignment = _workload()
    cfg = BalanceConfig(theta_max=0.08, table_max=600)
    res = compact_mixed(stats, assignment, cfg, r=2)
    assert res.feasible_balance
    # result is internally consistent when recomputed on true stats
    re_loads = metrics.loads(stats, res.assignment)
    np.testing.assert_allclose(re_loads, res.loads, rtol=1e-9)


@pytest.mark.parametrize("r", [0, 1, 3, 5])
def test_load_estimation_error_small(r):
    """Paper Fig. 11(b): discretized load estimates deviate < ~1% even at
    coarse R (we assert a conservative 5% on the harder synthetic mix)."""
    stats, assignment = _workload(seed=3)
    cfg = BalanceConfig(theta_max=0.08, table_max=600)
    res = compact_mixed(stats, assignment, cfg, r=r)
    assert res.meta["load_est_err"] < 0.05


def test_compact_vs_exact_same_quality():
    """With r=None (no discretization) the compact path must match plain
    Mixed's balance quality — it is the same algorithm over merged keys."""
    stats, assignment = _workload(seed=5)
    cfg = BalanceConfig(theta_max=0.08, table_max=600)
    res_c = compact_mixed(stats, assignment, cfg, r=None)
    res_p = mixed(stats, assignment, cfg)
    assert res_c.feasible_balance == res_p.feasible_balance
    assert res_c.theta <= cfg.theta_max + 1e-9 or not res_p.feasible_balance


def test_compact_faster_when_plan_touches_many_keys():
    """Paper Fig. 11(a): the compact representation wins when the plan must
    process many keys — tight theta_max makes nearly every instance shed load,
    so per-key LLFD churn dominates while the compact path works on
    O(#vectors) groups. The baseline is the scalar reference planner (the
    implementation the figure's complexity claim is about); the array-native
    `mixed` has since vectorized that churn away, so compact's edge over it
    is no longer a fixed multiple."""
    stats, assignment = _workload(seed=1, k=8_000, n_dest=15, z=0.6)
    cfg = BalanceConfig(theta_max=0.0, table_max=8_000)
    res_c = compact_mixed(stats, assignment, cfg, r=3)
    res_p = reference_mixed(stats, assignment, cfg)
    # (at K=50k the measured gap is ~365x: 40s per-key vs 0.11s compact)
    assert res_c.plan_time_s < res_p.plan_time_s / 5
    assert res_c.theta <= res_p.theta + 0.01     # pays only discretization error
    assert res_c.meta["groups"] < stats.num_keys / 8
