"""End-to-end behaviour tests for the paper's system: the stream engine under
skewed + fluctuating workloads with live rebalancing (paper Fig. 5 protocol).
"""

import numpy as np

from repro.core import (Assignment, BalanceConfig, ModHash,
                        RebalanceController)
from repro.streams import (KeyedStage, WindowedSelfJoin, WordCount,
                           WorkloadGen)


def make_stage(n_tasks=6, theta_max=0.08, table_max=500, window=2,
               algorithm="mixed", operator=None, seed=0):
    controller = RebalanceController(
        Assignment(ModHash(n_tasks, seed=seed)),
        BalanceConfig(theta_max=theta_max, table_max=table_max, window=window),
        algorithm=algorithm)
    return KeyedStage(operator or WordCount(), controller, window=window)


def drive(stage, gen, intervals=6, tuples_per_interval=4000):
    sent = {}
    for i in range(intervals):
        if i > 0:
            gen.interval(stage.controller.assignment)  # fluctuate distribution
        keys = gen.draw_tuples(tuples_per_interval)
        tuples = [(int(k), i) for k in keys]
        for k in keys:
            sent[int(k)] = sent.get(int(k), 0) + 1
        stage.process_interval(tuples)
    return sent


def test_wordcount_exactness_under_migration():
    """No tuple is lost or double-counted across rebalances: final window
    counts equal an oracle computed without any distribution machinery."""
    gen = WorkloadGen(k=800, z=1.1, f=0.8, seed=2)
    stage = make_stage(window=10)     # window larger than run: nothing evicted
    sent = drive(stage, gen, intervals=5)
    got = {}
    for store in stage.stores:
        for k, ks in store.keys.items():
            got[k] = got.get(k, 0) + sum(sl.payload["count"]
                                         for sl in ks.iter_window())
    assert got == sent


def test_each_key_lives_on_exactly_one_task():
    """Non-split-key semantics (the paper's core invariant vs PKG): at any
    time a key's state exists on exactly one task instance."""
    gen = WorkloadGen(k=500, z=1.0, f=1.0, seed=3)
    stage = make_stage()
    drive(stage, gen, intervals=5)
    seen = set()
    for store in stage.stores:
        for k in store.keys:
            assert k not in seen
            seen.add(k)


def test_rebalancing_restores_balance():
    """After the controller triggers, steady-state skew drops well below the
    hash-only baseline (the paper's headline effect, Fig. 7 vs Fig. 13)."""
    # k/z chosen so the hottest key stays below the mean load (the paper's
    # regime; otherwise absolute balance is provably infeasible and the
    # balancer caps at the oversized-key bound instead).
    gen_b = WorkloadGen(k=2000, z=1.0, f=0.0, seed=4)
    baseline = make_stage(theta_max=1e9)       # never triggers
    drive(baseline, gen_b, intervals=4, tuples_per_interval=6000)
    gen_m = WorkloadGen(k=2000, z=1.0, f=0.0, seed=4)
    managed = make_stage(theta_max=0.05)
    drive(managed, gen_m, intervals=4, tuples_per_interval=6000)
    base_skew = np.mean([r.skewness for r in baseline.reports[2:]])
    mng_skew = np.mean([r.skewness for r in managed.reports[2:]])
    assert mng_skew < base_skew
    assert mng_skew < 1.15


def test_pause_buffers_only_delta_keys():
    """During migration, only tuples of keys in Delta(F,F') are buffered; the
    rest flow uninterrupted (paper: 'no interruption of normal processing')."""
    gen = WorkloadGen(k=300, z=1.2, f=0.5, seed=5)
    stage = make_stage(theta_max=0.02)
    drive(stage, gen, intervals=5)
    triggered = [r for r in stage.reports if r.buffered > 0]
    assert triggered, "no rebalance was exercised"
    for r in triggered:
        assert r.buffered < r.tuples            # never a full stall


def test_selfjoin_outputs_correct_under_migration():
    """Windowed self-join (stateful, migration-heavy): total matches equal
    sum_i sum_k [C(n_ik,2) + n_ik * window-carry] regardless of migrations."""
    gen = WorkloadGen(k=120, z=1.0, f=0.8, seed=6)
    stage = make_stage(operator=WindowedSelfJoin(), window=3, theta_max=0.05)
    per_interval_counts = []
    for i in range(4):
        if i > 0:
            gen.interval(stage.controller.assignment)
        keys = gen.draw_tuples(1500)
        counts = {}
        for k in keys:
            counts[int(k)] = counts.get(int(k), 0) + 1
        per_interval_counts.append(counts)
        stage.process_interval([(int(k), i) for k in keys])
    window = 3
    expected = 0
    for i, counts in enumerate(per_interval_counts):
        for k, n_ik in counts.items():
            # paper semantics: T_{i-w} is erased only AFTER T_i finishes, so
            # interval i joins against intervals [i-w, i-1] plus itself.
            prev = sum(per_interval_counts[j].get(k, 0)
                       for j in range(max(0, i - window), i))
            expected += n_ik * (n_ik - 1) // 2 + n_ik * prev
    assert stage.emitted_sum == expected


def test_throughput_improves_with_balancing_on_skewed_stream():
    """The paper's Fig. 13/14 effect: Mixed's throughput beats hash-only."""
    gen_b = WorkloadGen(k=1000, z=1.1, f=0.6, seed=7)
    base = make_stage(theta_max=1e9)
    drive(base, gen_b, intervals=6)
    gen_m = WorkloadGen(k=1000, z=1.1, f=0.6, seed=7)
    mng = make_stage(theta_max=0.08)
    drive(mng, gen_m, intervals=6)
    thr_base = np.mean([r.throughput for r in base.reports[2:]])
    thr_mng = np.mean([r.throughput for r in mng.reports[2:]])
    assert thr_mng > thr_base


def test_elastic_scale_out():
    """Paper Fig. 15: adding a task instance, the controller rebalances onto
    the new fleet; the new instance receives meaningful load and every key's
    state ends up exactly where the new assignment routes it."""
    gen = WorkloadGen(k=600, z=1.0, f=0.3, seed=8)
    stage = make_stage(n_tasks=5, theta_max=0.08)
    drive(stage, gen, intervals=3)
    stage.scale_to(6)
    # state location invariant after the sweep
    for s_idx, store in enumerate(stage.stores):
        for k in store.keys:
            d = int(stage.controller.assignment.dest(
                np.asarray([k], np.int64))[0])
            assert d == s_idx
    gen.interval(stage.controller.assignment)
    keys = gen.draw_tuples(4000)
    rep = stage.process_interval([(int(k), 99) for k in keys])
    assert rep.task_loads.shape[0] == 6
    assert rep.task_loads[5] > 0.25 * rep.task_loads.mean()


def test_elastic_scale_in():
    """Shrinking the fleet drains the removed instance losslessly."""
    gen = WorkloadGen(k=400, z=0.9, f=0.3, seed=9)
    stage = make_stage(n_tasks=6, theta_max=0.08, window=10)
    sent = drive(stage, gen, intervals=3)
    stage.scale_to(4)
    assert len(stage.stores) == 4
    got = {}
    for store in stage.stores:
        for k, ks in store.keys.items():
            got[k] = got.get(k, 0) + sum(sl.payload["count"]
                                         for sl in ks.iter_window())
    assert got == sent
