"""Data-plane routing: host planner and jnp/kernels agree bit-for-bit."""

import jax.numpy as jnp
import numpy as np

from repro.core.balancer import Assignment, BalanceConfig, KeyStats, mixed
from repro.core.balancer.hashing import Hash32
from repro.core.routing import RoutingTableDev, hash_route, route
from repro.kernels import mixed_route


def test_route_matches_assignment_after_rebalance():
    """Controller plans on host -> table shipped to device -> every tuple
    routed identically by Assignment.dest, core.routing.route and the Pallas
    kernel."""
    rng = np.random.default_rng(0)
    keys = np.arange(2_000, dtype=np.int64)
    stats = KeyStats(keys=keys, cost=rng.pareto(1.3, 2_000) + 1,
                     mem=np.ones(2_000))
    assignment = Assignment(Hash32(12, seed=9))
    res = mixed(stats, assignment, BalanceConfig(theta_max=0.05,
                                                 table_max=800))
    a_max = 1_024
    table = RoutingTableDev.from_assignment(res.assignment, a_max)
    host = res.assignment.dest(keys)
    dev = route(jnp.asarray(keys), table, 12, seed=9)
    np.testing.assert_array_equal(np.asarray(dev), host)
    tk, td = res.assignment.table_arrays(a_max)
    kern = mixed_route(jnp.asarray(keys, jnp.int32),
                       jnp.asarray(tk, jnp.int32),
                       jnp.asarray(td, jnp.int32), 12, seed=9)
    np.testing.assert_array_equal(np.asarray(kern), host)


def test_hash_route_range():
    out = hash_route(jnp.arange(10_000, dtype=jnp.int32), 7, seed=3)
    assert int(out.min()) >= 0 and int(out.max()) < 7
