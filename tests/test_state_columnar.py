"""Columnar state backend == dict state backend, observationally.

``ColumnarStateStore`` must be a pure representation change: under the same
vectorized engine, the same streams, rebalances, window eviction and mid-run
rescales, it has to produce the identical :class:`IntervalReport` stream,
the identical ``key_location()`` map after migrations, and the identical
outputs/emit sums as the object store — the Hypothesis property below
drives randomized workloads through both backends in lockstep.

Costs are kept exact (WordCount's integer costs; the self-join pinned to a
dyadic ``probe_cost``) so every comparison is strict equality, same as
``tests/test_engine_parity.py``.
"""

import numpy as np
import pytest

from repro.core import (Assignment, BalanceConfig, ModHash,
                        RebalanceController)
from repro.streams import (ColumnarSpec, ColumnarStateStore, KeyedStage,
                           MergeCounts, Operator, WindowedSelfJoin, WordCount,
                           WorkloadGen)

REPORT_FIELDS = ("interval", "tuples", "makespan", "migration_stall",
                 "throughput", "skewness", "theta", "migrated_bytes",
                 "table_size", "buffered")


def make_stage(op, backend, n_tasks=5, window=3, theta_max=0.05,
               table_max=300, seed=1):
    controller = RebalanceController(
        Assignment(ModHash(n_tasks, seed=seed)),
        BalanceConfig(theta_max=theta_max, table_max=table_max,
                      window=window),
        algorithm="mixed")
    return KeyedStage(op, controller, window=window, vectorized=True,
                      state_backend=backend)


def assert_stages_identical(col, obj):
    assert len(col.reports) == len(obj.reports)
    for rc, ro in zip(col.reports, obj.reports):
        for field in REPORT_FIELDS:
            assert getattr(rc, field) == getattr(ro, field), field
        np.testing.assert_array_equal(rc.task_loads, ro.task_loads)
    assert col.outputs == obj.outputs
    assert col.emitted_sum == obj.emitted_sum
    assert col.total_state_keys() == obj.total_state_keys()
    # identical post-migration ownership: every held key lives on the same
    # task under both backends (and exactly one task each)
    all_keys = set()
    for store in obj.stores:
        all_keys.update(store.keys)
    for k in all_keys:
        loc_c, loc_o = col.key_location(k), obj.key_location(k)
        assert loc_c == loc_o, k
        assert len(loc_o) == 1, k


# -- backend unit behavior ----------------------------------------------------

def test_columnar_eviction_matches_object_semantics():
    spec = ColumnarSpec(mode="add", slot_bytes=4.0)
    store = ColumnarStateStore(window=2, spec=spec)
    store.update_slots(1, np.array([7], dtype=np.int64), np.array([1.0]))
    store.update_slots(2, np.array([9], dtype=np.int64), np.array([1.0]))
    store.end_interval(2)                      # key 7 still in window (w=2)
    assert sorted(store.keys) == [7, 9]
    store.end_interval(3)                      # key 7's last slice expires
    assert sorted(store.keys) == [9]
    store.end_interval(5)
    assert len(store.keys) == 0


def test_columnar_collect_reports_live_sizes():
    spec = ColumnarSpec(mode="add", slot_bytes=3.0)
    store = ColumnarStateStore(window=1, spec=spec)
    store.update_slots(1, np.array([1], dtype=np.int64), np.array([2.0]))
    store.update_slots(2, np.array([2], dtype=np.int64), np.array([1.0]))
    keys, sizes = store.end_interval_collect(2)   # key 1 expired, key 2 lives
    assert keys.tolist() == [2]
    assert sizes.tolist() == [3.0]
    keys, sizes = store.end_interval_collect(3)
    assert keys.size == 0 and sizes.size == 0


def test_columnar_pack_roundtrip_and_duplicate_reject():
    spec = ColumnarSpec(mode="add", slot_bytes=16.0)
    a = ColumnarStateStore(window=2, spec=spec)
    b = ColumnarStateStore(window=2, spec=spec)
    keys = np.arange(10, dtype=np.int64)
    a.update_slots(1, keys, np.ones(10))
    pack = a.extract_batch(np.array([2, 5, 7, 99], dtype=np.int64))
    assert pack.keys.tolist() == [2, 5, 7]        # missing keys ignored
    assert pack.nbytes == 48.0
    assert sorted(a.keys) == [0, 1, 3, 4, 6, 8, 9]
    sub = pack.take(pack.keys != 5)
    b.install_batch(sub)
    assert sorted(b.keys) == [2, 7]
    with pytest.raises(RuntimeError, match="already present"):
        b.install_batch(sub)
    # snapshot view reconstructs the window slices
    ks = b.keys[2]
    assert list(ks.slices) == [1]
    assert ks.slices[1].payload == {"count": 1}
    assert ks.slices[1].size == 16.0


def test_columnar_store_rejects_non_monotonic_interval():
    """The ring position is interval % (window+1): rewinding the clock
    would silently alias a live column, so the store must refuse."""
    spec = ColumnarSpec(mode="add", slot_bytes=4.0)
    store = ColumnarStateStore(window=2, spec=spec)
    keys = np.array([1, 2], dtype=np.int64)
    store.update_slots(5, keys, np.ones(2))
    store.update_slots(5, keys, np.ones(2))          # same interval: fine
    store.end_interval_collect(5)                    # boundary at 5: fine
    with pytest.raises(ValueError, match="non-monotonic"):
        store.update_slots(4, keys, np.ones(2))
    with pytest.raises(ValueError, match="non-monotonic"):
        store.end_interval_collect(3)
    store.update_slots(6, keys, np.ones(2))          # forward still works
    assert sorted(store.keys) == [1, 2]


def test_columnar_store_rejects_scalar_state_access():
    store = ColumnarStateStore(window=1, spec=ColumnarSpec())
    with pytest.raises(NotImplementedError, match="object backend"):
        store.state(3)


def test_backend_selection_rules():
    def controller():
        return RebalanceController(Assignment(ModHash(4, seed=0)),
                                   BalanceConfig())

    class CustomOp(Operator):
        def process(self, store, interval, key, value):
            return [], 1.0

    assert KeyedStage(WordCount(), controller()).state_backend == "columnar"
    assert KeyedStage(WordCount(), controller(),
                      vectorized=False).state_backend == "object"
    assert KeyedStage(CustomOp(), controller()).state_backend == "object"
    with pytest.raises(ValueError, match="columnar_spec"):
        KeyedStage(CustomOp(), controller(), state_backend="columnar")
    with pytest.raises(ValueError, match="vectorized"):
        KeyedStage(WordCount(), controller(), vectorized=False,
                   state_backend="columnar")
    with pytest.raises(ValueError, match="state backend"):
        KeyedStage(WordCount(), controller(), state_backend="arrow")


def test_merge_counts_columnar_matches_object():
    rng = np.random.default_rng(3)
    stages = [make_stage(MergeCounts(), b, window=2)
              for b in ("columnar", "object")]
    for _ in range(4):
        keys = rng.integers(0, 150, size=1200).astype(np.int64)
        vals = rng.integers(1, 40, size=1200)
        for stage in stages:
            stage.process_interval_arrays(keys, vals)
    assert_stages_identical(*stages)


# -- the property: randomized workloads, rebalances, eviction, rescale --------

def _check_property(seed, z, f, window, theta, op_kind, scale_step):
    """Identical IntervalReport streams and identical post-migration
    key_location maps over randomized skewed/fluctuating workloads with
    rebalances, window>1 eviction, and scale_to mid-run."""
    def op():
        return (WordCount() if op_kind == "wordcount"
                else WindowedSelfJoin(probe_cost=1.0 / 64))

    gens = [WorkloadGen(k=400, z=z, f=f, seed=seed, window=window)
            for _ in range(2)]
    stages = [make_stage(op(), b, window=window, theta_max=theta,
                         table_max=250, seed=seed % 13)
              for b in ("columnar", "object")]
    for i in range(5):
        keys = None
        for gen, stage in zip(gens, stages):
            if i:
                gen.interval(stage.controller.assignment)
            drawn = gen.draw_tuples(1000).astype(np.int64)
            if keys is None:
                keys = drawn
            else:
                assert np.array_equal(drawn, keys), "streams diverged"
            stage.process_interval_arrays(drawn, np.full(1000, i))
        if scale_step is not None and i == 2:
            for stage in stages:
                stage.scale_to(scale_step)
            assert stages[0]._migrated_bytes_pending == \
                stages[1]._migrated_bytes_pending
    assert_stages_identical(*stages)


@pytest.mark.parametrize("seed,z,f,window,theta,op_kind,scale_step", [
    (2, 1.1, 0.8, 3, 0.0, "wordcount", None),
    (11, 0.9, 1.0, 4, 0.03, "selfjoin", 7),
    (23, 1.2, 0.3, 2, 0.0, "wordcount", 3),
], ids=["wordcount_rebalance", "selfjoin_scale_out", "wordcount_scale_in"])
def test_columnar_equals_object_store_fixed(seed, z, f, window, theta,
                                            op_kind, scale_step):
    """Deterministic instances of the property — run even without the
    optional hypothesis extra (bare envs, see ci.yml's bare-collect job)."""
    _check_property(seed, z, f, window, theta, op_kind, scale_step)


try:                                    # optional [test] extra
    from hypothesis import given, settings, strategies as st
except ImportError:                     # pragma: no cover - bare env
    pass
else:
    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           z=st.floats(0.6, 1.3),
           f=st.floats(0.0, 1.2),
           window=st.integers(2, 4),
           theta=st.sampled_from([0.0, 0.03, 0.2]),
           op_kind=st.sampled_from(["wordcount", "selfjoin"]),
           scale_step=st.sampled_from([None, 3, 7]))
    def test_columnar_equals_object_store_property(seed, z, f, window, theta,
                                                   op_kind, scale_step):
        _check_property(seed, z, f, window, theta, op_kind, scale_step)
