"""Fig. 19 (appendix): migration cost vs window size w."""

from repro.core.balancer import mintable, mixed

from .common import timed, workload


def rows(quick=True):
    out = []
    ws = (1, 5, 15) if quick else (1, 5, 10, 15, 20)
    for w in ws:
        _, stats, a, cfg = workload(k=5_000, window=w)
        total = stats.mem.sum()
        for name, algo in (("mixed", mixed), ("mintable", mintable)):
            res, us = timed(algo, stats, a, cfg, repeats=1)
            out.append((f"fig19/{name}_w{w}", us,
                        f"mig_frac={res.migration_cost/total:.4f}"))
    return out
