"""Strategy x workload-shape comparison matrix (the scenario sweep).

One benchmark answering the paper's headline claim quantitatively: every
registered partitioning strategy — the paper's table planners (mixed,
mintable, minmig, readj) AND the competing choice routers (pkg, potc,
wchoices) — driven over the same workload shapes from the existing
generator (zipf exponent, drift rate, key-domain size, window length,
fluctuation bursts), emitting one matrix of

    imbalance theta (mean over steady-state intervals), migrated bytes,
    routing-table size, model throughput (tuples / sum(makespan + stall))

per (shape, strategy) point. Every strategy processes the *identical*
pre-generated tuple stream (fluctuation is driven against a fixed probe
assignment, not any stage's own), so the matrix is a controlled comparison
and the model metrics are fully deterministic given the seed.

Per-point parity is asserted where strategies are bit-comparable:

* ``mixed`` vs the scalar ``mixed_reference`` oracle — identical reports;
* ``pkg`` vs ``potc`` with ``n_sources=1`` — identical reports (the PoTC
  policy with one source IS PKG).

CI gates the ``mixed`` rows of a fresh quick run against the committed
``benchmarks/strategy_matrix.json`` via ``check_perf_gate.py
--matrix-fresh/--matrix-baseline`` (value tolerance, not wall time).

    PYTHONPATH=src:. python benchmarks/strategy_matrix.py --out strategy_matrix.json --csv strategy_matrix.csv
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core.balancer import Assignment, ModHash, PowerOfBothChoices
from repro.streams import PartialWordCount, WorkloadGen, keyed_stage

N_TASKS = 8
THETA_MAX = 0.08

#: every shape varies ONE generator knob off the base zipf profile
SHAPES = [
    # name, dict(k, z, f, window), per-interval fluctuation override list
    ("uniform", dict(k=2_000, z=0.3, f=0.8, window=2), None),
    ("zipf", dict(k=2_000, z=1.1, f=0.8, window=2), None),
    ("hot", dict(k=2_000, z=2.0, f=0.8, window=2), None),
    ("drift", dict(k=2_000, z=1.1, f=2.5, window=2), None),
    # fluctuation bursts: calm intervals punctuated by violent swaps
    ("burst", dict(k=2_000, z=1.1, f=0.0, window=2), [0.0, 4.0, 0.0, 4.0,
                                                      0.0, 4.0, 0.0, 4.0]),
    ("widekeys", dict(k=20_000, z=1.1, f=0.8, window=2), None),
    ("longwin", dict(k=2_000, z=1.1, f=0.8, window=6), None),
]

STRATEGIES = ["mixed", "mintable", "minmig", "readj", "pkg", "potc",
              "wchoices"]


def _batches(shape_cfg, fluct_schedule, n, intervals, seed):
    """Pre-generate the interval batches once per shape: fluctuation runs
    against a fixed probe assignment so every strategy sees the same
    stream (and none can influence its own workload)."""
    gen = WorkloadGen(seed=seed, total_tuples=n * intervals, **shape_cfg)
    probe = Assignment(ModHash(N_TASKS, seed=seed))
    out = []
    for i in range(intervals):
        if i:
            f = fluct_schedule[(i - 1) % len(fluct_schedule)] \
                if fluct_schedule else None
            if f is not None:
                gen.f = f
            gen.interval(probe, fluctuate=(f is None or f > 0))
        out.append(gen.draw_tuples(n).astype(np.int64))
    return out


def _run_point(algorithm, batches, window, seed):
    stage = keyed_stage(PartialWordCount(), n_tasks=N_TASKS,
                        theta_max=THETA_MAX, window=window, seed=seed,
                        algorithm=algorithm)
    t0 = time.perf_counter()
    for keys in batches:
        stage.process_interval_arrays(keys)
    wall = time.perf_counter() - t0
    reps = stage.reports
    steady = reps[1:] if len(reps) > 1 else reps
    denom = sum(r.makespan + r.migration_stall for r in reps)
    return stage, {
        "theta_mean": float(np.mean([r.theta for r in steady])),
        "migrated_bytes": float(sum(r.migrated_bytes for r in reps)),
        "table_size": int(reps[-1].table_size),
        "throughput": float(sum(r.tuples for r in reps) / denom)
        if denom > 0 else 0.0,
        "wall_s": wall,
    }


def _assert_report_parity(a, b, label):
    for ra, rb in zip(a.reports, b.reports):
        same = (ra.tuples == rb.tuples and ra.makespan == rb.makespan
                and ra.theta == rb.theta
                and ra.migrated_bytes == rb.migrated_bytes
                and ra.table_size == rb.table_size)
        if not same:
            raise AssertionError(
                f"parity violation [{label}] interval {ra.interval}: "
                f"{ra} != {rb}")


def build_matrix(quick=True, seed=17):
    n = 4_000 if quick else 20_000
    intervals = 6 if quick else 12
    rows = []
    for shape, cfg, fluct in SHAPES:
        window = cfg["window"]
        gen_cfg = {k: v for k, v in cfg.items() if k != "window"}
        gen_cfg["window"] = window
        batches = _batches(gen_cfg, fluct, n, intervals, seed)
        stages = {}
        for strat in STRATEGIES:
            stage, point = _run_point(strat, batches, window, seed)
            stages[strat] = stage
            rows.append(dict(shape=shape, strategy=strat, **point))
        # bit-comparable pairs, asserted on every shape
        ref_stage, _ = _run_point("mixed_reference", batches, window, seed)
        _assert_report_parity(stages["mixed"], ref_stage,
                              f"{shape}: mixed vs mixed_reference")
        potc1_stage, _ = _run_point(PowerOfBothChoices(n_sources=1),
                                    batches, window, seed)
        _assert_report_parity(stages["pkg"], potc1_stage,
                              f"{shape}: pkg vs potc(n_sources=1)")
    return {"quick": bool(quick), "seed": seed, "n_tasks": N_TASKS,
            "theta_max": THETA_MAX, "tuples_per_interval": n,
            "intervals": intervals, "rows": rows}


def rows(quick=True):
    matrix = build_matrix(quick=quick)
    out = []
    for r in matrix["rows"]:
        out.append((
            f"matrix/{r['shape']}/{r['strategy']}",
            r["wall_s"] / matrix["intervals"] * 1e6,
            (f"theta={r['theta_mean']:.4f};mig={r['migrated_bytes']:.0f};"
             f"table={r['table_size']};thr={r['throughput']:.2f}"),
        ))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default=None, help="write matrix JSON here")
    ap.add_argument("--csv", default=None, help="write matrix CSV here")
    args = ap.parse_args()
    matrix = build_matrix(quick=not args.full)
    header = "shape,strategy,theta_mean,migrated_bytes,table_size,throughput"
    lines = [header]
    for r in matrix["rows"]:
        lines.append(f"{r['shape']},{r['strategy']},{r['theta_mean']:.6f},"
                     f"{r['migrated_bytes']:.1f},{r['table_size']},"
                     f"{r['throughput']:.4f}")
    print("\n".join(lines))
    if args.out:
        with open(args.out, "w") as fh:
            json.dump(matrix, fh, indent=1, sort_keys=True)
        print(f"# wrote {args.out}")
    if args.csv:
        with open(args.csv, "w") as fh:
            fh.write("\n".join(lines) + "\n")
        print(f"# wrote {args.csv}")


if __name__ == "__main__":
    main()
