"""Fig. 7: workload skewness of the pure hash scheme vs N_D and K."""

import numpy as np

from repro.core import Assignment, ModHash
from repro.core.balancer import metrics
from repro.streams import WorkloadGen

from .common import Row, timed


def rows(quick=True):
    out = []
    intervals = 10 if quick else 50
    for n_dest in (5, 10, 20, 40):
        gen = WorkloadGen(k=10_000, z=0.85, f=0.5, seed=0)
        a = Assignment(ModHash(n_dest))
        skews = []
        def run():
            s = gen.interval(a)
            skews.append(metrics.skewness(metrics.loads(s, a)))
        _, us = timed(lambda: [run() for _ in range(intervals)], repeats=1)
        out.append((f"fig07/hash_skew_nd{n_dest}", us / intervals,
                    f"max_skew={max(skews):.2f};p50={np.median(skews):.2f}"))
    for k in (5_000, 10_000, 100_000, 1_000_000):
        gen = WorkloadGen(k=k, z=0.85, f=0.0, seed=1)
        a = Assignment(ModHash(15))
        s = gen.interval(a, fluctuate=False)
        sk = metrics.skewness(metrics.loads(s, a))
        out.append((f"fig07/hash_skew_k{k}", 0.0, f"skew={sk:.2f}"))
    return out
