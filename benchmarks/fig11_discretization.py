"""Fig. 11: compact-representation degree R: plan time + load-estimate error."""

from repro.core.balancer import compact_mixed, mixed

from .common import timed, workload


def rows(quick=True):
    out = []
    # theta_max=0: the paper's saturation setting ('requirement of absolute
    # load balancing') — the regime where plan cost is dominated by per-key
    # churn and the compact representation pays off by orders of magnitude.
    k = 8_000 if quick else 50_000
    _, stats, a, cfg = workload(k=k, theta_max=0.0, table_max=k)
    res, us = timed(mixed, stats, a, cfg, repeats=1)
    out.append((f"fig11/original_key_space_k{k}", us,
                f"theta={res.theta:.4f}"))
    for r in (0, 1, 2, 3, 5, 8):
        res, us = timed(compact_mixed, stats, a, cfg, r, repeats=1)
        out.append((f"fig11/compact_r{r}_k{k}", us,
                    f"est_err={res.meta['load_est_err']:.4f};"
                    f"groups={res.meta['groups']:.0f};theta={res.theta:.4f}"))
    return out
