"""Fig. 9: plan time + migration cost vs theta_max."""

import dataclasses

from repro.core.balancer import mintable, mixed

from .common import timed, workload


def rows(quick=True):
    out = []
    thetas = (0.02, 0.08, 0.2, 0.5) if quick else (0.02, 0.05, 0.08, 0.1,
                                                   0.2, 0.3, 0.5, 1.0)
    for w in (1, 5):
        for th in thetas:
            _, stats, a, cfg = workload(window=w, theta_max=th,
                                        k=5_000 if quick else 10_000)
            total = stats.mem.sum()
            for name, algo in (("mixed", mixed), ("mintable", mintable)):
                res, us = timed(algo, stats, a, cfg)
                out.append((f"fig09/{name}_theta{th}_w{w}", us,
                            f"mig_frac={res.migration_cost/total:.4f}"))
    return out
