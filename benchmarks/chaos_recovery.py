"""Throughput under injected failures + the recovery-lossless gate.

Three arms per state backend, all fed the SAME recorded traffic trace:

* **oracle** — plain fault-free run (no checkpoints): the cost floor;
* **checkpointed** — :class:`repro.streams.faults.ChaosRunner` with an empty
  fault plan, snapshotting every ``CADENCE`` intervals through the pack
  round-trip + controller serialization: measures pure checkpoint overhead;
* **chaos** — the same runner under a fixed kill/drop schedule: measures the
  cost of restore-last-checkpoint + replay-buffered-intervals recovery.

The *recovery-lossless contract is asserted per point*, not just reported:
the chaos arm's :class:`IntervalReport` stream (every modelled field plus
the per-task load vector), outputs and emitted sum must be **bit-identical**
to the oracle arm's. Any divergence lands in ``failures`` and the benchmark
exits 1 — CI's chaos job runs this before the wall-clock gate, so a
recovery that silently loses or perturbs state can never read as a perf
number.

Run directly for JSON output:

    PYTHONPATH=src:. python benchmarks/chaos_recovery.py [--smoke|--full] \
        [--backends object,columnar] [--out f]

or via the harness: ``python benchmarks/run.py --only chaos_recovery``.
The committed CI baseline (``benchmarks/chaos_recovery.json``) is generated
with the default sweep, a superset of the --smoke points (see
check_perf_gate.py --chaos-fresh/--chaos-baseline). The multidevice CI leg
re-runs with ``--backends sharded`` (assertion only, no baseline: virtual-
device wall clocks are not comparable across runner classes).
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from typing import List, Optional

import numpy as np

from repro.core import Assignment, BalanceConfig, RebalanceController
from repro.core.balancer.hashing import Hash32
from repro.streams import (ChaosRunner, DropDelivery, FaultPlan, KeyedStage,
                           KillTask, WordCount, WorkloadGen)

REPORT_FIELDS = ("interval", "tuples", "makespan", "migration_stall",
                 "throughput", "skewness", "theta", "migrated_bytes",
                 "table_size", "buffered")

N_TASKS = 6
WINDOW = 3
K = 2_000
TUPLES = 4_000
CADENCE = 2

SMOKE_BACKENDS = ["object", "columnar"]
FULL_BACKENDS = ["object", "columnar", "device"]


def _make_stage(backend: str) -> KeyedStage:
    controller = RebalanceController(
        Assignment(Hash32(N_TASKS, seed=0)),
        BalanceConfig(theta_max=0.05, table_max=600, window=WINDOW),
        algorithm="mixed")
    return KeyedStage(WordCount(), controller, window=WINDOW,
                      vectorized=True, state_backend=backend)


def _make_trace(n_iv: int) -> List[np.ndarray]:
    """One deterministic trace for every backend and arm (driver stage
    only advances the generator's fluctuation loop)."""
    gen = WorkloadGen(k=K, z=1.1, f=0.8, seed=2, window=WINDOW)
    driver = _make_stage("object")
    trace = []
    for i in range(n_iv):
        gen.interval(driver.controller.assignment, fluctuate=i > 0)
        keys = gen.draw_tuples(TUPLES)
        trace.append(keys)
        driver.process_interval_arrays(keys)
    return trace


def _fault_plan(n_iv: int) -> FaultPlan:
    """Two kills (one per crash site) + one dropped delivery, spread so the
    schedule exercises recovery from both fresh and stale checkpoints."""
    return FaultPlan([
        KillTask(interval=max(2, n_iv // 3), task=1, site="mid"),
        KillTask(interval=max(3, 2 * n_iv // 3), task=0, site="deliver"),
        DropDelivery(interval=n_iv - 1),
    ])


def _reports_mismatch(got, want) -> Optional[str]:
    if len(got) != len(want):
        return f"report count {len(got)} != {len(want)}"
    for rg, rw in zip(got, want):
        for field in REPORT_FIELDS:
            if getattr(rg, field) != getattr(rw, field):
                return (f"interval {rg.interval}: {field} "
                        f"{getattr(rg, field)!r} != {getattr(rw, field)!r}")
        if not np.array_equal(np.asarray(rg.task_loads),
                              np.asarray(rw.task_loads)):
            return f"interval {rg.interval}: task_loads diverged"
    return None


def run(backends: Optional[List[str]] = None, full: bool = False,
        smoke: bool = False) -> dict:
    if backends is None:
        backends = SMOKE_BACKENDS if smoke else FULL_BACKENDS
    n_iv = 16 if full else 10
    trace = _make_trace(n_iv)
    total_tuples = n_iv * TUPLES
    series: List[dict] = []
    failures: List[str] = []
    for backend in backends:
        # oracle arm: the fault-free floor
        oracle = _make_stage(backend)
        t0 = time.perf_counter()
        for keys in trace:
            oracle.process_interval_arrays(keys)
        t_oracle = time.perf_counter() - t0

        # checkpointed arm: snapshot cadence, no faults
        ck_stage = _make_stage(backend)
        runner = ChaosRunner(ck_stage, checkpoint_every=CADENCE)
        t0 = time.perf_counter()
        for keys in trace:
            runner.process_interval(keys)
        t_ckpt = time.perf_counter() - t0
        mism = _reports_mismatch(ck_stage.reports, oracle.reports)
        if mism:
            failures.append(f"{backend}/checkpointed: {mism}")

        # chaos arm: kills + drop, recovery must be lossless
        chaos_stage = _make_stage(backend)
        runner = ChaosRunner(chaos_stage, _fault_plan(n_iv),
                             checkpoint_every=CADENCE)
        t0 = time.perf_counter()
        for keys in trace:
            runner.process_interval(keys)
        t_chaos = time.perf_counter() - t0
        mism = _reports_mismatch(chaos_stage.reports, oracle.reports)
        if mism:
            failures.append(f"{backend}/chaos: {mism}")
        if chaos_stage.outputs != oracle.outputs:
            failures.append(f"{backend}/chaos: outputs diverged")
        if chaos_stage.emitted_sum != oracle.emitted_sum:
            failures.append(f"{backend}/chaos: emitted_sum diverged")
        n_events = len(runner.events)
        if n_events != len(_fault_plan(n_iv).faults):
            failures.append(
                f"{backend}/chaos: {n_events} recovery events for "
                f"{len(_fault_plan(n_iv).faults)} scheduled faults")

        series.append({"name": f"{backend}/oracle", "seconds": t_oracle,
                       "tuples_per_s": total_tuples / t_oracle})
        series.append({"name": f"{backend}/checkpointed", "seconds": t_ckpt,
                       "tuples_per_s": total_tuples / t_ckpt,
                       "overhead_vs_oracle": t_ckpt / t_oracle})
        series.append({"name": f"{backend}/chaos", "seconds": t_chaos,
                       "tuples_per_s": total_tuples / t_chaos,
                       "overhead_vs_oracle": t_chaos / t_oracle,
                       "recoveries": n_events,
                       "replayed": sum(e.replayed for e in runner.events)})
    return {"backends": backends, "intervals": n_iv, "tuples": TUPLES,
            "cadence": CADENCE, "series": series, "failures": failures,
            "ok": not failures}


def rows(quick: bool = True):
    """run.py harness adapter."""
    r = run(smoke=True) if quick else run(full=True)
    out = []
    for s in r["series"]:
        derived = f"tps={s['tuples_per_s']:.0f}"
        if "overhead_vs_oracle" in s:
            derived += f";x{s['overhead_vs_oracle']:.2f}"
        if "recoveries" in s:
            derived += f";rec={s['recoveries']};ok={r['ok']}"
        out.append((f"chaos_recovery/{s['name']}", s["seconds"] * 1e6,
                    derived))
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="host backends only, 10 intervals (CI)")
    ap.add_argument("--full", action="store_true",
                    help="adds the device backend and 16 intervals")
    ap.add_argument("--backends", default=None,
                    help="comma-separated backend override (e.g. 'sharded' "
                         "for the multidevice CI leg)")
    ap.add_argument("--out", default=None,
                    help="write JSON here instead of stdout")
    args = ap.parse_args()
    backends = args.backends.split(",") if args.backends else None
    t0 = time.time()
    result = run(backends=backends, full=args.full, smoke=args.smoke)
    result["wall_s"] = time.time() - t0
    blob = json.dumps(result, indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(blob + "\n")
        print(f"wrote {args.out}: ok={result['ok']}", file=sys.stderr)
    else:
        print(blob)
    if not result["ok"]:
        for msg in result["failures"]:
            print(f"RECOVERY FAILURE: {msg}", file=sys.stderr)
    sys.exit(0 if result["ok"] else 1)


if __name__ == "__main__":
    main()
