"""Fig. 14: throughput vs theta_max on the two real-application analogues,
run as genuine 2-stage topologies (the paper evaluates these on multi-stage
Storm jobs, not single operators):

* Social ("store and aggregation on keywords"): word count keyed by word ->
  top-k front keyed by a word bucket (running max per bucket) — the
  tokenize->count->top-k aggregation job, with every stage under its own
  controller.
* Stock ("self-join over sliding window"): windowed self-join keyed by
  ticker -> per-sector match volume (word count keyed by sector).

PKG is included for the aggregation topology only (it cannot run the join,
as in the paper); readj drives the Social pipeline as the low-migration
baseline.
"""

import numpy as np

from repro.core.balancer import pkg_route
from repro.streams import (MergeCounts, StageSpec, Topology, WindowedSelfJoin,
                           WordCount, WorkloadGen, keyed_stage)

SOCIAL = dict(k=3_000, z=0.8, f=0.5)     # slow-moving word frequencies
STOCK = dict(k=400, z=1.0, f=1.5)        # bursty keys


def _social_topology(theta, algorithm="mixed"):
    count = keyed_stage(WordCount(), n_tasks=10, theta_max=theta,
                        table_max=3_000, window=2, seed=0,
                        algorithm=algorithm)
    topk = keyed_stage(MergeCounts(), n_tasks=6, theta_max=theta,
                       table_max=500, window=2, seed=1, algorithm=algorithm)
    return Topology([
        StageSpec("count", count),
        StageSpec("topk", topk, rekey=lambda k, v: k % 64),
    ])


def _stock_topology(theta, algorithm="mixed"):
    join = keyed_stage(WindowedSelfJoin(), n_tasks=10, theta_max=theta,
                       table_max=3_000, window=2, seed=0, algorithm=algorithm)
    volume = keyed_stage(WordCount(), n_tasks=6, theta_max=theta,
                         table_max=500, window=2, seed=1, algorithm=algorithm)
    return Topology([
        StageSpec("join", join),
        StageSpec("volume", volume, rekey=lambda k, v: k % 20),
    ])


def _drive(topo, gen_kwargs, n, intervals=5, seed=0):
    gen = WorkloadGen(seed=seed, window=2, **gen_kwargs)
    for i in range(intervals):
        if i:
            gen.interval(topo.specs[0].stage.controller.assignment)
        keys = gen.draw_tuples(n).astype(np.int64)
        topo.process_interval(keys, np.full(n, i))
    reps = topo.reports[1:]
    thr = float(np.mean([r.throughput for r in reps]))
    skews = [float(np.mean([r.stage_reports[s].skewness for r in reps]))
             for s in range(topo.n_stages)]
    rebalances = sum(len(v) for v in topo.rebalances_by_stage().values())
    return thr, skews, rebalances


def rows(quick=True):
    out = []
    thetas = (0.02, 0.1, 0.3) if quick else (0.02, 0.05, 0.1, 0.15, 0.2, 0.3)
    n = 8_000 if quick else 40_000
    for th in thetas:
        thr, skews, reb = _drive(_social_topology(th), SOCIAL, n)
        out.append((f"fig14/social_mixed_th{th}", 0.0,
                    f"throughput={thr:.2f};skew_count={skews[0]:.2f};"
                    f"skew_topk={skews[1]:.2f};rebalances={reb}"))
        thr, skews, reb = _drive(_stock_topology(th), STOCK, n // 4)
        out.append((f"fig14/stock_mixed_th{th}", 0.0,
                    f"throughput={thr:.2f};skew_join={skews[0]:.2f};"
                    f"skew_volume={skews[1]:.2f};rebalances={reb}"))
        thr, skews, reb = _drive(_social_topology(th, algorithm="readj"),
                                 SOCIAL, n)
        out.append((f"fig14/social_readj_th{th}", 0.0,
                    f"throughput={thr:.2f};skew_count={skews[0]:.2f};"
                    f"skew_topk={skews[1]:.2f};rebalances={reb}"))
    # PKG: split-key two-choices + merge cost; theta-insensitive
    gen = WorkloadGen(seed=0, **SOCIAL)
    from repro.core import Assignment, ModHash
    stats = gen.interval(Assignment(ModHash(10)), fluctuate=False)
    reps = np.repeat(stats.keys, 4)
    w = np.repeat(stats.cost / 4, 4)
    res = pkg_route(reps[:n], w[:n], 10)
    makespan = res.loads.max() + res.merge_cost / 10
    out.append(("fig14/social_pkg", 0.0,
                f"throughput={n/makespan:.2f};"
                f"split_keys={res.split_keys}"))
    return out
