"""Fig. 14: throughput vs theta_max on the two real-workload analogues:
word count ('Social') and windowed self-join ('Stock'); PKG included for the
aggregation topology (it cannot run the join, as in the paper)."""

import numpy as np

from repro.core.balancer import pkg_route
from repro.streams import WindowedSelfJoin, WordCount, WorkloadGen

from .common import stage_throughput


def rows(quick=True):
    out = []
    thetas = (0.02, 0.1, 0.3) if quick else (0.02, 0.05, 0.1, 0.15, 0.2, 0.3)
    n = 8_000 if quick else 40_000
    social = dict(k=3_000, z=0.8, f=0.5)     # slow-moving word frequencies
    stock = dict(k=400, z=1.0, f=1.5)        # bursty keys
    for th in thetas:
        thr, _, skew = stage_throughput(WordCount(), "mixed", th, social,
                                        tuples_per_interval=n)
        out.append((f"fig14/social_mixed_th{th}", 0.0,
                    f"throughput={thr:.2f};skew={skew:.2f}"))
        thr, _, skew = stage_throughput(WindowedSelfJoin(), "mixed", th,
                                        stock, tuples_per_interval=n // 4)
        out.append((f"fig14/stock_mixed_th{th}", 0.0,
                    f"throughput={thr:.2f};skew={skew:.2f}"))
        thr, _, skew = stage_throughput(WordCount(), "readj", th, social,
                                        tuples_per_interval=n)
        out.append((f"fig14/social_readj_th{th}", 0.0,
                    f"throughput={thr:.2f};skew={skew:.2f}"))
    # PKG: split-key two-choices + merge cost; theta-insensitive
    gen = WorkloadGen(seed=0, **social)
    from repro.core import Assignment, ModHash
    stats = gen.interval(Assignment(ModHash(10)), fluctuate=False)
    reps = np.repeat(stats.keys, 4)
    w = np.repeat(stats.cost / 4, 4)
    res = pkg_route(reps[:n], w[:n], 10)
    makespan = res.loads.max() + res.merge_cost / 10
    out.append(("fig14/social_pkg", 0.0,
                f"throughput={n/makespan:.2f};"
                f"split_keys={res.split_keys}"))
    return out
