"""Planner scaling: plan time vs key-domain size K for the algorithm family.

Sweeps the array-native planner (mixed / mintable / minmig / compact /
readj / mixed+head-split) over K = 1e4..1e6 on two workload profiles and
A/Bs `mixed` against the scalar pre-PR planner preserved in
`repro.core.balancer.reference`:

* ``paper``  — Table II defaults (z=0.9, theta_max=0.08, warm table,
  f=0.5): the common near-balanced interval, little churn.
* ``tight``  — absolute balance (theta_max=0, the paper's Fig. 4 setting)
  under full fluctuation: every instance sheds to the exact mean and the
  table budget forces Mixed's n-escalation, i.e. the plan actually works.

Every A/B point also asserts plan parity (`RebalanceResult.same_plan`), so
the reported speedup is for bit-identical output. The headline acceptance
number is ``speedups["tight"]["100000"]`` (>= 10x required).

A ``mixed_sketch`` series rides along: the full sketch-mode controller
interval cycle (streaming ``ingest`` + O(head) snapshot/trigger/plan, see
``repro.core.balancer.sketch``) timed on the same instances. Exact
planners are capped at K=1e6 (materializing O(K) stats arrays per point
is exactly what sketch mode exists to avoid); the sketch series is what
completes the K=1e7 point in ``--full``, with controller-resident stats
bytes reported per point.

Run directly for JSON output:

    PYTHONPATH=src:. python benchmarks/planner_scaling.py [--full|--smoke] [--out f]

or via the harness: ``python benchmarks/run.py --only planner_scaling``.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core import RebalanceController
from repro.core.balancer import (Assignment, BalanceConfig, ModHash,
                                 SketchConfig, compact_mixed, metrics,
                                 mintable, minmig, mixed, readj,
                                 reference_mixed)
from repro.streams.generator import WorkloadGen

PROFILES = {
    "paper": dict(z=0.9, f=0.5, theta_max=0.08, table_max=3_000),
    "tight": dict(z=0.9, f=1.0, theta_max=0.0, table_max=3_000),
}

# per-algorithm K ceilings (None = no cap); anything skipped is logged so the
# JSON never silently narrows coverage
REFERENCE_K_CAP = 100_000     # scalar planner: ~18 s at 1e5 on 'tight'
READJ_K_CAP = 10_000          # pairwise search is O(H^2) per round
EXACT_K_CAP = 1_000_000       # O(K) stats + plan; sketch mode beyond this


def _head_mixed(stats, assignment, config):
    return mixed(stats, assignment,
                 dataclasses.replace(config, head_fraction=0.01))


def _compact(stats, assignment, config):
    return compact_mixed(stats, assignment, config, r=3)


def _readj(stats, assignment, config):
    return readj(stats, assignment, config, sigma=0.01)


ALGOS = {
    "mixed": mixed,
    "mintable": mintable,
    "minmig": minmig,
    "compact_mixed_r3": _compact,
    "mixed_head_1pct": _head_mixed,
    "readj": _readj,
}


def _instance(profile: str, k: int, seed: int = 0):
    """Warmed instance: one mixed solve builds the table, one fluctuation
    step produces the interval the planners are timed on."""
    p = PROFILES[profile]
    gen = WorkloadGen(k=k, z=p["z"], f=p["f"], seed=seed, window=2)
    assignment = Assignment(ModHash(15, seed=seed))
    cfg = BalanceConfig(theta_max=p["theta_max"], table_max=p["table_max"],
                        window=2)
    stats = gen.interval(assignment, fluctuate=False)
    assignment = mixed(stats, assignment, cfg).assignment
    return gen.interval(assignment), assignment, cfg


def _time_algo(fn, stats, assignment, cfg, repeats: int):
    best = None
    for _ in range(repeats):
        res = fn(stats, assignment, cfg)
        if best is None or res.plan_time_s < best.plan_time_s:
            best = res
    return best


def _time_sketch_cycle(stats, assignment, cfg, repeats: int):
    """Full sketch-mode interval cycle: streaming ingest of the raw
    per-interval arrays + O(head) snapshot/trigger/plan. Returns
    (seconds, event, resident_bytes, head_keys)."""
    best, ev, resident, head = float("inf"), None, 0, 0
    for _ in range(repeats):
        ctrl = RebalanceController(
            dataclasses.replace(assignment, table=dict(assignment.table)),
            cfg, algorithm="mixed", stats_mode="sketch",
            sketch=SketchConfig())
        t0 = time.perf_counter()
        ctrl.ingest(stats.keys, stats.cost, freq=stats.freq)
        ctrl.ingest(stats.keys, np.zeros(stats.keys.size), mem=stats.mem)
        e = ctrl.on_interval(None, force=True)
        dt = time.perf_counter() - t0
        if dt < best:
            snap = ctrl.last_stats
            best, ev = dt, e
            head = int(snap.keys.size)
            resident = int(ctrl.sketch.nbytes) + int(sum(
                a.nbytes for a in (snap.keys, snap.cost, snap.mem, snap.freq)
                if a is not None))
    return best, ev, resident, head


def run(ks: Optional[List[int]] = None, full: bool = False,
        smoke: bool = False) -> dict:
    if ks is None:
        if smoke:
            ks = [5_000]
        elif full:
            ks = [10_000, 30_000, 100_000, 300_000, 1_000_000, 10_000_000]
        else:
            ks = [10_000, 30_000, 100_000]
    series: List[dict] = []
    skipped: List[dict] = []
    speedups: Dict[str, Dict[str, float]] = {}
    parity: List[dict] = []
    for profile in PROFILES:
        speedups[profile] = {}
        for k in ks:
            stats, assignment, cfg = _instance(profile, k)
            repeats = 2 if k <= 30_000 else 1
            mixed_time = None
            for name, fn in ALGOS.items():
                if name == "readj" and k > READJ_K_CAP:
                    skipped.append({"algo": name, "profile": profile, "k": k,
                                    "reason": f"O(H^2) search; capped at "
                                              f"K={READJ_K_CAP}"})
                    continue
                if k > EXACT_K_CAP:
                    skipped.append({"algo": name, "profile": profile, "k": k,
                                    "reason": f"exact O(K) stats + plan; "
                                              f"capped at K={EXACT_K_CAP} "
                                              f"(sketch mode covers larger "
                                              f"K)"})
                    continue
                res = _time_algo(fn, stats, assignment, cfg, repeats)
                series.append({
                    "profile": profile, "algo": name, "k": k,
                    "plan_time_s": res.plan_time_s,
                    "theta": res.theta,
                    "feasible_balance": res.feasible_balance,
                    "table_size": res.table_size,
                    "moved_keys": int(len(res.moved_keys)),
                    "trials": res.meta.get("trials", 1.0),
                })
                if name == "mixed":
                    mixed_time = res
            # sketch-mode interval cycle at every K — the only series at
            # K > EXACT_K_CAP, where O(K) stats materialization is the
            # bottleneck the sketch removes
            t_s, ev_s, resident, head = _time_sketch_cycle(
                stats, assignment, cfg, repeats)
            series.append({
                "profile": profile, "algo": "mixed_sketch", "k": k,
                "plan_time_s": t_s,
                "theta": metrics.theta_for(stats, ev_s.result.assignment),
                "feasible_balance": ev_s.result.feasible_balance,
                "table_size": ev_s.result.table_size,
                "moved_keys": int(len(ev_s.result.moved_keys)),
                "head_keys": head,
                "stats_bytes": resident,
            })
            if k > REFERENCE_K_CAP:
                skipped.append({"algo": "reference_mixed", "profile": profile,
                                "k": k,
                                "reason": f"scalar planner; capped at "
                                          f"K={REFERENCE_K_CAP}"})
                continue
            # same best-of-N as the array planner, so the A/B is symmetric
            ref = _time_algo(reference_mixed, stats, assignment, cfg, repeats)
            series.append({
                "profile": profile, "algo": "reference_mixed", "k": k,
                "plan_time_s": ref.plan_time_s, "theta": ref.theta,
                "feasible_balance": ref.feasible_balance,
                "table_size": ref.table_size,
                "moved_keys": int(len(ref.moved_keys)),
                "trials": ref.meta.get("trials", 1.0),
            })
            ok = mixed_time.same_plan(ref)
            parity.append({"profile": profile, "k": k, "ok": ok})
            speedups[profile][str(k)] = (ref.plan_time_s /
                                         mixed_time.plan_time_s)
    return {
        "ks": ks,
        "profiles": PROFILES,
        "series": series,
        "speedups_mixed_vs_reference": speedups,
        "parity": parity,
        "parity_all_ok": all(p["ok"] for p in parity),
        "skipped": skipped,
    }


def rows(quick: bool = True):
    """run.py harness adapter (kept small: K <= 3e4 so the sweep stays fast)."""
    r = run(ks=[10_000, 30_000] if quick else [10_000, 30_000, 100_000])
    out = []
    for s in r["series"]:
        if s["algo"] in ("mixed", "reference_mixed", "compact_mixed_r3",
                         "mixed_sketch"):
            out.append((f"planner_scaling/{s['profile']}/{s['algo']}/k{s['k']}",
                        s["plan_time_s"] * 1e6,
                        f"theta={s['theta']:.4f};table={s['table_size']}"))
    for profile, sp in r["speedups_mixed_vs_reference"].items():
        for k, x in sp.items():
            out.append((f"planner_scaling/{profile}/speedup/k{k}", 0.0,
                        f"{x:.1f}x;parity={r['parity_all_ok']}"))
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="extend the sweep to K=3e5, 1e6 and a sketch-only "
                         "K=1e7 point")
    ap.add_argument("--smoke", action="store_true",
                    help="single small K (CI): exercises every algorithm, "
                         "the reference A/B and the parity check in seconds")
    ap.add_argument("--out", default=None,
                    help="write JSON here instead of stdout")
    ap.add_argument("--ks", default=None,
                    help="comma-separated explicit K sweep (overrides "
                         "--full/--smoke); the committed CI baseline is "
                         "generated with --ks 5000,10000,30000,100000 so it "
                         "is a superset of the --smoke points (see "
                         "check_perf_gate.py)")
    args = ap.parse_args()
    ks = ([int(x) for x in args.ks.split(",")] if args.ks else None)
    t0 = time.time()
    result = run(ks=ks, full=args.full, smoke=args.smoke)
    result["wall_s"] = time.time() - t0
    if not result["parity_all_ok"]:
        print("PARITY FAILURE: array planner diverged from reference",
              file=sys.stderr)
        sys.exit(1)
    blob = json.dumps(result, indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(blob + "\n")
        tight = result["speedups_mixed_vs_reference"].get("tight", {})
        print(f"wrote {args.out}: tight-profile speedups {tight}",
              file=sys.stderr)
    else:
        print(blob)


if __name__ == "__main__":
    main()
