"""Fig. 17 (appendix): migration cost vs routing-table budget N_A."""

from repro.core.balancer import mixed

from .common import timed, workload


def rows(quick=True):
    out = []
    nas = (64, 512, 2_000, 50_000) if quick else (64, 128, 256, 512, 1_024,
                                                  2_000, 10_000, 50_000)
    for na in nas:
        _, stats, a, cfg = workload(k=5_000, theta_max=0.08, table_max=na)
        total = stats.mem.sum()
        res, us = timed(mixed, stats, a, cfg, repeats=1)
        out.append((f"fig17/mixed_na{na}", us,
                    f"mig_frac={res.migration_cost/total:.4f};"
                    f"table={res.table_size}"))
    return out
