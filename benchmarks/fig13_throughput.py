"""Fig. 13: throughput + latency vs fluctuation rate (Mixed vs Readj vs
hash-only vs Ideal) on the stream engine's performance model."""

from repro.streams import WordCount

from .common import stage_throughput


def rows(quick=True):
    out = []
    fs = (0.2, 1.0) if quick else (0.0, 0.5, 1.0, 1.5, 2.0)
    n = 8_000 if quick else 40_000
    for f in fs:
        gk = dict(k=3_000, z=0.9, f=f)
        for name, algo, th in (("mixed", "mixed", 0.08),
                               ("readj", "readj", 0.08),
                               ("hash", "mixed", 1e9)):
            thr, lat, skew = stage_throughput(WordCount(), algo, th, gk,
                                              tuples_per_interval=n)
            out.append((f"fig13/{name}_f{f}", lat * 1e6 / n,
                        f"throughput={thr:.2f};skew={skew:.2f}"))
        out.append((f"fig13/ideal_f{f}", 0.0, "throughput=10.00;skew=1.00"))
    return out
