"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. --full runs paper-scale sweeps;
the default quick mode keeps the whole suite to a few minutes on CPU.
"""

import argparse
import importlib
import sys
import time

MODULES = [
    "fig07_skew_cdf", "fig08_instances", "fig09_theta", "fig10_keydomain",
    "fig11_discretization", "fig12_fluctuation", "fig13_throughput",
    "fig14_realdata", "fig15_scaleout", "fig16_tpch", "fig17_table_size",
    "fig18_table_growth", "fig19_window", "fig20_beta",
    "moe_skewshield", "kernels_bench", "engine_fastpath", "planner_scaling",
    "sketch_scaling", "topology_pipeline", "strategy_matrix",
    "chaos_recovery",
]

#: the per-PR CI subset (--smoke): one representative module per subsystem —
#: single-stage engine figure, multi-stage topology, the cross-strategy
#: matrix (which also asserts mixed/reference and pkg/potc parity per shape),
#: the sketch-vs-exact stats A/B (which asserts its theta-quality contract
#: per shape) and the chaos/recovery arms (which assert the recovery-
#: lossless contract per point)
SMOKE_MODULES = ["fig16_tpch", "topology_pipeline", "strategy_matrix",
                 "sketch_scaling", "chaos_recovery"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated module filter")
    ap.add_argument("--smoke", action="store_true",
                    help="per-PR CI subset (quick mode, one module per "
                         "subsystem); mutually exclusive with --only")
    args = ap.parse_args()
    if args.smoke and args.only:
        print("# pass either --smoke or --only, not both", file=sys.stderr)
        sys.exit(2)
    mods = SMOKE_MODULES if args.smoke else MODULES if not args.only else [
        m for m in MODULES if any(o in m for o in args.only.split(","))]
    if args.only and not mods:
        print(f"# no module matches --only={args.only}", file=sys.stderr)
        sys.exit(2)
    print("name,us_per_call,derived")
    failed = []
    for mod_name in mods:
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{mod_name}")
            for name, us, derived in mod.rows(quick=not args.full):
                print(f"{name},{us:.1f},{derived}", flush=True)
        except Exception as e:  # noqa: BLE001
            print(f"{mod_name}/ERROR,0,{type(e).__name__}: {e}",
                  file=sys.stderr, flush=True)
            failed.append(mod_name)
        print(f"# {mod_name} done in {time.time()-t0:.1f}s", file=sys.stderr)
    if failed:
        # non-zero exit so CI gates on the suite instead of silently passing
        print(f"# FAILED modules ({len(failed)}): {','.join(failed)}",
              file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
