"""Fig. 8: plan-generation time + migration cost vs number of instances."""

from repro.core.balancer import metrics, mintable, mixed

from .common import Row, timed, workload


def rows(quick=True):
    out = []
    nds = (5, 10, 15, 20, 30, 40) if not quick else (5, 15, 40)
    for w in (1, 5):
        for nd in nds:
            _, stats, a, cfg = workload(n_dest=nd, window=w,
                                        k=5_000 if quick else 10_000)
            total_mem = stats.mem.sum()
            for name, algo in (("mixed", mixed), ("mintable", mintable)):
                res, us = timed(algo, stats, a, cfg)
                out.append((f"fig08/{name}_nd{nd}_w{w}", us,
                            f"mig_frac={res.migration_cost/total_mem:.4f};"
                            f"theta={res.theta:.3f}"))
    return out
