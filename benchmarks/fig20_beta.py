"""Figs. 20/21 (appendix): routing-table size and migration cost vs beta."""

import dataclasses

from repro.core.balancer import minmig

from .common import timed, workload


def rows(quick=True):
    out = []
    betas = (1.0, 1.5, 2.0) if quick else (1.0, 1.25, 1.5, 1.75, 2.0)
    for beta in betas:
        _, stats, a, cfg = workload(k=5_000)
        cfg = dataclasses.replace(cfg, beta=beta, table_max=10**9)
        total = stats.mem.sum()
        res, us = timed(minmig, stats, a, cfg, repeats=1)
        out.append((f"fig20/minmig_beta{beta}", us,
                    f"table={res.table_size};"
                    f"mig_frac={res.migration_cost/total:.4f}"))
    return out
