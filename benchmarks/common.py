"""Shared helpers for the per-figure benchmarks.

Every bench module exposes ``rows(quick: bool) -> list[(name, us_per_call,
derived)]``; run.py prints them as CSV. ``us_per_call`` is the measured
wall-time of the operation the figure studies (plan generation, interval
processing); ``derived`` carries the figure's headline metric.
"""

from __future__ import annotations

import time
from typing import Callable, List, Tuple

import numpy as np

from repro.core import Assignment, BalanceConfig, ModHash, RebalanceController
from repro.core.balancer import KeyStats
from repro.streams import KeyedStage, WordCount, WindowedSelfJoin, WorkloadGen

Row = Tuple[str, float, str]


def timed(fn: Callable, *args, repeats: int = 3, **kw):
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best * 1e6  # us


def workload(k=10_000, z=0.85, f=1.0, n_dest=15, seed=0, window=1,
             warm_table=True, algorithm="mixed", theta_max=0.08,
             table_max=3_000):
    """Paper Table II defaults: returns (stats, assignment, config) after one
    warm rebalance so the routing table is non-trivial."""
    gen = WorkloadGen(k=k, z=z, f=f, seed=seed, window=window)
    assignment = Assignment(ModHash(n_dest, seed=seed))
    cfg = BalanceConfig(theta_max=theta_max, table_max=table_max,
                        window=window)
    stats = gen.interval(assignment, fluctuate=False)
    if warm_table:
        from repro.core.balancer import mixed
        assignment = mixed(stats, assignment, cfg).assignment
        stats = gen.interval(assignment)            # one fluctuation step
    return gen, stats, assignment, cfg


def stage_throughput(operator, algorithm, theta_max, gen_kwargs,
                     intervals=5, tuples_per_interval=20_000, table_max=3000,
                     window=2, n_tasks=10, seed=0, vectorized=True):
    """Drive the stream engine; return (mean throughput, mean latency proxy,
    mean skewness) over the steady-state intervals.

    Uses the array-native ``process_interval_arrays`` entry point so the
    figures measure the engine, not tuple-list construction; pass
    ``vectorized=False`` to benchmark the per-tuple reference loop instead
    (see ``benchmarks/engine_fastpath.py`` for the A/B comparison)."""
    gen = WorkloadGen(seed=seed, window=window, **gen_kwargs)
    controller = RebalanceController(
        Assignment(ModHash(n_tasks, seed=seed)),
        BalanceConfig(theta_max=theta_max, table_max=table_max,
                      window=window),
        algorithm=algorithm)
    stage = KeyedStage(operator, controller, window=window,
                       vectorized=vectorized)
    for i in range(intervals):
        if i > 0:
            gen.interval(stage.controller.assignment)
        keys = gen.draw_tuples(tuples_per_interval).astype(np.int64)
        stage.process_interval_arrays(keys, np.full(tuples_per_interval, i))
    reps = stage.reports[1:]
    thr = float(np.mean([r.throughput for r in reps]))
    lat = float(np.mean([r.makespan + r.migration_stall for r in reps]))
    skew = float(np.mean([r.skewness for r in reps]))
    return thr, lat, skew


def ideal_throughput(gen_kwargs, intervals=5, tuples_per_interval=20_000,
                     n_tasks=10, seed=0):
    """The paper's 'Ideal' line: key-oblivious shuffle (perfect balance)."""
    return tuples_per_interval / (tuples_per_interval / n_tasks)
