"""Kernel micro-bench (interpret mode on CPU: correctness-path timing only;
TPU numbers come from the roofline analysis, not wall time here)."""

import jax.numpy as jnp
import numpy as np

from repro.kernels import attention, fused_key_stats, mixed_route

from .common import timed


def rows(quick=True):
    out = []
    rng = np.random.default_rng(0)
    keys = jnp.asarray(rng.integers(0, 1024, 8_192), jnp.int32)
    costs = jnp.ones((8_192,), jnp.float32)
    (f, c), us = timed(lambda: [x.block_until_ready() for x in
                                fused_key_stats(keys, costs, 1024)],
                       repeats=2)
    out.append(("kernels/key_stats_8k_tokens", us, f"sum={float(f.sum()):.0f}"))
    tk = jnp.asarray(rng.choice(10_000, 256, replace=False), jnp.int32)
    td = jnp.asarray(rng.integers(0, 16, 256), jnp.int32)
    d, us = timed(lambda: mixed_route(keys, tk, td, 16).block_until_ready(),
                  repeats=2)
    out.append(("kernels/routing_lookup_8k", us, f"n_dest=16"))
    q = jnp.asarray(rng.standard_normal((1, 4, 256, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), jnp.bfloat16)
    o, us = timed(lambda: attention(q, k, k, block_t=128,
                                    block_s=128).block_until_ready(),
                  repeats=2)
    out.append(("kernels/flash_attention_256", us, "gqa=2"))
    return out
