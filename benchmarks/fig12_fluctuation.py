"""Fig. 12: plan time + migration cost vs fluctuation rate f
(Mixed vs Mixed_BF vs Readj)."""

from repro.core.balancer import mixed, mixed_bf, readj_best_sigma

from .common import timed, workload


def rows(quick=True):
    out = []
    fs = (0.2, 1.0, 2.0) if quick else (0.0, 0.2, 0.5, 1.0, 1.5, 2.0)
    k = 2_000 if quick else 10_000
    for f in fs:
        _, stats, a, cfg = workload(k=k, f=f, theta_max=0.08)
        total = stats.mem.sum()
        algos = [("mixed", mixed), ("mixed_bf", mixed_bf),
                 ("readj", readj_best_sigma)]
        for name, algo in algos:
            res, us = timed(algo, stats, a, cfg, repeats=1)
            out.append((f"fig12/{name}_f{f}", us,
                        f"mig_frac={res.migration_cost/total:.4f};"
                        f"theta={res.theta:.3f}"))
    return out
