"""Beyond-paper: end-to-end multi-stage topology benchmark.

Drives a 3-stage pipeline (filter -> windowed word count -> bucketed top-k
front) under per-stage Mixed controllers and reports:

* pipeline throughput (tuples / pipeline critical path) for mixed vs
  hash-only routing, with per-stage rebalance counts — the multi-stage
  analogue of fig13;
* the wall-clock speedup of the vectorized topology path over the
  per-tuple reference path, measured end to end across stage boundaries
  (parity of the two is proven in tests/test_topology.py).

This module is the per-PR CI smoke for the topology subsystem:

    PYTHONPATH=src:. python benchmarks/run.py --only topology_pipeline
"""

from __future__ import annotations

import time

import numpy as np

from repro.streams import (Filter, MergeCounts, StageSpec, Topology,
                           WordCount, WorkloadGen, keyed_stage)


def _topology(theta_max, vectorized=True):
    filt = keyed_stage(Filter(lambda k, v: (k + v) % 4 != 0), n_tasks=6,
                       theta_max=theta_max, table_max=1_000, window=2,
                       seed=0, vectorized=vectorized)
    count = keyed_stage(WordCount(), n_tasks=8, theta_max=theta_max,
                        table_max=2_000, window=2, seed=1,
                        vectorized=vectorized)
    topk = keyed_stage(MergeCounts(), n_tasks=4, theta_max=theta_max,
                       table_max=300, window=2, seed=2,
                       vectorized=vectorized)
    return Topology([
        StageSpec("filter", filt),
        StageSpec("count", count),
        StageSpec("topk", topk, rekey=lambda k, v: k % 64),
    ])


def _drive(topo, n, intervals, k=2_000, z=1.0, f=0.8, seed=5):
    """Returns (mean steady-state throughput, rebalance counts, wall seconds
    spent inside process_interval)."""
    gen = WorkloadGen(k=k, z=z, f=f, seed=seed, window=2)
    batches = []
    for i in range(intervals):
        if i:
            # fluctuate against the initial assignment: batches are
            # pre-generated so the timed loop below measures the engine only
            gen.interval(topo.specs[0].stage.controller.assignment)
        keys = gen.draw_tuples(n).astype(np.int64)
        batches.append((keys, (keys * 7 + i) % 11))
    elapsed = 0.0
    for keys, values in batches:
        t0 = time.perf_counter()
        topo.process_interval(keys, values)
        elapsed += time.perf_counter() - t0
    reps = topo.reports[1:]
    thr = float(np.mean([r.throughput for r in reps]))
    reb = {name: len(ivs) for name, ivs in topo.rebalances_by_stage().items()}
    return thr, reb, elapsed


def rows(quick=True):
    n = 6_000 if quick else 30_000
    intervals = 5 if quick else 10
    out = []
    thr, reb, vec_s = _drive(_topology(0.08), n, intervals)
    reb_s = ",".join(f"{k}:{v}" for k, v in reb.items())
    out.append(("topology/pipeline_mixed", vec_s / intervals * 1e6,
                f"throughput={thr:.2f};rebalances={reb_s}"))
    thr_hash, _, _ = _drive(_topology(1e9), n, intervals)
    out.append(("topology/pipeline_hash", 0.0,
                f"throughput={thr_hash:.2f};gain={thr/thr_hash:.2f}x"))
    _, _, ref_s = _drive(_topology(0.08, vectorized=False), n, intervals)
    out.append(("topology/vectorized_speedup", 0.0,
                f"{ref_s/vec_s:.1f}x;ref_s={ref_s:.2f};vec_s={vec_s:.2f}"))
    return out
