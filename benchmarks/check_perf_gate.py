"""Perf-regression gate: fresh planner_scaling JSON vs the committed baseline.

Compares per-point ``plan_time_s`` for every (profile, algo, k) series point
present in BOTH files and fails when any ratio fresh/baseline exceeds the
threshold (default 2x — generous enough to absorb runner-to-runner noise on
best-of-N timings, tight enough to catch a real algorithmic regression).
Points whose baseline time is below ``--min-baseline-s`` (default 15 ms)
are printed but not gated: small plans are scheduler-noise-dominated
(observed up to ~1.6x swing on the same machine) and would flake the ratio
even with no regression.

The committed baseline (``benchmarks/planner_scaling.json``) is generated
with a K sweep that is a superset of the CI smoke sweep
(``--ks 5000,10000,30000,100000``), so the per-PR ``--smoke`` run always
finds its points. Zero common points is a configuration error and exits 2
so the gate can never silently pass.

The comparison is absolute wall time, so the baseline must come from a
machine in the same speed class as the CI runners. If the gate starts
failing uniformly across algorithms after a runner-class change (every
ratio shifted by a similar factor, no code change), refresh the baseline:
rerun ``planner_scaling.py --ks 5000,10000,30000,100000`` on a runner (the
nightly workflow's environment) and commit the JSON. A genuine regression
shows up as one or a few algorithms moving while the rest hold.

Usage (what CI runs):

    python benchmarks/planner_scaling.py --smoke --out fresh.json
    python benchmarks/check_perf_gate.py --fresh fresh.json \
        --baseline benchmarks/planner_scaling.json --max-ratio 2.0
"""

from __future__ import annotations

import argparse
import json
import sys


def _index(series):
    return {(s["profile"], s["algo"], s["k"]): s["plan_time_s"]
            for s in series}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fresh", required=True,
                    help="JSON from the just-run planner_scaling sweep")
    ap.add_argument("--baseline", default="benchmarks/planner_scaling.json",
                    help="committed baseline JSON")
    ap.add_argument("--max-ratio", type=float, default=2.0,
                    help="fail when fresh/baseline plan time exceeds this")
    ap.add_argument("--min-baseline-s", type=float, default=0.015,
                    help="points with baseline plan time below this are "
                         "reported but not gated (noise-dominated: "
                         "low-tens-of-ms best-of-trials points can swing "
                         "~1.6x on the SAME machine)")
    args = ap.parse_args()

    with open(args.fresh) as f:
        fresh = _index(json.load(f)["series"])
    with open(args.baseline) as f:
        base = _index(json.load(f)["series"])

    common = sorted(set(fresh) & set(base))
    if not common:
        print("perf gate misconfigured: no (profile, algo, k) point is "
              "shared between fresh and baseline JSON", file=sys.stderr)
        sys.exit(2)

    violations = []
    gated = 0
    print(f"{'profile':>8} {'algo':>18} {'k':>8} {'base_s':>10} "
          f"{'fresh_s':>10} {'ratio':>7}")
    for key in common:
        b, fr = base[key], fresh[key]
        ratio = fr / b if b > 0 else float("inf")
        exempt = b < args.min_baseline_s
        flag = ("  (ungated: baseline < "
                f"{args.min_baseline_s * 1e3:.0f} ms)" if exempt
                else "  <-- REGRESSION" if ratio > args.max_ratio else "")
        print(f"{key[0]:>8} {key[1]:>18} {key[2]:>8} {b:>10.4f} "
              f"{fr:>10.4f} {ratio:>7.2f}{flag}")
        if exempt:
            continue
        gated += 1
        if ratio > args.max_ratio:
            violations.append((key, ratio))

    if not gated:
        print("perf gate misconfigured: every common point fell under "
              "--min-baseline-s; nothing was gated", file=sys.stderr)
        sys.exit(2)
    if violations:
        print(f"\nperf gate FAILED: {len(violations)}/{gated} gated points "
              f"regressed beyond {args.max_ratio}x", file=sys.stderr)
        sys.exit(1)
    print(f"\nperf gate OK: {gated} gated points within "
          f"{args.max_ratio}x of baseline "
          f"({len(common) - gated} noise-exempt)")


if __name__ == "__main__":
    main()
