"""Perf-regression gate: fresh benchmark JSON vs committed baselines.

Gates two smoke benchmarks with one rule — per-point wall time must not
exceed ``--max-ratio`` (default 2x) of the committed baseline; points whose
baseline time is below ``--min-baseline-s`` (default 15 ms) are printed but
not gated (small runs are scheduler-noise-dominated, observed up to ~1.6x
swing on the same machine):

* **planner** (``--fresh`` / ``--baseline``): ``planner_scaling`` series,
  keyed by ``(profile, algo, k)`` on ``plan_time_s``;
* **engine fast path** (``--fastpath-fresh`` / ``--fastpath-baseline``):
  ``engine_fastpath`` flat ``series``, keyed by point name on ``seconds``
  (the per-tuple/vectorized dispatch A/B and the object/columnar
  store-backend A/B);
* **sketch stats** (``--sketch-fresh`` / ``--sketch-baseline``):
  ``sketch_scaling`` series, keyed by ``(shape, k, mode)`` on ``seconds``
  (the exact-vs-sketch controller interval cycles — a regression in either
  mode's cycle time is caught here; the sketch's own >= 5x speedup and
  theta-quality contracts are asserted inside the benchmark itself);
* **chaos/recovery** (``--chaos-fresh`` / ``--chaos-baseline``):
  ``chaos_recovery`` series, keyed by point name (``backend/arm``) on
  ``seconds`` — the oracle floor, the checkpoint-overhead arm and the
  injected-failure recovery arm (the recovery-lossless bit-identity
  contract is asserted inside the benchmark itself).

A third section gates *values*, not wall time: **strategy matrix**
(``--matrix-fresh`` / ``--matrix-baseline``) compares the ``mixed``-planner
rows of ``strategy_matrix.py`` (imbalance theta, migrated bytes, table
size, model throughput — all deterministic model units given the seed)
against the committed ``benchmarks/strategy_matrix.json`` within
``--matrix-rtol`` relative tolerance. A drift here means the planner's
*behavior* changed (plans, migration volume, balance quality), which wall
clocks cannot see.

The committed planner baseline (``benchmarks/planner_scaling.json``) is
generated with a K sweep that is a superset of the CI smoke sweep
(``--ks 5000,10000,30000,100000``), so the per-PR ``--smoke`` run always
finds its points. The committed fast-path baseline is
``benchmarks/engine_fastpath.json`` (quick mode, the same mode CI runs).
Zero common points in any enabled section is a configuration error and
exits 2 so the gate can never silently pass.

The comparison is absolute wall time, so baselines must come from a machine
in the same speed class as the CI runners. If the gate starts failing
uniformly after a runner-class change (every ratio shifted by a similar
factor, no code change), refresh the affected baseline: rerun
``planner_scaling.py --ks 5000,10000,30000,100000`` and/or
``engine_fastpath.py`` on a runner (the nightly workflow's environment) and
commit the JSON. A genuine regression shows up as one or a few points
moving while the rest hold.

Usage (what CI runs):

    python benchmarks/planner_scaling.py --smoke --out fresh.json
    python benchmarks/engine_fastpath.py --out fresh_fastpath.json
    python benchmarks/sketch_scaling.py --smoke --out fresh_sketch.json
    python benchmarks/check_perf_gate.py --fresh fresh.json \
        --baseline benchmarks/planner_scaling.json \
        --fastpath-fresh fresh_fastpath.json \
        --fastpath-baseline benchmarks/engine_fastpath.json \
        --sketch-fresh fresh_sketch.json \
        --sketch-baseline benchmarks/sketch_scaling.json \
        --max-ratio 2.0

The committed sketch baseline (``benchmarks/sketch_scaling.json``) is the
default sweep (K=1e5 quality shapes + the K=1e6 scale point), a superset
of the --smoke points.
"""

from __future__ import annotations

import argparse
import json
import sys


def _index_planner(series):
    return {(s["profile"], s["algo"], s["k"]): s["plan_time_s"]
            for s in series}


def _index_fastpath(series):
    return {(s["name"],): s["seconds"] for s in series}


def _index_sketch(series):
    return {(s["shape"], s["k"], s["mode"]): s["seconds"] for s in series}


def _index_chaos(series):
    return {(s["name"],): s["seconds"] for s in series}

#: strategy-matrix metrics gated by value (wall_s is machine noise; these
#: are deterministic functions of the seeded workload + planner behavior)
MATRIX_METRICS = ("theta_mean", "migrated_bytes", "table_size", "throughput")


def _index_matrix(rows, strategy="mixed"):
    return {(r["shape"], r["strategy"], m): float(r[m])
            for r in rows if r["strategy"] == strategy
            for m in MATRIX_METRICS}


def _gate_matrix(fresh, base, rtol):
    """Value-tolerance comparison of the mixed-planner matrix rows; returns
    (violations, gated). Exits 2 on zero common points like _gate_section."""
    common = sorted(set(fresh) & set(base))
    if not common:
        print("perf gate misconfigured [strategy_matrix]: no point is "
              "shared between fresh and baseline JSON", file=sys.stderr)
        sys.exit(2)
    width = max(len(" ".join(str(p) for p in key)) for key in common)
    print("[strategy_matrix]")
    print(f"{'point':>{width}} {'base':>12} {'fresh':>12} {'rel_err':>8}")
    violations = []
    for key in common:
        b, fr = base[key], fresh[key]
        rel = abs(fr - b) / max(abs(b), 1e-12)
        flag = "  <-- DRIFT" if rel > rtol else ""
        name = " ".join(str(p) for p in key)
        print(f"{name:>{width}} {b:>12.4f} {fr:>12.4f} {rel:>8.4f}{flag}")
        if rel > rtol:
            violations.append((("strategy_matrix",) + key, rel))
    return violations, len(common)


def _gate_section(label, fresh, base, max_ratio, min_baseline_s):
    """Print one section's comparison table; returns (violations, gated).

    ``fresh``/``base`` map point-key tuples to wall seconds. Exits 2 from
    here when the section has no common points (misconfiguration must never
    read as a pass).
    """
    common = sorted(set(fresh) & set(base))
    if not common:
        print(f"perf gate misconfigured [{label}]: no point is shared "
              "between fresh and baseline JSON", file=sys.stderr)
        sys.exit(2)

    width = max(len(" ".join(str(p) for p in key)) for key in common)
    print(f"[{label}]")
    print(f"{'point':>{width}} {'base_s':>10} {'fresh_s':>10} {'ratio':>7}")
    violations = []
    gated = 0
    for key in common:
        b, fr = base[key], fresh[key]
        ratio = fr / b if b > 0 else float("inf")
        exempt = b < min_baseline_s
        flag = ("  (ungated: baseline < "
                f"{min_baseline_s * 1e3:.0f} ms)" if exempt
                else "  <-- REGRESSION" if ratio > max_ratio else "")
        name = " ".join(str(p) for p in key)
        print(f"{name:>{width}} {b:>10.4f} {fr:>10.4f} {ratio:>7.2f}{flag}")
        if exempt:
            continue
        gated += 1
        if ratio > max_ratio:
            violations.append(((label,) + key, ratio))
    return violations, gated


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fresh", default=None,
                    help="JSON from the just-run planner_scaling sweep")
    ap.add_argument("--baseline", default="benchmarks/planner_scaling.json",
                    help="committed planner baseline JSON")
    ap.add_argument("--fastpath-fresh", default=None,
                    help="JSON from the just-run engine_fastpath A/B")
    ap.add_argument("--fastpath-baseline",
                    default="benchmarks/engine_fastpath.json",
                    help="committed engine_fastpath baseline JSON")
    ap.add_argument("--sketch-fresh", default=None,
                    help="JSON from the just-run sketch_scaling A/B")
    ap.add_argument("--sketch-baseline",
                    default="benchmarks/sketch_scaling.json",
                    help="committed sketch_scaling baseline JSON")
    ap.add_argument("--chaos-fresh", default=None,
                    help="JSON from the just-run chaos_recovery arms")
    ap.add_argument("--chaos-baseline",
                    default="benchmarks/chaos_recovery.json",
                    help="committed chaos_recovery baseline JSON")
    ap.add_argument("--matrix-fresh", default=None,
                    help="JSON from the just-run strategy_matrix sweep")
    ap.add_argument("--matrix-baseline",
                    default="benchmarks/strategy_matrix.json",
                    help="committed strategy_matrix baseline JSON")
    ap.add_argument("--matrix-rtol", type=float, default=0.25,
                    help="relative tolerance for mixed-planner matrix "
                         "metrics (loose enough for cross-version numpy "
                         "rng stream drift, tight enough to catch the "
                         "planner losing balance or migration discipline)")
    ap.add_argument("--max-ratio", type=float, default=2.0,
                    help="fail when fresh/baseline wall time exceeds this")
    ap.add_argument("--min-baseline-s", type=float, default=0.015,
                    help="points with baseline time below this are reported "
                         "but not gated (noise-dominated: low-tens-of-ms "
                         "best-of-trials points can swing ~1.6x on the SAME "
                         "machine)")
    args = ap.parse_args()

    if (args.fresh is None and args.fastpath_fresh is None
            and args.sketch_fresh is None and args.chaos_fresh is None
            and args.matrix_fresh is None):
        print("perf gate misconfigured: pass --fresh, --fastpath-fresh, "
              "--sketch-fresh, --chaos-fresh and/or --matrix-fresh",
              file=sys.stderr)
        sys.exit(2)

    violations = []
    gated = 0
    if args.fresh is not None:
        with open(args.fresh) as f:
            fresh = _index_planner(json.load(f)["series"])
        with open(args.baseline) as f:
            base = _index_planner(json.load(f)["series"])
        v, g = _gate_section("planner", fresh, base, args.max_ratio,
                             args.min_baseline_s)
        violations += v
        gated += g
    if args.fastpath_fresh is not None:
        with open(args.fastpath_fresh) as f:
            fresh = _index_fastpath(json.load(f)["series"])
        with open(args.fastpath_baseline) as f:
            base = _index_fastpath(json.load(f)["series"])
        v, g = _gate_section("engine_fastpath", fresh, base, args.max_ratio,
                             args.min_baseline_s)
        violations += v
        gated += g
    if args.sketch_fresh is not None:
        with open(args.sketch_fresh) as f:
            fresh = _index_sketch(json.load(f)["series"])
        with open(args.sketch_baseline) as f:
            base = _index_sketch(json.load(f)["series"])
        v, g = _gate_section("sketch_scaling", fresh, base, args.max_ratio,
                             args.min_baseline_s)
        violations += v
        gated += g
    if args.chaos_fresh is not None:
        with open(args.chaos_fresh) as f:
            fresh = _index_chaos(json.load(f)["series"])
        with open(args.chaos_baseline) as f:
            base = _index_chaos(json.load(f)["series"])
        v, g = _gate_section("chaos_recovery", fresh, base, args.max_ratio,
                             args.min_baseline_s)
        violations += v
        gated += g
    if args.matrix_fresh is not None:
        with open(args.matrix_fresh) as f:
            fresh = _index_matrix(json.load(f)["rows"])
        with open(args.matrix_baseline) as f:
            base = _index_matrix(json.load(f)["rows"])
        v, g = _gate_matrix(fresh, base, args.matrix_rtol)
        violations += v
        gated += g

    if not gated:
        print("perf gate misconfigured: every common point fell under "
              "--min-baseline-s; nothing was gated", file=sys.stderr)
        sys.exit(2)
    if violations:
        print(f"\nperf gate FAILED: {len(violations)}/{gated} gated points "
              f"regressed beyond {args.max_ratio}x", file=sys.stderr)
        for key, ratio in violations:
            print(f"  {' '.join(str(p) for p in key)}: {ratio:.2f}x",
                  file=sys.stderr)
        sys.exit(1)
    print(f"\nperf gate OK: {gated} gated points within "
          f"{args.max_ratio}x of baseline")


if __name__ == "__main__":
    main()
