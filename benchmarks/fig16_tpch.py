"""Fig. 16: TPC-H Q5-like continuous query — a two-stage keyed topology
(join keyed by customer/order keys with zipf-skewed foreign keys), with a
distribution change every few intervals. Mixed vs hash-only ('Storm')."""

import numpy as np

from repro.core import Assignment, BalanceConfig, ModHash, RebalanceController
from repro.streams import KeyedStage, WindowedSelfJoin, WorkloadGen


def _run(algorithm, theta_max, quick):
    n = 4_000 if quick else 20_000
    gen = WorkloadGen(k=800, z=0.8, f=1.0, seed=3, window=3)
    controller = RebalanceController(
        Assignment(ModHash(12, seed=1)),
        BalanceConfig(theta_max=theta_max, table_max=2_000, window=3),
        algorithm=algorithm)
    stage = KeyedStage(WindowedSelfJoin(), controller, window=3)
    thr = []
    for i in range(8 if quick else 12):
        if i and i % 3 == 0:
            gen.interval(stage.controller.assignment)   # burst every 3
        keys = gen.draw_tuples(n)
        rep = stage.process_interval([(int(k), i) for k in keys])
        thr.append(rep.throughput)
    return float(np.mean(thr[2:])), float(np.min(thr[2:]))


def rows(quick=True):
    out = []
    for name, algo, th in (("mixed_th0.05", "mixed", 0.05),
                           ("mixed_th0.2", "mixed", 0.2),
                           ("storm_hash", "mixed", 1e9)):
        mean_thr, min_thr = _run(algo, th, quick)
        out.append((f"fig16/{name}", 0.0,
                    f"mean_throughput={mean_thr:.2f};min={min_thr:.2f}"))
    return out
