"""Fig. 16: TPC-H Q5-like continuous query as a genuine 3-stage pipeline —
selection -> keyed join -> aggregation, each stage key-partitioned over its
own task fleet with its own controller (the paper runs the protocol per
operator):

  1. filter keyed by orderkey (the date/region selection; ~30% pass),
  2. windowed self-join keyed by custkey (orderkey re-keyed to a customer),
  3. aggregation keyed by nationkey (custkey re-keyed to one of 25 nations).

Zipf-skewed foreign keys with a distribution change every few intervals.
Mixed (two theta budgets) vs hash-only ('Storm'). The derived column also
counts rebalances per stage to show the protocol firing at different
operators in the same run.
"""

import numpy as np

from repro.streams import (Filter, StageSpec, Topology, WindowedSelfJoin,
                           WordCount, WorkloadGen, keyed_stage)

N_CUST = 200
N_NATION = 25


def _topology(theta_max):
    # the selection passes tuples whose payload (a pseudo order attribute)
    # falls in the date window — deterministic in (key, value)
    filt = keyed_stage(Filter(lambda k, v: (k * 13 + v) % 10 < 3),
                       n_tasks=8, theta_max=theta_max, table_max=2_000,
                       window=3, seed=0)
    join = keyed_stage(WindowedSelfJoin(), n_tasks=12, theta_max=theta_max,
                       table_max=2_000, window=3, seed=1)
    agg = keyed_stage(WordCount(), n_tasks=5, theta_max=theta_max,
                      table_max=500, window=3, seed=2)
    return Topology([
        StageSpec("filter", filt),
        StageSpec("join", join, rekey=lambda k, v: k % N_CUST),
        StageSpec("agg", agg, rekey=lambda k, v: k % N_NATION),
    ])


def _run(theta_max, quick):
    n = 4_000 if quick else 20_000
    gen = WorkloadGen(k=800, z=0.8, f=1.0, seed=3, window=3)
    topo = _topology(theta_max)
    for i in range(8 if quick else 12):
        if i and i % 3 == 0:
            gen.interval(topo.specs[0].stage.controller.assignment)  # burst
        keys = gen.draw_tuples(n).astype(np.int64)
        values = (keys * 7 + i) % 10          # pseudo order attributes
        topo.process_interval(keys, values)
    reps = topo.reports[2:]
    thr = [r.throughput for r in reps]
    reb = {name: len(ivs) for name, ivs in topo.rebalances_by_stage().items()}
    return float(np.mean(thr)), float(np.min(thr)), reb


def rows(quick=True):
    out = []
    for name, th in (("mixed_th0.05", 0.05), ("mixed_th0.2", 0.2),
                     ("storm_hash", 1e9)):
        mean_thr, min_thr, reb = _run(th, quick)
        reb_s = ",".join(f"{k}:{v}" for k, v in reb.items())
        out.append((f"fig16/{name}", 0.0,
                    f"mean_throughput={mean_thr:.2f};min={min_thr:.2f};"
                    f"rebalances={reb_s}"))
    return out
