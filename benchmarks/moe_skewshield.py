"""Beyond-paper: SkewShield expert placement vs static layout on drifting
zipf-routed MoE loads — imbalance theta and capacity-drop fraction."""

import numpy as np

from repro.models.skewshield import SkewShieldPlacer


def _simulate(policy, intervals, rng):
    e, s = 32, 8
    placer = SkewShieldPlacer(e, s, bytes_per_expert=64e6, theta_max=0.1)
    # drifting zipf expert popularity
    pop = (np.arange(1, e + 1, dtype=np.float64) ** -0.9)
    rng.shuffle(pop)
    thetas, drops, moved = [], [], 0
    for i in range(intervals):
        # drift: swap popularity of two random experts
        a, b = rng.integers(0, e, 2)
        pop[a], pop[b] = pop[b], pop[a]
        load = pop / pop.sum() * 1e6
        if policy == "skewshield":
            upd = placer.update(load)
            shards = placer.current_shards()
            moved += len(upd.moved_experts)
        else:
            shards = np.arange(e) // (e // s)
        shard_load = np.bincount(shards, weights=load, minlength=s)
        mean = shard_load.mean()
        thetas.append((shard_load.max() - mean) / mean)
        cap = mean * 1.25
        drops.append(float(np.maximum(shard_load - cap, 0).sum() / 1e6))
    return float(np.mean(thetas)), float(np.mean(drops)), moved


def rows(quick=True):
    out = []
    rng = np.random.default_rng(0)
    n = 10 if quick else 50
    for policy in ("static", "skewshield"):
        th, dr, moved = _simulate(policy, n, np.random.default_rng(0))
        out.append((f"moe/{policy}", 0.0,
                    f"mean_theta={th:.3f};dropped_frac={dr:.4f};"
                    f"experts_moved={moved}"))
    return out
