"""Sketch-mode controller A/B: full interval-cycle wall time, exact vs sketch.

Times one complete controller interval cycle in both stats modes, exactly
as the stream engine drives them (``repro.streams.backends.collect_stats``):

* **exact** — materialize the O(K) ``KeyStats`` from raw per-interval
  arrays (``np.union1d`` over seen ∪ held keys, segment sums for
  cost/freq/mem) and run the O(K) plan round on it;
* **sketch** — fold the same raw arrays through ``ingest`` (activity batch
  + zero-cost state-size batch), then close the interval with
  ``on_interval(None)``: an O(head) snapshot and plan round.

Two point sets, with contracts *asserted per point*, not just reported:

* **quality points** — the strategy-matrix workload shapes (zipf / hot /
  drift) at K <= 1e5: both modes' resulting assignments are scored against
  the same exact stats, and the sketch plan's theta must be within 10% of
  the exact plan's (plus a 0.02 absolute floor for near-zero thetas).
  These shapes are plan-churn-bound by design (their theta floors sit far
  above ``theta_max``), which is what makes them quality probes — and why
  they are not speed probes;
* **scale points** — a feasible large-domain shape (z=0.9, f=1.0,
  theta_max=0.02) at K >= 1e6, where the interval cycle is dominated by
  stats work and the O(K)-vs-O(head) separation is what's being measured.
  The sketch cycle must be >= 5x faster (``REPRO_SKETCH_AB_MIN``
  overrides, for constrained CI runners), and resident sketch-stats bytes
  must stay under an absolute O(H + sketch) cap at every K plus under 1/5
  of the exact per-key arrays once those dominate.

Run directly for JSON output:

    PYTHONPATH=src:. python benchmarks/sketch_scaling.py [--smoke|--full] [--out f]

or via the harness: ``python benchmarks/run.py --only sketch_scaling``.
The committed CI baseline (``benchmarks/sketch_scaling.json``) is
generated with the default sweep, a superset of the --smoke points
(see check_perf_gate.py --sketch-fresh/--sketch-baseline).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time
from typing import List, Optional

import numpy as np

from repro.core import RebalanceController
from repro.core.balancer import (Assignment, BalanceConfig, KeyStats, ModHash,
                                 SketchConfig, metrics, mixed)
from repro.streams.generator import WorkloadGen

#: quality probes: the strategy-matrix shapes (zipf exponent / fluctuation
#: rate), gated on plan theta at K <= THETA_K
QUALITY_SHAPES = [
    ("zipf", dict(z=1.1, f=0.8), 0.08),
    ("hot", dict(z=2.0, f=0.8), 0.08),
    ("drift", dict(z=1.1, f=2.5), 0.08),
]

#: speed probe: feasible balance at huge K — the regime the sketch exists
#: for, where the interval cycle is stats-bound rather than churn-bound
SCALE_SHAPE = ("scale", dict(z=0.9, f=1.0), 0.02)

N_DEST = 15
WINDOW = 2
TABLE_MAX = 3_000

#: sketch cycle must beat exact by this factor at K >= SPEEDUP_K
SPEEDUP_K = 1_000_000
SPEEDUP_MIN = float(os.environ.get("REPRO_SKETCH_AB_MIN", "5"))

#: sketch plan theta <= THETA_RTOL * exact theta + THETA_ATOL, asserted up
#: to THETA_K (above it the fixed-capacity head tracks a shrinking mass
#: fraction, so the quality contract is only reported, not gated)
THETA_RTOL = 1.10
THETA_ATOL = 0.02
THETA_K = 100_000

#: resident sketch-stats bytes must stay under this at EVERY K (O(H+sketch),
#: not O(K)) and under exact/MEM_RATIO once the exact arrays dominate
MEM_ABS_CAP = 16 << 20
MEM_RATIO = 5


def _instance(shape_cfg: dict, theta_max: float, k: int, seed: int = 0):
    """Warmed instance: one exact mixed solve builds a realistic table, one
    fluctuation step produces the interval both modes are timed on."""
    gen = WorkloadGen(k=k, seed=seed, window=WINDOW, **shape_cfg)
    assignment = Assignment(ModHash(N_DEST, seed=seed))
    cfg = BalanceConfig(theta_max=theta_max, table_max=TABLE_MAX,
                        window=WINDOW)
    stats = gen.interval(assignment, fluctuate=False)
    assignment = mixed(stats, assignment, cfg).assignment
    return gen.interval(assignment), assignment, cfg


def _fresh(assignment: Assignment) -> Assignment:
    return dataclasses.replace(assignment, table=dict(assignment.table))


def _exact_cycle(ctrl: RebalanceController, stats: KeyStats):
    """The exact engine interval: collect_stats' seen ∪ held fold, then the
    O(K) controller round on the materialized KeyStats."""
    seen, held = stats.keys, stats.keys
    universe = np.union1d(seen, held)
    pos = np.searchsorted(universe, seen)
    cost = metrics.segment_sum(stats.cost, pos, universe.size)
    freq = metrics.segment_sum(stats.freq, pos, universe.size)
    mem = metrics.segment_sum(stats.mem, np.searchsorted(universe, held),
                              universe.size)
    return ctrl.on_interval(
        KeyStats(keys=universe, cost=cost, mem=mem, freq=freq), force=True)


def _sketch_cycle(ctrl: RebalanceController, stats: KeyStats):
    """The sketch engine interval: activity ingest + zero-cost state-size
    ingest, then the O(head) round (snapshot + trigger + plan)."""
    ctrl.ingest(stats.keys, stats.cost, freq=stats.freq)
    ctrl.ingest(stats.keys, np.zeros(stats.keys.size), mem=stats.mem)
    return ctrl.on_interval(None, force=True)


def _cycle(mode: str, stats, assignment, cfg, repeats: int):
    """Best-of-N full interval cycles; returns (seconds, event, ctrl)."""
    run_one = _exact_cycle if mode == "exact" else _sketch_cycle
    best, ev, ctrl = float("inf"), None, None
    for _ in range(repeats):
        c = RebalanceController(
            _fresh(assignment), cfg, algorithm="mixed", stats_mode=mode,
            sketch=SketchConfig() if mode == "sketch" else None)
        t0 = time.perf_counter()
        e = run_one(c, stats)
        dt = time.perf_counter() - t0
        if dt < best:
            best, ev, ctrl = dt, e, c
    return best, ev, ctrl


def _exact_stats_bytes(stats) -> int:
    arrs = (stats.keys, stats.cost, stats.mem, stats.freq)
    return int(sum(a.nbytes for a in arrs if a is not None))


def _sketch_resident_bytes(ctrl) -> int:
    snap = ctrl.last_stats
    snap_bytes = _exact_stats_bytes(snap) if snap is not None else 0
    return int(ctrl.sketch.nbytes) + snap_bytes


def run(ks: Optional[List[int]] = None, full: bool = False,
        smoke: bool = False) -> dict:
    if ks is None:
        if smoke:
            ks = [100_000]
        elif full:
            ks = [100_000, 1_000_000, 10_000_000]
        else:
            ks = [100_000, 1_000_000]
    series: List[dict] = []
    failures: List[str] = []
    points = []
    for k in sorted(set(ks)):
        if k <= THETA_K:
            points.extend((shape, cfg, th, k)
                          for shape, cfg, th in QUALITY_SHAPES)
        else:
            shape, cfg, th = SCALE_SHAPE
            points.append((shape, cfg, th, k))
    for shape, shape_cfg, theta_max, k in points:
        stats, assignment, cfg = _instance(shape_cfg, theta_max, k)
        repeats = 3 if k <= 100_000 else 2
        t_e, ev_e, _ = _cycle("exact", stats, assignment, cfg, repeats)
        t_s, ev_s, ctrl_s = _cycle("sketch", stats, assignment, cfg, repeats)
        # score BOTH plans against the same exact stats
        th_e = metrics.theta_for(stats, ev_e.result.assignment)
        th_s = metrics.theta_for(stats, ev_s.result.assignment)
        mem_exact = _exact_stats_bytes(stats)
        mem_sketch = _sketch_resident_bytes(ctrl_s)
        speedup = t_e / t_s if t_s > 0 else float("inf")
        point = dict(shape=shape, k=k)
        series.append({**point, "mode": "exact", "seconds": t_e,
                       "theta": th_e, "stats_bytes": mem_exact,
                       "table_size": ev_e.result.table_size})
        series.append({**point, "mode": "sketch", "seconds": t_s,
                       "theta": th_s, "stats_bytes": mem_sketch,
                       "table_size": ev_s.result.table_size,
                       "head_keys": int(ctrl_s.last_stats.keys.size),
                       "speedup_vs_exact": speedup})
        if k <= THETA_K and th_s > THETA_RTOL * th_e + THETA_ATOL:
            failures.append(
                f"{shape}/k={k}: sketch theta {th_s:.4f} vs exact "
                f"{th_e:.4f} breaches {THETA_RTOL}x + {THETA_ATOL}")
        if k >= SPEEDUP_K and speedup < SPEEDUP_MIN:
            failures.append(
                f"{shape}/k={k}: sketch cycle {speedup:.2f}x vs exact, "
                f"needs >= {SPEEDUP_MIN}x")
        if mem_sketch > MEM_ABS_CAP:
            failures.append(
                f"{shape}/k={k}: sketch resident {mem_sketch} B > "
                f"absolute cap {MEM_ABS_CAP} B")
        if k >= SPEEDUP_K and mem_sketch > mem_exact / MEM_RATIO:
            failures.append(
                f"{shape}/k={k}: sketch resident {mem_sketch} B > "
                f"exact/{MEM_RATIO} ({mem_exact // MEM_RATIO} B)")
    return {"ks": ks, "theta_rtol": THETA_RTOL, "theta_atol": THETA_ATOL,
            "speedup_min": SPEEDUP_MIN, "speedup_k": SPEEDUP_K,
            "series": series, "failures": failures, "ok": not failures}


def rows(quick: bool = True):
    """run.py harness adapter (smoke-sized: K=1e5, all quality shapes)."""
    r = run(smoke=True) if quick else run()
    out = []
    by_point = {}
    for s in r["series"]:
        by_point.setdefault((s["shape"], s["k"]), {})[s["mode"]] = s
    for (shape, k), modes in sorted(by_point.items()):
        for mode, s in sorted(modes.items()):
            out.append((f"sketch_scaling/{shape}/k{k}/{mode}",
                        s["seconds"] * 1e6,
                        f"theta={s['theta']:.4f};"
                        f"bytes={s['stats_bytes']}"))
        if "sketch" in modes:
            out.append((f"sketch_scaling/{shape}/k{k}/speedup", 0.0,
                        f"{modes['sketch']['speedup_vs_exact']:.1f}x;"
                        f"ok={r['ok']}"))
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="K=1e5 only (CI): theta-quality contract on all "
                         "shapes in seconds of wall time")
    ap.add_argument("--full", action="store_true",
                    help="extend the sweep to K=1e7")
    ap.add_argument("--out", default=None,
                    help="write JSON here instead of stdout")
    ap.add_argument("--ks", default=None,
                    help="comma-separated explicit K sweep (overrides "
                         "--smoke/--full)")
    args = ap.parse_args()
    ks = ([int(x) for x in args.ks.split(",")] if args.ks else None)
    t0 = time.time()
    result = run(ks=ks, full=args.full, smoke=args.smoke)
    result["wall_s"] = time.time() - t0
    blob = json.dumps(result, indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(blob + "\n")
        print(f"wrote {args.out}: ok={result['ok']}", file=sys.stderr)
    else:
        print(blob)
    if not result["ok"]:
        for msg in result["failures"]:
            print(f"QUALITY FAILURE: {msg}", file=sys.stderr)
    sys.exit(0 if result["ok"] else 1)


if __name__ == "__main__":
    main()
