"""A/B microbenchmark: vectorized engine fast path vs per-tuple baseline.

Drives the fig13 workload (k=3000, z=0.9 WordCount stream under the Mixed
controller) through ``KeyedStage`` twice — ``vectorized=False`` (the
per-tuple reference loop) and ``vectorized=True`` (argsort dispatch +
batched operators + segment-sum stats) — timing only ``process_interval``
(the engine hot path; workload generation is identical and excluded).

Run directly for JSON output (both tuples/sec numbers + speedup):

    PYTHONPATH=src:. python benchmarks/engine_fastpath.py [--full] [--out f]

or via the harness: ``python benchmarks/run.py --only engine_fastpath``.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from typing import List

import numpy as np

from repro.core import (Assignment, BalanceConfig, ModHash,
                        RebalanceController)
from repro.streams import KeyedStage, WordCount, WorkloadGen

FIG13_WORKLOAD = dict(k=3_000, z=0.9, f=1.0)


def _measure(vectorized: bool, tuples_per_interval: int, intervals: int,
             n_tasks: int = 10, window: int = 2, seed: int = 0) -> dict:
    gen = WorkloadGen(seed=seed, window=window, **FIG13_WORKLOAD)
    controller = RebalanceController(
        Assignment(ModHash(n_tasks, seed=seed)),
        BalanceConfig(theta_max=0.08, table_max=3_000, window=window),
        algorithm="mixed")
    stage = KeyedStage(WordCount(), controller, window=window,
                       vectorized=vectorized)
    batches: List[np.ndarray] = []
    for i in range(intervals):
        if i:
            gen.interval(controller.assignment)
        batches.append(gen.draw_tuples(tuples_per_interval).astype(np.int64))
    elapsed = 0.0
    for keys in batches:
        t0 = time.perf_counter()
        stage.process_interval_arrays(keys, None)
        elapsed += time.perf_counter() - t0
    total = intervals * tuples_per_interval
    return {
        "vectorized": vectorized,
        "tuples": total,
        "seconds": elapsed,
        "tuples_per_sec": total / elapsed,
        "mean_throughput_model": float(np.mean(
            [r.throughput for r in stage.reports[1:]])),
        "rebalances": sum(1 for ev in controller.history if ev.triggered),
    }


def run(quick: bool = True) -> dict:
    # fig13's full interval size; quick mode trims intervals/repeats, not the
    # per-interval tuple count (segment dedup — and thus the fast path's
    # advantage — scales with interval size, so shrinking it would benchmark
    # a different workload than the figure).
    n = 40_000
    intervals = 4 if quick else 8
    repeats = 2 if quick else 3
    baseline = min((_measure(False, n, intervals) for _ in range(repeats)),
                   key=lambda r: r["seconds"])
    fast = min((_measure(True, n, intervals) for _ in range(repeats)),
               key=lambda r: r["seconds"])
    return {
        "workload": {"figure": "fig13", **FIG13_WORKLOAD,
                     "tuples_per_interval": n, "intervals": intervals,
                     "operator": "wordcount"},
        "baseline_tuples_per_sec": baseline["tuples_per_sec"],
        "vectorized_tuples_per_sec": fast["tuples_per_sec"],
        "speedup": fast["tuples_per_sec"] / baseline["tuples_per_sec"],
        "baseline": baseline,
        "vectorized": fast,
    }


def rows(quick: bool = True):
    r = run(quick)
    us_base = 1e6 / r["baseline_tuples_per_sec"]
    us_fast = 1e6 / r["vectorized_tuples_per_sec"]
    return [
        ("engine_fastpath/per_tuple_baseline", us_base,
         f"tuples_per_sec={r['baseline_tuples_per_sec']:.0f}"),
        ("engine_fastpath/vectorized", us_fast,
         f"tuples_per_sec={r['vectorized_tuples_per_sec']:.0f};"
         f"speedup={r['speedup']:.1f}x"),
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="more intervals (8 vs 4) and repeats (3 vs 2); the "
                         "40k-tuple interval size is the same in both modes")
    ap.add_argument("--out", default=None,
                    help="write JSON here instead of stdout")
    args = ap.parse_args()
    result = run(quick=not args.full)
    blob = json.dumps(result, indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(blob + "\n")
        print(f"wrote {args.out}: speedup {result['speedup']:.1f}x",
              file=sys.stderr)
    else:
        print(blob)


if __name__ == "__main__":
    main()
