"""A/B microbenchmarks for the engine hot path.

Two A/Bs, both timing only ``process_interval`` (workload generation is
identical and excluded), with parity asserted per point:

1. **Dispatch A/B** — the fig13 workload (k=3000, z=0.9 WordCount stream
   under the Mixed controller) through ``KeyedStage`` twice:
   ``vectorized=False`` (the per-tuple reference loop) vs
   ``vectorized=True`` (argsort dispatch + batched operators + segment-sum
   stats).
2. **Store-backend A/B** — a large-key-domain windowed workload (K=1e5,
   window=4, rebalances active: the regime the paper's protocol pays per
   interval) through the vectorized engine twice: ``state_backend="object"``
   (dict-of-KeyState store, per-key Python at every interval boundary and
   migration) vs ``state_backend="columnar"`` (flat arrays + whole-interval
   single dispatch). Reports must be bit-identical; the JSON records both
   throughputs and the speedup.
3. **Host-vs-device A/B** — the same K=1e5/window=4/rebalancing regime
   under a Hash32 router (the device backend's requirement) through
   ``state_backend="columnar"`` (host arrays) vs ``state_backend="device"``
   (device-resident ring, one fused jitted step per interval). Parity is
   asserted per repeat; the run FAILS (AssertionError) if the device side
   is not at least ``REPRO_DEVICE_AB_MIN``x faster end-to-end (default
   2.0; set the env var to 0 to disable, e.g. on machines where jax falls
   back to an emulated backend).

Run directly for JSON output:

    PYTHONPATH=src:. python benchmarks/engine_fastpath.py [--full] [--out f]

or via the harness: ``python benchmarks/run.py --only engine_fastpath``.
The emitted JSON also carries a flat ``series`` list (name -> seconds) that
``benchmarks/check_perf_gate.py --fastpath-fresh/--fastpath-baseline`` gates
against the committed ``benchmarks/engine_fastpath.json`` baseline.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import List

import numpy as np

from repro.core import (Assignment, BalanceConfig, ModHash,
                        RebalanceController)
from repro.core.balancer.hashing import Hash32
from repro.streams import KeyedStage, WordCount, WorkloadGen

FIG13_WORKLOAD = dict(k=3_000, z=0.9, f=1.0)
# the store-backend A/B regime: large key domain, window > 1, frequent
# rebalance — per-interval store costs dominate exactly here
STORE_AB_WORKLOAD = dict(k=100_000, z=0.9, f=1.0)
STORE_AB_WINDOW = 4

REPORT_FIELDS = ("interval", "tuples", "makespan", "migration_stall",
                 "throughput", "skewness", "theta", "migrated_bytes",
                 "table_size", "buffered")


def _make_batches(gen: WorkloadGen, controller: RebalanceController,
                  tuples_per_interval: int, intervals: int) -> List[np.ndarray]:
    batches: List[np.ndarray] = []
    for i in range(intervals):
        if i:
            gen.interval(controller.assignment)
        batches.append(gen.draw_tuples(tuples_per_interval).astype(np.int64))
    return batches


def _drive(stage: KeyedStage, batches: List[np.ndarray]) -> float:
    elapsed = 0.0
    for keys in batches:
        t0 = time.perf_counter()
        stage.process_interval_arrays(keys, None)
        elapsed += time.perf_counter() - t0
    return elapsed


def _measure(vectorized: bool, tuples_per_interval: int, intervals: int,
             n_tasks: int = 10, window: int = 2, seed: int = 0) -> dict:
    gen = WorkloadGen(seed=seed, window=window, **FIG13_WORKLOAD)
    controller = RebalanceController(
        Assignment(ModHash(n_tasks, seed=seed)),
        BalanceConfig(theta_max=0.08, table_max=3_000, window=window),
        algorithm="mixed")
    stage = KeyedStage(WordCount(), controller, window=window,
                       vectorized=vectorized)
    batches = _make_batches(gen, controller, tuples_per_interval, intervals)
    elapsed = _drive(stage, batches)
    total = intervals * tuples_per_interval
    return {
        "vectorized": vectorized,
        "tuples": total,
        "seconds": elapsed,
        "tuples_per_sec": total / elapsed,
        "mean_throughput_model": float(np.mean(
            [r.throughput for r in stage.reports[1:]])),
        "rebalances": sum(1 for ev in controller.history if ev.triggered),
    }


def _store_stage(backend: str, window: int, n_tasks: int,
                 seed: int) -> KeyedStage:
    controller = RebalanceController(
        Assignment(ModHash(n_tasks, seed=seed)),
        BalanceConfig(theta_max=0.08, table_max=3_000, window=window),
        algorithm="mixed")
    return KeyedStage(WordCount(), controller, window=window,
                      vectorized=True, state_backend=backend)


def _assert_store_parity(col: KeyedStage, obj: KeyedStage) -> None:
    assert len(col.reports) == len(obj.reports)
    for rc, ro in zip(col.reports, obj.reports):
        for field in REPORT_FIELDS:
            assert getattr(rc, field) == getattr(ro, field), (
                f"store-backend parity violated on {field} at interval "
                f"{rc.interval}: columnar={getattr(rc, field)!r} "
                f"object={getattr(ro, field)!r}")
        assert np.array_equal(rc.task_loads, ro.task_loads), \
            f"task_loads diverged at interval {rc.interval}"
    assert col.total_state_keys() == obj.total_state_keys()


def _measure_store_backends(tuples_per_interval: int, intervals: int,
                            n_tasks: int = 10, seed: int = 0) -> dict:
    window = STORE_AB_WINDOW
    gen = WorkloadGen(seed=seed, window=window, **STORE_AB_WORKLOAD)
    # one shared stream: both backends must see identical traffic for the
    # per-point parity assertion to be meaningful
    probe = RebalanceController(
        Assignment(ModHash(n_tasks, seed=seed)),
        BalanceConfig(theta_max=0.08, table_max=3_000, window=window),
        algorithm="mixed")
    batches = _make_batches(gen, probe, tuples_per_interval, intervals)
    stages = {b: _store_stage(b, window, n_tasks, seed)
              for b in ("object", "columnar")}
    seconds = {b: _drive(stage, batches) for b, stage in stages.items()}
    _assert_store_parity(stages["columnar"], stages["object"])
    total = intervals * tuples_per_interval
    rebalances = sum(1 for ev in stages["columnar"].controller.history
                     if ev.triggered)
    assert rebalances > 0, "store A/B must exercise live rebalances"
    return {
        "workload": {**STORE_AB_WORKLOAD, "window": window,
                     "tuples_per_interval": tuples_per_interval,
                     "intervals": intervals, "n_tasks": n_tasks,
                     "operator": "wordcount"},
        "tuples": total,
        "object_seconds": seconds["object"],
        "columnar_seconds": seconds["columnar"],
        "object_tuples_per_sec": total / seconds["object"],
        "columnar_tuples_per_sec": total / seconds["columnar"],
        "speedup": seconds["object"] / seconds["columnar"],
        "rebalances": rebalances,
        "parity": True,                     # _assert_store_parity raised if not
    }


def _hash32_stage(backend: str, window: int, n_tasks: int,
                  seed: int) -> KeyedStage:
    controller = RebalanceController(
        Assignment(Hash32(n_tasks, seed=seed)),
        BalanceConfig(theta_max=0.08, table_max=3_000, window=window),
        algorithm="mixed")
    return KeyedStage(WordCount(), controller, window=window,
                      vectorized=True, state_backend=backend)


def _measure_device_backend(tuples_per_interval: int, intervals: int,
                            n_tasks: int = 10, seed: int = 0) -> dict:
    """Host (columnar) vs device state backend, same Hash32 traffic.

    Both sides run the identical tuple stream under Hash32 routing (the
    device backend's requirement); the per-interval reports must match
    bit-for-bit, so the timing difference is purely the state
    representation: host flat arrays + per-interval dispatch vs
    device-resident ring + one fused jitted step."""
    window = STORE_AB_WINDOW
    gen = WorkloadGen(seed=seed, window=window, **STORE_AB_WORKLOAD)
    probe = RebalanceController(
        Assignment(Hash32(n_tasks, seed=seed)),
        BalanceConfig(theta_max=0.08, table_max=3_000, window=window),
        algorithm="mixed")
    batches = _make_batches(gen, probe, tuples_per_interval, intervals)
    stages = {b: _hash32_stage(b, window, n_tasks, seed)
              for b in ("columnar", "device")}
    seconds = {b: _drive(stage, batches) for b, stage in stages.items()}
    _assert_store_parity(stages["device"], stages["columnar"])
    total = intervals * tuples_per_interval
    rebalances = sum(1 for ev in stages["device"].controller.history
                     if ev.triggered)
    assert rebalances > 0, "device A/B must exercise live rebalances"
    return {
        "workload": {**STORE_AB_WORKLOAD, "window": window,
                     "tuples_per_interval": tuples_per_interval,
                     "intervals": intervals, "n_tasks": n_tasks,
                     "operator": "wordcount", "router": "hash32"},
        "tuples": total,
        "host_seconds": seconds["columnar"],
        "device_seconds": seconds["device"],
        "host_tuples_per_sec": total / seconds["columnar"],
        "device_tuples_per_sec": total / seconds["device"],
        "speedup": seconds["columnar"] / seconds["device"],
        "rebalances": rebalances,
        "parity": True,                     # _assert_store_parity raised if not
    }


def run(quick: bool = True) -> dict:
    # fig13's full interval size; quick mode trims intervals/repeats, not the
    # per-interval tuple count (segment dedup — and thus the fast path's
    # advantage — scales with interval size, so shrinking it would benchmark
    # a different workload than the figure).
    n = 40_000
    intervals = 4 if quick else 8
    repeats = 2 if quick else 3
    baseline = min((_measure(False, n, intervals) for _ in range(repeats)),
                   key=lambda r: r["seconds"])
    fast = min((_measure(True, n, intervals) for _ in range(repeats)),
               key=lambda r: r["seconds"])
    # store A/B: K=1e5 needs interval size >= domain to keep most keys hot.
    # Parity is asserted inside every repeat; timing takes the best repeat
    # PER BACKEND independently (same rule as the dispatch A/B above) so a
    # noise spike on one side cannot fail the gate or skew the speedup.
    store_n = 150_000
    store_intervals = 3 if quick else 6
    store_runs = [_measure_store_backends(store_n, store_intervals)
                  for _ in range(repeats)]
    store = dict(min(store_runs, key=lambda r: r["columnar_seconds"]))
    store["object_seconds"] = min(r["object_seconds"] for r in store_runs)
    store["columnar_seconds"] = min(r["columnar_seconds"] for r in store_runs)
    store["object_tuples_per_sec"] = store["tuples"] / store["object_seconds"]
    store["columnar_tuples_per_sec"] = (store["tuples"]
                                        / store["columnar_seconds"])
    store["speedup"] = store["object_seconds"] / store["columnar_seconds"]
    # host-vs-device A/B: min per side across repeats — the first device
    # repeat pays one-time jit traces; the jit caches are module-level, so
    # later repeats time the steady state the backend actually runs at.
    dev_runs = [_measure_device_backend(store_n, store_intervals)
                for _ in range(repeats)]
    device = dict(min(dev_runs, key=lambda r: r["device_seconds"]))
    device["host_seconds"] = min(r["host_seconds"] for r in dev_runs)
    device["device_seconds"] = min(r["device_seconds"] for r in dev_runs)
    device["host_tuples_per_sec"] = device["tuples"] / device["host_seconds"]
    device["device_tuples_per_sec"] = (device["tuples"]
                                       / device["device_seconds"])
    device["speedup"] = device["host_seconds"] / device["device_seconds"]
    min_speedup = float(os.environ.get("REPRO_DEVICE_AB_MIN", "2.0"))
    device["min_speedup"] = min_speedup
    assert device["speedup"] >= min_speedup, (
        f"device backend speedup {device['speedup']:.2f}x fell below the "
        f"{min_speedup:.1f}x floor (set REPRO_DEVICE_AB_MIN=0 to disable)")
    return {
        "workload": {"figure": "fig13", **FIG13_WORKLOAD,
                     "tuples_per_interval": n, "intervals": intervals,
                     "operator": "wordcount"},
        "baseline_tuples_per_sec": baseline["tuples_per_sec"],
        "vectorized_tuples_per_sec": fast["tuples_per_sec"],
        "speedup": fast["tuples_per_sec"] / baseline["tuples_per_sec"],
        "baseline": baseline,
        "vectorized": fast,
        "store_backend": store,
        "device_backend": device,
        # flat points for check_perf_gate.py (name -> seconds)
        "series": [
            {"name": "per_tuple_baseline", "seconds": baseline["seconds"]},
            {"name": "vectorized", "seconds": fast["seconds"]},
            {"name": "store_object", "seconds": store["object_seconds"]},
            {"name": "store_columnar", "seconds": store["columnar_seconds"]},
            {"name": "store_host_hash32", "seconds": device["host_seconds"]},
            {"name": "store_device", "seconds": device["device_seconds"]},
        ],
    }


def rows(quick: bool = True):
    r = run(quick)
    us_base = 1e6 / r["baseline_tuples_per_sec"]
    us_fast = 1e6 / r["vectorized_tuples_per_sec"]
    st = r["store_backend"]
    dv = r["device_backend"]
    return [
        ("engine_fastpath/per_tuple_baseline", us_base,
         f"tuples_per_sec={r['baseline_tuples_per_sec']:.0f}"),
        ("engine_fastpath/vectorized", us_fast,
         f"tuples_per_sec={r['vectorized_tuples_per_sec']:.0f};"
         f"speedup={r['speedup']:.1f}x"),
        ("engine_fastpath/store_object", 1e6 / st["object_tuples_per_sec"],
         f"tuples_per_sec={st['object_tuples_per_sec']:.0f};"
         f"k={st['workload']['k']};window={st['workload']['window']}"),
        ("engine_fastpath/store_columnar", 1e6 / st["columnar_tuples_per_sec"],
         f"tuples_per_sec={st['columnar_tuples_per_sec']:.0f};"
         f"speedup={st['speedup']:.1f}x;parity=ok"),
        ("engine_fastpath/store_host_hash32",
         1e6 / dv["host_tuples_per_sec"],
         f"tuples_per_sec={dv['host_tuples_per_sec']:.0f};"
         f"k={dv['workload']['k']};window={dv['workload']['window']}"),
        ("engine_fastpath/store_device", 1e6 / dv["device_tuples_per_sec"],
         f"tuples_per_sec={dv['device_tuples_per_sec']:.0f};"
         f"speedup={dv['speedup']:.1f}x;parity=ok"),
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="more intervals and repeats; interval sizes are the "
                         "same in both modes")
    ap.add_argument("--out", default=None,
                    help="write JSON here instead of stdout")
    args = ap.parse_args()
    result = run(quick=not args.full)
    blob = json.dumps(result, indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(blob + "\n")
        print(f"wrote {args.out}: dispatch speedup {result['speedup']:.1f}x, "
              f"store-backend speedup "
              f"{result['store_backend']['speedup']:.1f}x, "
              f"host-vs-device speedup "
              f"{result['device_backend']['speedup']:.1f}x",
              file=sys.stderr)
    else:
        print(blob)


if __name__ == "__main__":
    main()
