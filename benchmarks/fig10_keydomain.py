"""Fig. 10: plan time + migration cost vs key-domain size K."""

from repro.core.balancer import mintable, mixed

from .common import timed, workload


def rows(quick=True):
    out = []
    ks = (5_000, 10_000, 100_000) if quick else (5_000, 10_000, 100_000,
                                                 1_000_000)
    for k in ks:
        for w in (1, 5):
            _, stats, a, cfg = workload(k=k, window=w)
            total = stats.mem.sum()
            for name, algo in (("mixed", mixed), ("mintable", mintable)):
                res, us = timed(algo, stats, a, cfg, repeats=1)
                out.append((f"fig10/{name}_k{k}_w{w}", us,
                            f"mig_frac={res.migration_cost/total:.4f}"))
    return out
