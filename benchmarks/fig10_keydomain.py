"""Fig. 10: plan time + migration cost vs key-domain size K.

Beyond the paper's sweep, a ``mixed_sketch`` series rides along: the full
sketch-mode controller interval cycle (streaming ``ingest`` + O(head)
snapshot/trigger/plan, see ``repro.core.balancer.sketch``), with the
controller-resident stats bytes reported per point next to the exact
arrays' O(K) footprint. The non-quick sweep extends to K=1e7, where only
the sketch series runs — materializing exact O(K) stats per interval is
capped at K=1e6, which is precisely the scaling wall the sketch removes.
"""

import dataclasses

import numpy as np

from repro.core import RebalanceController
from repro.core.balancer import SketchConfig, mintable, mixed

from .common import timed, workload

EXACT_K_CAP = 1_000_000


def _sketch_cycle(stats, a, cfg):
    ctrl = RebalanceController(
        dataclasses.replace(a, table=dict(a.table)), cfg,
        algorithm="mixed", stats_mode="sketch", sketch=SketchConfig())
    ctrl.ingest(stats.keys, stats.cost, freq=stats.freq)
    ctrl.ingest(stats.keys, np.zeros(stats.keys.size), mem=stats.mem)
    return ctrl, ctrl.on_interval(None, force=True)


def rows(quick=True):
    out = []
    ks = (5_000, 10_000, 100_000) if quick else (5_000, 10_000, 100_000,
                                                 1_000_000, 10_000_000)
    for k in ks:
        for w in (1, 5):
            _, stats, a, cfg = workload(k=k, window=w)
            total = stats.mem.sum()
            exact_bytes = int(sum(x.nbytes for x in
                                  (stats.keys, stats.cost, stats.mem,
                                   stats.freq)))
            if k <= EXACT_K_CAP:
                for name, algo in (("mixed", mixed), ("mintable", mintable)):
                    res, us = timed(algo, stats, a, cfg, repeats=1)
                    out.append((f"fig10/{name}_k{k}_w{w}", us,
                                f"mig_frac={res.migration_cost/total:.4f};"
                                f"stats_bytes={exact_bytes}"))
            (ctrl, ev), us = timed(_sketch_cycle, stats, a, cfg, repeats=1)
            snap = ctrl.last_stats
            resident = int(ctrl.sketch.nbytes) + int(sum(
                x.nbytes for x in (snap.keys, snap.cost, snap.mem, snap.freq)
                if x is not None))
            out.append((f"fig10/mixed_sketch_k{k}_w{w}", us,
                        f"mig_frac={ev.result.migration_cost/total:.4f};"
                        f"stats_bytes={resident}"))
    return out
