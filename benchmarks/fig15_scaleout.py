"""Fig. 15: elastic scale-out — rebalance response time and recovery.

Two sweeps:

* the original single-device rows (mixed vs readj on the host columnar
  store), now fed through the array-native ``process_interval_arrays``
  entry point so the timing measures ``scale_to`` + the engine, not
  per-tuple Python list construction;
* an ``n_devices`` sweep over the sharded device backend — the same
  scale-out scenario with per-key state partitioned over a JAX mesh
  (``n_shards`` virtual devices; run under
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to sweep past 1).
  Each sharded run asserts bit-parity of the recovery interval's totals
  against the single-device columnar row's oracle quantities.

On CPU the sharded rows are a correctness/latency probe, not a speedup
claim — virtual devices share the host; see docs/architecture.md
("Sharded streaming").
"""

import numpy as np

from repro.core import Assignment, BalanceConfig, ModHash, RebalanceController
from repro.core.balancer.hashing import Hash32
from repro.streams import KeyedStage, WordCount, WorkloadGen

from .common import timed

_INTERVALS = 3          # warm-up intervals before the scale-out
_SCALE_FROM, _SCALE_TO = 9, 10


def _drive(stage, gen, n):
    """Warm-up intervals -> timed scale_to -> one recovery interval."""
    for i in range(_INTERVALS):
        if i:
            gen.interval(stage.controller.assignment)
        keys = np.asarray(gen.draw_tuples(n), dtype=np.int64)
        vals = np.full(keys.shape[0], i, dtype=np.int64)
        stage.process_interval_arrays(keys, vals)
    _, us = timed(stage.scale_to, _SCALE_TO, repeats=1)
    gen.interval(stage.controller.assignment)
    keys = np.asarray(gen.draw_tuples(n), dtype=np.int64)
    vals = np.full(keys.shape[0], _SCALE_FROM, dtype=np.int64)
    rep = stage.process_interval_arrays(keys, vals)
    return us, rep


def _stage(algo, hash_cls, **stage_kw):
    controller = RebalanceController(
        Assignment(hash_cls(_SCALE_FROM, seed=0)),
        BalanceConfig(theta_max=0.1, table_max=3_000, window=2),
        algorithm=algo)
    return KeyedStage(WordCount(), controller, window=2, **stage_kw)


def rows(quick=True):
    out = []
    n = 8_000 if quick else 40_000
    for algo in ("mixed", "readj"):
        gen = WorkloadGen(k=3_000, z=0.9, f=0.3, seed=0, window=2)
        us, rep = _drive(_stage(algo, ModHash), gen, n)
        out.append((f"fig15/scaleout_{algo}", us,
                    f"skew_after={rep.skewness:.2f};"
                    f"new_worker_share="
                    f"{rep.task_loads[_SCALE_FROM]/rep.task_loads.mean():.2f}"))

    # -- n_devices sweep: sharded backend over the available mesh -------------
    # oracle: the same scenario on the single-device columnar store (Hash32
    # so routing is identical to the sharded runs)
    gen = WorkloadGen(k=3_000, z=0.9, f=0.3, seed=0, window=2)
    _, oracle = _drive(_stage("mixed", Hash32), gen, n)

    import jax
    dc = jax.device_count()
    for d in sorted({1, min(2, dc), dc}):
        gen = WorkloadGen(k=3_000, z=0.9, f=0.3, seed=0, window=2)
        stage = _stage("mixed", Hash32, state_backend="sharded", n_shards=d)
        us, rep = _drive(stage, gen, n)
        assert rep.task_loads.tolist() == oracle.task_loads.tolist(), \
            f"sharded n_devices={d} diverged from the columnar oracle"
        assert abs(rep.skewness - oracle.skewness) < 1e-9
        out.append((f"fig15/scaleout_sharded_d{d}", us,
                    f"n_devices={d};skew_after={rep.skewness:.2f};"
                    f"parity=ok"))
    return out
