"""Fig. 15: elastic scale-out — rebalance response time and recovery."""

import numpy as np

from repro.core import Assignment, BalanceConfig, ModHash, RebalanceController
from repro.streams import KeyedStage, WordCount, WorkloadGen

from .common import timed


def rows(quick=True):
    out = []
    n = 8_000 if quick else 40_000
    for algo in ("mixed", "readj"):
        gen = WorkloadGen(k=3_000, z=0.9, f=0.3, seed=0, window=2)
        controller = RebalanceController(
            Assignment(ModHash(9, seed=0)),
            BalanceConfig(theta_max=0.1, table_max=3_000, window=2),
            algorithm=algo)
        stage = KeyedStage(WordCount(), controller, window=2)
        for i in range(3):
            if i:
                gen.interval(stage.controller.assignment)
            stage.process_interval(
                [(int(k), i) for k in gen.draw_tuples(n)])
        _, us = timed(stage.scale_to, 10, repeats=1)
        gen.interval(stage.controller.assignment)
        rep = stage.process_interval(
            [(int(k), 9) for k in gen.draw_tuples(n)])
        out.append((f"fig15/scaleout_{algo}", us,
                    f"skew_after={rep.skewness:.2f};"
                    f"new_worker_share={rep.task_loads[9]/rep.task_loads.mean():.2f}"))
    return out
