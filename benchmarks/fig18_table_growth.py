"""Fig. 18 (appendix): routing-table growth over repeated adjustments
(MinMig, no table constraint) -> converges to K*(N_D-1)/N_D."""

from repro.core import Assignment, BalanceConfig, ModHash
from repro.core.balancer import minmig
from repro.streams import WorkloadGen


def rows(quick=True):
    out = []
    k = 2_000 if quick else 10_000
    for th in (0.02, 0.3):
        gen = WorkloadGen(k=k, z=0.85, f=1.0, seed=0)
        a = Assignment(ModHash(15, seed=0))
        cfg = BalanceConfig(theta_max=th, table_max=10**9)
        sizes = []
        for i in range(6 if quick else 20):
            stats = gen.interval(a, fluctuate=i > 0)
            res = minmig(stats, a, cfg)
            a = res.assignment
            sizes.append(res.table_size)
        bound = k * 14 / 15
        out.append((f"fig18/minmig_growth_th{th}", 0.0,
                    f"final_table={sizes[-1]};bound={bound:.0f};"
                    f"frac_of_bound={sizes[-1]/bound:.2f}"))
    return out
