"""The paper's Social-media word-count topology at benchmark scale, with
algorithm comparison (hash vs readj vs mixed) printed side by side. Run:
  PYTHONPATH=src python examples/stream_wordcount.py
"""

import numpy as np

from repro.core import (Assignment, BalanceConfig, ModHash,
                        RebalanceController)
from repro.streams import KeyedStage, WordCount, WorkloadGen


def run(algorithm: str, theta_max: float) -> dict:
    gen = WorkloadGen(k=8_000, z=1.05, f=0.25, seed=1, window=2)
    controller = RebalanceController(
        Assignment(ModHash(10, seed=1)),
        BalanceConfig(theta_max=theta_max, table_max=2_000, window=2),
        algorithm=algorithm)
    stage = KeyedStage(WordCount(), controller, window=2)
    for i in range(6):
        if i:
            gen.interval(controller.assignment)
        stage.process_interval([(int(k), i) for k in gen.draw_tuples(30_000)])
    reps = stage.reports[2:]
    return {
        "throughput": float(np.mean([r.throughput for r in reps])),
        "skew": float(np.mean([r.skewness for r in reps])),
        "migrated": float(np.sum([r.migrated_bytes for r in reps])),
        "plan_ms": float(np.mean([r.plan_time_s for r in reps]) * 1e3),
    }


def main() -> None:
    rows = [("hash-only", run("mixed", 1e9)),
            ("readj", run("readj", 0.08)),
            ("mixed (paper)", run("mixed", 0.08))]
    print(f"{'policy':>14} {'throughput':>11} {'skew':>6} "
          f"{'migrated':>10} {'plan ms':>8}")
    for name, r in rows:
        print(f"{name:>14} {r['throughput']:>11.2f} {r['skew']:>6.2f} "
              f"{r['migrated']:>10.0f} {r['plan_ms']:>8.1f}")


if __name__ == "__main__":
    main()
