"""Multi-stage topology demo: filter -> word count -> top-k front, every
stage key-partitioned over its own task fleet with its own Mixed controller
(the paper's protocol runs per operator). Prints per-interval pipeline
throughput, the per-stage routing-table sizes, and which stages rebalanced
when. Run:
  PYTHONPATH=src python examples/stream_topology.py
"""

import numpy as np

from repro.streams import (Filter, MergeCounts, StageSpec, Topology,
                           WordCount, WorkloadGen, keyed_stage)


def build_topology(theta_max: float = 0.08) -> Topology:
    # stage 1: selection on (key, payload) — drops ~25% of the stream
    filt = keyed_stage(Filter(lambda k, v: (k + v) % 4 != 0), n_tasks=6,
                      theta_max=theta_max, table_max=1_000, window=2, seed=0)
    # stage 2: windowed word count keyed by the word id
    count = keyed_stage(WordCount(), n_tasks=8, theta_max=theta_max,
                        table_max=2_000, window=2, seed=1)
    # stage 3: top-k front — running max per word bucket
    topk = keyed_stage(MergeCounts(), n_tasks=4, theta_max=theta_max,
                       table_max=300, window=2, seed=2)
    return Topology([
        StageSpec("filter", filt),
        StageSpec("count", count),
        StageSpec("topk", topk, rekey=lambda k, v: k % 64),
    ])


def main() -> None:
    gen = WorkloadGen(k=6_000, z=1.05, f=0.4, seed=1, window=2)
    topo = build_topology()
    print(f"{'iv':>3} {'thr':>8} {'critical':>9} {'buffered':>8} "
          f"{'migrated':>9}  stage tables (rebalanced*)")
    for i in range(8):
        if i:
            gen.interval(topo.specs[0].stage.controller.assignment)
        keys = gen.draw_tuples(20_000).astype(np.int64)
        rep = topo.process_interval(keys, (keys * 7 + i) % 11)
        marks = []
        for spec, sr in zip(topo.specs, rep.stage_reports):
            star = "*" if rep.interval in \
                spec.stage.controller.triggered_intervals() else ""
            marks.append(f"{spec.name}={sr.table_size}{star}")
        print(f"{rep.interval:>3} {rep.throughput:>8.2f} "
              f"{rep.critical_path:>9.1f} {rep.buffered:>8} "
              f"{rep.migrated_bytes:>9.0f}  {' '.join(marks)}")
    by_stage = topo.rebalances_by_stage()
    print("\nrebalances by stage:", by_stage)
    every = set.intersection(*(set(v) for v in by_stage.values()))
    if every:
        print(f"intervals with rebalances at EVERY stage: {sorted(every)}")
    # the top-k front: highest running counts per bucket
    top = {}
    for store in topo["topk"].stores:
        for k, ks in store.keys.items():
            top[k] = max(top.get(k, 0),
                         max(sl.payload["count"] for sl in ks.slices.values()))
    best = sorted(top.items(), key=lambda kv: -kv[1])[:5]
    print("top-5 buckets by running max count:",
          ", ".join(f"{b}:{c}" for b, c in best))


if __name__ == "__main__":
    main()
