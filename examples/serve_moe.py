"""Serving scenario: a smoke-scale MoE model decodes batched requests while
SkewShield keeps expert shards balanced; session routing keeps replica load
even as hot sessions appear. Run:
  PYTHONPATH=src python examples/serve_moe.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.models import init_cache, model_schema, schema
from repro.models.skewshield import SkewShieldPlacer, placements_array
from repro.serve.engine import ServeEngine
from repro.train.train_step import make_serve_step


def main() -> None:
    cfg = smoke_config("granite_moe_3b_a800m")
    params = schema.init(model_schema(cfg), jax.random.PRNGKey(0))
    serve_step = jax.jit(make_serve_step(cfg),
                         static_argnames=())
    max_seq, batch = 128, 4
    cache = init_cache(cfg, batch, max_seq)
    placers = [SkewShieldPlacer(cfg.moe_experts, 4,
                                bytes_per_expert=3 * cfg.d_model * cfg.d_ff * 2,
                                theta_max=0.15)
               for _ in range(cfg.n_layers)]
    rng = np.random.default_rng(0)

    # prefill 16 tokens, decode 24, updating SkewShield from router loads
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (batch, 16)), jnp.int32)
    logits, cache = serve_step(params, cache, {"tokens": tokens}, 0,
                               placements_array(placers))
    out_tokens = []
    for t in range(16, 40):
        nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out_tokens.append(np.asarray(nxt)[:, 0])
        logits, cache = serve_step(params, cache, {"tokens": nxt}, t,
                                   placements_array(placers))
    print("decoded token matrix (batch x steps):")
    print(np.stack(out_tokens, 1))

    # session-level routing across 8 replicas with two hot agents
    eng = ServeEngine(n_replicas=8, theta_max=0.1)
    for i in range(6):
        reqs = [(1, 512, 256), (2, 512, 256)]  # hot sessions
        reqs += [(int(rng.integers(100, 400)), 64, 32) for _ in range(40)]
        r = eng.run_interval(reqs)
        print(f"interval {r.interval}: theta={r.theta:.3f} "
              f"migrated_sessions={r.migrated_sessions} "
              f"kv_moved={r.migrated_kv_bytes/1e6:.1f}MB")


if __name__ == "__main__":
    main()
