"""Quickstart: the paper's technique end to end in 60 lines.

A skewed, fluctuating key stream hits 8 workers; pure hashing leaves one
worker ~2x overloaded; the Mixed controller fixes it each interval with
minimal state migration. Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (Assignment, BalanceConfig, ModHash,
                        RebalanceController)
from repro.streams import KeyedStage, WordCount, WorkloadGen


def main() -> None:
    gen = WorkloadGen(k=5_000, z=1.05, f=0.25, seed=0, window=2)

    controller = RebalanceController(
        Assignment(ModHash(n_dest=8)),
        BalanceConfig(theta_max=0.08,   # per-worker overload tolerance
                      table_max=1_000,  # routing-table budget A_max
                      window=2),        # state window w
        algorithm="mixed")              # paper Alg. 4
    stage = KeyedStage(WordCount(), controller, window=2)

    print(f"{'iv':>3} {'skew':>6} {'theta':>7} {'migrated':>9} "
          f"{'table':>6} {'throughput':>11}")
    for i in range(8):
        if i:
            gen.interval(controller.assignment)      # workload fluctuates
        tuples = [(int(k), i) for k in gen.draw_tuples(20_000)]
        r = stage.process_interval(tuples)
        print(f"{r.interval:>3} {r.skewness:>6.2f} {r.theta:>7.3f} "
              f"{r.migrated_bytes:>9.0f} {r.table_size:>6} "
              f"{r.throughput:>11.2f}")

    print("\nRouting table size stays under A_max; skew pinned near the f-drift floor "
          "after the first rebalance; only Delta(F,F') keys ever paused.")


if __name__ == "__main__":
    main()
